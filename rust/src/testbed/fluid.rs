//! Generic fluid (byte-accurate, fixed-timestep) workflow executor.
//!
//! This is the virtual testbed's core: an *independent* implementation of
//! "what actually happens" that never looks at the analytic solver. All
//! nodes advance **concurrently** in small time steps; data availability is
//! read off producers' current progress, and shared pools are divided per
//! step exactly like the paper's netfilter setup (per-flow caps, released
//! when a flow finishes). Optional multiplicative jitter models OS noise,
//! giving the Fig 7 min/max bars.
//!
//! Agreement between this executor and [`crate::solver`] is a strong
//! end-to-end correctness signal, exercised by property tests.

use crate::pwfn::PwPoly;
use crate::trace::format::{IoSeries, TsvTask, TsvTrace};
use crate::util::Rng;
use crate::workflow::graph::{DataSource, ResourceSource, Workflow};
use crate::{bail, ensure};

/// Executor options.
#[derive(Clone, Debug)]
pub struct FluidOpts {
    /// Time step in seconds.
    pub dt: f64,
    /// Give up after this time.
    pub horizon: f64,
    /// Multiplicative noise: `(seed, sigma)`; rates are scaled by per-node
    /// factors resampled every `jitter_period` seconds.
    pub jitter: Option<(u64, f64)>,
    pub jitter_period: f64,
    /// Record per-node cumulative I/O series at this interval (seconds);
    /// 0 disables recording. Each node also gets a final sample exactly at
    /// its completion, so exported counters match the summary row.
    pub sample_every: f64,
}

impl Default for FluidOpts {
    fn default() -> Self {
        FluidOpts {
            dt: 0.01,
            horizon: 1e5,
            jitter: None,
            jitter_period: 1.0,
            sample_every: 0.0,
        }
    }
}

/// Result of one fluid execution.
#[derive(Clone, Debug)]
pub struct FluidRun {
    pub finish: Vec<Option<f64>>,
    pub makespan: Option<f64>,
    /// Final progress per node.
    pub progress: Vec<f64>,
    /// Steps actually executed (cost accounting: scales with horizon/dt).
    pub steps: usize,
    /// Wall-clock time each node actually started (gating satisfied).
    pub started: Vec<Option<f64>>,
    /// Wall-clock time each node first *consumed* anything (progress or
    /// jump-debt payment) — what a process monitor logs as the task start.
    /// Later than `started` for nodes that sat waiting on input; the trace
    /// exporter uses this as the TSV `start`, so data-stall time is not
    /// double-counted as resource demand by the calibrator.
    pub active: Vec<Option<f64>>,
    /// Total resource actually consumed per node (summed across its
    /// resource inputs; the monitoring ground truth for `pcpu`).
    pub resource_used: Vec<f64>,
    /// Per-node cumulative I/O series (empty unless
    /// [`FluidOpts::sample_every`] > 0). `read` counts input bytes
    /// available to (i.e. ingestible by) the node, `written` its output
    /// bytes — the BPF view of a task that buffers its input.
    pub traces: Vec<IoSeries>,
}

struct NodeState {
    p: f64,
    done: Option<f64>,
    started: bool,
    started_at: Option<f64>,
    active_at: Option<f64>,
    /// outstanding resource-jump debt per resource
    debt: Vec<f64>,
    paid: Vec<Vec<bool>>,
    jitter: f64,
}

/// Execute the workflow with the fluid engine.
pub fn execute(wf: &Workflow, opts: &FluidOpts) -> FluidRun {
    let n = wf.nodes.len();
    let dres: Vec<Vec<PwPoly>> = wf
        .nodes
        .iter()
        .map(|nd| nd.process.res_reqs.iter().map(|r| r.func.derivative()).collect())
        .collect();
    let jumps: Vec<Vec<Vec<(f64, f64)>>> = wf
        .nodes
        .iter()
        .map(|nd| {
            nd.process
                .res_reqs
                .iter()
                .map(|r| {
                    r.func
                        .breaks
                        .iter()
                        .copied()
                        .filter(|b| b.is_finite())
                        .filter_map(|b| {
                            let j = r.func.jump_at(b);
                            (j > 1e-12).then_some((b, j))
                        })
                        .collect()
                })
                .collect()
        })
        .collect();

    let mut rng = opts.jitter.map(|(seed, _)| Rng::new(seed));
    let sigma = opts.jitter.map(|(_, s)| s).unwrap_or(0.0);

    let mut st: Vec<NodeState> = wf
        .nodes
        .iter()
        .enumerate()
        .map(|(i, nd)| NodeState {
            p: 0.0,
            done: if nd.process.max_progress <= 1e-12 {
                Some(nd.start.at)
            } else {
                None
            },
            started: false,
            started_at: None,
            active_at: None,
            debt: vec![0.0; nd.process.res_reqs.len()],
            paid: jumps[i].iter().map(|js| vec![false; js.len()]).collect(),
            jitter: 1.0,
        })
        .collect();

    let dt = opts.dt;
    let mut t = 0.0;
    let mut steps = 0usize;
    let mut next_jitter_refresh = 0.0;
    let mut resource_used = vec![0.0f64; n];
    let mut traces: Vec<IoSeries> = wf
        .nodes
        .iter()
        .map(|nd| IoSeries {
            task: nd.process.name.clone(),
            ..IoSeries::default()
        })
        .collect();
    let mut trace_closed = vec![false; n];
    let mut next_sample = 0.0f64;

    while t < opts.horizon && st.iter().any(|s| s.done.is_none()) {
        steps += 1;
        // refresh jitter factors
        if let Some(r) = rng.as_mut() {
            if t >= next_jitter_refresh {
                for s in st.iter_mut() {
                    s.jitter = r.jitter(sigma);
                }
                next_jitter_refresh = t + opts.jitter_period;
            }
        }

        // start gating
        for i in 0..n {
            if !st[i].started && st[i].done.is_none() {
                let nd = &wf.nodes[i];
                let ok = t >= nd.start.at
                    && nd.start.after.iter().all(|&d| st[d].done.is_some());
                if ok {
                    st[i].started = true;
                    st[i].started_at = Some(t);
                }
            }
        }

        // pool bookkeeping: per-pool, fraction users are capped; residual
        // users share what is left after the fraction users' actual usage
        let mut pool_used = vec![0.0f64; wf.pools.len()];
        let mut pool_active_others: Vec<usize> = vec![0; wf.pools.len()];
        for (i, nd) in wf.nodes.iter().enumerate() {
            if st[i].done.is_none() && st[i].started {
                for s in &nd.resource_sources {
                    let pid = match s {
                        ResourceSource::PoolFraction { pool, .. } => Some(*pool),
                        ResourceSource::PoolResidual { pool } => Some(*pool),
                        _ => None,
                    };
                    if let Some(p) = pid {
                        pool_active_others[p] += 1;
                    }
                }
            }
        }

        // two phases: fraction users first (their caps don't depend on
        // others), then residual users with the remainder
        for phase in 0..2 {
            for i in 0..n {
                if st[i].done.is_some() || !st[i].started {
                    continue;
                }
                let nd = &wf.nodes[i];
                let is_residual = nd
                    .resource_sources
                    .iter()
                    .any(|s| matches!(s, ResourceSource::PoolResidual { .. }));
                if (phase == 0) == is_residual {
                    continue;
                }

                // data limit
                let mut p_cap = nd.process.max_progress;
                for (k, src) in nd.data_sources.iter().enumerate() {
                    let avail = match src {
                        DataSource::External(f) => f.eval(t),
                        DataSource::ProcessOutput { node, output } => {
                            wf.nodes[*node].process.outputs[*output].func.eval(st[*node].p)
                        }
                    };
                    p_cap = p_cap.min(nd.process.data_reqs[k].func.eval(avail));
                }

                // resource limit
                let mut dp = p_cap - st[i].p;
                for (l, src) in nd.resource_sources.iter().enumerate() {
                    let alloc = match src {
                        ResourceSource::Fixed(f) => f.eval(t),
                        ResourceSource::PoolFraction { pool, fraction } => {
                            let cap = wf.pools[*pool].capacity.eval(t);
                            // released to full capacity when alone on pool
                            if pool_active_others[*pool] <= 1 {
                                cap
                            } else {
                                cap * fraction
                            }
                        }
                        ResourceSource::PoolResidual { pool } => {
                            (wf.pools[*pool].capacity.eval(t) - pool_used[*pool]).max(0.0)
                        }
                    } * st[i].jitter;
                    // pay jump debt
                    if st[i].debt[l] > 0.0 {
                        if alloc * dt > 0.0 {
                            st[i].active_at.get_or_insert(t);
                        }
                        resource_used[i] += (alloc * dt).min(st[i].debt[l]);
                        st[i].debt[l] -= alloc * dt;
                        if st[i].debt[l] > 0.0 {
                            dp = 0.0;
                            // still consuming the pool while stalled
                            charge_pool(&wf.nodes[i].resource_sources[l], alloc, &mut pool_used);
                            continue;
                        }
                    }
                    let c = dres[i][l].eval(st[i].p + 1e-12);
                    if c > 1e-15 {
                        dp = dp.min(alloc * dt / c);
                    }
                }
                dp = dp.max(0.0);

                // jump crossings
                for l in 0..jumps[i].len() {
                    for j in 0..jumps[i][l].len() {
                        let (pj, height) = jumps[i][l][j];
                        if !st[i].paid[l][j] && st[i].p + dp >= pj - 1e-12 {
                            dp = dp.min((pj - st[i].p).max(0.0));
                            st[i].debt[l] += height;
                            st[i].paid[l][j] = true;
                        }
                    }
                }

                // charge pools with actual usage
                for (l, src) in nd.resource_sources.iter().enumerate() {
                    let c = dres[i][l].eval(st[i].p + 1e-12);
                    let used_rate = c * dp / dt;
                    if used_rate > 0.0 {
                        charge_pool(src, used_rate, &mut pool_used);
                        resource_used[i] += c * dp;
                    }
                }

                if dp > 1e-15 * (1.0 + nd.process.max_progress) {
                    st[i].active_at.get_or_insert(t);
                }
                st[i].p += dp;
                if st[i].p >= nd.process.max_progress - 1e-9 * (1.0 + nd.process.max_progress)
                {
                    st[i].p = nd.process.max_progress;
                    st[i].done = Some(t + dt);
                }
            }
        }

        // ---- I/O recording (BPF-style cumulative counters) -------------
        if opts.sample_every > 0.0 {
            let due = t >= next_sample;
            for i in 0..n {
                if trace_closed[i] {
                    continue;
                }
                let finished = st[i].done.is_some();
                if !(due || finished) {
                    continue;
                }
                let nd = &wf.nodes[i];
                let read: f64 = nd
                    .data_sources
                    .iter()
                    .map(|src| match src {
                        DataSource::External(f) => f.eval(t),
                        DataSource::ProcessOutput { node, output } => {
                            wf.nodes[*node].process.outputs[*output].func.eval(st[*node].p)
                        }
                    })
                    .sum();
                let written = match nd.process.outputs.first() {
                    Some(o) => o.func.eval(st[i].p),
                    None => st[i].p,
                };
                let ts = if finished { st[i].done.unwrap() } else { t };
                let tr = &mut traces[i];
                if tr.ts.last().map(|&l| ts > l + 1e-12).unwrap_or(true) {
                    tr.ts.push(ts);
                    tr.read.push(read);
                    tr.written.push(written);
                } else {
                    // same timestamp as the previous sample: keep the maxima
                    let k = tr.ts.len() - 1;
                    tr.read[k] = tr.read[k].max(read);
                    tr.written[k] = tr.written[k].max(written);
                }
                if finished {
                    trace_closed[i] = true;
                }
            }
            if due {
                next_sample = t + opts.sample_every;
            }
        }
        t += dt;
    }

    let finish: Vec<Option<f64>> = st.iter().map(|s| s.done).collect();
    let makespan = finish
        .iter()
        .try_fold(0.0f64, |m, f| f.map(|f| m.max(f)));
    FluidRun {
        finish,
        makespan,
        progress: st.iter().map(|s| s.p).collect(),
        steps,
        started: st.iter().map(|s| s.started_at).collect(),
        active: st.iter().map(|s| s.active_at).collect(),
        resource_used,
        traces: if opts.sample_every > 0.0 { traces } else { vec![] },
    }
}

/// Export a recorded fluid execution in the trace-subsystem formats: a
/// Nextflow-style TSV row per node (ids = process names, deps from the
/// DAG, `pcpu` from the actually consumed resource) plus the recorded
/// cumulative I/O series. Feeding the result back through
/// [`crate::trace::calibrate_trace`] closes the round trip the
/// calibration tests assert on.
///
/// Requires unique process names, at most one resource requirement per
/// node (the TSV has a single `pcpu` column), and a run in which every
/// node finished.
pub fn export_trace(wf: &Workflow, run: &FluidRun) -> crate::util::Result<(TsvTrace, Vec<IoSeries>)> {
    let n = wf.nodes.len();
    ensure!(run.finish.len() == n, "run does not match workflow");
    validate_exportable(wf)?;
    let mut tasks = Vec::with_capacity(n);
    for (i, nd) in wf.nodes.iter().enumerate() {
        let finish = match run.finish[i] {
            Some(f) => f,
            None => bail!(
                "node {i} ('{}') never finished; cannot export a complete trace",
                nd.process.name
            ),
        };
        let start = run.active[i]
            .or(run.started[i])
            .unwrap_or_else(|| nd.start.at.min(finish))
            .min(finish);
        let realtime = (finish - start).max(0.0);
        let rchar: f64 = nd
            .data_sources
            .iter()
            .map(|src| match src {
                DataSource::External(f) => f.eval(finish),
                DataSource::ProcessOutput { node, output } => {
                    wf.nodes[*node].process.outputs[*output].func.eval(run.progress[*node])
                }
            })
            .sum();
        let wchar = match nd.process.outputs.first() {
            Some(o) => o.func.eval(run.progress[i]),
            None => run.progress[i],
        };
        let pcpu = (!nd.process.res_reqs.is_empty() && realtime > 1e-12)
            .then(|| 100.0 * run.resource_used[i] / realtime);
        tasks.push(TsvTask {
            id: nd.process.name.clone(),
            name: nd.process.name.clone(),
            deps: wf.deps(i).iter().map(|&d| wf.nodes[d].process.name.clone()).collect(),
            start: Some(start),
            complete: Some(finish),
            realtime,
            pcpu,
            rchar,
            wchar,
            peak_rss: 0.0,
        });
    }
    Ok((TsvTrace { tasks }, run.traces.clone()))
}

/// Export the *prefix* of a recorded fluid execution as a process monitor
/// would have seen it at workflow time `t` — an honest mid-flight
/// snapshot, for driving the live monitor's event stream in tests.
///
/// Per node:
/// * finished by `t` — the same complete row [`export_trace`] emits;
/// * active but unfinished at `t` — an in-flight row: `complete` absent,
///   `realtime = t − start`, `rchar`/`wchar` read off the last recorded
///   I/O sample at or before `t` (a monitor only knows the counters it has
///   sampled), and `pcpu` absent (average utilization is a
///   completion-time summary statistic);
/// * no monitor footprint yet at `t` (not started, or started but stalled
///   without consuming anything) — omitted, exactly as a live trace file
///   would not yet contain its row. Dependency lists and I/O series are
///   filtered to the tasks present in the snapshot.
///
/// The recorded I/O series are clipped to samples with `ts ≤ t`. At any
/// `t` at or past the run's makespan the snapshot equals the full
/// [`export_trace`] output bit-for-bit.
pub fn export_trace_until(
    wf: &Workflow,
    run: &FluidRun,
    t: f64,
) -> crate::util::Result<(TsvTrace, Vec<IoSeries>)> {
    let n = wf.nodes.len();
    ensure!(run.finish.len() == n, "run does not match workflow");
    ensure!(t.is_finite() && t >= 0.0, "snapshot time {t} must be finite and >= 0");
    let done = run
        .finish
        .iter()
        .all(|f| f.map(|f| f <= t).unwrap_or(false));
    if done {
        return export_trace(wf, run);
    }
    validate_exportable(wf)?;

    // clip the recorded series first: in-flight counters come from them
    let mut series: Vec<IoSeries> = Vec::new();
    for tr in &run.traces {
        let keep = tr.ts.partition_point(|&x| x <= t);
        if keep > 0 {
            series.push(IoSeries {
                task: tr.task.clone(),
                ts: tr.ts[..keep].to_vec(),
                read: tr.read[..keep].to_vec(),
                written: tr.written[..keep].to_vec(),
            });
        }
    }

    let mut tasks: Vec<TsvTask> = Vec::new();
    for (i, nd) in wf.nodes.iter().enumerate() {
        let finished = run.finish[i].filter(|&f| f <= t);
        // visibility = the task has consumed something by `t` (a stalled
        // task that has not touched data or resources leaves no monitor
        // footprint yet), or it already finished (zero-work nodes finish
        // at their release without ever activating)
        let start = match (run.active[i], finished) {
            (Some(s), f) if s <= t => s.min(f.unwrap_or(s)),
            (_, Some(f)) => run.started[i].unwrap_or(nd.start.at).min(f),
            _ => continue, // not yet visible at t
        };
        let (complete, realtime, rchar, wchar, pcpu) = match finished {
            Some(f) => {
                let realtime = (f - start).max(0.0);
                let rchar: f64 = nd
                    .data_sources
                    .iter()
                    .map(|src| match src {
                        DataSource::External(fl) => fl.eval(f),
                        DataSource::ProcessOutput { node, output } => wf.nodes[*node]
                            .process
                            .outputs[*output]
                            .func
                            .eval(run.progress[*node]),
                    })
                    .sum();
                let wchar = match nd.process.outputs.first() {
                    Some(o) => o.func.eval(run.progress[i]),
                    None => run.progress[i],
                };
                let pcpu = (!nd.process.res_reqs.is_empty() && realtime > 1e-12)
                    .then(|| 100.0 * run.resource_used[i] / realtime);
                (Some(f), realtime, rchar, wchar, pcpu)
            }
            None => {
                let (rchar, wchar) = series
                    .iter()
                    .find(|s| s.task == nd.process.name)
                    .map(|s| (*s.read.last().unwrap(), *s.written.last().unwrap()))
                    .unwrap_or((0.0, 0.0));
                (None, (t - start).max(0.0), rchar, wchar, None)
            }
        };
        tasks.push(TsvTask {
            id: nd.process.name.clone(),
            name: nd.process.name.clone(),
            deps: wf
                .deps(i)
                .iter()
                .map(|&d| wf.nodes[d].process.name.clone())
                .collect(),
            start: Some(start),
            complete,
            realtime,
            pcpu,
            rchar,
            wchar,
            peak_rss: 0.0,
        });
    }
    // a dep whose row is not in the snapshot yet cannot be referenced,
    // and the io log must not carry series for tasks the TSV does not
    // know (the calibrator rejects orphan series)
    let present: std::collections::HashSet<String> =
        tasks.iter().map(|tk| tk.id.clone()).collect();
    for tk in &mut tasks {
        tk.deps.retain(|d| present.contains(d));
    }
    series.retain(|s| present.contains(&s.task));
    Ok((TsvTrace { tasks }, series))
}

/// Shared export preconditions: unique, column-safe process names and at
/// most one resource requirement per node (the TSV has a single `pcpu`).
fn validate_exportable(wf: &Workflow) -> crate::util::Result<()> {
    let mut names: std::collections::HashSet<&str> = std::collections::HashSet::new();
    for (i, nd) in wf.nodes.iter().enumerate() {
        ensure!(
            nd.process.res_reqs.len() <= 1,
            "node {i} ('{}') has {} resource requirements; the TSV export models one",
            nd.process.name,
            nd.process.res_reqs.len()
        );
        ensure!(
            !nd.process.name.is_empty()
                && !nd.process.name.starts_with('#')
                && !nd.process.name.contains(|c: char| c.is_whitespace() || c == ','),
            "process name '{}' cannot be exported: empty, starts with '#' (a trace \
             comment), or contains whitespace/comma (it would corrupt the TSV/io-log \
             columns or the deps list)",
            nd.process.name
        );
        ensure!(
            names.insert(nd.process.name.as_str()),
            "duplicate process name '{}'",
            nd.process.name
        );
    }
    Ok(())
}

fn charge_pool(src: &ResourceSource, rate: f64, pool_used: &mut [f64]) {
    match src {
        ResourceSource::PoolFraction { pool, .. } | ResourceSource::PoolResidual { pool } => {
            pool_used[*pool] += rate;
        }
        ResourceSource::Fixed(_) => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ProcessBuilder;
    use crate::solver::SolverOpts;
    use crate::workflow::engine::analyze_fixpoint;
    use crate::workflow::graph::StartRule;
    use crate::workflow::scenario::VideoScenario;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() < tol
    }

    #[test]
    fn fluid_matches_analytic_simple_chain() {
        let mut wf = Workflow::new();
        let dl = ProcessBuilder::new("dl", 100.0)
            .stream_data("remote", 100.0)
            .stream_resource("link", 100.0)
            .identity_output("file")
            .build();
        let d = wf.add_node(
            dl,
            vec![DataSource::External(PwPoly::constant(100.0))],
            vec![ResourceSource::Fixed(PwPoly::constant(10.0))],
            StartRule::default(),
        );
        let rev = ProcessBuilder::new("rev", 100.0)
            .burst_data("in", 100.0)
            .stream_resource("cpu", 20.0)
            .identity_output("out")
            .build();
        wf.add_node(
            rev,
            vec![DataSource::ProcessOutput { node: d, output: 0 }],
            vec![ResourceSource::Fixed(PwPoly::constant(1.0))],
            StartRule::default(),
        );
        let run = execute(&wf, &FluidOpts::default());
        // analytic: 10 + 20 = 30
        assert!(close(run.makespan.unwrap(), 30.0, 0.1), "{:?}", run.makespan);
    }

    /// The Fig 5 scenario at 50 % and 95 %: fluid execution ("measurement")
    /// must match the analytic prediction closely.
    #[test]
    fn fluid_matches_prediction_video_scenario() {
        for f in [0.5, 0.95] {
            let sc = VideoScenario::default().with_fraction(f);
            let (wf, _) = sc.build();
            let predicted = analyze_fixpoint(&wf, &SolverOpts::default(), 6)
                .unwrap()
                .makespan
                .unwrap();
            let measured = execute(
                &wf,
                &FluidOpts {
                    dt: 0.05,
                    ..FluidOpts::default()
                },
            )
            .makespan
            .unwrap();
            assert!(
                close(predicted, measured, 1.5),
                "f={f}: predicted {predicted} vs fluid {measured}"
            );
        }
    }

    #[test]
    fn jitter_changes_but_stays_close() {
        let sc = VideoScenario::default().with_fraction(0.5);
        let (wf, _) = sc.build();
        let base = execute(&wf, &FluidOpts { dt: 0.05, ..FluidOpts::default() })
            .makespan
            .unwrap();
        let mut different = false;
        for seed in 1..=3u64 {
            let m = execute(
                &wf,
                &FluidOpts {
                    dt: 0.05,
                    jitter: Some((seed, 0.01)),
                    ..FluidOpts::default()
                },
            )
            .makespan
            .unwrap();
            if (m - base).abs() > 1e-6 {
                different = true;
            }
            assert!((m - base).abs() < 0.05 * base, "seed {seed}: {m} vs {base}");
        }
        assert!(different, "jitter had no effect");
    }

    /// Recording + export produce traces the strict parsers accept, with
    /// counters that match the run's summary facts.
    #[test]
    fn recording_and_export_parse_back() {
        let mut wf = Workflow::new();
        let dl = ProcessBuilder::new("dl", 100.0)
            .stream_data("remote", 100.0)
            .stream_resource("link", 100.0)
            .identity_output("file")
            .build();
        let d = wf.add_node(
            dl,
            vec![DataSource::External(PwPoly::constant(100.0))],
            vec![ResourceSource::Fixed(PwPoly::constant(10.0))],
            StartRule::default(),
        );
        let rev = ProcessBuilder::new("rev", 100.0)
            .burst_data("in", 100.0)
            .stream_resource("cpu", 20.0)
            .identity_output("out")
            .build();
        wf.add_node(
            rev,
            vec![DataSource::ProcessOutput { node: d, output: 0 }],
            vec![ResourceSource::Fixed(PwPoly::constant(1.0))],
            StartRule::default(),
        );
        let run = execute(
            &wf,
            &FluidOpts {
                dt: 0.01,
                sample_every: 0.5,
                ..FluidOpts::default()
            },
        );
        assert!(run.makespan.is_some());
        let (tsv, series) = export_trace(&wf, &run).unwrap();
        assert_eq!(tsv.tasks.len(), 2);
        let t_dl = tsv.task("dl").unwrap();
        assert!(close(t_dl.complete.unwrap(), 10.0, 0.1));
        assert!(close(t_dl.rchar, 100.0, 1e-6));
        assert!(close(t_dl.wchar, 100.0, 1e-6));
        // pcpu = 100 * consumed / realtime: 100 link-units over ~10 s
        assert!(close(t_dl.pcpu.unwrap(), 1000.0, 20.0), "{:?}", t_dl.pcpu);
        let t_rev = tsv.task("rev").unwrap();
        assert_eq!(t_rev.deps, vec!["dl".to_string()]);
        assert!(close(t_rev.complete.unwrap(), 30.0, 0.2));
        // the writers emit exactly what the strict parsers accept
        let tsv2 = crate::trace::format::parse_tsv(&crate::trace::format::write_tsv(&tsv))
            .unwrap();
        assert_eq!(tsv2, tsv);
        let log = crate::trace::format::write_io_log(&series);
        let series2 = crate::trace::format::parse_io_log(&log).unwrap();
        assert_eq!(series2.len(), 2);
        // the final sample lands exactly on the summary counters
        let s_rev = series2.iter().find(|s| s.task == "rev").unwrap();
        assert!(close(*s_rev.written.last().unwrap(), 100.0, 1e-6));
        assert!(close(*s_rev.ts.last().unwrap(), t_rev.complete.unwrap(), 1e-9));
    }

    /// Mid-flight snapshots: tasks appear as a live trace file would show
    /// them — finished rows complete, in-flight rows truncated, future
    /// rows absent — and a snapshot past the makespan is the full export.
    #[test]
    fn export_trace_until_prefixes() {
        let mut wf = Workflow::new();
        let dl = ProcessBuilder::new("dl", 100.0)
            .stream_data("remote", 100.0)
            .stream_resource("link", 100.0)
            .identity_output("file")
            .build();
        let d = wf.add_node(
            dl,
            vec![DataSource::External(PwPoly::constant(100.0))],
            vec![ResourceSource::Fixed(PwPoly::constant(10.0))],
            StartRule::default(),
        );
        let rev = ProcessBuilder::new("rev", 100.0)
            .burst_data("in", 100.0)
            .stream_resource("cpu", 20.0)
            .identity_output("out")
            .build();
        wf.add_node(
            rev,
            vec![DataSource::ProcessOutput { node: d, output: 0 }],
            vec![ResourceSource::Fixed(PwPoly::constant(1.0))],
            StartRule::default(),
        );
        let run = execute(
            &wf,
            &FluidOpts {
                dt: 0.01,
                sample_every: 0.5,
                ..FluidOpts::default()
            },
        );
        // dl runs [0, 10], rev (burst input) works [10, 30]

        // t = 5: only dl visible, in-flight — no complete, counters from
        // the last sample at or before 5 s, pcpu withheld
        let (tsv, series) = export_trace_until(&wf, &run, 5.0).unwrap();
        assert_eq!(tsv.tasks.len(), 1);
        let t_dl = &tsv.tasks[0];
        assert_eq!(t_dl.id, "dl");
        assert_eq!(t_dl.complete, None);
        assert_eq!(t_dl.pcpu, None);
        assert!(close(t_dl.realtime, 5.0, 0.1), "{}", t_dl.realtime);
        assert!(t_dl.wchar > 30.0 && t_dl.wchar <= 51.0, "{}", t_dl.wchar);
        assert_eq!(series.len(), 1);
        assert!(series[0].ts.iter().all(|&x| x <= 5.0));
        // the snapshot parses through the strict round trip
        let back = crate::trace::format::parse_tsv(&crate::trace::format::write_tsv(&tsv))
            .unwrap();
        assert_eq!(back, tsv);

        // t = 15: dl finished (full row, pcpu restored), rev in-flight
        let (tsv, _) = export_trace_until(&wf, &run, 15.0).unwrap();
        assert_eq!(tsv.tasks.len(), 2);
        let t_dl = tsv.task("dl").unwrap();
        assert!(close(t_dl.complete.unwrap(), 10.0, 0.1));
        assert!(t_dl.pcpu.is_some());
        let t_rev = tsv.task("rev").unwrap();
        assert_eq!(t_rev.complete, None);
        assert_eq!(t_rev.deps, vec!["dl".to_string()]);

        // past the makespan the snapshot IS the full export
        let full = export_trace(&wf, &run).unwrap();
        let snap = export_trace_until(&wf, &run, run.makespan.unwrap() + 1.0).unwrap();
        assert_eq!(snap.0, full.0);
        assert_eq!(snap.1, full.1);
    }

    #[test]
    fn unfinishable_gives_none() {
        let mut wf = Workflow::new();
        let p = ProcessBuilder::new("a", 10.0).stream_data("in", 10.0).build();
        wf.add_node(
            p,
            vec![DataSource::External(PwPoly::constant(5.0))],
            vec![],
            StartRule::default(),
        );
        let run = execute(
            &wf,
            &FluidOpts {
                dt: 0.1,
                horizon: 50.0,
                ..FluidOpts::default()
            },
        );
        assert_eq!(run.makespan, None);
        assert!(close(run.progress[0], 5.0, 1e-6));
    }
}
