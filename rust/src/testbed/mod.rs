//! The virtual testbed: ground truth standing in for the paper's VM/ffmpeg
//! evaluation rig (see DESIGN.md, environment substitutions).
//!
//! * [`fluid`] — generic byte-accurate fixed-timestep workflow executor
//!   (independent of the analytic solver) with seeded jitter;
//! * [`video`] — the concrete Fig 5 rig with task-internal structure
//!   (task 1's read+decode stage) and the BPF-style I/O trace recorder
//!   behind Fig 6.

pub mod fluid;
pub mod video;

pub use fluid::{execute, export_trace, FluidOpts, FluidRun};
