//! The concrete Fig 5 evaluation rig (virtual replacement for the paper's
//! two VMware VMs + webserver + netfilter rate limits).
//!
//! Unlike the model (which treats tasks as black boxes), the testbed knows
//! the tasks' *internal* structure, exactly like reality does:
//!
//! * task 1 (ffmpeg reverse) reads+decodes streaming from the wget pipe
//!   (26 s of decode CPU spread over the input) and only then encodes the
//!   reversed video (82 s over the 80 MB output);
//! * task 2 copies input to output as it arrives (5 s of I/O pacing at
//!   local speed);
//! * task 3 muxes both results in 3 s once tasks 1 and 2 finished;
//! * the two downloads share the link under per-flow caps `f·C` and
//!   `(1−f)·C`; when one finishes, the other's cap is released to `C`
//!   (the appendix's `nft replace rule`).
//!
//! The recorder samples cumulative read/written bytes per task — the
//! BPF-style I/O traces of Fig 6.

use crate::util::Rng;
use crate::workflow::scenario::VideoScenario;

/// Cumulative I/O activity of one task over time (Fig 6).
#[derive(Clone, Debug)]
pub struct IoTrace {
    pub name: String,
    pub ts: Vec<f64>,
    pub read: Vec<f64>,
    pub written: Vec<f64>,
}

/// Result of one testbed execution of the whole workflow.
#[derive(Clone, Debug)]
pub struct TestbedRun {
    pub dl1_done: f64,
    pub dl2_done: f64,
    pub t1_done: f64,
    pub t2_done: f64,
    pub t3_done: f64,
    /// Total workflow time (= t3 completion).
    pub total: f64,
    pub traces: Vec<IoTrace>,
}

/// The virtual testbed.
#[derive(Clone, Debug)]
pub struct VideoTestbed {
    pub sc: VideoScenario,
    /// Simulation step (s).
    pub dt: f64,
    /// Trace sampling interval (s); 0 disables traces.
    pub sample_every: f64,
}

impl VideoTestbed {
    pub fn new(sc: VideoScenario) -> Self {
        VideoTestbed {
            sc,
            dt: 0.02,
            sample_every: 0.0,
        }
    }

    /// Execute the full workflow. `jitter = Some((seed, sigma))` adds
    /// multiplicative OS-noise on all rates, resampled once per second.
    pub fn run(&self, jitter: Option<(u64, f64)>) -> TestbedRun {
        let sc = &self.sc;
        let dt = self.dt;
        let mut rng = jitter.map(|(s, _)| Rng::new(s));
        let sigma = jitter.map(|(_, s)| s).unwrap_or(0.0);

        // per-entity jitter factors
        let mut jf = [1.0f64; 6]; // link, dl1cap, dl2cap, t1cpu, t2io, t3io
        let mut next_refresh = 0.0;

        // state: downloaded bytes per flow
        let (mut d1, mut d2) = (0.0f64, 0.0f64);
        // task1: bytes read+decoded; encoded output bytes
        let (mut t1_read, mut t1_out) = (0.0f64, 0.0f64);
        // task2: output bytes (reads the same amount)
        let mut t2_out = 0.0f64;
        // task3: output bytes
        let mut t3_out = 0.0f64;
        let t3_total = sc.t1_output + sc.input_size;

        let (mut dl1_done, mut dl2_done) = (f64::NAN, f64::NAN);
        let (mut t1_done, mut t2_done, mut t3_done) = (f64::NAN, f64::NAN, f64::NAN);

        let mut traces = vec![
            IoTrace { name: "task1".into(), ts: vec![], read: vec![], written: vec![] },
            IoTrace { name: "task2".into(), ts: vec![], read: vec![], written: vec![] },
            IoTrace { name: "task3".into(), ts: vec![], read: vec![], written: vec![] },
        ];
        let mut next_sample = 0.0f64;

        let mut t = 0.0f64;
        let horizon = 100.0 * (sc.input_size / sc.link_rate) + 1e4;
        while t3_done.is_nan() && t < horizon {
            if let Some(r) = rng.as_mut() {
                if t >= next_refresh {
                    for f in jf.iter_mut() {
                        *f = r.jitter(sigma);
                    }
                    next_refresh = t + 1.0;
                }
            }
            let link = sc.link_rate * jf[0];

            // ---- downloads with nft-style caps & release ---------------
            let cap1 = if dl2_done.is_nan() {
                link * sc.frac_task1 * jf[1]
            } else {
                link
            };
            let cap2 = if dl1_done.is_nan() {
                link * (1.0 - sc.frac_task1) * jf[2]
            } else {
                link
            };
            if dl1_done.is_nan() {
                d1 = (d1 + cap1 * dt).min(sc.input_size);
                if d1 >= sc.input_size {
                    dl1_done = t + dt;
                }
            }
            if dl2_done.is_nan() {
                d2 = (d2 + cap2 * dt).min(sc.input_size);
                if d2 >= sc.input_size {
                    dl2_done = t + dt;
                }
            }

            // ---- task 1: read+decode stage, then encode ----------------
            if t1_done.is_nan() {
                if t1_read < sc.input_size {
                    // decode CPU paces reading at input_size/26 B/s
                    let decode_rate = sc.input_size / sc.t1_decode_cpu * jf[3];
                    t1_read = (t1_read + decode_rate * dt).min(d1);
                } else {
                    let encode_rate = sc.t1_output / sc.t1_cpu * jf[3];
                    t1_out = (t1_out + encode_rate * dt).min(sc.t1_output);
                    if t1_out >= sc.t1_output {
                        t1_done = t + dt;
                    }
                }
            }

            // ---- task 2: streaming copy ---------------------------------
            if t2_done.is_nan() {
                let io_rate = sc.input_size / sc.t2_time * jf[4];
                t2_out = (t2_out + io_rate * dt).min(d2);
                if t2_out >= sc.input_size {
                    t2_done = t + dt;
                }
            }

            // ---- task 3: mux after both done ----------------------------
            if t3_done.is_nan() && !t1_done.is_nan() && !t2_done.is_nan() {
                let start = t1_done.max(t2_done);
                if t >= start {
                    let io_rate = t3_total / sc.t3_time * jf[5];
                    t3_out = (t3_out + io_rate * dt).min(t3_total);
                    if t3_out >= t3_total {
                        t3_done = t + dt;
                    }
                }
            }

            // ---- traces --------------------------------------------------
            if self.sample_every > 0.0 && t >= next_sample {
                traces[0].ts.push(t);
                traces[0].read.push(t1_read);
                traces[0].written.push(t1_out);
                traces[1].ts.push(t);
                traces[1].read.push(t2_out); // copy reads what it writes
                traces[1].written.push(t2_out);
                traces[2].ts.push(t);
                traces[2].read.push(t3_out);
                traces[2].written.push(t3_out);
                next_sample = t + self.sample_every;
            }

            t += dt;
        }

        TestbedRun {
            dl1_done,
            dl2_done,
            t1_done,
            t2_done,
            t3_done,
            total: t3_done,
            traces,
        }
    }

    /// Isolated local execution of task 1 (input on local disk, Fig 6 top):
    /// read+decode 26 s, then encode+write 82 s.
    pub fn isolated_task1(&self) -> IoTrace {
        let sc = &self.sc;
        let dt = self.dt;
        let sample = if self.sample_every > 0.0 {
            self.sample_every
        } else {
            0.5
        };
        let mut trace = IoTrace {
            name: "task1-isolated".into(),
            ts: vec![],
            read: vec![],
            written: vec![],
        };
        let (mut read, mut out) = (0.0f64, 0.0f64);
        let mut t = 0.0;
        let mut next_sample = 0.0;
        while out < sc.t1_output {
            if read < sc.input_size {
                read = (read + sc.input_size / sc.t1_decode_cpu * dt).min(sc.input_size);
            } else {
                out = (out + sc.t1_output / sc.t1_cpu * dt).min(sc.t1_output);
            }
            if t >= next_sample {
                trace.ts.push(t);
                trace.read.push(read);
                trace.written.push(out);
                next_sample = t + sample;
            }
            t += dt;
        }
        trace.ts.push(t);
        trace.read.push(read);
        trace.written.push(out);
        trace
    }

    /// Isolated local execution of task 2 (Fig 6 bottom): streaming copy
    /// paced by local I/O; a brief cache-warm burst at the start mirrors the
    /// paper's observation that early input "rises faster ... because the
    /// file is still in the cache".
    pub fn isolated_task2(&self) -> IoTrace {
        let sc = &self.sc;
        let dt = self.dt;
        let sample = if self.sample_every > 0.0 {
            self.sample_every
        } else {
            0.1
        };
        let mut trace = IoTrace {
            name: "task2-isolated".into(),
            ts: vec![],
            read: vec![],
            written: vec![],
        };
        let base_rate = sc.input_size / sc.t2_time;
        let (mut read, mut written) = (0.0f64, 0.0f64);
        let mut t = 0.0;
        let mut next_sample = 0.0;
        while written < sc.input_size {
            // cache burst: first 10% of the file reads 3x faster
            let rate = if read < 0.1 * sc.input_size {
                3.0 * base_rate
            } else {
                base_rate
            };
            read = (read + rate * dt).min(sc.input_size);
            written = (written + rate * dt).min(read);
            if t >= next_sample {
                trace.ts.push(t);
                trace.read.push(read);
                trace.written.push(written);
                next_sample = t + sample;
            }
            t += dt;
        }
        trace.ts.push(t);
        trace.read.push(read);
        trace.written.push(written);
        trace
    }

    /// Repeat the workflow `n_runs` times with different seeds (Fig 7's
    /// averaged measurements with min/max bars). Returns total times.
    pub fn measure(&self, n_runs: usize, base_seed: u64, sigma: f64) -> Vec<f64> {
        (0..n_runs)
            .map(|i| self.run(Some((base_seed + i as u64, sigma))).total)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::SolverOpts;
    use crate::workflow::engine::analyze_fixpoint;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() < tol
    }

    #[test]
    fn isolated_task1_timeline() {
        let tb = VideoTestbed::new(VideoScenario::default());
        let tr = tb.isolated_task1();
        let total = *tr.ts.last().unwrap();
        // 26 s read+decode + 82 s encode = 108 s (paper §5.1)
        assert!(close(total, 108.0, 0.5), "{total}");
        // no output before the read completes
        let mid = tr.ts.iter().position(|&t| t >= 20.0).unwrap();
        assert_eq!(tr.written[mid], 0.0);
        assert!(tr.read[mid] > 0.0);
    }

    #[test]
    fn isolated_task2_timeline() {
        let tb = VideoTestbed::new(VideoScenario::default());
        let tr = tb.isolated_task2();
        let total = *tr.ts.last().unwrap();
        // ≈5 s (slightly less due to the cache burst)
        assert!(total > 3.0 && total < 5.5, "{total}");
        // streaming: read and written track each other
        for i in 0..tr.ts.len() {
            assert!(tr.written[i] <= tr.read[i] + 1e-6);
        }
    }

    #[test]
    fn testbed_total_matches_model_50() {
        let sc = VideoScenario::default().with_fraction(0.5);
        let (wf, _) = sc.build();
        let predicted = analyze_fixpoint(&wf, &SolverOpts::default(), 6)
            .unwrap()
            .makespan
            .unwrap();
        let tb = VideoTestbed::new(sc);
        let run = tb.run(None);
        // the testbed has the decode stage the model abstracts away; the
        // model must still predict the total well (paper Fig 7)
        assert!(
            close(predicted, run.total, 0.02 * predicted),
            "predicted {predicted} vs testbed {}",
            run.total
        );
    }

    #[test]
    fn testbed_total_matches_model_95() {
        let sc = VideoScenario::default().with_fraction(0.95);
        let (wf, _) = sc.build();
        let predicted = analyze_fixpoint(&wf, &SolverOpts::default(), 6)
            .unwrap()
            .makespan
            .unwrap();
        let tb = VideoTestbed::new(sc);
        let run = tb.run(None);
        assert!(
            close(predicted, run.total, 0.02 * predicted),
            "predicted {predicted} vs testbed {}",
            run.total
        );
    }

    #[test]
    fn measured_runs_spread_small() {
        let tb = VideoTestbed::new(VideoScenario::default().with_fraction(0.5));
        let runs = tb.measure(5, 42, 0.01);
        let s = crate::util::stats::Summary::of(&runs);
        assert!(s.max - s.min < 0.05 * s.mean, "{s:?}");
        assert!(s.min > 0.0);
    }

    #[test]
    fn release_behaviour_in_testbed() {
        // at 95%, dl2 should finish at ≈ 2*89 = 178 s thanks to release
        let tb = VideoTestbed::new(VideoScenario::default().with_fraction(0.95));
        let run = tb.run(None);
        assert!(close(run.dl2_done, 178.0, 1.5), "{}", run.dl2_done);
        assert!(close(run.dl1_done, 93.7, 1.0), "{}", run.dl1_done);
    }
}
