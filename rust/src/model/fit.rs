//! Deriving requirement functions from logged executions.
//!
//! The paper leaves this as future work (§5.2: "executions of such tasks
//! can be logged and the requirement functions can be derived from such
//! logs. However, that is part of future work."; §8 suggests eBPF traces).
//! This module implements it: given a BPF-style cumulative I/O trace of an
//! *isolated* task execution (input fully available, known constant
//! resource allocation), it fits
//!
//! * the data requirement `R_D(n)` from the (bytes-read → bytes-written)
//!   relation — a stream task yields a proportional curve, a
//!   read-everything-first task yields the burst step;
//! * the resource requirement `R_R(p)` from the (bytes-written →
//!   elapsed-time × allocation) relation — up-front work (e.g. decode
//!   before any output) appears as a jump at p = 0⁺, which the solver's
//!   stall semantics replay correctly (and, unlike the paper's hand model,
//!   let that up-front work overlap a slow download);
//! * an identity output function (progress metric = output bytes).
//!
//! Curves are compacted by greedy piecewise-linear segmentation with a
//! relative tolerance, so fitted models stay small (few pieces) and the
//! solver stays fast.

use crate::pwfn::{poly::Poly, PwPoly};
use crate::testbed::video::IoTrace;

use super::process::{DataRequirement, OutputFn, Process, ResourceRequirement};

/// Options for trace fitting.
#[derive(Clone, Debug)]
pub struct FitOpts {
    /// Relative y-tolerance for segment fitting (fraction of the y-span).
    pub tol: f64,
    /// x-gaps smaller than this fraction of the x-span become jumps.
    pub jump_eps: f64,
}

impl Default for FitOpts {
    fn default() -> Self {
        FitOpts {
            tol: 0.01,
            jump_eps: 1e-6,
        }
    }
}

/// Greedy PL segmentation of a monotone curve: returns breakpoints
/// `(x, y)` such that linear interpolation stays within `tol * y_span` of
/// every sample. Input must be sorted by x (ties allowed, last wins).
pub fn fit_pl(points: &[(f64, f64)], tol: f64) -> Vec<(f64, f64)> {
    assert!(points.len() >= 2, "need at least two samples");
    let y_span = points
        .iter()
        .map(|p| p.1)
        .fold(f64::NEG_INFINITY, f64::max)
        - points.iter().map(|p| p.1).fold(f64::INFINITY, f64::min);
    let eps = tol * y_span.max(1e-300);

    let mut out = vec![points[0]];
    let mut seg_start = 0usize;
    let mut i = 1;
    while i < points.len() {
        // try extending the current segment to point i+1; check deviation
        let cand_end = (i + 1).min(points.len() - 1);
        let (x0, y0) = points[seg_start];
        let (x1, y1) = points[cand_end];
        let dx = x1 - x0;
        let ok = if dx.abs() < 1e-300 {
            true
        } else {
            let slope = (y1 - y0) / dx;
            points[seg_start..=cand_end].iter().all(|&(x, y)| {
                let pred = y0 + slope * (x - x0);
                (pred - y).abs() <= eps
            })
        };
        if ok && cand_end > i {
            i = cand_end;
            continue;
        }
        if ok && cand_end == i {
            // reached the end
            break;
        }
        // cut the segment at i
        out.push(points[i]);
        seg_start = i;
        i += 1;
    }
    let last = *points.last().unwrap();
    if out.last() != Some(&last) {
        out.push(last);
    }
    out
}

/// Build a monotone PwPoly from fitted breakpoints. Near-vertical steps
/// (consecutive points closer in x than `jump_eps_abs`) are widened into
/// steep piecewise-linear ramps of width `jump_eps_abs` — exactly
/// equivalent for the solver (the cumulative amount is preserved, and the
/// function stays PL so Algorithm 2's §4 restriction holds), and crucially
/// visible at the domain edge, where a true jump at `x = x_min` would
/// degenerate into an invisible constant offset of a derivative-based
/// model.
pub fn pl_to_pwpoly(points: &[(f64, f64)], jump_eps_abs: f64) -> PwPoly {
    pl_to_pwpoly_dir(points, jump_eps_abs, false)
}

/// Like [`pl_to_pwpoly`], but widening direction is selectable: forward
/// (steps keep their left edge — right for resource requirements, whose
/// up-front cost must be payable from the start) or backward (steps keep
/// their right edge — right for data requirements, whose burst threshold
/// must not exceed the actually-available input).
pub fn pl_to_pwpoly_dir(points: &[(f64, f64)], jump_eps_abs: f64, backward: bool) -> PwPoly {
    assert!(points.len() >= 2);
    let eps = jump_eps_abs.max(1e-12);
    // enforce strictly increasing x by widening steps
    let mut pts: Vec<(f64, f64)> = Vec::with_capacity(points.len());
    if backward {
        for &(x, y) in points.iter().rev() {
            let x = match pts.last() {
                Some(&(nx, ny)) => {
                    if y >= ny - 1e-300 && x >= nx - eps {
                        continue; // duplicate sample
                    }
                    x.min(nx - eps)
                }
                None => x,
            };
            pts.push((x, y));
        }
        pts.reverse();
        // backward widening may push the first x negative; clamp by
        // dropping points left of the original start
        let x0 = points[0].0;
        pts.retain(|&(x, _)| x >= x0 - 1e-300);
        if pts.first().map(|p| p.0) != Some(x0) {
            pts.insert(0, points[0]);
        }
    } else {
        for &(x, y) in points {
            let x = match pts.last() {
                Some(&(px, py)) => {
                    if y <= py + 1e-300 && x <= px + eps {
                        continue; // duplicate sample
                    }
                    x.max(px + eps)
                }
                None => x,
            };
            pts.push((x, y));
        }
    }
    if pts.len() < 2 {
        return PwPoly::constant_from(points[0].0, points.last().unwrap().1);
    }
    let mut breaks: Vec<f64> = Vec::with_capacity(pts.len() + 1);
    let mut polys: Vec<Poly> = Vec::with_capacity(pts.len());
    for w in pts.windows(2) {
        let ((x0, y0), (x1, y1)) = (w[0], w[1]);
        breaks.push(x0);
        polys.push(Poly::linear(y0, (y1 - y0) / (x1 - x0)));
    }
    breaks.push(pts[pts.len() - 1].0);
    breaks.push(f64::INFINITY);
    polys.push(Poly::constant(pts[pts.len() - 1].1));
    PwPoly::new(breaks, polys)
}

/// Fit a full process model from an isolated-execution I/O trace.
///
/// `alloc` is the (constant) resource rate the task had during the traced
/// run (e.g. 1.0 CPU). The returned process uses output bytes as its
/// progress metric.
pub fn fit_process(name: &str, trace: &IoTrace, alloc: f64, opts: &FitOpts) -> Process {
    assert_eq!(trace.ts.len(), trace.read.len());
    assert_eq!(trace.ts.len(), trace.written.len());
    let total_out = *trace.written.last().unwrap();
    let total_in = *trace.read.last().unwrap();
    let x_span = total_in.max(1e-300);

    // ---- data requirement: written as a function of read ----------------
    // enforce monotone x by taking the running max of read
    let mut dw: Vec<(f64, f64)> = vec![];
    let mut max_read: f64 = 0.0;
    for i in 0..trace.ts.len() {
        max_read = max_read.max(trace.read[i]);
        dw.push((max_read, trace.written[i]));
    }
    let fitted = fit_pl(&dw, opts.tol);
    let data_req = pl_to_pwpoly_dir(&fitted, opts.jump_eps * x_span, true);

    // ---- resource requirement: cumulative resource vs written -----------
    // (time * alloc) as a function of output; up-front time becomes a jump
    let pw: Vec<(f64, f64)> = {
        let mut v: Vec<(f64, f64)> = vec![];
        let mut max_w: f64 = 0.0;
        for i in 0..trace.ts.len() {
            max_w = max_w.max(trace.written[i]);
            v.push((max_w, trace.ts[i] * alloc));
        }
        v
    };
    let fitted_r = fit_pl(&pw, opts.tol);
    let res_req = pl_to_pwpoly(&fitted_r, opts.jump_eps * total_out.max(1e-300));

    Process {
        name: name.to_string(),
        data_reqs: vec![DataRequirement {
            name: "in".to_string(),
            func: data_req,
        }],
        res_reqs: vec![ResourceRequirement {
            name: "cpu".to_string(),
            func: res_req,
        }],
        outputs: vec![OutputFn {
            name: "out".to_string(),
            func: PwPoly::linear_from(0.0, 0.0, 1.0),
        }],
        max_progress: total_out,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::process::ProcessInputs;
    use crate::solver::{solve, SolverOpts};
    use crate::testbed::video::VideoTestbed;
    use crate::workflow::scenario::VideoScenario;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() < tol
    }

    #[test]
    fn fit_pl_compacts_straight_line() {
        let pts: Vec<(f64, f64)> = (0..100).map(|i| (i as f64, 2.0 * i as f64)).collect();
        let fitted = fit_pl(&pts, 0.01);
        assert!(fitted.len() <= 3, "{}", fitted.len());
    }

    #[test]
    fn fit_pl_keeps_kinks() {
        let mut pts: Vec<(f64, f64)> = (0..50).map(|i| (i as f64, i as f64)).collect();
        pts.extend((50..100).map(|i| (i as f64, 50.0 + 3.0 * (i - 50) as f64)));
        let fitted = fit_pl(&pts, 0.005);
        // must keep the kink at x=50 within tolerance
        assert!(fitted.len() >= 3);
        let y_at_50 = fitted
            .windows(2)
            .find(|w| w[0].0 <= 50.0 && 50.0 <= w[1].0)
            .map(|w| {
                let (x0, y0) = w[0];
                let (x1, y1) = w[1];
                y0 + (y1 - y0) * (50.0 - x0) / (x1 - x0)
            })
            .unwrap();
        assert!(close(y_at_50, 50.0, 2.0), "{y_at_50}");
    }

    #[test]
    fn fitted_task2_is_stream_shaped() {
        let mut tb = VideoTestbed::new(VideoScenario::default());
        tb.sample_every = 0.05;
        let trace = tb.isolated_task2();
        let p = fit_process("task2-fitted", &trace, 1.0, &FitOpts::default());
        assert!(p.validate().is_ok());
        // stream: halfway input gives (roughly) halfway progress
        let half = p.data_reqs[0].func.eval(0.5 * 1_137_486_559.0);
        assert!(
            half > 0.35 * p.max_progress && half < 0.65 * p.max_progress,
            "{half}"
        );
        // few pieces (compacted)
        assert!(p.data_reqs[0].func.n_pieces() <= 8);
    }

    #[test]
    fn fitted_task1_is_burst_shaped_with_upfront_cpu() {
        let mut tb = VideoTestbed::new(VideoScenario::default());
        tb.sample_every = 0.25;
        let trace = tb.isolated_task1();
        let p = fit_process("task1-fitted", &trace, 1.0, &FitOpts::default());
        assert!(p.validate().is_ok());
        let size = 1_137_486_559.0;
        // burst: no progress at 99% input, full at 100%
        assert!(p.data_reqs[0].func.eval(0.99 * size) < 0.02 * p.max_progress);
        assert!(
            p.data_reqs[0].func.eval(size * 1.001) > 0.98 * p.max_progress
        );
        // the 26 s of decode shows up as up-front resource demand
        let upfront = p.res_reqs[0].func.eval(0.002 * p.max_progress);
        assert!(close(upfront, 26.0, 3.0), "{upfront}");
        // and the total CPU is ~108 s
        let total = p.res_reqs[0].func.eval(p.max_progress);
        assert!(close(total, 108.0, 3.0), "{total}");
    }

    /// The fitted model replayed in isolation reproduces the traced runtime.
    #[test]
    fn fitted_model_replays_isolated_run() {
        let mut tb = VideoTestbed::new(VideoScenario::default());
        tb.sample_every = 0.25;
        for (trace, expect) in [(tb.isolated_task1(), 108.0), (tb.isolated_task2(), 4.7)] {
            let total_in = *trace.read.last().unwrap();
            let p = fit_process("fitted", &trace, 1.0, &FitOpts::default());
            let inputs = ProcessInputs {
                data: vec![PwPoly::constant(total_in)],
                resources: vec![PwPoly::constant(1.0)],
                start_time: 0.0,
            };
            let a = solve(&p, &inputs, &SolverOpts::default()).unwrap();
            let got = a.finish_time.expect("finishes");
            assert!(close(got, expect, 0.05 * expect + 1.0), "{got} vs {expect}");
        }
    }
}
