//! Deriving requirement functions from logged executions.
//!
//! The paper leaves this as future work (§5.2: "executions of such tasks
//! can be logged and the requirement functions can be derived from such
//! logs. However, that is part of future work."; §8 suggests eBPF traces).
//! This module implements it for the virtual testbed's isolated-execution
//! [`IoTrace`]s: given a BPF-style cumulative I/O trace of an *isolated*
//! task execution (input fully available, known constant resource
//! allocation), it fits
//!
//! * the data requirement `R_D(n)` from the (bytes-read → bytes-written)
//!   relation — a stream task yields a proportional curve, a
//!   read-everything-first task yields the burst step;
//! * the resource requirement `R_R(p)` from the (bytes-written →
//!   elapsed-time × allocation) relation — up-front work (e.g. decode
//!   before any output) appears as a jump at p = 0⁺, which the solver's
//!   stall semantics replay correctly (and, unlike the paper's hand model,
//!   let that up-front work overlap a slow download);
//! * an identity output function (progress metric = output bytes).
//!
//! The fitting machinery lives in the trace subsystem and is shared with
//! full workflow-trace calibration: segmentation in
//! [`crate::trace::segment`] (re-exported here under the historical names
//! [`fit_pl`] / [`pl_to_pwpoly`] / [`pl_to_pwpoly_dir`]), the fit itself
//! in [`crate::trace::calibrate::fit_series`], to which [`fit_process`]
//! delegates. Curves are compacted by greedy piecewise-linear
//! segmentation with a relative tolerance, so fitted models stay small
//! (few pieces) and the solver stays fast.

use crate::testbed::video::IoTrace;

pub use crate::trace::segment::{
    compact as fit_pl, to_pwpoly as pl_to_pwpoly, to_pwpoly_dir as pl_to_pwpoly_dir,
};

use super::process::Process;

/// Options for trace fitting.
#[derive(Clone, Debug)]
pub struct FitOpts {
    /// Relative y-tolerance for segment fitting (fraction of the y-span).
    pub tol: f64,
    /// x-gaps smaller than this fraction of the x-span become jumps.
    pub jump_eps: f64,
}

impl Default for FitOpts {
    fn default() -> Self {
        FitOpts {
            tol: 0.01,
            jump_eps: 1e-6,
        }
    }
}

/// Fit a full process model from an isolated-execution I/O trace.
///
/// `alloc` is the (constant) resource rate the task had during the traced
/// run (e.g. 1.0 CPU). The returned process uses output bytes as its
/// progress metric. Delegates to [`crate::trace::calibrate::fit_series`].
pub fn fit_process(name: &str, trace: &IoTrace, alloc: f64, opts: &FitOpts) -> Process {
    assert_eq!(trace.ts.len(), trace.read.len());
    assert_eq!(trace.ts.len(), trace.written.len());
    crate::trace::calibrate::fit_series(
        name,
        &trace.ts,
        &trace.read,
        &trace.written,
        alloc,
        opts.tol,
        opts.jump_eps,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::process::ProcessInputs;
    use crate::pwfn::PwPoly;
    use crate::solver::{solve, SolverOpts};
    use crate::testbed::video::VideoTestbed;
    use crate::workflow::scenario::VideoScenario;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() < tol
    }

    #[test]
    fn fit_pl_compacts_straight_line() {
        let pts: Vec<(f64, f64)> = (0..100).map(|i| (i as f64, 2.0 * i as f64)).collect();
        let fitted = fit_pl(&pts, 0.01);
        assert!(fitted.len() <= 3, "{}", fitted.len());
    }

    #[test]
    fn fit_pl_keeps_kinks() {
        let mut pts: Vec<(f64, f64)> = (0..50).map(|i| (i as f64, i as f64)).collect();
        pts.extend((50..100).map(|i| (i as f64, 50.0 + 3.0 * (i - 50) as f64)));
        let fitted = fit_pl(&pts, 0.005);
        // must keep the kink at x=50 within tolerance
        assert!(fitted.len() >= 3);
        let y_at_50 = fitted
            .windows(2)
            .find(|w| w[0].0 <= 50.0 && 50.0 <= w[1].0)
            .map(|w| {
                let (x0, y0) = w[0];
                let (x1, y1) = w[1];
                y0 + (y1 - y0) * (50.0 - x0) / (x1 - x0)
            })
            .unwrap();
        assert!(close(y_at_50, 50.0, 2.0), "{y_at_50}");
    }

    #[test]
    fn fitted_task2_is_stream_shaped() {
        let mut tb = VideoTestbed::new(VideoScenario::default());
        tb.sample_every = 0.05;
        let trace = tb.isolated_task2();
        let p = fit_process("task2-fitted", &trace, 1.0, &FitOpts::default());
        assert!(p.validate().is_ok());
        // stream: halfway input gives (roughly) halfway progress
        let half = p.data_reqs[0].func.eval(0.5 * 1_137_486_559.0);
        assert!(
            half > 0.35 * p.max_progress && half < 0.65 * p.max_progress,
            "{half}"
        );
        // few pieces (compacted)
        assert!(p.data_reqs[0].func.n_pieces() <= 8);
    }

    #[test]
    fn fitted_task1_is_burst_shaped_with_upfront_cpu() {
        let mut tb = VideoTestbed::new(VideoScenario::default());
        tb.sample_every = 0.25;
        let trace = tb.isolated_task1();
        let p = fit_process("task1-fitted", &trace, 1.0, &FitOpts::default());
        assert!(p.validate().is_ok());
        let size = 1_137_486_559.0;
        // burst: no progress at 99% input, full at 100%
        assert!(p.data_reqs[0].func.eval(0.99 * size) < 0.02 * p.max_progress);
        assert!(
            p.data_reqs[0].func.eval(size * 1.001) > 0.98 * p.max_progress
        );
        // the 26 s of decode shows up as up-front resource demand
        let upfront = p.res_reqs[0].func.eval(0.002 * p.max_progress);
        assert!(close(upfront, 26.0, 3.0), "{upfront}");
        // and the total CPU is ~108 s
        let total = p.res_reqs[0].func.eval(p.max_progress);
        assert!(close(total, 108.0, 3.0), "{total}");
    }

    /// The fitted model replayed in isolation reproduces the traced runtime.
    #[test]
    fn fitted_model_replays_isolated_run() {
        let mut tb = VideoTestbed::new(VideoScenario::default());
        tb.sample_every = 0.25;
        for (trace, expect) in [(tb.isolated_task1(), 108.0), (tb.isolated_task2(), 4.7)] {
            let total_in = *trace.read.last().unwrap();
            let p = fit_process("fitted", &trace, 1.0, &FitOpts::default());
            let inputs = ProcessInputs {
                data: vec![PwPoly::constant(total_in)],
                resources: vec![PwPoly::constant(1.0)],
                start_time: 0.0,
            };
            let a = solve(&p, &inputs, &SolverOpts::default()).unwrap();
            let got = a.finish_time.expect("finishes");
            assert!(close(got, expect, 0.05 * expect + 1.0), "{got} vs {expect}");
        }
    }
}
