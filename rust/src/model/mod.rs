//! Process models (paper §2): requirement, input and output functions.

pub mod builder;
pub mod fit;
pub mod process;
pub mod spec;

pub use builder::ProcessBuilder;
pub use process::{DataRequirement, ModelError, OutputFn, Process, ProcessInputs, ResourceRequirement};
