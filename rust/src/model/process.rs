//! The process model of paper §2.
//!
//! A *process* (a task execution, a network transfer, ...) is described by
//! process-specific **requirement** functions and execution-specific
//! **input** functions:
//!
//! * data requirement `R_Dk(n)` — input bytes consumed → max progress
//!   attainable from data input `k` alone (monotone nondecreasing);
//! * resource requirement `R_Rl(p)` — progress → *cumulative* amount of
//!   resource `l` needed (monotone nondecreasing; Algorithm 2 requires
//!   piecewise-linear, which [`Process::validate`] checks);
//! * output function `O_m(p)` — progress → bytes of output `m` produced;
//! * data input `I_Dk(t)` — wall time → cumulative bytes available;
//! * resource input `I_Rl(t)` — wall time → allocated resource *rate*.
//!
//! The progress metric is arbitrary but consistent within one process
//! (paper §2.1); the canonical choice in the evaluation is "output bytes".

use crate::pwfn::PwPoly;

/// A named data requirement `R_Dk`.
#[derive(Clone, Debug)]
pub struct DataRequirement {
    pub name: String,
    /// bytes of this input consumed → maximum possible progress.
    pub func: PwPoly,
}

/// A named resource requirement `R_Rl`.
#[derive(Clone, Debug)]
pub struct ResourceRequirement {
    pub name: String,
    /// progress → cumulative resource needed (CPU-seconds, bytes on a link, ...).
    pub func: PwPoly,
}

/// A named output function `O_m`.
#[derive(Clone, Debug)]
pub struct OutputFn {
    pub name: String,
    /// progress → cumulative output bytes produced.
    pub func: PwPoly,
}

/// Process-specific description (execution-independent; paper §2.2/§2.4).
#[derive(Clone, Debug)]
pub struct Process {
    pub name: String,
    pub data_reqs: Vec<DataRequirement>,
    pub res_reqs: Vec<ResourceRequirement>,
    pub outputs: Vec<OutputFn>,
    /// The process finishes when `P(t)` reaches this progress value.
    pub max_progress: f64,
}

/// Execution-specific side: one input function per requirement (paper §2.3).
#[derive(Clone, Debug)]
pub struct ProcessInputs {
    /// `I_Dk(t)`, cumulative, aligned with `Process::data_reqs`.
    pub data: Vec<PwPoly>,
    /// `I_Rl(t)`, a rate, aligned with `Process::res_reqs`.
    pub resources: Vec<PwPoly>,
    /// Wall-clock time at which the process may begin.
    pub start_time: f64,
}

/// Validation failure for a model (bad shapes, wrong monotonicity, ...).
#[derive(Debug, Clone)]
pub struct ModelError {
    pub process: String,
    pub msg: String,
}

impl std::fmt::Display for ModelError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "invalid model for process '{}': {}", self.process, self.msg)
    }
}

impl std::error::Error for ModelError {}

impl Process {
    /// A process with no requirements that is instantly complete — useful as
    /// a DAG source.
    pub fn nop(name: &str) -> Process {
        Process {
            name: name.to_string(),
            data_reqs: vec![],
            res_reqs: vec![],
            outputs: vec![],
            max_progress: 0.0,
        }
    }

    fn err(&self, msg: String) -> ModelError {
        ModelError {
            process: self.name.clone(),
            msg,
        }
    }

    /// Check the §2 model invariants: requirement and output functions are
    /// monotone nondecreasing; resource requirements are piecewise-linear
    /// (the paper's §4 restriction that makes Algorithm 2 applicable);
    /// max_progress is reachable data-wise given unlimited input.
    pub fn validate(&self) -> Result<(), ModelError> {
        if self.max_progress < 0.0 || !self.max_progress.is_finite() {
            return Err(self.err(format!("bad max_progress {}", self.max_progress)));
        }
        for d in &self.data_reqs {
            if !d.func.is_nondecreasing() {
                return Err(self.err(format!("data requirement '{}' not monotone", d.name)));
            }
        }
        for r in &self.res_reqs {
            if !r.func.is_nondecreasing() {
                return Err(self.err(format!("resource requirement '{}' not monotone", r.name)));
            }
            for (i, p) in r.func.polys.iter().enumerate() {
                if p.degree() > 1 {
                    return Err(self.err(format!(
                        "resource requirement '{}' piece {} has degree {} — Algorithm 2 \
                         requires piecewise-linear resource requirements (paper §4)",
                        r.name,
                        i,
                        p.degree()
                    )));
                }
            }
        }
        for o in &self.outputs {
            if !o.func.is_nondecreasing() {
                return Err(self.err(format!("output function '{}' not monotone", o.name)));
            }
        }
        Ok(())
    }

    /// Validate an inputs object against this process (arity + monotone data).
    pub fn validate_inputs(&self, inputs: &ProcessInputs) -> Result<(), ModelError> {
        if inputs.data.len() != self.data_reqs.len() {
            return Err(self.err(format!(
                "expected {} data inputs, got {}",
                self.data_reqs.len(),
                inputs.data.len()
            )));
        }
        if inputs.resources.len() != self.res_reqs.len() {
            return Err(self.err(format!(
                "expected {} resource inputs, got {}",
                self.res_reqs.len(),
                inputs.resources.len()
            )));
        }
        for (k, f) in inputs.data.iter().enumerate() {
            if !f.is_nondecreasing() {
                return Err(self.err(format!("data input {k} not monotone")));
            }
        }
        Ok(())
    }

    /// Total bytes of output `m` at full progress.
    pub fn output_size(&self, m: usize) -> f64 {
        self.outputs[m].func.eval(self.max_progress)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builder::ProcessBuilder;
    use crate::pwfn::{poly::Poly, PwPoly};

    #[test]
    fn validate_accepts_stream_process() {
        let p = ProcessBuilder::new("enc", 100.0)
            .stream_data("in", 1000.0)
            .stream_resource("cpu", 50.0)
            .identity_output("out")
            .build();
        assert!(p.validate().is_ok());
    }

    #[test]
    fn validate_rejects_decreasing_requirement() {
        let mut p = ProcessBuilder::new("bad", 10.0)
            .stream_data("in", 10.0)
            .build();
        p.data_reqs[0].func = PwPoly::from_points(&[(0.0, 5.0), (1.0, 0.0)]);
        assert!(p.validate().is_err());
    }

    #[test]
    fn validate_rejects_quadratic_resource_req() {
        let mut p = ProcessBuilder::new("bad", 10.0)
            .stream_resource("cpu", 10.0)
            .build();
        p.res_reqs[0].func = PwPoly::new(
            vec![0.0, f64::INFINITY],
            vec![Poly::new(vec![0.0, 0.0, 1.0])],
        );
        let err = p.validate().unwrap_err();
        assert!(err.msg.contains("piecewise-linear"));
    }

    #[test]
    fn validate_inputs_arity() {
        let p = ProcessBuilder::new("t", 10.0).stream_data("in", 10.0).build();
        let bad = ProcessInputs {
            data: vec![],
            resources: vec![],
            start_time: 0.0,
        };
        assert!(p.validate_inputs(&bad).is_err());
    }

    #[test]
    fn output_size_via_output_fn() {
        let p = ProcessBuilder::new("t", 80e6)
            .identity_output("out")
            .build();
        assert_eq!(p.output_size(0), 80e6);
    }
}
