//! Fluent construction of the common process shapes of paper Fig 1.
//!
//! * **stream** data requirement — progress grows proportionally with input
//!   read (re-encoding a video);
//! * **burst** data requirement — *all* input must be read before any
//!   progress (reversing a video);
//! * **stream** resource requirement — resource consumed evenly across
//!   progress;
//! * **burst** resource requirement — all resource needed up front
//!   (modelled as a jump at p = 0⁺, which the solver treats as "stall until
//!   the cumulative allocation covers the jump").

use crate::pwfn::{poly::Poly, PwPoly};

use super::process::{DataRequirement, OutputFn, Process, ResourceRequirement};

/// Builder for [`Process`].
#[derive(Clone, Debug)]
pub struct ProcessBuilder {
    p: Process,
}

impl ProcessBuilder {
    pub fn new(name: &str, max_progress: f64) -> Self {
        ProcessBuilder {
            p: Process {
                name: name.to_string(),
                data_reqs: vec![],
                res_reqs: vec![],
                outputs: vec![],
                max_progress,
            },
        }
    }

    // ------------------------------------------------------ data (Fig 1a)

    /// Stream-type data requirement: progress proportional to bytes read;
    /// `total_bytes` of input yield `max_progress`.
    pub fn stream_data(mut self, name: &str, total_bytes: f64) -> Self {
        let slope = self.p.max_progress / total_bytes;
        self.p.data_reqs.push(DataRequirement {
            name: name.to_string(),
            func: PwPoly::ramp_to(0.0, slope, self.p.max_progress),
        });
        self
    }

    /// Burst-type data requirement: zero progress until all `total_bytes`
    /// are available, then full progress (paper Fig 1a 'burst'; used for
    /// the video-reversal task).
    pub fn burst_data(mut self, name: &str, total_bytes: f64) -> Self {
        self.p.data_reqs.push(DataRequirement {
            name: name.to_string(),
            func: PwPoly::step(0.0, total_bytes, 0.0, self.p.max_progress),
        });
        self
    }

    /// Arbitrary data requirement from (bytes, progress) control points.
    pub fn custom_data(mut self, name: &str, points: &[(f64, f64)]) -> Self {
        self.p.data_reqs.push(DataRequirement {
            name: name.to_string(),
            func: PwPoly::from_points(points),
        });
        self
    }

    /// Raw piecewise data requirement.
    pub fn data_req_fn(mut self, name: &str, func: PwPoly) -> Self {
        self.p.data_reqs.push(DataRequirement {
            name: name.to_string(),
            func,
        });
        self
    }

    // -------------------------------------------------- resources (Fig 1b)

    /// Stream-type resource requirement: `total_amount` of the resource
    /// spread evenly over the whole progress (e.g. `executionTime /
    /// outputSize` CPU-seconds per progress unit, paper §5.2).
    pub fn stream_resource(mut self, name: &str, total_amount: f64) -> Self {
        let slope = total_amount / self.p.max_progress.max(f64::MIN_POSITIVE);
        self.p.res_reqs.push(ResourceRequirement {
            name: name.to_string(),
            func: PwPoly::linear_from(0.0, 0.0, slope),
        });
        self
    }

    /// Burst-type resource requirement: all `total_amount` needed before the
    /// first progress unit (paper Fig 1b 'burst'), i.e. a jump at p = 0⁺.
    pub fn burst_resource(mut self, name: &str, total_amount: f64) -> Self {
        self.p.res_reqs.push(ResourceRequirement {
            name: name.to_string(),
            // represented as a jump right after 0; the solver stalls until
            // the cumulative allocation covers it
            func: PwPoly::new(
                vec![0.0, crate::pwfn::poly::EPS.max(1e-12), f64::INFINITY],
                vec![Poly::constant(0.0), Poly::constant(total_amount)],
            ),
        });
        self
    }

    /// Two-phase resource requirement: `front` of the resource over the
    /// first `split` fraction of progress, `back` over the rest. Models
    /// read-then-encode tasks like the paper's task 1.
    pub fn two_phase_resource(
        mut self,
        name: &str,
        front: f64,
        back: f64,
        split: f64,
    ) -> Self {
        let p_split = self.p.max_progress * split;
        self.p.res_reqs.push(ResourceRequirement {
            name: name.to_string(),
            func: PwPoly::from_points(&[
                (0.0, 0.0),
                (p_split.max(1e-12), front),
                (self.p.max_progress, front + back),
            ]),
        });
        self
    }

    /// Raw piecewise resource requirement (must be PL; `validate` checks).
    pub fn res_req_fn(mut self, name: &str, func: PwPoly) -> Self {
        self.p.res_reqs.push(ResourceRequirement {
            name: name.to_string(),
            func,
        });
        self
    }

    // ------------------------------------------------------------ outputs

    /// Identity output: the progress metric *is* the output byte count
    /// (the paper's choice for every evaluation process, §5.2).
    pub fn identity_output(mut self, name: &str) -> Self {
        self.p.outputs.push(OutputFn {
            name: name.to_string(),
            func: PwPoly::linear_from(0.0, 0.0, 1.0),
        });
        self
    }

    /// Output only produced when the process completes (counting-style
    /// tasks): a jump of `size` at full progress.
    pub fn final_output(mut self, name: &str, size: f64) -> Self {
        let p_max = self.p.max_progress;
        self.p.outputs.push(OutputFn {
            name: name.to_string(),
            func: PwPoly::step(0.0, p_max.max(1e-12), 0.0, size),
        });
        self
    }

    /// Proportional output: `size` bytes spread linearly over progress.
    pub fn linear_output(mut self, name: &str, size: f64) -> Self {
        let p_max = self.p.max_progress.max(f64::MIN_POSITIVE);
        self.p.outputs.push(OutputFn {
            name: name.to_string(),
            func: PwPoly::ramp_to(0.0, size / p_max, size),
        });
        self
    }

    /// Raw output function.
    pub fn output_fn(mut self, name: &str, func: PwPoly) -> Self {
        self.p.outputs.push(OutputFn {
            name: name.to_string(),
            func,
        });
        self
    }

    pub fn build(self) -> Process {
        self.p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_data_shape() {
        let p = ProcessBuilder::new("t", 100.0).stream_data("in", 1000.0).build();
        let f = &p.data_reqs[0].func;
        assert_eq!(f.eval(0.0), 0.0);
        assert_eq!(f.eval(500.0), 50.0);
        assert_eq!(f.eval(1000.0), 100.0);
        assert_eq!(f.eval(2000.0), 100.0); // saturates
    }

    #[test]
    fn burst_data_shape() {
        let p = ProcessBuilder::new("t", 100.0).burst_data("in", 1000.0).build();
        let f = &p.data_reqs[0].func;
        assert_eq!(f.eval(999.9), 0.0);
        assert_eq!(f.eval(1000.0), 100.0);
    }

    #[test]
    fn stream_resource_slope() {
        let p = ProcessBuilder::new("t", 80.0).stream_resource("cpu", 40.0).build();
        let f = &p.res_reqs[0].func;
        assert_eq!(f.eval(80.0), 40.0);
        assert_eq!(f.slope_right(10.0), 0.5);
    }

    #[test]
    fn two_phase_resource_split() {
        // paper task 1: 26 s of CPU before any output, 82 s spread over output
        let p = ProcessBuilder::new("t1", 80e6)
            .two_phase_resource("cpu", 26.0, 82.0, 1e-9)
            .build();
        let f = &p.res_reqs[0].func;
        assert!(f.eval(80e6) - 108.0 < 1e-6);
        assert!(p.validate().is_ok());
    }

    #[test]
    fn final_output_jump() {
        let p = ProcessBuilder::new("t", 100.0).final_output("out", 42.0).build();
        let f = &p.outputs[0].func;
        assert_eq!(f.eval(99.0), 0.0);
        assert_eq!(f.eval(100.0), 42.0);
    }

    #[test]
    fn burst_resource_validates() {
        let p = ProcessBuilder::new("t", 10.0).burst_resource("cpu", 5.0).build();
        assert!(p.validate().is_ok());
        assert!(p.res_reqs[0].func.jump_at(crate::pwfn::poly::EPS.max(1e-12)) > 4.9);
    }
}
