//! JSON workflow specifications.
//!
//! The config-file front end of the system: a declarative description of
//! processes, requirement functions, wiring and pools that loads into a
//! [`crate::workflow::Workflow`]. Used by the CLI (`bottlemod analyze`)
//! and the e2e example. See `examples/specs/video.json` for the Fig 5
//! workflow in this format.
//!
//! Function specs:
//! ```json
//! {"type": "stream", "total": 100.0}          // Fig 1 stream
//! {"type": "burst",  "total": 100.0}          // Fig 1 burst
//! {"type": "points", "points": [[0,0],[2,4]]} // PL interpolation
//! {"type": "constant", "value": 5.0}
//! ```

use std::collections::HashMap;

use crate::pwfn::PwPoly;
use crate::util::Json;
use crate::workflow::graph::{DataSource, ResourceSource, StartRule, Workflow};

use super::builder::ProcessBuilder;

/// Spec parsing failure with a path-ish context string.
#[derive(Debug, Clone)]
pub struct SpecError(pub String);

impl std::fmt::Display for SpecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "workflow spec: {}", self.0)
    }
}

impl std::error::Error for SpecError {}

fn err(msg: impl Into<String>) -> SpecError {
    SpecError(msg.into())
}

/// Parse a function spec in the context of a process with `max_progress`.
/// `kind` selects the builder semantics: "data", "resource" or "output".
fn parse_fn(j: &Json, max_progress: f64, kind: &str) -> Result<PwPoly, SpecError> {
    let ty = j.get("type").as_str().unwrap_or("stream");
    match ty {
        "stream" => {
            let total = j
                .get("total")
                .as_f64()
                .ok_or_else(|| err(format!("{kind} stream needs total")))?;
            Ok(match kind {
                "data" => PwPoly::ramp_to(0.0, max_progress / total, max_progress),
                "resource" => PwPoly::linear_from(0.0, 0.0, total / max_progress.max(1e-300)),
                _ => PwPoly::ramp_to(0.0, total / max_progress.max(1e-300), total),
            })
        }
        "burst" => {
            let total = j
                .get("total")
                .as_f64()
                .ok_or_else(|| err(format!("{kind} burst needs total")))?;
            Ok(match kind {
                "data" => PwPoly::step(0.0, total, 0.0, max_progress),
                "resource" => PwPoly::new(
                    vec![0.0, 1e-12, f64::INFINITY],
                    vec![
                        crate::pwfn::Poly::constant(0.0),
                        crate::pwfn::Poly::constant(total),
                    ],
                ),
                _ => PwPoly::step(0.0, max_progress.max(1e-12), 0.0, total),
            })
        }
        "identity" => Ok(PwPoly::linear_from(0.0, 0.0, 1.0)),
        "constant" => {
            let v = j
                .get("value")
                .as_f64()
                .ok_or_else(|| err("constant needs value"))?;
            Ok(PwPoly::constant(v))
        }
        "points" => {
            let pts = j
                .get("points")
                .as_arr()
                .ok_or_else(|| err("points needs points array"))?;
            let mut points = vec![];
            for p in pts {
                let xy = p.as_arr().ok_or_else(|| err("point must be [x,y]"))?;
                if xy.len() != 2 {
                    return Err(err("point must be [x,y]"));
                }
                points.push((
                    xy[0].as_f64().ok_or_else(|| err("x not a number"))?,
                    xy[1].as_f64().ok_or_else(|| err("y not a number"))?,
                ));
            }
            if points.len() < 2 {
                return Err(err("points needs at least 2 entries"));
            }
            Ok(PwPoly::from_points(&points))
        }
        other => Err(err(format!("unknown function type '{other}'"))),
    }
}

/// Parse a full workflow spec document.
pub fn parse_workflow(text: &str) -> Result<Workflow, SpecError> {
    let j = Json::parse(text).map_err(|e| err(format!("json: {e}")))?;
    let mut wf = Workflow::new();

    // pools first (referenced by name)
    let mut pool_ids: HashMap<String, usize> = HashMap::new();
    if let Some(pools) = j.get("pools").as_arr() {
        for p in pools {
            let name = p
                .get("name")
                .as_str()
                .ok_or_else(|| err("pool needs name"))?;
            let cap = match p.get("capacity") {
                Json::Num(c) => PwPoly::constant(*c),
                other => parse_fn(other, 1.0, "input")?,
            };
            pool_ids.insert(name.to_string(), wf.add_pool(name, cap));
        }
    }

    let procs = j
        .get("processes")
        .as_arr()
        .ok_or_else(|| err("spec needs processes[]"))?;
    // name -> index mapping for wiring
    let mut name_to_idx: HashMap<String, usize> = HashMap::new();
    for (i, p) in procs.iter().enumerate() {
        let name = p
            .get("name")
            .as_str()
            .ok_or_else(|| err(format!("process {i} needs name")))?;
        if name_to_idx.insert(name.to_string(), i).is_some() {
            return Err(err(format!("duplicate process name '{name}'")));
        }
    }

    for p in procs {
        let name = p.get("name").as_str().unwrap();
        let max_progress = p
            .get("max_progress")
            .as_f64()
            .ok_or_else(|| err(format!("process '{name}' needs max_progress")))?;
        let mut b = ProcessBuilder::new(name, max_progress);
        let mut data_sources = vec![];
        let mut resource_sources = vec![];

        if let Some(data) = p.get("data").as_arr() {
            for (k, d) in data.iter().enumerate() {
                let dname = d.get("name").as_str().unwrap_or("in");
                let f = parse_fn(d.get("req"), max_progress, "data")?;
                b = b.data_req_fn(dname, f);
                let src = d.get("source");
                let source = if let Some(c) = src.get("external_constant").as_f64() {
                    DataSource::External(PwPoly::constant(c))
                } else if let Some(node) = src.get("node").as_str() {
                    let idx = *name_to_idx
                        .get(node)
                        .ok_or_else(|| err(format!("'{name}' input {k}: unknown node '{node}'")))?;
                    DataSource::ProcessOutput {
                        node: idx,
                        output: src.get("output").as_f64().unwrap_or(0.0) as usize,
                    }
                } else if src.get("external").as_obj().is_some() {
                    DataSource::External(parse_fn(src.get("external"), 1.0, "input")?)
                } else {
                    return Err(err(format!("'{name}' input {k}: missing source")));
                };
                data_sources.push(source);
            }
        }

        if let Some(res) = p.get("resources").as_arr() {
            for (l, r) in res.iter().enumerate() {
                let rname = r.get("name").as_str().unwrap_or("res");
                let f = parse_fn(r.get("req"), max_progress, "resource")?;
                b = b.res_req_fn(rname, f);
                let src = r.get("source");
                let source = if let Some(c) = src.get("constant").as_f64() {
                    ResourceSource::Fixed(PwPoly::constant(c))
                } else if let Some(pool) = src.get("pool").as_str() {
                    let pid = *pool_ids
                        .get(pool)
                        .ok_or_else(|| err(format!("'{name}' res {l}: unknown pool '{pool}'")))?;
                    if src.get("residual").as_bool() == Some(true) {
                        ResourceSource::PoolResidual { pool: pid }
                    } else {
                        let fr = src.get("fraction").as_f64().ok_or_else(|| {
                            err(format!("'{name}' res {l}: needs fraction or residual"))
                        })?;
                        ResourceSource::PoolFraction {
                            pool: pid,
                            fraction: fr,
                        }
                    }
                } else {
                    return Err(err(format!("'{name}' res {l}: missing source")));
                };
                resource_sources.push(source);
            }
        }

        if let Some(outputs) = p.get("outputs").as_arr() {
            for o in outputs {
                let oname = o.get("name").as_str().unwrap_or("out");
                let f = parse_fn(o, max_progress, "output")?;
                b = b.output_fn(oname, f);
            }
        }

        let mut start = StartRule {
            at: p.get("start_at").as_f64().unwrap_or(0.0),
            after: vec![],
        };
        if let Some(after) = p.get("start_after").as_arr() {
            for a in after {
                let an = a
                    .as_str()
                    .ok_or_else(|| err("start_after entries must be names"))?;
                start.after.push(
                    *name_to_idx
                        .get(an)
                        .ok_or_else(|| err(format!("'{name}': unknown start_after '{an}'")))?,
                );
            }
        }

        wf.add_node(b.build(), data_sources, resource_sources, start);
    }

    wf.validate().map_err(|e| err(format!("validation: {e}")))?;
    Ok(wf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::SolverOpts;
    use crate::workflow::engine::analyze_fixpoint;

    const VIDEO_SPEC: &str = r#"{
      "pools": [{"name": "link", "capacity": 12780748.0}],
      "processes": [
        {"name": "dl1", "max_progress": 1137486559.0,
         "data": [{"name": "remote", "req": {"type": "stream", "total": 1137486559.0},
                   "source": {"external_constant": 1137486559.0}}],
         "resources": [{"name": "link", "req": {"type": "stream", "total": 1137486559.0},
                        "source": {"pool": "link", "fraction": 0.5}}],
         "outputs": [{"name": "file", "type": "identity"}]},
        {"name": "dl2", "max_progress": 1137486559.0,
         "data": [{"name": "remote", "req": {"type": "stream", "total": 1137486559.0},
                   "source": {"external_constant": 1137486559.0}}],
         "resources": [{"name": "link", "req": {"type": "stream", "total": 1137486559.0},
                        "source": {"pool": "link", "residual": true}}],
         "outputs": [{"name": "file", "type": "identity"}]},
        {"name": "task1", "max_progress": 80000000.0,
         "data": [{"name": "video", "req": {"type": "burst", "total": 1137486559.0},
                   "source": {"node": "dl1", "output": 0}}],
         "resources": [{"name": "cpu", "req": {"type": "stream", "total": 82.0},
                        "source": {"constant": 1.0}}],
         "outputs": [{"name": "reversed", "type": "identity"}]},
        {"name": "task2", "max_progress": 1137486559.0,
         "data": [{"name": "video", "req": {"type": "stream", "total": 1137486559.0},
                   "source": {"node": "dl2", "output": 0}}],
         "resources": [{"name": "io", "req": {"type": "stream", "total": 5.0},
                        "source": {"constant": 1.0}}],
         "outputs": [{"name": "rotated", "type": "identity"}]},
        {"name": "task3", "max_progress": 1217486559.0,
         "data": [
           {"name": "reversed", "req": {"type": "points",
             "points": [[0, 0], [80000000.0, 1217486559.0]]},
            "source": {"node": "task1", "output": 0}},
           {"name": "rotated", "req": {"type": "points",
             "points": [[0, 0], [1137486559.0, 1217486559.0]]},
            "source": {"node": "task2", "output": 0}}],
         "resources": [{"name": "io", "req": {"type": "stream", "total": 3.0},
                        "source": {"constant": 1.0}}],
         "outputs": [{"name": "result", "type": "identity"}],
         "start_after": ["task1", "task2"]}
      ]
    }"#;

    #[test]
    fn video_spec_parses_and_matches_builder() {
        let wf = parse_workflow(VIDEO_SPEC).unwrap();
        assert_eq!(wf.nodes.len(), 5);
        assert_eq!(wf.pools.len(), 1);
        let wa = analyze_fixpoint(&wf, &SolverOpts::default(), 6).unwrap();
        let total = wa.makespan.unwrap();
        // must match the builder-built scenario (≈263 s at 50:50)
        let (wf2, _) = crate::workflow::scenario::VideoScenario::default().build();
        let total2 = analyze_fixpoint(&wf2, &SolverOpts::default(), 6)
            .unwrap()
            .makespan
            .unwrap();
        assert!(
            (total - total2).abs() < 1.0,
            "spec {total} vs builder {total2}"
        );
    }

    #[test]
    fn missing_fields_rejected() {
        assert!(parse_workflow("{}").is_err());
        assert!(parse_workflow(r#"{"processes": [{"name": "x"}]}"#).is_err());
        let bad_ref = r#"{"processes": [{"name": "x", "max_progress": 1.0,
          "data": [{"req": {"type": "stream", "total": 1.0},
                    "source": {"node": "nope"}}]}]}"#;
        assert!(parse_workflow(bad_ref).is_err());
    }

    #[test]
    fn unknown_function_type_rejected() {
        let s = r#"{"processes": [{"name": "x", "max_progress": 1.0,
          "data": [{"req": {"type": "wavelet"}, "source": {"external_constant": 1}}]}]}"#;
        let e = parse_workflow(s).unwrap_err();
        assert!(e.to_string().contains("wavelet"));
    }

    #[test]
    fn duplicate_names_rejected() {
        let s = r#"{"processes": [
          {"name": "x", "max_progress": 1.0},
          {"name": "x", "max_progress": 1.0}]}"#;
        assert!(parse_workflow(s).is_err());
    }
}
