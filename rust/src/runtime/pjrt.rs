//! PJRT artifact registry (manifest handling) + execution stub.
//!
//! The original deployment executes AOT-compiled JAX/Pallas HLO artifacts
//! (`artifacts/*.hlo.txt`, built by `make artifacts` from `python/compile`)
//! through a PJRT CPU client. The offline build has no `xla` crate, so this
//! module keeps the full manifest/registry surface — artifact discovery,
//! shape validation, the `execute_f32` call signature — but the execution
//! backend reports [`Runtime::backend_available`] `== false` and
//! `execute_f32` returns an error. Every caller (benches, the PJRT sweep,
//! the integration tests) already gates on artifact availability, so the
//! rest of the system is unaffected; the exact Rust engine is the
//! authoritative path either way.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::bail;
use crate::util::error::{Context, Error, Result};
use crate::util::Json;

/// One loadable artifact as described by `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: PathBuf,
    /// Expected input shapes (row-major dims).
    pub inputs: Vec<Vec<usize>>,
}

/// The PJRT runtime: the artifact registry plus (when built with an XLA
/// backend) lazily compiled executables.
pub struct Runtime {
    artifacts: HashMap<String, ArtifactInfo>,
}

impl Runtime {
    /// Default artifact location (next to the repo root, `artifacts/`).
    pub fn default_dir() -> PathBuf {
        std::env::var_os("BOTTLEMOD_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Whether this build can actually execute artifacts. Always `false` in
    /// the offline build (no `xla` crate vendored).
    pub fn backend_available() -> bool {
        false
    }

    /// Open the runtime over an artifact directory (reads `manifest.json`).
    pub fn new(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run `make artifacts`"))?;
        let manifest =
            Json::parse(&text).map_err(|e| Error::msg(format!("parsing manifest: {e}")))?;
        let mut artifacts = HashMap::new();
        for (name, entry) in manifest
            .as_obj()
            .ok_or_else(|| Error::msg("manifest is not an object"))?
        {
            let file = entry
                .get("file")
                .as_str()
                .ok_or_else(|| Error::msg(format!("artifact {name}: missing file")))?;
            let inputs = entry
                .get("inputs")
                .as_arr()
                .ok_or_else(|| Error::msg(format!("artifact {name}: missing inputs")))?
                .iter()
                .map(|shape| {
                    shape
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|d| d.as_f64())
                        .map(|d| d as usize)
                        .collect()
                })
                .collect();
            artifacts.insert(
                name.clone(),
                ArtifactInfo {
                    name: name.clone(),
                    file: dir.join(file),
                    inputs,
                },
            );
        }
        Ok(Runtime { artifacts })
    }

    /// Names of all known artifacts.
    pub fn names(&self) -> Vec<&str> {
        self.artifacts.keys().map(String::as_str).collect()
    }

    /// Artifact metadata.
    pub fn info(&self, name: &str) -> Option<&ArtifactInfo> {
        self.artifacts.get(name)
    }

    /// Compile (memoized) an artifact. Errors in the offline build.
    pub fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if !self.artifacts.contains_key(name) {
            bail!("unknown artifact '{name}'");
        }
        bail!(
            "cannot compile '{name}': this build has no PJRT execution backend \
             (the `xla` crate is not vendored offline — see DESIGN.md)"
        );
    }

    /// Execute an artifact on f32 tensors. Each input is `(data, dims)`;
    /// dims must match the manifest. Returns the flattened f32 outputs.
    ///
    /// Input validation runs in every build; execution requires the XLA
    /// backend and errors without it.
    pub fn execute_f32(
        &mut self,
        name: &str,
        inputs: &[(&[f32], &[usize])],
    ) -> Result<Vec<Vec<f32>>> {
        let info = self
            .artifacts
            .get(name)
            .ok_or_else(|| Error::msg(format!("unknown artifact '{name}'")))?;
        if inputs.len() != info.inputs.len() {
            bail!(
                "artifact {name}: expected {} inputs, got {}",
                info.inputs.len(),
                inputs.len()
            );
        }
        for (i, (data, dims)) in inputs.iter().enumerate() {
            if *dims != info.inputs[i].as_slice() {
                bail!(
                    "artifact {name}: input {i} shape {:?} != manifest {:?}",
                    dims,
                    info.inputs[i]
                );
            }
            let n: usize = dims.iter().product();
            if n != data.len() {
                bail!(
                    "artifact {name}: input {i} has {} elems for shape {:?}",
                    data.len(),
                    dims
                );
            }
        }
        self.ensure_compiled(name)?;
        unreachable!("ensure_compiled errors in the offline build");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_present() -> bool {
        Runtime::backend_available() && Runtime::default_dir().join("manifest.json").exists()
    }

    #[test]
    fn backend_is_stubbed_offline() {
        assert!(!Runtime::backend_available());
    }

    #[test]
    fn manifest_parses_and_validates_shapes() {
        let dir = std::env::temp_dir().join("bottlemod_pjrt_stub_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"toy": {"file": "toy.hlo.txt", "inputs": [[2, 3], [6]]}}"#,
        )
        .unwrap();
        let mut rt = Runtime::new(&dir).unwrap();
        assert_eq!(rt.names(), vec!["toy"]);
        assert_eq!(rt.info("toy").unwrap().inputs, vec![vec![2, 3], vec![6]]);

        // unknown artifact
        assert!(rt.execute_f32("nope", &[]).is_err());
        // wrong shape rejected before the backend is even consulted
        let bad = vec![0f32; 4];
        let dims: [usize; 1] = [4];
        let one: (&[f32], &[usize]) = (&bad, &dims);
        assert!(rt.execute_f32("toy", &[one, one]).is_err());
        // right shapes still error (no backend), with a clear message
        let a = vec![0f32; 6];
        let da: [usize; 2] = [2, 3];
        let b = vec![0f32; 6];
        let db: [usize; 1] = [6];
        let err = rt
            .execute_f32("toy", &[(&a, &da), (&b, &db)])
            .unwrap_err()
            .to_string();
        assert!(err.contains("backend"), "{err}");
    }

    #[test]
    fn missing_manifest_is_an_error() {
        let dir = std::env::temp_dir().join("bottlemod_pjrt_missing_9a2f");
        assert!(Runtime::new(&dir).is_err());
    }

    /// Kept from the backend build: only meaningful when artifacts exist
    /// *and* a backend is compiled in.
    #[test]
    fn eval_pw_artifact_matches_rust_engine() {
        if !artifacts_present() {
            return;
        }
        unreachable!("offline build has no backend");
    }
}
