//! PJRT runtime: load AOT HLO-text artifacts and execute them on the CPU
//! client. Python is never on this path — `make artifacts` ran once at
//! build time, and this module only touches `artifacts/*.hlo.txt`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::Json;

/// One loadable artifact as described by `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: PathBuf,
    /// Expected input shapes (row-major dims).
    pub inputs: Vec<Vec<usize>>,
}

/// The PJRT runtime: a CPU client plus lazily compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts: HashMap<String, ArtifactInfo>,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Default artifact location (next to the repo root, `artifacts/`).
    pub fn default_dir() -> PathBuf {
        std::env::var_os("BOTTLEMOD_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Open the runtime over an artifact directory (reads `manifest.json`).
    pub fn new(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run `make artifacts`"))?;
        let manifest =
            Json::parse(&text).map_err(|e| anyhow!("parsing manifest: {e}"))?;
        let mut artifacts = HashMap::new();
        for (name, entry) in manifest
            .as_obj()
            .ok_or_else(|| anyhow!("manifest is not an object"))?
        {
            let file = entry
                .get("file")
                .as_str()
                .ok_or_else(|| anyhow!("artifact {name}: missing file"))?;
            let inputs = entry
                .get("inputs")
                .as_arr()
                .ok_or_else(|| anyhow!("artifact {name}: missing inputs"))?
                .iter()
                .map(|shape| {
                    shape
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|d| d.as_f64())
                        .map(|d| d as usize)
                        .collect()
                })
                .collect();
            artifacts.insert(
                name.clone(),
                ArtifactInfo {
                    name: name.clone(),
                    file: dir.join(file),
                    inputs,
                },
            );
        }
        Ok(Runtime {
            client: xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?,
            artifacts,
            compiled: HashMap::new(),
        })
    }

    /// Names of all known artifacts.
    pub fn names(&self) -> Vec<&str> {
        self.artifacts.keys().map(String::as_str).collect()
    }

    /// Artifact metadata.
    pub fn info(&self, name: &str) -> Option<&ArtifactInfo> {
        self.artifacts.get(name)
    }

    /// Compile (memoized) an artifact.
    pub fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.compiled.contains_key(name) {
            return Ok(());
        }
        let info = self
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        let proto = xla::HloModuleProto::from_text_file(
            info.file
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 path {:?}", info.file))?,
        )
        .map_err(|e| anyhow!("loading {:?}: {e:?}", info.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        self.compiled.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact on f32 tensors. Each input is `(data, dims)`;
    /// dims must match the manifest. Returns the flattened f32 outputs.
    pub fn execute_f32(
        &mut self,
        name: &str,
        inputs: &[(&[f32], &[usize])],
    ) -> Result<Vec<Vec<f32>>> {
        self.ensure_compiled(name)?;
        let info = &self.artifacts[name];
        if inputs.len() != info.inputs.len() {
            bail!(
                "artifact {name}: expected {} inputs, got {}",
                info.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (data, dims)) in inputs.iter().enumerate() {
            if *dims != info.inputs[i].as_slice() {
                bail!(
                    "artifact {name}: input {i} shape {:?} != manifest {:?}",
                    dims,
                    info.inputs[i]
                );
            }
            let n: usize = dims.iter().product();
            if n != data.len() {
                bail!(
                    "artifact {name}: input {i} has {} elems for shape {:?}",
                    data.len(),
                    dims
                );
            }
            let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims_i64)
                .map_err(|e| anyhow!("reshape input {i}: {e:?}"))?;
            literals.push(lit);
        }
        let exe = self.compiled.get(name).unwrap();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result: {e:?}"))?;
        // aot.py lowers with return_tuple=True
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow!("untupling result: {e:?}"))?;
        parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}")))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_present() -> bool {
        Runtime::default_dir().join("manifest.json").exists()
    }

    /// Full L3->PJRT->L1 smoke: evaluate a known piecewise function through
    /// the compiled Pallas artifact and compare with the Rust engine.
    #[test]
    fn eval_pw_artifact_matches_rust_engine() {
        if !artifacts_present() {
            eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
            return;
        }
        let mut rt = Runtime::new(&Runtime::default_dir()).unwrap();
        let name = "eval_pw_b64_s16_d4_t1024";
        let info = rt.info(name).expect("artifact in manifest").clone();
        let (b, s1) = (info.inputs[0][0], info.inputs[0][1]);
        let s = s1 - 1;
        let d = info.inputs[1][2];
        let t = info.inputs[2][0];

        const BIG: f32 = 1e30;
        // function 0: ramp slope 2 until t=10 (value 20), then constant
        let mut breaks = vec![BIG; b * s1];
        let mut coeffs = vec![0f32; b * s * d];
        breaks[0] = 0.0;
        breaks[1] = 10.0;
        coeffs[1] = 2.0; // piece 0, degree 1
        coeffs[d] = 20.0; // piece 1, degree 0
        let ts: Vec<f32> = (0..t).map(|i| i as f32 * 0.05).collect();

        let out = rt
            .execute_f32(
                name,
                &[
                    (&breaks, &info.inputs[0]),
                    (&coeffs, &info.inputs[1]),
                    (&ts, &info.inputs[2]),
                ],
            )
            .unwrap();
        assert_eq!(out[0].len(), b * t);

        let f = crate::pwfn::PwPoly::ramp_to(0.0, 2.0, 20.0);
        for (i, &tv) in ts.iter().enumerate().step_by(97) {
            let want = f.eval(tv as f64) as f32;
            let got = out[0][i];
            assert!(
                (want - got).abs() < 1e-3 * (1.0 + want.abs()),
                "t={tv}: rust {want} vs pjrt {got}"
            );
        }
    }

    #[test]
    fn unknown_artifact_errors() {
        if !artifacts_present() {
            return;
        }
        let mut rt = Runtime::new(&Runtime::default_dir()).unwrap();
        assert!(rt.execute_f32("nope", &[]).is_err());
    }

    #[test]
    fn shape_mismatch_rejected() {
        if !artifacts_present() {
            return;
        }
        let mut rt = Runtime::new(&Runtime::default_dir()).unwrap();
        let bad = vec![0f32; 4];
        let dims: [usize; 1] = [4];
        let one: (&[f32], &[usize]) = (&bad, &dims);
        let r = rt.execute_f32("eval_pw_b64_s16_d4_t1024", &[one, one, one]);
        assert!(r.is_err());
    }
}
