//! PJRT execution of the AOT-compiled JAX/Pallas artifacts.

pub mod pjrt;
pub mod sweep;

pub use pjrt::{ArtifactInfo, Runtime};
pub use sweep::{fig7_sweep, SweepResult};
