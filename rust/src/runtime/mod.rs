//! Sweep runtimes: the CPU-parallel batched scenario-sweep engine
//! ([`sweep`]) with its DAG-aware analysis cache ([`cache`]), and the PJRT
//! artifact path ([`pjrt`] + [`xla_sweep`], stubbed in offline builds).

pub mod cache;
pub mod pjrt;
pub mod sweep;
pub mod xla_sweep;

pub use cache::{AnalysisCache, CacheStats};
pub use pjrt::{ArtifactInfo, Runtime};
pub use sweep::{
    BottleneckReport, FixedWorkflow, RankedBottleneck, ScenarioOutcome, SweepBatch, SweepError,
    SweepModel,
};
pub use xla_sweep::{fig7_sweep, SweepResult};
