//! DAG-aware analysis caching for incremental sweeps.
//!
//! The exact solver ([`crate::solver::exact::solve`]) is a pure function of
//! `(Process, ProcessInputs, SolverOpts)`: same inputs, bit-for-bit same
//! [`Analysis`]. A sweep batch of N perturbed scenarios re-solves every node
//! of every scenario, yet most perturbations (one task's CPU scale, a
//! task-model swap, ...) leave the upstream subgraph's materialized inputs
//! *identical* — and the fixpoint engine re-solves unchanged nodes once per
//! pass on top of that. [`AnalysisCache`] memoizes `solve` across all of it:
//!
//! * the key is a **content hash** of the full solver input — every
//!   breakpoint and coefficient of every requirement/input `PwPoly`, the
//!   start time, and the solver options — via a deterministic 128-bit
//!   FNV-1a ([`Fnv128`]); no pointer identity, no randomized hasher state;
//! * the value is an [`Arc<NodeSolve>`]: the [`Analysis`] plus the derived
//!   output-over-time and resource-demand functions downstream consumers
//!   need, so a hit shares everything without cloning a single `PwPoly`
//!   *and* skips the derived piecewise algebra (compose / derivative /
//!   multiply), not just the solve;
//! * the map is **sharded** (key-selected mutexes) and designed to be
//!   `Arc`-shared across the sweep engine's worker threads;
//! * hit/miss/insert/eviction counters are atomic and exported as
//!   [`CacheStats`] (surfaced in `BottleneckReport` and the service's
//!   `sweep` op).
//!
//! Determinism contract: because the cached value is exactly what a fresh
//! `solve` would return, a cached (even parallel) run is **bit-for-bit
//! identical** to a cold sequential run — asserted by
//! `tests/incremental_equivalence.rs` and `benches/sweep_parallel.rs`.
//! A 128-bit key makes an accidental collision astronomically unlikely
//! (~2^-64 at a billion entries); there is no second-chance verification.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use crate::model::process::{Process, ProcessInputs};
use crate::pwfn::{BatchPwPoly, Poly, PwPoly};
use crate::solver::{Analysis, SolverOpts};

// ------------------------------------------------------------------ hashing

/// Incremental 128-bit FNV-1a. Deterministic across runs and platforms
/// (unlike `DefaultHasher`, whose `RandomState` is seeded per process) —
/// cache keys must be stable so tests can assert cross-run reuse.
#[derive(Clone, Debug)]
pub struct Fnv128 {
    state: u128,
}

const FNV128_OFFSET: u128 = 0x6c62272e07bb014262b821756295c58d;
const FNV128_PRIME: u128 = 0x0000000001000000000000000000013b;

impl Default for Fnv128 {
    fn default() -> Self {
        Fnv128 {
            state: FNV128_OFFSET,
        }
    }
}

impl Fnv128 {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn write_bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state ^= b as u128;
            self.state = self.state.wrapping_mul(FNV128_PRIME);
        }
    }

    pub fn write_u64(&mut self, x: u64) {
        self.write_bytes(&x.to_le_bytes());
    }

    pub fn write_usize(&mut self, x: usize) {
        self.write_u64(x as u64);
    }

    /// Hash the exact bit pattern of the float. `-0.0` and `0.0` hash
    /// differently, which only ever causes a spurious *miss* — never a
    /// wrong hit.
    pub fn write_f64(&mut self, x: f64) {
        self.write_u64(x.to_bits());
    }

    pub fn write_str(&mut self, s: &str) {
        self.write_usize(s.len());
        self.write_bytes(s.as_bytes());
    }

    pub fn finish(&self) -> u128 {
        self.state
    }
}

/// Types whose full mathematical content can be folded into a cache key.
pub trait ContentHash {
    fn content_hash(&self, h: &mut Fnv128);
}

impl ContentHash for Poly {
    fn content_hash(&self, h: &mut Fnv128) {
        h.write_usize(self.coeffs.len());
        for &c in &self.coeffs {
            h.write_f64(c);
        }
    }
}

impl ContentHash for PwPoly {
    fn content_hash(&self, h: &mut Fnv128) {
        h.write_usize(self.breaks.len());
        for &b in &self.breaks {
            h.write_f64(b);
        }
        for p in &self.polys {
            p.content_hash(h);
        }
    }
}

impl ContentHash for Process {
    fn content_hash(&self, h: &mut Fnv128) {
        h.write_str(&self.name);
        h.write_f64(self.max_progress);
        h.write_usize(self.data_reqs.len());
        for d in &self.data_reqs {
            h.write_str(&d.name);
            d.func.content_hash(h);
        }
        h.write_usize(self.res_reqs.len());
        for r in &self.res_reqs {
            h.write_str(&r.name);
            r.func.content_hash(h);
        }
        h.write_usize(self.outputs.len());
        for o in &self.outputs {
            h.write_str(&o.name);
            o.func.content_hash(h);
        }
    }
}

impl ContentHash for ProcessInputs {
    fn content_hash(&self, h: &mut Fnv128) {
        h.write_f64(self.start_time);
        h.write_usize(self.data.len());
        for f in &self.data {
            f.content_hash(h);
        }
        h.write_usize(self.resources.len());
        for f in &self.resources {
            f.content_hash(h);
        }
    }
}

impl ContentHash for SolverOpts {
    fn content_hash(&self, h: &mut Fnv128) {
        h.write_f64(self.horizon);
        h.write_usize(self.max_events);
        h.write_f64(self.tol);
        // budgeted solves key differently from exact ones: the engine
        // coarsens materialized inputs under these knobs, so a cache
        // entry is only reusable under the same budget configuration
        h.write_usize(self.piece_budget);
        h.write_f64(self.piece_budget_err);
    }
}

/// The cache key of one node-level solve: everything `solve` reads.
pub fn node_key(process: &Process, inputs: &ProcessInputs, opts: &SolverOpts) -> u128 {
    let mut h = Fnv128::new();
    process.content_hash(&mut h);
    inputs.content_hash(&mut h);
    opts.content_hash(&mut h);
    h.finish()
}

// -------------------------------------------------------------- cache value

/// Everything one node-level solve contributes to the rest of a workflow
/// analysis: the analysis itself plus the derived functions the engine
/// otherwise recomputes per consumer / per pool charge. All fields are pure
/// functions of `(Process, ProcessInputs, SolverOpts)`, so they are exactly
/// as cacheable as the analysis.
///
/// The derived vectors are sparse: the engine asks only for the outputs
/// some consumer actually reads and the demands of pool-backed resources
/// (a `None` slot is derived lazily from `analysis` by the engine — same
/// expression, so results never depend on which slots were precomputed).
/// The key does not cover wiring, so a value derived under one wiring may
/// be hit by a node wired differently; sparseness + fallback keeps that
/// correct.
#[derive(Clone, Debug)]
pub struct NodeSolve {
    /// The solver result, `Arc`'d so `WorkflowAnalysis` shares it.
    pub analysis: Arc<Analysis>,
    /// `O_m(P(t))` per output `m` ([`Analysis::output_over_time`]) — the
    /// data-input function of downstream consumers.
    pub outputs: Vec<Option<PwPoly>>,
    /// Simplified `P'(t)·R'_Rl(P(t))` per resource `l`
    /// ([`Analysis::resource_demand`]) — what the engine charges against
    /// shared pools.
    pub demands: Vec<Option<PwPoly>>,
}

impl NodeSolve {
    /// Derive the consumer-facing functions from a finished analysis —
    /// only the slots flagged in `need_outputs` / `need_demands` (missing
    /// flags count as not needed). Uses the very same expressions the
    /// uncached engine evaluates lazily, so cached and cold runs stay
    /// bit-for-bit identical.
    pub fn derive(
        process: &Process,
        analysis: Arc<Analysis>,
        need_outputs: &[bool],
        need_demands: &[bool],
    ) -> NodeSolve {
        let outputs = (0..process.outputs.len())
            .map(|m| {
                need_outputs
                    .get(m)
                    .copied()
                    .unwrap_or(false)
                    .then(|| analysis.output_over_time(process, m))
            })
            .collect();
        // one progress derivative shared across all charged resources
        let any_demand = (0..process.res_reqs.len())
            .any(|l| need_demands.get(l).copied().unwrap_or(false));
        let dp = any_demand.then(|| analysis.progress.derivative());
        let demands = (0..process.res_reqs.len())
            .map(|l| {
                need_demands.get(l).copied().unwrap_or(false).then(|| {
                    analysis
                        .resource_demand_with(dp.as_ref().unwrap(), process, l)
                        .simplify()
                })
            })
            .collect();
        NodeSolve {
            analysis,
            outputs,
            demands,
        }
    }

    /// Materialize the derived curves on a shared time grid through the
    /// structure-of-arrays batch backend ([`BatchPwPoly`]): one compile
    /// over every present output-over-time / pool-demand slot, one
    /// galloping merge per curve. Returns `(outputs, demands)` sampled at
    /// `ts`, slot-aligned with [`NodeSolve::outputs`] /
    /// [`NodeSolve::demands`] (`None` slots stay `None`). Each value is
    /// bit-for-bit the scalar `PwPoly::eval` at the same point — the
    /// grid-materialization counterpart of [`NodeSolve::derive`]'s
    /// symbolic algebra.
    pub fn sample_derived(&self, ts: &[f64]) -> (Vec<Option<Vec<f64>>>, Vec<Option<Vec<f64>>>) {
        if ts.is_empty() {
            let empty = |v: &[Option<PwPoly>]| -> Vec<Option<Vec<f64>>> {
                v.iter().map(|o| o.as_ref().map(|_| Vec::new())).collect()
            };
            return (empty(&self.outputs), empty(&self.demands));
        }
        let curves: Vec<&PwPoly> = self
            .outputs
            .iter()
            .chain(self.demands.iter())
            .flatten()
            .collect();
        let flat = BatchPwPoly::compile(&curves).eval_scenarios(ts);
        let mut rows = flat.chunks(ts.len());
        let outputs = self
            .outputs
            .iter()
            .map(|o| o.as_ref().map(|_| rows.next().unwrap().to_vec()))
            .collect();
        let demands = self
            .demands
            .iter()
            .map(|o| o.as_ref().map(|_| rows.next().unwrap().to_vec()))
            .collect();
        (outputs, demands)
    }

    /// Approximate resident heap size of this value in bytes — what the
    /// cache's byte quota charges. Counts the piecewise payloads (break
    /// vectors, coefficient vectors, per-`Vec` overhead); the fixed-size
    /// scalar fields are a constant. An approximation is fine here: the
    /// quota bounds memory to within a small constant factor, and a too-low
    /// estimate only ever costs extra misses, never wrong results.
    pub fn cost_bytes(&self) -> u64 {
        let a = &self.analysis;
        let mut b = 160; // scalars, Vec headers, Arc control blocks
        b += pw_bytes(&a.progress);
        for f in &a.data_progress {
            b += pw_bytes(f);
        }
        b += pw_bytes(&a.pd.func) + 8 * a.pd.winners.len() as u64;
        b += 32 * a.segments.len() as u64;
        for f in self.outputs.iter().flatten() {
            b += pw_bytes(f);
        }
        for f in self.demands.iter().flatten() {
            b += pw_bytes(f);
        }
        b
    }
}

/// Heap bytes of one piecewise polynomial: 8 per break/coefficient plus a
/// `Vec` header per polynomial and for the two top-level vectors.
fn pw_bytes(p: &PwPoly) -> u64 {
    let coeffs: usize = p.polys.iter().map(|q| q.coeffs.len()).sum();
    (8 * (p.breaks.len() + coeffs) + 24 * p.polys.len() + 48) as u64
}

// -------------------------------------------------------------------- stats

/// A point-in-time snapshot of the cache counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups answered from the cache.
    pub hits: u64,
    /// Lookups that fell through to a fresh solve.
    pub misses: u64,
    /// Values stored (== misses unless a racing worker inserted first).
    pub inserts: u64,
    /// Entries dropped by capacity or byte-quota eviction.
    pub evictions: u64,
    /// Entries currently resident.
    pub entries: u64,
    /// Approximate resident heap bytes ([`NodeSolve::cost_bytes`]) of the
    /// current entries.
    pub bytes: u64,
}

impl CacheStats {
    /// `hits / (hits + misses)`, 0 when the cache was never consulted.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// The counter deltas between `earlier` and `self` (entries and bytes
    /// stay the current values) — how a shared, long-lived cache reports
    /// one batch's behaviour in isolation.
    pub fn since(&self, earlier: &CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits.saturating_sub(earlier.hits),
            misses: self.misses.saturating_sub(earlier.misses),
            inserts: self.inserts.saturating_sub(earlier.inserts),
            evictions: self.evictions.saturating_sub(earlier.evictions),
            entries: self.entries,
            bytes: self.bytes,
        }
    }
}

impl std::fmt::Display for CacheStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} hits / {} misses ({:.1}% hit rate), {} entries ({} KiB), {} evicted",
            self.hits,
            self.misses,
            self.hit_rate() * 100.0,
            self.entries,
            self.bytes / 1024,
            self.evictions
        )
    }
}

// -------------------------------------------------------------------- cache

/// One resident value plus its accounting metadata.
struct Entry {
    value: Arc<NodeSolve>,
    /// [`NodeSolve::cost_bytes`], computed once at insert.
    cost: u64,
    /// Last-touch tick; key into the shard's LRU index.
    tick: u64,
}

/// One shard: the map, a recency index (`tick -> key`; ticks are unique,
/// so a `BTreeMap` is an exact LRU order), and the shard's byte total.
#[derive(Default)]
struct Shard {
    map: HashMap<u128, Entry>,
    lru: BTreeMap<u64, u128>,
    bytes: u64,
}

/// A sharded, thread-safe memo table for node-level analyses.
///
/// Wrap it in an [`Arc`] and hand clones to every sweep worker; lookups
/// contend only on the shard owning the key. Both quotas — entry count and
/// approximate resident bytes — are enforced per shard with least-recently
/// used eviction, so a long-lived multi-tenant session stays within its
/// configured memory budget. Eviction can only cause extra *misses*, never
/// wrong results.
pub struct AnalysisCache {
    shards: Vec<Mutex<Shard>>,
    /// Global recency clock; unique per touch, so LRU ordering is exact.
    tick: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    inserts: AtomicU64,
    evictions: AtomicU64,
    capacity_per_shard: usize,
    byte_quota_per_shard: u64,
}

const DEFAULT_SHARDS: usize = 16;
const DEFAULT_CAPACITY: usize = 1 << 16;

impl Default for AnalysisCache {
    fn default() -> Self {
        Self::with_capacity(DEFAULT_CAPACITY)
    }
}

/// A panic in another thread while it held a shard lock poisons the mutex;
/// the shard data is only ever mutated under short, non-panicking critical
/// sections, so the state behind a poisoned lock is sound — recover it
/// rather than cascading the failure into every future lookup (the server
/// catches job panics and must keep serving).
fn lock_shard(m: &Mutex<Shard>) -> MutexGuard<'_, Shard> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

impl AnalysisCache {
    /// A cache with the default capacity (65 536 entries, no byte quota).
    pub fn new() -> Self {
        Self::default()
    }

    /// A cache holding up to `capacity` entries across all shards, with no
    /// byte quota.
    pub fn with_capacity(capacity: usize) -> Self {
        Self::with_quota(capacity, u64::MAX)
    }

    /// A cache bounded by both an entry count and an approximate byte
    /// budget ([`NodeSolve::cost_bytes`]) across all shards. Whichever
    /// quota is hit first evicts least-recently-used entries. One caveat:
    /// a single entry larger than a whole shard's byte quota stays
    /// resident until something displaces it (evicting the value being
    /// inserted would livelock the solver).
    pub fn with_quota(capacity: usize, max_bytes: u64) -> Self {
        AnalysisCache {
            shards: (0..DEFAULT_SHARDS).map(|_| Mutex::new(Shard::default())).collect(),
            tick: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            inserts: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            capacity_per_shard: (capacity / DEFAULT_SHARDS).max(1),
            byte_quota_per_shard: if max_bytes == u64::MAX {
                u64::MAX
            } else {
                (max_bytes / DEFAULT_SHARDS as u64).max(1)
            },
        }
    }

    fn shard(&self, key: u128) -> &Mutex<Shard> {
        // low bits of an FNV state are well mixed
        &self.shards[(key as usize) % self.shards.len()]
    }

    fn next_tick(&self) -> u64 {
        self.tick.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Look up a node analysis, counting the hit or miss. A hit refreshes
    /// the entry's LRU position.
    pub fn get(&self, key: u128) -> Option<Arc<NodeSolve>> {
        let mut guard = lock_shard(self.shard(key));
        let Shard { map, lru, .. } = &mut *guard;
        let found = map.get_mut(&key).map(|e| {
            let t = self.next_tick();
            lru.remove(&e.tick);
            e.tick = t;
            lru.insert(t, key);
            Arc::clone(&e.value)
        });
        drop(guard);
        match &found {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        found
    }

    /// Store a freshly solved analysis, then evict least-recently-used
    /// entries (never the one just stored) while the shard exceeds either
    /// its entry capacity or its byte quota.
    pub fn insert(&self, key: u128, value: Arc<NodeSolve>) {
        let cost = value.cost_bytes();
        let t = self.next_tick();
        let mut guard = lock_shard(self.shard(key));
        let shard = &mut *guard;
        let mut fresh = false;
        if let Some(old) = shard.map.insert(key, Entry { value, cost, tick: t }) {
            shard.lru.remove(&old.tick);
            shard.bytes = shard.bytes + cost - old.cost;
        } else {
            fresh = true;
            shard.bytes += cost;
        }
        shard.lru.insert(t, key);
        // `t` is the largest tick in this shard (the clock is monotone and
        // the shard is locked), so `pop_first` can only reach the entry
        // just inserted when it is the shard's sole entry — which the
        // `len() > 1` guard excludes.
        let mut evicted = 0u64;
        while shard.map.len() > 1
            && (shard.map.len() > self.capacity_per_shard
                || shard.bytes > self.byte_quota_per_shard)
        {
            let (_, victim) = shard.lru.pop_first().expect("lru indexes every entry");
            let gone = shard.map.remove(&victim).expect("lru and map agree");
            shard.bytes -= gone.cost;
            evicted += 1;
        }
        drop(guard);
        if fresh {
            self.inserts.fetch_add(1, Ordering::Relaxed);
        }
        if evicted > 0 {
            self.evictions.fetch_add(evicted, Ordering::Relaxed);
        }
    }

    /// Entries currently resident across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| lock_shard(s).map.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Approximate resident heap bytes across all shards.
    pub fn bytes(&self) -> u64 {
        self.shards.iter().map(|s| lock_shard(s).bytes).sum()
    }

    /// Drop every entry (counters keep running).
    pub fn clear(&self) {
        for s in &self.shards {
            let mut shard = lock_shard(s);
            self.evictions
                .fetch_add(shard.map.len() as u64, Ordering::Relaxed);
            shard.map.clear();
            shard.lru.clear();
            shard.bytes = 0;
        }
    }

    /// Zero the hit/miss/insert/eviction counters (entries stay resident) —
    /// used to measure one batch in isolation.
    pub fn reset_counters(&self) {
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
        self.inserts.store(0, Ordering::Relaxed);
        self.evictions.store(0, Ordering::Relaxed);
    }

    /// Snapshot the counters.
    pub fn stats(&self) -> CacheStats {
        let (mut entries, mut bytes) = (0u64, 0u64);
        for s in &self.shards {
            let shard = lock_shard(s);
            entries += shard.map.len() as u64;
            bytes += shard.bytes;
        }
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            inserts: self.inserts.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            entries,
            bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ProcessBuilder;

    fn sample_inputs(rate: f64) -> ProcessInputs {
        ProcessInputs {
            data: vec![PwPoly::constant(100.0)],
            resources: vec![PwPoly::constant(rate)],
            start_time: 0.0,
        }
    }

    fn sample_process(cpu: f64) -> Process {
        ProcessBuilder::new("p", 100.0)
            .stream_data("in", 100.0)
            .stream_resource("cpu", cpu)
            .identity_output("out")
            .build()
    }

    #[test]
    fn key_is_deterministic_and_content_sensitive() {
        let p = sample_process(50.0);
        let i = sample_inputs(1.0);
        let o = SolverOpts::default();
        let k1 = node_key(&p, &i, &o);
        let k2 = node_key(&p.clone(), &i.clone(), &o.clone());
        assert_eq!(k1, k2, "same content must give the same key");

        // any single knob changes the key
        assert_ne!(k1, node_key(&sample_process(51.0), &i, &o));
        assert_ne!(k1, node_key(&p, &sample_inputs(2.0), &o));
        let o2 = SolverOpts {
            tol: 1e-8,
            ..SolverOpts::default()
        };
        assert_ne!(k1, node_key(&p, &i, &o2));
        // budgeted solves must never alias exact ones
        let o3 = SolverOpts {
            piece_budget: 64,
            piece_budget_err: 1e-6,
            ..SolverOpts::default()
        };
        assert_ne!(k1, node_key(&p, &i, &o3));
        let mut i2 = sample_inputs(1.0);
        i2.start_time = 5.0;
        assert_ne!(k1, node_key(&p, &i2, &o));
    }

    #[test]
    fn get_insert_roundtrip_counts() {
        let cache = AnalysisCache::new();
        let p = sample_process(50.0);
        let i = sample_inputs(1.0);
        let o = SolverOpts::default();
        let key = node_key(&p, &i, &o);
        assert!(cache.get(key).is_none());
        let solved = Arc::new(crate::solver::solve(&p, &i, &o).unwrap());
        let a = Arc::new(NodeSolve::derive(&p, solved, &[true], &[true]));
        cache.insert(key, a.clone());
        let back = cache.get(key).expect("hit after insert");
        assert!(Arc::ptr_eq(&a, &back), "hit must share, not clone");
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.inserts), (1, 1, 1));
        assert_eq!(s.entries, 1);
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    fn sample_value() -> Arc<NodeSolve> {
        let p = sample_process(50.0);
        let i = sample_inputs(1.0);
        let solved = Arc::new(crate::solver::solve(&p, &i, &SolverOpts::default()).unwrap());
        Arc::new(NodeSolve::derive(&p, solved, &[true], &[true]))
    }

    /// Grid materialization of the derived curves goes through the SoA
    /// batch backend and stays bit-for-bit the scalar per-point eval;
    /// `None` slots stay `None`.
    #[test]
    fn sample_derived_matches_scalar_and_keeps_slots() {
        let p = sample_process(50.0);
        let i = sample_inputs(1.0);
        let solved = Arc::new(crate::solver::solve(&p, &i, &SolverOpts::default()).unwrap());
        let ns = NodeSolve::derive(&p, solved, &[true], &[false]);
        let ts: Vec<f64> = (0..40).map(|k| k as f64 * 3.5).collect();
        let (outputs, demands) = ns.sample_derived(&ts);
        assert_eq!(outputs.len(), ns.outputs.len());
        assert_eq!(demands.len(), ns.demands.len());
        assert!(demands.iter().all(|d| d.is_none()), "unneeded slot stays None");
        let curve = ns.outputs[0].as_ref().unwrap();
        let row = outputs[0].as_ref().unwrap();
        for (&t, &v) in ts.iter().zip(row) {
            assert_eq!(v.to_bits(), curve.eval(t).to_bits());
        }
        // empty grid: present slots become empty rows, not None
        let (o0, _) = ns.sample_derived(&[]);
        assert_eq!(o0[0].as_deref(), Some(&[][..]));
    }

    /// Keys `n * DEFAULT_SHARDS` for small `n` all land in shard 0.
    fn shard0_key(n: usize) -> u128 {
        (n * DEFAULT_SHARDS) as u128
    }

    #[test]
    fn eviction_drops_oldest_when_shard_full() {
        let cache = AnalysisCache::with_capacity(16); // 1 entry per shard
        let a = sample_value();
        cache.insert(shard0_key(0), a.clone());
        cache.insert(shard0_key(1), a.clone());
        assert!(cache.get(shard0_key(0)).is_none(), "oldest entry evicted");
        assert!(cache.get(shard0_key(1)).is_some());
        assert!(cache.stats().evictions >= 1);
    }

    #[test]
    fn lru_keeps_recently_touched_entries() {
        let cache = AnalysisCache::with_capacity(32); // 2 entries per shard
        let a = sample_value();
        cache.insert(shard0_key(0), a.clone());
        cache.insert(shard0_key(1), a.clone());
        // touching k0 makes k1 the LRU victim of the next insert
        assert!(cache.get(shard0_key(0)).is_some());
        cache.insert(shard0_key(2), a.clone());
        let s = cache.stats();
        assert!(cache.get(shard0_key(0)).is_some(), "recently used survives");
        assert!(cache.get(shard0_key(1)).is_none(), "LRU entry evicted");
        assert!(cache.get(shard0_key(2)).is_some());
        assert_eq!(s.evictions, 1, "{s}");
    }

    #[test]
    fn byte_quota_bounds_resident_bytes() {
        let a = sample_value();
        let cost = a.cost_bytes();
        assert!(cost > 0);
        // room for ~2 entries' bytes in shard 0, far more entry slots
        let quota = (2 * cost + cost / 2) * DEFAULT_SHARDS as u64;
        let cache = AnalysisCache::with_quota(1 << 16, quota);
        for n in 0..6 {
            cache.insert(shard0_key(n), a.clone());
        }
        let s = cache.stats();
        assert!(s.bytes <= quota / DEFAULT_SHARDS as u64, "{s}");
        assert_eq!(s.entries, 2, "{s}");
        assert_eq!(s.evictions, 4, "{s}");
        assert_eq!(cache.bytes(), s.bytes);
        // the newest entries are the survivors
        assert!(cache.get(shard0_key(4)).is_some());
        assert!(cache.get(shard0_key(5)).is_some());
    }

    #[test]
    fn oversized_single_entry_stays_resident() {
        let a = sample_value();
        // quota below one entry's cost: the lone entry must not be evicted
        let cache = AnalysisCache::with_quota(1 << 16, DEFAULT_SHARDS as u64);
        cache.insert(shard0_key(0), a.clone());
        assert!(cache.get(shard0_key(0)).is_some());
        // a second insert displaces it (the newer entry survives)
        cache.insert(shard0_key(1), a.clone());
        assert!(cache.get(shard0_key(0)).is_none());
        assert!(cache.get(shard0_key(1)).is_some());
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn reinsert_updates_bytes_not_entries() {
        let cache = AnalysisCache::new();
        let a = sample_value();
        cache.insert(7, a.clone());
        let before = cache.stats();
        cache.insert(7, a.clone());
        let after = cache.stats();
        assert_eq!(after.entries, before.entries);
        assert_eq!(after.bytes, before.bytes);
        assert_eq!(after.inserts, before.inserts, "re-insert is not fresh");
    }

    #[test]
    fn clear_zeroes_bytes() {
        let cache = AnalysisCache::new();
        cache.insert(1, sample_value());
        assert!(cache.bytes() > 0);
        cache.clear();
        assert_eq!(cache.bytes(), 0);
        assert!(cache.is_empty());
    }

    #[test]
    fn reset_counters_keeps_entries() {
        let cache = AnalysisCache::new();
        let p = sample_process(50.0);
        let i = sample_inputs(1.0);
        let o = SolverOpts::default();
        let key = node_key(&p, &i, &o);
        let solved = Arc::new(crate::solver::solve(&p, &i, &o).unwrap());
        cache.insert(key, Arc::new(NodeSolve::derive(&p, solved, &[true], &[true])));
        let _ = cache.get(key);
        cache.reset_counters();
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (0, 0));
        assert_eq!(s.entries, 1);
        assert!(cache.get(key).is_some());
    }

    #[test]
    fn fnv_distinguishes_field_boundaries() {
        // [1.0, 2.0] vs [1.0], [2.0]: the length prefixes must disambiguate
        let mut h1 = Fnv128::new();
        PwPoly::constant(1.0).content_hash(&mut h1);
        PwPoly::constant(2.0).content_hash(&mut h1);
        let mut h2 = Fnv128::new();
        PwPoly::constant(2.0).content_hash(&mut h2);
        PwPoly::constant(1.0).content_hash(&mut h2);
        assert_ne!(h1.finish(), h2.finish());
    }
}
