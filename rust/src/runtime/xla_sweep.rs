//! Batched Fig 7 sweep on the PJRT runtime (XLA-backend path).
//!
//! Evaluates the whole Fig 5 workflow for B link-fraction configurations at
//! once by staging the batched L2 grid solver (`grid_solve_pd` artifact):
//! the Rust coordinator walks the workflow stages (downloads → tasks 1/2 →
//! task 3) and hands each stage's B-wide numeric work to XLA. Pool release
//! is handled with the same two-pass fixpoint as the exact engine.
//!
//! This trades the exact solver's precision for one fused, vectorized pass
//! per stage. In the offline build the PJRT backend is a stub
//! ([`Runtime::backend_available`] is false), so [`fig7_sweep`] errors at
//! the first artifact execution. The batched path no longer depends on
//! PJRT, though: its pure-Rust realization is the structure-of-arrays
//! batch backend [`crate::pwfn::BatchPwPoly`] — exact solves via
//! [`super::sweep::SweepBatch`] (no artifacts at all), then one
//! `eval_scenarios` pass materializes the same B-configurations ×
//! T-points grid this artifact would produce, bit-for-bit equal to the
//! scalar evaluator. `benches/fig7_sweep.rs` falls back to that backend
//! when no execution backend is built in.

use crate::bail;
use crate::util::error::Result;
use crate::workflow::scenario::VideoScenario;

use super::pjrt::Runtime;

/// Shape constants of the sweep artifact (`grid_solve_pd_b600_k2_l2_s4_t2048`).
pub const B: usize = 600;
pub const K: usize = 2;
pub const L: usize = 2;
pub const S2: usize = 4;
pub const T: usize = 2048;
const BIG: f32 = 1e30;

/// Result of a batched sweep.
#[derive(Clone, Debug)]
pub struct SweepResult {
    pub fractions: Vec<f64>,
    /// Predicted total workflow time per fraction.
    pub totals: Vec<f64>,
    /// Stage makespans for diagnostics.
    pub dl1_done: Vec<f64>,
    pub dl2_done: Vec<f64>,
    pub t1_done: Vec<f64>,
    pub t2_done: Vec<f64>,
}

struct Stage<'rt> {
    rt: &'rt mut Runtime,
    name: String,
    ts: Vec<f32>,
}

impl<'rt> Stage<'rt> {
    /// One batched grid_solve_pd call. All slices are row-major.
    fn solve(
        &mut self,
        pd: &[f32],      // [B, K, T]
        rbreaks: &[f32], // [B, L, S2+1]
        rslopes: &[f32], // [B, L, S2]
        rin: &[f32],     // [B, L, T]
        target: &[f32],  // [B]
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let out = self.rt.execute_f32(
            &self.name,
            &[
                (pd, &[B, K, T]),
                (rbreaks, &[B, L, S2 + 1]),
                (rslopes, &[B, L, S2]),
                (rin, &[B, L, T]),
                (&self.ts, &[T]),
                (target, &[B]),
            ],
        )?;
        let p = out[0].clone();
        let mk = out[1].clone();
        Ok((p, mk))
    }
}

/// Single-piece R' = slope resource tables (resource 1 is padding).
fn simple_resources(slope: f64) -> (Vec<f32>, Vec<f32>) {
    let mut rbreaks = vec![BIG; B * L * (S2 + 1)];
    let mut rslopes = vec![0f32; B * L * S2];
    for b in 0..B {
        rbreaks[b * L * (S2 + 1)] = 0.0; // resource 0 piece 0 starts at 0
        rbreaks[b * L * (S2 + 1) + (S2 + 1)] = 0.0; // resource 1 (padding)
        rslopes[b * L * S2] = slope as f32;
    }
    (rbreaks, rslopes)
}

/// Run the batched Fig 7 sweep. `fractions.len()` must be ≤ B; missing
/// entries are padded with the last fraction.
pub fn fig7_sweep(
    rt: &mut Runtime,
    sc: &VideoScenario,
    fractions: &[f64],
) -> Result<SweepResult> {
    if fractions.is_empty() || fractions.len() > B {
        bail!("need 1..={B} fractions, got {}", fractions.len());
    }
    let name = format!("grid_solve_pd_b{B}_k{K}_l{L}_s{S2}_t{T}");
    if rt.info(&name).is_none() {
        bail!("artifact {name} missing — run `make artifacts`");
    }
    let span = 6.0 * sc.input_size / sc.link_rate; // ≳ 2 workflows worth
    let ts: Vec<f32> = (0..T).map(|i| (i as f64 * span / T as f64) as f32).collect();
    let dt = span / T as f64;
    let mut stage = Stage { rt, name, ts };

    let mut fr = fractions.to_vec();
    fr.resize(B, *fractions.last().unwrap());
    let size = sc.input_size;
    let cap = sc.link_rate;

    // pd for the downloads: remote file always fully available
    let mut pd_const = vec![0f32; B * K * T];
    for b in 0..B {
        for t in 0..T {
            pd_const[(b * K) * T + t] = size as f32;
            pd_const[(b * K + 1) * T + t] = BIG; // padding input
        }
    }
    let (rb1, rs1) = simple_resources(1.0); // downloads: 1 byte link / byte
    let target_dl = vec![size as f32; B];

    // ---- pass 1: dl1 at its fraction, dl2 on the residual --------------
    let rin_dl1: Vec<f32> = rin_const(|b| fr[b] * cap);
    let (p1, _t1) = stage.solve(&pd_const, &rb1, &rs1, &rin_dl1, &target_dl)?;
    let rin_dl2 = residual_rin(&p1, cap, dt);
    let (p2, mk2) = stage.solve(&pd_const, &rb1, &rs1, &rin_dl2, &target_dl)?;

    // ---- pass 2: release dl1 when dl2 finished, recompute residual ------
    let rin_dl1b = released_rin(&mk2, |b| fr[b] * cap, cap, &stage.ts);
    let (p1b, mk1b) = stage.solve(&pd_const, &rb1, &rs1, &rin_dl1b, &target_dl)?;
    let rin_dl2b = residual_rin(&p1b, cap, dt);
    let (p2b, mk2b) = stage.solve(&pd_const, &rb1, &rs1, &rin_dl2b, &target_dl)?;

    // ---- task 1: burst on dl1 completion, encode CPU --------------------
    let mut pd_t1 = vec![0f32; B * K * T];
    for b in 0..B {
        for t in 0..T {
            let done = p1b[b * T + t] >= (size * (1.0 - 1e-6)) as f32;
            pd_t1[(b * K) * T + t] = if done { sc.t1_output as f32 } else { 0.0 };
            pd_t1[(b * K + 1) * T + t] = BIG;
        }
    }
    let (rb_t1, rs_t1) = simple_resources(sc.t1_cpu / sc.t1_output);
    let rin_one: Vec<f32> = rin_const(|_| 1.0);
    let target_t1 = vec![sc.t1_output as f32; B];
    let (_pt1, mk_t1) = stage.solve(&pd_t1, &rb_t1, &rs_t1, &rin_one, &target_t1)?;

    // ---- task 2: stream on dl2 progress ---------------------------------
    let mut pd_t2 = vec![0f32; B * K * T];
    for b in 0..B {
        for t in 0..T {
            pd_t2[(b * K) * T + t] = p2b[b * T + t];
            pd_t2[(b * K + 1) * T + t] = BIG;
        }
    }
    let (rb_t2, rs_t2) = simple_resources(sc.t2_time / sc.input_size);
    let target_t2 = vec![size as f32; B];
    let (_pt2, mk_t2) = stage.solve(&pd_t2, &rb_t2, &rs_t2, &rin_one, &target_t2)?;

    // ---- task 3: barrier start, 3 s of io --------------------------------
    let t3_total = sc.t1_output + sc.input_size;
    let pd_t3: Vec<f32> = {
        let mut v = vec![0f32; B * K * T];
        for b in 0..B {
            for t in 0..T {
                v[(b * K) * T + t] = t3_total as f32;
                v[(b * K + 1) * T + t] = BIG;
            }
        }
        v
    };
    let (rb_t3, rs_t3) = simple_resources(sc.t3_time / t3_total);
    // allocation gated on the barrier
    let mut rin_t3 = vec![0f32; B * L * T];
    for b in 0..B {
        let start = mk_t1[b].max(mk_t2[b]);
        for t in 0..T {
            if stage.ts[t] >= start {
                rin_t3[(b * L) * T + t] = 1.0;
            }
        }
    }
    let target_t3 = vec![t3_total as f32; B];
    let (_pt3, mk_t3) = stage.solve(&pd_t3, &rb_t3, &rs_t3, &rin_t3, &target_t3)?;

    let _ = p2;
    Ok(SweepResult {
        fractions: fractions.to_vec(),
        totals: mk_t3[..fractions.len()].iter().map(|&x| x as f64).collect(),
        dl1_done: mk1b[..fractions.len()].iter().map(|&x| x as f64).collect(),
        dl2_done: mk2b[..fractions.len()].iter().map(|&x| x as f64).collect(),
        t1_done: mk_t1[..fractions.len()].iter().map(|&x| x as f64).collect(),
        t2_done: mk_t2[..fractions.len()].iter().map(|&x| x as f64).collect(),
    })
}

/// rin with a constant rate per config on resource 0, zeros on padding.
fn rin_const(rate: impl Fn(usize) -> f64) -> Vec<f32> {
    let mut v = vec![0f32; B * L * T];
    for b in 0..B {
        let r = rate(b) as f32;
        for t in 0..T {
            v[(b * L) * T + t] = r;
        }
    }
    v
}

/// Residual capacity: cap − observed rate of the other flow (from its
/// progress grid).
fn residual_rin(p_other: &[f32], cap: f64, dt: f64) -> Vec<f32> {
    let mut v = vec![0f32; B * L * T];
    for b in 0..B {
        for t in 0..T {
            let rate = if t + 1 < T {
                (p_other[b * T + t + 1] - p_other[b * T + t]) as f64 / dt
            } else {
                0.0
            };
            v[(b * L) * T + t] = (cap - rate).max(0.0) as f32;
        }
    }
    v
}

/// Fraction rate until the peer's finish time, full capacity after.
fn released_rin(
    peer_done: &[f32],
    frac_rate: impl Fn(usize) -> f64,
    cap: f64,
    ts: &[f32],
) -> Vec<f32> {
    let mut v = vec![0f32; B * L * T];
    for b in 0..B {
        let release = peer_done[b];
        let fr = frac_rate(b) as f32;
        for t in 0..T {
            v[(b * L) * T + t] = if ts[t] >= release { cap as f32 } else { fr };
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batched_sweep_matches_exact_engine() {
        if !Runtime::backend_available()
            || !Runtime::default_dir().join("manifest.json").exists()
        {
            eprintln!("skipping: PJRT backend/artifacts not available");
            return;
        }
        use crate::solver::SolverOpts;
        use crate::workflow::engine::analyze_fixpoint;
        let mut rt = Runtime::new(&Runtime::default_dir()).unwrap();
        let sc = VideoScenario::default();
        let fractions = [0.2, 0.5, 0.8, 0.93, 0.95];
        let sweep = fig7_sweep(&mut rt, &sc, &fractions).unwrap();
        for (i, &f) in fractions.iter().enumerate() {
            let (wf, _) = sc.clone().with_fraction(f).build();
            let exact = analyze_fixpoint(&wf, &SolverOpts::default(), 6)
                .unwrap()
                .makespan
                .unwrap();
            let batched = sweep.totals[i];
            // grid dt ≈ 0.26 s + f32: allow ~1.5%
            assert!(
                (exact - batched).abs() < 0.015 * exact + 2.0 * 0.3,
                "f={f}: exact {exact} vs batched {batched}"
            );
        }
    }

    #[test]
    fn sweep_without_backend_reports_missing_artifact() {
        let dir = std::env::temp_dir().join("bottlemod_xla_sweep_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), "{}").unwrap();
        let mut rt = Runtime::new(&dir).unwrap();
        let err = fig7_sweep(&mut rt, &VideoScenario::default(), &[0.5])
            .unwrap_err()
            .to_string();
        assert!(err.contains("missing"), "{err}");
    }
}
