//! The batched, thread-parallel scenario-sweep engine.
//!
//! The §6 headline makes massive what-if sweeps the natural scaling axis:
//! the exact solver's cost depends on model complexity only, so evaluating
//! hundreds of scenario variants is hundreds of *independent, cheap*
//! analyses — an embarrassingly parallel batch. [`SweepBatch`] is that
//! batch: it holds one immutable base model behind an `Arc<dyn SweepModel>`
//! (the built-in [`VideoScenario`] / [`GenomicsScenario`] scenarios, or any
//! [`FixedWorkflow`] from an inline spec or a calibrated trace; the task
//! models — every requirement/output `PwPoly` — are shared, never
//! copied per worker), takes N [`Perturbation`]s (input-rate,
//! resource-allocation and task-model variants), fans the per-scenario
//! `solver::exact` fixpoint analyses out on the scoped-thread pool
//! ([`crate::util::par`]), and aggregates every scenario's
//! `Analysis`/`Bottleneck` segments into one ranked bottleneck report.
//!
//! Determinism contract: scenario `i`'s outcome is produced by the same
//! pure computation regardless of thread count, and [`par_map`] returns
//! results at their input index — so a parallel run is **bit-for-bit
//! identical** to the sequential one (`threads = 1`). The
//! `sweep_parallel` bench asserts this on a 256-scenario batch.
//!
//! # Incremental sweeps
//!
//! With an [`AnalysisCache`] attached ([`SweepBatch::with_cache`]), the
//! batch becomes *incremental*: each node-level solve is memoized on a
//! content hash of its materialized inputs, so a perturbation only pays for
//! its own dirty cone ([`Perturbation::dirty_set`]) — the upstream subgraph
//! is served from the cache, as are the unchanged re-solves inside each
//! scenario's fixpoint iteration. The planner ([`SweepBatch::plan`]) orders
//! the batch by dirty-set shape so scenarios sharing a clean prefix run
//! consecutively; results are still returned in input order, bit-for-bit
//! equal to a cold run (the cache stores exactly what a fresh solve would
//! produce). Cache statistics ride along in [`BottleneckReport::cache`].

use std::collections::HashMap;
use std::fmt;
use std::sync::Arc;

use crate::pwfn::{BatchPwPoly, PwPoly};
use crate::runtime::cache::{AnalysisCache, CacheStats};
use crate::solver::{Analysis, SolverOpts};
use crate::util::par::{num_threads, par_map};
use crate::workflow::engine::{analyze_fixpoint_cached, WorkflowError};
use crate::workflow::graph::NodeSet;
use crate::workflow::scenario::{GenomicsScenario, Perturbation, VideoScenario};
use crate::workflow::Workflow;

// The fan-out contract: everything a worker borrows must be Send + Sync.
// These compile-time assertions keep the solver stack clean — a field that
// loses Send/Sync (an Rc, a raw pointer, a RefCell) breaks the build here,
// not at a distant spawn site.
const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<crate::model::Process>();
    assert_send_sync::<crate::model::ProcessInputs>();
    assert_send_sync::<crate::pwfn::PwPoly>();
    assert_send_sync::<crate::pwfn::Envelope>();
    assert_send_sync::<SolverOpts>();
    assert_send_sync::<Analysis>();
    assert_send_sync::<crate::workflow::Workflow>();
    assert_send_sync::<VideoScenario>();
    assert_send_sync::<Perturbation>();
    assert_send_sync::<WorkflowError>();
};

/// A workload the sweep engine can fan a perturbation batch over. The
/// engine is generic over this trait: the built-in [`VideoScenario`] and
/// [`GenomicsScenario`] models, inline-spec workflows and trace-calibrated
/// models ([`FixedWorkflow`]) all sweep through the same code path.
///
/// Contract: `build_perturbed` is pure (same perturbation → bit-identical
/// workflow), and a knob the model does not expose comes back as `Err`
/// (the API boundary maps it to a structured `bad_request`) — never a
/// panic, which would kill a whole batch and, behind the service, the
/// server.
pub trait SweepModel: Send + Sync {
    /// Workload label surfaced in reports and API responses
    /// (`"video"`, `"genomics"`, `"spec"`, `"trace"`).
    fn label(&self) -> &str;

    /// The unperturbed workflow (what [`Perturbation::Identity`] analyzes;
    /// also the planner's reference for dirty-set shapes).
    fn base_workflow(&self) -> Workflow;

    /// The workflow under perturbation `p`.
    fn build_perturbed(&self, p: &Perturbation) -> Result<Workflow, String>;

    /// Planner hint: nodes of `wf` (the base workflow) whose analyses `p`
    /// may change. Ordering-only — supersets are always safe and results
    /// never depend on it. Default: everything dirty.
    fn dirty_set(&self, wf: &Workflow, p: &Perturbation) -> NodeSet {
        let _ = p;
        NodeSet::all(wf.nodes.len())
    }
}

impl SweepModel for VideoScenario {
    fn label(&self) -> &str {
        "video"
    }

    fn base_workflow(&self) -> Workflow {
        self.build().0
    }

    fn build_perturbed(&self, p: &Perturbation) -> Result<Workflow, String> {
        Ok(self.perturbed(p).build().0)
    }

    fn dirty_set(&self, wf: &Workflow, p: &Perturbation) -> NodeSet {
        // node ids are deterministic, so a rebuild's ids index `wf` too
        let (_, nodes) = self.build();
        p.dirty_set(wf, &nodes)
    }
}

impl SweepModel for GenomicsScenario {
    fn label(&self) -> &str {
        "genomics"
    }

    fn base_workflow(&self) -> Workflow {
        self.build()
    }

    fn build_perturbed(&self, p: &Perturbation) -> Result<Workflow, String> {
        Ok(self.perturbed(p)?.build())
    }

    fn dirty_set(&self, wf: &Workflow, p: &Perturbation) -> NodeSet {
        self.dirty_nodes(wf, p)
    }
}

/// A [`SweepModel`] over one fixed, prebuilt workflow — inline specs and
/// trace-calibrated models, which expose no scenario-specific knobs.
/// [`Perturbation::Identity`] and the two *generic* scale knobs apply:
/// `link_rate_scale` multiplies every shared pool's capacity and
/// `cpu_scale` multiplies every node's resource-requirement functions
/// (cost curves) — both well-defined on any workflow, which makes fixed
/// models first-class citizens of the sensitivity layer (`crate::sense`).
/// Everything else (fractions, per-task video knobs) is a typed
/// `Unsupported` error. A batch of identities turns the sweep engine into
/// a cached analyzer that still produces the ranked bottleneck report.
pub struct FixedWorkflow {
    label: String,
    wf: Workflow,
}

impl FixedWorkflow {
    pub fn new(label: impl Into<String>, wf: Workflow) -> FixedWorkflow {
        FixedWorkflow {
            label: label.into(),
            wf,
        }
    }
}

impl SweepModel for FixedWorkflow {
    fn label(&self) -> &str {
        &self.label
    }

    fn base_workflow(&self) -> Workflow {
        self.wf.clone()
    }

    fn build_perturbed(&self, p: &Perturbation) -> Result<Workflow, String> {
        match p {
            Perturbation::Identity => Ok(self.wf.clone()),
            Perturbation::LinkRateScale(s) => {
                let mut wf = self.wf.clone();
                for pool in &mut wf.pools {
                    pool.capacity = pool.capacity.scale(*s);
                }
                Ok(wf)
            }
            Perturbation::CpuScale(s) => {
                let mut wf = self.wf.clone();
                for node in &mut wf.nodes {
                    for r in &mut node.process.res_reqs {
                        r.func = r.func.scale(*s);
                    }
                }
                Ok(wf)
            }
            other => Err(format!(
                "workflow '{}' is a fixed model: only the 'identity', 'link_rate_scale' and \
                 'cpu_scale' perturbations apply (got '{}')",
                self.label,
                other.kind()
            )),
        }
    }

    fn dirty_set(&self, wf: &Workflow, p: &Perturbation) -> NodeSet {
        match p {
            Perturbation::Identity => NodeSet::empty(wf.nodes.len()),
            _ => NodeSet::all(wf.nodes.len()),
        }
    }
}

/// Failure of a sweep batch. Distinguishes a *rejected perturbation* (a
/// wire-level bad request: the model does not expose that knob) from a
/// *failed analysis* (the model accepted it but the solve blew up, e.g. a
/// dependency that never finishes).
#[derive(Debug, Clone)]
pub enum SweepError {
    Unsupported(String),
    Analysis(WorkflowError),
}

impl fmt::Display for SweepError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SweepError::Unsupported(m) => f.write_str(m),
            SweepError::Analysis(e) => e.fmt(f),
        }
    }
}

impl std::error::Error for SweepError {}

impl From<WorkflowError> for SweepError {
    fn from(e: WorkflowError) -> SweepError {
        SweepError::Analysis(e)
    }
}

/// Full result of one scenario in a sweep batch.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioOutcome {
    /// Index into the perturbation batch.
    pub index: usize,
    /// The perturbation this scenario applied to the base model.
    pub perturbation: Perturbation,
    /// Whole-workflow completion time (`None` if it never finishes).
    pub makespan: Option<f64>,
    /// Total solver events (the §6 cost accounting).
    pub events: usize,
    /// Fixpoint passes used.
    pub passes: usize,
    /// Node names, aligned with `analyses`.
    pub node_names: Vec<String>,
    /// Per-node exact analyses (progress functions, segments, metrics),
    /// `Arc`-shared with the engine/cache so cached upstream results are
    /// reused without cloning a `PwPoly`.
    pub analyses: Vec<Arc<Analysis>>,
    /// Bottleneck attribution rows: `(process, bottleneck label, seconds)`,
    /// one per maximal constant-bottleneck segment.
    pub attributed: Vec<(String, String, f64)>,
}

impl ScenarioOutcome {
    /// Report sampling: every node's progress function materialized on a
    /// shared time grid through the structure-of-arrays batch backend
    /// ([`BatchPwPoly`]) — one compile over all curves, one galloping
    /// merge per curve instead of `nodes × points` independent binary
    /// searches. Row `i` is node `i` (aligned with
    /// [`ScenarioOutcome::node_names`]); each value is bit-for-bit
    /// `analyses[i].progress.eval(ts[j])`.
    pub fn sample_progress(&self, ts: &[f64]) -> Vec<Vec<f64>> {
        let curves: Vec<&PwPoly> = self.analyses.iter().map(|a| &a.progress).collect();
        if ts.is_empty() {
            return vec![Vec::new(); curves.len()];
        }
        let flat = BatchPwPoly::compile(&curves).eval_scenarios(ts);
        flat.chunks(ts.len()).map(|row| row.to_vec()).collect()
    }
}

/// One aggregated bottleneck across the batch.
#[derive(Clone, Debug, PartialEq)]
pub struct RankedBottleneck {
    pub process: String,
    pub bottleneck: String,
    /// Total seconds this (process, bottleneck) pair limited progress,
    /// summed over all scenarios.
    pub total_seconds: f64,
    /// Number of scenarios in which it appears at all.
    pub scenarios: usize,
}

/// The ranked cross-scenario bottleneck report.
#[derive(Clone, Debug, PartialEq)]
pub struct BottleneckReport {
    /// Descending by `total_seconds`.
    pub ranked: Vec<RankedBottleneck>,
    pub scenarios: usize,
    pub total_events: usize,
    /// Analysis-cache statistics for the batch that produced this report
    /// (`None` when the sweep ran cold / uncached). Excluded from any
    /// determinism comparison — cold and warm runs agree on everything
    /// *except* this bookkeeping.
    pub cache: Option<CacheStats>,
}

impl BottleneckReport {
    /// Aggregate per-scenario attributions into the ranked report.
    pub fn aggregate(outcomes: &[ScenarioOutcome]) -> BottleneckReport {
        let mut acc: HashMap<(String, String), (f64, usize)> = HashMap::new();
        for o in outcomes {
            let mut seen: Vec<&(String, String, f64)> = vec![];
            for row in &o.attributed {
                let e = acc.entry((row.0.clone(), row.1.clone())).or_insert((0.0, 0));
                e.0 += row.2;
                if !seen
                    .iter()
                    .any(|r| r.0 == row.0 && r.1 == row.1)
                {
                    e.1 += 1;
                    seen.push(row);
                }
            }
        }
        let mut ranked: Vec<RankedBottleneck> = acc
            .into_iter()
            .map(|((process, bottleneck), (total_seconds, scenarios))| RankedBottleneck {
                process,
                bottleneck,
                total_seconds,
                scenarios,
            })
            .collect();
        ranked.sort_by(|a, b| {
            b.total_seconds
                .partial_cmp(&a.total_seconds)
                .unwrap()
                .then_with(|| a.process.cmp(&b.process))
                .then_with(|| a.bottleneck.cmp(&b.bottleneck))
        });
        BottleneckReport {
            ranked,
            scenarios: outcomes.len(),
            total_events: outcomes.iter().map(|o| o.events).sum(),
            cache: None,
        }
    }
}

/// A batch of scenario analyses over one shared base model.
#[derive(Clone)]
pub struct SweepBatch {
    base: Arc<dyn SweepModel>,
    opts: SolverOpts,
    threads: usize,
    fixpoint_passes: usize,
    cache: Option<Arc<AnalysisCache>>,
}

impl SweepBatch {
    /// New batch over the shared Fig 5 video scenario — the historical
    /// constructor, kept for the advisor/CLI/bench call sites. The generic
    /// entry point is [`SweepBatch::over`].
    pub fn new(base: Arc<VideoScenario>) -> SweepBatch {
        SweepBatch::over(base)
    }

    /// New batch over any [`SweepModel`]; worker count defaults to the
    /// machine's parallelism (`BOTTLEMOD_THREADS` overrides). Cold (no
    /// cache) by default — attach one with [`SweepBatch::with_cache`] /
    /// [`SweepBatch::with_new_cache`].
    pub fn over(base: Arc<dyn SweepModel>) -> SweepBatch {
        SweepBatch {
            base,
            opts: SolverOpts::default(),
            threads: num_threads(),
            fixpoint_passes: 6,
            cache: None,
        }
    }

    /// The base model's workload label (`"video"`, `"genomics"`, ...).
    pub fn label(&self) -> &str {
        self.base.label()
    }

    /// Force a worker count (1 = the sequential reference path).
    pub fn with_threads(mut self, threads: usize) -> SweepBatch {
        self.threads = threads.max(1);
        self
    }

    pub fn with_opts(mut self, opts: SolverOpts) -> SweepBatch {
        self.opts = opts;
        self
    }

    pub fn with_fixpoint_passes(mut self, passes: usize) -> SweepBatch {
        self.fixpoint_passes = passes.max(1);
        self
    }

    /// Attach a (possibly shared, possibly pre-warmed) analysis cache. The
    /// batch becomes incremental: only each perturbation's dirty cone is
    /// re-solved. Results stay bit-for-bit equal to an uncached run.
    pub fn with_cache(mut self, cache: Arc<AnalysisCache>) -> SweepBatch {
        self.cache = Some(cache);
        self
    }

    /// Attach a fresh default-capacity cache.
    pub fn with_new_cache(self) -> SweepBatch {
        let cache = Arc::new(AnalysisCache::new());
        self.with_cache(cache)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The attached cache, if any.
    pub fn cache(&self) -> Option<&Arc<AnalysisCache>> {
        self.cache.as_ref()
    }

    /// Statistics of the attached cache (`None` when running cold).
    pub fn cache_stats(&self) -> Option<CacheStats> {
        self.cache.as_ref().map(|c| c.stats())
    }

    /// Processing order for a batch: scenarios grouped by dirty-set shape
    /// ([`Perturbation::dirty_set`] fingerprints, largest clean prefix
    /// first), stable within a group. Grouping maximizes shared-prefix
    /// cache reuse and temporal locality (clean-node entries are touched
    /// back-to-back instead of `N` scenarios apart). Pure reordering: the
    /// per-scenario computation — and therefore every outcome — is
    /// unchanged.
    pub fn plan(&self, perturbations: &[Perturbation]) -> Vec<usize> {
        let wf = self.base.base_workflow();
        // a perturbation's dirty set depends on its *variant*, not its
        // payload, so one dirty_set call per distinct variant suffices
        // (each call rebuilds graph adjacency — don't pay it per element)
        let mut memo: Vec<(std::mem::Discriminant<Perturbation>, (u32, u64))> = Vec::new();
        let mut keyed: Vec<(usize, u32, u64)> = perturbations
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let disc = std::mem::discriminant(p);
                let found = memo.iter().find(|(d, _)| *d == disc).map(|(_, v)| *v);
                let (len, fp) = found.unwrap_or_else(|| {
                    let dirty = self.base.dirty_set(&wf, p);
                    let v = (dirty.len() as u32, dirty.fingerprint());
                    memo.push((disc, v));
                    v
                });
                (i, len, fp)
            })
            .collect();
        // smallest dirty sets first: their clean prefixes populate the
        // cache entries the dirtier groups will reuse
        keyed.sort_by(|a, b| a.1.cmp(&b.1).then(a.2.cmp(&b.2)).then(a.0.cmp(&b.0)));
        keyed.into_iter().map(|(i, _, _)| i).collect()
    }

    /// Analyze every perturbation of the base scenario. Results are in
    /// batch order and independent of the worker count and of whether a
    /// cache is attached (bit-for-bit).
    pub fn run(
        &self,
        perturbations: &[Perturbation],
    ) -> Result<Vec<ScenarioOutcome>, SweepError> {
        let base = self.base.as_ref();
        let opts = &self.opts;
        let passes = self.fixpoint_passes;
        let cache = self.cache.as_deref();
        let mut outcomes: Vec<ScenarioOutcome> = match cache {
            None => par_map(perturbations, self.threads, |index, p| {
                solve_one(base, opts, passes, index, p, None)
            })
            .into_iter()
            .collect::<Result<_, _>>()?,
            Some(c) => {
                // planner order for cache locality; original indices ride
                // along so outcomes can be restored to batch order below
                let planned: Vec<(usize, Perturbation)> = self
                    .plan(perturbations)
                    .into_iter()
                    .map(|i| (i, perturbations[i]))
                    .collect();
                par_map(&planned, self.threads, |_, (index, p)| {
                    solve_one(base, opts, passes, *index, p, Some(c))
                })
                .into_iter()
                .collect::<Result<_, _>>()?
            }
        };
        outcomes.sort_by_key(|o| o.index);
        Ok(outcomes)
    }

    /// [`Self::run`] plus the aggregated ranked bottleneck report. With a
    /// cache attached, the report carries *this batch's* cache behaviour
    /// (counters diffed across the run, so a shared or pre-warmed cache
    /// reports per-batch rates, not lifetime totals). Caveat: the counters
    /// are cache-global, so if *other* batches run concurrently against
    /// the same shared cache, their lookups land in this window too — the
    /// per-batch stats are exact for sequential use and approximate under
    /// concurrency. (Outcomes are unaffected either way.)
    pub fn run_report(
        &self,
        perturbations: &[Perturbation],
    ) -> Result<(Vec<ScenarioOutcome>, BottleneckReport), SweepError> {
        let before = self.cache_stats();
        let outcomes = self.run(perturbations)?;
        let mut report = BottleneckReport::aggregate(&outcomes);
        report.cache = match (before, self.cache_stats()) {
            (Some(b), Some(a)) => Some(a.since(&b)),
            _ => None,
        };
        Ok((outcomes, report))
    }
}

/// Analyze one perturbed scenario (pure: same inputs → same outputs; the
/// cache only changes *where* an identical analysis comes from).
fn solve_one(
    base: &dyn SweepModel,
    opts: &SolverOpts,
    passes: usize,
    index: usize,
    p: &Perturbation,
    cache: Option<&AnalysisCache>,
) -> Result<ScenarioOutcome, SweepError> {
    let wf = base.build_perturbed(p).map_err(SweepError::Unsupported)?;
    let wa = analyze_fixpoint_cached(&wf, opts, passes, cache)?;

    let node_names: Vec<String> = wf.nodes.iter().map(|n| n.process.name.clone()).collect();
    let mut attributed = vec![];
    for (i, a) in wa.analyses.iter().enumerate() {
        let proc = &wf.nodes[i].process;
        for s in &a.segments {
            let end = s.end.min(a.finish_time.unwrap_or(opts.horizon));
            let dur = end - s.start;
            if dur > 1e-9 {
                attributed.push((
                    proc.name.clone(),
                    a.bottleneck_name(proc, s.bottleneck),
                    dur,
                ));
            }
        }
    }

    Ok(ScenarioOutcome {
        index,
        perturbation: *p,
        makespan: wa.makespan,
        events: wa.events,
        passes: wa.passes,
        node_names,
        analyses: wa.analyses,
        attributed,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workflow::scenario::Perturbation as P;

    fn fractions(n: usize) -> Vec<Perturbation> {
        (1..=n)
            .map(|i| P::Fraction(i as f64 / (n as f64 + 1.0)))
            .collect()
    }

    /// The determinism contract: a parallel run is bit-for-bit identical
    /// to the sequential reference.
    #[test]
    fn parallel_is_bit_identical_to_sequential() {
        let base = Arc::new(VideoScenario::default());
        let batch = fractions(16);
        let seq = SweepBatch::new(base.clone())
            .with_threads(1)
            .run(&batch)
            .unwrap();
        let par = SweepBatch::new(base)
            .with_threads(4)
            .run(&batch)
            .unwrap();
        assert_eq!(seq.len(), 16);
        assert_eq!(seq, par); // full PartialEq, including every Analysis
    }

    /// Mixed perturbation kinds in one batch, each behaving as documented.
    #[test]
    fn mixed_perturbations_solve() {
        let base = Arc::new(VideoScenario::default());
        let batch = vec![
            P::Fraction(0.5),
            P::Fraction(0.93),
            P::InputScale(10.0),
            P::LinkRateScale(2.0),
            P::CpuScale(2.0),
            P::Task2Burst,
        ];
        let out = SweepBatch::new(base).with_threads(3).run(&batch).unwrap();
        let mk = |i: usize| out[i].makespan.unwrap();
        // Fig 7 headline: ≥93% beats 50:50 by ~32%
        assert!(mk(1) < 0.75 * mk(0), "{} vs {}", mk(1), mk(0));
        // 10x the data at the same rates ≈ 10x the makespan, same events
        assert!((mk(2) - 10.0 * mk(0)).abs() < 0.03 * mk(2));
        assert!(out[2].events <= out[0].events + 4);
        // doubling the link shrinks the download-dominated total
        // (downloads 178 s -> 89 s; encode + mux tails stay): ~174 vs ~263
        assert!(mk(3) < 0.70 * mk(0), "{} vs {}", mk(3), mk(0));
        // doubling CPU cost pushes the encode tail out
        assert!(mk(4) > mk(0) + 40.0);
        // outcomes carry the full per-node analyses
        assert_eq!(out[0].analyses.len(), 5);
        assert_eq!(out[0].node_names[0], "dl-task1");
    }

    /// Report sampling goes through the SoA batch backend and stays
    /// bit-for-bit the scalar per-point evaluation.
    #[test]
    fn sample_progress_matches_scalar_eval() {
        let base = Arc::new(VideoScenario::default());
        let out = SweepBatch::new(base)
            .with_threads(1)
            .run(&[P::Fraction(0.5)])
            .unwrap();
        let total = out[0].makespan.unwrap();
        let ts: Vec<f64> = (0..64).map(|i| total * i as f64 / 63.0).collect();
        let rows = out[0].sample_progress(&ts);
        assert_eq!(rows.len(), out[0].analyses.len());
        for (a, row) in out[0].analyses.iter().zip(&rows) {
            for (&t, &v) in ts.iter().zip(row) {
                assert_eq!(v.to_bits(), a.progress.eval(t).to_bits());
            }
        }
        assert!(out[0].sample_progress(&[]).iter().all(|r| r.is_empty()));
    }

    /// The ranked report surfaces the link as the dominant bottleneck of
    /// the 50:50 video scenario.
    #[test]
    fn report_ranks_link_bottleneck_first() {
        let base = Arc::new(VideoScenario::default());
        let (outcomes, report) = SweepBatch::new(base)
            .with_threads(2)
            .run_report(&[P::Fraction(0.5)])
            .unwrap();
        assert_eq!(report.scenarios, 1);
        assert_eq!(report.total_events, outcomes[0].events);
        assert!(!report.ranked.is_empty());
        // the two downloads are link-limited for the full 178 s each; no
        // other single (process, bottleneck) pair is attributed longer
        let top3: Vec<&RankedBottleneck> = report.ranked.iter().take(3).collect();
        assert!(
            top3.iter()
                .any(|r| r.process.starts_with("dl-") && r.bottleneck == "res:link"),
            "top3 = {top3:?}"
        );
        // ranking is descending
        for w in report.ranked.windows(2) {
            assert!(w[0].total_seconds >= w[1].total_seconds);
        }
    }

    /// The incremental path: a cached (warm) run is bit-for-bit the cold
    /// run, the report carries the stats, and single-node perturbation
    /// batches hit the cache on their clean prefixes.
    #[test]
    fn cached_sweep_is_bit_identical_and_hits() {
        let base = Arc::new(VideoScenario::default());
        let batch: Vec<Perturbation> = (0..12)
            .map(|i| P::Task3TimeScale(0.5 + i as f64 / 8.0))
            .collect();
        let (cold, cold_report) = SweepBatch::new(base.clone())
            .with_threads(1)
            .run_report(&batch)
            .unwrap();
        let warm_batch = SweepBatch::new(base.clone()).with_threads(2).with_new_cache();
        let (warm, warm_report) = warm_batch.run_report(&batch).unwrap();
        assert_eq!(cold, warm, "cache must not change any outcome bit");
        assert_eq!(cold_report.ranked, warm_report.ranked);
        assert_eq!(cold_report.total_events, warm_report.total_events);
        assert_eq!(cold_report.cache, None);
        let stats = warm_report.cache.expect("warm report carries stats");
        assert!(
            stats.hit_rate() >= 0.5,
            "single-node batch should be mostly hits: {stats}"
        );
    }

    /// The planner groups scenarios by dirty-set shape and stays a
    /// permutation of the batch.
    #[test]
    fn plan_groups_by_dirty_shape() {
        let base = Arc::new(VideoScenario::default());
        let batch = vec![
            P::Fraction(0.3),        // whole graph dirty
            P::Task3TimeScale(1.5),  // {task3}
            P::Fraction(0.7),        // whole graph dirty
            P::Task3TimeScale(2.5),  // {task3}
            P::Task1CpuScale(2.0),   // {task1, task3}
        ];
        let sweep = SweepBatch::new(base).with_new_cache();
        let order = sweep.plan(&batch);
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3, 4], "plan must be a permutation");
        // smallest dirty sets first, same-shape scenarios adjacent and in
        // batch order within the group
        assert_eq!(order, vec![1, 3, 4, 0, 2]);
        // and running through the plan still returns batch order
        let out = sweep.run(&batch).unwrap();
        let idx: Vec<usize> = out.iter().map(|o| o.index).collect();
        assert_eq!(idx, vec![0, 1, 2, 3, 4]);
    }

    /// The generic engine: a genomics batch with non-fraction knobs runs
    /// through the same incremental path as the video sweeps.
    #[test]
    fn generic_model_sweep_genomics() {
        let base: Arc<dyn SweepModel> = Arc::new(GenomicsScenario::default());
        let batch = vec![P::LinkRateScale(2.0), P::Identity, P::Fraction(0.7)];
        let engine = SweepBatch::over(base).with_threads(2).with_new_cache();
        assert_eq!(engine.label(), "genomics");
        let (out, report) = engine.run_report(&batch).unwrap();
        assert_eq!(out.len(), 3);
        assert!(out.iter().all(|o| o.makespan.is_some()));
        assert!(!report.ranked.is_empty());
        assert!(report.cache.is_some());
        // a faster ingest link cannot slow the pipeline
        assert!(out[0].makespan.unwrap() <= out[1].makespan.unwrap() + 1e-9);
    }

    /// A knob the model does not expose is a typed `Unsupported` error —
    /// a wire-level bad request, not a panic and not an analysis failure.
    #[test]
    fn unsupported_knob_is_a_typed_error() {
        let base: Arc<dyn SweepModel> = Arc::new(GenomicsScenario::default());
        let err = SweepBatch::over(base)
            .with_threads(1)
            .run(&[P::Task2Burst])
            .unwrap_err();
        match err {
            SweepError::Unsupported(m) => assert!(m.contains("task2_burst"), "{m}"),
            other => panic!("expected Unsupported, got {other:?}"),
        }
    }

    /// Fixed (spec / trace-calibrated) workflows sweep under identity only,
    /// and a batch of identities is answered almost entirely by the cache.
    #[test]
    fn fixed_workflow_identity_only() {
        let (wf, _) = VideoScenario::default().build();
        let base: Arc<dyn SweepModel> = Arc::new(FixedWorkflow::new("spec", wf));
        let engine = SweepBatch::over(base).with_threads(1).with_new_cache();
        let (out, report) = engine.run_report(&[P::Identity, P::Identity]).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].makespan, out[1].makespan);
        let stats = report.cache.unwrap();
        assert!(stats.hits > 0, "second identity must hit: {stats}");
        let err = engine.run(&[P::Fraction(0.5)]).unwrap_err();
        assert!(matches!(err, SweepError::Unsupported(_)), "{err:?}");
    }

    /// Fixed workflows expose the generic scale knobs: pool capacity up
    /// ⇒ faster, resource cost up ⇒ slower, and the identity point of
    /// each knob is bit-identical to the identity perturbation.
    #[test]
    fn fixed_workflow_generic_scale_knobs() {
        let (wf, _) = VideoScenario::default().build();
        let base: Arc<dyn SweepModel> = Arc::new(FixedWorkflow::new("spec", wf));
        let engine = SweepBatch::over(base).with_threads(1).with_new_cache();
        let out = engine
            .run(&[
                P::Identity,
                P::LinkRateScale(2.0),
                P::CpuScale(2.0),
                P::LinkRateScale(1.0),
                P::CpuScale(1.0),
            ])
            .unwrap();
        let mk = |i: usize| out[i].makespan.unwrap();
        assert!(mk(1) < 0.75 * mk(0), "faster link: {} vs {}", mk(1), mk(0));
        assert!(mk(2) > mk(0) + 40.0, "doubled cost: {} vs {}", mk(2), mk(0));
        assert_eq!(mk(3).to_bits(), mk(0).to_bits());
        assert_eq!(mk(4).to_bits(), mk(0).to_bits());
    }

    /// Attribution durations of one scenario sum to (roughly) the busy
    /// time of all nodes — segments cover [start, finish] per node.
    #[test]
    fn attribution_covers_node_lifetimes() {
        let base = Arc::new(VideoScenario::default());
        let out = SweepBatch::new(base)
            .with_threads(1)
            .run(&[P::Fraction(0.5)])
            .unwrap();
        let o = &out[0];
        let attributed: f64 = o.attributed.iter().map(|r| r.2).sum();
        let busy: f64 = o
            .analyses
            .iter()
            .map(|a| a.finish_time.unwrap() - a.start_time)
            .sum();
        assert!(
            (attributed - busy).abs() < 0.02 * busy + 1.0,
            "attributed {attributed} vs busy {busy}"
        );
    }
}
