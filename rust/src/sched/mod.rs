//! Scheduling applications of the analysis: the allocation advisor and the
//! online re-analysis controller.

pub mod advisor;
pub mod online;

pub use advisor::{
    candidate_fractions, recommend, recommend_from_report, recommend_model, recommend_ranked,
    KnobRecommendation, Recommendation,
};
pub use online::{
    frontier_bottleneck, live_bottleneck, predict_remaining, run_online, BottleneckShift,
    Decision, LiveState, LiveTracker, OnlineResult,
};
