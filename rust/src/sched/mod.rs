//! Scheduling applications of the analysis: the allocation advisor and the
//! online re-analysis controller.

pub mod advisor;
pub mod online;

pub use advisor::{candidate_fractions, recommend, Recommendation};
pub use online::{predict_remaining, run_online, Decision, LiveState, OnlineResult};
