//! Allocation advisor: pick the resource split that minimizes the predicted
//! makespan (the paper's "comparison of different scheduling options").

use crate::workflow::scenario::VideoScenario;

use crate::coordinator::sweeper::{best_fraction, exact_sweep, fig7_fractions};

/// A recommendation with its predicted effect.
#[derive(Clone, Debug)]
pub struct Recommendation {
    pub best_fraction: f64,
    pub best_total: f64,
    /// Predicted total under the fair 50:50 default.
    pub fair_total: f64,
    /// Relative improvement over fair sharing.
    pub gain: f64,
}

/// Sweep `points` candidate fractions and recommend the best one.
pub fn recommend(sc: &VideoScenario, points: usize, threads: usize) -> Recommendation {
    let mut fractions = fig7_fractions(points);
    if !fractions.iter().any(|f| (f - 0.5).abs() < 1e-12) {
        fractions.push(0.5);
    }
    let sweep = exact_sweep(sc, &fractions, threads);
    let (best_f, best_t) = best_fraction(&sweep);
    let fair_total = sweep
        .fractions
        .iter()
        .zip(&sweep.totals)
        .find(|(f, _)| (**f - 0.5).abs() < 1e-12)
        .map(|(_, t)| *t)
        .unwrap();
    Recommendation {
        best_fraction: best_f,
        best_total: best_t,
        fair_total,
        gain: 1.0 - best_t / fair_total,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recommends_the_paper_headline() {
        let rec = recommend(&VideoScenario::default(), 50, 4);
        assert!(rec.best_fraction >= 0.85, "{rec:?}");
        assert!((0.25..0.40).contains(&rec.gain), "{rec:?}");
        assert!(rec.best_total < rec.fair_total);
    }
}
