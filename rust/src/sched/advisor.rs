//! Allocation advisor: pick the resource split that minimizes the predicted
//! makespan (the paper's "comparison of different scheduling options").
//!
//! Two entry points: [`recommend`] is the historical video-scenario path
//! (exact sweep over the Fig 7 fraction grid), and [`recommend_model`] is
//! its generalization over any [`SweepModel`] — the live monitor calls it
//! whenever the observed bottleneck shifts, turning the shift into a
//! candidate-split → predicted-gain advisory for whatever workload is
//! being monitored.

use std::sync::Arc;

use crate::runtime::cache::AnalysisCache;
use crate::runtime::sweep::{SweepBatch, SweepError, SweepModel};
use crate::workflow::scenario::{Perturbation, VideoScenario};

use crate::coordinator::sweeper::{best_fraction, exact_sweep, fig7_fractions};

/// A recommendation with its predicted effect.
#[derive(Clone, Debug)]
pub struct Recommendation {
    pub best_fraction: f64,
    pub best_total: f64,
    /// Predicted total under the baseline split — 50:50 for [`recommend`],
    /// the model's current (identity) allocation for [`recommend_model`].
    pub fair_total: f64,
    /// Relative improvement over the baseline.
    pub gain: f64,
}

/// Candidate fractions for [`recommend`]: the Fig 7 grid plus the fair
/// 50:50 baseline, sorted and deduplicated. The dedup matters: for grid
/// sizes where `0.5` (or a float within rounding of it) is already a grid
/// point, a naive push would sweep a duplicate and make the `fair_total`
/// lookup ambiguous.
///
/// Memoized per grid size: the grid is pure in `points`, yet it used to
/// be regenerated (re-sorted, re-deduped) on every advisory sweep and on
/// every live-monitor bottleneck shift. Repeated calls now return the
/// identical shared slice ([`Arc::ptr_eq`]-same allocation). Grid sizes
/// above `MEMO_MAX_POINTS` — only reachable through adversarial service
/// inputs — are computed fresh so the memo's memory stays bounded.
pub fn candidate_fractions(points: usize) -> Arc<[f64]> {
    use std::collections::HashMap;
    use std::sync::{Mutex, OnceLock};
    const MEMO_MAX_POINTS: usize = 1 << 14;
    static MEMO: OnceLock<Mutex<HashMap<usize, Arc<[f64]>>>> = OnceLock::new();
    if points > MEMO_MAX_POINTS {
        return compute_candidate_fractions(points).into();
    }
    let memo = MEMO.get_or_init(|| Mutex::new(HashMap::new()));
    let mut guard = memo.lock().unwrap_or_else(|e| e.into_inner());
    if let Some(hit) = guard.get(&points) {
        return Arc::clone(hit);
    }
    let fresh: Arc<[f64]> = compute_candidate_fractions(points).into();
    guard.insert(points, Arc::clone(&fresh));
    fresh
}

fn compute_candidate_fractions(points: usize) -> Vec<f64> {
    let mut fractions = fig7_fractions(points);
    fractions.push(0.5);
    fractions.sort_by(|a, b| a.partial_cmp(b).unwrap());
    fractions.dedup_by(|a, b| (*a - *b).abs() < 1e-9);
    fractions
}

/// Sweep `points` candidate fractions and recommend the best one.
pub fn recommend(sc: &VideoScenario, points: usize, threads: usize) -> Recommendation {
    let fractions = candidate_fractions(points);
    let sweep = exact_sweep(sc, &fractions, threads);
    let (best_f, best_t) = best_fraction(&sweep);
    // the list always contains exactly one fraction within 1e-9 of 0.5;
    // pick the closest rather than an exact bit-match
    let fair_total = sweep
        .fractions
        .iter()
        .zip(&sweep.totals)
        .min_by(|(a, _), (b, _)| {
            (**a - 0.5)
                .abs()
                .partial_cmp(&(**b - 0.5).abs())
                .unwrap()
        })
        .map(|(_, t)| *t)
        .unwrap();
    Recommendation {
        best_fraction: best_f,
        best_total: best_t,
        fair_total,
        gain: 1.0 - best_t / fair_total,
    }
}

/// [`recommend`] generalized over any [`SweepModel`]: sweep the
/// [`Perturbation::Fraction`] candidates of [`candidate_fractions`] against
/// the model's identity baseline and recommend the best split.
///
/// Returns `Ok(None)` when the model has no actionable split — it rejects
/// the fraction knob (fixed spec/trace workflows), or neither the baseline
/// nor any candidate finishes. A failed analysis is a real `Err`. With a
/// cache attached, repeated calls (the monitor re-advising on every
/// bottleneck shift) re-solve only what changed.
pub fn recommend_model(
    model: &Arc<dyn SweepModel>,
    points: usize,
    threads: usize,
    cache: Option<Arc<AnalysisCache>>,
) -> Result<Option<Recommendation>, SweepError> {
    let fractions = candidate_fractions(points);
    let mut perts: Vec<Perturbation> = Vec::with_capacity(fractions.len() + 1);
    perts.push(Perturbation::Identity);
    perts.extend(fractions.iter().map(|&f| Perturbation::Fraction(f)));
    let mut batch = SweepBatch::over(Arc::clone(model)).with_threads(threads);
    if let Some(c) = cache {
        batch = batch.with_cache(c);
    }
    let outcomes = match batch.run(&perts) {
        Ok(o) => o,
        Err(SweepError::Unsupported(_)) => return Ok(None),
        Err(e) => return Err(e),
    };
    let baseline = outcomes[0].makespan.unwrap_or(f64::INFINITY);
    let best = outcomes[1..]
        .iter()
        .zip(fractions.iter())
        .map(|(o, &f)| (f, o.makespan.unwrap_or(f64::INFINITY)))
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(a.0.partial_cmp(&b.0).unwrap()));
    let (best_f, best_t) = match best {
        Some(b) => b,
        None => return Ok(None),
    };
    if !best_t.is_finite() || !baseline.is_finite() {
        return Ok(None);
    }
    Ok(Some(Recommendation {
        best_fraction: best_f,
        best_total: best_t,
        fair_total: baseline,
        gain: 1.0 - best_t / baseline,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::sweep::FixedWorkflow;
    use crate::workflow::scenario::GenomicsScenario;

    #[test]
    fn candidates_sorted_unique_and_contain_fair_share() {
        // n = 49: fig7_fractions contains 25/50 = 0.5 exactly — the push
        // used to duplicate it; n = 50 has no exact 0.5
        for n in [1, 49, 50, 200] {
            let c = candidate_fractions(n);
            assert!(c.windows(2).all(|w| w[0] < w[1]), "not sorted/unique: n={n}");
            assert_eq!(
                c.iter().filter(|f| (**f - 0.5).abs() < 1e-9).count(),
                1,
                "n={n}: {c:?}"
            );
            assert!(c.len() >= n, "n={n}");
        }
        // the exact-grid case keeps exactly n entries (no duplicate sweep)
        assert_eq!(candidate_fractions(49).len(), 49);
        assert_eq!(candidate_fractions(50).len(), 51);
    }

    /// The per-grid-size memo hands back the identical allocation on
    /// repeat calls — the advisor and the live monitor stop re-sorting
    /// the same grid on every sweep/shift.
    #[test]
    fn candidate_fractions_memoized_identical_slice() {
        let a = candidate_fractions(33);
        let b = candidate_fractions(33);
        assert!(Arc::ptr_eq(&a, &b), "repeat call must share the memoized slice");
        let c = candidate_fractions(34);
        assert!(!Arc::ptr_eq(&a, &c), "distinct sizes are distinct entries");
        assert_eq!(a.as_ref(), candidate_fractions(33).as_ref());
    }

    #[test]
    fn recommends_the_paper_headline() {
        let rec = recommend(&VideoScenario::default(), 50, 4);
        assert!(rec.best_fraction >= 0.85, "{rec:?}");
        assert!((0.25..0.40).contains(&rec.gain), "{rec:?}");
        assert!(rec.best_total < rec.fair_total);
    }

    /// The generic path reproduces the video headline: the default
    /// scenario's identity baseline *is* the 50:50 split, so the gain
    /// matches [`recommend`]'s.
    #[test]
    fn recommend_model_matches_video_headline() {
        let model: Arc<dyn SweepModel> = Arc::new(VideoScenario::default());
        let rec = recommend_model(&model, 50, 2, None).unwrap().unwrap();
        assert!(rec.best_fraction >= 0.85, "{rec:?}");
        assert!((0.25..0.40).contains(&rec.gain), "{rec:?}");
    }

    /// Models without a fraction knob yield no recommendation — not an
    /// error (the monitor then emits a shift-only advisory).
    #[test]
    fn recommend_model_none_for_fixed_workflows() {
        let (wf, _) = VideoScenario::default().build();
        let model: Arc<dyn SweepModel> = Arc::new(FixedWorkflow::new("trace", wf));
        assert!(recommend_model(&model, 10, 1, None).unwrap().is_none());
    }

    /// Any model exposing the fraction knob works — genomics included —
    /// and an attached cache does not change the recommendation.
    #[test]
    fn recommend_model_generalizes_and_caches() {
        let model: Arc<dyn SweepModel> = Arc::new(GenomicsScenario::default());
        let cold = recommend_model(&model, 20, 1, None).unwrap().unwrap();
        let cache = Arc::new(AnalysisCache::new());
        let warm1 = recommend_model(&model, 20, 1, Some(Arc::clone(&cache)))
            .unwrap()
            .unwrap();
        let warm2 = recommend_model(&model, 20, 1, Some(Arc::clone(&cache)))
            .unwrap()
            .unwrap();
        assert_eq!(cold.best_fraction, warm1.best_fraction);
        assert_eq!(cold.best_total, warm1.best_total);
        assert_eq!(warm1.best_total, warm2.best_total);
        assert!(cache.stats().hits > 0, "repeat advisory must hit the cache");
    }
}
