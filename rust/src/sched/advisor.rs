//! Allocation advisor: pick the resource split that minimizes the predicted
//! makespan (the paper's "comparison of different scheduling options").
//!
//! Three entry points, oldest to newest: [`recommend`] is the historical
//! video-scenario path (exact sweep over the Fig 7 fraction grid);
//! [`recommend_model`] generalizes it over any [`SweepModel`] but still
//! hard-codes *which* knob to search (the link fraction) — the live
//! monitor calls it whenever the observed bottleneck shifts; and
//! [`recommend_from_report`] consumes a ranked sensitivity report
//! (`crate::sense`) to pick the highest-gain actionable knob *first* and
//! only then line-search its candidate grid — fraction-less models (fixed
//! specs, calibrated traces) get real advice through their generic scale
//! knobs instead of `None`.

use std::sync::Arc;

use crate::runtime::cache::AnalysisCache;
use crate::runtime::sweep::{SweepBatch, SweepError, SweepModel};
use crate::sense::{Report, SenseOpts};
use crate::workflow::scenario::{Perturbation, VideoScenario};

use crate::coordinator::sweeper::{best_fraction, exact_sweep, fig7_fractions};

/// A recommendation with its predicted effect.
#[derive(Clone, Debug)]
pub struct Recommendation {
    pub best_fraction: f64,
    pub best_total: f64,
    /// Predicted total under the baseline split — 50:50 for [`recommend`],
    /// the model's current (identity) allocation for [`recommend_model`].
    pub fair_total: f64,
    /// Relative improvement over the baseline.
    pub gain: f64,
}

/// Candidate fractions for [`recommend`]: the Fig 7 grid plus the fair
/// 50:50 baseline, sorted and deduplicated. The dedup matters: for grid
/// sizes where `0.5` (or a float within rounding of it) is already a grid
/// point, a naive push would sweep a duplicate and make the `fair_total`
/// lookup ambiguous.
///
/// Memoized per grid size: the grid is pure in `points`, yet it used to
/// be regenerated (re-sorted, re-deduped) on every advisory sweep and on
/// every live-monitor bottleneck shift. Repeated calls now return the
/// identical shared slice ([`Arc::ptr_eq`]-same allocation). Grid sizes
/// above `MEMO_MAX_POINTS` — only reachable through adversarial service
/// inputs — are computed fresh so the memo's memory stays bounded.
pub fn candidate_fractions(points: usize) -> Arc<[f64]> {
    use std::collections::HashMap;
    use std::sync::{Mutex, OnceLock};
    const MEMO_MAX_POINTS: usize = 1 << 14;
    static MEMO: OnceLock<Mutex<HashMap<usize, Arc<[f64]>>>> = OnceLock::new();
    if points > MEMO_MAX_POINTS {
        return compute_candidate_fractions(points).into();
    }
    let memo = MEMO.get_or_init(|| Mutex::new(HashMap::new()));
    let mut guard = memo.lock().unwrap_or_else(|e| e.into_inner());
    if let Some(hit) = guard.get(&points) {
        return Arc::clone(hit);
    }
    let fresh: Arc<[f64]> = compute_candidate_fractions(points).into();
    guard.insert(points, Arc::clone(&fresh));
    fresh
}

fn compute_candidate_fractions(points: usize) -> Vec<f64> {
    let mut fractions = fig7_fractions(points);
    fractions.push(0.5);
    fractions.sort_by(|a, b| a.partial_cmp(b).unwrap());
    fractions.dedup_by(|a, b| (*a - *b).abs() < 1e-9);
    fractions
}

/// Sweep `points` candidate fractions and recommend the best one.
pub fn recommend(sc: &VideoScenario, points: usize, threads: usize) -> Recommendation {
    let fractions = candidate_fractions(points);
    let sweep = exact_sweep(sc, &fractions, threads);
    let (best_f, best_t) = best_fraction(&sweep);
    // the list always contains exactly one fraction within 1e-9 of 0.5;
    // pick the closest rather than an exact bit-match
    let fair_total = sweep
        .fractions
        .iter()
        .zip(&sweep.totals)
        .min_by(|(a, _), (b, _)| {
            (**a - 0.5)
                .abs()
                .partial_cmp(&(**b - 0.5).abs())
                .unwrap()
        })
        .map(|(_, t)| *t)
        .unwrap();
    Recommendation {
        best_fraction: best_f,
        best_total: best_t,
        fair_total,
        gain: 1.0 - best_t / fair_total,
    }
}

/// [`recommend`] generalized over any [`SweepModel`]: sweep the
/// [`Perturbation::Fraction`] candidates of [`candidate_fractions`] against
/// the model's identity baseline and recommend the best split.
///
/// Returns `Ok(None)` when the model has no actionable split — it rejects
/// the fraction knob (fixed spec/trace workflows), or neither the baseline
/// nor any candidate finishes. A failed analysis is a real `Err`. With a
/// cache attached, repeated calls (the monitor re-advising on every
/// bottleneck shift) re-solve only what changed.
pub fn recommend_model(
    model: &Arc<dyn SweepModel>,
    points: usize,
    threads: usize,
    cache: Option<Arc<AnalysisCache>>,
) -> Result<Option<Recommendation>, SweepError> {
    let fractions = candidate_fractions(points);
    let mut perts: Vec<Perturbation> = Vec::with_capacity(fractions.len() + 1);
    perts.push(Perturbation::Identity);
    perts.extend(fractions.iter().map(|&f| Perturbation::Fraction(f)));
    let mut batch = SweepBatch::over(Arc::clone(model)).with_threads(threads);
    if let Some(c) = cache {
        batch = batch.with_cache(c);
    }
    let outcomes = match batch.run(&perts) {
        Ok(o) => o,
        Err(SweepError::Unsupported(_)) => return Ok(None),
        Err(e) => return Err(e),
    };
    let baseline = outcomes[0].makespan.unwrap_or(f64::INFINITY);
    let best = outcomes[1..]
        .iter()
        .zip(fractions.iter())
        .map(|(o, &f)| (f, o.makespan.unwrap_or(f64::INFINITY)))
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(a.0.partial_cmp(&b.0).unwrap()));
    let (best_f, best_t) = match best {
        Some(b) => b,
        None => return Ok(None),
    };
    if !best_t.is_finite() || !baseline.is_finite() {
        return Ok(None);
    }
    Ok(Some(Recommendation {
        best_fraction: best_f,
        best_total: best_t,
        fair_total: baseline,
        gain: 1.0 - best_t / baseline,
    }))
}

/// A recommendation on an arbitrary knob — the ranking-driven
/// generalization of [`Recommendation`].
#[derive(Clone, Debug)]
pub struct KnobRecommendation {
    /// The perturbation kind the advisor searched (`"fraction"`,
    /// `"link_rate_scale"`, ...).
    pub kind: &'static str,
    /// The best candidate value of that knob.
    pub best_value: f64,
    pub best_total: f64,
    /// Predicted total under the model's identity configuration.
    pub baseline_total: f64,
    /// Relative improvement over the baseline.
    pub gain: f64,
}

/// Candidate grid for the generic scale knobs: log-spaced over
/// `[1/4, 4]`, odd-sized so the identity point `1.0` is always a
/// candidate (the baseline anchor the gain is measured against).
fn scale_candidates(points: usize) -> Vec<f64> {
    let n = points.max(3) | 1;
    (0..n)
        .map(|i| 0.25 * 16f64.powf(i as f64 / (n - 1) as f64))
        .collect()
}

/// Pick the first actionable knob of a ranked sensitivity report and
/// line-search its candidate grid: fractions sweep the Fig 7 grid
/// ([`candidate_fractions`]), scale knobs a log-spaced `[1/4, 4]` grid.
/// Knobs marked `insensitive` (or without a stencil derivative) are
/// skipped; a knob whose grid yields no improvement falls through to the
/// next-ranked one. `Ok(None)` means the report has no knob that moves
/// the makespan — an honest "nothing to fix here".
pub fn recommend_from_report(
    model: &Arc<dyn SweepModel>,
    report: &Report,
    points: usize,
    threads: usize,
    cache: Option<Arc<AnalysisCache>>,
) -> Result<Option<KnobRecommendation>, SweepError> {
    for knob in &report.knobs {
        if knob.insensitive || knob.derivative.is_none() {
            continue;
        }
        let values: Vec<f64> = if knob.kind == "fraction" {
            candidate_fractions(points).to_vec()
        } else {
            scale_candidates(points)
        };
        let mut perts: Vec<Perturbation> = Vec::with_capacity(values.len() + 1);
        perts.push(Perturbation::Identity);
        for &v in &values {
            match Perturbation::with_value(knob.kind, v) {
                Some(p) => perts.push(p),
                None => break,
            }
        }
        if perts.len() != values.len() + 1 {
            continue; // unknown kind in a foreign report: skip it
        }
        let mut batch = SweepBatch::over(Arc::clone(model)).with_threads(threads);
        if let Some(c) = cache.as_ref() {
            batch = batch.with_cache(Arc::clone(c));
        }
        let outcomes = match batch.run(&perts) {
            Ok(o) => o,
            // the report was built against a different vocabulary
            Err(SweepError::Unsupported(_)) => continue,
            Err(e) => return Err(e),
        };
        let baseline = outcomes[0].makespan.unwrap_or(f64::INFINITY);
        let best = outcomes[1..]
            .iter()
            .zip(values.iter())
            .map(|(o, &v)| (v, o.makespan.unwrap_or(f64::INFINITY)))
            .min_by(|a, b| a.1.total_cmp(&b.1).then(a.0.total_cmp(&b.0)));
        let Some((best_v, best_t)) = best else { continue };
        if !best_t.is_finite() || !baseline.is_finite() {
            continue;
        }
        let gain = 1.0 - best_t / baseline;
        if gain <= 1e-6 {
            continue; // ranked high but flat across the grid: next knob
        }
        return Ok(Some(KnobRecommendation {
            kind: knob.kind,
            best_value: best_v,
            best_total: best_t,
            baseline_total: baseline,
            gain,
        }));
    }
    Ok(None)
}

/// Convenience wrapper: build the sensitivity report for `model` (no
/// residuals) and feed it to [`recommend_from_report`].
pub fn recommend_ranked(
    model: &Arc<dyn SweepModel>,
    points: usize,
    threads: usize,
    cache: Option<Arc<AnalysisCache>>,
) -> Result<Option<KnobRecommendation>, SweepError> {
    let opts = SenseOpts {
        threads,
        cache: cache.clone(),
        ..SenseOpts::default()
    };
    let report = crate::sense::analyze(model, &[], &opts)?;
    recommend_from_report(model, &report, points, threads, cache)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::sweep::FixedWorkflow;
    use crate::sense::{Band, KnobReport};
    use crate::workflow::scenario::GenomicsScenario;

    #[test]
    fn candidates_sorted_unique_and_contain_fair_share() {
        // n = 49: fig7_fractions contains 25/50 = 0.5 exactly — the push
        // used to duplicate it; n = 50 has no exact 0.5
        for n in [1, 49, 50, 200] {
            let c = candidate_fractions(n);
            assert!(c.windows(2).all(|w| w[0] < w[1]), "not sorted/unique: n={n}");
            assert_eq!(
                c.iter().filter(|f| (**f - 0.5).abs() < 1e-9).count(),
                1,
                "n={n}: {c:?}"
            );
            assert!(c.len() >= n, "n={n}");
        }
        // the exact-grid case keeps exactly n entries (no duplicate sweep)
        assert_eq!(candidate_fractions(49).len(), 49);
        assert_eq!(candidate_fractions(50).len(), 51);
    }

    /// The per-grid-size memo hands back the identical allocation on
    /// repeat calls — the advisor and the live monitor stop re-sorting
    /// the same grid on every sweep/shift.
    #[test]
    fn candidate_fractions_memoized_identical_slice() {
        let a = candidate_fractions(33);
        let b = candidate_fractions(33);
        assert!(Arc::ptr_eq(&a, &b), "repeat call must share the memoized slice");
        let c = candidate_fractions(34);
        assert!(!Arc::ptr_eq(&a, &c), "distinct sizes are distinct entries");
        assert_eq!(a.as_ref(), candidate_fractions(33).as_ref());
    }

    #[test]
    fn recommends_the_paper_headline() {
        let rec = recommend(&VideoScenario::default(), 50, 4);
        assert!(rec.best_fraction >= 0.85, "{rec:?}");
        assert!((0.25..0.40).contains(&rec.gain), "{rec:?}");
        assert!(rec.best_total < rec.fair_total);
    }

    /// The generic path reproduces the video headline: the default
    /// scenario's identity baseline *is* the 50:50 split, so the gain
    /// matches [`recommend`]'s.
    #[test]
    fn recommend_model_matches_video_headline() {
        let model: Arc<dyn SweepModel> = Arc::new(VideoScenario::default());
        let rec = recommend_model(&model, 50, 2, None).unwrap().unwrap();
        assert!(rec.best_fraction >= 0.85, "{rec:?}");
        assert!((0.25..0.40).contains(&rec.gain), "{rec:?}");
    }

    /// Models without a fraction knob yield no recommendation — not an
    /// error (the monitor then emits a shift-only advisory).
    #[test]
    fn recommend_model_none_for_fixed_workflows() {
        let (wf, _) = VideoScenario::default().build();
        let model: Arc<dyn SweepModel> = Arc::new(FixedWorkflow::new("trace", wf));
        assert!(recommend_model(&model, 10, 1, None).unwrap().is_none());
    }

    /// Any model exposing the fraction knob works — genomics included —
    /// and an attached cache does not change the recommendation.
    #[test]
    fn recommend_model_generalizes_and_caches() {
        let model: Arc<dyn SweepModel> = Arc::new(GenomicsScenario::default());
        let cold = recommend_model(&model, 20, 1, None).unwrap().unwrap();
        let cache = Arc::new(AnalysisCache::new());
        let warm1 = recommend_model(&model, 20, 1, Some(Arc::clone(&cache)))
            .unwrap()
            .unwrap();
        let warm2 = recommend_model(&model, 20, 1, Some(Arc::clone(&cache)))
            .unwrap()
            .unwrap();
        assert_eq!(cold.best_fraction, warm1.best_fraction);
        assert_eq!(cold.best_total, warm1.best_total);
        assert_eq!(warm1.best_total, warm2.best_total);
        assert!(cache.stats().hits > 0, "repeat advisory must hit the cache");
    }

    /// The scale grid always contains the identity anchor and stays
    /// inside the documented `[1/4, 4]` envelope.
    #[test]
    fn scale_candidates_contain_identity() {
        for n in [1, 3, 4, 10, 33] {
            let c = scale_candidates(n);
            assert!(c.len() % 2 == 1, "n={n}: grid must be odd-sized");
            assert!(c.len() >= n, "n={n}");
            assert!(c.windows(2).all(|w| w[0] < w[1]), "n={n}: not sorted");
            assert!((c[0] - 0.25).abs() < 1e-12 && (c[c.len() - 1] - 4.0).abs() < 1e-12);
            assert!(
                c.iter().any(|&v| (v - 1.0).abs() < 1e-12),
                "n={n}: identity missing from {c:?}"
            );
        }
    }

    /// The ranking-driven advisor on the video scenario follows the
    /// report's top knob (input size dominates the makespan gradient) and
    /// finds the large win of shrinking the input.
    #[test]
    fn recommend_ranked_video_follows_top_knob() {
        let model: Arc<dyn SweepModel> = Arc::new(VideoScenario::default());
        let rec = recommend_ranked(&model, 9, 2, None).unwrap().unwrap();
        assert_eq!(rec.kind, "input_scale", "{rec:?}");
        assert!(rec.best_value < 1.0, "{rec:?}");
        assert!(rec.gain > 0.5, "{rec:?}");
        assert!(rec.best_total < rec.baseline_total);
    }

    /// Fraction-less models get real advice through their generic scale
    /// knobs — exactly where [`recommend_model`] gives up with `None`.
    #[test]
    fn recommend_ranked_advises_fixed_workflows() {
        let (wf, _) = VideoScenario::default().build();
        let model: Arc<dyn SweepModel> = Arc::new(FixedWorkflow::new("trace", wf));
        assert!(recommend_model(&model, 9, 1, None).unwrap().is_none());
        let rec = recommend_ranked(&model, 9, 1, None).unwrap().unwrap();
        assert!(
            rec.kind == "link_rate_scale" || rec.kind == "cpu_scale",
            "{rec:?}"
        );
        assert!(rec.gain > 0.2, "{rec:?}");
        assert!(rec.best_value > 1.0, "scaling a resource up must be the win: {rec:?}");
    }

    /// A report whose only actionable knob is the fraction routes through
    /// the Fig 7 fraction grid and reproduces the headline recommendation.
    #[test]
    fn report_fraction_knob_uses_fraction_grid() {
        let report = Report {
            workflow: "video".into(),
            makespan: 263.0,
            band: Band {
                lower: 263.0,
                median: 263.0,
                upper: 263.0,
            },
            knobs: vec![KnobReport {
                kind: "fraction",
                base: Some(0.5),
                derivative: Some(-95.0),
                closed_form: None,
                delta: None,
                gain_per_unit: 95.0,
                uncertainty: 0.0,
                direction: "decrease",
                insensitive: false,
                non_smooth: true,
                attribution: Vec::new(),
            }],
            events: 0,
            band_samples: Vec::new(),
            cache: None,
        };
        let model: Arc<dyn SweepModel> = Arc::new(VideoScenario::default());
        let rec = recommend_from_report(&model, &report, 50, 2, None)
            .unwrap()
            .unwrap();
        assert_eq!(rec.kind, "fraction");
        assert!(rec.best_value >= 0.85, "{rec:?}");
        assert!((0.25..0.40).contains(&rec.gain), "{rec:?}");
    }

    /// Insensitive and unknown knobs are skipped; a report with nothing
    /// actionable yields an honest `None`.
    #[test]
    fn report_without_actionable_knobs_yields_none() {
        let dud = |kind: &'static str, insensitive: bool, derivative: Option<f64>| KnobReport {
            kind,
            base: Some(1.0),
            derivative,
            closed_form: None,
            delta: None,
            gain_per_unit: 0.0,
            uncertainty: 0.0,
            direction: "none",
            insensitive,
            non_smooth: false,
            attribution: Vec::new(),
        };
        let report = Report {
            workflow: "video".into(),
            makespan: 263.0,
            band: Band {
                lower: 263.0,
                median: 263.0,
                upper: 263.0,
            },
            knobs: vec![
                dud("task2_time_scale", true, Some(0.0)),
                dud("warp_speed", false, Some(1.0)),
                dud("cpu_scale", false, None),
            ],
            events: 0,
            band_samples: Vec::new(),
            cache: None,
        };
        let model: Arc<dyn SweepModel> = Arc::new(VideoScenario::default());
        assert!(recommend_from_report(&model, &report, 9, 1, None)
            .unwrap()
            .is_none());
    }
}
