//! Allocation advisor: pick the resource split that minimizes the predicted
//! makespan (the paper's "comparison of different scheduling options").

use crate::workflow::scenario::VideoScenario;

use crate::coordinator::sweeper::{best_fraction, exact_sweep, fig7_fractions};

/// A recommendation with its predicted effect.
#[derive(Clone, Debug)]
pub struct Recommendation {
    pub best_fraction: f64,
    pub best_total: f64,
    /// Predicted total under the fair 50:50 default.
    pub fair_total: f64,
    /// Relative improvement over fair sharing.
    pub gain: f64,
}

/// Candidate fractions for [`recommend`]: the Fig 7 grid plus the fair
/// 50:50 baseline, sorted and deduplicated. The dedup matters: for grid
/// sizes where `0.5` (or a float within rounding of it) is already a grid
/// point, a naive push would sweep a duplicate and make the `fair_total`
/// lookup ambiguous.
pub fn candidate_fractions(points: usize) -> Vec<f64> {
    let mut fractions = fig7_fractions(points);
    fractions.push(0.5);
    fractions.sort_by(|a, b| a.partial_cmp(b).unwrap());
    fractions.dedup_by(|a, b| (*a - *b).abs() < 1e-9);
    fractions
}

/// Sweep `points` candidate fractions and recommend the best one.
pub fn recommend(sc: &VideoScenario, points: usize, threads: usize) -> Recommendation {
    let fractions = candidate_fractions(points);
    let sweep = exact_sweep(sc, &fractions, threads);
    let (best_f, best_t) = best_fraction(&sweep);
    // the list always contains exactly one fraction within 1e-9 of 0.5;
    // pick the closest rather than an exact bit-match
    let fair_total = sweep
        .fractions
        .iter()
        .zip(&sweep.totals)
        .min_by(|(a, _), (b, _)| {
            (**a - 0.5)
                .abs()
                .partial_cmp(&(**b - 0.5).abs())
                .unwrap()
        })
        .map(|(_, t)| *t)
        .unwrap();
    Recommendation {
        best_fraction: best_f,
        best_total: best_t,
        fair_total,
        gain: 1.0 - best_t / fair_total,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn candidates_sorted_unique_and_contain_fair_share() {
        // n = 49: fig7_fractions contains 25/50 = 0.5 exactly — the push
        // used to duplicate it; n = 50 has no exact 0.5
        for n in [1, 49, 50, 200] {
            let c = candidate_fractions(n);
            assert!(c.windows(2).all(|w| w[0] < w[1]), "not sorted/unique: n={n}");
            assert_eq!(
                c.iter().filter(|f| (**f - 0.5).abs() < 1e-9).count(),
                1,
                "n={n}: {c:?}"
            );
            assert!(c.len() >= n, "n={n}");
        }
        // the exact-grid case keeps exactly n entries (no duplicate sweep)
        assert_eq!(candidate_fractions(49).len(), 49);
        assert_eq!(candidate_fractions(50).len(), 51);
    }

    #[test]
    fn recommends_the_paper_headline() {
        let rec = recommend(&VideoScenario::default(), 50, 4);
        assert!(rec.best_fraction >= 0.85, "{rec:?}");
        assert!((0.25..0.40).contains(&rec.gain), "{rec:?}");
        assert!(rec.best_total < rec.fair_total);
    }
}
