//! Online re-analysis: re-run BottleMod on live state and react when the
//! bottleneck moves.
//!
//! This demonstrates the paper's closing claim: because the analysis is
//! almost instant, it "may even be used while the tasks or the workflow is
//! still executing to conduct certain optimizations just in time". Two
//! layers live here:
//!
//! * The **workload-agnostic primitives** — [`live_bottleneck`] (which
//!   (process, bottleneck) pair is binding at an observation time, read
//!   off any [`WorkflowAnalysis`]) and [`LiveTracker`] (edge-detection on
//!   that identity: a [`BottleneckShift`] fires exactly when it changes).
//!   These drive [`crate::live`]'s monitor sessions for *any* workflow —
//!   the generalization of the controller below.
//! * The **self-contained video demo** ([`run_online`]) — the historical
//!   closed loop against the Fig 5 scenario's physics, kept as the
//!   reference experiment (`bottlemod online-demo`).

use crate::solver::SolverOpts;
use crate::workflow::engine::{analyze_fixpoint, WorkflowAnalysis};
use crate::workflow::graph::{DataSource, ResourceSource, StartRule, Workflow};
use crate::model::ProcessBuilder;
use crate::pwfn::PwPoly;
use crate::workflow::scenario::VideoScenario;

/// The live bottleneck of an analyzed workflow at observation time `now`:
/// among all nodes whose analysis has a segment covering `now` (and which
/// have not finished by `now`), the `(process name, bottleneck label)` of
/// the segment with the most remaining duration — the constraint that will
/// bind longest from here, i.e. the one worth re-allocating around.
/// `None` when nothing is running at `now` (before the first start or
/// after the predicted finish).
///
/// Deterministic: ties break toward the lowest node id, and the inputs are
/// the bit-exact analyses, so the identity — and therefore every
/// [`BottleneckShift`] a [`LiveTracker`] derives from it — is reproducible
/// run to run.
pub fn live_bottleneck(
    wf: &Workflow,
    wa: &WorkflowAnalysis,
    now: f64,
) -> Option<(String, String)> {
    let mut best: Option<(f64, String, String)> = None;
    for (i, a) in wa.analyses.iter().enumerate() {
        if a.finish_time.map(|f| f <= now).unwrap_or(false) {
            continue;
        }
        for s in &a.segments {
            if !(s.start <= now && now < s.end) {
                continue;
            }
            let end = s.end.min(a.finish_time.unwrap_or(f64::INFINITY));
            let remaining = end - now;
            if remaining <= 1e-9 {
                continue;
            }
            if best.as_ref().map(|b| remaining > b.0).unwrap_or(true) {
                let proc = &wf.nodes[i].process;
                best = Some((
                    remaining,
                    proc.name.clone(),
                    a.bottleneck_name(proc, s.bottleneck),
                ));
            }
        }
    }
    best.map(|(_, p, b)| (p, b))
}

/// The regime that set the predicted horizon: the latest-finishing node's
/// final (positive-length) bottleneck segment.
///
/// This is the live monitor's fallback when [`live_bottleneck`] finds
/// nothing strictly active at `now`: models calibrated from observations
/// alone predict no further than the observation frontier, so at the
/// frontier itself nothing is "running" — but the constraint that bound
/// the last-finishing task up to that point is exactly what is binding the
/// execution right now. `None` when no node has a predicted finish.
///
/// Deterministic for the same reasons as [`live_bottleneck`]: ties on the
/// finish time break toward the lowest node id.
pub fn frontier_bottleneck(wf: &Workflow, wa: &WorkflowAnalysis) -> Option<(String, String)> {
    let mut latest: Option<(f64, usize)> = None;
    for (i, a) in wa.analyses.iter().enumerate() {
        if let Some(f) = a.finish_time {
            if latest.map(|(bf, _)| f > bf).unwrap_or(true) {
                latest = Some((f, i));
            }
        }
    }
    let (finish, i) = latest?;
    let a = &wa.analyses[i];
    let proc = &wf.nodes[i].process;
    a.segments
        .iter()
        .rev()
        .find(|s| s.start < finish && s.end.min(finish) - s.start > 1e-9)
        .map(|s| (proc.name.clone(), a.bottleneck_name(proc, s.bottleneck)))
}

/// A change in the live bottleneck's identity between two observations.
#[derive(Clone, Debug, PartialEq)]
pub struct BottleneckShift {
    /// The previously binding `(process, bottleneck)`, if one was ever
    /// established.
    pub from: Option<(String, String)>,
    /// The newly binding pair.
    pub to: (String, String),
}

/// Edge detector over [`live_bottleneck`] observations: remembers the last
/// established identity and reports a [`BottleneckShift`] exactly when a
/// *different* one is observed. The first establishment does not fire
/// (there is nothing to re-allocate away from yet), and `None`
/// observations (nothing running) neither fire nor forget.
#[derive(Clone, Debug, Default)]
pub struct LiveTracker {
    last: Option<(String, String)>,
    established: bool,
}

impl LiveTracker {
    pub fn new() -> LiveTracker {
        LiveTracker::default()
    }

    /// The last established bottleneck identity, if any.
    pub fn current(&self) -> Option<&(String, String)> {
        self.last.as_ref()
    }

    /// Feed one observation; returns the shift it completes, if any.
    pub fn observe(&mut self, current: Option<(String, String)>) -> Option<BottleneckShift> {
        let cur = current?;
        if self.last.as_ref() == Some(&cur) {
            return None;
        }
        let from = self.last.replace(cur.clone());
        if !self.established {
            self.established = true;
            return None;
        }
        Some(BottleneckShift { from, to: cur })
    }
}

/// Observable mid-flight state of the Fig 5 workflow.
#[derive(Clone, Copy, Debug)]
pub struct LiveState {
    pub d1: f64,
    pub d2: f64,
    pub t1_out: f64,
    pub t2_out: f64,
}

/// One controller decision.
#[derive(Clone, Copy, Debug)]
pub struct Decision {
    pub t: f64,
    pub fraction: f64,
    pub predicted_remaining: f64,
}

/// Result of an online-controlled execution.
#[derive(Clone, Debug)]
pub struct OnlineResult {
    pub total: f64,
    pub decisions: Vec<Decision>,
    /// Wall-clock spent inside the analyses (model overhead).
    pub analysis_seconds: f64,
}

/// Build the model of the *remaining* workflow from live state.
fn remaining_workflow(sc: &VideoScenario, st: &LiveState, fraction: f64) -> Workflow {
    let mut wf = Workflow::new();
    let pool = wf.add_pool("link", PwPoly::constant(sc.link_rate));
    let rem1 = (sc.input_size - st.d1).max(0.0);
    let rem2 = (sc.input_size - st.d2).max(0.0);

    let mk_dl = |name: &str, rem: f64| {
        ProcessBuilder::new(name, rem.max(1.0))
            .stream_data("remote", rem.max(1.0))
            .stream_resource("link", rem.max(1.0))
            .identity_output("file")
            .build()
    };
    let dl1 = wf.add_node(
        mk_dl("dl1", rem1),
        vec![DataSource::External(PwPoly::constant(rem1.max(1.0)))],
        vec![ResourceSource::PoolFraction { pool, fraction }],
        StartRule::default(),
    );
    let dl2 = wf.add_node(
        mk_dl("dl2", rem2),
        vec![DataSource::External(PwPoly::constant(rem2.max(1.0)))],
        vec![ResourceSource::PoolResidual { pool }],
        StartRule::default(),
    );

    // task 1: still needs the rest of dl1, then the remaining encode CPU
    let enc_left = sc.t1_cpu * (1.0 - st.t1_out / sc.t1_output);
    let out_left = (sc.t1_output - st.t1_out).max(1.0);
    let t1 = ProcessBuilder::new("task1", out_left)
        .burst_data("video", rem1.max(1e-9))
        .stream_resource("cpu", enc_left.max(1e-9))
        .identity_output("reversed")
        .build();
    let t1n = wf.add_node(
        t1,
        vec![DataSource::ProcessOutput { node: dl1, output: 0 }],
        vec![ResourceSource::Fixed(PwPoly::constant(1.0))],
        StartRule::default(),
    );

    // task 2: streams the remaining dl2 bytes; already-downloaded but not
    // yet copied bytes (the backlog) are progress available up front
    let t2_left = (sc.input_size - st.t2_out).max(1.0);
    let backlog = (st.d2 - st.t2_out).max(0.0);
    let t2 = ProcessBuilder::new("task2", t2_left)
        .custom_data(
            "video",
            &[(0.0, backlog.min(t2_left)), (rem2.max(1.0), t2_left)],
        )
        .stream_resource("io", sc.t2_time * t2_left / sc.input_size)
        .identity_output("rotated")
        .build();
    let t2n = wf.add_node(
        t2,
        vec![DataSource::ProcessOutput { node: dl2, output: 0 }],
        vec![ResourceSource::Fixed(PwPoly::constant(1.0))],
        StartRule::default(),
    );

    // task 3 barrier
    let t3_total = out_left + t2_left;
    let t3 = ProcessBuilder::new("task3", t3_total)
        .stream_resource("io", sc.t3_time)
        .identity_output("result")
        .build();
    wf.add_node(
        t3,
        vec![],
        vec![ResourceSource::Fixed(PwPoly::constant(1.0))],
        StartRule {
            at: 0.0,
            after: vec![t1n, t2n],
        },
    );
    wf
}

/// Predict the remaining time for a candidate fraction from live state.
pub fn predict_remaining(sc: &VideoScenario, st: &LiveState, fraction: f64) -> f64 {
    let wf = remaining_workflow(sc, st, fraction);
    analyze_fixpoint(&wf, &SolverOpts::default(), 4)
        .ok()
        .and_then(|wa| wa.makespan)
        .unwrap_or(f64::INFINITY)
}

/// Execute the workflow with the controller re-planning every
/// `replan_every` seconds over `candidates`. With a single candidate this
/// degrades to a static allocation.
pub fn run_online(
    sc: &VideoScenario,
    replan_every: f64,
    candidates: &[f64],
) -> OnlineResult {
    let dt = 0.02;
    let size = sc.input_size;
    let (mut d1, mut d2) = (0.0f64, 0.0f64);
    let (mut t1_read, mut t1_out, mut t2_out, mut t3_out) = (0.0f64, 0.0, 0.0, 0.0);
    let t3_total = sc.t1_output + sc.input_size;
    let (mut t1_done, mut t2_done, mut t3_done) = (f64::NAN, f64::NAN, f64::NAN);
    let (mut dl1_done, mut dl2_done) = (f64::NAN, f64::NAN);

    let mut fraction = candidates[0];
    let mut decisions = vec![];
    let mut analysis_time = 0.0f64;
    let mut next_replan = 0.0f64;

    let mut t = 0.0f64;
    let horizon = 50.0 * size / sc.link_rate + 1e4;
    while t3_done.is_nan() && t < horizon {
        // ---- controller ---------------------------------------------------
        if t >= next_replan && (dl1_done.is_nan() || dl2_done.is_nan()) {
            let st = LiveState {
                d1,
                d2,
                t1_out,
                t2_out,
            };
            let t0 = std::time::Instant::now();
            let mut best = (fraction, f64::INFINITY);
            for &c in candidates {
                let pred = predict_remaining(sc, &st, c);
                if pred < best.1 {
                    best = (c, pred);
                }
            }
            analysis_time += t0.elapsed().as_secs_f64();
            fraction = best.0;
            decisions.push(Decision {
                t,
                fraction,
                predicted_remaining: best.1,
            });
            next_replan = t + replan_every;
        }

        // ---- physics (same as the testbed) --------------------------------
        let cap1 = if dl2_done.is_nan() {
            sc.link_rate * fraction
        } else {
            sc.link_rate
        };
        let cap2 = if dl1_done.is_nan() {
            sc.link_rate * (1.0 - fraction)
        } else {
            sc.link_rate
        };
        if dl1_done.is_nan() {
            d1 = (d1 + cap1 * dt).min(size);
            if d1 >= size {
                dl1_done = t + dt;
            }
        }
        if dl2_done.is_nan() {
            d2 = (d2 + cap2 * dt).min(size);
            if d2 >= size {
                dl2_done = t + dt;
            }
        }
        if t1_done.is_nan() {
            if t1_read < size {
                t1_read = (t1_read + size / sc.t1_decode_cpu * dt).min(d1);
            } else {
                t1_out = (t1_out + sc.t1_output / sc.t1_cpu * dt).min(sc.t1_output);
                if t1_out >= sc.t1_output {
                    t1_done = t + dt;
                }
            }
        }
        if t2_done.is_nan() {
            t2_out = (t2_out + size / sc.t2_time * dt).min(d2);
            if t2_out >= size {
                t2_done = t + dt;
            }
        }
        if t3_done.is_nan() && !t1_done.is_nan() && !t2_done.is_nan() {
            let start = t1_done.max(t2_done);
            if t >= start {
                t3_out = (t3_out + t3_total / sc.t3_time * dt).min(t3_total);
                if t3_out >= t3_total {
                    t3_done = t + dt;
                }
            }
        }
        t += dt;
    }

    OnlineResult {
        total: t3_done,
        decisions,
        analysis_seconds: analysis_time,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// In the Fig 5 video workflow at 50:50, the shared link binds both
    /// downloads until ~178 s, then task1's encode cpu (~82 s), then the
    /// 3 s mux tail on io; [`live_bottleneck`] must read exactly that off
    /// the analysis, and a [`LiveTracker`] over a time sweep must fire
    /// exactly those two handoffs (link -> cpu -> io).
    #[test]
    fn live_bottleneck_tracks_the_video_handoffs() {
        let (wf, _) = VideoScenario::default().build();
        let wa = analyze_fixpoint(&wf, &SolverOpts::default(), 8).unwrap();
        let total = wa.makespan.unwrap();

        let early = live_bottleneck(&wf, &wa, 10.0).unwrap();
        assert_eq!(early.1, "res:link", "{early:?}");
        let late = live_bottleneck(&wf, &wa, 200.0).unwrap();
        assert_eq!(late, ("task1-reverse".to_string(), "res:cpu".to_string()));
        // after the predicted finish nothing is running
        assert!(live_bottleneck(&wf, &wa, total + 1.0).is_none());

        let mut tracker = LiveTracker::new();
        let mut shifts = Vec::new();
        let mut t = 0.0;
        while t < total {
            if let Some(s) = tracker.observe(live_bottleneck(&wf, &wa, t)) {
                shifts.push(s);
            }
            t += 1.0;
        }
        assert_eq!(shifts.len(), 2, "{shifts:?}");
        assert_eq!(shifts[0].from.as_ref().unwrap().1, "res:link");
        assert_eq!(shifts[0].to.1, "res:cpu");
        assert_eq!(shifts[1].to, ("task3-mux".to_string(), "res:io".to_string()));

        // the horizon-setting regime is the mux tail — and it is still
        // reported at (and past) the frontier, where live_bottleneck sees
        // nothing strictly active anymore
        assert_eq!(
            frontier_bottleneck(&wf, &wa).unwrap(),
            ("task3-mux".to_string(), "res:io".to_string())
        );
    }

    #[test]
    fn tracker_ignores_gaps_and_repeats() {
        let mut tr = LiveTracker::new();
        let link = ("dl".to_string(), "res:link".to_string());
        let cpu = ("t1".to_string(), "res:cpu".to_string());
        assert!(tr.observe(None).is_none());
        assert!(tr.observe(Some(link.clone())).is_none()); // establishment
        assert!(tr.observe(Some(link.clone())).is_none()); // repeat
        assert!(tr.observe(None).is_none()); // gap neither fires nor forgets
        let s = tr.observe(Some(cpu.clone())).unwrap();
        assert_eq!(s.from, Some(link));
        assert_eq!(s.to, cpu);
        assert_eq!(tr.current(), Some(&cpu));
    }

    #[test]
    fn online_beats_static_fair_share() {
        let sc = VideoScenario::default();
        let static_fair = run_online(&sc, 1e9, &[0.5]); // never replans past t=0
        let candidates: Vec<f64> = (1..=19).map(|i| i as f64 / 20.0).collect();
        let online = run_online(&sc, 10.0, &candidates);
        assert!(
            online.total < 0.75 * static_fair.total,
            "online {} vs fair {}",
            online.total,
            static_fair.total
        );
        // the controller picks a high dl1 fraction from the start (the
        // paper's insight); once dl1 is finished, it flips the remaining
        // bandwidth to dl2
        let first = online.decisions[0];
        assert!(first.fraction >= 0.8, "{first:?}");
    }

    #[test]
    fn analysis_overhead_is_tiny() {
        let sc = VideoScenario::default();
        let candidates: Vec<f64> = (1..=9).map(|i| i as f64 / 10.0).collect();
        let online = run_online(&sc, 20.0, &candidates);
        // total model overhead must be well under a simulated second —
        // this is the "fast enough to run online" claim
        assert!(
            online.analysis_seconds < 0.5,
            "analysis took {}",
            online.analysis_seconds
        );
        assert!(online.total.is_finite());
    }

    #[test]
    fn mid_flight_prediction_is_consistent() {
        // from the true 50:50 state at t=60, predicting the remaining time
        // should land near (true total - 60)
        let sc = VideoScenario::default().with_fraction(0.5);
        let rate = sc.link_rate * 0.5;
        let st = LiveState {
            d1: rate * 60.0,
            d2: rate * 60.0,
            t1_out: 0.0,
            t2_out: rate * 60.0,
        };
        let pred = predict_remaining(&sc, &st, 0.5);
        let (wf, _) = sc.build();
        let truth = analyze_fixpoint(&wf, &SolverOpts::default(), 6)
            .unwrap()
            .makespan
            .unwrap();
        assert!(
            (pred - (truth - 60.0)).abs() < 3.0,
            "pred {pred} vs {}",
            truth - 60.0
        );
    }
}
