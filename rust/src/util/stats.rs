//! Small statistics + ASCII table helpers for benches, the testbed's
//! multi-run aggregation (Fig 7's min/max bars) and report printing.

/// Summary statistics of a sample.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub std: f64,
    pub p50: f64,
    pub p95: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let mut s = xs.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            min: s[0],
            max: s[n - 1],
            std: var.sqrt(),
            p50: percentile_sorted(&s, 0.50),
            p95: percentile_sorted(&s, 0.95),
        }
    }
}

/// Percentile of an already-sorted slice (linear interpolation).
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (pos - lo as f64) * (sorted[hi] - sorted[lo])
    }
}

/// Render rows as a boxed ASCII table. First row is the header.
pub fn ascii_table(rows: &[Vec<String>]) -> String {
    if rows.is_empty() {
        return String::new();
    }
    let ncols = rows.iter().map(|r| r.len()).max().unwrap();
    let mut widths = vec![0usize; ncols];
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.chars().count());
        }
    }
    let sep = {
        let mut s = String::from("+");
        for w in &widths {
            s.push_str(&"-".repeat(w + 2));
            s.push('+');
        }
        s
    };
    let mut out = String::new();
    out.push_str(&sep);
    out.push('\n');
    for (ri, row) in rows.iter().enumerate() {
        out.push('|');
        for i in 0..ncols {
            let cell = row.get(i).map(String::as_str).unwrap_or("");
            let pad = widths[i] - cell.chars().count();
            out.push(' ');
            out.push_str(cell);
            out.push_str(&" ".repeat(pad + 1));
            out.push('|');
        }
        out.push('\n');
        if ri == 0 {
            out.push_str(&sep);
            out.push('\n');
        }
    }
    out.push_str(&sep);
    out.push('\n');
    out
}

/// Format seconds human-readably for reports.
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.1} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{secs:.2} s")
    }
}

/// Format a byte count.
pub fn fmt_bytes(bytes: f64) -> String {
    const UNITS: &[&str] = &["B", "KB", "MB", "GB", "TB"];
    let mut v = bytes;
    let mut u = 0;
    while v >= 1000.0 && u + 1 < UNITS.len() {
        v /= 1000.0;
        u += 1;
    }
    if u == 0 {
        format!("{v:.0} {}", UNITS[u])
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
        assert!((s.std - (2.0f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let s = [0.0, 10.0];
        assert_eq!(percentile_sorted(&s, 0.5), 5.0);
        assert_eq!(percentile_sorted(&s, 0.0), 0.0);
        assert_eq!(percentile_sorted(&s, 1.0), 10.0);
    }

    #[test]
    fn table_renders() {
        let t = ascii_table(&[
            vec!["a".into(), "long header".into()],
            vec!["1".into(), "2".into()],
        ]);
        assert!(t.contains("| a |"));
        assert!(t.contains("| long header |"));
        // sep, header, sep, row, sep
        assert_eq!(t.lines().count(), 5);
    }

    #[test]
    fn duration_format() {
        assert_eq!(fmt_duration(0.5e-9 * 100.0), "50.0 ns");
        assert_eq!(fmt_duration(0.0205), "20.50 ms");
        assert_eq!(fmt_duration(2.0), "2.00 s");
    }

    #[test]
    fn bytes_format() {
        assert_eq!(fmt_bytes(999.0), "999 B");
        assert_eq!(fmt_bytes(1_137_486_559.0), "1.14 GB");
    }
}
