//! Deterministic xorshift PRNG.
//!
//! No `rand` crate offline; the testbed's jitter, the property-test harness
//! and workload generators all need *seeded, reproducible* randomness, which
//! a xorshift64* generator provides with plenty of quality for simulation.

/// A seeded xorshift64* generator.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // avoid the all-zero fixpoint
        Rng {
            state: seed.wrapping_mul(0x9E3779B97F4A7C15) | 1,
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in `[lo, hi)`.
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in `[0, n)`.
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Multiplicative jitter `1 + sigma * N(0,1)`, clamped positive.
    pub fn jitter(&mut self, sigma: f64) -> f64 {
        (1.0 + sigma * self.normal()).max(0.05)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_reasonable() {
        let mut r = Rng::new(123);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(99);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}
