//! Minimal `anyhow`-style dynamic error for CLI / exporter / service code.
//!
//! The offline vendor set has no `anyhow`; this is the subset the repo
//! needs: a string-backed [`Error`] that any `std::error::Error` converts
//! into (so `?` works on io/parse/solver errors alike), a [`Result`]
//! alias, a [`Context`] extension trait, and `bail!`/`ensure!` macros.
//! Library modules keep their typed errors (`SolveError`, `WorkflowError`,
//! ...); this type is for the binary-shaped layers only.

use std::fmt;

/// A dynamic, message-carrying error.
///
/// Deliberately does *not* implement `std::error::Error` so the blanket
/// `From<E: std::error::Error>` impl below cannot overlap with the identity
/// `From<Error> for Error` (the same trick `anyhow::Error` uses).
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(m: impl Into<String>) -> Error {
        Error { msg: m.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// Result alias defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to an error, `anyhow::Context`-style.
pub trait Context<T> {
    fn context(self, msg: impl Into<String>) -> Result<T>;
    fn with_context<S: Into<String>>(self, f: impl FnOnce() -> S) -> Result<T>;
}

impl<T, E: std::error::Error> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl Into<String>) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", msg.into())))
    }

    fn with_context<S: Into<String>>(self, f: impl FnOnce() -> S) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f().into())))
    }
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::util::error::Error::msg(format!($($arg)*)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/file/9b1c")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert!(io_fail().is_err());
    }

    #[test]
    fn context_prepends() {
        let r: std::result::Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::Other,
            "inner",
        ));
        let e = r.context("outer").unwrap_err();
        let s = e.to_string();
        assert!(s.contains("outer") && s.contains("inner"), "{s}");
    }

    fn bails(x: i32) -> Result<i32> {
        ensure!(x > 0, "x must be positive, got {x}");
        if x > 100 {
            bail!("x too big: {x}");
        }
        Ok(x)
    }

    #[test]
    fn macros_work() {
        assert_eq!(bails(5).unwrap(), 5);
        assert!(bails(-1).unwrap_err().to_string().contains("positive"));
        assert!(bails(200).unwrap_err().to_string().contains("too big"));
    }
}
