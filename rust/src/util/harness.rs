//! Hand-rolled micro-bench + property-test harnesses.
//!
//! The offline vendor set has neither `criterion` nor `proptest`, so the
//! bench targets (`rust/benches/*.rs`, `harness = false`) and the
//! property-style tests build on these. The bench harness does warmup,
//! adaptive iteration-count selection and reports mean/p50/p95; the property
//! harness drives seeded generators and reports the failing seed for
//! reproduction.

use std::time::Instant;

use super::stats::{fmt_duration, Summary};

/// Result of a single benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// seconds per iteration
    pub per_iter: Summary,
    pub iters: usize,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12} /iter  (p50 {:>10}, p95 {:>10}, n={})",
            self.name,
            fmt_duration(self.per_iter.mean),
            fmt_duration(self.per_iter.p50),
            fmt_duration(self.per_iter.p95),
            self.iters
        )
    }
}

/// Benchmark `f`, choosing the iteration count so each sample lasts ≥ ~20 ms,
/// collecting `samples` samples. Returns per-iteration timing stats.
pub fn bench<R>(name: &str, samples: usize, mut f: impl FnMut() -> R) -> BenchResult {
    // warmup + calibrate
    let mut iters = 1usize;
    loop {
        let t0 = Instant::now();
        for _ in 0..iters {
            std::hint::black_box(f());
        }
        let dt = t0.elapsed().as_secs_f64();
        if dt > 0.02 || iters >= 1 << 22 {
            break;
        }
        iters = (iters * 2).max((0.025 / dt.max(1e-9)) as usize);
    }
    let mut per_iter = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        for _ in 0..iters {
            std::hint::black_box(f());
        }
        per_iter.push(t0.elapsed().as_secs_f64() / iters as f64);
    }
    BenchResult {
        name: name.to_string(),
        per_iter: Summary::of(&per_iter),
        iters,
    }
}

/// Benchmark that runs `f` exactly once per sample (for expensive runs where
/// adaptive batching is unwanted, e.g. whole-workflow DES at 100 GB).
pub fn bench_once<R>(name: &str, samples: usize, mut f: impl FnMut() -> R) -> BenchResult {
    let mut per_iter = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        std::hint::black_box(f());
        per_iter.push(t0.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        per_iter: Summary::of(&per_iter),
        iters: 1,
    }
}

/// Property-test driver: runs `prop(rng)` for `cases` seeded cases; on a
/// panic-free failure (returning `Err(msg)`) it reports the seed and case.
pub fn check_property(
    name: &str,
    cases: u64,
    prop: impl Fn(&mut super::rng::Rng) -> Result<(), String>,
) {
    for case in 0..cases {
        let seed = 0x5EED_0000 + case;
        let mut rng = super::rng::Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench("noop-ish", 3, || 1 + 1);
        assert!(r.per_iter.mean > 0.0);
        assert!(r.per_iter.mean < 1e-3);
        assert!(r.report().contains("noop-ish"));
    }

    #[test]
    fn bench_once_runs_each_sample() {
        let mut count = 0;
        let r = bench_once("once", 5, || count += 1);
        assert_eq!(count, 5);
        assert_eq!(r.iters, 1);
    }

    #[test]
    fn property_pass() {
        check_property("always-true", 50, |rng| {
            let x = rng.f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("x={x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn property_fail_reports_seed() {
        check_property("always-false", 1, |_| Err("nope".into()));
    }
}
