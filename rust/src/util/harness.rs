//! Hand-rolled micro-bench + property-test harnesses.
//!
//! The offline vendor set has neither `criterion` nor `proptest`, so the
//! bench targets (`rust/benches/*.rs`, `harness = false`) and the
//! property-style tests build on these. The bench harness does warmup,
//! adaptive iteration-count selection and reports mean/p50/p95; the property
//! harness drives seeded generators and reports the failing seed for
//! reproduction.

use std::path::PathBuf;
use std::time::Instant;

use super::json::Json;
use super::stats::{fmt_duration, Summary};

/// Result of a single benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// seconds per iteration
    pub per_iter: Summary,
    pub iters: usize,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12} /iter  (p50 {:>10}, p95 {:>10}, n={})",
            self.name,
            fmt_duration(self.per_iter.mean),
            fmt_duration(self.per_iter.p50),
            fmt_duration(self.per_iter.p95),
            self.iters
        )
    }
}

/// Benchmark `f`, choosing the iteration count so each sample lasts ≥ ~20 ms,
/// collecting `samples` samples. Returns per-iteration timing stats.
pub fn bench<R>(name: &str, samples: usize, mut f: impl FnMut() -> R) -> BenchResult {
    // warmup + calibrate
    let mut iters = 1usize;
    loop {
        let t0 = Instant::now();
        for _ in 0..iters {
            std::hint::black_box(f());
        }
        let dt = t0.elapsed().as_secs_f64();
        if dt > 0.02 || iters >= 1 << 22 {
            break;
        }
        iters = (iters * 2).max((0.025 / dt.max(1e-9)) as usize);
    }
    let mut per_iter = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        for _ in 0..iters {
            std::hint::black_box(f());
        }
        per_iter.push(t0.elapsed().as_secs_f64() / iters as f64);
    }
    BenchResult {
        name: name.to_string(),
        per_iter: Summary::of(&per_iter),
        iters,
    }
}

/// Benchmark that runs `f` exactly once per sample (for expensive runs where
/// adaptive batching is unwanted, e.g. whole-workflow DES at 100 GB).
pub fn bench_once<R>(name: &str, samples: usize, mut f: impl FnMut() -> R) -> BenchResult {
    let mut per_iter = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        std::hint::black_box(f());
        per_iter.push(t0.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        per_iter: Summary::of(&per_iter),
        iters: 1,
    }
}

// --------------------------------------------------------- bench artifacts

/// Directory for machine-readable bench artifacts (`BENCH_<name>.json`):
/// `BOTTLEMOD_BENCH_DIR` if set, else the repo root (the parent of the
/// package's `CARGO_MANIFEST_DIR`, which cargo exports when running
/// benches), else the current directory.
pub fn bench_artifact_dir() -> PathBuf {
    if let Ok(d) = std::env::var("BOTTLEMOD_BENCH_DIR") {
        return PathBuf::from(d);
    }
    if let Ok(m) = std::env::var("CARGO_MANIFEST_DIR") {
        let p = PathBuf::from(m);
        if let Some(parent) = p.parent() {
            return parent.to_path_buf();
        }
    }
    PathBuf::from(".")
}

/// Path of one bench's JSON artifact, `BENCH_<bench>.json`.
pub fn bench_artifact_path(bench: &str) -> PathBuf {
    bench_artifact_dir().join(format!("BENCH_{bench}.json"))
}

/// Read a previously persisted artifact — the perf trajectory's last
/// recorded point (e.g. the prior PR's run). `None` when absent or
/// unparsable.
pub fn read_bench_artifact(bench: &str) -> Option<Json> {
    let s = std::fs::read_to_string(bench_artifact_path(bench)).ok()?;
    Json::parse(&s).ok()
}

/// Persist a bench's results as `BENCH_<bench>.json` (one pretty-printed
/// object, deterministic key order) so the perf trajectory is tracked
/// across PRs; CI uploads these as artifacts. Returns the written path.
pub fn write_bench_artifact(bench: &str, fields: Vec<(&str, Json)>) -> std::io::Result<PathBuf> {
    write_bench_artifact_in(&bench_artifact_dir(), bench, fields)
}

/// [`write_bench_artifact`] into an explicit directory (tests; callers
/// that resolve the directory themselves).
pub fn write_bench_artifact_in(
    dir: &std::path::Path,
    bench: &str,
    fields: Vec<(&str, Json)>,
) -> std::io::Result<PathBuf> {
    let path = dir.join(format!("BENCH_{bench}.json"));
    let mut body = Json::obj(fields).to_string_pretty();
    body.push('\n');
    std::fs::write(&path, body)?;
    Ok(path)
}

/// Property-test driver: runs `prop(rng)` for `cases` seeded cases; on a
/// panic-free failure (returning `Err(msg)`) it reports the seed and case.
pub fn check_property(
    name: &str,
    cases: u64,
    prop: impl Fn(&mut super::rng::Rng) -> Result<(), String>,
) {
    for case in 0..cases {
        let seed = 0x5EED_0000 + case;
        let mut rng = super::rng::Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench("noop-ish", 3, || 1 + 1);
        assert!(r.per_iter.mean > 0.0);
        assert!(r.per_iter.mean < 1e-3);
        assert!(r.report().contains("noop-ish"));
    }

    #[test]
    fn bench_once_runs_each_sample() {
        let mut count = 0;
        let r = bench_once("once", 5, || count += 1);
        assert_eq!(count, 5);
        assert_eq!(r.iters, 1);
    }

    #[test]
    fn bench_artifact_roundtrip() {
        // explicit directory: no process-global env mutation (tests run on
        // parallel threads; setenv would race with concurrent env reads)
        let dir = std::env::temp_dir().join("bottlemod_bench_artifact_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = write_bench_artifact_in(
            &dir,
            "unit_test",
            vec![
                ("scenarios", Json::Num(256.0)),
                ("speedup", Json::Num(3.5)),
                ("tag", Json::Str("test".into())),
            ],
        )
        .unwrap();
        assert_eq!(path, dir.join("BENCH_unit_test.json"));
        let body = std::fs::read_to_string(&path).unwrap();
        let back = Json::parse(&body).expect("parses back");
        assert_eq!(back.get("scenarios").as_f64(), Some(256.0));
        assert_eq!(back.get("tag").as_str(), Some("test"));
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn property_pass() {
        check_property("always-true", 50, |rng| {
            let x = rng.f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("x={x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn property_fail_reports_seed() {
        check_property("always-false", 1, |_| Err("nope".into()));
    }
}
