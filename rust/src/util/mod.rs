//! Support utilities: seeded RNG, minimal JSON, stats/tables, and the
//! hand-rolled bench + property-test harnesses (the offline vendor set has
//! no criterion/proptest/serde).

pub mod harness;
pub mod json;
pub mod rng;
pub mod stats;

pub use json::Json;
pub use rng::Rng;
