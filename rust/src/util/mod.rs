//! Support utilities: seeded RNG, minimal JSON, stats/tables, the
//! hand-rolled bench + property-test harnesses, a string-backed dynamic
//! error, and a scoped-thread parallel map (the offline vendor set has no
//! criterion/proptest/serde/anyhow/rayon).

pub mod error;
pub mod harness;
pub mod json;
pub mod par;
pub mod rng;
pub mod stats;

pub use error::{Context, Error, Result};
pub use json::Json;
pub use rng::Rng;
