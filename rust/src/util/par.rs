//! Work-stealing parallel map on scoped std threads.
//!
//! The offline vendor set has no `rayon`, so the batched sweep engine
//! ([`crate::runtime::sweep`]) fans out on this instead: a fixed pool of
//! scoped threads pulling item indices from a shared atomic counter. Each
//! item's result lands at its input index, so the output is *identical* to
//! the sequential map regardless of scheduling — the property the sweep
//! engine's bit-for-bit determinism contract rests on.
//!
//! The per-item lock on the result vector is negligible next to the work
//! each item does here (a full workflow analysis, ~ms); this is a fan-out
//! primitive for coarse tasks, not a data-parallel inner loop.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Worker count: `BOTTLEMOD_THREADS` env override, else the machine's
/// available parallelism, else 1.
pub fn num_threads() -> usize {
    std::env::var("BOTTLEMOD_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
}

/// Map `f` over `items` on up to `threads` scoped threads. `f` receives the
/// item index and the item; results are returned in input order.
///
/// With `threads <= 1` this runs inline on the caller's thread with no
/// synchronization at all — the sequential reference path.
pub fn par_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    if threads <= 1 {
        return items.iter().enumerate().map(|(i, it)| f(i, it)).collect();
    }
    let next = AtomicUsize::new(0);
    let out: Mutex<Vec<Option<R>>> = Mutex::new((0..items.len()).map(|_| None).collect());
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(i, &items[i]);
                out.lock().unwrap()[i] = Some(r);
            });
        }
    });
    out.into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("every slot filled by a worker"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order() {
        let items: Vec<usize> = (0..257).collect();
        let seq = par_map(&items, 1, |i, &x| (i, x * x));
        let par = par_map(&items, 8, |i, &x| (i, x * x));
        assert_eq!(seq, par);
        assert_eq!(par[100], (100, 10_000));
    }

    #[test]
    fn handles_empty_and_single() {
        let empty: Vec<u32> = vec![];
        assert!(par_map(&empty, 4, |_, &x| x).is_empty());
        assert_eq!(par_map(&[7u32], 4, |_, &x| x + 1), vec![8]);
    }

    #[test]
    fn all_items_processed_exactly_once() {
        use std::sync::atomic::AtomicUsize;
        let calls = AtomicUsize::new(0);
        let items: Vec<usize> = (0..1000).collect();
        let out = par_map(&items, 6, |_, &x| {
            calls.fetch_add(1, Ordering::Relaxed);
            x
        });
        assert_eq!(calls.load(Ordering::Relaxed), 1000);
        assert_eq!(out, items);
    }

    #[test]
    fn num_threads_is_positive() {
        assert!(num_threads() >= 1);
    }
}
