//! Minimal JSON parser + serializer.
//!
//! The offline vendor set has no `serde`/`serde_json`, and BottleMod needs a
//! structured interchange format for workflow specs (`model::spec`), figure
//! exports and coordinator requests. This is a small, strict JSON subset
//! implementation: objects, arrays, strings (with escapes), f64 numbers,
//! booleans, null. Good enough for configs; not a streaming parser.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are sorted (BTreeMap) so serialization is
/// deterministic — handy for golden tests.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ----------------------------------------------------------- accessors

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The number as a non-negative integer. `None` for non-numbers,
    /// negatives, fractional values, and anything ≥ 2^53 (where f64 stops
    /// representing integers exactly) — the strict accessor behind the
    /// wire protocol's `id`/`v` fields.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x)
                if x.is_finite()
                    && *x >= 0.0
                    && *x == x.trunc()
                    && *x < 9_007_199_254_740_992.0 =>
            {
                Some(*x as u64)
            }
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj["key"]`, or `Json::Null` when missing / not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|m| m.get(key)).unwrap_or(&NULL)
    }

    pub fn obj(entries: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            entries
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    // ------------------------------------------------------------- parsing

    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let b = s.as_bytes();
        let mut p = Parser { b, pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // --------------------------------------------------------- serializing

    #[allow(clippy::inherent_to_string_shadow_display)]
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(0));
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        out.push_str(&format!("{}", *x as i64));
                    } else {
                        out.push_str(&format!("{x}"));
                    }
                } else {
                    // JSON has no inf/nan; encode as null (documented subset)
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(ind) = indent {
                        out.push('\n');
                        out.push_str(&"  ".repeat(ind + 1));
                        item.write(out, Some(ind + 1));
                    } else {
                        item.write(out, None);
                    }
                }
                if let Some(ind) = indent {
                    out.push('\n');
                    out.push_str(&"  ".repeat(ind));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(ind) = indent {
                        out.push('\n');
                        out.push_str(&"  ".repeat(ind + 1));
                        Json::Str(k.clone()).write(out, None);
                        out.push_str(": ");
                        v.write(out, Some(ind + 1));
                    } else {
                        Json::Str(k.clone()).write(out, None);
                        out.push(':');
                        v.write(out, None);
                    }
                }
                if let Some(ind) = indent {
                    out.push('\n');
                    out.push_str(&"  ".repeat(ind));
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c == b'+' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    /// Numbers: f64 literals including scientific notation (`1.2e9`,
    /// `3E+8`, `-1.5e-3`) — trace files routinely log byte counts that
    /// way. A leading `+` is accepted as a documented extension beyond
    /// strict JSON (skipped here; the rest goes through `f64::from_str`).
    fn number(&mut self) -> Result<Json, JsonError> {
        if self.peek() == Some(b'+')
            && matches!(self.b.get(self.pos + 1), Some(c) if c.is_ascii_digit() || *c == b'.')
        {
            self.pos += 1;
        }
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a full utf-8 character
                    let s = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = vec![];
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    /// Scientific-notation byte counts, as trace files emit them.
    #[test]
    fn parse_scientific_notation() {
        assert_eq!(Json::parse("1.2e9").unwrap(), Json::Num(1.2e9));
        assert_eq!(Json::parse("3E+8").unwrap(), Json::Num(3e8));
        assert_eq!(Json::parse("5e-3").unwrap(), Json::Num(0.005));
        assert_eq!(Json::parse("+2.5").unwrap(), Json::Num(2.5));
        assert_eq!(Json::parse("+1e2").unwrap(), Json::Num(100.0));
        let v = Json::parse(r#"{"rchar": 1.137486559e9, "wchar": 8e7}"#).unwrap();
        assert_eq!(v.get("rchar").as_f64(), Some(1.137486559e9));
        assert_eq!(v.get("wchar").as_f64(), Some(8e7));
        // malformed exponents still fail loudly
        assert!(Json::parse("1.2e").is_err());
        assert!(Json::parse("1e+").is_err());
        assert!(Json::parse("+").is_err());
        assert!(Json::parse("++1").is_err());
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.get("c"), &Json::Bool(false));
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("x"));
    }

    #[test]
    fn missing_key_is_null() {
        let v = Json::parse(r#"{"a": 1}"#).unwrap();
        assert_eq!(v.get("nope"), &Json::Null);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"obj":{"k":null},"t":true}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn pretty_roundtrip() {
        let v = Json::obj(vec![
            ("x", Json::arr_f64(&[1.0, 2.0])),
            ("y", Json::Str("z".into())),
        ]);
        let v2 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v2);
    }

    /// Whole numbers serialize without a trailing `.0` (`"events": 42`,
    /// not `42.0`) — responses are smaller and the golden-file protocol
    /// tests (`tests/service_protocol.rs`, the docs-conformance CI step)
    /// are byte-stable. Pinned here so a formatting change can't slip in.
    #[test]
    fn integers_format_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(-7.0).to_string(), "-7");
        assert_eq!(Json::Num(0.0).to_string(), "0");
        assert_eq!(Json::Num(-0.0).to_string(), "0");
        assert_eq!(Json::Num(2.5).to_string(), "2.5");
        assert_eq!(
            Json::obj(vec![("events", Json::Num(42.0))]).to_string(),
            r#"{"events":42}"#
        );
        // still parses back to the same value
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        // non-finite values stay encoded as null (documented subset)
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn as_u64_accepts_exact_integers_only() {
        assert_eq!(Json::Num(7.0).as_u64(), Some(7));
        assert_eq!(Json::Num(0.0).as_u64(), Some(0));
        assert_eq!(Json::Num(7.5).as_u64(), None);
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(f64::NAN).as_u64(), None);
        assert_eq!(Json::Num(f64::INFINITY).as_u64(), None);
        assert_eq!(Json::Num(9_007_199_254_740_992.0).as_u64(), None);
        assert_eq!(Json::Num(9_007_199_254_740_991.0).as_u64(), Some(9007199254740991));
        assert_eq!(Json::Str("7".into()).as_u64(), None);
        assert_eq!(Json::Null.as_u64(), None);
    }

    #[test]
    fn errors_reported() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"abc").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""Aß🎉""#).unwrap();
        assert_eq!(v.as_str(), Some("Aß🎉"));
        let s = Json::Str("a\"b\\c\n".into()).to_string();
        assert_eq!(Json::parse(&s).unwrap().as_str(), Some("a\"b\\c\n"));
    }
}
