//! BottleMod CLI — the leader entrypoint.
//!
//! Subcommands:
//!   analyze <spec.json>           analyze a workflow spec, print schedule +
//!                                 bottleneck segments
//!   calibrate <trace.tsv>         fit solver-ready models from a raw
//!     [--io <series.log>]         workflow trace and replay-validate them
//!     [--tol <t>]                 (formats: docs/TRACES.md)
//!   sweep [N] [--pjrt]            Fig 7 prioritization sweep (exact engine,
//!     [--workflow video|genomics] optionally also the batched PJRT path;
//!                                 --workflow picks the swept model)
//!   measure [points] [runs]       virtual-testbed measurements (Fig 7 bars)
//!   compare-des [gb ...]          §6 performance comparison table
//!   generate [--shape <s>]        seeded random topology (layered|
//!     [--seed <n>] [--nodes <n>]  scatter-gather|fan-in|chain|genomics):
//!     [--budget <p>]              generate, analyze, print schedule summary
//!                                 + content fingerprint (docs/SCALING.md)
//!   export-figures <dir>          regenerate every figure's data as JSON
//!   advisor                       recommend the link split (paper headline)
//!   sensitivity                   ranked per-knob makespan sensitivity
//!     [--workflow video|genomics] report with a confidence band: which
//!     [--spec <spec.json>]        parameter to fix first, and how sure the
//!     [--trace <trace.tsv>]       model is (docs/SENSITIVITY.md)
//!     [--io <series.log>] [--h <step>]
//!   online-demo                   online re-analysis controller demo
//!   watch <trace.tsv>             live monitor: stream the trace row by row
//!     [--io <series.log>]         through a monitor session, one JSON line
//!     [--follow] [--interval <s>] per event; --follow tails file growth
//!     [--tol <t>] [--bands]       (docs/LIVE.md); --bands adds confidence
//!                                 bands to every snapshot
//!   serve [--tcp <host:port>]     JSON-lines analysis service; stdio by
//!     [--unix <path>] [--no-stdio] default, optionally a multi-session
//!     [--threads <n>] [--queue <n>] socket server with bounded admission
//!     [--session-cache-entries <n>] and per-session cache quotas
//!     [--session-cache-mb <n>]    (wire protocol: docs/SERVICE.md)
//!   artifacts                     list loadable PJRT artifacts
//!
//! (argument parsing is hand-rolled: the offline vendor set has no clap)

use std::process::ExitCode;

use bottlemod::api::{encode_v1, ApiHandler, Request, Response, WorkflowSel};
use bottlemod::coordinator::exporter;
use bottlemod::coordinator::service::{pump_lines, serve_stdio};
use bottlemod::coordinator::sweeper::fig7_fractions;
use bottlemod::coordinator::{ServeOpts, Server};
use bottlemod::runtime::Runtime;
use bottlemod::sched;
use bottlemod::solver::SolverOpts;
use bottlemod::testbed::video::VideoTestbed;
use bottlemod::util::error::{Error, Result};
use bottlemod::util::stats::{ascii_table, fmt_duration, Summary};
use bottlemod::workflow::engine::analyze_fixpoint;
use bottlemod::workflow::scenario::{Perturbation, VideoScenario};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest = &args[1.min(args.len())..];
    let result = match cmd {
        "analyze" => cmd_analyze(rest),
        "calibrate" => cmd_calibrate(rest),
        "sweep" => cmd_sweep(rest),
        "measure" => cmd_measure(rest),
        "compare-des" => cmd_compare_des(rest),
        "generate" => cmd_generate(rest),
        "export-figures" => cmd_export(rest),
        "advisor" => cmd_advisor(),
        "sensitivity" => cmd_sensitivity(rest),
        "online-demo" => cmd_online(),
        "watch" => cmd_watch(rest),
        "serve" => cmd_serve(rest),
        "artifacts" => cmd_artifacts(),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'");
            print_help();
            return ExitCode::from(2);
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn print_help() {
    println!(
        "bottlemod — fast bottleneck analysis for scientific workflows\n\
         usage: bottlemod <analyze|calibrate|sweep|measure|compare-des|generate|\
         export-figures|advisor|sensitivity|online-demo|watch|serve|artifacts> [args]\n\
         calibrate: bottlemod calibrate <trace.tsv> [--io <series.log>] [--tol <t>]\n\
         sensitivity: bottlemod sensitivity [--workflow video|genomics] [--spec <spec.json>]\n\
         \x20      [--trace <trace.tsv>] [--io <series.log>] [--h <step>]\n\
         watch: bottlemod watch <trace.tsv> [--io <series.log>] [--follow]\n\
         \x20      [--interval <secs>] [--tol <t>] [--bands]\n\
         generate: bottlemod generate [--shape layered|scatter-gather|fan-in|chain|\
         genomics] [--seed <n>] [--nodes <n>] [--budget <pieces>]\n\
         sweep: bottlemod sweep [N] [--workflow video|genomics] [--pjrt]\n\
         serve: bottlemod serve [--tcp <host:port>] [--unix <path>] [--no-stdio]\n\
         \x20      [--threads <n>] [--queue <n>] [--session-cache-entries <n>]\n\
         \x20      [--session-cache-mb <n>]"
    );
}

/// All JSON-speaking subcommands (`analyze`, `calibrate`, `sweep`) build a
/// typed [`Request`] and delegate to the same [`ApiHandler`] the service
/// runs on — the CLI does no spec parsing or response assembly of its own.
fn cmd_analyze(args: &[String]) -> Result<()> {
    let path = args
        .first()
        .ok_or_else(|| Error::msg("usage: bottlemod analyze <spec.json>"))?;
    let text = std::fs::read_to_string(path)?;
    let t0 = std::time::Instant::now();
    let res = match ApiHandler::new().handle(&Request::Analyze { spec: text })? {
        Response::Analyze(r) => r,
        other => return Err(Error::msg(format!("unexpected response {other:?}"))),
    };
    let dt = t0.elapsed().as_secs_f64();

    let mut rows = vec![vec![
        "process".to_string(),
        "start".to_string(),
        "finish".to_string(),
        "bottlenecks over time".to_string(),
    ]];
    for row in &res.schedule {
        let segs = res
            .bottlenecks
            .iter()
            .filter(|s| s.process == row.name)
            .map(|s| format!("[{:.1}-{:.1}] {}", s.start, s.end.min(1e9), s.bottleneck))
            .collect::<Vec<_>>()
            .join(", ");
        rows.push(vec![
            row.name.clone(),
            format!("{:.2}", row.start),
            row.finish
                .map(|f| format!("{f:.2}"))
                .unwrap_or_else(|| "never".into()),
            segs,
        ]);
    }
    print!("{}", ascii_table(&rows));
    match res.makespan {
        Some(m) => println!("makespan: {m:.2} s"),
        None => println!("makespan: never finishes"),
    }
    println!(
        "analysis: {} ({} events, {} passes)",
        fmt_duration(dt),
        res.events,
        res.passes
    );
    Ok(())
}

fn cmd_calibrate(args: &[String]) -> Result<()> {
    let usage = "usage: bottlemod calibrate <trace.tsv> [--io <series.log>] [--tol <t>]";
    let mut tsv_path: Option<&String> = None;
    let mut io_path: Option<&String> = None;
    let mut tol: Option<f64> = None;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--io" => {
                io_path = Some(
                    args.get(i + 1)
                        .ok_or_else(|| Error::msg(format!("--io needs a path\n{usage}")))?,
                );
                i += 2;
            }
            "--tol" => {
                tol = Some(
                    args.get(i + 1)
                        .and_then(|a| a.parse().ok())
                        .ok_or_else(|| Error::msg(format!("--tol needs a number\n{usage}")))?,
                );
                i += 2;
            }
            a if !a.starts_with("--") => {
                if tsv_path.is_none() {
                    tsv_path = Some(&args[i]);
                } else {
                    return Err(Error::msg(format!("unexpected argument '{a}'\n{usage}")));
                }
                i += 1;
            }
            other => {
                return Err(Error::msg(format!("unknown flag '{other}'\n{usage}")));
            }
        }
    }
    let tsv_path = tsv_path.ok_or_else(|| Error::msg(usage))?;
    let tsv = std::fs::read_to_string(tsv_path)?;
    let io = match io_path {
        Some(p) => Some(std::fs::read_to_string(p)?),
        None => None,
    };
    let t0 = std::time::Instant::now();
    let res = match ApiHandler::new().handle(&Request::Calibrate { tsv, io, tol })? {
        Response::Calibrate(r) => r,
        other => return Err(Error::msg(format!("unexpected response {other:?}"))),
    };
    let dt = t0.elapsed().as_secs_f64();

    let fmt_opt = |x: Option<f64>| x.map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into());
    let mut rows = vec![vec![
        "task".to_string(),
        "model".to_string(),
        "R_D/R_R pieces".to_string(),
        "observed".to_string(),
        "predicted".to_string(),
        "err %".to_string(),
    ]];
    for s in &res.tasks {
        rows.push(vec![
            s.id.clone(),
            s.model.clone(),
            format!("{}/{}", s.data_pieces, s.res_pieces),
            fmt_opt(s.observed),
            fmt_opt(s.predicted),
            s.rel_err
                .map(|e| format!("{:.2}", e * 100.0))
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    print!("{}", ascii_table(&rows));
    println!(
        "calibrated {} task(s) in {}; predicted makespan {} (observed {})",
        res.tasks.len(),
        fmt_duration(dt),
        fmt_opt(res.predicted_makespan),
        fmt_opt(res.observed_makespan),
    );
    match res.max_rel_err {
        Some(e) => println!("worst per-task completion error: {:.2}%", e * 100.0),
        None => println!("trace logs no completion times; replay error unavailable"),
    }
    Ok(())
}

fn cmd_sweep(args: &[String]) -> Result<()> {
    let n: usize = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .and_then(|a| a.parse().ok())
        .unwrap_or(600);
    let use_pjrt = args.iter().any(|a| a == "--pjrt");
    let workflow = match args.iter().position(|a| a == "--workflow") {
        None => WorkflowSel::Video,
        Some(i) => match args.get(i + 1).map(String::as_str) {
            Some("video") => WorkflowSel::Video,
            Some("genomics") => WorkflowSel::Genomics,
            other => {
                return Err(Error::msg(format!(
                    "--workflow needs 'video' or 'genomics', got {other:?}"
                )))
            }
        },
    };
    let is_video = workflow == WorkflowSel::Video;
    let fractions = fig7_fractions(n);
    let threads = bottlemod::util::par::num_threads();

    let t0 = std::time::Instant::now();
    let req = Request::Sweep {
        workflow,
        perturbations: fractions.iter().map(|&f| Perturbation::Fraction(f)).collect(),
    };
    let res = match ApiHandler::new().handle(&req)? {
        Response::Sweep(r) => r,
        other => return Err(Error::msg(format!("unexpected response {other:?}"))),
    };
    let exact_dt = t0.elapsed().as_secs_f64();
    println!(
        "exact sweep: {n} configs of the '{}' workflow on {threads} threads in {} ({} per analysis, {} events total)",
        res.workflow,
        fmt_duration(exact_dt),
        fmt_duration(exact_dt / n as f64),
        res.events
    );
    if let Some(stats) = &res.cache {
        println!("analysis cache: {stats}");
    }

    // print a compact table at decile fractions
    let mut rows = vec![vec!["fraction".to_string(), "predicted total (s)".to_string()]];
    for i in (0..n).step_by((n / 10).max(1)) {
        rows.push(vec![
            format!("{:.3}", fractions[i]),
            res.makespans[i]
                .map(|t| format!("{t:.2}"))
                .unwrap_or_else(|| "never".into()),
        ]);
    }
    print!("{}", ascii_table(&rows));

    // ranked cross-scenario bottleneck report
    let mut rows = vec![vec![
        "process".to_string(),
        "bottleneck".to_string(),
        "total limited (s)".to_string(),
        "scenarios".to_string(),
    ]];
    for r in res.ranked.iter().take(8) {
        rows.push(vec![
            r.process.clone(),
            r.bottleneck.clone(),
            format!("{:.1}", r.total_seconds),
            format!("{}/{}", r.scenarios, n),
        ]);
    }
    println!("top bottlenecks across the batch:");
    print!("{}", ascii_table(&rows));

    if use_pjrt && !is_video {
        println!("(--pjrt compares against the video artifacts; skipped for this workflow)");
    }
    if use_pjrt && is_video {
        let sc = VideoScenario::default();
        let mut rt = Runtime::new(&Runtime::default_dir())?;
        let t0 = std::time::Instant::now();
        let batched = bottlemod::runtime::fig7_sweep(&mut rt, &sc, &fractions)?;
        let dt = t0.elapsed().as_secs_f64();
        let max_err = res
            .makespans
            .iter()
            .map(|m| m.unwrap_or(f64::INFINITY))
            .zip(&batched.totals)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        println!(
            "pjrt batched sweep: {} total ({} per config), max |Δ| vs exact: {:.2} s",
            fmt_duration(dt),
            fmt_duration(dt / n as f64),
            max_err
        );
    }
    Ok(())
}

/// `bottlemod serve` with no flags is the legacy single-session stdio
/// service, byte-for-byte unchanged. Any flag switches to the
/// multi-session server: sockets via `--tcp`/`--unix`, a shared worker
/// pool with bounded admission (`--threads`, `--queue`), and per-session
/// cache quotas (`--session-cache-entries`, `--session-cache-mb`). Stdio
/// stays served as one more session unless `--no-stdio`; stdin EOF then
/// drains the whole server gracefully.
fn cmd_serve(args: &[String]) -> Result<()> {
    if args.is_empty() {
        let stdin = std::io::stdin();
        return serve_stdio(stdin.lock(), std::io::stdout());
    }
    let usage = "usage: bottlemod serve [--tcp <host:port>] [--unix <path>] [--no-stdio] \
                 [--threads <n>] [--queue <n>] [--session-cache-entries <n>] \
                 [--session-cache-mb <n>]";
    let num = |i: usize, flag: &str| -> Result<usize> {
        args.get(i + 1)
            .and_then(|a| a.parse().ok())
            .ok_or_else(|| Error::msg(format!("{flag} needs a positive number\n{usage}")))
    };
    let mut tcp: Option<&String> = None;
    let mut unix: Option<&String> = None;
    let mut no_stdio = false;
    let mut opts = ServeOpts::default();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--tcp" => {
                tcp = Some(
                    args.get(i + 1)
                        .ok_or_else(|| Error::msg(format!("--tcp needs host:port\n{usage}")))?,
                );
                i += 2;
            }
            "--unix" => {
                unix = Some(
                    args.get(i + 1)
                        .ok_or_else(|| Error::msg(format!("--unix needs a path\n{usage}")))?,
                );
                i += 2;
            }
            "--no-stdio" => {
                no_stdio = true;
                i += 1;
            }
            "--threads" => {
                opts.threads = num(i, "--threads")?.max(1);
                i += 2;
            }
            "--queue" => {
                opts.queue_bound = num(i, "--queue")?.max(1);
                i += 2;
            }
            "--session-cache-entries" => {
                opts.session_cache_entries = num(i, "--session-cache-entries")?.max(1);
                i += 2;
            }
            "--session-cache-mb" => {
                opts.session_cache_bytes = (num(i, "--session-cache-mb")? as u64) << 20;
                i += 2;
            }
            other => {
                return Err(Error::msg(format!("unknown flag '{other}'\n{usage}")));
            }
        }
    }
    if no_stdio && tcp.is_none() && unix.is_none() {
        return Err(Error::msg(format!(
            "--no-stdio needs at least one socket transport\n{usage}"
        )));
    }
    #[cfg(not(unix))]
    if unix.is_some() {
        return Err(Error::msg("--unix needs a unix platform; use --tcp here"));
    }
    let mut server = Server::new(opts);
    if let Some(addr) = tcp {
        let bound = server.listen_tcp(addr)?;
        eprintln!("listening on tcp {bound}");
    }
    #[cfg(unix)]
    if let Some(path) = unix {
        server.listen_unix(path)?;
        eprintln!("listening on unix socket {path}");
    }
    if no_stdio {
        server.join();
        return Ok(());
    }
    let handler = server.session_handler();
    let stdin = std::io::stdin();
    let mut stdout = std::io::stdout();
    pump_lines(&handler, stdin.lock(), &mut stdout)?;
    drop(handler);
    server.shutdown(); // stdin EOF: drain sockets and the pool too
    Ok(())
}

fn cmd_measure(args: &[String]) -> Result<()> {
    let points: usize = args.first().and_then(|a| a.parse().ok()).unwrap_or(13);
    let runs: usize = args.get(1).and_then(|a| a.parse().ok()).unwrap_or(10);
    let mut rows = vec![vec![
        "fraction".to_string(),
        "mean (s)".to_string(),
        "min".to_string(),
        "max".to_string(),
        "predicted".to_string(),
    ]];
    for i in 0..points {
        let f = (i + 1) as f64 / (points + 1) as f64;
        let sc = VideoScenario::default().with_fraction(f);
        let tb = VideoTestbed::new(sc.clone());
        let samples = tb.measure(runs, 4242 + i as u64, 0.01);
        let s = Summary::of(&samples);
        let (wf, _) = sc.build();
        let pred = analyze_fixpoint(&wf, &SolverOpts::default(), 6)?
            .makespan
            .unwrap_or(f64::NAN);
        rows.push(vec![
            format!("{f:.3}"),
            format!("{:.2}", s.mean),
            format!("{:.2}", s.min),
            format!("{:.2}", s.max),
            format!("{pred:.2}"),
        ]);
    }
    print!("{}", ascii_table(&rows));
    Ok(())
}

fn cmd_compare_des(args: &[String]) -> Result<()> {
    let sizes: Vec<f64> = if args.is_empty() {
        vec![1.1, 10.0, 100.0]
    } else {
        args.iter().filter_map(|a| a.parse().ok()).collect()
    };
    let dir = std::env::temp_dir().join("bottlemod_sec6");
    std::fs::create_dir_all(&dir)?;
    let rows = exporter::sec6(&dir, &sizes, 3)?;
    print!("{}", ascii_table(&rows));
    println!("(BottleMod cost is flat in input size; the DES scales — §6)");
    Ok(())
}

/// Generate a seeded random topology (docs/SCALING.md), analyze it with
/// the worklist fixpoint, and print a compact summary plus the content
/// fingerprint (same seed + shape + nodes → same fingerprint, anywhere).
fn cmd_generate(args: &[String]) -> Result<()> {
    use bottlemod::workflow::generator::{fingerprint, generate, GeneratorOpts, Topology};

    let usage = "usage: bottlemod generate [--shape layered|scatter-gather|fan-in|chain|\
                 genomics] [--seed <n>] [--nodes <n>] [--budget <pieces>]";
    let mut shape = Topology::Layered;
    let mut seed: u64 = 0;
    let mut nodes: usize = 50;
    let mut budget: usize = 0;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--shape" => {
                let s = args
                    .get(i + 1)
                    .ok_or_else(|| Error::msg(format!("--shape needs a value\n{usage}")))?;
                shape = Topology::parse(s)
                    .ok_or_else(|| Error::msg(format!("unknown shape '{s}'\n{usage}")))?;
                i += 2;
            }
            "--seed" => {
                seed = args
                    .get(i + 1)
                    .and_then(|a| a.parse().ok())
                    .ok_or_else(|| Error::msg(format!("--seed needs a number\n{usage}")))?;
                i += 2;
            }
            "--nodes" => {
                nodes = args
                    .get(i + 1)
                    .and_then(|a| a.parse().ok())
                    .ok_or_else(|| Error::msg(format!("--nodes needs a number\n{usage}")))?;
                i += 2;
            }
            "--budget" => {
                budget = args
                    .get(i + 1)
                    .and_then(|a| a.parse().ok())
                    .ok_or_else(|| Error::msg(format!("--budget needs a number\n{usage}")))?;
                i += 2;
            }
            other => return Err(Error::msg(format!("unknown flag '{other}'\n{usage}"))),
        }
    }

    let gopts = GeneratorOpts {
        topology: shape,
        width_jitter: 0.2,
        pool_residual_prob: 0.3,
        ..GeneratorOpts::default()
    }
    .target_nodes(nodes);
    let mut rng = bottlemod::util::Rng::new(seed);
    let wf = generate(&mut rng, &gopts);
    wf.validate().map_err(|e| Error::msg(e.to_string()))?;
    let fp = fingerprint(&wf);

    let opts = SolverOpts {
        piece_budget: budget,
        piece_budget_err: if budget > 0 { 1e-6 } else { 0.0 },
        ..SolverOpts::default()
    };
    let t0 = std::time::Instant::now();
    let wa = analyze_fixpoint(&wf, &opts, 8).map_err(|e| Error::msg(e.to_string()))?;
    let dt = t0.elapsed().as_secs_f64();

    println!(
        "shape {} seed {seed}: {} nodes, {} pool(s)  fingerprint {fp:032x}",
        shape.name(),
        wf.nodes.len(),
        wf.pools.len()
    );
    match wa.makespan {
        Some(m) => println!("makespan: {m:.2} s"),
        None => println!("makespan: never finishes"),
    }
    println!(
        "analysis: {} ({} events, {} passes{})",
        fmt_duration(dt),
        wa.events,
        wa.passes,
        if budget > 0 {
            format!(", piece budget {budget}, error bound {:.2e}", wa.budget_err)
        } else {
            String::new()
        }
    );
    Ok(())
}

fn cmd_export(args: &[String]) -> Result<()> {
    let dir = args
        .first()
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| "figures".into());
    exporter::export_all(&dir)
}

fn cmd_advisor() -> Result<()> {
    let threads = bottlemod::util::par::num_threads();
    let rec = sched::recommend(&VideoScenario::default(), 200, threads);
    println!(
        "recommended link fraction for task 1's download: {:.3}\n\
         predicted total: {:.1} s (fair 50:50: {:.1} s) — {:.1}% faster",
        rec.best_fraction,
        rec.best_total,
        rec.fair_total,
        rec.gain * 100.0
    );
    Ok(())
}

/// `bottlemod sensitivity` runs the `sensitivity` API op
/// (docs/SENSITIVITY.md) against a built-in scenario, an inline spec, or a
/// trace-calibrated model, and prints the ranked fix-this-first table plus
/// the makespan confidence band.
fn cmd_sensitivity(args: &[String]) -> Result<()> {
    let usage = "usage: bottlemod sensitivity [--workflow video|genomics] [--spec <spec.json>] \
                 [--trace <trace.tsv>] [--io <series.log>] [--h <step>]";
    let mut workflow: Option<WorkflowSel> = None;
    let mut spec_path: Option<&String> = None;
    let mut trace_path: Option<&String> = None;
    let mut io_path: Option<&String> = None;
    let mut h: Option<f64> = None;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--workflow" => {
                workflow = match args.get(i + 1).map(String::as_str) {
                    Some("video") => Some(WorkflowSel::Video),
                    Some("genomics") => Some(WorkflowSel::Genomics),
                    other => {
                        return Err(Error::msg(format!(
                            "--workflow needs 'video' or 'genomics', got {other:?}\n{usage}"
                        )))
                    }
                };
                i += 2;
            }
            "--spec" => {
                spec_path = Some(
                    args.get(i + 1)
                        .ok_or_else(|| Error::msg(format!("--spec needs a path\n{usage}")))?,
                );
                i += 2;
            }
            "--trace" => {
                trace_path = Some(
                    args.get(i + 1)
                        .ok_or_else(|| Error::msg(format!("--trace needs a path\n{usage}")))?,
                );
                i += 2;
            }
            "--io" => {
                io_path = Some(
                    args.get(i + 1)
                        .ok_or_else(|| Error::msg(format!("--io needs a path\n{usage}")))?,
                );
                i += 2;
            }
            "--h" => {
                h = Some(
                    args.get(i + 1)
                        .and_then(|a| a.parse::<f64>().ok())
                        .filter(|v| v.is_finite() && *v > 0.0)
                        .ok_or_else(|| {
                            Error::msg(format!("--h needs a positive number\n{usage}"))
                        })?,
                );
                i += 2;
            }
            other => return Err(Error::msg(format!("unknown flag '{other}'\n{usage}"))),
        }
    }
    let sel = match (spec_path, trace_path) {
        (Some(_), Some(_)) => {
            return Err(Error::msg(format!("--spec and --trace are exclusive\n{usage}")))
        }
        (Some(p), None) => WorkflowSel::Spec(std::fs::read_to_string(p)?),
        (None, Some(p)) => WorkflowSel::Trace {
            tsv: std::fs::read_to_string(p)?,
            io: match io_path {
                Some(q) => Some(std::fs::read_to_string(q)?),
                None => None,
            },
        },
        (None, None) => workflow.unwrap_or(WorkflowSel::Video),
    };

    let t0 = std::time::Instant::now();
    let rep = match ApiHandler::new().handle(&Request::Sensitivity { workflow: sel, h })? {
        Response::Sensitivity(r) => r,
        other => return Err(Error::msg(format!("unexpected response {other:?}"))),
    };
    let dt = t0.elapsed().as_secs_f64();

    let fmt_opt = |x: Option<f64>| x.map(|v| format!("{v:.4}")).unwrap_or_else(|| "-".into());
    let mut rows = vec![vec![
        "#".to_string(),
        "knob".to_string(),
        "d makespan/d knob".to_string(),
        "closed form".to_string(),
        "gain/unit (s)".to_string(),
        "direction".to_string(),
        "uncertainty".to_string(),
        "notes".to_string(),
    ]];
    for (rank, k) in rep.knobs.iter().enumerate() {
        let mut notes: Vec<&str> = Vec::new();
        if k.insensitive {
            notes.push("insensitive");
        }
        if k.non_smooth {
            notes.push("non-smooth");
        }
        let notes = if notes.is_empty() {
            k.attribution
                .first()
                .map(|a| format!("{} <- {}", a.process, a.bottleneck))
                .unwrap_or_default()
        } else {
            notes.join(", ")
        };
        rows.push(vec![
            format!("{}", rank + 1),
            k.kind.to_string(),
            fmt_opt(k.derivative),
            fmt_opt(k.closed_form),
            format!("{:.4}", k.gain_per_unit),
            k.direction.to_string(),
            format!("{:.4}", k.uncertainty),
            notes,
        ]);
    }
    print!("{}", ascii_table(&rows));
    println!(
        "workflow '{}': makespan {:.2} s, band [{:.2}, {:.2}]{}",
        rep.workflow,
        rep.makespan,
        rep.band.lower,
        rep.band.upper,
        if rep.band.is_point() {
            " (point estimate: no calibration residuals)"
        } else {
            ""
        }
    );
    println!(
        "sensitivity analysis: {} ({} solver events)",
        fmt_duration(dt),
        rep.events
    );
    if let Some(stats) = &rep.cache {
        println!("analysis cache: {stats}");
    }
    Ok(())
}

fn cmd_online() -> Result<()> {
    let sc = VideoScenario::default();
    let static_fair = sched::run_online(&sc, 1e9, &[0.5]);
    let candidates: Vec<f64> = (1..=19).map(|i| i as f64 / 20.0).collect();
    let online = sched::run_online(&sc, 10.0, &candidates);
    println!("static fair share: {:.1} s", static_fair.total);
    println!(
        "online re-analysis (replan every 10 s): {:.1} s ({:.1}% faster, model overhead {})",
        online.total,
        (1.0 - online.total / static_fair.total) * 100.0,
        fmt_duration(online.analysis_seconds)
    );
    for d in online.decisions.iter().take(8) {
        println!(
            "  t={:>6.1}s -> fraction {:.2} (predicted remaining {:.1} s)",
            d.t, d.fraction, d.predicted_remaining
        );
    }
    Ok(())
}

/// `bottlemod watch` replays a trace file through a live monitor session
/// (docs/LIVE.md): the header opens the session, then one `monitor_feed`
/// per TSV row, printing one v1 JSON-lines envelope per event — exactly
/// what a `serve` client would see. `--follow` keeps tailing both files
/// for complete new lines until interrupted; without it the session is
/// closed with a final `monitor_status` once the files are drained.
fn cmd_watch(args: &[String]) -> Result<()> {
    let usage = "usage: bottlemod watch <trace.tsv> [--io <series.log>] [--follow] \
                 [--interval <secs>] [--tol <t>] [--bands]";
    let mut tsv_path: Option<&String> = None;
    let mut io_path: Option<&String> = None;
    let mut follow = false;
    let mut bands = false;
    let mut interval = 1.0f64;
    let mut tol: Option<f64> = None;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--io" => {
                io_path = Some(
                    args.get(i + 1)
                        .ok_or_else(|| Error::msg(format!("--io needs a path\n{usage}")))?,
                );
                i += 2;
            }
            "--follow" => {
                follow = true;
                i += 1;
            }
            "--bands" => {
                bands = true;
                i += 1;
            }
            "--interval" => {
                interval = args
                    .get(i + 1)
                    .and_then(|a| a.parse::<f64>().ok())
                    .filter(|s| s.is_finite() && *s > 0.0)
                    .ok_or_else(|| {
                        Error::msg(format!("--interval needs a positive number\n{usage}"))
                    })?;
                i += 2;
            }
            "--tol" => {
                tol = Some(
                    args.get(i + 1)
                        .and_then(|a| a.parse().ok())
                        .ok_or_else(|| Error::msg(format!("--tol needs a number\n{usage}")))?,
                );
                i += 2;
            }
            a if !a.starts_with("--") => {
                if tsv_path.is_none() {
                    tsv_path = Some(&args[i]);
                } else {
                    return Err(Error::msg(format!("unexpected argument '{a}'\n{usage}")));
                }
                i += 1;
            }
            other => return Err(Error::msg(format!("unknown flag '{other}'\n{usage}"))),
        }
    }
    let tsv_path = tsv_path.ok_or_else(|| Error::msg(usage))?;
    let pause = std::time::Duration::from_secs_f64(interval);

    // in follow mode a line is only real once its newline lands; a
    // half-written row must not be fed as an event
    let complete_lines = |text: &str| -> Vec<String> {
        let mut lines: Vec<String> = text.lines().map(str::to_string).collect();
        if follow && !text.is_empty() && !text.ends_with('\n') {
            lines.pop();
        }
        lines
    };
    let is_content = |l: &str| {
        let t = l.trim();
        !t.is_empty() && !t.starts_with('#')
    };

    // the header line opens the session; with --follow, wait for it
    let (header, mut tsv_consumed) = loop {
        let lines = complete_lines(&std::fs::read_to_string(tsv_path)?);
        match lines.iter().position(|l| is_content(l)) {
            Some(at) => break (lines[at].clone(), at + 1),
            None if follow => std::thread::sleep(pause),
            None => return Err(Error::msg("trace has no header line to open a monitor with")),
        }
    };

    let handler = ApiHandler::new();
    let mut next_id: u64 = 0;
    // every envelope a serve client would see, one line each; feed errors
    // are printed too (the monitor rejects bad input atomically, so the
    // session survives them)
    let mut send = |req: Request| -> bool {
        next_id += 1;
        let outcome = handler.handle(&req);
        let ok = outcome.is_ok();
        println!("{}", encode_v1(Some(next_id), &outcome));
        ok
    };

    let opened = send(Request::MonitorOpen {
        workflow: WorkflowSel::Trace {
            tsv: format!("{header}\n"),
            io: None,
        },
        tol,
        bands,
    });
    if !opened {
        return Err(Error::msg("monitor_open failed"));
    }

    let mut io_consumed = 0usize;
    loop {
        let lines = complete_lines(&std::fs::read_to_string(tsv_path)?);
        for line in lines.iter().skip(tsv_consumed) {
            if is_content(line) {
                send(Request::MonitorFeed {
                    tsv: Some(format!("{line}\n")),
                    io: None,
                });
            }
        }
        tsv_consumed = tsv_consumed.max(lines.len());

        if let Some(p) = io_path {
            // the I/O log may lag the trace (or not exist yet) in follow
            // mode; new samples land as one event per poll
            let text = match std::fs::read_to_string(p) {
                Ok(t) => t,
                Err(_) if follow => String::new(),
                Err(e) => return Err(e.into()),
            };
            let lines = complete_lines(&text);
            let fresh: Vec<String> = lines
                .iter()
                .skip(io_consumed)
                .filter(|l| is_content(l))
                .cloned()
                .collect();
            if !fresh.is_empty() {
                send(Request::MonitorFeed {
                    tsv: None,
                    io: Some(format!("{}\n", fresh.join("\n"))),
                });
            }
            io_consumed = io_consumed.max(lines.len());
        }

        if !follow {
            break;
        }
        std::thread::sleep(pause);
    }

    send(Request::MonitorStatus { close: true });
    Ok(())
}

fn cmd_artifacts() -> Result<()> {
    let rt = Runtime::new(&Runtime::default_dir())?;
    let mut names = rt.names();
    names.sort();
    for n in names {
        let info = rt.info(n).unwrap();
        println!("{n}: inputs {:?}", info.inputs);
    }
    Ok(())
}
