//! Workflow DAG structure (paper §3.4).
//!
//! A workflow is a set of processes whose data inputs are wired either to
//! external input functions or to the *output-over-time* functions
//! `O_m(P(t))` of predecessor processes, and whose resources come from fixed
//! allocations or shared pools. Start rules express barrier edges ("task 3
//! is started after both task 1 and 2 are completed", §5.1).

use crate::model::process::Process;
use crate::pwfn::PwPoly;

/// Where a process's data input `k` comes from.
#[derive(Clone, Debug)]
pub enum DataSource {
    /// An exogenous cumulative input function `I_Dk(t)`.
    External(PwPoly),
    /// The output-over-time function `O_m(P(t))` of another node — the
    /// paper's chaining mechanism.
    ProcessOutput { node: usize, output: usize },
}

/// Where a process's resource input `l` comes from.
#[derive(Clone, Debug)]
pub enum ResourceSource {
    /// A fixed allocation function `I_Rl(t)`.
    Fixed(PwPoly),
    /// A static fraction of a shared pool's capacity.
    PoolFraction { pool: usize, fraction: f64 },
    /// Whatever the pool has left after all *previously analyzed* users'
    /// actual consumption is subtracted (the paper's §5.2 retrospective
    /// reassignment: task 2's download gets "the difference between the
    /// known maximum data rate and the data rate of task 1's download").
    PoolResidual { pool: usize },
}

/// When a node may begin.
#[derive(Clone, Debug, Default)]
pub struct StartRule {
    /// Earliest wall-clock start.
    pub at: f64,
    /// Barrier predecessors: start only after all of these finished.
    pub after: Vec<usize>,
}

/// One workflow node: a process plus its input wiring.
#[derive(Clone, Debug)]
pub struct Node {
    pub process: Process,
    pub data_sources: Vec<DataSource>,
    pub resource_sources: Vec<ResourceSource>,
    pub start: StartRule,
}

/// A shared resource pool (e.g. the 100 Mbit/s link of Fig 5).
#[derive(Clone, Debug)]
pub struct Pool {
    pub name: String,
    /// Capacity as a rate function of time.
    pub capacity: PwPoly,
}

/// The workflow DAG.
#[derive(Clone, Debug, Default)]
pub struct Workflow {
    pub nodes: Vec<Node>,
    pub pools: Vec<Pool>,
}

/// Graph-structure error.
#[derive(Debug, Clone)]
pub enum GraphError {
    Cycle(usize),
    BadRef {
        node: usize,
        what: &'static str,
        index: usize,
    },
    BadNode { node: usize, msg: String },
}

impl std::fmt::Display for GraphError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GraphError::Cycle(n) => {
                write!(f, "workflow has a dependency cycle involving node {n}")
            }
            GraphError::BadRef { node, what, index } => {
                write!(f, "node {node} references missing {what} {index}")
            }
            GraphError::BadNode { node, msg } => write!(f, "node {node}: {msg}"),
        }
    }
}

impl std::error::Error for GraphError {}

impl Workflow {
    pub fn new() -> Self {
        Workflow::default()
    }

    /// Register a shared pool, returning its id.
    pub fn add_pool(&mut self, name: &str, capacity: PwPoly) -> usize {
        self.pools.push(Pool {
            name: name.to_string(),
            capacity,
        });
        self.pools.len() - 1
    }

    /// Add a node, returning its id.
    pub fn add_node(
        &mut self,
        process: Process,
        data_sources: Vec<DataSource>,
        resource_sources: Vec<ResourceSource>,
        start: StartRule,
    ) -> usize {
        self.nodes.push(Node {
            process,
            data_sources,
            resource_sources,
            start,
        });
        self.nodes.len() - 1
    }

    /// All hard dependencies of node `i` (data-producing predecessors and
    /// barrier predecessors).
    pub fn deps(&self, i: usize) -> Vec<usize> {
        let mut out: Vec<usize> = self.nodes[i]
            .data_sources
            .iter()
            .filter_map(|s| match s {
                DataSource::ProcessOutput { node, .. } => Some(*node),
                _ => None,
            })
            .collect();
        out.extend(&self.nodes[i].start.after);
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Validate wiring: arities match, references are in range.
    pub fn validate(&self) -> Result<(), GraphError> {
        for (i, n) in self.nodes.iter().enumerate() {
            if n.data_sources.len() != n.process.data_reqs.len() {
                return Err(GraphError::BadNode {
                    node: i,
                    msg: format!(
                        "{} data sources for {} data requirements",
                        n.data_sources.len(),
                        n.process.data_reqs.len()
                    ),
                });
            }
            if n.resource_sources.len() != n.process.res_reqs.len() {
                return Err(GraphError::BadNode {
                    node: i,
                    msg: format!(
                        "{} resource sources for {} resource requirements",
                        n.resource_sources.len(),
                        n.process.res_reqs.len()
                    ),
                });
            }
            for s in &n.data_sources {
                if let DataSource::ProcessOutput { node, output } = s {
                    if *node >= self.nodes.len() {
                        return Err(GraphError::BadRef {
                            node: i,
                            what: "node",
                            index: *node,
                        });
                    }
                    if *output >= self.nodes[*node].process.outputs.len() {
                        return Err(GraphError::BadRef {
                            node: i,
                            what: "output",
                            index: *output,
                        });
                    }
                }
            }
            for s in &n.resource_sources {
                let pool = match s {
                    ResourceSource::PoolFraction { pool, .. } => Some(*pool),
                    ResourceSource::PoolResidual { pool } => Some(*pool),
                    ResourceSource::Fixed(_) => None,
                };
                if let Some(p) = pool {
                    if p >= self.pools.len() {
                        return Err(GraphError::BadRef {
                            node: i,
                            what: "pool",
                            index: p,
                        });
                    }
                }
            }
            for &a in &n.start.after {
                if a >= self.nodes.len() {
                    return Err(GraphError::BadRef {
                        node: i,
                        what: "node",
                        index: a,
                    });
                }
            }
        }
        self.topo_order().map(|_| ())
    }

    /// Topological order (Kahn); `Err` on cycles. Ties resolve in node-id
    /// order, which keeps pool residual-assignment deterministic.
    pub fn topo_order(&self) -> Result<Vec<usize>, GraphError> {
        let n = self.nodes.len();
        let mut indeg = vec![0usize; n];
        let mut succ: Vec<Vec<usize>> = vec![vec![]; n];
        for i in 0..n {
            for d in self.deps(i) {
                indeg[i] += 1;
                succ[d].push(i);
            }
        }
        let mut ready: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        while let Some(&i) = ready.first() {
            // pop the smallest id (ready is kept sorted)
            ready.remove(0);
            order.push(i);
            for &s in &succ[i] {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    let pos = ready.binary_search(&s).unwrap_or_else(|e| e);
                    ready.insert(pos, s);
                }
            }
        }
        if order.len() != n {
            let stuck = (0..n).find(|&i| indeg[i] > 0).unwrap();
            return Err(GraphError::Cycle(stuck));
        }
        Ok(order)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ProcessBuilder;

    fn simple_proc(name: &str) -> Process {
        ProcessBuilder::new(name, 10.0)
            .stream_data("in", 10.0)
            .identity_output("out")
            .build()
    }

    #[test]
    fn topo_order_chain() {
        let mut wf = Workflow::new();
        let a = wf.add_node(
            simple_proc("a"),
            vec![DataSource::External(PwPoly::constant(10.0))],
            vec![],
            StartRule::default(),
        );
        let b = wf.add_node(
            simple_proc("b"),
            vec![DataSource::ProcessOutput { node: a, output: 0 }],
            vec![],
            StartRule::default(),
        );
        let c = wf.add_node(
            simple_proc("c"),
            vec![DataSource::ProcessOutput { node: b, output: 0 }],
            vec![],
            StartRule::default(),
        );
        assert_eq!(wf.topo_order().unwrap(), vec![a, b, c]);
        assert!(wf.validate().is_ok());
    }

    #[test]
    fn cycle_detected() {
        let mut wf = Workflow::new();
        wf.add_node(
            simple_proc("a"),
            vec![DataSource::ProcessOutput { node: 1, output: 0 }],
            vec![],
            StartRule::default(),
        );
        wf.add_node(
            simple_proc("b"),
            vec![DataSource::ProcessOutput { node: 0, output: 0 }],
            vec![],
            StartRule::default(),
        );
        assert!(matches!(wf.validate(), Err(GraphError::Cycle(_))));
    }

    #[test]
    fn barrier_edges_are_deps() {
        let mut wf = Workflow::new();
        let a = wf.add_node(
            simple_proc("a"),
            vec![DataSource::External(PwPoly::constant(10.0))],
            vec![],
            StartRule::default(),
        );
        let b = wf.add_node(
            simple_proc("b"),
            vec![DataSource::External(PwPoly::constant(10.0))],
            vec![],
            StartRule {
                at: 0.0,
                after: vec![a],
            },
        );
        assert_eq!(wf.deps(b), vec![a]);
        assert_eq!(wf.topo_order().unwrap(), vec![a, b]);
    }

    #[test]
    fn arity_mismatch_rejected() {
        let mut wf = Workflow::new();
        wf.add_node(simple_proc("a"), vec![], vec![], StartRule::default());
        assert!(matches!(
            wf.validate(),
            Err(GraphError::BadNode { node: 0, .. })
        ));
    }

    #[test]
    fn bad_pool_ref_rejected() {
        let mut wf = Workflow::new();
        let p = ProcessBuilder::new("a", 10.0).stream_resource("net", 10.0).build();
        wf.add_node(
            p,
            vec![],
            vec![ResourceSource::PoolFraction {
                pool: 3,
                fraction: 0.5,
            }],
            StartRule::default(),
        );
        assert!(matches!(wf.validate(), Err(GraphError::BadRef { .. })));
    }
}
