//! Workflow DAG structure (paper §3.4).
//!
//! A workflow is a set of processes whose data inputs are wired either to
//! external input functions or to the *output-over-time* functions
//! `O_m(P(t))` of predecessor processes, and whose resources come from fixed
//! allocations or shared pools. Start rules express barrier edges ("task 3
//! is started after both task 1 and 2 are completed", §5.1).

use crate::model::process::Process;
use crate::pwfn::PwPoly;

/// Where a process's data input `k` comes from.
#[derive(Clone, Debug)]
pub enum DataSource {
    /// An exogenous cumulative input function `I_Dk(t)`.
    External(PwPoly),
    /// The output-over-time function `O_m(P(t))` of another node — the
    /// paper's chaining mechanism.
    ProcessOutput { node: usize, output: usize },
}

/// Where a process's resource input `l` comes from.
#[derive(Clone, Debug)]
pub enum ResourceSource {
    /// A fixed allocation function `I_Rl(t)`.
    Fixed(PwPoly),
    /// A static fraction of a shared pool's capacity.
    PoolFraction { pool: usize, fraction: f64 },
    /// Whatever the pool has left after all *previously analyzed* users'
    /// actual consumption is subtracted (the paper's §5.2 retrospective
    /// reassignment: task 2's download gets "the difference between the
    /// known maximum data rate and the data rate of task 1's download").
    PoolResidual { pool: usize },
}

/// When a node may begin.
#[derive(Clone, Debug, Default)]
pub struct StartRule {
    /// Earliest wall-clock start.
    pub at: f64,
    /// Barrier predecessors: start only after all of these finished.
    pub after: Vec<usize>,
}

/// One workflow node: a process plus its input wiring.
#[derive(Clone, Debug)]
pub struct Node {
    pub process: Process,
    pub data_sources: Vec<DataSource>,
    pub resource_sources: Vec<ResourceSource>,
    pub start: StartRule,
}

/// A shared resource pool (e.g. the 100 Mbit/s link of Fig 5).
#[derive(Clone, Debug)]
pub struct Pool {
    pub name: String,
    /// Capacity as a rate function of time.
    pub capacity: PwPoly,
}

/// The workflow DAG.
#[derive(Clone, Debug, Default)]
pub struct Workflow {
    pub nodes: Vec<Node>,
    pub pools: Vec<Pool>,
}

/// A set of node ids of one workflow — the currency of dirty-set analysis
/// (which nodes a [`crate::workflow::scenario::Perturbation`] invalidates).
/// Backed by a bit vector sized to the workflow.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NodeSet {
    bits: Vec<bool>,
}

impl NodeSet {
    /// The empty set over `n` nodes.
    pub fn empty(n: usize) -> NodeSet {
        NodeSet {
            bits: vec![false; n],
        }
    }

    /// The full set over `n` nodes.
    pub fn all(n: usize) -> NodeSet {
        NodeSet {
            bits: vec![true; n],
        }
    }

    /// Number of node slots (dirty or not).
    pub fn capacity(&self) -> usize {
        self.bits.len()
    }

    pub fn insert(&mut self, i: usize) {
        self.bits[i] = true;
    }

    pub fn contains(&self, i: usize) -> bool {
        self.bits.get(i).copied().unwrap_or(false)
    }

    /// Number of members.
    pub fn len(&self) -> usize {
        self.bits.iter().filter(|&&b| b).count()
    }

    pub fn is_empty(&self) -> bool {
        self.bits.iter().all(|&b| !b)
    }

    pub fn union_with(&mut self, other: &NodeSet) {
        for (a, b) in self.bits.iter_mut().zip(other.bits.iter()) {
            *a |= *b;
        }
    }

    /// Member ids in ascending order.
    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.bits
            .iter()
            .enumerate()
            .filter(|(_, &b)| b)
            .map(|(i, _)| i)
    }

    /// A 64-bit membership fingerprint (node `i` folds onto bit `i % 64`).
    /// Equal sets always share a fingerprint; the sweep planner uses it as
    /// a grouping key to schedule scenarios with the same dirty shape
    /// consecutively.
    pub fn fingerprint(&self) -> u64 {
        let mut f = 0u64;
        for i in self.iter() {
            f |= 1u64 << (i % 64);
        }
        f
    }
}

/// Graph-structure error.
#[derive(Debug, Clone)]
pub enum GraphError {
    Cycle(usize),
    BadRef {
        node: usize,
        what: &'static str,
        index: usize,
    },
    BadNode { node: usize, msg: String },
}

impl std::fmt::Display for GraphError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GraphError::Cycle(n) => {
                write!(f, "workflow has a dependency cycle involving node {n}")
            }
            GraphError::BadRef { node, what, index } => {
                write!(f, "node {node} references missing {what} {index}")
            }
            GraphError::BadNode { node, msg } => write!(f, "node {node}: {msg}"),
        }
    }
}

impl std::error::Error for GraphError {}

impl Workflow {
    pub fn new() -> Self {
        Workflow::default()
    }

    /// Register a shared pool, returning its id.
    pub fn add_pool(&mut self, name: &str, capacity: PwPoly) -> usize {
        self.pools.push(Pool {
            name: name.to_string(),
            capacity,
        });
        self.pools.len() - 1
    }

    /// Add a node, returning its id.
    pub fn add_node(
        &mut self,
        process: Process,
        data_sources: Vec<DataSource>,
        resource_sources: Vec<ResourceSource>,
        start: StartRule,
    ) -> usize {
        self.nodes.push(Node {
            process,
            data_sources,
            resource_sources,
            start,
        });
        self.nodes.len() - 1
    }

    /// All hard dependencies of node `i` (data-producing predecessors and
    /// barrier predecessors).
    pub fn deps(&self, i: usize) -> Vec<usize> {
        let mut out: Vec<usize> = self.nodes[i]
            .data_sources
            .iter()
            .filter_map(|s| match s {
                DataSource::ProcessOutput { node, .. } => Some(*node),
                _ => None,
            })
            .collect();
        out.extend(&self.nodes[i].start.after);
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Successor adjacency: `successors()[d]` lists every node with a hard
    /// dependency on `d` (inverse of [`Workflow::deps`]).
    pub fn successors(&self) -> Vec<Vec<usize>> {
        let mut succ: Vec<Vec<usize>> = vec![vec![]; self.nodes.len()];
        for i in 0..self.nodes.len() {
            for d in self.deps(i) {
                succ[d].push(i);
            }
        }
        succ
    }

    /// The downstream cone of `seeds`: the seeds plus every node reachable
    /// from them along dependency edges. A perturbation that invalidates
    /// exactly `seeds` invalidates exactly this set — everything else can be
    /// served from the analysis cache.
    pub fn downstream_closure(&self, seeds: &[usize]) -> NodeSet {
        let succ = self.successors();
        let mut set = NodeSet::empty(self.nodes.len());
        let mut stack: Vec<usize> = seeds.to_vec();
        while let Some(i) = stack.pop() {
            if set.contains(i) {
                continue;
            }
            set.insert(i);
            stack.extend(succ[i].iter().copied());
        }
        set
    }

    /// Node ids consuming each pool (via fraction or residual), in node-id
    /// order. Pool semantics couple these nodes: any change to the pool or
    /// to one consumer's share dirties *all* of them (the engine charges
    /// consumption retrospectively and releases capacity on finish).
    pub fn pool_consumers(&self) -> Vec<Vec<usize>> {
        let mut out = vec![vec![]; self.pools.len()];
        for (i, n) in self.nodes.iter().enumerate() {
            for s in &n.resource_sources {
                let pid = match s {
                    ResourceSource::PoolFraction { pool, .. } => Some(*pool),
                    ResourceSource::PoolResidual { pool } => Some(*pool),
                    ResourceSource::Fixed(_) => None,
                };
                if let Some(p) = pid {
                    if !out[p].contains(&i) {
                        out[p].push(i);
                    }
                }
            }
        }
        out
    }

    /// Pool ids each node consumes (fraction or residual), sorted and
    /// deduplicated — the transpose of [`Workflow::pool_consumers`]. The
    /// worklist fixpoint uses it to propagate dirtiness through shared
    /// pools: a changed finish time is only observable cross-pass via
    /// `others_end` release hints, i.e. by co-consumers of these pools.
    pub fn consumed_pools(&self) -> Vec<Vec<usize>> {
        self.nodes
            .iter()
            .map(|n| {
                let mut ps: Vec<usize> = n
                    .resource_sources
                    .iter()
                    .filter_map(|s| match s {
                        ResourceSource::PoolFraction { pool, .. } => Some(*pool),
                        ResourceSource::PoolResidual { pool } => Some(*pool),
                        ResourceSource::Fixed(_) => None,
                    })
                    .collect();
                ps.sort_unstable();
                ps.dedup();
                ps
            })
            .collect()
    }

    /// Validate wiring: arities match, references are in range.
    pub fn validate(&self) -> Result<(), GraphError> {
        for (i, n) in self.nodes.iter().enumerate() {
            if n.data_sources.len() != n.process.data_reqs.len() {
                return Err(GraphError::BadNode {
                    node: i,
                    msg: format!(
                        "{} data sources for {} data requirements",
                        n.data_sources.len(),
                        n.process.data_reqs.len()
                    ),
                });
            }
            if n.resource_sources.len() != n.process.res_reqs.len() {
                return Err(GraphError::BadNode {
                    node: i,
                    msg: format!(
                        "{} resource sources for {} resource requirements",
                        n.resource_sources.len(),
                        n.process.res_reqs.len()
                    ),
                });
            }
            for s in &n.data_sources {
                if let DataSource::ProcessOutput { node, output } = s {
                    if *node >= self.nodes.len() {
                        return Err(GraphError::BadRef {
                            node: i,
                            what: "node",
                            index: *node,
                        });
                    }
                    if *output >= self.nodes[*node].process.outputs.len() {
                        return Err(GraphError::BadRef {
                            node: i,
                            what: "output",
                            index: *output,
                        });
                    }
                }
            }
            for s in &n.resource_sources {
                let pool = match s {
                    ResourceSource::PoolFraction { pool, .. } => Some(*pool),
                    ResourceSource::PoolResidual { pool } => Some(*pool),
                    ResourceSource::Fixed(_) => None,
                };
                if let Some(p) = pool {
                    if p >= self.pools.len() {
                        return Err(GraphError::BadRef {
                            node: i,
                            what: "pool",
                            index: p,
                        });
                    }
                }
            }
            for &a in &n.start.after {
                if a >= self.nodes.len() {
                    return Err(GraphError::BadRef {
                        node: i,
                        what: "node",
                        index: a,
                    });
                }
            }
        }
        self.topo_order().map(|_| ())
    }

    /// Topological order (Kahn); `Err` on cycles. Ties resolve in node-id
    /// order, which keeps pool residual-assignment deterministic.
    pub fn topo_order(&self) -> Result<Vec<usize>, GraphError> {
        let n = self.nodes.len();
        let mut indeg = vec![0usize; n];
        let mut succ: Vec<Vec<usize>> = vec![vec![]; n];
        for i in 0..n {
            for d in self.deps(i) {
                indeg[i] += 1;
                succ[d].push(i);
            }
        }
        let mut ready: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        while let Some(&i) = ready.first() {
            // pop the smallest id (ready is kept sorted)
            ready.remove(0);
            order.push(i);
            for &s in &succ[i] {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    let pos = ready.binary_search(&s).unwrap_or_else(|e| e);
                    ready.insert(pos, s);
                }
            }
        }
        if order.len() != n {
            let stuck = (0..n).find(|&i| indeg[i] > 0).unwrap();
            return Err(GraphError::Cycle(stuck));
        }
        Ok(order)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ProcessBuilder;

    fn simple_proc(name: &str) -> Process {
        ProcessBuilder::new(name, 10.0)
            .stream_data("in", 10.0)
            .identity_output("out")
            .build()
    }

    #[test]
    fn topo_order_chain() {
        let mut wf = Workflow::new();
        let a = wf.add_node(
            simple_proc("a"),
            vec![DataSource::External(PwPoly::constant(10.0))],
            vec![],
            StartRule::default(),
        );
        let b = wf.add_node(
            simple_proc("b"),
            vec![DataSource::ProcessOutput { node: a, output: 0 }],
            vec![],
            StartRule::default(),
        );
        let c = wf.add_node(
            simple_proc("c"),
            vec![DataSource::ProcessOutput { node: b, output: 0 }],
            vec![],
            StartRule::default(),
        );
        assert_eq!(wf.topo_order().unwrap(), vec![a, b, c]);
        assert!(wf.validate().is_ok());
    }

    #[test]
    fn cycle_detected() {
        let mut wf = Workflow::new();
        wf.add_node(
            simple_proc("a"),
            vec![DataSource::ProcessOutput { node: 1, output: 0 }],
            vec![],
            StartRule::default(),
        );
        wf.add_node(
            simple_proc("b"),
            vec![DataSource::ProcessOutput { node: 0, output: 0 }],
            vec![],
            StartRule::default(),
        );
        assert!(matches!(wf.validate(), Err(GraphError::Cycle(_))));
    }

    #[test]
    fn barrier_edges_are_deps() {
        let mut wf = Workflow::new();
        let a = wf.add_node(
            simple_proc("a"),
            vec![DataSource::External(PwPoly::constant(10.0))],
            vec![],
            StartRule::default(),
        );
        let b = wf.add_node(
            simple_proc("b"),
            vec![DataSource::External(PwPoly::constant(10.0))],
            vec![],
            StartRule {
                at: 0.0,
                after: vec![a],
            },
        );
        assert_eq!(wf.deps(b), vec![a]);
        assert_eq!(wf.topo_order().unwrap(), vec![a, b]);
    }

    #[test]
    fn arity_mismatch_rejected() {
        let mut wf = Workflow::new();
        wf.add_node(simple_proc("a"), vec![], vec![], StartRule::default());
        assert!(matches!(
            wf.validate(),
            Err(GraphError::BadNode { node: 0, .. })
        ));
    }

    #[test]
    fn downstream_closure_follows_edges() {
        // a -> b -> c, plus isolated d
        let mut wf = Workflow::new();
        let a = wf.add_node(
            simple_proc("a"),
            vec![DataSource::External(PwPoly::constant(10.0))],
            vec![],
            StartRule::default(),
        );
        let b = wf.add_node(
            simple_proc("b"),
            vec![DataSource::ProcessOutput { node: a, output: 0 }],
            vec![],
            StartRule::default(),
        );
        let c = wf.add_node(
            simple_proc("c"),
            vec![DataSource::ProcessOutput { node: b, output: 0 }],
            vec![],
            StartRule::default(),
        );
        let d = wf.add_node(
            simple_proc("d"),
            vec![DataSource::External(PwPoly::constant(10.0))],
            vec![],
            StartRule::default(),
        );
        let cone = wf.downstream_closure(&[b]);
        assert!(!cone.contains(a));
        assert!(cone.contains(b) && cone.contains(c));
        assert!(!cone.contains(d));
        assert_eq!(cone.len(), 2);
        let from_a = wf.downstream_closure(&[a]);
        assert_eq!(from_a.len(), 3);
        assert_eq!(wf.successors()[a], vec![b]);
    }

    #[test]
    fn nodeset_ops() {
        let mut s = NodeSet::empty(5);
        assert!(s.is_empty());
        s.insert(1);
        s.insert(3);
        assert_eq!(s.iter().collect::<Vec<_>>(), vec![1, 3]);
        assert_eq!(s.len(), 2);
        let mut t = NodeSet::empty(5);
        t.insert(3);
        t.insert(4);
        s.union_with(&t);
        assert_eq!(s.len(), 3);
        assert_eq!(s.fingerprint(), (1u64 << 1) | (1u64 << 3) | (1u64 << 4));
        assert_eq!(NodeSet::all(5).len(), 5);
        assert_eq!(s.capacity(), 5);
    }

    #[test]
    fn bad_pool_ref_rejected() {
        let mut wf = Workflow::new();
        let p = ProcessBuilder::new("a", 10.0).stream_resource("net", 10.0).build();
        wf.add_node(
            p,
            vec![],
            vec![ResourceSource::PoolFraction {
                pool: 3,
                fraction: 0.5,
            }],
            StartRule::default(),
        );
        assert!(matches!(wf.validate(), Err(GraphError::BadRef { .. })));
    }
}
