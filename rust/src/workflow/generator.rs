//! Seeded random workflow generation: a family of realistic DAG topologies
//! (layered, scatter/gather, fan-in reduction, deep chains, a genomics-style
//! pipeline) with stream/burst mixes and shared-link pool wiring. Used by
//! the scalability tests/benches (`tests/generated_graphs.rs`,
//! `benches/sec6_scaling.rs`, docs/SCALING.md) and as a workload generator
//! for users evaluating the analyzer on their own topology sizes.
//!
//! Generation is a pure function of `(Rng seed, GeneratorOpts)`: every draw
//! happens in a fixed order, so the same seed reproduces the same workflow
//! byte-for-byte — [`fingerprint`] pins that in tests.

use crate::model::ProcessBuilder;
use crate::pwfn::PwPoly;
use crate::runtime::cache::{ContentHash, Fnv128};
use crate::util::Rng;

use super::graph::{DataSource, ResourceSource, StartRule, Workflow};

/// The topology family a generated workflow is drawn from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    /// `layers × width` grid; each interior node consumes one random node
    /// of the previous layer (the original generator's shape).
    Layered,
    /// Repeated scatter/gather blocks: a row of downloads sharing the link
    /// pool, joined by one gather node, chained `layers` times.
    ScatterGather,
    /// A wide source row reduced to a single sink by random-arity joins
    /// (a reduction tree, e.g. map-reduce aggregation).
    FanInJoin,
    /// One long chain of `layers × width` stages — the deep-pipeline shape
    /// that stresses piece growth ([`crate::solver::SolverOpts::piece_budget`]).
    ChainedStages,
    /// A genomics-style pipeline: per-sample download → align → sort lanes,
    /// a barrier merge over all samples, then a calling chain.
    Genomics,
}

impl Topology {
    /// Every shape, for exhaustive test sweeps.
    pub const ALL: [Topology; 5] = [
        Topology::Layered,
        Topology::ScatterGather,
        Topology::FanInJoin,
        Topology::ChainedStages,
        Topology::Genomics,
    ];

    /// Stable name (CLI `--shape` values, bench artifact keys).
    pub fn name(self) -> &'static str {
        match self {
            Topology::Layered => "layered",
            Topology::ScatterGather => "scatter-gather",
            Topology::FanInJoin => "fan-in",
            Topology::ChainedStages => "chain",
            Topology::Genomics => "genomics",
        }
    }

    /// Parse a CLI `--shape` value.
    pub fn parse(s: &str) -> Option<Topology> {
        Topology::ALL.iter().copied().find(|t| t.name() == s)
    }
}

/// Shape parameters for the generator.
#[derive(Clone, Debug)]
pub struct GeneratorOpts {
    /// Which topology family to draw from.
    pub topology: Topology,
    pub layers: usize,
    /// Processes per layer (scatter row width / sample count / chain factor,
    /// depending on the topology).
    pub width: usize,
    /// Probability that a consumer is burst-type (vs stream).
    pub burst_prob: f64,
    /// Bytes produced by each source process.
    pub source_bytes: f64,
    /// Shared-link capacity feeding the download nodes.
    pub link_rate: f64,
    /// Maximum join arity for [`Topology::FanInJoin`] (draws 2..=fan_in).
    pub fan_in: usize,
    /// ± relative jitter applied to each layer's width (0.0 = exact).
    pub width_jitter: f64,
    /// Probability a download draws [`ResourceSource::PoolResidual`]
    /// instead of its fair [`ResourceSource::PoolFraction`] share. Residual
    /// users make the fixpoint multi-pass (release ordering), so tests that
    /// want to exercise the worklist scheduler set this > 0.
    pub pool_residual_prob: f64,
}

impl Default for GeneratorOpts {
    fn default() -> Self {
        GeneratorOpts {
            topology: Topology::Layered,
            layers: 3,
            width: 2,
            burst_prob: 0.3,
            source_bytes: 1e8,
            link_rate: 1e7,
            fan_in: 3,
            width_jitter: 0.0,
            pool_residual_prob: 0.0,
        }
    }
}

impl GeneratorOpts {
    /// Scale `layers`/`width` so the generated workflow has roughly `n`
    /// nodes under this topology (the bench's 10²–10⁴ node axis).
    pub fn target_nodes(mut self, n: usize) -> Self {
        let n = n.max(2);
        match self.topology {
            Topology::Layered => {
                self.width = self.width.max(1);
                self.layers = (n / self.width).max(1);
            }
            Topology::ScatterGather => {
                let per_block = self.width.max(1) + 1;
                self.layers = (n / per_block).max(1);
            }
            Topology::FanInJoin => {
                // width·f/(f−1) total nodes for arity f
                let f = self.fan_in.max(2) as f64;
                self.width = ((n as f64 * (f - 1.0) / f).round() as usize).max(2);
            }
            Topology::ChainedStages => {
                self.layers = n;
                self.width = 1;
            }
            Topology::Genomics => {
                // 3·width lanes + merge + layers tail
                self.layers = (n / 4).max(1);
                self.width = (n.saturating_sub(1 + self.layers) / 3).max(1);
            }
        }
        self
    }
}

/// Content fingerprint of a workflow: every function, wiring edge, and
/// start rule folded through the deterministic [`Fnv128`] hash. Same seed
/// and opts → same fingerprint, across runs and platforms.
pub fn fingerprint(wf: &Workflow) -> u128 {
    let mut h = Fnv128::new();
    h.write_usize(wf.pools.len());
    for p in &wf.pools {
        h.write_str(&p.name);
        p.capacity.content_hash(&mut h);
    }
    h.write_usize(wf.nodes.len());
    for nd in &wf.nodes {
        nd.process.content_hash(&mut h);
        h.write_usize(nd.data_sources.len());
        for s in &nd.data_sources {
            match s {
                DataSource::External(f) => {
                    h.write_usize(0);
                    f.content_hash(&mut h);
                }
                DataSource::ProcessOutput { node, output } => {
                    h.write_usize(1);
                    h.write_usize(*node);
                    h.write_usize(*output);
                }
            }
        }
        h.write_usize(nd.resource_sources.len());
        for s in &nd.resource_sources {
            match s {
                ResourceSource::Fixed(f) => {
                    h.write_usize(0);
                    f.content_hash(&mut h);
                }
                ResourceSource::PoolFraction { pool, fraction } => {
                    h.write_usize(1);
                    h.write_usize(*pool);
                    h.write_f64(*fraction);
                }
                ResourceSource::PoolResidual { pool } => {
                    h.write_usize(2);
                    h.write_usize(*pool);
                }
            }
        }
        h.write_f64(nd.start.at);
        h.write_usize(nd.start.after.len());
        for &a in &nd.start.after {
            h.write_usize(a);
        }
    }
    h.finish()
}

/// Generate a workflow of the configured [`Topology`]. Pure in
/// `(rng state, opts)` — see the module docs.
pub fn generate(rng: &mut Rng, opts: &GeneratorOpts) -> Workflow {
    match opts.topology {
        Topology::Layered => gen_layered(rng, opts),
        Topology::ScatterGather => gen_scatter_gather(rng, opts),
        Topology::FanInJoin => gen_fan_in(rng, opts),
        Topology::ChainedStages => gen_chain(rng, opts),
        Topology::Genomics => gen_genomics(rng, opts),
    }
}

/// A download node on the shared link pool. **Every** download draws from
/// the pool — its fair `1/n_downloads` fraction by default, or the residual
/// with probability `pool_residual_prob` — so link contention is always
/// visible in the bottleneck report (regression: an earlier version pooled
/// only the first source per layer). `extra_src` chains a staged download
/// onto an upstream node's output (scatter/gather blocks).
fn source(
    wf: &mut Workflow,
    rng: &mut Rng,
    opts: &GeneratorOpts,
    pool: usize,
    name: &str,
    share: f64,
    extra_src: Option<usize>,
) -> usize {
    let bytes = opts.source_bytes * rng.range(0.5, 1.5);
    let mut b = ProcessBuilder::new(name, bytes).stream_data("remote", bytes);
    let mut data = vec![DataSource::External(PwPoly::constant(bytes))];
    if let Some(s) = extra_src {
        let in_bytes = wf.nodes[s].process.max_progress;
        b = b.stream_data("in", in_bytes);
        data.push(DataSource::ProcessOutput { node: s, output: 0 });
    }
    let p = b
        .stream_resource("link", bytes)
        .identity_output("out")
        .build();
    let rs = if rng.f64() < opts.pool_residual_prob {
        ResourceSource::PoolResidual { pool }
    } else {
        ResourceSource::PoolFraction {
            pool,
            fraction: share,
        }
    };
    wf.add_node(p, data, vec![rs], StartRule::default())
}

/// A compute stage consuming the outputs of `srcs` (stream or burst), with
/// a random CPU requirement and optional barrier predecessors.
fn consumer(
    wf: &mut Workflow,
    rng: &mut Rng,
    name: &str,
    srcs: &[usize],
    burst: bool,
    after: Vec<usize>,
) -> usize {
    let total_in: f64 = srcs
        .iter()
        .map(|&s| wf.nodes[s].process.max_progress)
        .sum();
    let out_bytes = total_in * rng.range(0.3, 1.1);
    let cpu = rng.range(1.0, 30.0);
    let mut b = ProcessBuilder::new(name, out_bytes);
    for (k, &s) in srcs.iter().enumerate() {
        let in_bytes = wf.nodes[s].process.max_progress;
        let dname = format!("in{k}");
        b = if burst {
            b.burst_data(&dname, in_bytes)
        } else {
            b.stream_data(&dname, in_bytes)
        };
    }
    let p = b
        .stream_resource("cpu", cpu)
        .identity_output("out")
        .build();
    wf.add_node(
        p,
        srcs.iter()
            .map(|&s| DataSource::ProcessOutput { node: s, output: 0 })
            .collect(),
        vec![ResourceSource::Fixed(PwPoly::constant(1.0))],
        StartRule { at: 0.0, after },
    )
}

/// One jittered layer width (always ≥ 1; consumes exactly one draw).
fn jittered_width(rng: &mut Rng, opts: &GeneratorOpts) -> usize {
    let f = 1.0 + rng.range(-opts.width_jitter, opts.width_jitter);
    ((opts.width.max(1) as f64 * f).round().max(1.0)) as usize
}

fn gen_layered(rng: &mut Rng, opts: &GeneratorOpts) -> Workflow {
    let mut wf = Workflow::new();
    let pool = wf.add_pool("link", PwPoly::constant(opts.link_rate));
    let widths: Vec<usize> = (0..opts.layers.max(1))
        .map(|_| jittered_width(rng, opts))
        .collect();
    let n_src = widths[0];
    let mut prev: Vec<usize> = vec![];
    for (layer, &wl) in widths.iter().enumerate() {
        let mut this = vec![];
        for w in 0..wl {
            let name = format!("p{layer}_{w}");
            let node = if layer == 0 {
                source(&mut wf, rng, opts, pool, &name, 1.0 / n_src as f64, None)
            } else {
                let s = prev[rng.below(prev.len())];
                let burst = rng.f64() < opts.burst_prob;
                consumer(&mut wf, rng, &name, &[s], burst, vec![])
            };
            this.push(node);
        }
        prev = this;
    }
    wf
}

fn gen_scatter_gather(rng: &mut Rng, opts: &GeneratorOpts) -> Workflow {
    let mut wf = Workflow::new();
    let pool = wf.add_pool("link", PwPoly::constant(opts.link_rate));
    let widths: Vec<usize> = (0..opts.layers.max(1))
        .map(|_| jittered_width(rng, opts))
        .collect();
    let total_dl: usize = widths.iter().sum();
    let mut prev_gather: Option<usize> = None;
    for (stage, &wl) in widths.iter().enumerate() {
        let mut dls = vec![];
        for w in 0..wl {
            dls.push(source(
                &mut wf,
                rng,
                opts,
                pool,
                &format!("dl{stage}_{w}"),
                1.0 / total_dl as f64,
                prev_gather,
            ));
        }
        let burst = rng.f64() < opts.burst_prob;
        prev_gather = Some(consumer(
            &mut wf,
            rng,
            &format!("gather{stage}"),
            &dls,
            burst,
            vec![],
        ));
    }
    wf
}

fn gen_fan_in(rng: &mut Rng, opts: &GeneratorOpts) -> Workflow {
    let mut wf = Workflow::new();
    let pool = wf.add_pool("link", PwPoly::constant(opts.link_rate));
    let w0 = jittered_width(rng, opts).max(2);
    let mut cur: Vec<usize> = (0..w0)
        .map(|w| {
            source(
                &mut wf,
                rng,
                opts,
                pool,
                &format!("src{w}"),
                1.0 / w0 as f64,
                None,
            )
        })
        .collect();
    let mut depth = 0usize;
    while cur.len() > 1 {
        let mut next = vec![];
        let mut i = 0;
        while i < cur.len() {
            let k = (2 + rng.below(opts.fan_in.max(2) - 1)).min(cur.len() - i);
            let group = &cur[i..i + k];
            let burst = rng.f64() < opts.burst_prob;
            let name = format!("join{depth}_{}", next.len());
            next.push(consumer(&mut wf, rng, &name, group, burst, vec![]));
            i += k;
        }
        cur = next;
        depth += 1;
    }
    wf
}

fn gen_chain(rng: &mut Rng, opts: &GeneratorOpts) -> Workflow {
    let mut wf = Workflow::new();
    let pool = wf.add_pool("link", PwPoly::constant(opts.link_rate));
    let len = (opts.layers.max(1) * opts.width.max(1)).max(2);
    let mut prev = source(&mut wf, rng, opts, pool, "dl0", 1.0, None);
    for stage in 1..len {
        let burst = rng.f64() < opts.burst_prob;
        prev = consumer(&mut wf, rng, &format!("s{stage}"), &[prev], burst, vec![]);
    }
    let _ = prev;
    wf
}

fn gen_genomics(rng: &mut Rng, opts: &GeneratorOpts) -> Workflow {
    let mut wf = Workflow::new();
    let pool = wf.add_pool("link", PwPoly::constant(opts.link_rate));
    let w = jittered_width(rng, opts);
    let mut sorts = vec![];
    for smp in 0..w {
        let dl = source(
            &mut wf,
            rng,
            opts,
            pool,
            &format!("dl{smp}"),
            1.0 / w as f64,
            None,
        );
        let align = consumer(&mut wf, rng, &format!("align{smp}"), &[dl], false, vec![]);
        let sort = consumer(&mut wf, rng, &format!("sort{smp}"), &[align], true, vec![]);
        sorts.push(sort);
    }
    let merge = consumer(&mut wf, rng, "merge", &sorts, true, sorts.clone());
    let mut prev = merge;
    for stage in 0..opts.layers {
        let burst = rng.f64() < opts.burst_prob;
        prev = consumer(&mut wf, rng, &format!("call{stage}"), &[prev], burst, vec![]);
    }
    let _ = prev;
    wf
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::SolverOpts;
    use crate::workflow::engine::analyze_fixpoint;

    #[test]
    fn generated_workflows_validate_and_solve() {
        let mut rng = Rng::new(7);
        for case in 0..25 {
            let opts = GeneratorOpts {
                layers: 1 + rng.below(4),
                width: 1 + rng.below(3),
                ..GeneratorOpts::default()
            };
            let wf = generate(&mut rng, &opts);
            wf.validate().unwrap_or_else(|e| panic!("case {case}: {e}"));
            let wa = analyze_fixpoint(&wf, &SolverOpts::default(), 6)
                .unwrap_or_else(|e| panic!("case {case}: {e}"));
            assert!(wa.makespan.is_some(), "case {case} never finishes");
        }
    }

    /// Analysis scales with workflow size, not with data volume: a 100-node
    /// pipeline still analyzes in ~linear events per node.
    #[test]
    fn analysis_scales_linearly_with_nodes() {
        let mut rng = Rng::new(11);
        let mk = |rng: &mut Rng, layers: usize| {
            let wf = generate(
                rng,
                &GeneratorOpts {
                    layers,
                    width: 2,
                    ..GeneratorOpts::default()
                },
            );
            analyze_fixpoint(&wf, &SolverOpts::default(), 6)
                .unwrap()
                .events
        };
        let e10 = mk(&mut rng, 5); // 10 nodes
        let e100 = mk(&mut rng, 50); // 100 nodes
        // events per node stay bounded (well under 10x blowup per node)
        assert!(
            (e100 as f64) < 25.0 * e10 as f64,
            "events {e10} -> {e100}"
        );
    }

    /// The generated DAG agrees with the fluid executor (end-to-end check
    /// of generator + engine + executor on larger topologies).
    #[test]
    fn generated_dag_matches_fluid() {
        use crate::testbed::fluid::{execute, FluidOpts};
        let mut rng = Rng::new(3);
        let wf = generate(
            &mut rng,
            &GeneratorOpts {
                layers: 3,
                width: 2,
                source_bytes: 1e6,
                link_rate: 1e5,
                ..GeneratorOpts::default()
            },
        );
        let predicted = analyze_fixpoint(&wf, &SolverOpts::default(), 6)
            .unwrap()
            .makespan
            .unwrap();
        let fluid = execute(
            &wf,
            &FluidOpts {
                dt: 0.02,
                horizon: predicted * 3.0 + 100.0,
                ..FluidOpts::default()
            },
        )
        .makespan
        .unwrap();
        assert!(
            (predicted - fluid).abs() < 0.02 * predicted + 0.5,
            "predicted {predicted} vs fluid {fluid}"
        );
    }

    /// Regression for the pool-wiring bug: every source-layer download must
    /// draw from the shared link pool (an earlier version gave only the
    /// first per layer a `PoolFraction`), and the resulting contention must
    /// be visible — both in the wiring and in the bottleneck report.
    #[test]
    fn all_sources_share_the_link_pool() {
        let mut rng = Rng::new(42);
        let opts = GeneratorOpts {
            layers: 2,
            width: 3,
            ..GeneratorOpts::default()
        };
        let wf = generate(&mut rng, &opts);
        let n_src = 3;
        for i in 0..n_src {
            match wf.nodes[i].resource_sources[0] {
                ResourceSource::PoolFraction { pool, fraction } => {
                    assert_eq!(pool, 0);
                    assert!((fraction - 1.0 / n_src as f64).abs() < 1e-12);
                }
                ref other => panic!("source {i} not on the pool: {other:?}"),
            }
        }

        let wa = analyze_fixpoint(&wf, &SolverOpts::default(), 6).unwrap();
        for i in 0..n_src {
            // contention: a fair-share download cannot beat its solo time,
            // and the first finisher runs at 1/3 capacity throughout
            let bytes = wf.nodes[i].process.max_progress;
            let solo = bytes / opts.link_rate;
            let finish = wa.analyses[i].finish_time.unwrap();
            assert!(finish >= solo - 1e-9, "source {i} beat the link: {finish}");
            // the report names the link as a bottleneck for every download
            let named: Vec<String> = wa.analyses[i]
                .segments
                .iter()
                .map(|s| wa.analyses[i].bottleneck_name(&wf.nodes[i].process, s.bottleneck))
                .collect();
            assert!(
                named.iter().any(|n| n == "res:link"),
                "source {i} bottlenecks: {named:?}"
            );
        }
        let first = (0..n_src)
            .map(|i| wa.analyses[i].finish_time.unwrap())
            .fold(f64::INFINITY, f64::min);
        let min_solo = (0..n_src)
            .map(|i| wf.nodes[i].process.max_progress / opts.link_rate)
            .fold(f64::INFINITY, f64::min);
        assert!(
            first > 1.9 * min_solo,
            "no contention visible: first finish {first} vs min solo {min_solo}"
        );
    }

    /// Same seed → byte-identical workflow; different seed → different one.
    /// Covers every topology in the family.
    #[test]
    fn generation_is_deterministic_per_seed() {
        for topo in Topology::ALL {
            let opts = GeneratorOpts {
                topology: topo,
                layers: 3,
                width: 3,
                width_jitter: 0.25,
                pool_residual_prob: 0.3,
                ..GeneratorOpts::default()
            };
            let a = fingerprint(&generate(&mut Rng::new(9), &opts));
            let b = fingerprint(&generate(&mut Rng::new(9), &opts));
            assert_eq!(a, b, "{topo:?} not reproducible");
            let c = fingerprint(&generate(&mut Rng::new(10), &opts));
            assert_ne!(a, c, "{topo:?} ignores the seed");
        }
    }

    /// Every topology validates, is acyclic, and roughly honors
    /// `target_nodes`.
    #[test]
    fn all_topologies_validate_and_scale() {
        for topo in Topology::ALL {
            for &n in &[12usize, 60] {
                let opts = GeneratorOpts {
                    topology: topo,
                    width_jitter: 0.2,
                    pool_residual_prob: 0.2,
                    ..GeneratorOpts::default()
                }
                .target_nodes(n);
                let mut rng = Rng::new(n as u64);
                let wf = generate(&mut rng, &opts);
                wf.validate()
                    .unwrap_or_else(|e| panic!("{topo:?}/{n}: {e}"));
                wf.topo_order()
                    .unwrap_or_else(|e| panic!("{topo:?}/{n}: {e}"));
                let got = wf.nodes.len();
                assert!(
                    got >= n / 3 && got <= n * 3,
                    "{topo:?}: asked ~{n} nodes, got {got}"
                );
            }
        }
    }
}
