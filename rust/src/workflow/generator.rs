//! Random workflow generation: layered DAGs of stream/burst processes with
//! realistic wiring. Used by scalability tests/benches and as a workload
//! generator for users evaluating the analyzer on their own topology sizes.

use crate::model::ProcessBuilder;
use crate::pwfn::PwPoly;
use crate::util::Rng;

use super::graph::{DataSource, ResourceSource, StartRule, Workflow};

/// Shape parameters for the generator.
#[derive(Clone, Debug)]
pub struct GeneratorOpts {
    pub layers: usize,
    /// Processes per layer.
    pub width: usize,
    /// Probability that a consumer is burst-type (vs stream).
    pub burst_prob: f64,
    /// Bytes produced by each source process.
    pub source_bytes: f64,
    /// Shared-link capacity feeding the source layer.
    pub link_rate: f64,
}

impl Default for GeneratorOpts {
    fn default() -> Self {
        GeneratorOpts {
            layers: 3,
            width: 2,
            burst_prob: 0.3,
            source_bytes: 1e8,
            link_rate: 1e7,
        }
    }
}

/// Generate a layered workflow: layer 0 downloads from a shared link; each
/// later process consumes one output of the previous layer (stream or
/// burst) with its own CPU requirement.
pub fn generate(rng: &mut Rng, opts: &GeneratorOpts) -> Workflow {
    let mut wf = Workflow::new();
    let pool = wf.add_pool("link", PwPoly::constant(opts.link_rate));
    let mut prev_layer: Vec<usize> = vec![];

    for layer in 0..opts.layers {
        let mut this_layer = vec![];
        for w in 0..opts.width {
            let name = format!("p{layer}_{w}");
            let node = if layer == 0 {
                let bytes = opts.source_bytes * rng.range(0.5, 1.5);
                let p = ProcessBuilder::new(&name, bytes)
                    .stream_data("remote", bytes)
                    .stream_resource("link", bytes)
                    .identity_output("out")
                    .build();
                wf.add_node(
                    p,
                    vec![DataSource::External(PwPoly::constant(bytes))],
                    vec![if w == 0 {
                        ResourceSource::PoolFraction {
                            pool,
                            fraction: 1.0 / opts.width as f64,
                        }
                    } else {
                        ResourceSource::PoolResidual { pool }
                    }],
                    StartRule::default(),
                )
            } else {
                let src = prev_layer[rng.below(prev_layer.len())];
                let in_bytes = wf.nodes[src].process.max_progress;
                let out_bytes = in_bytes * rng.range(0.3, 1.1);
                let cpu = rng.range(1.0, 30.0);
                let burst = rng.f64() < opts.burst_prob;
                let b = ProcessBuilder::new(&name, out_bytes);
                let b = if burst {
                    b.burst_data("in", in_bytes)
                } else {
                    b.stream_data("in", in_bytes)
                };
                let p = b
                    .stream_resource("cpu", cpu)
                    .identity_output("out")
                    .build();
                wf.add_node(
                    p,
                    vec![DataSource::ProcessOutput {
                        node: src,
                        output: 0,
                    }],
                    vec![ResourceSource::Fixed(PwPoly::constant(1.0))],
                    StartRule::default(),
                )
            };
            this_layer.push(node);
        }
        prev_layer = this_layer;
    }
    wf
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::SolverOpts;
    use crate::workflow::engine::analyze_fixpoint;

    #[test]
    fn generated_workflows_validate_and_solve() {
        let mut rng = Rng::new(7);
        for case in 0..25 {
            let opts = GeneratorOpts {
                layers: 1 + rng.below(4),
                width: 1 + rng.below(3),
                ..GeneratorOpts::default()
            };
            let wf = generate(&mut rng, &opts);
            wf.validate().unwrap_or_else(|e| panic!("case {case}: {e}"));
            let wa = analyze_fixpoint(&wf, &SolverOpts::default(), 6)
                .unwrap_or_else(|e| panic!("case {case}: {e}"));
            assert!(wa.makespan.is_some(), "case {case} never finishes");
        }
    }

    /// Analysis scales with workflow size, not with data volume: a 100-node
    /// pipeline still analyzes in ~linear events per node.
    #[test]
    fn analysis_scales_linearly_with_nodes() {
        let mut rng = Rng::new(11);
        let mk = |rng: &mut Rng, layers: usize| {
            let wf = generate(
                rng,
                &GeneratorOpts {
                    layers,
                    width: 2,
                    ..GeneratorOpts::default()
                },
            );
            analyze_fixpoint(&wf, &SolverOpts::default(), 6)
                .unwrap()
                .events
        };
        let e10 = mk(&mut rng, 5); // 10 nodes
        let e100 = mk(&mut rng, 50); // 100 nodes
        // events per node stay bounded (well under 10x blowup per node)
        assert!(
            (e100 as f64) < 25.0 * e10 as f64,
            "events {e10} -> {e100}"
        );
    }

    /// The generated DAG agrees with the fluid executor (end-to-end check
    /// of generator + engine + executor on larger topologies).
    #[test]
    fn generated_dag_matches_fluid() {
        use crate::testbed::fluid::{execute, FluidOpts};
        let mut rng = Rng::new(3);
        let wf = generate(
            &mut rng,
            &GeneratorOpts {
                layers: 3,
                width: 2,
                source_bytes: 1e6,
                link_rate: 1e5,
                ..GeneratorOpts::default()
            },
        );
        let predicted = analyze_fixpoint(&wf, &SolverOpts::default(), 6)
            .unwrap()
            .makespan
            .unwrap();
        let fluid = execute(
            &wf,
            &FluidOpts {
                dt: 0.02,
                horizon: predicted * 3.0 + 100.0,
                ..FluidOpts::default()
            },
        )
        .makespan
        .unwrap();
        assert!(
            (predicted - fluid).abs() < 0.02 * predicted + 0.5,
            "predicted {predicted} vs fluid {fluid}"
        );
    }
}
