//! Workflow analysis: per-node BottleMod analyses chained through
//! `O_m(P(t))` output functions and shared resource pools (paper §3.4, §5.2).
//!
//! Pool semantics mirror the paper's evaluation setup:
//!
//! * a `PoolFraction` user is rate-limited to `fraction · capacity` while
//!   any other consumer of the pool is still running, and upgraded to the
//!   full capacity once all others finished (the appendix's
//!   `nft replace rule` releasing the bandwidth to the other task);
//! * after a pool user is analyzed, its *actual* consumption
//!   `P'(t)·R'(P(t))` is charged against the pool retrospectively
//!   ("the consumed data rate is set for the process retrospectively",
//!   §5.2), and `PoolResidual` users receive what is left.
//!
//! Because "once all others finished" can refer to nodes analyzed *later*
//! in topological order, [`analyze_fixpoint`] iterates single passes with
//! finish-time hints until the schedule stabilizes (2–3 passes in
//! practice). [`analyze`] is a single pass with no hints — exactly the
//! paper's §5.2 procedure, sufficient when prioritized consumers are
//! analyzed first.

use crate::model::process::ProcessInputs;
use crate::pwfn::PwPoly;
use crate::solver::{solve, Analysis, SolveError, SolverOpts};

use super::graph::{DataSource, GraphError, ResourceSource, Workflow};

/// Result of analyzing a whole workflow.
#[derive(Clone, Debug)]
pub struct WorkflowAnalysis {
    /// Per-node analyses, indexed like `Workflow::nodes`.
    pub analyses: Vec<Analysis>,
    /// Materialized inputs each node was analyzed under (useful for the
    /// §3.3 metrics, which need the `I` functions).
    pub inputs: Vec<ProcessInputs>,
    /// Wall-clock completion of the whole workflow (`None` if any node
    /// never finishes).
    pub makespan: Option<f64>,
    /// Per-pool remaining capacity after all consumers were charged.
    pub pool_residuals: Vec<PwPoly>,
    /// Total solver events across all nodes (§6 cost accounting).
    pub events: usize,
    /// Fixpoint passes used (1 for plain [`analyze`]).
    pub passes: usize,
}

/// Workflow-level failure.
#[derive(Debug, Clone)]
pub enum WorkflowError {
    Graph(GraphError),
    Solve {
        node: usize,
        name: String,
        err: SolveError,
    },
    DepNeverFinishes { node: usize, dep: usize },
}

impl std::fmt::Display for WorkflowError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WorkflowError::Graph(e) => e.fmt(f),
            WorkflowError::Solve { node, name, err } => {
                write!(f, "node {node} ('{name}'): {err}")
            }
            WorkflowError::DepNeverFinishes { node, dep } => {
                write!(f, "node {node} depends on node {dep} which never finishes")
            }
        }
    }
}

impl std::error::Error for WorkflowError {}

impl From<GraphError> for WorkflowError {
    fn from(e: GraphError) -> Self {
        WorkflowError::Graph(e)
    }
}

/// Consumers of each pool (node ids), from the wiring.
fn pool_consumers(wf: &Workflow) -> Vec<Vec<usize>> {
    let mut out = vec![vec![]; wf.pools.len()];
    for (i, n) in wf.nodes.iter().enumerate() {
        for s in &n.resource_sources {
            let pid = match s {
                ResourceSource::PoolFraction { pool, .. } => Some(*pool),
                ResourceSource::PoolResidual { pool } => Some(*pool),
                ResourceSource::Fixed(_) => None,
            };
            if let Some(p) = pid {
                if !out[p].contains(&i) {
                    out[p].push(i);
                }
            }
        }
    }
    out
}

/// One analysis pass. `finish_hints[i]` carries node `i`'s finish time from
/// a previous pass (used for pool release when `i` hasn't been analyzed yet
/// in this pass).
fn analyze_pass(
    wf: &Workflow,
    opts: &SolverOpts,
    finish_hints: &[Option<f64>],
) -> Result<WorkflowAnalysis, WorkflowError> {
    let order = wf.topo_order()?;
    let n = wf.nodes.len();
    let consumers = pool_consumers(wf);

    let mut analyses: Vec<Option<Analysis>> = vec![None; n];
    let mut inputs_used: Vec<Option<ProcessInputs>> = vec![None; n];
    // per-pool charged demand functions of already-analyzed consumers
    let mut pool_claims: Vec<Vec<(usize, PwPoly)>> = vec![vec![]; wf.pools.len()];
    let mut events = 0usize;

    for &i in &order {
        let node = &wf.nodes[i];

        // ---- start time: barrier predecessors must have finished --------
        let mut start = node.start.at;
        for &d in &node.start.after {
            match analyses[d].as_ref().unwrap().finish_time {
                Some(f) => start = start.max(f),
                None => return Err(WorkflowError::DepNeverFinishes { node: i, dep: d }),
            }
        }

        // ---- data inputs -------------------------------------------------
        let data: Vec<PwPoly> = node
            .data_sources
            .iter()
            .map(|s| match s {
                DataSource::External(f) => f.clone(),
                DataSource::ProcessOutput { node: d, output } => analyses[*d]
                    .as_ref()
                    .unwrap()
                    .output_over_time(&wf.nodes[*d].process, *output),
            })
            .collect();

        // finish time of all *other* consumers of a pool, best knowledge:
        // current-pass analysis if available, else the hint from last pass
        let others_end = |pool: usize| -> Option<f64> {
            let mut end = 0.0f64;
            for &c in &consumers[pool] {
                if c == i {
                    continue;
                }
                let f = match analyses[c].as_ref() {
                    Some(a) => a.finish_time,
                    None => finish_hints[c],
                };
                match f {
                    Some(f) => end = end.max(f),
                    None => return None, // unknown/never: no release
                }
            }
            Some(end)
        };

        // ---- resource inputs ----------------------------------------------
        let resources: Vec<PwPoly> = node
            .resource_sources
            .iter()
            .map(|s| match s {
                ResourceSource::Fixed(f) => f.clone(),
                ResourceSource::PoolFraction { pool, fraction } => {
                    let cap = &wf.pools[*pool].capacity;
                    let frac_fn = cap.scale(*fraction);
                    match others_end(*pool) {
                        Some(end) if end > cap.x_min() && end.is_finite() => {
                            // fraction until the others are done, then full
                            concat(
                                frac_fn.clip(cap.x_min(), end),
                                cap.clip(end, f64::INFINITY),
                            )
                        }
                        Some(_) => cap.clone(), // no other consumers at all
                        None => frac_fn,
                    }
                }
                ResourceSource::PoolResidual { pool } => {
                    let mut rem = wf.pools[*pool].capacity.clone();
                    for (_, demand) in &pool_claims[*pool] {
                        rem = rem.sub(demand).max_with_zero();
                    }
                    rem.simplify()
                }
            })
            .collect();

        let inputs = ProcessInputs {
            data,
            resources,
            start_time: start,
        };
        let analysis = solve(&node.process, &inputs, opts).map_err(|err| {
            WorkflowError::Solve {
                node: i,
                name: node.process.name.clone(),
                err,
            }
        })?;
        events += analysis.events;

        // charge pool consumption retrospectively
        for (l, s) in node.resource_sources.iter().enumerate() {
            let pid = match s {
                ResourceSource::PoolFraction { pool, .. } => Some(*pool),
                ResourceSource::PoolResidual { pool } => Some(*pool),
                ResourceSource::Fixed(_) => None,
            };
            if let Some(pid) = pid {
                let demand = analysis.resource_demand(&node.process, l).simplify();
                pool_claims[pid].push((i, demand));
            }
        }

        inputs_used[i] = Some(inputs);
        analyses[i] = Some(analysis);
    }

    let mut makespan = Some(0.0f64);
    for a in analyses.iter().flatten() {
        makespan = match (makespan, a.finish_time) {
            (Some(m), Some(f)) => Some(m.max(f)),
            _ => None,
        };
    }

    let pool_residuals = wf
        .pools
        .iter()
        .enumerate()
        .map(|(pid, pool)| {
            let mut rem = pool.capacity.clone();
            for (_, demand) in &pool_claims[pid] {
                rem = rem.sub(demand).max_with_zero();
            }
            rem.simplify()
        })
        .collect();

    Ok(WorkflowAnalysis {
        analyses: analyses.into_iter().map(Option::unwrap).collect(),
        inputs: inputs_used.into_iter().map(Option::unwrap).collect(),
        makespan,
        pool_residuals,
        events,
        passes: 1,
    })
}

/// Single-pass analysis (the paper's §5.2 procedure).
pub fn analyze(wf: &Workflow, opts: &SolverOpts) -> Result<WorkflowAnalysis, WorkflowError> {
    wf.validate()?;
    let hints = vec![None; wf.nodes.len()];
    analyze_pass(wf, opts, &hints)
}

/// Fixpoint analysis: iterate passes, feeding each pass the previous pass's
/// finish times as pool-release hints, until the schedule stabilizes.
/// Needed when a pool consumer analyzed *earlier* in topological order is
/// released by one analyzed *later* (e.g. Fig 7 with small fractions, where
/// task 2's download finishes first and task 1's download inherits the full
/// link).
pub fn analyze_fixpoint(
    wf: &Workflow,
    opts: &SolverOpts,
    max_passes: usize,
) -> Result<WorkflowAnalysis, WorkflowError> {
    wf.validate()?;
    let n = wf.nodes.len();
    let mut hints: Vec<Option<f64>> = vec![None; n];
    let mut last: Option<WorkflowAnalysis> = None;
    let mut total_events = 0usize;
    for pass in 0..max_passes.max(1) {
        let wa = analyze_pass(wf, opts, &hints)?;
        total_events += wa.events;
        let new_hints: Vec<Option<f64>> =
            wa.analyses.iter().map(|a| a.finish_time).collect();
        let stable = new_hints
            .iter()
            .zip(hints.iter())
            .all(|(a, b)| match (a, b) {
                (Some(x), Some(y)) => (x - y).abs() < 1e-6 * (1.0 + x.abs()),
                (None, None) => true,
                _ => false,
            });
        hints = new_hints;
        let mut done = wa;
        done.passes = pass + 1;
        done.events = total_events;
        last = Some(done);
        if stable {
            break;
        }
    }
    Ok(last.unwrap())
}

/// Concatenate two piecewise functions with adjacent domains.
fn concat(a: PwPoly, b: PwPoly) -> PwPoly {
    let mut breaks = a.breaks.clone();
    breaks.pop();
    let mut polys = a.polys.clone();
    breaks.extend_from_slice(&b.breaks);
    polys.extend_from_slice(&b.polys);
    PwPoly::new(breaks, polys)
}

impl WorkflowAnalysis {
    /// Per-node `(name, start, finish)` report rows.
    pub fn schedule(&self, wf: &Workflow) -> Vec<(String, f64, Option<f64>)> {
        wf.nodes
            .iter()
            .zip(self.analyses.iter())
            .map(|(n, a)| (n.process.name.clone(), a.start_time, a.finish_time))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ProcessBuilder;
    use crate::workflow::graph::StartRule;
    use crate::model::process::Process;

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() < 1e-6 * (1.0 + a.abs().max(b.abs()))
    }

    fn dl_proc(name: &str, size: f64) -> Process {
        ProcessBuilder::new(name, size)
            .stream_data("remote", size)
            .stream_resource("link", size)
            .identity_output("file")
            .build()
    }

    /// download -> stream task pipeline: the two overlap (pipelined).
    #[test]
    fn pipelined_chain() {
        let mut wf = Workflow::new();
        let d = wf.add_node(
            dl_proc("dl", 100.0),
            vec![DataSource::External(PwPoly::constant(100.0))],
            vec![ResourceSource::Fixed(PwPoly::constant(10.0))],
            StartRule::default(),
        );
        let task = ProcessBuilder::new("rot", 100.0)
            .stream_data("in", 100.0)
            .stream_resource("cpu", 1.0)
            .identity_output("out")
            .build();
        let t = wf.add_node(
            task,
            vec![DataSource::ProcessOutput { node: d, output: 0 }],
            vec![ResourceSource::Fixed(PwPoly::constant(1.0))],
            StartRule::default(),
        );
        let wa = analyze(&wf, &SolverOpts::default()).unwrap();
        assert!(close(wa.analyses[d].finish_time.unwrap(), 10.0));
        // pipelined: consumer tracks the download, finishing at ~10 too
        assert!(close(wa.analyses[t].finish_time.unwrap(), 10.0));
        assert!(close(wa.makespan.unwrap(), 10.0));
    }

    /// burst consumer cannot overlap: starts processing only when its input
    /// is complete.
    #[test]
    fn burst_chain_serializes() {
        let mut wf = Workflow::new();
        let d = wf.add_node(
            dl_proc("dl", 100.0),
            vec![DataSource::External(PwPoly::constant(100.0))],
            vec![ResourceSource::Fixed(PwPoly::constant(10.0))],
            StartRule::default(),
        );
        let rev = ProcessBuilder::new("rev", 100.0)
            .burst_data("in", 100.0)
            .stream_resource("cpu", 20.0)
            .identity_output("out")
            .build();
        let t = wf.add_node(
            rev,
            vec![DataSource::ProcessOutput { node: d, output: 0 }],
            vec![ResourceSource::Fixed(PwPoly::constant(1.0))],
            StartRule::default(),
        );
        let wa = analyze(&wf, &SolverOpts::default()).unwrap();
        // download done at 10, then 20 cpu-s at 1/s
        assert!(close(wa.analyses[t].finish_time.unwrap(), 30.0));
    }

    /// barrier start (paper's task 3).
    #[test]
    fn barrier_start() {
        let mut wf = Workflow::new();
        let a = ProcessBuilder::new("a", 10.0)
            .stream_resource("cpu", 10.0)
            .identity_output("out")
            .build();
        let na = wf.add_node(
            a,
            vec![],
            vec![ResourceSource::Fixed(PwPoly::constant(1.0))],
            StartRule::default(),
        );
        let b = ProcessBuilder::new("b", 10.0)
            .stream_data("in", 10.0)
            .stream_resource("cpu", 5.0)
            .build();
        let nb = wf.add_node(
            b,
            vec![DataSource::ProcessOutput { node: na, output: 0 }],
            vec![ResourceSource::Fixed(PwPoly::constant(1.0))],
            StartRule {
                at: 0.0,
                after: vec![na],
            },
        );
        let wa = analyze(&wf, &SolverOpts::default()).unwrap();
        assert!(close(wa.analyses[na].finish_time.unwrap(), 10.0));
        assert!(close(wa.analyses[nb].start_time, 10.0));
        assert!(close(wa.analyses[nb].finish_time.unwrap(), 15.0));
    }

    /// two downloads share a link pool: fraction + residual, with release.
    #[test]
    fn shared_pool_fraction_and_residual() {
        let mut wf = Workflow::new();
        let pool = wf.add_pool("link", PwPoly::constant(10.0));
        // dl1: 50 B at 50% of 10 B/s = 5 B/s -> done at 10
        let d1 = wf.add_node(
            dl_proc("dl1", 50.0),
            vec![DataSource::External(PwPoly::constant(50.0))],
            vec![ResourceSource::PoolFraction {
                pool,
                fraction: 0.5,
            }],
            StartRule::default(),
        );
        // dl2: 100 B, residual = 10 - consumption(dl1) = 5 until t=10, then 10
        let d2 = wf.add_node(
            dl_proc("dl2", 100.0),
            vec![DataSource::External(PwPoly::constant(100.0))],
            vec![ResourceSource::PoolResidual { pool }],
            StartRule::default(),
        );
        let wa = analyze_fixpoint(&wf, &SolverOpts::default(), 5).unwrap();
        assert!(close(wa.analyses[d1].finish_time.unwrap(), 10.0));
        // dl2: 5 B/s for 10 s = 50 B, remaining 50 B at 10 B/s -> t=15
        assert!(
            close(wa.analyses[d2].finish_time.unwrap(), 15.0),
            "{:?}",
            wa.analyses[d2].finish_time
        );
        assert!(close(wa.makespan.unwrap(), 15.0));
    }

    /// the *reverse* release: the fraction user's peer finishes first, so
    /// the fraction user is upgraded — requires the fixpoint.
    #[test]
    fn fixpoint_releases_fraction_user() {
        let mut wf = Workflow::new();
        let pool = wf.add_pool("link", PwPoly::constant(10.0));
        // d1: big download at a tiny fraction
        let d1 = wf.add_node(
            dl_proc("dl1", 200.0),
            vec![DataSource::External(PwPoly::constant(200.0))],
            vec![ResourceSource::PoolFraction {
                pool,
                fraction: 0.2,
            }],
            StartRule::default(),
        );
        // d2: small download on the residual (8 B/s) -> finishes at 12.5...
        let d2 = wf.add_node(
            dl_proc("dl2", 100.0),
            vec![DataSource::External(PwPoly::constant(100.0))],
            vec![ResourceSource::PoolResidual { pool }],
            StartRule::default(),
        );
        let wa = analyze_fixpoint(&wf, &SolverOpts::default(), 6).unwrap();
        let f2 = wa.analyses[d2].finish_time.unwrap();
        let f1 = wa.analyses[d1].finish_time.unwrap();
        // d2 runs at 8 B/s -> 12.5 s. d1: 2 B/s for 12.5 s = 25 B, then
        // 10 B/s for the remaining 175 B -> 12.5 + 17.5 = 30 s.
        assert!(close(f2, 12.5), "{f2}");
        assert!(close(f1, 30.0), "{f1}");
        assert!(wa.passes > 1);

        // single-pass (paper procedure) would NOT release d1:
        let single = analyze(&wf, &SolverOpts::default()).unwrap();
        assert!(close(single.analyses[d1].finish_time.unwrap(), 100.0));
    }

    /// unfinishable node propagates None makespan.
    #[test]
    fn makespan_none_when_stuck() {
        let mut wf = Workflow::new();
        let p = ProcessBuilder::new("a", 10.0).stream_data("in", 10.0).build();
        wf.add_node(
            p,
            vec![DataSource::External(PwPoly::constant(5.0))],
            vec![],
            StartRule::default(),
        );
        let wa = analyze(&wf, &SolverOpts::default()).unwrap();
        assert_eq!(wa.makespan, None);
    }

    /// diamond DAG: two parallel branches joined by a two-input process.
    #[test]
    fn diamond_join() {
        let mut wf = Workflow::new();
        let src = |name: &str, rate: f64| {
            (
                dl_proc(name, 100.0),
                vec![DataSource::External(PwPoly::constant(100.0))],
                vec![ResourceSource::Fixed(PwPoly::constant(rate))],
            )
        };
        let (p1, d1, r1) = src("a", 10.0);
        let a = wf.add_node(p1, d1, r1, StartRule::default());
        let (p2, d2, r2) = src("b", 5.0);
        let b = wf.add_node(p2, d2, r2, StartRule::default());
        let join = ProcessBuilder::new("join", 200.0)
            .custom_data("ina", &[(0.0, 0.0), (100.0, 200.0)])
            .custom_data("inb", &[(0.0, 0.0), (100.0, 200.0)])
            .stream_resource("cpu", 2.0)
            .identity_output("out")
            .build();
        let j = wf.add_node(
            join,
            vec![
                DataSource::ProcessOutput { node: a, output: 0 },
                DataSource::ProcessOutput { node: b, output: 0 },
            ],
            vec![ResourceSource::Fixed(PwPoly::constant(1.0))],
            StartRule {
                at: 0.0,
                after: vec![a, b],
            },
        );
        let wa = analyze(&wf, &SolverOpts::default()).unwrap();
        // a done at 10, b at 20; join starts at 20, all data ready,
        // cpu: 2 cpu-s at 1/s -> 22
        assert!(close(wa.analyses[j].start_time, 20.0));
        assert!(close(wa.analyses[j].finish_time.unwrap(), 22.0));
    }
}
