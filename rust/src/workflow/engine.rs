//! Workflow analysis: per-node BottleMod analyses chained through
//! `O_m(P(t))` output functions and shared resource pools (paper §3.4, §5.2).
//!
//! Pool semantics mirror the paper's evaluation setup:
//!
//! * a `PoolFraction` user is rate-limited to `fraction · capacity` while
//!   any other consumer of the pool is still running, and upgraded to the
//!   full capacity once all others finished (the appendix's
//!   `nft replace rule` releasing the bandwidth to the other task);
//! * after a pool user is analyzed, its *actual* consumption
//!   `P'(t)·R'(P(t))` is charged against the pool retrospectively
//!   ("the consumed data rate is set for the process retrospectively",
//!   §5.2), and `PoolResidual` users receive what is left.
//!
//! Because "once all others finished" can refer to nodes analyzed *later*
//! in topological order, [`analyze_fixpoint`] iterates single passes with
//! finish-time hints until the schedule stabilizes (2–3 passes in
//! practice). [`analyze`] is a single pass with no hints — exactly the
//! paper's §5.2 procedure, sufficient when prioritized consumers are
//! analyzed first.
//!
//! Fixpoint passes after the first run on a **worklist**: only nodes whose
//! materialized inputs can have changed since the previous pass (the dirty
//! closure over graph successors and shared-pool co-membership, seeded by
//! bitwise finish-hint changes) are re-solved; every other node replays its
//! `Arc`'d previous result bit-identically. On a pool-free DAG the second
//! pass re-solves nothing — the stability confirmation is free. See
//! `docs/SCALING.md` for the correctness argument, and
//! [`analyze_fixpoint_full`] for the retained re-solve-everything oracle.
//!
//! # Invariants
//!
//! * Nodes are analyzed in Kahn topological order with node-id tie-breaks
//!   ([`Workflow::topo_order`]), so pool residual assignment — which depends
//!   on *analysis order* — is deterministic.
//! * Each node's solve is a **pure function** of `(Process, ProcessInputs,
//!   SolverOpts)`: the materialized `ProcessInputs` carry every upstream
//!   effect (output-over-time functions, pool fractions/residuals, barrier
//!   start times). This is what makes node-level memoization sound — see
//!   [`crate::runtime::cache`].
//! * Per-node analyses are stored as [`Arc<Analysis>`], so a cached (or
//!   merely repeated) analysis is shared, never deep-cloned.
//!
//! # Cost model
//!
//! One pass costs `Σ_nodes solve(node)` plus `O(E)` piecewise algebra to
//! materialize inputs; `solve` is event-driven, so the total is a function
//! of **model complexity** (pieces × limit changes), independent of bytes
//! moved (paper §6). The fixpoint multiplies that by the number of passes
//! (≤ `max_passes`, 2–3 in practice). With an [`AnalysisCache`] attached
//! ([`analyze_fixpoint_cached`]), any node whose materialized inputs are
//! bit-identical to a previously solved one — across passes *or* across
//! sweep scenarios — costs one content hash instead of one solve.

use std::sync::Arc;

use crate::model::process::ProcessInputs;
use crate::pwfn::PwPoly;
use crate::runtime::cache::{node_key, AnalysisCache, NodeSolve};
use crate::solver::{solve, Analysis, SolveError, SolverOpts};

use super::graph::{DataSource, GraphError, NodeSet, ResourceSource, Workflow};

/// Result of analyzing a whole workflow.
#[derive(Clone, Debug)]
pub struct WorkflowAnalysis {
    /// Per-node analyses, indexed like `Workflow::nodes`. `Arc`-shared so
    /// cache hits (and clones of this struct) never copy a `PwPoly`.
    pub analyses: Vec<Arc<Analysis>>,
    /// Materialized inputs each node was analyzed under (useful for the
    /// §3.3 metrics, which need the `I` functions).
    pub inputs: Vec<ProcessInputs>,
    /// Wall-clock completion of the whole workflow (`None` if any node
    /// never finishes).
    pub makespan: Option<f64>,
    /// Per-pool remaining capacity after all consumers were charged.
    pub pool_residuals: Vec<PwPoly>,
    /// Total solver events across all nodes (§6 cost accounting). The
    /// worklist fixpoint charges a reused (clean) node the same event
    /// count a re-solve would have produced, so this field is identical
    /// between the worklist and the full reference fixpoint.
    pub events: usize,
    /// Fixpoint passes used (1 for plain [`analyze`]).
    pub passes: usize,
    /// Worst error bound reported by piece budgeting
    /// ([`SolverOpts::piece_budget`]) across every coarsened input/demand
    /// function; `0.0` when budgeting is off or never triggered.
    pub budget_err: f64,
}

/// Workflow-level failure.
#[derive(Debug, Clone)]
pub enum WorkflowError {
    Graph(GraphError),
    Solve {
        node: usize,
        name: String,
        err: SolveError,
    },
    DepNeverFinishes { node: usize, dep: usize },
}

impl std::fmt::Display for WorkflowError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WorkflowError::Graph(e) => e.fmt(f),
            WorkflowError::Solve { node, name, err } => {
                write!(f, "node {node} ('{name}'): {err}")
            }
            WorkflowError::DepNeverFinishes { node, dep } => {
                write!(f, "node {node} depends on node {dep} which never finishes")
            }
        }
    }
}

impl std::error::Error for WorkflowError {}

impl From<GraphError> for WorkflowError {
    fn from(e: GraphError) -> Self {
        WorkflowError::Graph(e)
    }
}

/// Everything one pass produces, `Arc`-shared so the worklist scheduler
/// can carry clean nodes into the next pass without deep copies.
struct PassState {
    analyses: Vec<Arc<Analysis>>,
    inputs: Vec<Arc<ProcessInputs>>,
    solves: Vec<Option<Arc<NodeSolve>>>,
    /// Per-node pool charges: `(pool id, simplified demand)`, in resource-
    /// slot order. Clean nodes replay these bit-identically next pass.
    claims: Vec<Vec<(usize, Arc<PwPoly>)>>,
    /// Per-node worst piece-budget error bound (0.0 when off).
    budget_err: Vec<f64>,
}

/// One analysis pass. `finish_hints[i]` carries node `i`'s finish time from
/// a previous pass (used for pool release when `i` hasn't been analyzed yet
/// in this pass). With `cache`, each node's solve is memoized on a content
/// hash of its materialized inputs ([`node_key`]). With `reuse`, a node
/// *not* in the dirty set skips materialization and solving entirely and
/// replays the previous pass's `Arc`'d result — sound because a clean
/// node's materialized inputs are provably bit-identical to the previous
/// pass (see [`analyze_fixpoint`] and docs/SCALING.md).
///
/// Returns the pass state plus the solver events accounted to this pass
/// (reused nodes charge their stored event count, keeping the §6 cost
/// accounting identical to a full re-solve).
fn analyze_pass(
    wf: &Workflow,
    order: &[usize],
    consumers: &[Vec<usize>],
    opts: &SolverOpts,
    finish_hints: &[Option<f64>],
    cache: Option<&AnalysisCache>,
    reuse: Option<(&PassState, &NodeSet)>,
) -> Result<(PassState, usize), WorkflowError> {
    let n = wf.nodes.len();

    let mut analyses: Vec<Option<Arc<Analysis>>> = vec![None; n];
    // cached mode: the full NodeSolve per node, so downstream consumers and
    // pool charges reuse the precomputed output/demand functions
    let mut solves: Vec<Option<Arc<NodeSolve>>> = vec![None; n];
    // which outputs some consumer reads, and which resources feed a pool —
    // the slots a NodeSolve must carry under this wiring (anything else
    // would be derived work the cold path never does)
    let consumed_outputs: Vec<Vec<bool>> = if cache.is_some() {
        let mut used: Vec<Vec<bool>> = wf
            .nodes
            .iter()
            .map(|nd| vec![false; nd.process.outputs.len()])
            .collect();
        for nd in &wf.nodes {
            for s in &nd.data_sources {
                if let DataSource::ProcessOutput { node, output } = s {
                    used[*node][*output] = true;
                }
            }
        }
        used
    } else {
        vec![]
    };
    let pool_backed: Vec<Vec<bool>> = if cache.is_some() {
        wf.nodes
            .iter()
            .map(|nd| {
                nd.resource_sources
                    .iter()
                    .map(|s| !matches!(s, ResourceSource::Fixed(_)))
                    .collect()
            })
            .collect()
    } else {
        vec![]
    };
    let mut inputs_used: Vec<Option<Arc<ProcessInputs>>> = vec![None; n];
    let mut claims: Vec<Vec<(usize, Arc<PwPoly>)>> = vec![vec![]; n];
    let mut budget_errs: Vec<f64> = vec![0.0; n];
    // per-pool charged demand functions of already-analyzed consumers
    let mut pool_claims: Vec<Vec<Arc<PwPoly>>> = vec![vec![]; wf.pools.len()];
    let mut events = 0usize;

    for &i in order {
        let node = &wf.nodes[i];

        // ---- clean node: replay the previous pass bit-identically -------
        if let Some((prev, dirty)) = reuse {
            if !dirty.contains(i) {
                events += prev.analyses[i].events;
                analyses[i] = Some(prev.analyses[i].clone());
                solves[i] = prev.solves[i].clone();
                inputs_used[i] = Some(prev.inputs[i].clone());
                budget_errs[i] = prev.budget_err[i];
                for (pid, d) in &prev.claims[i] {
                    pool_claims[*pid].push(d.clone());
                }
                claims[i] = prev.claims[i].clone();
                continue;
            }
        }

        // ---- start time: barrier predecessors must have finished --------
        let mut start = node.start.at;
        for &d in &node.start.after {
            match analyses[d].as_ref().unwrap().finish_time {
                Some(f) => start = start.max(f),
                None => return Err(WorkflowError::DepNeverFinishes { node: i, dep: d }),
            }
        }

        // ---- data inputs -------------------------------------------------
        let mut data: Vec<PwPoly> = node
            .data_sources
            .iter()
            .map(|s| match s {
                DataSource::External(f) => f.clone(),
                DataSource::ProcessOutput { node: d, output } => {
                    // cached mode: `O_m(P(t))` was derived with the solve
                    // (the slot can be empty if the entry was derived under
                    // different wiring — fall back to the same expression)
                    let derived = solves[*d]
                        .as_ref()
                        .and_then(|ns| ns.outputs[*output].clone());
                    derived.unwrap_or_else(|| {
                        analyses[*d]
                            .as_ref()
                            .unwrap()
                            .output_over_time(&wf.nodes[*d].process, *output)
                    })
                }
            })
            .collect();

        // finish time of all *other* consumers of a pool, best knowledge:
        // current-pass analysis if available, else the hint from last pass
        let others_end = |pool: usize| -> Option<f64> {
            let mut end = 0.0f64;
            for &c in &consumers[pool] {
                if c == i {
                    continue;
                }
                let f = match analyses[c].as_ref() {
                    Some(a) => a.finish_time,
                    None => finish_hints[c],
                };
                match f {
                    Some(f) => end = end.max(f),
                    None => return None, // unknown/never: no release
                }
            }
            Some(end)
        };

        // ---- resource inputs ----------------------------------------------
        let mut resources: Vec<PwPoly> = node
            .resource_sources
            .iter()
            .map(|s| match s {
                ResourceSource::Fixed(f) => f.clone(),
                ResourceSource::PoolFraction { pool, fraction } => {
                    let cap = &wf.pools[*pool].capacity;
                    let frac_fn = cap.scale(*fraction);
                    match others_end(*pool) {
                        Some(end) if end > cap.x_min() && end.is_finite() => {
                            // fraction until the others are done, then full
                            concat(
                                frac_fn.clip(cap.x_min(), end),
                                cap.clip(end, f64::INFINITY),
                            )
                        }
                        Some(_) => cap.clone(), // no other consumers at all
                        None => frac_fn,
                    }
                }
                ResourceSource::PoolResidual { pool } => {
                    residual_capacity(&wf.pools[*pool].capacity, &pool_claims[*pool])
                }
            })
            .collect();

        // ---- opt-in piece budget (SolverOpts::piece_budget) -------------
        // Coarsen any materialized function over the cap *before* the key
        // is hashed, so cached and cold budgeted runs stay bit-identical.
        let mut node_err = 0.0f64;
        if opts.piece_budget > 0 {
            for f in data.iter_mut().chain(resources.iter_mut()) {
                if f.n_pieces() > opts.piece_budget {
                    let (g, e) = f.simplify_budget(opts.piece_budget, opts.piece_budget_err);
                    *f = g;
                    node_err = node_err.max(e);
                }
            }
        }

        let inputs = Arc::new(ProcessInputs {
            data,
            resources,
            start_time: start,
        });
        // `solve` is pure in (process, inputs, opts); a cache hit returns
        // the bit-identical Arc'd analysis of an earlier solve, so cached
        // and cold runs are indistinguishable in every output field
        // (including the per-node event counts folded into `events`).
        let solve_fresh = |inputs: &ProcessInputs| -> Result<Analysis, WorkflowError> {
            solve(&node.process, inputs, opts).map_err(|err| WorkflowError::Solve {
                node: i,
                name: node.process.name.clone(),
                err,
            })
        };
        let analysis: Arc<Analysis> = match cache {
            Some(c) => {
                let key = node_key(&node.process, &*inputs, opts);
                let ns = match c.get(key) {
                    Some(hit) => hit,
                    None => {
                        let fresh = Arc::new(NodeSolve::derive(
                            &node.process,
                            Arc::new(solve_fresh(&*inputs)?),
                            &consumed_outputs[i],
                            &pool_backed[i],
                        ));
                        c.insert(key, fresh.clone());
                        fresh
                    }
                };
                let analysis = ns.analysis.clone();
                solves[i] = Some(ns);
                analysis
            }
            None => Arc::new(solve_fresh(&*inputs)?),
        };
        events += analysis.events;

        // charge pool consumption retrospectively
        for (l, s) in node.resource_sources.iter().enumerate() {
            let pid = match s {
                ResourceSource::PoolFraction { pool, .. } => Some(*pool),
                ResourceSource::PoolResidual { pool } => Some(*pool),
                ResourceSource::Fixed(_) => None,
            };
            if let Some(pid) = pid {
                // cached mode: the simplified demand was derived with the
                // solve (empty slot = entry from different wiring: fall
                // back to the same expression)
                let mut demand = solves[i]
                    .as_ref()
                    .and_then(|ns| ns.demands[l].clone())
                    .unwrap_or_else(|| {
                        analysis.resource_demand(&node.process, l).simplify()
                    });
                if opts.piece_budget > 0 && demand.n_pieces() > opts.piece_budget {
                    let (g, e) = demand.simplify_budget(opts.piece_budget, opts.piece_budget_err);
                    demand = g;
                    node_err = node_err.max(e);
                }
                let demand = Arc::new(demand);
                pool_claims[pid].push(demand.clone());
                claims[i].push((pid, demand));
            }
        }

        budget_errs[i] = node_err;
        inputs_used[i] = Some(inputs);
        analyses[i] = Some(analysis);
    }

    Ok((
        PassState {
            analyses: analyses.into_iter().map(Option::unwrap).collect(),
            inputs: inputs_used.into_iter().map(Option::unwrap).collect(),
            solves,
            claims,
            budget_err: budget_errs,
        },
        events,
    ))
}

/// Build the public [`WorkflowAnalysis`] from the final pass state.
/// Pool residuals are recomputed from the stored per-node claims in
/// analysis (topological) order — the same order the pass charged them,
/// so the k-way demand sum is bit-identical.
fn finalize(
    wf: &Workflow,
    order: &[usize],
    state: PassState,
    events: usize,
    passes: usize,
) -> WorkflowAnalysis {
    let mut makespan = Some(0.0f64);
    for a in &state.analyses {
        makespan = match (makespan, a.finish_time) {
            (Some(m), Some(f)) => Some(m.max(f)),
            _ => None,
        };
    }

    let mut per_pool: Vec<Vec<Arc<PwPoly>>> = vec![vec![]; wf.pools.len()];
    for &i in order {
        for (pid, d) in &state.claims[i] {
            per_pool[*pid].push(d.clone());
        }
    }
    let pool_residuals = wf
        .pools
        .iter()
        .enumerate()
        .map(|(pid, pool)| residual_capacity(&pool.capacity, &per_pool[pid]))
        .collect();

    let budget_err = state.budget_err.iter().fold(0.0f64, |m, e| m.max(*e));
    WorkflowAnalysis {
        analyses: state.analyses,
        // the final pass holds the only reference in the common case, so
        // this is a move, not a deep copy
        inputs: state
            .inputs
            .into_iter()
            .map(|a| Arc::try_unwrap(a).unwrap_or_else(|a| (*a).clone()))
            .collect(),
        makespan,
        pool_residuals,
        events,
        passes,
        budget_err,
    }
}

/// Single-pass analysis (the paper's §5.2 procedure).
pub fn analyze(wf: &Workflow, opts: &SolverOpts) -> Result<WorkflowAnalysis, WorkflowError> {
    wf.validate()?;
    let order = wf.topo_order()?;
    let consumers = wf.pool_consumers();
    let hints = vec![None; wf.nodes.len()];
    let (state, events) = analyze_pass(wf, &order, &consumers, opts, &hints, None, None)?;
    Ok(finalize(wf, &order, state, events, 1))
}

/// Fixpoint analysis: iterate passes, feeding each pass the previous pass's
/// finish times as pool-release hints, until the schedule stabilizes.
/// Needed when a pool consumer analyzed *earlier* in topological order is
/// released by one analyzed *later* (e.g. Fig 7 with small fractions, where
/// task 2's download finishes first and task 1's download inherits the full
/// link).
///
/// Passes after the first run on a **worklist**: only nodes whose
/// materialized inputs can have changed since the previous pass are
/// re-solved; every other node replays its `Arc`'d previous result. The
/// dirty set is the closure, over graph successors and shared-pool
/// co-membership, of the nodes observing a bitwise-changed finish hint —
/// finish hints being the only cross-pass input channel
/// ([`analyze_pass`]'s `others_end`). Clean nodes therefore provably
/// materialize bit-identical inputs, and `solve` is a pure function of
/// them, so the result is **bit-for-bit identical** to the full
/// re-solve-everything fixpoint ([`analyze_fixpoint_full`], kept as the
/// differential-testing oracle; `tests/generated_graphs.rs` pins the
/// equivalence across generated topologies).
pub fn analyze_fixpoint(
    wf: &Workflow,
    opts: &SolverOpts,
    max_passes: usize,
) -> Result<WorkflowAnalysis, WorkflowError> {
    analyze_fixpoint_cached(wf, opts, max_passes, None)
}

/// [`analyze_fixpoint`] with node-level memoization. Any node whose
/// `(Process, ProcessInputs, SolverOpts)` content-hash was already solved —
/// in an earlier pass of this call, or in *any* earlier workflow sharing the
/// cache (the sweep engine's case) — reuses the `Arc`'d cached analysis.
/// Results are bit-for-bit identical to the uncached path.
pub fn analyze_fixpoint_cached(
    wf: &Workflow,
    opts: &SolverOpts,
    max_passes: usize,
    cache: Option<&AnalysisCache>,
) -> Result<WorkflowAnalysis, WorkflowError> {
    run_fixpoint(wf, opts, max_passes, cache, true)
}

/// The reference fixpoint: re-solves **every** node in **every** pass (the
/// pre-worklist behavior). Kept as the oracle for the worklist scheduler's
/// bit-for-bit differential tests; prefer [`analyze_fixpoint`] everywhere
/// else.
pub fn analyze_fixpoint_full(
    wf: &Workflow,
    opts: &SolverOpts,
    max_passes: usize,
) -> Result<WorkflowAnalysis, WorkflowError> {
    run_fixpoint(wf, opts, max_passes, None, false)
}

fn run_fixpoint(
    wf: &Workflow,
    opts: &SolverOpts,
    max_passes: usize,
    cache: Option<&AnalysisCache>,
    worklist: bool,
) -> Result<WorkflowAnalysis, WorkflowError> {
    wf.validate()?;
    let n = wf.nodes.len();
    let order = wf.topo_order()?;
    let consumers = wf.pool_consumers();
    let succ = wf.successors();
    let pools_of = wf.consumed_pools();

    let mut hints: Vec<Option<f64>> = vec![None; n];
    // bitwise hint changes from the previous pass — the dirty-set seeds
    let mut changed: Vec<bool> = vec![true; n];
    let mut state: Option<PassState> = None;
    let mut total_events = 0usize;
    let mut passes = 0usize;
    for pass in 0..max_passes.max(1) {
        let dirty = if worklist && pass > 0 {
            Some(dirty_from_changed(&changed, &pools_of, &consumers, &succ))
        } else {
            None
        };
        let reuse = match (&state, &dirty) {
            (Some(prev), Some(d)) => Some((prev, d)),
            _ => None,
        };
        let (st, ev) = analyze_pass(wf, &order, &consumers, opts, &hints, cache, reuse)?;
        total_events += ev;
        passes = pass + 1;
        let new_hints: Vec<Option<f64>> = st.analyses.iter().map(|a| a.finish_time).collect();
        // exact comparison drives the next dirty set (bit-for-bit
        // soundness); the tolerance comparison below only decides when to
        // stop iterating, exactly as the reference fixpoint does
        for ((c, a), b) in changed.iter_mut().zip(&new_hints).zip(&hints) {
            *c = a != b;
        }
        let stable = new_hints
            .iter()
            .zip(hints.iter())
            .all(|(a, b)| match (a, b) {
                (Some(x), Some(y)) => (x - y).abs() < 1e-6 * (1.0 + x.abs()),
                (None, None) => true,
                _ => false,
            });
        hints = new_hints;
        state = Some(st);
        if stable {
            break;
        }
    }
    Ok(finalize(wf, &order, state.unwrap(), total_events, passes))
}

/// The worklist: nodes whose pass-`k` inputs can differ from pass `k−1`.
/// A changed finish hint is only readable through pool release
/// (`others_end`), so the seeds are the pool co-consumers of every changed
/// node; dirtiness then propagates to graph successors (data/barrier
/// inputs) and to pool co-members (release times and retrospective
/// charges), transitively.
fn dirty_from_changed(
    changed: &[bool],
    pools_of: &[Vec<usize>],
    consumers: &[Vec<usize>],
    succ: &[Vec<usize>],
) -> NodeSet {
    let n = changed.len();
    let mut set = NodeSet::empty(n);
    let mut stack: Vec<usize> = vec![];
    for (c, &ch) in changed.iter().enumerate() {
        if !ch {
            continue;
        }
        for &p in &pools_of[c] {
            stack.extend(consumers[p].iter().copied());
        }
    }
    while let Some(i) = stack.pop() {
        if set.contains(i) {
            continue;
        }
        set.insert(i);
        stack.extend(succ[i].iter().copied());
        for &p in &pools_of[i] {
            stack.extend(consumers[p].iter().copied());
        }
    }
    set
}

/// Remaining pool capacity after charging `claims`: one k-way demand sum
/// ([`PwPoly::sum_all`]) and a single clamp, instead of a subtract-and-
/// clamp chain that rebuilds the growing refinement per claim. Value-
/// identical for the nonnegative demand functions the engine charges
/// (`max(0, max(0, c − d₁) − d₂) = max(0, c − d₁ − d₂)` for `dᵢ ≥ 0`).
fn residual_capacity(capacity: &PwPoly, claims: &[Arc<PwPoly>]) -> PwPoly {
    if claims.is_empty() {
        return capacity.simplify();
    }
    let demands: Vec<&PwPoly> = claims.iter().map(|d| &**d).collect();
    capacity
        .sub(&PwPoly::sum_all(&demands))
        .max_with_zero()
        .simplify()
}

/// Concatenate two piecewise functions with adjacent domains.
fn concat(a: PwPoly, b: PwPoly) -> PwPoly {
    let mut breaks = a.breaks.clone();
    breaks.pop();
    let mut polys = a.polys.clone();
    breaks.extend_from_slice(&b.breaks);
    polys.extend_from_slice(&b.polys);
    PwPoly::new(breaks, polys)
}

impl WorkflowAnalysis {
    /// Per-node `(name, start, finish)` report rows.
    pub fn schedule(&self, wf: &Workflow) -> Vec<(String, f64, Option<f64>)> {
        wf.nodes
            .iter()
            .zip(self.analyses.iter())
            .map(|(n, a)| (n.process.name.clone(), a.start_time, a.finish_time))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::process::Process;
    use crate::model::ProcessBuilder;
    use crate::workflow::graph::StartRule;

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() < 1e-6 * (1.0 + a.abs().max(b.abs()))
    }

    fn dl_proc(name: &str, size: f64) -> Process {
        ProcessBuilder::new(name, size)
            .stream_data("remote", size)
            .stream_resource("link", size)
            .identity_output("file")
            .build()
    }

    /// download -> stream task pipeline: the two overlap (pipelined).
    #[test]
    fn pipelined_chain() {
        let mut wf = Workflow::new();
        let d = wf.add_node(
            dl_proc("dl", 100.0),
            vec![DataSource::External(PwPoly::constant(100.0))],
            vec![ResourceSource::Fixed(PwPoly::constant(10.0))],
            StartRule::default(),
        );
        let task = ProcessBuilder::new("rot", 100.0)
            .stream_data("in", 100.0)
            .stream_resource("cpu", 1.0)
            .identity_output("out")
            .build();
        let t = wf.add_node(
            task,
            vec![DataSource::ProcessOutput { node: d, output: 0 }],
            vec![ResourceSource::Fixed(PwPoly::constant(1.0))],
            StartRule::default(),
        );
        let wa = analyze(&wf, &SolverOpts::default()).unwrap();
        assert!(close(wa.analyses[d].finish_time.unwrap(), 10.0));
        // pipelined: consumer tracks the download, finishing at ~10 too
        assert!(close(wa.analyses[t].finish_time.unwrap(), 10.0));
        assert!(close(wa.makespan.unwrap(), 10.0));
    }

    /// burst consumer cannot overlap: starts processing only when its input
    /// is complete.
    #[test]
    fn burst_chain_serializes() {
        let mut wf = Workflow::new();
        let d = wf.add_node(
            dl_proc("dl", 100.0),
            vec![DataSource::External(PwPoly::constant(100.0))],
            vec![ResourceSource::Fixed(PwPoly::constant(10.0))],
            StartRule::default(),
        );
        let rev = ProcessBuilder::new("rev", 100.0)
            .burst_data("in", 100.0)
            .stream_resource("cpu", 20.0)
            .identity_output("out")
            .build();
        let t = wf.add_node(
            rev,
            vec![DataSource::ProcessOutput { node: d, output: 0 }],
            vec![ResourceSource::Fixed(PwPoly::constant(1.0))],
            StartRule::default(),
        );
        let wa = analyze(&wf, &SolverOpts::default()).unwrap();
        // download done at 10, then 20 cpu-s at 1/s
        assert!(close(wa.analyses[t].finish_time.unwrap(), 30.0));
    }

    /// barrier start (paper's task 3).
    #[test]
    fn barrier_start() {
        let mut wf = Workflow::new();
        let a = ProcessBuilder::new("a", 10.0)
            .stream_resource("cpu", 10.0)
            .identity_output("out")
            .build();
        let na = wf.add_node(
            a,
            vec![],
            vec![ResourceSource::Fixed(PwPoly::constant(1.0))],
            StartRule::default(),
        );
        let b = ProcessBuilder::new("b", 10.0)
            .stream_data("in", 10.0)
            .stream_resource("cpu", 5.0)
            .build();
        let nb = wf.add_node(
            b,
            vec![DataSource::ProcessOutput { node: na, output: 0 }],
            vec![ResourceSource::Fixed(PwPoly::constant(1.0))],
            StartRule {
                at: 0.0,
                after: vec![na],
            },
        );
        let wa = analyze(&wf, &SolverOpts::default()).unwrap();
        assert!(close(wa.analyses[na].finish_time.unwrap(), 10.0));
        assert!(close(wa.analyses[nb].start_time, 10.0));
        assert!(close(wa.analyses[nb].finish_time.unwrap(), 15.0));
    }

    /// two downloads share a link pool: fraction + residual, with release.
    #[test]
    fn shared_pool_fraction_and_residual() {
        let mut wf = Workflow::new();
        let pool = wf.add_pool("link", PwPoly::constant(10.0));
        // dl1: 50 B at 50% of 10 B/s = 5 B/s -> done at 10
        let d1 = wf.add_node(
            dl_proc("dl1", 50.0),
            vec![DataSource::External(PwPoly::constant(50.0))],
            vec![ResourceSource::PoolFraction {
                pool,
                fraction: 0.5,
            }],
            StartRule::default(),
        );
        // dl2: 100 B, residual = 10 - consumption(dl1) = 5 until t=10, then 10
        let d2 = wf.add_node(
            dl_proc("dl2", 100.0),
            vec![DataSource::External(PwPoly::constant(100.0))],
            vec![ResourceSource::PoolResidual { pool }],
            StartRule::default(),
        );
        let wa = analyze_fixpoint(&wf, &SolverOpts::default(), 5).unwrap();
        assert!(close(wa.analyses[d1].finish_time.unwrap(), 10.0));
        // dl2: 5 B/s for 10 s = 50 B, remaining 50 B at 10 B/s -> t=15
        assert!(
            close(wa.analyses[d2].finish_time.unwrap(), 15.0),
            "{:?}",
            wa.analyses[d2].finish_time
        );
        assert!(close(wa.makespan.unwrap(), 15.0));
    }

    /// the *reverse* release: the fraction user's peer finishes first, so
    /// the fraction user is upgraded — requires the fixpoint.
    #[test]
    fn fixpoint_releases_fraction_user() {
        let mut wf = Workflow::new();
        let pool = wf.add_pool("link", PwPoly::constant(10.0));
        // d1: big download at a tiny fraction
        let d1 = wf.add_node(
            dl_proc("dl1", 200.0),
            vec![DataSource::External(PwPoly::constant(200.0))],
            vec![ResourceSource::PoolFraction {
                pool,
                fraction: 0.2,
            }],
            StartRule::default(),
        );
        // d2: small download on the residual (8 B/s) -> finishes at 12.5...
        let d2 = wf.add_node(
            dl_proc("dl2", 100.0),
            vec![DataSource::External(PwPoly::constant(100.0))],
            vec![ResourceSource::PoolResidual { pool }],
            StartRule::default(),
        );
        let wa = analyze_fixpoint(&wf, &SolverOpts::default(), 6).unwrap();
        let f2 = wa.analyses[d2].finish_time.unwrap();
        let f1 = wa.analyses[d1].finish_time.unwrap();
        // d2 runs at 8 B/s -> 12.5 s. d1: 2 B/s for 12.5 s = 25 B, then
        // 10 B/s for the remaining 175 B -> 12.5 + 17.5 = 30 s.
        assert!(close(f2, 12.5), "{f2}");
        assert!(close(f1, 30.0), "{f1}");
        assert!(wa.passes > 1);

        // single-pass (paper procedure) would NOT release d1:
        let single = analyze(&wf, &SolverOpts::default()).unwrap();
        assert!(close(single.analyses[d1].finish_time.unwrap(), 100.0));
    }

    /// unfinishable node propagates None makespan.
    #[test]
    fn makespan_none_when_stuck() {
        let mut wf = Workflow::new();
        let p = ProcessBuilder::new("a", 10.0).stream_data("in", 10.0).build();
        wf.add_node(
            p,
            vec![DataSource::External(PwPoly::constant(5.0))],
            vec![],
            StartRule::default(),
        );
        let wa = analyze(&wf, &SolverOpts::default()).unwrap();
        assert_eq!(wa.makespan, None);
    }

    /// A cached fixpoint run is bit-for-bit the uncached one, and a second
    /// identical run is answered (almost) entirely from the cache.
    #[test]
    fn cached_fixpoint_is_bit_identical() {
        let mut wf = Workflow::new();
        let pool = wf.add_pool("link", PwPoly::constant(10.0));
        let d1 = wf.add_node(
            dl_proc("dl1", 50.0),
            vec![DataSource::External(PwPoly::constant(50.0))],
            vec![ResourceSource::PoolFraction {
                pool,
                fraction: 0.5,
            }],
            StartRule::default(),
        );
        let d2 = wf.add_node(
            dl_proc("dl2", 100.0),
            vec![DataSource::External(PwPoly::constant(100.0))],
            vec![ResourceSource::PoolResidual { pool }],
            StartRule::default(),
        );
        let opts = SolverOpts::default();
        let cold = analyze_fixpoint(&wf, &opts, 5).unwrap();

        let cache = AnalysisCache::new();
        let warm = analyze_fixpoint_cached(&wf, &opts, 5, Some(&cache)).unwrap();
        assert_eq!(cold.analyses, warm.analyses);
        assert_eq!(cold.makespan, warm.makespan);
        assert_eq!(cold.events, warm.events);
        assert_eq!(cold.passes, warm.passes);
        assert!(close(warm.analyses[d1].finish_time.unwrap(), 10.0));
        assert!(close(warm.analyses[d2].finish_time.unwrap(), 15.0));

        // the multi-pass fixpoint already reuses stable nodes across passes
        let after_first = cache.stats();
        assert!(after_first.hits > 0, "cross-pass reuse expected");

        // a second identical run misses nothing
        cache.reset_counters();
        let again = analyze_fixpoint_cached(&wf, &opts, 5, Some(&cache)).unwrap();
        assert_eq!(again.analyses, cold.analyses);
        let s = cache.stats();
        assert_eq!(s.misses, 0, "fully warm run must not re-solve: {s:?}");
        assert!(s.hits > 0);
    }

    /// diamond DAG: two parallel branches joined by a two-input process.
    #[test]
    fn diamond_join() {
        let mut wf = Workflow::new();
        let src = |name: &str, rate: f64| {
            (
                dl_proc(name, 100.0),
                vec![DataSource::External(PwPoly::constant(100.0))],
                vec![ResourceSource::Fixed(PwPoly::constant(rate))],
            )
        };
        let (p1, d1, r1) = src("a", 10.0);
        let a = wf.add_node(p1, d1, r1, StartRule::default());
        let (p2, d2, r2) = src("b", 5.0);
        let b = wf.add_node(p2, d2, r2, StartRule::default());
        let join = ProcessBuilder::new("join", 200.0)
            .custom_data("ina", &[(0.0, 0.0), (100.0, 200.0)])
            .custom_data("inb", &[(0.0, 0.0), (100.0, 200.0)])
            .stream_resource("cpu", 2.0)
            .identity_output("out")
            .build();
        let j = wf.add_node(
            join,
            vec![
                DataSource::ProcessOutput { node: a, output: 0 },
                DataSource::ProcessOutput { node: b, output: 0 },
            ],
            vec![ResourceSource::Fixed(PwPoly::constant(1.0))],
            StartRule {
                at: 0.0,
                after: vec![a, b],
            },
        );
        let wa = analyze(&wf, &SolverOpts::default()).unwrap();
        // a done at 10, b at 20; join starts at 20, all data ready,
        // cpu: 2 cpu-s at 1/s -> 22
        assert!(close(wa.analyses[j].start_time, 20.0));
        assert!(close(wa.analyses[j].finish_time.unwrap(), 22.0));
    }

    /// A pooled workflow needing the fixpoint: the worklist scheduler's
    /// result must be bit-for-bit the full re-solve-everything oracle's.
    #[test]
    fn worklist_matches_full_fixpoint() {
        let mut wf = Workflow::new();
        let pool = wf.add_pool("link", PwPoly::constant(10.0));
        let d1 = wf.add_node(
            dl_proc("dl1", 200.0),
            vec![DataSource::External(PwPoly::constant(200.0))],
            vec![ResourceSource::PoolFraction {
                pool,
                fraction: 0.2,
            }],
            StartRule::default(),
        );
        let d2 = wf.add_node(
            dl_proc("dl2", 100.0),
            vec![DataSource::External(PwPoly::constant(100.0))],
            vec![ResourceSource::PoolResidual { pool }],
            StartRule::default(),
        );
        // downstream consumer off the pool: clean in later passes only if
        // its upstream chain is — exercises successor propagation
        let crunch = ProcessBuilder::new("crunch", 100.0)
            .stream_data("in", 100.0)
            .stream_resource("cpu", 50.0)
            .build();
        let c = wf.add_node(
            crunch,
            vec![DataSource::ProcessOutput { node: d2, output: 0 }],
            vec![ResourceSource::Fixed(PwPoly::constant(10.0))],
            StartRule::default(),
        );
        let opts = SolverOpts::default();
        let fast = analyze_fixpoint(&wf, &opts, 6).unwrap();
        let full = analyze_fixpoint_full(&wf, &opts, 6).unwrap();
        assert_eq!(fast.analyses, full.analyses);
        assert_eq!(fast.makespan, full.makespan);
        assert_eq!(fast.pool_residuals, full.pool_residuals);
        assert_eq!(fast.events, full.events);
        assert_eq!(fast.passes, full.passes);
        assert!(fast.passes > 1, "test must exercise multi-pass reuse");
        for i in [d1, d2, c] {
            assert_eq!(fast.inputs[i].data, full.inputs[i].data);
            assert_eq!(fast.inputs[i].resources, full.inputs[i].resources);
            assert_eq!(fast.inputs[i].start_time, full.inputs[i].start_time);
        }
    }

    /// Piece budgeting: a long staircase input gets coarsened, the error
    /// bound surfaces in `budget_err`, and the default (budget off) is
    /// bitwise unaffected.
    #[test]
    fn piece_budget_coarsens_and_reports() {
        // staircase arrival: 64 steps of 1 B each
        let mut pts = vec![(0.0, 0.0)];
        for k in 0..64 {
            let t = k as f64;
            pts.push((t + 0.5, k as f64));
            pts.push((t + 1.0, (k + 1) as f64));
        }
        let arrival = PwPoly::from_points(&pts);
        assert!(arrival.n_pieces() > 16);
        let mut wf = Workflow::new();
        wf.add_node(
            dl_proc("dl", 64.0),
            vec![DataSource::External(arrival)],
            vec![ResourceSource::Fixed(PwPoly::constant(1000.0))],
            StartRule::default(),
        );
        let exact = analyze_fixpoint(&wf, &SolverOpts::default(), 4).unwrap();
        assert_eq!(exact.budget_err, 0.0);
        let opts = SolverOpts {
            piece_budget: 8,
            piece_budget_err: 1e-9,
            ..SolverOpts::default()
        };
        let coarse = analyze_fixpoint(&wf, &opts, 4).unwrap();
        assert!(coarse.budget_err > 0.0 && coarse.budget_err.is_finite());
        for inp in &coarse.inputs {
            for f in inp.data.iter().chain(inp.resources.iter()) {
                assert!(f.n_pieces() <= 8, "budget violated: {}", f.n_pieces());
            }
        }
        // the link is fast: both finish at ~64 s (data-limited)
        let fe = exact.makespan.unwrap();
        let fc = coarse.makespan.unwrap();
        assert!((fe - fc).abs() <= 2.0, "exact {fe} vs budgeted {fc}");
    }

    /// Pool-free DAG: pass 2 is a free confirmation pass — the worklist
    /// re-solves nothing. Observable through the cache: pass 1 misses once
    /// per node, pass 2 replays without a single lookup. Event accounting
    /// still matches the full fixpoint (which re-solves everything twice).
    #[test]
    fn pool_free_confirmation_pass_is_free() {
        let mut wf = Workflow::new();
        let d = wf.add_node(
            dl_proc("dl", 100.0),
            vec![DataSource::External(PwPoly::constant(100.0))],
            vec![ResourceSource::Fixed(PwPoly::constant(10.0))],
            StartRule::default(),
        );
        let task = ProcessBuilder::new("rot", 100.0)
            .stream_data("in", 100.0)
            .stream_resource("cpu", 1.0)
            .build();
        wf.add_node(
            task,
            vec![DataSource::ProcessOutput { node: d, output: 0 }],
            vec![ResourceSource::Fixed(PwPoly::constant(1.0))],
            StartRule::default(),
        );
        let opts = SolverOpts::default();
        let one = analyze(&wf, &opts).unwrap();
        let cache = AnalysisCache::new();
        let fx = analyze_fixpoint_cached(&wf, &opts, 6, Some(&cache)).unwrap();
        assert_eq!(fx.passes, 2);
        let s = cache.stats();
        assert_eq!(s.misses, 2, "pass 1 solves each node once: {s:?}");
        assert_eq!(s.hits, 0, "confirmation pass must not even hash: {s:?}");
        // clean replays charge their stored event counts, so accounting
        // matches the full fixpoint exactly
        let full = analyze_fixpoint_full(&wf, &opts, 6).unwrap();
        assert_eq!(fx.events, full.events);
        assert_eq!(fx.events, 2 * one.events);
        assert_eq!(fx.analyses, full.analyses);
    }
}
