//! The paper's evaluation workflow (Fig 5) as a BottleMod model (§5.2).
//!
//! Five processes: two downloads sharing the 100 Mbit/s link, the three
//! ffmpeg tasks (reverse / rotate / mux). All constants are the paper's
//! published measurements:
//!
//! * input video: 1,137,486,559 bytes; a full-rate direct download takes
//!   89 s ⇒ net link rate ≈ 97.51 Mibit/s ≈ 12.78 MB/s;
//! * task 1 (reverse): burst data requirement (all input before any
//!   output), 80 MB output, 82 s of encode CPU spread over the output
//!   (the 26 s of read+decode overlap the much slower download and are
//!   charged in the virtual testbed, not the model — see DESIGN.md);
//! * task 2 (rotate): stream task, 1.1 GB copied output, 5 s local
//!   execution time spread over progress (never binding behind a download);
//! * task 3 (mux): starts after tasks 1 and 2 complete (barrier), 3 s.
//!
//! Progress metric: output bytes, with identity output functions — exactly
//! the paper's choice.

use crate::model::{Process, ProcessBuilder};
use crate::pwfn::PwPoly;
use crate::runtime::sweep::SweepModel;
use crate::util::Json;
use crate::workflow::graph::{DataSource, NodeSet, ResourceSource, StartRule, Workflow};

/// Paper's measured constants (all sizes in bytes, times in seconds).
#[derive(Clone, Debug)]
pub struct VideoScenario {
    /// Input video size (1,137,486,559 B).
    pub input_size: f64,
    /// Task 1 output size (80 MB).
    pub t1_output: f64,
    /// Net shared-link rate in bytes/s (input_size / 89 s ≈ 12.78 MB/s).
    pub link_rate: f64,
    /// Task 1 encode CPU seconds (82 s).
    pub t1_cpu: f64,
    /// Task 1 read+decode CPU seconds (26 s; testbed only).
    pub t1_decode_cpu: f64,
    /// Task 2 local execution seconds (5 s).
    pub t2_time: f64,
    /// Task 3 local execution seconds (3 s).
    pub t3_time: f64,
    /// Fraction of the link initially assigned to task 1's download.
    pub frac_task1: f64,
    /// Task-model variant: model task 2 as a burst consumer (all input
    /// before any output) instead of the paper's stream model.
    pub t2_burst: bool,
}

impl Default for VideoScenario {
    fn default() -> Self {
        let input_size = 1_137_486_559.0;
        VideoScenario {
            input_size,
            t1_output: 80e6,
            link_rate: input_size / 89.0,
            t1_cpu: 82.0,
            t1_decode_cpu: 26.0,
            t2_time: 5.0,
            t3_time: 3.0,
            frac_task1: 0.5,
            t2_burst: false,
        }
    }
}

/// One scenario variation for a sweep batch: the knobs the paper's "what
/// if" analyses turn (link prioritization, input rate, data volume,
/// resource speed) plus task-model variants. Applied to a base
/// [`VideoScenario`] via [`VideoScenario::perturbed`].
///
/// Each variant knows which workflow nodes it invalidates
/// ([`Perturbation::dirty_set`]); everything outside that set is
/// bit-identical to the base scenario's analysis and can be served from the
/// [`crate::runtime::cache::AnalysisCache`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Perturbation {
    /// Leave the base model untouched — the baseline scenario of a batch.
    /// The only knob every workflow supports (fixed spec/trace models
    /// accept nothing else); its dirty set is empty.
    Identity,
    /// Set the link fraction assigned to task 1's download (Fig 7 x-axis).
    Fraction(f64),
    /// Scale the shared link's data rate (input-rate variant).
    LinkRateScale(f64),
    /// Scale the input data volume (the §6 scaling axis).
    InputScale(f64),
    /// Scale every task's CPU/IO cost (resource-demand variant).
    CpuScale(f64),
    /// Scale only task 1's encode CPU seconds — a single-node perturbation
    /// (its dirty cone is `{task1, task3}`; both downloads and task 2 stay
    /// cache-clean).
    Task1CpuScale(f64),
    /// Scale only task 2's local execution seconds (dirty cone
    /// `{task2, task3}`).
    Task2TimeScale(f64),
    /// Scale only task 3's mux seconds — the smallest possible dirty set:
    /// the sink node alone.
    Task3TimeScale(f64),
    /// Swap task 2's stream data requirement for a burst requirement
    /// (task-model variant).
    Task2Burst,
}

/// Node ids of the built workflow.
#[derive(Clone, Copy, Debug)]
pub struct VideoNodes {
    pub dl1: usize,
    pub dl2: usize,
    pub task1: usize,
    pub task2: usize,
    pub task3: usize,
    pub link_pool: usize,
}

impl Perturbation {
    /// Every wire kind, in declaration order — the full perturbation
    /// vocabulary of the protocol (`docs/SERVICE.md`).
    pub const ALL_KINDS: [&'static str; 9] = [
        "identity",
        "fraction",
        "link_rate_scale",
        "input_scale",
        "cpu_scale",
        "task1_cpu_scale",
        "task2_time_scale",
        "task3_time_scale",
        "task2_burst",
    ];

    /// Construct the variant for a wire `kind` carrying `value` (the
    /// valueless kinds ignore it). `None` for unknown kinds.
    pub fn with_value(kind: &str, value: f64) -> Option<Perturbation> {
        Some(match kind {
            "identity" => Perturbation::Identity,
            "fraction" => Perturbation::Fraction(value),
            "link_rate_scale" => Perturbation::LinkRateScale(value),
            "input_scale" => Perturbation::InputScale(value),
            "cpu_scale" => Perturbation::CpuScale(value),
            "task1_cpu_scale" => Perturbation::Task1CpuScale(value),
            "task2_time_scale" => Perturbation::Task2TimeScale(value),
            "task3_time_scale" => Perturbation::Task3TimeScale(value),
            "task2_burst" => Perturbation::Task2Burst,
            _ => return None,
        })
    }

    /// The canonical near-no-op probe for a kind: scale knobs at `1.0`,
    /// the link fraction at the scenarios' base `0.5` split. Used to test
    /// whether a model exposes a knob without actually moving it, and as
    /// the stencil midpoint of `crate::sense`.
    pub fn probe(kind: &str) -> Option<Perturbation> {
        let v = if kind == "fraction" { 0.5 } else { 1.0 };
        Perturbation::with_value(kind, v)
    }

    /// The knob vocabulary `model` accepts, in declaration order —
    /// probing [`SweepModel::build_perturbed`] with each kind's canonical
    /// probe. Backs the `sweep` op's structured `bad_request` detail (a
    /// rejected knob lists the valid vocabulary) and the sensitivity
    /// report's knob enumeration.
    pub fn applicable_kinds(model: &dyn SweepModel) -> Vec<&'static str> {
        Perturbation::ALL_KINDS
            .iter()
            .copied()
            .filter(|kind| {
                Perturbation::probe(kind)
                    .map(|p| model.build_perturbed(&p).is_ok())
                    .unwrap_or(false)
            })
            .collect()
    }

    /// The wire tag of this variant — the `"kind"` field of the JSON
    /// encoding, and the vocabulary of `docs/SERVICE.md`'s sweep op.
    pub fn kind(&self) -> &'static str {
        match self {
            Perturbation::Identity => "identity",
            Perturbation::Fraction(_) => "fraction",
            Perturbation::LinkRateScale(_) => "link_rate_scale",
            Perturbation::InputScale(_) => "input_scale",
            Perturbation::CpuScale(_) => "cpu_scale",
            Perturbation::Task1CpuScale(_) => "task1_cpu_scale",
            Perturbation::Task2TimeScale(_) => "task2_time_scale",
            Perturbation::Task3TimeScale(_) => "task3_time_scale",
            Perturbation::Task2Burst => "task2_burst",
        }
    }

    /// The numeric payload (`None` for the valueless `identity` /
    /// `task2_burst` kinds).
    pub fn value(&self) -> Option<f64> {
        match self {
            Perturbation::Identity | Perturbation::Task2Burst => None,
            Perturbation::Fraction(v)
            | Perturbation::LinkRateScale(v)
            | Perturbation::InputScale(v)
            | Perturbation::CpuScale(v)
            | Perturbation::Task1CpuScale(v)
            | Perturbation::Task2TimeScale(v)
            | Perturbation::Task3TimeScale(v) => Some(*v),
        }
    }

    /// The wire encoding: `{"kind": "...", "value": x}` (`value` omitted
    /// for valueless kinds). [`Perturbation::from_json`] inverts it
    /// bit-for-bit.
    pub fn to_json(&self) -> Json {
        match self.value() {
            Some(v) => Json::obj(vec![
                ("kind", Json::Str(self.kind().to_string())),
                ("value", Json::Num(v)),
            ]),
            None => Json::obj(vec![("kind", Json::Str(self.kind().to_string()))]),
        }
    }

    /// Decode the wire encoding. Unknown kinds and missing/non-numeric
    /// values are `Err` (the API boundary maps them to a structured
    /// `bad_request`) — never a panic.
    pub fn from_json(j: &Json) -> Result<Perturbation, String> {
        let kind = j
            .get("kind")
            .as_str()
            .ok_or_else(|| "perturbation needs a string 'kind' field".to_string())?;
        let value = || {
            j.get("value")
                .as_f64()
                .ok_or_else(|| format!("perturbation kind '{kind}' needs a numeric 'value' field"))
        };
        Ok(match kind {
            "identity" => Perturbation::Identity,
            "fraction" => Perturbation::Fraction(value()?),
            "link_rate_scale" => Perturbation::LinkRateScale(value()?),
            "input_scale" => Perturbation::InputScale(value()?),
            "cpu_scale" => Perturbation::CpuScale(value()?),
            "task1_cpu_scale" => Perturbation::Task1CpuScale(value()?),
            "task2_time_scale" => Perturbation::Task2TimeScale(value()?),
            "task3_time_scale" => Perturbation::Task3TimeScale(value()?),
            "task2_burst" => Perturbation::Task2Burst,
            other => return Err(format!("unknown perturbation kind '{other}'")),
        })
    }

    /// The set of nodes whose analyses this perturbation can change — the
    /// perturbation's *seed* nodes plus their downstream dependency cone
    /// ([`Workflow::downstream_closure`]). Pool-level knobs (fraction, link
    /// rate) seed **every consumer of the pool**: pool capacity is shared,
    /// consumption is charged retrospectively, and finish-time release
    /// couples all users, so no pool peer can be assumed clean.
    ///
    /// Nodes *outside* the dirty set are guaranteed to materialize
    /// bit-identical solver inputs under the perturbed scenario, so the
    /// sweep planner can count on the cache serving them.
    pub fn dirty_set(&self, wf: &Workflow, nodes: &VideoNodes) -> NodeSet {
        let seeds: Vec<usize> = match self {
            Perturbation::Identity => vec![],
            // pool knobs couple every consumer of the link pool
            Perturbation::Fraction(_) | Perturbation::LinkRateScale(_) => {
                wf.pool_consumers()[nodes.link_pool].clone()
            }
            // the §6 axis rescales every process model
            Perturbation::InputScale(_) => (0..wf.nodes.len()).collect(),
            Perturbation::CpuScale(_) => {
                vec![nodes.task1, nodes.task2, nodes.task3]
            }
            Perturbation::Task1CpuScale(_) => vec![nodes.task1],
            Perturbation::Task2TimeScale(_) => vec![nodes.task2],
            Perturbation::Task3TimeScale(_) => vec![nodes.task3],
            Perturbation::Task2Burst => vec![nodes.task2],
        };
        wf.downstream_closure(&seeds)
    }
}

impl VideoScenario {
    /// Scale the scenario to a different input size (the §6 performance
    /// comparison sweeps this; BottleMod's analysis cost must stay flat).
    pub fn with_input_size(mut self, bytes: f64) -> Self {
        let scale = bytes / self.input_size;
        self.input_size = bytes;
        self.t1_output *= scale;
        // keep the *link rate* fixed (same testbed), so durations scale
        self.t1_cpu *= scale;
        self.t2_time *= scale;
        self.t3_time *= scale;
        self
    }

    pub fn with_fraction(mut self, f: f64) -> Self {
        self.frac_task1 = f;
        self
    }

    /// Apply one sweep perturbation, returning the varied scenario. The
    /// receiver is the immutable base model a sweep batch shares across
    /// workers; every variant is a cheap value-level copy.
    pub fn perturbed(&self, p: &Perturbation) -> VideoScenario {
        let mut sc = self.clone();
        match *p {
            Perturbation::Identity => {}
            Perturbation::Fraction(f) => sc.frac_task1 = f,
            Perturbation::LinkRateScale(s) => sc.link_rate *= s,
            Perturbation::InputScale(s) => {
                sc = sc.with_input_size(self.input_size * s);
            }
            Perturbation::CpuScale(s) => {
                sc.t1_cpu *= s;
                sc.t1_decode_cpu *= s;
                sc.t2_time *= s;
                sc.t3_time *= s;
            }
            Perturbation::Task1CpuScale(s) => {
                sc.t1_cpu *= s;
                sc.t1_decode_cpu *= s;
            }
            Perturbation::Task2TimeScale(s) => sc.t2_time *= s,
            Perturbation::Task3TimeScale(s) => sc.t3_time *= s,
            Perturbation::Task2Burst => sc.t2_burst = true,
        }
        sc
    }

    /// A download is a process whose single resource is the link data rate:
    /// one byte of link capacity per byte of output (paper §5.2).
    fn download(&self, name: &str) -> Process {
        ProcessBuilder::new(name, self.input_size)
            .stream_data("remote-file", self.input_size)
            .stream_resource("link", self.input_size)
            .identity_output("file")
            .build()
    }

    /// Build the Fig 5 workflow.
    pub fn build(&self) -> (Workflow, VideoNodes) {
        let mut wf = Workflow::new();
        let link_pool = wf.add_pool("link", PwPoly::constant(self.link_rate));

        // the remote file is fully available on the webserver from t=0
        let remote = DataSource::External(PwPoly::constant(self.input_size));

        let dl1 = wf.add_node(
            self.download("dl-task1"),
            vec![remote.clone()],
            vec![ResourceSource::PoolFraction {
                pool: link_pool,
                fraction: self.frac_task1,
            }],
            StartRule::default(),
        );
        let dl2 = wf.add_node(
            self.download("dl-task2"),
            vec![remote],
            vec![ResourceSource::PoolResidual { pool: link_pool }],
            StartRule::default(),
        );

        // task 1: reverse — burst input, encode CPU spread over output
        let t1 = ProcessBuilder::new("task1-reverse", self.t1_output)
            .burst_data("video", self.input_size)
            .stream_resource("cpu", self.t1_cpu)
            .identity_output("reversed")
            .build();
        let task1 = wf.add_node(
            t1,
            vec![DataSource::ProcessOutput {
                node: dl1,
                output: 0,
            }],
            vec![ResourceSource::Fixed(PwPoly::constant(1.0))],
            StartRule::default(),
        );

        // task 2: rotate — pure stream by default, burst under the
        // Task2Burst model variant
        let t2b = ProcessBuilder::new("task2-rotate", self.input_size);
        let t2b = if self.t2_burst {
            t2b.burst_data("video", self.input_size)
        } else {
            t2b.stream_data("video", self.input_size)
        };
        let t2 = t2b
            .stream_resource("io", self.t2_time)
            .identity_output("rotated")
            .build();
        let task2 = wf.add_node(
            t2,
            vec![DataSource::ProcessOutput {
                node: dl2,
                output: 0,
            }],
            vec![ResourceSource::Fixed(PwPoly::constant(1.0))],
            StartRule::default(),
        );

        // task 3: mux — starts after both complete (paper §5.1)
        let t3_out = self.t1_output + self.input_size;
        let t3 = ProcessBuilder::new("task3-mux", t3_out)
            .custom_data("reversed", &[(0.0, 0.0), (self.t1_output, t3_out)])
            .custom_data("rotated", &[(0.0, 0.0), (self.input_size, t3_out)])
            .stream_resource("io", self.t3_time)
            .identity_output("result")
            .build();
        let task3 = wf.add_node(
            t3,
            vec![
                DataSource::ProcessOutput {
                    node: task1,
                    output: 0,
                },
                DataSource::ProcessOutput {
                    node: task2,
                    output: 0,
                },
            ],
            vec![ResourceSource::Fixed(PwPoly::constant(1.0))],
            StartRule {
                at: 0.0,
                after: vec![task1, task2],
            },
        );

        (
            wf,
            VideoNodes {
                dl1,
                dl2,
                task1,
                task2,
                task3,
                link_pool,
            },
        )
    }
}

/// A genomics-flavoured evaluation workflow (the paper's intro motivates
/// genome analysis): per sample, a sequencer dump is downloaded, QC-filtered
/// (stream), and aligned (burst — the aligner indexes the full sample
/// first); variants are called from all alignments (burst join) and
/// summarized. Two samples share the ingest link; QC/align/call share a CPU
/// pool. Used by the conformance tests and as a second workload for the
/// sweep engine.
#[derive(Clone, Debug)]
pub struct GenomicsScenario {
    /// Raw reads per sample (bytes).
    pub sample_bytes: f64,
    /// QC output per sample (bytes).
    pub filtered_bytes: f64,
    /// Alignment output per sample (bytes).
    pub bam_bytes: f64,
    /// Called-variant output (bytes).
    pub vcf_bytes: f64,
    /// Shared ingest-link rate (bytes/s).
    pub link_rate: f64,
    /// Shared CPU pool capacity (cores).
    pub cores: f64,
    /// Ingest-link fraction initially assigned to sample 0.
    pub frac_sample1: f64,
    /// Multiplier on every task's CPU-seconds cost (the
    /// [`Perturbation::CpuScale`] knob).
    pub cpu_scale: f64,
}

impl Default for GenomicsScenario {
    fn default() -> Self {
        GenomicsScenario {
            sample_bytes: 4e9,
            filtered_bytes: 3e9,
            bam_bytes: 1.5e9,
            vcf_bytes: 50e6,
            link_rate: 100e6,
            cores: 8.0,
            frac_sample1: 0.5,
            cpu_scale: 1.0,
        }
    }
}

impl GenomicsScenario {
    pub fn with_fraction(mut self, f: f64) -> Self {
        self.frac_sample1 = f;
        self
    }

    /// Apply one sweep perturbation. The genomics model exposes the
    /// *generic* knobs — `identity`, `fraction` (ingest-link split),
    /// `link_rate_scale` (ingest pool capacity), `input_scale` (sample
    /// volume) and `cpu_scale` (CPU-seconds cost) — and rejects the
    /// video-specific per-task knobs with a descriptive `Err` the API
    /// boundary turns into a structured `bad_request`.
    pub fn perturbed(&self, p: &Perturbation) -> Result<GenomicsScenario, String> {
        let mut sc = self.clone();
        match *p {
            Perturbation::Identity => {}
            Perturbation::Fraction(f) => sc.frac_sample1 = f,
            Perturbation::LinkRateScale(s) => sc.link_rate *= s,
            Perturbation::InputScale(s) => {
                sc.sample_bytes *= s;
                sc.filtered_bytes *= s;
                sc.bam_bytes *= s;
                sc.vcf_bytes *= s;
            }
            Perturbation::CpuScale(s) => sc.cpu_scale *= s,
            other => {
                return Err(format!(
                    "perturbation '{}' applies to the video workflow only",
                    other.kind()
                ))
            }
        }
        Ok(sc)
    }

    /// Planner hint (ordering only — supersets are always safe, results
    /// never depend on it): nodes whose analyses `p` can change in the
    /// built workflow. Pool knobs dirty that pool's consumers plus their
    /// cones; the global scale knobs dirty everything.
    pub fn dirty_nodes(&self, wf: &Workflow, p: &Perturbation) -> NodeSet {
        // pool ids by construction order in `build`: 0 = ingest-link, 1 = cpu
        let seeds: Vec<usize> = match p {
            Perturbation::Identity => vec![],
            Perturbation::Fraction(_) | Perturbation::LinkRateScale(_) => {
                wf.pool_consumers()[0].clone()
            }
            Perturbation::CpuScale(_) => wf.pool_consumers()[1].clone(),
            _ => (0..wf.nodes.len()).collect(),
        };
        wf.downstream_closure(&seeds)
    }

    /// Build the 8-process workflow (2 × ingest/qc/align + call + report).
    pub fn build(&self) -> Workflow {
        let mut wf = Workflow::new();
        let link = wf.add_pool("ingest-link", PwPoly::constant(self.link_rate));
        let cpu = wf.add_pool("cpu", PwPoly::constant(self.cores));
        let mut align_nodes = vec![];

        for s in 0..2 {
            let dl = ProcessBuilder::new(&format!("ingest-s{s}"), self.sample_bytes)
                .stream_data("remote", self.sample_bytes)
                .stream_resource("link", self.sample_bytes)
                .identity_output("raw")
                .build();
            let dl_n = wf.add_node(
                dl,
                vec![DataSource::External(PwPoly::constant(self.sample_bytes))],
                vec![if s == 0 {
                    ResourceSource::PoolFraction {
                        pool: link,
                        fraction: self.frac_sample1,
                    }
                } else {
                    ResourceSource::PoolResidual { pool: link }
                }],
                StartRule::default(),
            );

            let qc = ProcessBuilder::new(&format!("qc-s{s}"), self.filtered_bytes)
                .stream_data("raw", self.sample_bytes)
                .stream_resource("cpu", 120.0 * self.cpu_scale)
                .identity_output("filtered")
                .build();
            let qc_n = wf.add_node(
                qc,
                vec![DataSource::ProcessOutput {
                    node: dl_n,
                    output: 0,
                }],
                vec![ResourceSource::PoolFraction {
                    pool: cpu,
                    fraction: 2.0 / self.cores,
                }],
                StartRule::default(),
            );

            let align = ProcessBuilder::new(&format!("align-s{s}"), self.bam_bytes)
                .burst_data("filtered", self.filtered_bytes)
                .stream_resource("cpu", 600.0 * self.cpu_scale)
                .identity_output("bam")
                .build();
            let align_n = wf.add_node(
                align,
                vec![DataSource::ProcessOutput {
                    node: qc_n,
                    output: 0,
                }],
                vec![ResourceSource::PoolFraction {
                    pool: cpu,
                    fraction: 2.0 / self.cores,
                }],
                StartRule::default(),
            );
            align_nodes.push(align_n);
        }

        let call = ProcessBuilder::new("call-variants", self.vcf_bytes)
            .burst_data("bam0", self.bam_bytes)
            .burst_data("bam1", self.bam_bytes)
            .stream_resource("cpu", 300.0 * self.cpu_scale)
            .identity_output("vcf")
            .build();
        let call_n = wf.add_node(
            call,
            vec![
                DataSource::ProcessOutput {
                    node: align_nodes[0],
                    output: 0,
                },
                DataSource::ProcessOutput {
                    node: align_nodes[1],
                    output: 0,
                },
            ],
            vec![ResourceSource::PoolFraction {
                pool: cpu,
                fraction: 1.0,
            }],
            StartRule {
                at: 0.0,
                after: align_nodes.clone(),
            },
        );

        let report = ProcessBuilder::new("report", 1e6)
            .stream_data("vcf", self.vcf_bytes)
            .stream_resource("cpu", 5.0 * self.cpu_scale)
            .identity_output("html")
            .build();
        wf.add_node(
            report,
            vec![DataSource::ProcessOutput {
                node: call_n,
                output: 0,
            }],
            vec![ResourceSource::PoolFraction {
                pool: cpu,
                fraction: 1.0 / self.cores,
            }],
            StartRule::default(),
        );
        wf
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::SolverOpts;
    use crate::workflow::engine::analyze_fixpoint;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() < tol
    }

    /// 50:50 split: both downloads finish together at 2·89 = 178 s, task 1
    /// encodes for 82 s afterwards, task 3 adds 3 s ⇒ ≈ 263 s.
    #[test]
    fn fifty_fifty_prediction() {
        let sc = VideoScenario::default().with_fraction(0.5);
        let (wf, nodes) = sc.build();
        let wa = analyze_fixpoint(&wf, &SolverOpts::default(), 6).unwrap();
        let dl1 = wa.analyses[nodes.dl1].finish_time.unwrap();
        let t_total = wa.makespan.unwrap();
        assert!(close(dl1, 178.0, 1.0), "dl1 {dl1}");
        assert!(close(t_total, 263.0, 2.0), "total {t_total}");
    }

    /// 95 % split: dl1 at ~93.7 s, task 1 done ≈ 175.7, but task 2's
    /// download (with release) finishes at 2·89 = 178 ⇒ total ≈ 181.
    #[test]
    fn ninety_five_prediction() {
        let sc = VideoScenario::default().with_fraction(0.95);
        let (wf, nodes) = sc.build();
        let wa = analyze_fixpoint(&wf, &SolverOpts::default(), 6).unwrap();
        let dl1 = wa.analyses[nodes.dl1].finish_time.unwrap();
        let dl2 = wa.analyses[nodes.dl2].finish_time.unwrap();
        let total = wa.makespan.unwrap();
        assert!(close(dl1, 89.0 / 0.95, 1.0), "dl1 {dl1}");
        assert!(close(dl2, 178.0, 1.5), "dl2 {dl2}");
        assert!(close(total, 181.3, 2.5), "total {total}");
    }

    /// The headline: ≥93 % allocation is ≈ 32 % faster than 50:50.
    #[test]
    fn paper_headline_32_percent() {
        let mk = |f: f64| {
            let (wf, _) = VideoScenario::default().with_fraction(f).build();
            analyze_fixpoint(&wf, &SolverOpts::default(), 6)
                .unwrap()
                .makespan
                .unwrap()
        };
        let t50 = mk(0.50);
        let t93 = mk(0.93);
        let gain = 1.0 - t93 / t50;
        assert!(
            (0.28..0.36).contains(&gain),
            "expected ≈32% gain, got {:.1}% (t50={t50:.1}, t93={t93:.1})",
            gain * 100.0
        );
    }

    /// Low fractions: with bidirectional release both downloads still end
    /// at 178 s, so the total plateaus at the 50:50 value.
    #[test]
    fn low_fraction_plateau() {
        let mk = |f: f64| {
            let (wf, _) = VideoScenario::default().with_fraction(f).build();
            analyze_fixpoint(&wf, &SolverOpts::default(), 6)
                .unwrap()
                .makespan
                .unwrap()
        };
        let t10 = mk(0.10);
        let t30 = mk(0.30);
        let t50 = mk(0.50);
        assert!(close(t10, t50, 3.0), "t10 {t10} vs t50 {t50}");
        assert!(close(t30, t50, 3.0), "t30 {t30} vs t50 {t50}");
    }

    /// Input-size scaling: analysis cost (events) must NOT grow with bytes
    /// — the §6 claim.
    #[test]
    fn events_flat_in_input_size() {
        let ev = |size: f64| {
            let (wf, _) = VideoScenario::default()
                .with_input_size(size)
                .with_fraction(0.5)
                .build();
            analyze_fixpoint(&wf, &SolverOpts::default(), 6)
                .unwrap()
                .events
        };
        let e1 = ev(1.1e9);
        let e100 = ev(100e9);
        assert!(
            e100 <= e1 + 4,
            "events grew with input size: {e1} -> {e100}"
        );
    }

    /// Perturbations are pure value transforms of the shared base model.
    #[test]
    fn perturbations_apply_expected_knobs() {
        let base = VideoScenario::default();
        let f = base.perturbed(&Perturbation::Fraction(0.9));
        assert_eq!(f.frac_task1, 0.9);
        assert_eq!(f.input_size, base.input_size);

        let r = base.perturbed(&Perturbation::LinkRateScale(2.0));
        assert!((r.link_rate - 2.0 * base.link_rate).abs() < 1e-6);

        let s = base.perturbed(&Perturbation::InputScale(10.0));
        assert!((s.input_size - 10.0 * base.input_size).abs() < 1.0);
        assert!((s.link_rate - base.link_rate).abs() < 1e-9); // rate fixed

        let c = base.perturbed(&Perturbation::CpuScale(0.5));
        assert!((c.t1_cpu - 41.0).abs() < 1e-9);

        let b = base.perturbed(&Perturbation::Task2Burst);
        assert!(b.t2_burst && !base.t2_burst);

        let t1 = base.perturbed(&Perturbation::Task1CpuScale(2.0));
        assert!((t1.t1_cpu - 164.0).abs() < 1e-9);
        assert!((t1.t2_time - base.t2_time).abs() < 1e-12);
        let t2 = base.perturbed(&Perturbation::Task2TimeScale(3.0));
        assert!((t2.t2_time - 15.0).abs() < 1e-9);
        assert!((t2.t1_cpu - base.t1_cpu).abs() < 1e-12);
        let t3 = base.perturbed(&Perturbation::Task3TimeScale(2.0));
        assert!((t3.t3_time - 6.0).abs() < 1e-9);

        // identity is a pure no-op
        let id = base.perturbed(&Perturbation::Identity);
        assert_eq!(id.frac_task1, base.frac_task1);
        assert_eq!(id.t1_cpu, base.t1_cpu);

        // base untouched throughout
        assert_eq!(base.frac_task1, 0.5);
    }

    /// Every variant survives `to_json` → `from_json` bit-for-bit
    /// (including non-representable-in-short-decimal payloads — the f64
    /// `Display` impl round-trips exactly).
    #[test]
    fn perturbation_json_roundtrip_all_variants() {
        let all = [
            Perturbation::Identity,
            Perturbation::Fraction(0.9300000000000001),
            Perturbation::LinkRateScale(1.5),
            Perturbation::InputScale(10.0),
            Perturbation::CpuScale(0.123456789012345),
            Perturbation::Task1CpuScale(2.0),
            Perturbation::Task2TimeScale(0.5),
            Perturbation::Task3TimeScale(1.0 / 3.0),
            Perturbation::Task2Burst,
        ];
        for p in all {
            let text = p.to_json().to_string();
            let back = Perturbation::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(p, back, "{text}");
            // the wire tag matches the documented vocabulary
            assert_eq!(p.to_json().get("kind").as_str(), Some(p.kind()));
        }
    }

    /// Malformed encodings are descriptive `Err`s, never panics.
    #[test]
    fn perturbation_from_json_rejects_unknowns() {
        let cases = [
            (r#"{"kind": "warp_speed"}"#, "unknown perturbation kind"),
            (r#"{"value": 1}"#, "string 'kind'"),
            (r#"{"kind": "fraction"}"#, "numeric 'value'"),
            (r#"{"kind": "fraction", "value": "x"}"#, "numeric 'value'"),
            ("3", "string 'kind'"),
        ];
        for (text, want) in cases {
            let err = Perturbation::from_json(&Json::parse(text).unwrap()).unwrap_err();
            assert!(err.contains(want), "{text}: {err}");
        }
    }

    /// The genomics model exposes the generic knobs and rejects the
    /// video-specific ones.
    #[test]
    fn genomics_perturbations() {
        let base = GenomicsScenario::default();
        let l = base.perturbed(&Perturbation::LinkRateScale(2.0)).unwrap();
        assert!((l.link_rate - 2.0 * base.link_rate).abs() < 1e-6);
        let f = base.perturbed(&Perturbation::Fraction(0.8)).unwrap();
        assert_eq!(f.frac_sample1, 0.8);
        let c = base.perturbed(&Perturbation::CpuScale(0.5)).unwrap();
        assert!((c.cpu_scale - 0.5).abs() < 1e-12);
        let i = base.perturbed(&Perturbation::InputScale(2.0)).unwrap();
        assert!((i.sample_bytes - 2.0 * base.sample_bytes).abs() < 1.0);
        let id = base.perturbed(&Perturbation::Identity).unwrap();
        assert_eq!(id.link_rate, base.link_rate);
        let err = base.perturbed(&Perturbation::Task1CpuScale(2.0)).unwrap_err();
        assert!(err.contains("task1_cpu_scale"), "{err}");

        // the CPU knob actually moves the genomics makespan
        let mk = |sc: &GenomicsScenario| {
            analyze_fixpoint(&sc.build(), &SolverOpts::default(), 6)
                .unwrap()
                .makespan
                .unwrap()
        };
        let slow = base.perturbed(&Perturbation::CpuScale(2.0)).unwrap();
        assert!(mk(&slow) > mk(&base), "cpu_scale must slow the pipeline");
    }

    /// Dirty-set coverage, one assertion per perturbation variant. The
    /// pool-level knobs must dirty *all* nodes sharing the pool (plus their
    /// cones); single-task knobs dirty exactly the task and its cone.
    #[test]
    fn dirty_sets_per_variant() {
        let (wf, nodes) = VideoScenario::default().build();
        let members = |p: &Perturbation| -> Vec<usize> {
            p.dirty_set(&wf, &nodes).iter().collect()
        };

        // every node is downstream of the two downloads -> whole graph
        let frac = members(&Perturbation::Fraction(0.9));
        assert_eq!(frac.len(), wf.nodes.len(), "{frac:?}");
        // a pool change dirties all consumers of that pool in particular
        let set = Perturbation::Fraction(0.9).dirty_set(&wf, &nodes);
        for &c in &wf.pool_consumers()[nodes.link_pool] {
            assert!(set.contains(c), "pool consumer {c} must be dirty");
        }
        assert_eq!(
            members(&Perturbation::LinkRateScale(2.0)).len(),
            wf.nodes.len()
        );
        assert_eq!(
            members(&Perturbation::InputScale(10.0)).len(),
            wf.nodes.len()
        );

        // CpuScale touches the three tasks, whose joint cone excludes the
        // downloads
        let cpu = members(&Perturbation::CpuScale(2.0));
        assert_eq!(cpu, vec![nodes.task1, nodes.task2, nodes.task3]);

        // single-task knobs: seed + downstream cone only
        assert_eq!(
            members(&Perturbation::Task1CpuScale(2.0)),
            vec![nodes.task1, nodes.task3]
        );
        assert_eq!(
            members(&Perturbation::Task2TimeScale(2.0)),
            vec![nodes.task2, nodes.task3]
        );
        assert_eq!(
            members(&Perturbation::Task3TimeScale(2.0)),
            vec![nodes.task3]
        );
        assert_eq!(
            members(&Perturbation::Task2Burst),
            vec![nodes.task2, nodes.task3]
        );
        // identity dirties nothing — every node is served from the cache
        assert!(members(&Perturbation::Identity).is_empty());
    }

    /// Single-task perturbations actually move the makespan the way their
    /// dirty sets promise.
    #[test]
    fn single_task_perturbations_solve() {
        let mk = |sc: VideoScenario| {
            let (wf, _) = sc.build();
            analyze_fixpoint(&wf, &SolverOpts::default(), 6)
                .unwrap()
                .makespan
                .unwrap()
        };
        let base = VideoScenario::default();
        let t0 = mk(base.clone());
        // doubling the mux time adds ~3 s to the tail
        let t3 = mk(base.perturbed(&Perturbation::Task3TimeScale(2.0)));
        assert!((t3 - t0 - base.t3_time).abs() < 1.0, "{t3} vs {t0}");
        // scaling task 1's encode by 2 pushes the encode tail out by ~82 s
        let t1 = mk(base.perturbed(&Perturbation::Task1CpuScale(2.0)));
        assert!(t1 > t0 + 0.5 * base.t1_cpu, "{t1} vs {t0}");
    }

    /// The Task2Burst model variant delays the workflow at high fractions
    /// (task 2 can no longer pipeline behind its download).
    #[test]
    fn task2_burst_variant_slows_high_fraction() {
        let mk = |sc: VideoScenario| {
            let (wf, _) = sc.build();
            analyze_fixpoint(&wf, &SolverOpts::default(), 6)
                .unwrap()
                .makespan
                .unwrap()
        };
        let base = VideoScenario::default().with_fraction(0.95);
        let stream = mk(base.clone());
        let burst = mk(base.perturbed(&Perturbation::Task2Burst));
        assert!(
            burst > stream + 3.0,
            "burst {burst} should exceed stream {stream} by the t2 runtime"
        );
    }

    /// `applicable_kinds` probes the models' real vocabularies: the video
    /// scenario answers to every knob, genomics only to the generic ones.
    #[test]
    fn applicable_kinds_video_vs_genomics() {
        let video = VideoScenario::default();
        assert_eq!(
            Perturbation::applicable_kinds(&video),
            Perturbation::ALL_KINDS.to_vec()
        );
        let genomics = GenomicsScenario::default();
        assert_eq!(
            Perturbation::applicable_kinds(&genomics),
            vec![
                "identity",
                "fraction",
                "link_rate_scale",
                "input_scale",
                "cpu_scale"
            ]
        );
        // with_value/probe cover the full vocabulary and reject unknowns
        for kind in Perturbation::ALL_KINDS {
            let p = Perturbation::probe(kind).unwrap();
            assert_eq!(p.kind(), kind);
        }
        assert!(Perturbation::with_value("warp_speed", 1.0).is_none());
        assert_eq!(
            Perturbation::with_value("fraction", 0.8),
            Some(Perturbation::Fraction(0.8))
        );
        assert_eq!(
            Perturbation::with_value("task2_burst", 42.0),
            Some(Perturbation::Task2Burst)
        );
    }

    /// The genomics workflow validates, solves, and has the expected shape.
    #[test]
    fn genomics_scenario_builds_and_solves() {
        let wf = GenomicsScenario::default().build();
        assert_eq!(wf.nodes.len(), 8);
        assert_eq!(wf.pools.len(), 2);
        wf.validate().unwrap();
        let wa = analyze_fixpoint(&wf, &SolverOpts::default(), 6).unwrap();
        let mk = wa.makespan.expect("genomics workflow finishes");
        // ingest of 4 GB at ≤100 MB/s alone takes ≥ 40 s; alignment adds
        // hundreds of CPU-seconds at 2 cores
        assert!(mk > 100.0, "{mk}");
    }
}
