//! The paper's evaluation workflow (Fig 5) as a BottleMod model (§5.2).
//!
//! Five processes: two downloads sharing the 100 Mbit/s link, the three
//! ffmpeg tasks (reverse / rotate / mux). All constants are the paper's
//! published measurements:
//!
//! * input video: 1,137,486,559 bytes; a full-rate direct download takes
//!   89 s ⇒ net link rate ≈ 97.51 Mibit/s ≈ 12.78 MB/s;
//! * task 1 (reverse): burst data requirement (all input before any
//!   output), 80 MB output, 82 s of encode CPU spread over the output
//!   (the 26 s of read+decode overlap the much slower download and are
//!   charged in the virtual testbed, not the model — see DESIGN.md);
//! * task 2 (rotate): stream task, 1.1 GB copied output, 5 s local
//!   execution time spread over progress (never binding behind a download);
//! * task 3 (mux): starts after tasks 1 and 2 complete (barrier), 3 s.
//!
//! Progress metric: output bytes, with identity output functions — exactly
//! the paper's choice.

use crate::model::{Process, ProcessBuilder};
use crate::pwfn::PwPoly;
use crate::workflow::graph::{DataSource, ResourceSource, StartRule, Workflow};

/// Paper's measured constants (all sizes in bytes, times in seconds).
#[derive(Clone, Debug)]
pub struct VideoScenario {
    /// Input video size (1,137,486,559 B).
    pub input_size: f64,
    /// Task 1 output size (80 MB).
    pub t1_output: f64,
    /// Net shared-link rate in bytes/s (input_size / 89 s ≈ 12.78 MB/s).
    pub link_rate: f64,
    /// Task 1 encode CPU seconds (82 s).
    pub t1_cpu: f64,
    /// Task 1 read+decode CPU seconds (26 s; testbed only).
    pub t1_decode_cpu: f64,
    /// Task 2 local execution seconds (5 s).
    pub t2_time: f64,
    /// Task 3 local execution seconds (3 s).
    pub t3_time: f64,
    /// Fraction of the link initially assigned to task 1's download.
    pub frac_task1: f64,
}

impl Default for VideoScenario {
    fn default() -> Self {
        let input_size = 1_137_486_559.0;
        VideoScenario {
            input_size,
            t1_output: 80e6,
            link_rate: input_size / 89.0,
            t1_cpu: 82.0,
            t1_decode_cpu: 26.0,
            t2_time: 5.0,
            t3_time: 3.0,
            frac_task1: 0.5,
        }
    }
}

/// Node ids of the built workflow.
#[derive(Clone, Copy, Debug)]
pub struct VideoNodes {
    pub dl1: usize,
    pub dl2: usize,
    pub task1: usize,
    pub task2: usize,
    pub task3: usize,
    pub link_pool: usize,
}

impl VideoScenario {
    /// Scale the scenario to a different input size (the §6 performance
    /// comparison sweeps this; BottleMod's analysis cost must stay flat).
    pub fn with_input_size(mut self, bytes: f64) -> Self {
        let scale = bytes / self.input_size;
        self.input_size = bytes;
        self.t1_output *= scale;
        // keep the *link rate* fixed (same testbed), so durations scale
        self.t1_cpu *= scale;
        self.t2_time *= scale;
        self.t3_time *= scale;
        self
    }

    pub fn with_fraction(mut self, f: f64) -> Self {
        self.frac_task1 = f;
        self
    }

    /// A download is a process whose single resource is the link data rate:
    /// one byte of link capacity per byte of output (paper §5.2).
    fn download(&self, name: &str) -> Process {
        ProcessBuilder::new(name, self.input_size)
            .stream_data("remote-file", self.input_size)
            .stream_resource("link", self.input_size)
            .identity_output("file")
            .build()
    }

    /// Build the Fig 5 workflow.
    pub fn build(&self) -> (Workflow, VideoNodes) {
        let mut wf = Workflow::new();
        let link_pool = wf.add_pool("link", PwPoly::constant(self.link_rate));

        // the remote file is fully available on the webserver from t=0
        let remote = DataSource::External(PwPoly::constant(self.input_size));

        let dl1 = wf.add_node(
            self.download("dl-task1"),
            vec![remote.clone()],
            vec![ResourceSource::PoolFraction {
                pool: link_pool,
                fraction: self.frac_task1,
            }],
            StartRule::default(),
        );
        let dl2 = wf.add_node(
            self.download("dl-task2"),
            vec![remote],
            vec![ResourceSource::PoolResidual { pool: link_pool }],
            StartRule::default(),
        );

        // task 1: reverse — burst input, encode CPU spread over output
        let t1 = ProcessBuilder::new("task1-reverse", self.t1_output)
            .burst_data("video", self.input_size)
            .stream_resource("cpu", self.t1_cpu)
            .identity_output("reversed")
            .build();
        let task1 = wf.add_node(
            t1,
            vec![DataSource::ProcessOutput {
                node: dl1,
                output: 0,
            }],
            vec![ResourceSource::Fixed(PwPoly::constant(1.0))],
            StartRule::default(),
        );

        // task 2: rotate — pure stream, local execution time spread evenly
        let t2 = ProcessBuilder::new("task2-rotate", self.input_size)
            .stream_data("video", self.input_size)
            .stream_resource("io", self.t2_time)
            .identity_output("rotated")
            .build();
        let task2 = wf.add_node(
            t2,
            vec![DataSource::ProcessOutput {
                node: dl2,
                output: 0,
            }],
            vec![ResourceSource::Fixed(PwPoly::constant(1.0))],
            StartRule::default(),
        );

        // task 3: mux — starts after both complete (paper §5.1)
        let t3_out = self.t1_output + self.input_size;
        let t3 = ProcessBuilder::new("task3-mux", t3_out)
            .custom_data("reversed", &[(0.0, 0.0), (self.t1_output, t3_out)])
            .custom_data("rotated", &[(0.0, 0.0), (self.input_size, t3_out)])
            .stream_resource("io", self.t3_time)
            .identity_output("result")
            .build();
        let task3 = wf.add_node(
            t3,
            vec![
                DataSource::ProcessOutput {
                    node: task1,
                    output: 0,
                },
                DataSource::ProcessOutput {
                    node: task2,
                    output: 0,
                },
            ],
            vec![ResourceSource::Fixed(PwPoly::constant(1.0))],
            StartRule {
                at: 0.0,
                after: vec![task1, task2],
            },
        );

        (
            wf,
            VideoNodes {
                dl1,
                dl2,
                task1,
                task2,
                task3,
                link_pool,
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::SolverOpts;
    use crate::workflow::engine::analyze_fixpoint;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() < tol
    }

    /// 50:50 split: both downloads finish together at 2·89 = 178 s, task 1
    /// encodes for 82 s afterwards, task 3 adds 3 s ⇒ ≈ 263 s.
    #[test]
    fn fifty_fifty_prediction() {
        let sc = VideoScenario::default().with_fraction(0.5);
        let (wf, nodes) = sc.build();
        let wa = analyze_fixpoint(&wf, &SolverOpts::default(), 6).unwrap();
        let dl1 = wa.analyses[nodes.dl1].finish_time.unwrap();
        let t_total = wa.makespan.unwrap();
        assert!(close(dl1, 178.0, 1.0), "dl1 {dl1}");
        assert!(close(t_total, 263.0, 2.0), "total {t_total}");
    }

    /// 95 % split: dl1 at ~93.7 s, task 1 done ≈ 175.7, but task 2's
    /// download (with release) finishes at 2·89 = 178 ⇒ total ≈ 181.
    #[test]
    fn ninety_five_prediction() {
        let sc = VideoScenario::default().with_fraction(0.95);
        let (wf, nodes) = sc.build();
        let wa = analyze_fixpoint(&wf, &SolverOpts::default(), 6).unwrap();
        let dl1 = wa.analyses[nodes.dl1].finish_time.unwrap();
        let dl2 = wa.analyses[nodes.dl2].finish_time.unwrap();
        let total = wa.makespan.unwrap();
        assert!(close(dl1, 89.0 / 0.95, 1.0), "dl1 {dl1}");
        assert!(close(dl2, 178.0, 1.5), "dl2 {dl2}");
        assert!(close(total, 181.3, 2.5), "total {total}");
    }

    /// The headline: ≥93 % allocation is ≈ 32 % faster than 50:50.
    #[test]
    fn paper_headline_32_percent() {
        let mk = |f: f64| {
            let (wf, _) = VideoScenario::default().with_fraction(f).build();
            analyze_fixpoint(&wf, &SolverOpts::default(), 6)
                .unwrap()
                .makespan
                .unwrap()
        };
        let t50 = mk(0.50);
        let t93 = mk(0.93);
        let gain = 1.0 - t93 / t50;
        assert!(
            (0.28..0.36).contains(&gain),
            "expected ≈32% gain, got {:.1}% (t50={t50:.1}, t93={t93:.1})",
            gain * 100.0
        );
    }

    /// Low fractions: with bidirectional release both downloads still end
    /// at 178 s, so the total plateaus at the 50:50 value.
    #[test]
    fn low_fraction_plateau() {
        let mk = |f: f64| {
            let (wf, _) = VideoScenario::default().with_fraction(f).build();
            analyze_fixpoint(&wf, &SolverOpts::default(), 6)
                .unwrap()
                .makespan
                .unwrap()
        };
        let t10 = mk(0.10);
        let t30 = mk(0.30);
        let t50 = mk(0.50);
        assert!(close(t10, t50, 3.0), "t10 {t10} vs t50 {t50}");
        assert!(close(t30, t50, 3.0), "t30 {t30} vs t50 {t50}");
    }

    /// Input-size scaling: analysis cost (events) must NOT grow with bytes
    /// — the §6 claim.
    #[test]
    fn events_flat_in_input_size() {
        let ev = |size: f64| {
            let (wf, _) = VideoScenario::default()
                .with_input_size(size)
                .with_fraction(0.5)
                .build();
            analyze_fixpoint(&wf, &SolverOpts::default(), 6)
                .unwrap()
                .events
        };
        let e1 = ev(1.1e9);
        let e100 = ev(100e9);
        assert!(
            e100 <= e1 + 4,
            "events grew with input size: {e1} -> {e100}"
        );
    }
}
