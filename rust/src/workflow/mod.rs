//! Workflows: DAGs of processes with chained outputs and shared resource
//! pools (paper §3.4), plus the Fig 5 evaluation scenario.

pub mod engine;
pub mod generator;
pub mod graph;
pub mod scenario;

pub use engine::{
    analyze, analyze_fixpoint, analyze_fixpoint_cached, analyze_fixpoint_full, WorkflowAnalysis,
    WorkflowError,
};
pub use graph::{
    DataSource, GraphError, Node, NodeSet, Pool, ResourceSource, StartRule, Workflow,
};
