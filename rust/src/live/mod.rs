//! Online bottleneck monitor: streaming trace events in, live incremental
//! re-analysis and re-allocation advisories out.
//!
//! The paper's closing claim is that the analysis is cheap enough to run
//! "while the tasks or the workflow is still executing to conduct certain
//! optimizations just in time". This module is that loop. A [`Monitor`] is
//! a long-lived session that accumulates an *effective trace* from
//! incremental events — appended (or re-sent, updated) Nextflow-style TSV
//! rows and BPF-style cumulative I/O samples — and, after every event,
//! re-derives the full prediction for the workflow as observed so far:
//! predicted makespan, remaining time from the newest observation,
//! the currently binding `(process, bottleneck)` pair, and the ranked
//! bottleneck attribution.
//!
//! ## Incrementality, and what it guarantees
//!
//! Each feed is analytically **equivalent to a cold start** — parse the
//! accumulated TSV + I/O log, [`calibrate`](crate::trace::calibrate) every
//! task, [`assemble`](crate::trace::assemble::assemble), solve — but does
//! almost none of that work again:
//!
//! * **Calibration** is per task and depends only on that task's row and
//!   its own I/O series (see [`crate::trace::calibrate::calibrate`]); the
//!   monitor memoizes each fit keyed on the *exact* row text and series
//!   bits, so a feed re-fits only the tasks whose observations actually
//!   changed ([`FeedReport::refit`] vs [`FeedReport::reused`]).
//! * **Solving** goes through the session's content-addressed
//!   [`AnalysisCache`] and the worklist fixpoint
//!   ([`analyze_fixpoint_cached`]): a node re-solves only if its process
//!   or materialized inputs changed bits, which confines re-solves to the
//!   *dirty cone* — the changed tasks plus their downstream closure
//!   ([`FeedReport::dirty`]); everything else is a cache hit
//!   ([`FeedReport::cache`]).
//!
//! Because the memo compares exact bytes/bits and the cached fixpoint is
//! bit-for-bit identical to the uncached one (the engine's pinned
//! contract), the state after any feed sequence is **bit-for-bit
//! identical** to [`crate::trace::assemble::calibrate_trace`] on the same
//! accumulated text — `tests/live_monitor.rs` asserts exactly that.
//!
//! ## Advisories
//!
//! The snapshot's binding pair is
//! [`live_bottleneck`](crate::sched::online::live_bottleneck) at the
//! newest observation — falling back to
//! [`frontier_bottleneck`](crate::sched::online::frontier_bottleneck)
//! when nothing is strictly active there, which is the common case:
//! models fitted from observations alone predict no further than the
//! observation frontier, and the regime that set that horizon is what is
//! binding the execution right now.
//!
//! A [`LiveTracker`] watches the live bottleneck's identity across feeds.
//! When it shifts — the binding task or resource changes — the monitor
//! emits an [`Advisory`] in that event's [`FeedReport`]: the shift itself,
//! plus (when an allocation model is attached) a candidate split →
//! predicted gain recommendation from
//! [`recommend_model`](crate::sched::advisor::recommend_model).
//!
//! ## Failure model
//!
//! * **Malformed events** (bad TSV/I/O syntax, a row without a task id)
//!   are rejected atomically: the feed returns an error and the monitor's
//!   state is exactly as before the call.
//! * **Analytically incoherent states** (a row whose dependency has not
//!   arrived yet, a mid-stream cycle) are *kept* — the data is retained,
//!   the feed succeeds, and the report carries [`FeedReport::stale`] with
//!   the reason while [`FeedReport::snapshot`] stays the last good
//!   prediction. The next event may well repair the state.
//! * **I/O samples for tasks with no TSV row yet** are held pending (real
//!   monitors deliver per-process samples before the scheduler logs the
//!   task) and join the analysis when the row arrives.
//!
//! Wire surface: the `monitor_open` / `monitor_feed` / `monitor_status`
//! v1 service ops (`docs/SERVICE.md`) and the `bottlemod watch` CLI
//! subcommand; semantics are documented in `docs/LIVE.md`.

use std::collections::HashMap;
use std::sync::Arc;

use crate::pwfn::{BatchPwPoly, PwPoly};
use crate::runtime::cache::{AnalysisCache, CacheStats};
use crate::runtime::sweep::SweepModel;
use crate::sched::advisor::{recommend_model, Recommendation};
use crate::sched::online::{frontier_bottleneck, live_bottleneck, BottleneckShift, LiveTracker};
use crate::solver::{Analysis, SolverOpts};
use crate::trace::assemble::assemble;
use crate::trace::calibrate::{calibrate, CalibrateOpts, CalibratedTask};
use crate::trace::format::{parse_io_log, parse_tsv, parse_tsv_structural, IoSeries, TsvTrace};
use crate::util::error::{Error, Result};
use crate::workflow::engine::analyze_fixpoint_cached;
use crate::ensure;

/// Options for a monitor session.
#[derive(Clone, Debug)]
pub struct MonitorOpts {
    /// Per-task calibration options (defaults match the offline pipeline).
    pub calibrate: CalibrateOpts,
    /// Solver options for each re-analysis.
    pub solver: SolverOpts,
    /// Fixpoint passes per re-analysis. The default (8) matches the
    /// offline replay, which is what makes monitor state bit-comparable
    /// to [`crate::trace::assemble::calibrate_trace`].
    pub passes: usize,
    /// Candidate fractions swept per advisory (see
    /// [`crate::sched::advisor::candidate_fractions`]).
    pub advisor_points: usize,
    /// Attach a calibration-residual confidence band
    /// ([`crate::sense::confidence_band`]) to every snapshot. Off by
    /// default: the extra lower/upper solves only run when asked for, so
    /// band-free monitors keep their exact cold-start cache accounting.
    pub bands: bool,
}

impl Default for MonitorOpts {
    fn default() -> Self {
        MonitorOpts {
            calibrate: CalibrateOpts::default(),
            solver: SolverOpts::default(),
            passes: 8,
            advisor_points: 20,
            bands: false,
        }
    }
}

/// One `(process, bottleneck)` attribution row: how long that bottleneck
/// bound that process over the predicted execution, ranked descending.
#[derive(Clone, Debug, PartialEq)]
pub struct RankedSegment {
    pub process: String,
    /// `"res:cpu"`, `"data:in"`, `"unconstrained"`, ...
    pub bottleneck: String,
    pub seconds: f64,
}

/// The monitor's current prediction, refreshed by every successful
/// re-analysis.
#[derive(Clone, Debug)]
pub struct Snapshot {
    /// Tasks in the effective trace (== workflow nodes).
    pub tasks: usize,
    /// Predicted makespan of the workflow as observed so far.
    pub makespan: Option<f64>,
    /// Newest observation time on the workflow clock (latest completion,
    /// in-flight elapsed point, or I/O sample).
    pub now: f64,
    /// `max(makespan − now, 0)` — predicted time still to run.
    pub remaining: Option<f64>,
    /// The binding `(process, bottleneck)` at `now`, per
    /// [`live_bottleneck`]; `None` when nothing is predicted running.
    pub bottleneck: Option<(String, String)>,
    /// Bottleneck attribution over the whole predicted execution,
    /// descending by bound duration (ties broken by name).
    pub ranked: Vec<RankedSegment>,
    /// Solver events across the analysis (diagnostics).
    pub solver_events: usize,
    /// Fixpoint passes the analysis took.
    pub passes: usize,
    /// Confidence band on the predicted makespan, from the per-task
    /// calibration residuals (prediction vs observation). Present only on
    /// monitors opened with [`MonitorOpts::bands`].
    pub band: Option<crate::sense::Band>,
}

/// A re-allocation advisory, emitted when the live bottleneck shifts.
#[derive(Clone, Debug)]
pub struct Advisory {
    /// The identity change that triggered the advisory.
    pub shift: BottleneckShift,
    /// Candidate split → predicted gain, when the attached allocation
    /// model exposes a split knob and the sweep succeeds.
    pub recommendation: Option<Recommendation>,
    /// Why there is no recommendation, when there is none.
    pub note: Option<String>,
}

/// What one feed did: the incremental-work accounting plus the resulting
/// prediction (or the reason it is stale).
#[derive(Clone, Debug)]
pub struct FeedReport {
    /// Monotone event counter (this feed's ordinal, 1-based).
    pub event: u64,
    /// Tasks whose model was re-fitted this feed (observations changed).
    pub refit: usize,
    /// Tasks whose memoized fit was reused untouched.
    pub reused: usize,
    /// Names of the tasks in this feed's dirty cone: the re-fitted tasks
    /// plus their downstream closure — the only nodes the solve may have
    /// re-solved. Empty when the analysis was skipped or stale.
    pub dirty: Vec<String>,
    /// The analysis cache's counter deltas for this feed's solve alone
    /// (`misses` = nodes actually re-solved, `hits` = reused).
    pub cache: CacheStats,
    /// `Some(reason)` when the accumulated state does not analyze yet
    /// (e.g. a dependency row has not arrived); the data is kept and
    /// `snapshot` is the last good prediction.
    pub stale: Option<String>,
    /// The current prediction: fresh if `stale` is `None`, otherwise the
    /// last good one. `None` before the first successful analysis.
    pub snapshot: Option<Snapshot>,
    /// Present exactly when this feed's analysis moved the live
    /// bottleneck to a different identity.
    pub advisory: Option<Advisory>,
}

/// A point-in-time summary of the session ( the `monitor_status` op).
#[derive(Clone, Debug)]
pub struct MonitorStatus {
    pub label: String,
    /// Feeds processed so far.
    pub events: u64,
    /// Advisories emitted so far.
    pub advisories: u64,
    /// Tasks in the effective trace.
    pub tasks: usize,
    /// I/O series held pending (no TSV row for their task yet).
    pub pending_series: usize,
    /// Lifetime cache counters for the session.
    pub cache: CacheStats,
    pub snapshot: Option<Snapshot>,
}

/// Exact-observation memo key for one task's fit: the raw row text plus
/// the task's I/O series compared bit-for-bit. Byte/bit equality — not
/// float equality — is what upholds the monitor's bit-identity guarantee
/// (`-0.0` vs `0.0`, for instance, must refit).
#[derive(Clone, Debug)]
struct FitKey {
    row: String,
    series: Vec<IoSeries>,
}

impl FitKey {
    fn matches(&self, row: &str, series: &[IoSeries]) -> bool {
        self.row == row
            && self.series.len() == series.len()
            && self.series.iter().zip(series).all(|(a, b)| {
                a.task == b.task
                    && bits_eq(&a.ts, &b.ts)
                    && bits_eq(&a.read, &b.read)
                    && bits_eq(&a.written, &b.written)
            })
    }
}

fn bits_eq(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// A live monitor session. See the module docs for semantics.
pub struct Monitor {
    label: String,
    /// Allocation model the advisor sweeps on a bottleneck shift, if any.
    advisor: Option<Arc<dyn SweepModel>>,
    opts: MonitorOpts,
    cache: Arc<AnalysisCache>,
    /// The TSV header, fixed by the first fed line.
    header: Option<String>,
    /// `task_id` column index within the header.
    c_id: usize,
    /// Task ids in first-seen order (the effective TSV's row order).
    row_order: Vec<String>,
    /// Current raw row text per task id (re-sent rows overwrite).
    rows: HashMap<String, String>,
    /// Accumulated raw I/O log text (the parser handles reordering).
    io_text: String,
    fit_memo: HashMap<String, (FitKey, CalibratedTask)>,
    tracker: LiveTracker,
    events: u64,
    advisories: u64,
    snapshot: Option<Snapshot>,
    /// `(task id, analysis)` per task from the last good analysis —
    /// `Arc`-shared with the engine/cache, retained so
    /// [`Monitor::sample_progress`] can materialize curves without
    /// re-solving.
    curves: Vec<(String, Arc<Analysis>)>,
}

impl Monitor {
    /// Open a session. `advisor` is the allocation model advisories sweep
    /// (`None` → shift-only advisories).
    pub fn new(label: &str, advisor: Option<Arc<dyn SweepModel>>, opts: MonitorOpts) -> Monitor {
        Monitor {
            label: label.to_string(),
            advisor,
            opts,
            cache: Arc::new(AnalysisCache::new()),
            header: None,
            c_id: 0,
            row_order: Vec::new(),
            rows: HashMap::new(),
            io_text: String::new(),
            fit_memo: HashMap::new(),
            tracker: LiveTracker::new(),
            events: 0,
            advisories: 0,
            snapshot: None,
            curves: Vec::new(),
        }
    }

    pub fn label(&self) -> &str {
        &self.label
    }

    pub fn events(&self) -> u64 {
        self.events
    }

    pub fn snapshot(&self) -> Option<&Snapshot> {
        self.snapshot.as_ref()
    }

    /// The session's analysis cache (shared with advisory sweeps).
    pub fn cache(&self) -> &Arc<AnalysisCache> {
        &self.cache
    }

    /// The accumulated effective TSV text — feeding this (plus
    /// [`Monitor::io_log`]) to `calibrate_trace` reproduces the monitor's
    /// current prediction bit-for-bit.
    pub fn effective_tsv(&self) -> String {
        let mut text = String::new();
        if let Some(h) = &self.header {
            text.push_str(h);
            text.push('\n');
            for id in &self.row_order {
                text.push_str(&self.rows[id]);
                text.push('\n');
            }
        }
        text
    }

    /// The accumulated raw I/O log text.
    pub fn io_log(&self) -> &str {
        &self.io_text
    }

    /// Ingest one event — any mix of TSV lines (header first, rows upsert
    /// by task id) and I/O log lines — and re-analyze incrementally.
    ///
    /// Malformed input is rejected atomically (state unchanged); see the
    /// module docs for the full failure model.
    pub fn feed(&mut self, tsv: Option<&str>, io: Option<&str>) -> Result<FeedReport> {
        // ---- structural ingest, all-or-nothing --------------------------
        let saved_header = self.header.clone();
        let saved_c_id = self.c_id;
        let saved_rows = self.row_order.len();
        let saved_io = self.io_text.len();
        let mut touched: Vec<(String, Option<String>)> = Vec::new();
        let ingest = self.ingest(tsv, io, &mut touched);
        if let Err(e) = ingest {
            self.header = saved_header;
            self.c_id = saved_c_id;
            self.io_text.truncate(saved_io);
            self.row_order.truncate(saved_rows);
            // reverse order restores the oldest previous value last
            for (id, prev) in touched.into_iter().rev() {
                match prev {
                    Some(p) => {
                        self.rows.insert(id, p);
                    }
                    None => {
                        self.rows.remove(&id);
                    }
                }
            }
            return Err(e);
        }
        self.events += 1;
        let event = self.events;

        let zero = {
            let s = self.cache.stats();
            s.since(&s)
        };
        if self.row_order.is_empty() {
            return Ok(FeedReport {
                event,
                refit: 0,
                reused: 0,
                dirty: vec![],
                cache: zero,
                stale: None,
                snapshot: self.snapshot.clone(),
                advisory: None,
            });
        }

        // structurally validated at ingest; the full parse adds the
        // referential check, which can legitimately fail mid-stream (a dep
        // row in flight) — analytically incoherent, so stale, not an error
        let trace = match parse_tsv(&self.effective_tsv()) {
            Ok(t) => t,
            Err(e) => {
                return Ok(FeedReport {
                    event,
                    refit: 0,
                    reused: 0,
                    dirty: vec![],
                    cache: zero,
                    stale: Some(e.to_string()),
                    snapshot: self.snapshot.clone(),
                    advisory: None,
                });
            }
        };
        let all_series = parse_io_log(&self.io_text).expect("validated at ingest");
        let (series, pending): (Vec<IoSeries>, Vec<IoSeries>) = all_series
            .into_iter()
            .partition(|s| trace.task(&s.task).is_some());
        drop(pending); // held in io_text until their rows arrive

        // ---- incremental per-task calibration (exact-observation memo) --
        let mut refit_idx: Vec<usize> = Vec::new();
        let mut reused = 0usize;
        let mut tasks: Vec<CalibratedTask> = Vec::with_capacity(trace.tasks.len());
        let mut stale: Option<String> = None;
        for (i, t) in trace.tasks.iter().enumerate() {
            let own: Vec<IoSeries> =
                series.iter().filter(|s| s.task == t.id).cloned().collect();
            let row = &self.rows[&t.id];
            if let Some((key, cached)) = self.fit_memo.get(&t.id) {
                if key.matches(row, &own) {
                    tasks.push(cached.clone());
                    reused += 1;
                    continue;
                }
            }
            let single = TsvTrace {
                tasks: vec![t.clone()],
            };
            match calibrate(&single, &own, &self.opts.calibrate) {
                Ok(mut v) => {
                    let ct = v.pop().expect("one task in, one task out");
                    let key = FitKey {
                        row: row.clone(),
                        series: own,
                    };
                    self.fit_memo.insert(t.id.clone(), (key, ct.clone()));
                    tasks.push(ct);
                    refit_idx.push(i);
                }
                Err(e) => {
                    stale = Some(format!("calibration: {e}"));
                    break;
                }
            }
        }

        // ---- assemble + cached worklist solve on the dirty cone ---------
        let mut dirty: Vec<String> = Vec::new();
        let mut delta = zero;
        let mut advisory = None;
        if stale.is_none() {
            let before = self.cache.stats();
            let analyzed = assemble(tasks).and_then(|cal| {
                let wa = analyze_fixpoint_cached(
                    &cal.workflow,
                    &self.opts.solver,
                    self.opts.passes,
                    Some(&self.cache),
                )
                .map_err(|e| Error::msg(format!("analysis: {e}")))?;
                Ok((cal, wa))
            });
            delta = self.cache.stats().since(&before);
            match analyzed {
                Ok((cal, wa)) => {
                    let cone = cal.workflow.downstream_closure(&refit_idx);
                    dirty = (0..cal.workflow.nodes.len())
                        .filter(|&i| cone.contains(i))
                        .map(|i| cal.tasks[i].id.clone())
                        .collect();
                    let snap = self.build_snapshot(&trace, &series, &cal, &wa);
                    let shifted = self.tracker.observe(snap.bottleneck.clone());
                    self.snapshot = Some(snap);
                    self.curves = cal
                        .tasks
                        .iter()
                        .zip(&wa.analyses)
                        .map(|(t, a)| (t.id.clone(), Arc::clone(a)))
                        .collect();
                    if let Some(shift) = shifted {
                        self.advisories += 1;
                        advisory = Some(self.advise(shift));
                    }
                }
                Err(e) => stale = Some(e.to_string()),
            }
        }

        Ok(FeedReport {
            event,
            refit: refit_idx.len(),
            reused,
            dirty,
            cache: delta,
            stale,
            snapshot: self.snapshot.clone(),
            advisory,
        })
    }

    /// Snapshot curve attribution: every task's predicted progress from
    /// the last good analysis, materialized on a shared time grid through
    /// the structure-of-arrays batch backend ([`BatchPwPoly`]) — one
    /// compile over all curves, one galloping merge per curve, no
    /// re-solve. This is what curve renderers (`watch` sparklines,
    /// dashboards) sample per refresh. Rows are `(task id, samples)` in
    /// task order; each value is bit-for-bit `progress.eval(ts[j])`.
    /// Empty before the first successful analysis.
    pub fn sample_progress(&self, ts: &[f64]) -> Vec<(String, Vec<f64>)> {
        if self.curves.is_empty() || ts.is_empty() {
            return self.curves.iter().map(|(id, _)| (id.clone(), Vec::new())).collect();
        }
        let funcs: Vec<&PwPoly> = self.curves.iter().map(|(_, a)| &a.progress).collect();
        let flat = BatchPwPoly::compile(&funcs).eval_scenarios(ts);
        self.curves
            .iter()
            .zip(flat.chunks(ts.len()))
            .map(|((id, _), row)| (id.clone(), row.to_vec()))
            .collect()
    }

    /// Current session summary (the `monitor_status` op).
    pub fn status(&self) -> MonitorStatus {
        let pending = parse_io_log(&self.io_text)
            .map(|series| {
                series
                    .iter()
                    .filter(|s| !self.rows.contains_key(&s.task))
                    .count()
            })
            .unwrap_or(0);
        MonitorStatus {
            label: self.label.clone(),
            events: self.events,
            advisories: self.advisories,
            tasks: self.row_order.len(),
            pending_series: pending,
            cache: self.cache.stats(),
            snapshot: self.snapshot.clone(),
        }
    }

    fn ingest(
        &mut self,
        tsv: Option<&str>,
        io: Option<&str>,
        touched: &mut Vec<(String, Option<String>)>,
    ) -> Result<()> {
        if let Some(text) = tsv {
            for line in text.lines() {
                let line = line.trim();
                if line.is_empty() || line.starts_with('#') {
                    continue;
                }
                match &self.header {
                    None => {
                        let cols: Vec<&str> = line.split('\t').map(str::trim).collect();
                        let c_id = cols.iter().position(|c| *c == "task_id").ok_or_else(|| {
                            Error::msg(
                                "monitor feed: first TSV line must be a header with a 'task_id' column",
                            )
                        })?;
                        self.header = Some(line.to_string());
                        self.c_id = c_id;
                    }
                    // a replayed header (tailing a file from the top) is a no-op
                    Some(h) if h == line => {}
                    Some(_) => {
                        let fields: Vec<&str> = line.split('\t').map(str::trim).collect();
                        let id = fields.get(self.c_id).copied().unwrap_or("");
                        ensure!(!id.is_empty(), "monitor feed: row without a task_id: '{line}'");
                        let prev = self.rows.insert(id.to_string(), line.to_string());
                        if prev.is_none() {
                            self.row_order.push(id.to_string());
                        }
                        touched.push((id.to_string(), prev));
                    }
                }
            }
        }
        if let Some(text) = io {
            for line in text.lines() {
                let line = line.trim();
                if line.is_empty() || line.starts_with('#') {
                    continue;
                }
                self.io_text.push_str(line);
                self.io_text.push('\n');
            }
        }
        // validate the *accumulated* state now, so a malformed line is
        // rejected before it poisons the session for every later feed.
        // Structural check only: a bare header (stream sends it before the
        // first row) and a dep whose row has not arrived yet are both fine
        // here — the latter surfaces as `stale` at analysis time instead.
        if self.header.is_some() && !self.row_order.is_empty() {
            parse_tsv_structural(&self.effective_tsv())?;
        }
        parse_io_log(&self.io_text)?;
        Ok(())
    }

    fn build_snapshot(
        &self,
        trace: &TsvTrace,
        series: &[IoSeries],
        cal: &crate::trace::assemble::CalibratedWorkflow,
        wa: &crate::workflow::engine::WorkflowAnalysis,
    ) -> Snapshot {
        // newest observation: latest completion, in-flight elapsed point
        // (start + realtime), or I/O sample on the workflow clock
        let mut now = 0.0f64;
        for t in &trace.tasks {
            let obs = t
                .complete
                .unwrap_or_else(|| t.start.unwrap_or(0.0) + t.realtime);
            now = now.max(obs);
        }
        for s in series {
            if let Some(&last) = s.ts.last() {
                now = now.max(last);
            }
        }

        // whole-execution bottleneck attribution, as the sweep engine does
        let mut acc: HashMap<(String, String), f64> = HashMap::new();
        for (i, a) in wa.analyses.iter().enumerate() {
            let proc = &cal.workflow.nodes[i].process;
            for s in &a.segments {
                let end = s.end.min(a.finish_time.unwrap_or(self.opts.solver.horizon));
                let dur = end - s.start;
                if dur > 1e-9 {
                    *acc.entry((proc.name.clone(), a.bottleneck_name(proc, s.bottleneck)))
                        .or_insert(0.0) += dur;
                }
            }
        }
        let mut ranked: Vec<RankedSegment> = acc
            .into_iter()
            .map(|((process, bottleneck), seconds)| RankedSegment {
                process,
                bottleneck,
                seconds,
            })
            .collect();
        ranked.sort_by(|a, b| {
            b.seconds
                .partial_cmp(&a.seconds)
                .unwrap()
                .then_with(|| a.process.cmp(&b.process))
                .then_with(|| a.bottleneck.cmp(&b.bottleneck))
        });

        // per-task calibration residuals — how far the fitted model's
        // finish is from the observed completion, relative — propagated
        // into a lower/median/upper makespan band through the same cache
        let band = if self.opts.bands {
            let residuals: Vec<f64> = cal
                .tasks
                .iter()
                .zip(&wa.analyses)
                .map(|(t, a)| {
                    match (trace.task(&t.id).and_then(|row| row.complete), a.finish_time) {
                        (Some(obs), Some(pred)) if obs > 1e-9 => ((pred - obs) / obs).abs(),
                        _ => 0.0,
                    }
                })
                .collect();
            crate::sense::confidence_band(
                &cal.workflow,
                &residuals,
                wa.makespan,
                &self.opts.solver,
                self.opts.passes,
                Some(&self.cache),
                0,
            )
            .ok()
            .map(|r| r.band)
        } else {
            None
        };

        Snapshot {
            tasks: trace.tasks.len(),
            makespan: wa.makespan,
            now,
            remaining: wa.makespan.map(|m| (m - now).max(0.0)),
            // models fitted from observations predict no further than the
            // observation frontier, so at `now` itself nothing is strictly
            // active — the regime that set the horizon is what binds then
            bottleneck: live_bottleneck(&cal.workflow, wa, now)
                .or_else(|| frontier_bottleneck(&cal.workflow, wa)),
            ranked,
            solver_events: wa.events,
            passes: wa.passes,
            band,
        }
    }

    fn advise(&self, shift: BottleneckShift) -> Advisory {
        let (recommendation, note) = match &self.advisor {
            Some(model) => match recommend_model(
                model,
                self.opts.advisor_points,
                1,
                Some(Arc::clone(&self.cache)),
            ) {
                Ok(Some(rec)) => (Some(rec), None),
                Ok(None) => (
                    None,
                    Some("no actionable split for this workload".to_string()),
                ),
                Err(e) => (None, Some(format!("advisor sweep failed: {e}"))),
            },
            None => (None, Some("no allocation model attached".to_string())),
        };
        Advisory {
            shift,
            recommendation,
            note,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::assemble::calibrate_trace;

    const HEADER: &str =
        "task_id\tdeps\tstart\tcomplete\trealtime\tpcpu\trchar\twchar\tpeak_rss";
    const DL: &str = "dl\t-\t0\t10\t10\t1e9\t1e8\t1e8\t2e6";
    const ENC: &str = "enc\tdl\t0\t20\t20\t100\t1e8\t5e7\t8e6";
    const MUX: &str = "mux\tdl,enc\t20\t23\t3\t100\t1.5e8\t1.5e8\t1.4e8";

    /// Feeding row by row matches a one-shot cold calibrate+solve on the
    /// same accumulated text — bit for bit, at every prefix.
    #[test]
    fn feed_matches_cold_calibrate_at_every_prefix() {
        let mut m = Monitor::new("t", None, MonitorOpts::default());
        let mut fed = format!("{HEADER}\n");
        for (i, row) in [DL, ENC, MUX].iter().enumerate() {
            let chunk = if i == 0 {
                format!("{HEADER}\n{row}\n")
            } else {
                format!("{row}\n")
            };
            let rep = m.feed(Some(&chunk), None).unwrap();
            assert!(rep.stale.is_none(), "{rep:?}");
            fed.push_str(row);
            fed.push('\n');
            assert_eq!(m.effective_tsv(), fed);
            let (_, cold) = calibrate_trace(
                &fed,
                None,
                &CalibrateOpts::default(),
                &SolverOpts::default(),
            )
            .unwrap();
            let snap = rep.snapshot.unwrap();
            assert_eq!(
                snap.makespan.unwrap().to_bits(),
                cold.predicted_makespan.unwrap().to_bits(),
                "prefix {i}"
            );
        }
        assert_eq!(m.events(), 3);
    }

    /// Snapshot curve sampling goes through the SoA batch backend, stays
    /// bit-for-bit the scalar progress eval, and never re-solves.
    #[test]
    fn sample_progress_matches_cold_analysis() {
        let mut m = Monitor::new("t", None, MonitorOpts::default());
        assert!(m.sample_progress(&[0.0, 1.0]).is_empty(), "no analysis yet");
        let all = format!("{HEADER}\n{DL}\n{ENC}\n{MUX}\n");
        m.feed(Some(&all), None).unwrap();
        let events_before = m.cache.stats();
        let ts: Vec<f64> = (0..50).map(|i| i as f64 * 0.5).collect();
        let rows = m.sample_progress(&ts);
        assert_eq!(rows.len(), 3);
        let (cal, _) = calibrate_trace(
            &all,
            None,
            &CalibrateOpts::default(),
            &SolverOpts::default(),
        )
        .unwrap();
        let wa = crate::workflow::engine::analyze_fixpoint(
            &cal.workflow,
            &SolverOpts::default(),
            MonitorOpts::default().passes,
        )
        .unwrap();
        for ((id, row), (t, a)) in rows.iter().zip(cal.tasks.iter().zip(&wa.analyses)) {
            assert_eq!(id, &t.id);
            for (&x, &v) in ts.iter().zip(row) {
                assert_eq!(v.to_bits(), a.progress.eval(x).to_bits(), "{id} t={x}");
            }
        }
        // pure sampling: no cache traffic, no re-solve
        let after = m.cache.stats();
        assert_eq!(after.misses, events_before.misses);
        // empty grid keeps the task rows, empty samples
        assert!(m.sample_progress(&[]).iter().all(|(_, r)| r.is_empty()));
    }

    /// A re-sent (updated) row re-fits only itself; the solve re-solves
    /// only its dirty cone and hits the cache for the rest.
    #[test]
    fn updated_row_refits_only_the_cone() {
        let mut m = Monitor::new("t", None, MonitorOpts::default());
        let all = format!("{HEADER}\n{DL}\n{ENC}\n{MUX}\n");
        let first = m.feed(Some(&all), None).unwrap();
        assert_eq!(first.refit, 3);
        assert_eq!(first.dirty.len(), 3);

        // re-send enc with a longer runtime: dl's fit and solve are reused
        let upd = "enc\tdl\t0\t30\t30\t100\t1e8\t5e7\t8e6";
        let rep = m.feed(Some(&format!("{upd}\n")), None).unwrap();
        assert!(rep.stale.is_none(), "{rep:?}");
        assert_eq!(rep.refit, 1, "{rep:?}");
        assert_eq!(rep.reused, 2, "{rep:?}");
        assert_eq!(rep.dirty, vec!["enc".to_string(), "mux".to_string()]);
        assert!(rep.cache.hits >= 1, "{:?}", rep.cache);
        assert!(
            (rep.cache.misses as usize) <= rep.dirty.len(),
            "{:?} vs {:?}",
            rep.cache,
            rep.dirty
        );

        // and the result still matches a cold run of the updated text
        let cold_text = format!("{HEADER}\n{DL}\n{upd}\n{MUX}\n");
        let (_, cold) = calibrate_trace(
            &cold_text,
            None,
            &CalibrateOpts::default(),
            &SolverOpts::default(),
        )
        .unwrap();
        assert_eq!(
            rep.snapshot.unwrap().makespan.unwrap().to_bits(),
            cold.predicted_makespan.unwrap().to_bits()
        );
    }

    /// An identical re-send is a full reuse: zero refits, zero misses.
    #[test]
    fn identical_resend_reuses_everything() {
        let mut m = Monitor::new("t", None, MonitorOpts::default());
        let all = format!("{HEADER}\n{DL}\n{ENC}\n{MUX}\n");
        m.feed(Some(&all), None).unwrap();
        let rep = m.feed(Some(&all), None).unwrap();
        assert_eq!(rep.refit, 0, "{rep:?}");
        assert_eq!(rep.reused, 3);
        assert_eq!(rep.cache.misses, 0, "{:?}", rep.cache);
        assert!(rep.cache.hit_rate() > 0.99, "{:?}", rep.cache);
        assert!(rep.dirty.is_empty(), "{rep:?}");
    }

    /// Malformed events are rejected atomically: the failed feed leaves
    /// no trace in the session.
    #[test]
    fn malformed_feed_rolls_back() {
        let mut m = Monitor::new("t", None, MonitorOpts::default());
        m.feed(Some(&format!("{HEADER}\n{DL}\n")), None).unwrap();
        let before_tsv = m.effective_tsv();

        // malformed row (wrong field count) alongside a valid row: neither lands
        let bad = "enc\tdl\t0\t20\nshort\trow";
        assert!(m.feed(Some(bad), None).is_err());
        assert_eq!(m.effective_tsv(), before_tsv);
        // malformed io line is rejected and not retained
        assert!(m.feed(None, Some("dl not-a-number 0 0\n")).is_err());
        assert_eq!(m.io_log(), "");
        assert_eq!(m.events(), 1);

        // the session still works afterwards
        let rep = m.feed(Some(&format!("{ENC}\n")), None).unwrap();
        assert!(rep.stale.is_none());
    }

    /// A row whose dependency has not arrived yet marks the state stale
    /// (last good snapshot retained) and heals when the dep arrives.
    #[test]
    fn dangling_dep_is_stale_then_heals() {
        let mut m = Monitor::new("t", None, MonitorOpts::default());
        let rep = m
            .feed(Some(&format!("{HEADER}\n{ENC}\n")), None)
            .unwrap();
        let msg = rep.stale.unwrap();
        assert!(msg.contains("unknown task"), "{msg}");
        assert!(rep.snapshot.is_none());

        let rep = m.feed(Some(&format!("{DL}\n")), None).unwrap();
        assert!(rep.stale.is_none(), "{rep:?}");
        assert!(rep.snapshot.is_some());
        assert_eq!(m.status().tasks, 2);
    }

    /// I/O samples may arrive before their task's row: they are held
    /// pending, visible in the status, and join the fit once the row lands.
    #[test]
    fn early_io_samples_wait_for_their_row() {
        let mut m = Monitor::new("t", None, MonitorOpts::default());
        m.feed(Some(&format!("{HEADER}\n{DL}\n")), None).unwrap();
        let io = "enc 0 2.5e7 0\nenc 10 5e7 0\nenc 15 7.5e7 2.5e7\nenc 20 1e8 5e7\n";
        let rep = m.feed(None, Some(io)).unwrap();
        assert!(rep.stale.is_none());
        assert_eq!(m.status().pending_series, 1);

        let rep = m.feed(Some(&format!("{ENC}\n")), None).unwrap();
        assert!(rep.stale.is_none());
        assert_eq!(m.status().pending_series, 0);
        // the series now backs enc's model, same as a cold run would see
        let (cold_cal, cold) = calibrate_trace(
            &m.effective_tsv(),
            Some(m.io_log()),
            &CalibrateOpts::default(),
            &SolverOpts::default(),
        )
        .unwrap();
        assert_eq!(
            cold_cal.tasks[1].source,
            crate::trace::calibrate::ModelSource::Series
        );
        assert_eq!(
            rep.snapshot.unwrap().makespan.unwrap().to_bits(),
            cold.predicted_makespan.unwrap().to_bits()
        );
    }

    /// The snapshot carries the live surface: now, remaining, the binding
    /// bottleneck and the ranked attribution.
    #[test]
    fn snapshot_surfaces_the_live_state() {
        let mut m = Monitor::new("t", None, MonitorOpts::default());
        let rep = m
            .feed(Some(&format!("{HEADER}\n{DL}\n{ENC}\n{MUX}\n")), None)
            .unwrap();
        let snap = rep.snapshot.unwrap();
        assert_eq!(snap.tasks, 3);
        assert!((snap.now - 23.0).abs() < 1e-9, "{snap:?}");
        assert!((snap.makespan.unwrap() - 23.0).abs() < 0.1);
        // trace fully observed: nothing remains
        assert!(snap.remaining.unwrap() < 0.2, "{snap:?}");
        assert!(!snap.ranked.is_empty());
        assert!(snap.ranked.windows(2).all(|w| w[0].seconds >= w[1].seconds));
        let st = m.status();
        assert_eq!(st.events, 1);
        assert_eq!(st.tasks, 3);
    }

    /// With `bands: true` every snapshot carries a confidence band
    /// bracketing the predicted makespan; the default monitor stays
    /// band-free (and pays no extra solves).
    #[test]
    fn banded_monitor_brackets_the_prediction() {
        let all = format!("{HEADER}\n{DL}\n{ENC}\n{MUX}\n");
        let mut plain = Monitor::new("t", None, MonitorOpts::default());
        let rep = plain.feed(Some(&all), None).unwrap();
        assert!(rep.snapshot.unwrap().band.is_none());

        let opts = MonitorOpts {
            bands: true,
            ..MonitorOpts::default()
        };
        let mut m = Monitor::new("t", None, opts);
        let rep = m.feed(Some(&all), None).unwrap();
        let snap = rep.snapshot.unwrap();
        let band = snap.band.expect("bands requested");
        assert!(
            band.lower <= band.median && band.median <= band.upper,
            "{band:?}"
        );
        assert_eq!(
            band.median.to_bits(),
            snap.makespan.unwrap().to_bits(),
            "median is the point prediction"
        );
        // the banded monitor's prediction itself is untouched
        let cold = plain.snapshot().unwrap().makespan.unwrap();
        assert_eq!(snap.makespan.unwrap().to_bits(), cold.to_bits());
    }
}
