//! Sensitivity & uncertainty over the sweep engine (docs/SENSITIVITY.md).
//!
//! The paper's payoff is that the piecewise bottleneck function "can be
//! used as a basis for optimized resource allocation" — but a point
//! prediction alone does not tell an allocator *which* knob to turn, by
//! how much, or how far to trust the number. This module turns the
//! perturbation/sweep machinery into that missing layer. Three pillars:
//!
//! 1. **Per-knob sensitivities** ([`analyze`]): for every applicable
//!    [`Perturbation`] kind of a [`SweepModel`], the makespan gradient
//!    `∂T/∂knob` from a central finite-difference stencil at the model's
//!    base point, routed through one [`SweepBatch`] so the shared
//!    [`AnalysisCache`] serves every stencil point's clean cone. Where the
//!    piecewise algebra allows it, a **closed-form** derivative rides
//!    along: within one segment of the piecewise solution the makespan is
//!    an analytic function of the knob (affine `T = α + β·s` for
//!    work-scale knobs, hyperbolic `T = α + W/(r·s)` for rate/capacity
//!    knobs), so the active segment's local model is recovered from the
//!    stencil solves and differentiated analytically. The two estimates
//!    cross-check each other; their midpoint residual flags "non-smooth
//!    here (segment boundary)" honestly instead of averaging over a kink.
//! 2. **Confidence bands** ([`confidence_band`]): per-task calibration
//!    residuals (the replay validator's relative errors, or the live
//!    monitor's refit deltas) are propagated into lower/median/upper
//!    completion-time bands by re-solving at residual-shifted task models
//!    (every task's resource requirement scaled by `1 ∓ ε_task`), with
//!    the three progress surfaces batch-evaluated through
//!    [`BatchPwPoly::eval_scenarios`]. Zero residuals collapse the band
//!    to the point estimate — an honest "nothing to widen" marker.
//! 3. **Ranked advice** ([`Report`]): knobs ordered by expected makespan
//!    gain per unit of favorable change, each ± an uncertainty derived
//!    from the band halfwidth, with explicit `insensitive` and
//!    `non_smooth` markers. `sched/advisor.rs` consumes this ranking to
//!    pick *which* knob to line-search instead of hard-coding the link
//!    fraction, and the `sensitivity` API op / CLI subcommand serialize
//!    it via the canonical, byte-deterministic [`Report::to_json`].

use std::collections::HashMap;
use std::sync::Arc;

use crate::pwfn::{BatchPwPoly, PwPoly};
use crate::runtime::cache::{AnalysisCache, CacheStats};
use crate::runtime::sweep::{ScenarioOutcome, SweepBatch, SweepError, SweepModel};
use crate::solver::SolverOpts;
use crate::util::par::num_threads;
use crate::util::Json;
use crate::workflow::engine::{analyze_fixpoint_cached, WorkflowAnalysis, WorkflowError};
use crate::workflow::scenario::Perturbation;
use crate::workflow::Workflow;

/// Configuration for a sensitivity analysis.
#[derive(Clone, Debug)]
pub struct SenseOpts {
    /// Relative stencil half-step: each continuous knob is solved at
    /// `v0 ± h·max(|v0|, 1e-3)`. The default `1e-4` keeps the structural
    /// closed-form/finite-difference disagreement at `O(h²) ≈ 1e-8`,
    /// well inside the 1e-6 agreement contract on smooth knobs.
    pub h: f64,
    /// Worker threads for the stencil batch (1 = sequential reference).
    pub threads: usize,
    /// Fixpoint passes per solve (the sweep engine's default, 6).
    pub fixpoint_passes: usize,
    pub solver: SolverOpts,
    /// Shared analysis cache; `None` attaches a fresh one (the stencil
    /// still shares clean cones *within* the report).
    pub cache: Option<Arc<AnalysisCache>>,
    /// Keep at most this many attribution-shift rows per knob.
    pub max_attribution: usize,
    /// Sample the band's completion-fraction curves on this many grid
    /// points (`0` = no samples; they never enter the canonical JSON).
    pub band_grid: usize,
}

impl Default for SenseOpts {
    fn default() -> Self {
        SenseOpts {
            h: 1e-4,
            threads: num_threads(),
            fixpoint_passes: 6,
            solver: SolverOpts::default(),
            cache: None,
            max_attribution: 8,
            band_grid: 0,
        }
    }
}

/// How a knob enters the local piecewise algebra — which analytic family
/// the active segment's makespan-vs-knob model belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum KnobClass {
    /// Capacity/share knobs: time ≈ work / (rate·s), locally hyperbolic.
    Rate,
    /// Cost/volume knobs: time is locally affine in the scale.
    Work,
    /// Model variants with no derivative — reported as a finite delta.
    Discrete,
}

fn classify(kind: &str) -> Option<KnobClass> {
    match kind {
        "fraction" | "link_rate_scale" => Some(KnobClass::Rate),
        "input_scale" | "cpu_scale" | "task1_cpu_scale" | "task2_time_scale"
        | "task3_time_scale" => Some(KnobClass::Work),
        "task2_burst" => Some(KnobClass::Discrete),
        _ => None, // identity (not a knob) and future kinds
    }
}

/// The stencil midpoint of a continuous knob: scale knobs sit at the
/// identity point `1.0`, the link fraction at the scenarios' base split.
fn base_value(kind: &str) -> f64 {
    if kind == "fraction" {
        0.5
    } else {
        1.0
    }
}

/// One `(process, bottleneck)` attribution row's response to the knob:
/// `d seconds / d knob` of the time that pair limits progress.
#[derive(Clone, Debug, PartialEq)]
pub struct AttributionShift {
    pub process: String,
    pub bottleneck: String,
    pub shift: f64,
}

/// Sensitivity of the makespan to one knob.
#[derive(Clone, Debug, PartialEq)]
pub struct KnobReport {
    /// The perturbation wire tag (`"fraction"`, `"cpu_scale"`, ...).
    pub kind: &'static str,
    /// Stencil midpoint (`None` for discrete variants).
    pub base: Option<f64>,
    /// Central finite difference `∂makespan/∂knob` at `base`.
    pub derivative: Option<f64>,
    /// Analytic derivative of the fitted active-segment model
    /// (`None` for discrete variants).
    pub closed_form: Option<f64>,
    /// Discrete variants only: `makespan(variant) − makespan(base)`.
    pub delta: Option<f64>,
    /// Expected makespan seconds saved per unit move in the favorable
    /// direction (`|derivative|`; for discrete knobs `max(−delta, 0)`).
    pub gain_per_unit: f64,
    /// ± on `gain_per_unit`: the gain scaled by the confidence band's
    /// halfwidth ratio (zero when the band is a point estimate).
    pub uncertainty: f64,
    /// The favorable move: `"increase"`, `"decrease"`, `"apply"`
    /// (discrete variant that helps) or `"none"`.
    pub direction: &'static str,
    /// The makespan does not respond to this knob at the base point.
    pub insensitive: bool,
    /// The stencil straddles a segment boundary of the piecewise solution
    /// (the fitted local model misses the midpoint): the derivative is a
    /// one-sided average across a kink — trust the sign, not the digits.
    pub non_smooth: bool,
    /// Largest `d seconds / d knob` responses among the per-bottleneck
    /// attribution rows (descending by magnitude).
    pub attribution: Vec<AttributionShift>,
}

/// Lower/median/upper completion-time band.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Band {
    pub lower: f64,
    pub median: f64,
    pub upper: f64,
}

impl Band {
    /// `true` when the band carries no width beyond float noise — zero
    /// residuals collapse to the point estimate.
    pub fn is_point(&self) -> bool {
        (self.upper - self.lower).abs() <= 1e-9 * self.median.abs().max(1.0)
    }

    /// Halfwidth as a fraction of the median — the multiplier that turns
    /// a gain into its uncertainty.
    pub fn halfwidth_ratio(&self) -> f64 {
        let m = self.median.abs().max(1e-12);
        ((self.upper - self.lower) / (2.0 * m)).max(0.0)
    }
}

/// One sampled point of the band's completion-fraction curves.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BandSample {
    pub t: f64,
    /// Completion fraction of the optimistic (residual-shrunk) model.
    pub optimistic: f64,
    pub median: f64,
    /// Completion fraction of the pessimistic (residual-grown) model.
    pub pessimistic: f64,
}

/// Result of [`confidence_band`].
#[derive(Clone, Debug)]
pub struct BandResult {
    pub band: Band,
    /// Solver events spent on the band's re-solves.
    pub events: usize,
    /// Completion-fraction samples (empty when `grid == 0` or the band
    /// is a point estimate).
    pub samples: Vec<BandSample>,
}

/// The ranked sensitivity report — the "fix this first" list.
#[derive(Clone, Debug)]
pub struct Report {
    /// The model's workload label (`"video"`, `"genomics"`, ...).
    pub workflow: String,
    /// Baseline (identity) makespan.
    pub makespan: f64,
    /// Confidence band around the baseline from the supplied residuals.
    pub band: Band,
    /// Knobs, descending by `gain_per_unit` (ties broken by kind).
    pub knobs: Vec<KnobReport>,
    /// Total solver events across the stencil and the band.
    pub events: usize,
    /// Band samples at [`SenseOpts::band_grid`] resolution (display-only;
    /// excluded from the canonical JSON).
    pub band_samples: Vec<BandSample>,
    /// Cache behaviour of this report's solves (`None` when the counter
    /// window is unavailable). Excluded from the canonical JSON — like
    /// sweep reports, determinism comparisons must not see bookkeeping.
    pub cache: Option<CacheStats>,
}

impl Report {
    /// The canonical, byte-deterministic JSON encoding (sorted keys, no
    /// volatile bookkeeping): same model + same residuals + same opts ⇒
    /// byte-identical output, regardless of thread count.
    pub fn to_json(&self) -> Json {
        let knobs = self.knobs.iter().map(knob_json).collect();
        Json::obj(vec![
            ("workflow", Json::Str(self.workflow.clone())),
            ("makespan", Json::Num(self.makespan)),
            (
                "band",
                Json::obj(vec![
                    ("lower", Json::Num(self.band.lower)),
                    ("median", Json::Num(self.band.median)),
                    ("upper", Json::Num(self.band.upper)),
                    ("point_estimate", Json::Bool(self.band.is_point())),
                ]),
            ),
            ("knobs", Json::Arr(knobs)),
            ("events", Json::Num(self.events as f64)),
        ])
    }
}

fn knob_json(k: &KnobReport) -> Json {
    let mut fields: Vec<(&str, Json)> = vec![
        ("kind", Json::Str(k.kind.to_string())),
        ("direction", Json::Str(k.direction.to_string())),
        ("gain_per_unit", Json::Num(k.gain_per_unit)),
        ("uncertainty", Json::Num(k.uncertainty)),
        ("insensitive", Json::Bool(k.insensitive)),
        ("non_smooth", Json::Bool(k.non_smooth)),
    ];
    if let Some(v) = k.base {
        fields.push(("base", Json::Num(v)));
    }
    if let Some(v) = k.derivative {
        fields.push(("derivative", Json::Num(v)));
    }
    if let Some(v) = k.closed_form {
        fields.push(("closed_form", Json::Num(v)));
    }
    if let Some(v) = k.delta {
        fields.push(("delta", Json::Num(v)));
    }
    if !k.attribution.is_empty() {
        let rows = k
            .attribution
            .iter()
            .map(|a| {
                Json::obj(vec![
                    ("process", Json::Str(a.process.clone())),
                    ("bottleneck", Json::Str(a.bottleneck.clone())),
                    ("shift", Json::Num(a.shift)),
                ])
            })
            .collect();
        fields.push(("attribution", Json::Arr(rows)));
    }
    Json::obj(fields)
}

/// One knob's stencil bookkeeping: which batch indices hold its solves.
struct Stencil {
    kind: &'static str,
    class: KnobClass,
    v0: f64,
    delta: f64,
    /// `v0 − δ` outcome index (equals `plus` for discrete kinds).
    minus: usize,
    /// `v0 + δ` outcome index (the variant itself for discrete kinds).
    plus: usize,
}

/// Full sensitivity analysis of `model` at its base point.
///
/// `residuals` are per-node relative calibration errors of the base
/// workflow (index-aligned with `Workflow::nodes`; missing entries are
/// zero) — pass an empty slice for uncalibrated models to get an honest
/// point-estimate band. Errors: a model whose baseline never finishes is
/// reported as [`SweepError::Unsupported`]; solver failures propagate as
/// [`SweepError::Analysis`].
pub fn analyze(
    model: &Arc<dyn SweepModel>,
    residuals: &[f64],
    opts: &SenseOpts,
) -> Result<Report, SweepError> {
    let kinds: Vec<&'static str> = Perturbation::applicable_kinds(model.as_ref())
        .into_iter()
        .filter(|k| *k != "identity")
        .collect();
    let cache = opts
        .cache
        .clone()
        .unwrap_or_else(|| Arc::new(AnalysisCache::new()));
    let before = cache.stats();

    // One batch holds the whole stencil: the planner groups the points by
    // dirty-set shape and the shared cache serves every clean cone.
    let mut perts: Vec<Perturbation> = vec![Perturbation::Identity];
    let mut stencils: Vec<Stencil> = Vec::new();
    for kind in kinds {
        let Some(class) = classify(kind) else { continue };
        if class == KnobClass::Discrete {
            let at = perts.len();
            // the value is ignored by valueless kinds
            perts.push(Perturbation::with_value(kind, 0.0).expect("known kind"));
            stencils.push(Stencil {
                kind,
                class,
                v0: 0.0,
                delta: 1.0,
                minus: at,
                plus: at,
            });
            continue;
        }
        let v0 = base_value(kind);
        let delta = opts.h * v0.abs().max(1e-3);
        let minus = perts.len();
        perts.push(Perturbation::with_value(kind, v0 - delta).expect("known kind"));
        let plus = perts.len();
        perts.push(Perturbation::with_value(kind, v0 + delta).expect("known kind"));
        stencils.push(Stencil {
            kind,
            class,
            v0,
            delta,
            minus,
            plus,
        });
    }

    let batch = SweepBatch::over(model.clone())
        .with_threads(opts.threads)
        .with_opts(opts.solver.clone())
        .with_fixpoint_passes(opts.fixpoint_passes)
        .with_cache(cache.clone());
    let outcomes = batch.run(&perts)?;
    let baseline = &outcomes[0];
    let t0 = baseline.makespan.ok_or_else(|| {
        SweepError::Unsupported(format!(
            "workflow '{}' does not finish within the solver horizon; \
             sensitivity needs a finite baseline makespan",
            model.label()
        ))
    })?;

    let base_wf = model.base_workflow();
    let band_result = confidence_band(
        &base_wf,
        residuals,
        Some(t0),
        &opts.solver,
        opts.fixpoint_passes,
        Some(&cache),
        opts.band_grid,
    )?;
    let rho = band_result.band.halfwidth_ratio();

    let mut knobs: Vec<KnobReport> = stencils
        .iter()
        .map(|s| knob_report(s, &outcomes, baseline, t0, rho, opts.max_attribution))
        .collect();
    // ranked: biggest expected gain first, kind as the deterministic tie-break
    knobs.sort_by(|a, b| {
        b.gain_per_unit
            .total_cmp(&a.gain_per_unit)
            .then_with(|| a.kind.cmp(b.kind))
    });

    let events: usize =
        outcomes.iter().map(|o| o.events).sum::<usize>() + band_result.events;
    Ok(Report {
        workflow: model.label().to_string(),
        makespan: t0,
        band: band_result.band,
        knobs,
        events,
        band_samples: band_result.samples,
        cache: Some(cache.stats().since(&before)),
    })
}

/// Evaluate one knob's stencil: central difference, active-segment
/// closed form, smoothness check, markers, attribution shifts.
fn knob_report(
    s: &Stencil,
    outcomes: &[ScenarioOutcome],
    baseline: &ScenarioOutcome,
    t0: f64,
    rho: f64,
    max_attribution: usize,
) -> KnobReport {
    if s.class == KnobClass::Discrete {
        let var = &outcomes[s.plus];
        let delta = var.makespan.map(|t| t - t0);
        let gain = delta.map(|d| (-d).max(0.0)).unwrap_or(0.0);
        let direction = match delta {
            Some(d) if d < -1e-9 * t0.abs().max(1.0) => "apply",
            Some(_) => "none",
            None => "none",
        };
        return KnobReport {
            kind: s.kind,
            base: None,
            derivative: None,
            closed_form: None,
            delta,
            gain_per_unit: gain,
            uncertainty: gain * rho,
            direction,
            insensitive: gain <= 1e-9 * t0.abs().max(1.0),
            non_smooth: delta.is_none(),
            attribution: attribution_shifts(var, baseline, 1.0, max_attribution),
        };
    }

    let (t_minus, t_plus) = (outcomes[s.minus].makespan, outcomes[s.plus].makespan);
    let (Some(tm), Some(tp)) = (t_minus, t_plus) else {
        // a stencil point fell off the horizon: no derivative, flag it
        return KnobReport {
            kind: s.kind,
            base: Some(s.v0),
            derivative: None,
            closed_form: None,
            delta: None,
            gain_per_unit: 0.0,
            uncertainty: 0.0,
            direction: "none",
            insensitive: false,
            non_smooth: true,
            attribution: vec![],
        };
    };

    let derivative = (tp - tm) / (2.0 * s.delta);
    // Fit the active segment's analytic family through the two stencil
    // points and differentiate it; check the fit against the midpoint.
    let (closed_form, fit_mid) = match s.class {
        KnobClass::Work => {
            // affine T(v) = a + b·v: b is the secant slope, the fit's
            // midpoint is the average of the two stencil values
            let b = (tp - tm) / (2.0 * s.delta);
            (b, (tp + tm) / 2.0)
        }
        KnobClass::Rate => {
            // hyperbolic T(v) = a + b/v through v0 ± δ
            let (vm, vp) = (s.v0 - s.delta, s.v0 + s.delta);
            let b = (tm - tp) * vm * vp / (2.0 * s.delta);
            let a = tp - b / vp;
            (-b / (s.v0 * s.v0), a + b / s.v0)
        }
        KnobClass::Discrete => unreachable!("handled above"),
    };
    let scale = t0.abs().max(1.0);
    let non_smooth = (fit_mid - t0).abs() > 1e-7 * scale;
    let insensitive = derivative.abs() <= 1e-9 * scale;
    let gain = if insensitive { 0.0 } else { derivative.abs() };
    let direction = if insensitive {
        "none"
    } else if derivative < 0.0 {
        "increase"
    } else {
        "decrease"
    };
    KnobReport {
        kind: s.kind,
        base: Some(s.v0),
        derivative: Some(derivative),
        closed_form: Some(closed_form),
        delta: None,
        gain_per_unit: gain,
        uncertainty: gain * rho,
        direction,
        insensitive,
        non_smooth,
        attribution: attribution_shifts(
            &outcomes[s.plus],
            &outcomes[s.minus],
            2.0 * s.delta,
            max_attribution,
        ),
    }
}

/// Per-`(process, bottleneck)` attribution response: how many seconds the
/// pair gains/loses per unit of knob, from the difference of the two
/// stencil points' attribution rows.
fn attribution_shifts(
    plus: &ScenarioOutcome,
    minus: &ScenarioOutcome,
    denom: f64,
    max_rows: usize,
) -> Vec<AttributionShift> {
    let mut acc: HashMap<(String, String), f64> = HashMap::new();
    for (p, b, d) in &plus.attributed {
        *acc.entry((p.clone(), b.clone())).or_insert(0.0) += d;
    }
    for (p, b, d) in &minus.attributed {
        *acc.entry((p.clone(), b.clone())).or_insert(0.0) -= d;
    }
    let mut rows: Vec<AttributionShift> = acc
        .into_iter()
        .filter(|(_, d)| d.abs() / denom > 1e-6)
        .map(|((process, bottleneck), d)| AttributionShift {
            process,
            bottleneck,
            shift: d / denom,
        })
        .collect();
    rows.sort_by(|a, b| {
        b.shift
            .abs()
            .total_cmp(&a.shift.abs())
            .then_with(|| a.process.cmp(&b.process))
            .then_with(|| a.bottleneck.cmp(&b.bottleneck))
    });
    rows.truncate(max_rows);
    rows
}

/// The base workflow with every node's resource requirements scaled by
/// `1 + sign·ε_node` — the residual-shifted model family behind the band
/// (`sign = −1` optimistic, `+1` pessimistic). Residuals are clamped to
/// `[0, 0.9]`; nodes beyond the slice (or with ~zero residual) are
/// untouched, so their solves stay cache-clean.
pub fn residual_shifted(wf: &Workflow, residuals: &[f64], sign: f64) -> Workflow {
    let mut out = wf.clone();
    for (i, node) in out.nodes.iter_mut().enumerate() {
        let eps = residuals.get(i).copied().unwrap_or(0.0).clamp(0.0, 0.9);
        if eps <= 1e-12 {
            continue;
        }
        let k = 1.0 + sign * eps;
        for r in &mut node.process.res_reqs {
            r.func = r.func.scale(k);
        }
    }
    out
}

/// Propagate per-node calibration residuals into a completion-time band:
/// re-solve the optimistic (`1−ε`) and pessimistic (`1+ε`) models and
/// bracket the median. `baseline` short-circuits the median makespan if
/// the caller already solved it (the solve still runs for the sample
/// curves, but a shared `cache` answers it from memory). With all-zero
/// residuals no extra solves run and the band is the point estimate.
pub fn confidence_band(
    wf: &Workflow,
    residuals: &[f64],
    baseline: Option<f64>,
    solver: &SolverOpts,
    passes: usize,
    cache: Option<&AnalysisCache>,
    grid: usize,
) -> Result<BandResult, WorkflowError> {
    let active = residuals
        .iter()
        .take(wf.nodes.len())
        .any(|&e| e.clamp(0.0, 0.9) > 1e-12);
    if !active {
        let (t_mid, events) = match baseline {
            Some(t) => (t, 0),
            None => {
                let mid = analyze_fixpoint_cached(wf, solver, passes, cache)?;
                (mid.makespan.unwrap_or(solver.horizon), mid.events)
            }
        };
        return Ok(BandResult {
            band: Band {
                lower: t_mid,
                median: t_mid,
                upper: t_mid,
            },
            events,
            samples: vec![],
        });
    }

    let mid = analyze_fixpoint_cached(wf, solver, passes, cache)?;
    let t_mid = baseline
        .or(mid.makespan)
        .unwrap_or(solver.horizon);
    let lo_wf = residual_shifted(wf, residuals, -1.0);
    let hi_wf = residual_shifted(wf, residuals, 1.0);
    let lo = analyze_fixpoint_cached(&lo_wf, solver, passes, cache)?;
    let hi = analyze_fixpoint_cached(&hi_wf, solver, passes, cache)?;
    // a monotone solver keeps lo ≤ mid ≤ hi; the min/max makes the
    // ordering a structural guarantee, not a numerical hope
    let t_lo = lo.makespan.unwrap_or(solver.horizon).min(t_mid);
    let t_hi = hi.makespan.unwrap_or(solver.horizon).max(t_mid);
    let band = Band {
        lower: t_lo,
        median: t_mid,
        upper: t_hi,
    };
    let events = mid.events + lo.events + hi.events;
    let samples = if grid >= 2 {
        band_samples(&[&lo, &mid, &hi], grid, t_hi)
    } else {
        vec![]
    };
    Ok(BandResult {
        band,
        events,
        samples,
    })
}

/// Whole-workflow completion fraction of the three band scenarios on a
/// shared time grid, through one SoA compile + [`BatchPwPoly::eval_scenarios`]
/// over all `3·N` progress curves.
fn band_samples(was: &[&WorkflowAnalysis; 3], grid: usize, t_end: f64) -> Vec<BandSample> {
    let ts: Vec<f64> = (0..grid)
        .map(|i| t_end * i as f64 / (grid - 1) as f64)
        .collect();
    let mut curves: Vec<&PwPoly> = Vec::new();
    for wa in was {
        for a in &wa.analyses {
            curves.push(&a.progress);
        }
    }
    if curves.is_empty() {
        return vec![];
    }
    let flat = BatchPwPoly::compile(&curves).eval_scenarios(&ts);
    let n = ts.len();
    let mut fracs = [vec![0.0f64; n], vec![0.0f64; n], vec![0.0f64; n]];
    let mut row = 0usize;
    for (si, wa) in was.iter().enumerate() {
        let total: f64 = wa
            .analyses
            .iter()
            .map(|a| a.max_progress)
            .sum::<f64>()
            .max(1e-12);
        for _ in &wa.analyses {
            for (j, v) in flat[row * n..(row + 1) * n].iter().enumerate() {
                fracs[si][j] += v;
            }
            row += 1;
        }
        for v in &mut fracs[si] {
            *v /= total;
        }
    }
    ts.iter()
        .enumerate()
        .map(|(j, &t)| BandSample {
            t,
            optimistic: fracs[0][j],
            median: fracs[1][j],
            pessimistic: fracs[2][j],
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::sweep::FixedWorkflow;
    use crate::workflow::scenario::{GenomicsScenario, VideoScenario};

    fn video_model() -> Arc<dyn SweepModel> {
        Arc::new(VideoScenario::default())
    }

    fn seq_opts() -> SenseOpts {
        SenseOpts {
            threads: 1,
            ..SenseOpts::default()
        }
    }

    /// The headline report: knobs ranked by gain, the expected markers on
    /// the Fig 5 scenario, and a point-estimate band without residuals.
    #[test]
    fn video_report_ranks_and_markers() {
        let model = video_model();
        let r = analyze(&model, &[], &seq_opts()).unwrap();
        assert_eq!(r.workflow, "video");
        assert!((r.makespan - 263.0).abs() < 2.0, "{}", r.makespan);
        assert!(r.band.is_point());
        assert!(r.band_samples.is_empty());
        // ranking is descending by gain
        for w in r.knobs.windows(2) {
            assert!(w[0].gain_per_unit >= w[1].gain_per_unit);
        }
        let knob = |k: &str| r.knobs.iter().find(|x| x.kind == k).unwrap().clone();
        // the §6 axis dominates: makespan is ≈ linear in the input volume
        assert_eq!(r.knobs[0].kind, "input_scale");
        let input = knob("input_scale");
        assert!(
            (input.derivative.unwrap() - r.makespan).abs() < 0.05 * r.makespan,
            "{:?}",
            input.derivative
        );
        assert_eq!(input.direction, "decrease");
        // a faster link shortens the downloads: negative derivative
        let link = knob("link_rate_scale");
        assert!(link.derivative.unwrap() < -100.0, "{:?}", link.derivative);
        assert_eq!(link.direction, "increase");
        assert!(!link.non_smooth, "link knob is smooth at 1.0");
        // task 2 never binds at the 50:50 split — honest marker
        let t2 = knob("task2_time_scale");
        assert!(t2.insensitive, "{t2:?}");
        assert_eq!(t2.direction, "none");
        assert_eq!(t2.gain_per_unit, 0.0);
        // the discrete variant has a delta, no derivative
        let burst = knob("task2_burst");
        assert!(burst.derivative.is_none());
        assert!(burst.delta.is_some());
        // uncalibrated model ⇒ zero uncertainty everywhere
        assert!(r.knobs.iter().all(|k| k.uncertainty == 0.0));
        // attribution shifts surface where the time moves: the cpu knob
        // grows task1's cpu-bound segments
        let cpu = knob("task1_cpu_scale");
        assert!(
            cpu.attribution
                .iter()
                .any(|a| a.process == "task1-reverse" && a.shift > 1.0),
            "{:?}",
            cpu.attribution
        );
    }

    /// Smooth knobs: the closed-form (fitted active-segment) derivative
    /// agrees with the central difference to ≤1e-6 relative.
    #[test]
    fn closed_form_agrees_on_smooth_knobs() {
        let model = video_model();
        let r = analyze(&model, &[], &seq_opts()).unwrap();
        let mut checked = 0;
        for k in &r.knobs {
            let (Some(cf), Some(fd)) = (k.closed_form, k.derivative) else {
                continue;
            };
            if k.insensitive || k.non_smooth {
                continue;
            }
            assert!(
                (cf - fd).abs() <= 1e-6 * fd.abs().max(1e-9 * r.makespan),
                "{}: closed {cf} vs stencil {fd}",
                k.kind
            );
            checked += 1;
        }
        assert!(checked >= 3, "expected ≥3 smooth knobs, got {checked}");
    }

    /// Residuals widen the band monotonically; zero residuals collapse it.
    #[test]
    fn band_widens_with_residuals_and_collapses_without() {
        let (wf, _) = VideoScenario::default().build();
        let solver = SolverOpts::default();
        let zero = confidence_band(&wf, &[0.0; 5], None, &solver, 6, None, 0).unwrap();
        assert!(zero.band.is_point());
        assert_eq!(zero.band.lower, zero.band.median);
        let res = vec![0.1; wf.nodes.len()];
        let wide = confidence_band(&wf, &res, None, &solver, 6, None, 12).unwrap();
        assert!(wide.band.lower < wide.band.median);
        assert!(wide.band.median < wide.band.upper);
        assert_eq!(wide.band.median, zero.band.median);
        // fraction curves: 12 samples, each within [0, 1+eps], optimistic
        // at least as complete as pessimistic at every t
        assert_eq!(wide.samples.len(), 12);
        for s in &wide.samples {
            assert!(s.optimistic >= s.pessimistic - 1e-9, "{s:?}");
            assert!((-1e-9..=1.0 + 1e-9).contains(&s.median), "{s:?}");
        }
        // the sampled fractions are cumulative in t
        for w in wide.samples.windows(2) {
            assert!(w[1].median >= w[0].median - 1e-9);
        }
    }

    /// Uncertainty rides the band: with residuals attached, sensitive
    /// knobs carry a strictly positive ± and the report stays ranked.
    #[test]
    fn residuals_put_uncertainty_on_gains() {
        let model = video_model();
        let r = analyze(&model, &[0.05; 5], &seq_opts()).unwrap();
        assert!(!r.band.is_point());
        assert!(r.band.lower < r.makespan && r.makespan < r.band.upper);
        let sensitive: Vec<_> = r.knobs.iter().filter(|k| !k.insensitive).collect();
        assert!(!sensitive.is_empty());
        for k in sensitive {
            if k.gain_per_unit > 0.0 {
                assert!(k.uncertainty > 0.0, "{k:?}");
            }
        }
    }

    /// The genomics model exposes exactly the generic knobs; the report
    /// covers them all with finite stencil derivatives.
    #[test]
    fn genomics_report_covers_generic_knobs() {
        let model: Arc<dyn SweepModel> = Arc::new(GenomicsScenario::default());
        let r = analyze(&model, &[], &seq_opts()).unwrap();
        let mut kinds: Vec<&str> = r.knobs.iter().map(|k| k.kind).collect();
        kinds.sort_unstable();
        assert_eq!(
            kinds,
            vec!["cpu_scale", "fraction", "input_scale", "link_rate_scale"]
        );
        for k in &r.knobs {
            assert!(k.derivative.unwrap().is_finite(), "{k:?}");
        }
    }

    /// Determinism: two runs produce byte-identical canonical JSON, and
    /// thread count does not change a single byte.
    #[test]
    fn report_json_is_byte_deterministic() {
        let model = video_model();
        let a = analyze(&model, &[0.02; 5], &seq_opts()).unwrap();
        let b = analyze(&model, &[0.02; 5], &seq_opts()).unwrap();
        let par = analyze(
            &model,
            &[0.02; 5],
            &SenseOpts {
                threads: 4,
                ..SenseOpts::default()
            },
        )
        .unwrap();
        let text = a.to_json().to_string();
        assert_eq!(text, b.to_json().to_string());
        assert_eq!(text, par.to_json().to_string());
        // canonical JSON carries the schema, not the bookkeeping
        assert!(text.contains("\"point_estimate\":false"));
        assert!(!text.contains("\"hits\""));
    }

    /// A fixed workflow (spec/trace) reports on its generic scale knobs.
    #[test]
    fn fixed_workflow_reports_scale_knobs() {
        let (wf, _) = VideoScenario::default().build();
        let model: Arc<dyn SweepModel> = Arc::new(FixedWorkflow::new("spec", wf));
        let r = analyze(&model, &[], &seq_opts()).unwrap();
        let kinds: Vec<&str> = r.knobs.iter().map(|k| k.kind).collect();
        assert!(kinds.contains(&"link_rate_scale"), "{kinds:?}");
        assert!(kinds.contains(&"cpu_scale"), "{kinds:?}");
        assert!(!kinds.contains(&"fraction"), "{kinds:?}");
        let link = r.knobs.iter().find(|k| k.kind == "link_rate_scale").unwrap();
        assert!(link.derivative.unwrap() < 0.0, "{link:?}");
    }
}
