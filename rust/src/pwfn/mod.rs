//! Piecewise-function math substrate.
//!
//! BottleMod (§4) represents every model function as a piecewise-defined
//! polynomial. This module provides that representation and all operations
//! the solver needs:
//!
//! * [`poly`] — dense `f64` polynomials with exact low-degree and bracketed
//!   high-degree root finding.
//! * [`piecewise`] — [`piecewise::PwPoly`], right-continuous piecewise
//!   polynomials with jumps, lower envelopes with winner attribution,
//!   monotone composition/inversion, and calculus.
//! * [`rat`] / [`linear`] — the exact rational piecewise-linear fast path
//!   (the paper's "only rational numbers are needed" observation).

pub mod linear;
pub mod piecewise;
pub mod poly;
pub mod rat;

pub use linear::{ExactEnvelope, PwLinear};
pub use piecewise::{Envelope, PwPoly};
pub use poly::Poly;
pub use rat::Rat;
