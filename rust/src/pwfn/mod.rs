//! Piecewise-function math substrate.
//!
//! BottleMod (§4) represents every model function as a piecewise-defined
//! polynomial. This module provides that representation and all operations
//! the solver needs:
//!
//! * [`poly`] — dense `f64` polynomials with exact low-degree and bracketed
//!   high-degree root finding.
//! * [`piecewise`] — [`piecewise::PwPoly`], right-continuous piecewise
//!   polynomials with jumps, lower envelopes with winner attribution,
//!   monotone composition/inversion, and calculus. The kernel is
//!   allocation-lean: binary ops run on a streaming two-sequence
//!   breakpoint merge, the n-ary `sum_all`/`min_all`/`max_all` on a
//!   single k-way sweep, and the in-place variants (`add_assign`,
//!   `scale_mut`, `shift_x_mut`, `refine_in_place`) avoid cloning vectors
//!   that are immediately overwritten (cost model: `docs/PERF.md`).
//! * [`rat`] / [`linear`] — the exact rational piecewise-linear fast path
//!   (the paper's "only rational numbers are needed" observation).
//! * [`batch`] — [`batch::BatchPwPoly`], the structure-of-arrays batch
//!   evaluation backend: one-or-many functions compiled to contiguous
//!   degree-padded blocks, evaluated bit-for-bit against scalar `eval`
//!   with galloping piece lookup (`eval_many` / `eval_grid` /
//!   `eval_scenarios` — the sweep/sensitivity/monitor sampling shape).
//!
//! All breakpoint dedup/merge decisions derive from one tolerance,
//! [`piecewise::EPS_BREAK`] / [`piecewise::break_tol`].

pub mod batch;
pub mod linear;
pub mod piecewise;
pub mod poly;
pub mod rat;

pub use batch::BatchPwPoly;
pub use linear::{ExactEnvelope, PwLinear};
pub use piecewise::{break_tol, Envelope, PwPoly, EPS_BREAK};
pub use poly::Poly;
pub use rat::Rat;
