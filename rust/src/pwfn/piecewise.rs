//! Piecewise polynomial functions — the quasi-symbolic substrate of BottleMod.
//!
//! A [`PwPoly`] is defined by `n+1` strictly increasing breakpoints and `n`
//! polynomial pieces. Piece `i` covers `[breaks[i], breaks[i+1])` and is
//! evaluated in *local* coordinates (`x - breaks[i]`) for conditioning. The
//! function is right-continuous: the value at a breakpoint comes from the
//! piece to the right, and a jump discontinuity is simply a pair of adjacent
//! pieces whose values disagree at the shared break ([`PwPoly::jump_at`]).
//!
//! The final breakpoint may be `f64::INFINITY`, in which case the last piece
//! extends forever; left of the first breakpoint the function is clamped to
//! its value at the first breakpoint. This matches the paper's functions:
//! cumulative data inputs and requirement functions are monotone and defined
//! "from here on".

use super::poly::{Poly, EPS};

/// Relative tolerance for breakpoint deduplication.
fn btol(a: f64, b: f64) -> f64 {
    EPS * (1.0 + a.abs().max(b.abs()))
}

/// A piecewise polynomial function (PPoly-style, right-continuous).
#[derive(Clone, Debug, PartialEq)]
pub struct PwPoly {
    /// `n+1` strictly increasing breakpoints; the last may be `+inf`.
    pub breaks: Vec<f64>,
    /// `n` pieces, local coordinates: piece `i` value at `x` is
    /// `polys[i].eval(x - breaks[i])`.
    pub polys: Vec<Poly>,
}

/// A lower envelope together with the index of the winning input function on
/// every piece — the raw material for bottleneck attribution.
#[derive(Clone, Debug, PartialEq)]
pub struct Envelope {
    pub func: PwPoly,
    /// `winners[i]` is the index (into the `min` argument list) of the
    /// function that attains the envelope on piece `i` of `func`.
    pub winners: Vec<usize>,
}

impl PwPoly {
    // ---------------------------------------------------------------- ctors

    /// Build from raw breaks + local-coordinate pieces. Panics on malformed
    /// input (this is an internal constructor; spec parsing validates first).
    pub fn new(breaks: Vec<f64>, polys: Vec<Poly>) -> Self {
        assert!(breaks.len() >= 2, "need at least one piece");
        assert_eq!(breaks.len(), polys.len() + 1, "breaks/polys mismatch");
        for w in breaks.windows(2) {
            assert!(w[0] < w[1], "breaks must be strictly increasing: {w:?}");
        }
        assert!(breaks[0].is_finite(), "first break must be finite");
        PwPoly { breaks, polys }
    }

    /// Constant function `c` on `[x0, inf)`.
    pub fn constant_from(x0: f64, c: f64) -> Self {
        PwPoly::new(vec![x0, f64::INFINITY], vec![Poly::constant(c)])
    }

    /// Constant function `c` on `[0, inf)`.
    pub fn constant(c: f64) -> Self {
        Self::constant_from(0.0, c)
    }

    /// Linear function `y0 + slope * (x - x0)` on `[x0, inf)`.
    pub fn linear_from(x0: f64, y0: f64, slope: f64) -> Self {
        PwPoly::new(vec![x0, f64::INFINITY], vec![Poly::linear(y0, slope)])
    }

    /// Piecewise-linear interpolation through `(x, y)` points (at least two),
    /// extended with a constant after the last point.
    ///
    /// ```
    /// use bottlemod::pwfn::PwPoly;
    ///
    /// // a stream input: 2 B/s for 2 s, then complete at 4 B
    /// let f = PwPoly::from_points(&[(0.0, 0.0), (2.0, 4.0)]);
    /// assert_eq!(f.eval(1.0), 2.0);
    /// assert_eq!(f.eval(10.0), 4.0); // constant extension
    /// assert!(f.is_nondecreasing());
    /// ```
    pub fn from_points(points: &[(f64, f64)]) -> Self {
        assert!(points.len() >= 2, "need at least two points");
        let mut breaks = Vec::with_capacity(points.len() + 1);
        let mut polys = Vec::with_capacity(points.len());
        for w in points.windows(2) {
            let ((x0, y0), (x1, y1)) = (w[0], w[1]);
            assert!(x1 > x0, "points must have increasing x");
            breaks.push(x0);
            polys.push(Poly::linear(y0, (y1 - y0) / (x1 - x0)));
        }
        breaks.push(points[points.len() - 1].0);
        breaks.push(f64::INFINITY);
        polys.push(Poly::constant(points[points.len() - 1].1));
        PwPoly::new(breaks, polys)
    }

    /// Step function: value `lo` on `[x0, at)`, `hi` on `[at, inf)`.
    /// This is the paper's "burst" shape (Fig 1).
    pub fn step(x0: f64, at: f64, lo: f64, hi: f64) -> Self {
        assert!(at > x0);
        PwPoly::new(
            vec![x0, at, f64::INFINITY],
            vec![Poly::constant(lo), Poly::constant(hi)],
        )
    }

    /// Ramp from `(x0, 0)` with `slope`, saturating at value `cap`
    /// (constant afterwards). The paper's "stream" shape with completion.
    pub fn ramp_to(x0: f64, slope: f64, cap: f64) -> Self {
        assert!(slope > 0.0 && cap > 0.0);
        let x_cap = x0 + cap / slope;
        PwPoly::new(
            vec![x0, x_cap, f64::INFINITY],
            vec![Poly::linear(0.0, slope), Poly::constant(cap)],
        )
    }

    // ------------------------------------------------------------ accessors

    pub fn n_pieces(&self) -> usize {
        self.polys.len()
    }

    pub fn x_min(&self) -> f64 {
        self.breaks[0]
    }

    pub fn x_max(&self) -> f64 {
        *self.breaks.last().unwrap()
    }

    /// Index of the piece governing `x` (right-continuous; clamped to
    /// `[0, n-1]`).
    pub fn piece_index(&self, x: f64) -> usize {
        if x < self.breaks[0] {
            return 0;
        }
        // binary search on the inner breaks
        match self.breaks[1..self.breaks.len() - 1]
            .binary_search_by(|b| b.partial_cmp(&x).unwrap())
        {
            Ok(i) => (i + 1).min(self.polys.len() - 1),
            Err(i) => i.min(self.polys.len() - 1),
        }
    }

    /// Evaluate (right-continuous, clamped left of the domain).
    pub fn eval(&self, x: f64) -> f64 {
        let x = x.max(self.breaks[0]);
        let i = self.piece_index(x);
        self.polys[i].eval(x - self.breaks[i])
    }

    /// Left limit at `x` (differs from `eval` exactly at jump breaks).
    pub fn eval_left(&self, x: f64) -> f64 {
        if x <= self.breaks[0] {
            return self.eval(x);
        }
        let i = self.piece_index(x);
        if i > 0 && (x - self.breaks[i]).abs() < btol(x, self.breaks[i]) {
            self.polys[i - 1].eval(x - self.breaks[i - 1])
        } else {
            self.polys[i].eval(x - self.breaks[i])
        }
    }

    /// Jump height at `x` (0 where continuous).
    pub fn jump_at(&self, x: f64) -> f64 {
        self.eval(x) - self.eval_left(x)
    }

    /// Right derivative at `x`.
    pub fn slope_right(&self, x: f64) -> f64 {
        let x = x.max(self.breaks[0]);
        let i = self.piece_index(x);
        self.polys[i].derivative().eval(x - self.breaks[i])
    }

    /// Evaluate on a grid (convenience for exporters/tests).
    pub fn sample(&self, xs: &[f64]) -> Vec<f64> {
        xs.iter().map(|&x| self.eval(x)).collect()
    }

    // ------------------------------------------------------------- calculus

    /// Piecewise derivative. Jumps become finite-slope discontinuities in the
    /// output (the Dirac part is dropped) — the solver handles jumps
    /// explicitly via [`PwPoly::jump_at`], never through `derivative`.
    pub fn derivative(&self) -> PwPoly {
        PwPoly {
            breaks: self.breaks.clone(),
            polys: self.polys.iter().map(|p| p.derivative()).collect(),
        }
    }

    /// Piecewise antiderivative, continuous, with `F(breaks[0]) = c0`.
    /// (Jumps in `self` appear as kinks in the result.)
    pub fn antiderivative(&self, c0: f64) -> PwPoly {
        let mut acc = c0;
        let mut polys = Vec::with_capacity(self.polys.len());
        for (i, p) in self.polys.iter().enumerate() {
            let ad = p.antiderivative(acc);
            let width = self.breaks[i + 1] - self.breaks[i];
            if width.is_finite() {
                acc = ad.eval(width);
            }
            polys.push(ad);
        }
        PwPoly {
            breaks: self.breaks.clone(),
            polys,
        }
    }

    /// Definite integral over `[a, b]` (both within or beyond the domain;
    /// constant extension applies).
    pub fn integrate(&self, a: f64, b: f64) -> f64 {
        if b <= a {
            return 0.0;
        }
        let f = self.antiderivative(0.0);
        // antiderivative uses constant extension of self beyond the last
        // finite break only if last break is inf; clamp manually otherwise.
        f.eval(b) - f.eval(a)
    }

    // ------------------------------------------------------- restructuring

    /// Insert additional breakpoints (values outside the domain or duplicates
    /// are ignored). The function is unchanged.
    pub fn refine(&self, extra: &[f64]) -> PwPoly {
        let mut cuts: Vec<f64> = extra
            .iter()
            .copied()
            .filter(|&x| x > self.breaks[0] && x < self.x_max() && x.is_finite())
            .collect();
        if cuts.is_empty() {
            return self.clone();
        }
        cuts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut breaks = Vec::with_capacity(self.breaks.len() + cuts.len());
        let mut polys = Vec::with_capacity(self.polys.len() + cuts.len());
        let mut ci = 0;
        for i in 0..self.polys.len() {
            breaks.push(self.breaks[i]);
            polys.push(self.polys[i].clone());
            while ci < cuts.len() && cuts[ci] < self.breaks[i + 1] {
                let c = cuts[ci];
                ci += 1;
                if (c - *breaks.last().unwrap()).abs() < btol(c, *breaks.last().unwrap()) {
                    continue;
                }
                // split current piece at c
                let origin = self.breaks[i];
                breaks.push(c);
                polys.push(self.polys[i].shift(c - origin));
            }
        }
        breaks.push(self.x_max());
        PwPoly::new(breaks, polys)
    }

    /// Merge adjacent pieces that are continuations of the same polynomial.
    pub fn simplify(&self) -> PwPoly {
        let mut breaks = vec![self.breaks[0]];
        let mut polys: Vec<Poly> = vec![self.polys[0].clone()];
        for i in 1..self.polys.len() {
            let prev_origin = breaks[breaks.len() - 1];
            let cur_start = self.breaks[i];
            // candidate: previous poly continued to this piece's range
            let cont = polys.last().unwrap().shift(cur_start - prev_origin);
            let scale = cont
                .coeffs
                .iter()
                .chain(self.polys[i].coeffs.iter())
                .fold(1.0f64, |m, c| m.max(c.abs()));
            let same = cont.sub(&self.polys[i])
                .coeffs
                .iter()
                .all(|c| c.abs() <= 1e-9 * scale);
            if !same {
                breaks.push(cur_start);
                polys.push(self.polys[i].clone());
            }
        }
        breaks.push(self.x_max());
        PwPoly::new(breaks, polys)
    }

    /// Restrict to `[a, b]`, keeping constant extension semantics (the last
    /// piece is truncated at `b`; `b` may be `inf`).
    pub fn clip(&self, a: f64, b: f64) -> PwPoly {
        assert!(b > a);
        let r = self.refine(&[a, b]);
        let mut breaks = vec![];
        let mut polys = vec![];
        for i in 0..r.polys.len() {
            let (s, e) = (r.breaks[i], r.breaks[i + 1]);
            if e.is_finite() && e <= a + btol(e, a) {
                continue;
            }
            if b.is_finite() && s >= b - btol(s, b) {
                break;
            }
            if breaks.is_empty() && s < a {
                // starts before a: shift into place
                breaks.push(a);
                polys.push(r.polys[i].shift(a - s));
            } else {
                breaks.push(s.max(a));
                polys.push(r.polys[i].clone());
            }
        }
        if breaks.is_empty() {
            // degenerate: single clamped value
            return PwPoly::new(vec![a, b], vec![Poly::constant(self.eval(a))]);
        }
        breaks.push(b.min(r.x_max().max(b)));
        PwPoly::new(breaks, polys)
    }

    // ------------------------------------------------------------- algebra

    /// The union of both functions' breakpoints, within the joint span.
    fn common_breaks(&self, other: &PwPoly) -> Vec<f64> {
        let lo = self.breaks[0].min(other.breaks[0]);
        let hi = self.x_max().max(other.x_max());
        let mut all: Vec<f64> = self
            .breaks
            .iter()
            .chain(other.breaks.iter())
            .copied()
            .filter(|x| x.is_finite())
            .collect();
        all.push(lo);
        all.sort_by(|a, b| a.partial_cmp(b).unwrap());
        all.dedup_by(|a, b| (*a - *b).abs() < btol(*a, *b));
        if hi.is_infinite() {
            all.push(f64::INFINITY);
        }
        all
    }

    /// Pointwise combination on a common refinement.
    fn zip_with(&self, other: &PwPoly, f: impl Fn(&Poly, &Poly) -> Poly) -> PwPoly {
        let breaks = self.common_breaks(other);
        let mut polys = Vec::with_capacity(breaks.len() - 1);
        for i in 0..breaks.len() - 1 {
            let s = breaks[i];
            let a = self.local_poly_at(s);
            let b = other.local_poly_at(s);
            polys.push(f(&a, &b));
        }
        PwPoly::new(breaks, polys)
    }

    /// The polynomial governing `x`, re-expressed in local coordinates with
    /// origin `x` (clamped/constant-extended outside the domain).
    pub fn local_poly_at(&self, x: f64) -> Poly {
        if x < self.breaks[0] {
            return Poly::constant(self.eval(self.breaks[0]));
        }
        if x >= self.x_max() {
            // constant extension beyond a finite domain end
            return Poly::constant(self.eval_left(self.x_max()));
        }
        let i = self.piece_index(x);
        self.polys[i].shift(x - self.breaks[i])
    }

    pub fn add(&self, other: &PwPoly) -> PwPoly {
        self.zip_with(other, |a, b| a.add(b))
    }

    pub fn sub(&self, other: &PwPoly) -> PwPoly {
        self.zip_with(other, |a, b| a.sub(b))
    }

    pub fn mul(&self, other: &PwPoly) -> PwPoly {
        self.zip_with(other, |a, b| a.mul(b))
    }

    pub fn scale(&self, k: f64) -> PwPoly {
        PwPoly {
            breaks: self.breaks.clone(),
            polys: self.polys.iter().map(|p| p.scale(k)).collect(),
        }
    }

    pub fn shift_y(&self, dy: f64) -> PwPoly {
        PwPoly {
            breaks: self.breaks.clone(),
            polys: self
                .polys
                .iter()
                .map(|p| p.add(&Poly::constant(dy)))
                .collect(),
        }
    }

    /// Translate along x: `g(x) = f(x - dx)`.
    pub fn shift_x(&self, dx: f64) -> PwPoly {
        PwPoly {
            breaks: self.breaks.iter().map(|b| b + dx).collect(),
            polys: self.polys.clone(),
        }
    }

    // ------------------------------------------------------------ envelope

    /// Lower envelope of several functions with per-piece winner indices.
    /// Ties are broken toward the lower index (stable attribution).
    ///
    /// The winner index is the raw material of bottleneck attribution: the
    /// paper's `P_D(t) = min_k P_Dk(t)` keeps track of *which* data input
    /// is the limiting one.
    ///
    /// ```
    /// use bottlemod::pwfn::PwPoly;
    ///
    /// let f = PwPoly::linear_from(0.0, 0.0, 1.0); // x
    /// let g = PwPoly::constant(3.0);              // crosses f at x = 3
    /// let env = PwPoly::min_envelope(&[&f, &g]);
    /// assert_eq!(env.winner_at(1.0), 0);  // f is below
    /// assert_eq!(env.winner_at(10.0), 1); // g is below
    /// assert_eq!(env.func.eval(10.0), 3.0);
    /// ```
    pub fn min_envelope(fns: &[&PwPoly]) -> Envelope {
        assert!(!fns.is_empty());
        let mut env = Envelope {
            func: fns[0].clone(),
            winners: vec![0; fns[0].n_pieces()],
        };
        for (idx, f) in fns.iter().enumerate().skip(1) {
            env = env.min_with(f, idx);
        }
        env.dedup();
        env
    }

    /// Convenience: plain minimum.
    pub fn min(fns: &[&PwPoly]) -> PwPoly {
        Self::min_envelope(fns).func
    }

    /// Pointwise maximum (via `max(f,g) = -min(-f,-g)`).
    pub fn max_with(&self, other: &PwPoly) -> PwPoly {
        PwPoly::min(&[&self.scale(-1.0), &other.scale(-1.0)]).scale(-1.0)
    }

    /// Clamp below at zero — used for pool residual capacities.
    pub fn max_with_zero(&self) -> PwPoly {
        let zero = PwPoly::constant_from(self.breaks[0], 0.0);
        self.max_with(&zero)
    }

    /// First `x >= from` where `eval(x) >= y` for a monotonically
    /// nondecreasing function; `None` if never reached before `x_max`.
    ///
    /// ```
    /// use bottlemod::pwfn::PwPoly;
    ///
    /// // a burst input: nothing until t = 5, then 10 B at once
    /// let f = PwPoly::step(0.0, 5.0, 0.0, 10.0);
    /// assert_eq!(f.first_reach(2.0, 0.0), Some(5.0));
    /// assert_eq!(f.first_reach(11.0, 0.0), None);
    /// ```
    pub fn first_reach(&self, y: f64, from: f64) -> Option<f64> {
        let from = from.max(self.breaks[0]);
        if self.eval(from) >= y - EPS * (1.0 + y.abs()) {
            return Some(from);
        }
        let start = self.piece_index(from);
        for i in start..self.polys.len() {
            let s = self.breaks[i].max(from);
            let e = self.breaks[i + 1];
            // value at start of the (sub)piece
            if self.polys[i].eval(s - self.breaks[i]) >= y - EPS * (1.0 + y.abs()) {
                return Some(s);
            }
            // allocation-free fast path: linear piece
            if let [a, b] = self.polys[i].coeffs.as_slice() {
                if *b > EPS {
                    let x = self.breaks[i] + (y - a) / b;
                    if x >= s - btol(x, s) && x < e + btol(x, e.min(1e300)) {
                        return Some(x.max(s));
                    }
                }
                continue;
            }
            let shifted = self.polys[i].sub(&Poly::constant(y));
            let hi = if e.is_finite() {
                e - self.breaks[i]
            } else {
                cauchy_bound(&shifted).max(1.0)
            };
            if let Some(r) = shifted.first_root_after(s - self.breaks[i] - 1.0, hi) {
                let x = self.breaks[i] + r;
                if x >= s - btol(x, s) && x < e + btol(x, e) {
                    return Some(x.max(s));
                }
            }
        }
        None
    }

    /// Numeric inverse at a single value for strictly increasing functions:
    /// smallest `x` with `f(x) >= y`.
    pub fn inverse_at(&self, y: f64) -> Option<f64> {
        self.first_reach(y, self.breaks[0])
    }

    /// Check monotone nondecreasing (piece derivatives nonnegative on their
    /// intervals and no downward jumps). Tolerance-based.
    pub fn is_nondecreasing(&self) -> bool {
        for i in 0..self.polys.len() {
            let d = self.polys[i].derivative();
            let w = if self.breaks[i + 1].is_finite() {
                self.breaks[i + 1] - self.breaks[i]
            } else {
                1e6
            };
            // sample + roots: a polynomial negative anywhere on [0,w] has a
            // negative value at an endpoint or at a critical point
            let mut pts = vec![0.0, w];
            for r in d.derivative().roots_in(0.0, w) {
                pts.push(r);
            }
            // tolerances are relative to the function's local magnitude:
            // byte-scale functions (~1e9) legitimately carry absolute noise
            let mag = 1.0 + self.eval(self.breaks[i]).abs();
            let slope_mag = 1.0 + d.eval(0.0).abs().max(d.eval(w).abs());
            for p in pts {
                if d.eval(p) < -1e-7 * slope_mag.max(mag * 1e-3) {
                    return false;
                }
            }
            if i > 0 && self.jump_at(self.breaks[i]) < -1e-7 * mag {
                return false;
            }
        }
        true
    }

    // ---------------------------------------------------------- composition

    /// Compose `self(inner(x))` where `inner` is monotonically nondecreasing.
    /// Result breakpoints: the union of `inner`'s breaks and the preimages of
    /// `self`'s breaks under `inner`.
    ///
    /// This is the paper's chaining mechanism: a successor's data input is
    /// `O_m(P(t))`, the producer's output function composed with its
    /// progress function.
    ///
    /// ```
    /// use bottlemod::pwfn::PwPoly;
    ///
    /// // output function O(p) = 3p over a progress that saturates at 2
    /// let outer = PwPoly::linear_from(0.0, 0.0, 3.0);
    /// let inner = PwPoly::from_points(&[(0.0, 0.0), (2.0, 2.0)]);
    /// let chained = outer.compose(&inner);
    /// assert_eq!(chained.eval(1.0), 3.0);
    /// assert_eq!(chained.eval(5.0), 6.0);
    /// ```
    pub fn compose(&self, inner: &PwPoly) -> PwPoly {
        let mut cuts: Vec<f64> = vec![];
        for &b in &self.breaks {
            if !b.is_finite() {
                continue;
            }
            if let Some(x) = inner.first_reach(b, inner.breaks[0]) {
                cuts.push(x);
            }
        }
        let refined = inner.refine(&cuts);
        let mut breaks = Vec::with_capacity(refined.polys.len() + 1);
        let mut polys = Vec::with_capacity(refined.polys.len());
        for i in 0..refined.polys.len() {
            let s = refined.breaks[i];
            breaks.push(s);
            // value of inner just right of s selects the outer piece
            let inner_local = &refined.polys[i]; // local coords origin s
            let y0 = inner_local.eval(0.0);
            if y0 < self.breaks[0] - btol(y0, self.breaks[0]) {
                // inner below the outer domain on this whole piece (cuts
                // split at the crossing): clamp-left semantics
                polys.push(Poly::constant(self.polys[0].eval(0.0)));
                continue;
            }
            let oi = self.piece_index(y0);
            let outer = &self.polys[oi];
            // result(u) = outer(inner_local(u) - outer_origin), u = x - s
            let arg = inner_local.sub(&Poly::constant(self.breaks[oi]));
            polys.push(outer.compose(&arg));
        }
        breaks.push(refined.x_max());
        PwPoly::new(breaks, polys).simplify()
    }

    /// Exact inverse for strictly increasing piecewise functions whose
    /// pieces are linear with positive slope (errors otherwise). Jumps in
    /// the function become flat... no — jumps become *gaps* in the image; the
    /// inverse fills them with a constant piece (the jump time), matching the
    /// "smallest x with f(x) >= y" convention. Plateaus (zero slope) are
    /// skipped: the inverse jumps over them.
    pub fn inverse_linear(&self) -> Result<PwPoly, String> {
        let mut breaks: Vec<f64> = vec![];
        let mut polys: Vec<Poly> = vec![];
        let mut last_y = f64::NEG_INFINITY;
        for i in 0..self.polys.len() {
            let p = &self.polys[i];
            if p.degree() > 1 {
                return Err(format!("piece {i} has degree {} > 1", p.degree()));
            }
            let a = p.coeffs[0];
            let b = if p.degree() == 1 { p.coeffs[1] } else { 0.0 };
            let (s, e) = (self.breaks[i], self.breaks[i + 1]);
            let y_start = a;
            // jump (gap in image) => constant piece mapping [last_y, y_start) -> s
            if i > 0 && y_start > last_y + btol(y_start, last_y) {
                breaks.push(last_y);
                polys.push(Poly::constant(s));
            }
            if b <= EPS {
                // plateau: contributes nothing to the inverse domain
                last_y = last_y.max(y_start);
                continue;
            }
            let y_end = if e.is_finite() {
                p.eval(e - s)
            } else {
                f64::INFINITY
            };
            breaks.push(y_start);
            // inverse piece in local coords (origin y_start):
            // x = s + (y - y_start)/b
            polys.push(Poly::linear(s, 1.0 / b));
            last_y = y_end;
            if !e.is_finite() {
                breaks.push(f64::INFINITY);
                let out = PwPoly::new(breaks, polys);
                return Ok(out);
            }
        }
        if breaks.is_empty() {
            return Err("function has no increasing piece; inverse undefined".into());
        }
        breaks.push(last_y.max(breaks[breaks.len() - 1] + 1e-9));
        Ok(PwPoly::new(breaks, polys))
    }
}

impl Envelope {
    fn min_with(&self, g: &PwPoly, g_idx: usize) -> Envelope {
        let f = &self.func;
        let breaks0 = f.common_breaks(g);
        // split each interval at intersections of f and g
        let mut breaks: Vec<f64> = vec![];
        for i in 0..breaks0.len() - 1 {
            let s = breaks0[i];
            let e = breaks0[i + 1];
            breaks.push(s);
            let d = f.local_poly_at(s).sub(&g.local_poly_at(s));
            let hi = if e.is_finite() {
                e - s
            } else {
                cauchy_bound(&d).max(1.0)
            };
            for r in d.roots_in(0.0, hi) {
                let x = s + r;
                let below_end = !e.is_finite() || x < e - btol(x, e);
                if x > s + btol(x, s) && below_end {
                    breaks.push(x);
                }
            }
        }
        breaks.push(*breaks0.last().unwrap());
        breaks.dedup_by(|a, b| (*a - *b).abs() < btol(*a, *b));

        let mut polys = Vec::with_capacity(breaks.len() - 1);
        let mut winners = Vec::with_capacity(breaks.len() - 1);
        for i in 0..breaks.len() - 1 {
            let s = breaks[i];
            let e = breaks[i + 1];
            let fa = f.local_poly_at(s);
            let ga = g.local_poly_at(s);
            // compare at the interval midpoint (or s + 1 for infinite pieces)
            let m = if e.is_finite() { 0.5 * (e - s) } else { 1.0 };
            let (fv, gv) = (fa.eval(m), ga.eval(m));
            let tol = 1e-9 * (1.0 + fv.abs().max(gv.abs()));
            if gv < fv - tol {
                polys.push(ga);
                winners.push(g_idx);
            } else {
                polys.push(fa);
                // winner index from the underlying envelope piece
                let wi = self.winner_at(s);
                winners.push(wi);
            }
        }
        Envelope {
            func: PwPoly::new(breaks, polys),
            winners,
        }
    }

    /// Winner index governing position `x`.
    pub fn winner_at(&self, x: f64) -> usize {
        self.winners[self.func.piece_index(x)]
    }

    /// Merge adjacent pieces with identical winner *and* continuous equal
    /// polynomials (keeps attribution segments tidy).
    fn dedup(&mut self) {
        let f = &self.func;
        let mut breaks = vec![f.breaks[0]];
        let mut polys = vec![f.polys[0].clone()];
        let mut winners = vec![self.winners[0]];
        for i in 1..f.polys.len() {
            let prev_origin = breaks[breaks.len() - 1];
            let cont = polys.last().unwrap().shift(f.breaks[i] - prev_origin);
            let scale = cont
                .coeffs
                .iter()
                .chain(f.polys[i].coeffs.iter())
                .fold(1.0f64, |m, c| m.max(c.abs()));
            let same_poly = cont
                .sub(&f.polys[i])
                .coeffs
                .iter()
                .all(|c| c.abs() <= 1e-9 * scale);
            if same_poly && self.winners[i] == *winners.last().unwrap() {
                continue;
            }
            breaks.push(f.breaks[i]);
            polys.push(f.polys[i].clone());
            winners.push(self.winners[i]);
        }
        breaks.push(f.x_max());
        self.func = PwPoly::new(breaks, polys);
        self.winners = winners;
    }

    /// Contiguous segments `(start, end, winner)`.
    pub fn segments(&self) -> Vec<(f64, f64, usize)> {
        let mut out: Vec<(f64, f64, usize)> = vec![];
        for i in 0..self.func.n_pieces() {
            let (s, e, w) = (self.func.breaks[i], self.func.breaks[i + 1], self.winners[i]);
            if let Some(last) = out.last_mut() {
                if last.2 == w && (last.1 - s).abs() < btol(last.1, s) {
                    last.1 = e;
                    continue;
                }
            }
            out.push((s, e, w));
        }
        out
    }
}

/// Cauchy root bound for a polynomial in local coordinates: all real roots
/// lie within `[-(1+A), 1+A]` where `A = max |c_i| / |c_lead|`.
pub fn cauchy_bound(p: &Poly) -> f64 {
    let lead = p.coeffs.last().unwrap().abs();
    if lead < EPS {
        return 1.0;
    }
    let a = p.coeffs[..p.coeffs.len() - 1]
        .iter()
        .fold(0.0f64, |m, c| m.max(c.abs()));
    1.0 + a / lead
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: f64, b: f64) {
        assert!((a - b).abs() < 1e-7, "{a} vs {b}");
    }

    #[test]
    fn constant_and_linear_eval() {
        let c = PwPoly::constant(5.0);
        assert_close(c.eval(0.0), 5.0);
        assert_close(c.eval(1e9), 5.0);
        let l = PwPoly::linear_from(1.0, 2.0, 3.0);
        assert_close(l.eval(1.0), 2.0);
        assert_close(l.eval(3.0), 8.0);
        assert_close(l.eval(0.0), 2.0); // clamped left
    }

    #[test]
    fn from_points_interpolates() {
        let f = PwPoly::from_points(&[(0.0, 0.0), (2.0, 4.0), (4.0, 4.0)]);
        assert_close(f.eval(1.0), 2.0);
        assert_close(f.eval(3.0), 4.0);
        assert_close(f.eval(100.0), 4.0);
    }

    #[test]
    fn step_has_jump() {
        let f = PwPoly::step(0.0, 2.0, 0.0, 10.0);
        assert_close(f.eval(1.9), 0.0);
        assert_close(f.eval(2.0), 10.0); // right-continuous
        assert_close(f.eval_left(2.0), 0.0);
        assert_close(f.jump_at(2.0), 10.0);
        assert_close(f.jump_at(1.0), 0.0);
    }

    #[test]
    fn piece_index_binary_search() {
        let f = PwPoly::from_points(&[(0.0, 0.0), (1.0, 1.0), (2.0, 3.0), (3.0, 3.0)]);
        assert_eq!(f.piece_index(0.5), 0);
        assert_eq!(f.piece_index(1.0), 1);
        assert_eq!(f.piece_index(2.5), 2);
        assert_eq!(f.piece_index(50.0), 3);
    }

    #[test]
    fn add_mul_on_common_refinement() {
        let f = PwPoly::from_points(&[(0.0, 0.0), (2.0, 2.0)]); // slope 1 then flat 2
        let g = PwPoly::constant(3.0);
        let s = f.add(&g);
        assert_close(s.eval(1.0), 4.0);
        assert_close(s.eval(10.0), 5.0);
        let m = f.mul(&g);
        assert_close(m.eval(1.0), 3.0);
        assert_close(m.eval(2.0), 6.0);
    }

    #[test]
    fn antiderivative_continuous() {
        let f = PwPoly::step(0.0, 1.0, 1.0, 2.0); // rate 1 then 2
        let g = f.antiderivative(0.0);
        assert_close(g.eval(1.0), 1.0);
        assert_close(g.eval(2.0), 3.0);
        assert_close(f.integrate(0.5, 1.5), 0.5 + 1.0);
    }

    #[test]
    fn refine_preserves_function() {
        let f = PwPoly::from_points(&[(0.0, 0.0), (2.0, 4.0), (3.0, 5.0)]);
        let r = f.refine(&[0.5, 1.0, 2.5, 7.0]);
        for x in [0.0, 0.3, 0.5, 1.0, 1.7, 2.0, 2.5, 2.9, 3.5, 10.0] {
            assert_close(f.eval(x), r.eval(x));
        }
        assert!(r.n_pieces() > f.n_pieces());
    }

    #[test]
    fn simplify_merges() {
        let f = PwPoly::linear_from(0.0, 0.0, 1.0);
        let r = f.refine(&[1.0, 2.0, 3.0]).simplify();
        assert_eq!(r.n_pieces(), 1);
        assert_close(r.eval(2.5), 2.5);
    }

    #[test]
    fn min_envelope_two_lines() {
        let f = PwPoly::linear_from(0.0, 0.0, 1.0); // x
        let g = PwPoly::linear_from(0.0, 2.0, 0.5); // 2 + x/2, crosses at x=4
        let env = PwPoly::min_envelope(&[&f, &g]);
        assert_close(env.func.eval(2.0), 2.0);
        assert_close(env.func.eval(6.0), 5.0);
        let segs = env.segments();
        assert_eq!(segs.len(), 2);
        assert_eq!(segs[0].2, 0);
        assert_eq!(segs[1].2, 1);
        assert_close(segs[0].1, 4.0);
    }

    #[test]
    fn min_envelope_three_with_quadratic() {
        // f = x, g = const 4, h = x^2/8 (crosses f at 0 and 8, g at ~5.66)
        let f = PwPoly::linear_from(0.0, 0.0, 1.0);
        let g = PwPoly::constant(4.0);
        let h = PwPoly::new(
            vec![0.0, f64::INFINITY],
            vec![Poly::new(vec![0.0, 0.0, 0.125])],
        );
        let env = PwPoly::min_envelope(&[&f, &g, &h]);
        // near 0 f and h tie at 0... for x in (0,8) h < f; h < 4 until x = 5.657
        assert_close(env.func.eval(2.0), 0.5);
        assert_close(env.func.eval(7.0), 4.0);
        assert_eq!(env.winner_at(7.0), 1);
        assert_close(env.func.eval(1.0), 0.125);
    }

    #[test]
    fn first_reach_linear_and_jump() {
        let f = PwPoly::from_points(&[(0.0, 0.0), (2.0, 4.0)]);
        assert_close(f.first_reach(2.0, 0.0).unwrap(), 1.0);
        assert!(f.first_reach(5.0, 0.0).is_none());
        let s = PwPoly::step(0.0, 3.0, 1.0, 10.0);
        assert_close(s.first_reach(5.0, 0.0).unwrap(), 3.0);
        assert_close(s.first_reach(0.5, 0.0).unwrap(), 0.0);
    }

    #[test]
    fn first_reach_on_infinite_piece() {
        let f = PwPoly::linear_from(0.0, 0.0, 2.0);
        assert_close(f.first_reach(1000.0, 0.0).unwrap(), 500.0);
    }

    #[test]
    fn compose_linear_pieces() {
        // outer: burst at 10 (0 before, 7 after); inner: data arriving at rate 2
        let outer = PwPoly::step(0.0, 10.0, 0.0, 7.0);
        let inner = PwPoly::linear_from(0.0, 0.0, 2.0);
        let c = outer.compose(&inner);
        assert_close(c.eval(4.9), 0.0);
        assert_close(c.eval(5.0), 7.0);
        assert_close(c.eval(9.0), 7.0);
    }

    #[test]
    fn compose_quadratic_inner() {
        // outer(y) = y^2 on [0, inf); inner(x) = 2x => (2x)^2 = 4x^2
        let outer = PwPoly::new(vec![0.0, f64::INFINITY], vec![Poly::new(vec![0.0, 0.0, 1.0])]);
        let inner = PwPoly::linear_from(0.0, 0.0, 2.0);
        let c = outer.compose(&inner);
        for x in [0.0, 0.5, 1.0, 3.0] {
            assert_close(c.eval(x), 4.0 * x * x);
        }
    }

    #[test]
    fn compose_respects_inner_breaks() {
        let outer = PwPoly::linear_from(0.0, 0.0, 3.0); // 3y
        let inner = PwPoly::from_points(&[(0.0, 0.0), (1.0, 1.0), (2.0, 1.5)]);
        let c = outer.compose(&inner);
        assert_close(c.eval(0.5), 1.5);
        assert_close(c.eval(1.5), 3.0 * 1.25);
        assert_close(c.eval(5.0), 4.5);
    }

    #[test]
    fn inverse_linear_roundtrip() {
        let f = PwPoly::from_points(&[(0.0, 0.0), (2.0, 4.0), (5.0, 10.0)]);
        let inv = f.inverse_linear().unwrap();
        for y in [0.0, 1.0, 3.9, 4.0, 7.0, 9.9] {
            assert_close(f.eval(inv.eval(y)), y);
        }
    }

    #[test]
    fn inverse_linear_with_plateau_and_jump() {
        // plateau between x=1..2 at y=1, then jump at x=3 from 2 to 5
        let f = PwPoly::new(
            vec![0.0, 1.0, 2.0, 3.0, f64::INFINITY],
            vec![
                Poly::linear(0.0, 1.0),
                Poly::constant(1.0),
                Poly::linear(1.0, 1.0),
                Poly::linear(5.0, 1.0),
            ],
        );
        let inv = f.inverse_linear().unwrap();
        // y in (1,2]: x = 2 + (y-1)
        assert_close(inv.eval(1.5), 2.5);
        // y in (2,5]: gap => inverse constant 3
        assert_close(inv.eval(3.0), 3.0);
        assert_close(inv.eval(4.99), 3.0);
        // y > 5: x = 3 + (y-5)
        assert_close(inv.eval(6.0), 4.0);
    }

    #[test]
    fn monotonicity_check() {
        assert!(PwPoly::from_points(&[(0.0, 0.0), (1.0, 2.0), (3.0, 2.0)]).is_nondecreasing());
        assert!(!PwPoly::from_points(&[(0.0, 0.0), (1.0, 2.0), (2.0, 1.0)]).is_nondecreasing());
        assert!(PwPoly::step(0.0, 1.0, 0.0, 5.0).is_nondecreasing());
        // downward jump
        let f = PwPoly::new(
            vec![0.0, 1.0, f64::INFINITY],
            vec![Poly::constant(5.0), Poly::constant(1.0)],
        );
        assert!(!f.is_nondecreasing());
    }

    #[test]
    fn clip_restricts_domain() {
        let f = PwPoly::linear_from(0.0, 0.0, 1.0);
        let c = f.clip(2.0, 5.0);
        assert_close(c.x_min(), 2.0);
        assert_close(c.x_max(), 5.0);
        assert_close(c.eval(3.0), 3.0);
    }

    #[test]
    fn sub_and_scale() {
        let f = PwPoly::linear_from(0.0, 0.0, 2.0);
        let g = PwPoly::linear_from(0.0, 1.0, 1.0);
        let d = f.sub(&g);
        assert_close(d.eval(0.0), -1.0);
        assert_close(d.eval(1.0), 0.0);
        assert_close(d.eval(2.0), 1.0);
        assert_close(f.scale(0.5).eval(4.0), 4.0);
    }
}
