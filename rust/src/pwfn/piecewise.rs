//! Piecewise polynomial functions — the quasi-symbolic substrate of BottleMod.
//!
//! A [`PwPoly`] is defined by `n+1` strictly increasing breakpoints and `n`
//! polynomial pieces. Piece `i` covers `[breaks[i], breaks[i+1])` and is
//! evaluated in *local* coordinates (`x - breaks[i]`) for conditioning. The
//! function is right-continuous: the value at a breakpoint comes from the
//! piece to the right, and a jump discontinuity is simply a pair of adjacent
//! pieces whose values disagree at the shared break ([`PwPoly::jump_at`]).
//!
//! The final breakpoint may be `f64::INFINITY`, in which case the last piece
//! extends forever; left of the first breakpoint the function is clamped to
//! its value at the first breakpoint. This matches the paper's functions:
//! cumulative data inputs and requirement functions are monotone and defined
//! "from here on".

use std::borrow::Cow;

use super::poly::{Poly, EPS};

/// Canonical breakpoint-coincidence tolerance (relative): two breakpoints
/// `a` and `b` denote the *same* break iff `|a - b| < break_tol(a, b)`.
/// Every dedup/merge in the piecewise substrate — the streaming common
/// refinement, [`PwPoly::refine`], [`PwPoly::simplify`], the envelope
/// piece merge, the solver's progress builder and the trace compactor's
/// step widening ([`crate::trace::segment`]) — derives its tolerance from
/// this one constant, so near-coincident breaks collapse identically
/// everywhere (asserted in `tests/pwfn_differential.rs`). It doubles as
/// the relative coefficient tolerance of the "same polynomial
/// continuation" test (`poly_continues`).
pub const EPS_BREAK: f64 = EPS;

/// The absolute coincidence tolerance for breakpoints `a`, `b` (see
/// [`EPS_BREAK`]).
pub fn break_tol(a: f64, b: f64) -> f64 {
    EPS_BREAK * (1.0 + a.abs().max(b.abs()))
}

/// Internal shorthand for [`break_tol`].
fn btol(a: f64, b: f64) -> f64 {
    break_tol(a, b)
}

/// Does `poly` (local origin `start`) continue `prev` (local origin
/// `prev_origin`) as the same polynomial? The shared piece-merge criterion
/// of [`PwPoly::simplify`], the envelope dedup, the simplify-on-build
/// merge used by the k-way ops, and the exact solver's progress builder:
/// coefficients of the shifted continuation agree to [`EPS_BREAK`]
/// relative to the largest coefficient magnitude involved.
pub(crate) fn poly_continues(prev: &Poly, prev_origin: f64, start: f64, poly: &Poly) -> bool {
    let cont = prev.shift(start - prev_origin);
    let scale = cont
        .coeffs
        .iter()
        .chain(poly.coeffs.iter())
        .fold(1.0f64, |m, c| m.max(c.abs()));
    cont.sub(poly)
        .coeffs
        .iter()
        .all(|c| c.abs() <= EPS_BREAK * scale)
}

/// A piecewise polynomial function (PPoly-style, right-continuous).
#[derive(Clone, Debug, PartialEq)]
pub struct PwPoly {
    /// `n+1` strictly increasing breakpoints; the last may be `+inf`.
    pub breaks: Vec<f64>,
    /// `n` pieces, local coordinates: piece `i` value at `x` is
    /// `polys[i].eval(x - breaks[i])`.
    pub polys: Vec<Poly>,
}

/// A lower envelope together with the index of the winning input function on
/// every piece — the raw material for bottleneck attribution.
#[derive(Clone, Debug, PartialEq)]
pub struct Envelope {
    pub func: PwPoly,
    /// `winners[i]` is the index (into the `min` argument list) of the
    /// function that attains the envelope on piece `i` of `func`.
    pub winners: Vec<usize>,
}

impl PwPoly {
    // ---------------------------------------------------------------- ctors

    /// Build from raw breaks + local-coordinate pieces. Panics on malformed
    /// input (this is an internal constructor; spec parsing validates first).
    pub fn new(breaks: Vec<f64>, polys: Vec<Poly>) -> Self {
        assert!(breaks.len() >= 2, "need at least one piece");
        assert_eq!(breaks.len(), polys.len() + 1, "breaks/polys mismatch");
        for w in breaks.windows(2) {
            assert!(w[0] < w[1], "breaks must be strictly increasing: {w:?}");
        }
        assert!(breaks[0].is_finite(), "first break must be finite");
        PwPoly { breaks, polys }
    }

    /// Constant function `c` on `[x0, inf)`.
    pub fn constant_from(x0: f64, c: f64) -> Self {
        PwPoly::new(vec![x0, f64::INFINITY], vec![Poly::constant(c)])
    }

    /// Constant function `c` on `[0, inf)`.
    pub fn constant(c: f64) -> Self {
        Self::constant_from(0.0, c)
    }

    /// Linear function `y0 + slope * (x - x0)` on `[x0, inf)`.
    pub fn linear_from(x0: f64, y0: f64, slope: f64) -> Self {
        PwPoly::new(vec![x0, f64::INFINITY], vec![Poly::linear(y0, slope)])
    }

    /// Piecewise-linear interpolation through `(x, y)` points (at least two),
    /// extended with a constant after the last point.
    ///
    /// ```
    /// use bottlemod::pwfn::PwPoly;
    ///
    /// // a stream input: 2 B/s for 2 s, then complete at 4 B
    /// let f = PwPoly::from_points(&[(0.0, 0.0), (2.0, 4.0)]);
    /// assert_eq!(f.eval(1.0), 2.0);
    /// assert_eq!(f.eval(10.0), 4.0); // constant extension
    /// assert!(f.is_nondecreasing());
    /// ```
    pub fn from_points(points: &[(f64, f64)]) -> Self {
        assert!(points.len() >= 2, "need at least two points");
        let mut breaks = Vec::with_capacity(points.len() + 1);
        let mut polys = Vec::with_capacity(points.len());
        for w in points.windows(2) {
            let ((x0, y0), (x1, y1)) = (w[0], w[1]);
            assert!(x1 > x0, "points must have increasing x");
            breaks.push(x0);
            polys.push(Poly::linear(y0, (y1 - y0) / (x1 - x0)));
        }
        breaks.push(points[points.len() - 1].0);
        breaks.push(f64::INFINITY);
        polys.push(Poly::constant(points[points.len() - 1].1));
        PwPoly::new(breaks, polys)
    }

    /// Step function: value `lo` on `[x0, at)`, `hi` on `[at, inf)`.
    /// This is the paper's "burst" shape (Fig 1).
    pub fn step(x0: f64, at: f64, lo: f64, hi: f64) -> Self {
        assert!(at > x0);
        PwPoly::new(
            vec![x0, at, f64::INFINITY],
            vec![Poly::constant(lo), Poly::constant(hi)],
        )
    }

    /// Ramp from `(x0, 0)` with `slope`, saturating at value `cap`
    /// (constant afterwards). The paper's "stream" shape with completion.
    pub fn ramp_to(x0: f64, slope: f64, cap: f64) -> Self {
        assert!(slope > 0.0 && cap > 0.0);
        let x_cap = x0 + cap / slope;
        PwPoly::new(
            vec![x0, x_cap, f64::INFINITY],
            vec![Poly::linear(0.0, slope), Poly::constant(cap)],
        )
    }

    // ------------------------------------------------------------ accessors

    pub fn n_pieces(&self) -> usize {
        self.polys.len()
    }

    pub fn x_min(&self) -> f64 {
        self.breaks[0]
    }

    pub fn x_max(&self) -> f64 {
        *self.breaks.last().unwrap()
    }

    /// Index of the piece governing `x` (right-continuous; clamped to
    /// `[0, n-1]`).
    pub fn piece_index(&self, x: f64) -> usize {
        if x < self.breaks[0] {
            return 0;
        }
        // binary search on the inner breaks
        match self.breaks[1..self.breaks.len() - 1]
            .binary_search_by(|b| b.partial_cmp(&x).unwrap())
        {
            Ok(i) => (i + 1).min(self.polys.len() - 1),
            Err(i) => i.min(self.polys.len() - 1),
        }
    }

    /// Evaluate (right-continuous, clamped left of the domain).
    pub fn eval(&self, x: f64) -> f64 {
        let x = x.max(self.breaks[0]);
        let i = self.piece_index(x);
        self.polys[i].eval(x - self.breaks[i])
    }

    /// Left limit at `x` (differs from `eval` exactly at jump breaks).
    pub fn eval_left(&self, x: f64) -> f64 {
        if x <= self.breaks[0] {
            return self.eval(x);
        }
        let i = self.piece_index(x);
        if i > 0 && (x - self.breaks[i]).abs() < btol(x, self.breaks[i]) {
            self.polys[i - 1].eval(x - self.breaks[i - 1])
        } else {
            self.polys[i].eval(x - self.breaks[i])
        }
    }

    /// Jump height at `x` (0 where continuous).
    pub fn jump_at(&self, x: f64) -> f64 {
        self.eval(x) - self.eval_left(x)
    }

    /// Right derivative at `x`.
    pub fn slope_right(&self, x: f64) -> f64 {
        let x = x.max(self.breaks[0]);
        let i = self.piece_index(x);
        self.polys[i].derivative().eval(x - self.breaks[i])
    }

    /// Evaluate on a grid (convenience for exporters/tests). Delegates to
    /// [`PwPoly::eval_many`].
    pub fn sample(&self, xs: &[f64]) -> Vec<f64> {
        self.eval_many(xs)
    }

    /// Evaluate at many points through the structure-of-arrays batch
    /// backend ([`crate::pwfn::BatchPwPoly`]): one cheap compile, then a
    /// galloping merge over pieces instead of a per-point binary search.
    /// Bit-for-bit equal to calling [`PwPoly::eval`] per point, for any
    /// query order (pinned by `tests/pwfn_batch_differential.rs`).
    pub fn eval_many(&self, xs: &[f64]) -> Vec<f64> {
        super::batch::BatchPwPoly::compile_one(self).eval_many(xs)
    }

    /// [`PwPoly::eval_many`] fast path for monotone (nondecreasing) grids —
    /// the exporter/report shape. The piece cursor only moves forward: one
    /// comparison per point on the hot path. Results are only defined for
    /// sorted `xs`; use [`PwPoly::eval_many`] for arbitrary order.
    pub fn eval_many_sorted(&self, xs: &[f64]) -> Vec<f64> {
        super::batch::BatchPwPoly::compile_one(self).eval_many_sorted(xs)
    }

    // ------------------------------------------------------------- calculus

    /// Piecewise derivative. Jumps become finite-slope discontinuities in the
    /// output (the Dirac part is dropped) — the solver handles jumps
    /// explicitly via [`PwPoly::jump_at`], never through `derivative`.
    pub fn derivative(&self) -> PwPoly {
        PwPoly {
            breaks: self.breaks.clone(),
            polys: self.polys.iter().map(|p| p.derivative()).collect(),
        }
    }

    /// Piecewise antiderivative, continuous, with `F(breaks[0]) = c0`.
    /// (Jumps in `self` appear as kinks in the result.)
    pub fn antiderivative(&self, c0: f64) -> PwPoly {
        let mut acc = c0;
        let mut polys = Vec::with_capacity(self.polys.len());
        for (i, p) in self.polys.iter().enumerate() {
            let ad = p.antiderivative(acc);
            let width = self.breaks[i + 1] - self.breaks[i];
            if width.is_finite() {
                acc = ad.eval(width);
            }
            polys.push(ad);
        }
        PwPoly {
            breaks: self.breaks.clone(),
            polys,
        }
    }

    /// Definite integral over `[a, b]` (both within or beyond the domain;
    /// constant extension applies).
    pub fn integrate(&self, a: f64, b: f64) -> f64 {
        if b <= a {
            return 0.0;
        }
        let f = self.antiderivative(0.0);
        // antiderivative uses constant extension of self beyond the last
        // finite break only if last break is inf; clamp manually otherwise.
        f.eval(b) - f.eval(a)
    }

    // ------------------------------------------------------- restructuring

    /// Insert additional breakpoints (values outside the domain or duplicates
    /// are ignored). The function is unchanged. Allocation note: when there
    /// is nothing to insert this clones; use [`PwPoly::refine_cow`] /
    /// [`PwPoly::refine_in_place`] on hot paths.
    pub fn refine(&self, extra: &[f64]) -> PwPoly {
        self.refine_cow(extra).into_owned()
    }

    /// [`PwPoly::refine`] without the full clone when there is nothing to
    /// insert: empty or entirely out-of-domain cut sets return
    /// `Cow::Borrowed(self)`.
    pub fn refine_cow<'a>(&'a self, extra: &[f64]) -> Cow<'a, PwPoly> {
        match self.refined_parts(extra) {
            None => Cow::Borrowed(self),
            Some((breaks, polys)) => Cow::Owned(PwPoly::new(breaks, polys)),
        }
    }

    /// In-place [`PwPoly::refine`]: a true no-op (not even a clone) when
    /// `extra` adds nothing.
    pub fn refine_in_place(&mut self, extra: &[f64]) {
        if let Some((breaks, polys)) = self.refined_parts(extra) {
            *self = PwPoly::new(breaks, polys);
        }
    }

    /// Shared refine worker: `None` when no cut falls strictly inside the
    /// domain (the function would be unchanged).
    fn refined_parts(&self, extra: &[f64]) -> Option<(Vec<f64>, Vec<Poly>)> {
        let mut cuts: Vec<f64> = extra
            .iter()
            .copied()
            .filter(|&x| x > self.breaks[0] && x < self.x_max() && x.is_finite())
            .collect();
        if cuts.is_empty() {
            return None;
        }
        cuts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut breaks = Vec::with_capacity(self.breaks.len() + cuts.len());
        let mut polys = Vec::with_capacity(self.polys.len() + cuts.len());
        let mut ci = 0;
        for i in 0..self.polys.len() {
            breaks.push(self.breaks[i]);
            polys.push(self.polys[i].clone());
            while ci < cuts.len() && cuts[ci] < self.breaks[i + 1] {
                let c = cuts[ci];
                ci += 1;
                if (c - *breaks.last().unwrap()).abs() < btol(c, *breaks.last().unwrap()) {
                    continue;
                }
                // split current piece at c
                let origin = self.breaks[i];
                breaks.push(c);
                polys.push(self.polys[i].shift(c - origin));
            }
        }
        breaks.push(self.x_max());
        Some((breaks, polys))
    }

    /// Merge adjacent pieces that are continuations of the same polynomial
    /// (the `poly_continues` criterion, [`EPS_BREAK`]-relative).
    pub fn simplify(&self) -> PwPoly {
        let mut breaks = vec![self.breaks[0]];
        let mut polys: Vec<Poly> = vec![self.polys[0].clone()];
        for i in 1..self.polys.len() {
            let prev_origin = breaks[breaks.len() - 1];
            if poly_continues(
                polys.last().unwrap(),
                prev_origin,
                self.breaks[i],
                &self.polys[i],
            ) {
                continue;
            }
            breaks.push(self.breaks[i]);
            polys.push(self.polys[i].clone());
        }
        breaks.push(self.x_max());
        PwPoly::new(breaks, polys)
    }

    /// Lossy piece reduction under a hard piece budget (deep-graph
    /// scaling, ROADMAP item 3). Coarsens the function to at most
    /// `max(2, max_pieces)` pieces by replacing runs of adjacent
    /// finite-span pieces with their secant (endpoint-interpolating)
    /// line, greedily left-to-right under an error threshold that starts
    /// at `max_err` and is raised (×4) until the budget is met. Returns
    /// the coarsened function and a sound upper bound on
    /// `sup_x |coarse(x) − self(x)|` (`0.0` when the function already
    /// fits the budget and is returned unchanged).
    ///
    /// Guarantees the engine and the generative test layer rely on:
    ///
    /// * the result has at most `max(2, max_pieces)` pieces;
    /// * values at every *kept* break are preserved exactly (the secant
    ///   interpolates run endpoints), so nondecreasing functions stay
    ///   nondecreasing and jumps at kept breaks survive;
    /// * a final infinite-span piece is never merged (no secant over an
    ///   unbounded interval), so constant-extension semantics survive;
    /// * pure `f64` computation of the input only — deterministic, and
    ///   safe to key content-hash caches on ([`crate::runtime::cache`]).
    pub fn simplify_budget(&self, max_pieces: usize, max_err: f64) -> (PwPoly, f64) {
        let cap = max_pieces.max(2);
        if self.n_pieces() <= cap {
            return (self.clone(), 0.0);
        }
        // value scale at the (finite) breaks, for a sane starting
        // threshold when the caller passes max_err <= 0
        let scale = self
            .breaks
            .iter()
            .filter(|b| b.is_finite())
            .map(|&b| self.eval(b).abs())
            .fold(0.0f64, f64::max);
        let mut eps = if max_err > 0.0 {
            max_err
        } else {
            1e-9 * (1.0 + scale)
        };
        for _ in 0..64 {
            let (out, err) = self.coarsen(eps);
            if out.n_pieces() <= cap {
                return (out, err);
            }
            eps *= 4.0;
        }
        // unreachable for finite inputs (eps eventually exceeds the total
        // variation and everything merges); collapse outright as a backstop
        self.coarsen(f64::INFINITY)
    }

    /// One greedy left-to-right coarsening sweep: grow each run of
    /// adjacent finite-span pieces while its secant's error bound stays
    /// within `eps`. Returns the coarsened function and the worst
    /// accepted run bound.
    fn coarsen(&self, eps: f64) -> (PwPoly, f64) {
        let n = self.polys.len();
        let last_inf = !self.x_max().is_finite();
        let merge_n = if last_inf { n - 1 } else { n };
        let mut b = PwBuilder::with_capacity(16);
        let mut worst = 0.0f64;
        let mut i = 0;
        while i < merge_n {
            // run starts as the single exact piece i
            let mut run = (self.polys[i].clone(), 0.0f64);
            let mut j = i + 1;
            while j < merge_n {
                let (sec, err) = self.secant_over(i, j + 1);
                if err <= eps {
                    run = (sec, err);
                    j += 1;
                } else {
                    break;
                }
            }
            b.push(self.breaks[i], run.0);
            worst = worst.max(run.1);
            i = j;
        }
        if last_inf {
            b.push(self.breaks[n - 1], self.polys[n - 1].clone());
        }
        (b.finish(self.x_max()), worst)
    }

    /// Secant line through `(breaks[i], f(breaks[i]))` and
    /// `(breaks[jexcl], f(breaks[jexcl]⁻))` in the local coordinates of
    /// `breaks[i]`, plus a sound sup bound of `|f − secant|` over pieces
    /// `i..jexcl` via per-piece coefficient norms `Σ |d_k| len^k`.
    fn secant_over(&self, i: usize, jexcl: usize) -> (Poly, f64) {
        let a = self.breaks[i];
        let bx = self.breaks[jexcl];
        let ya = self.eval(a);
        let yb = self.eval_left(bx);
        let slope = (yb - ya) / (bx - a);
        let sec = Poly::new(vec![ya, slope]);
        let mut err = 0.0f64;
        for k in i..jexcl {
            let s = self.breaks[k];
            let len = self.breaks[k + 1] - s;
            let p = &self.polys[k];
            // difference in the piece's local coordinates u = x − s:
            // d(u) = p(u) − ya − slope·(u + (s − a))
            let d0 = p.coeffs[0] - ya - slope * (s - a);
            let d1 = p.coeffs.get(1).copied().unwrap_or(0.0) - slope;
            let mut bound = d0.abs() + d1.abs() * len;
            let mut lp = len;
            for c in p.coeffs.iter().skip(2) {
                lp *= len;
                bound += c.abs() * lp;
            }
            err = err.max(bound);
        }
        (sec, err)
    }

    /// True when `clip(a, b)` would return the function unchanged (the
    /// whole-domain clip).
    fn is_clip_noop(&self, a: f64, b: f64) -> bool {
        a <= self.breaks[0] && b == self.x_max()
    }

    /// By-value [`PwPoly::clip`]: the whole-domain clip returns `self`
    /// with no copy at all (the hot `data_envelope` path, where inputs
    /// usually already start at the process start time).
    pub fn clipped(self, a: f64, b: f64) -> PwPoly {
        if b > a && self.is_clip_noop(a, b) {
            self
        } else {
            self.clip(a, b)
        }
    }

    /// Restrict to `[a, b]`, keeping constant extension semantics (the last
    /// piece is truncated at `b`; `b` may be `inf`).
    pub fn clip(&self, a: f64, b: f64) -> PwPoly {
        assert!(b > a);
        if self.is_clip_noop(a, b) {
            return self.clone();
        }
        let r = self.refine_cow(&[a, b]);
        let mut breaks = vec![];
        let mut polys = vec![];
        for i in 0..r.polys.len() {
            let (s, e) = (r.breaks[i], r.breaks[i + 1]);
            if e.is_finite() && e <= a + btol(e, a) {
                continue;
            }
            if b.is_finite() && s >= b - btol(s, b) {
                break;
            }
            if breaks.is_empty() && s < a {
                // starts before a: shift into place
                breaks.push(a);
                polys.push(r.polys[i].shift(a - s));
            } else {
                breaks.push(s.max(a));
                polys.push(r.polys[i].clone());
            }
        }
        if breaks.is_empty() {
            // degenerate: single clamped value
            return PwPoly::new(vec![a, b], vec![Poly::constant(self.eval(a))]);
        }
        breaks.push(b.min(r.x_max().max(b)));
        PwPoly::new(breaks, polys)
    }

    // ------------------------------------------------------------- algebra

    /// The union of both functions' breakpoints, within the joint span.
    /// Retained as the reference for [`merged_breaks`] (the streaming
    /// one-pass equivalent) and by the pairwise envelope reference; the
    /// differential tests pin both to the same output.
    fn common_breaks(&self, other: &PwPoly) -> Vec<f64> {
        let lo = self.breaks[0].min(other.breaks[0]);
        let hi = self.x_max().max(other.x_max());
        let mut all: Vec<f64> = self
            .breaks
            .iter()
            .chain(other.breaks.iter())
            .copied()
            .filter(|x| x.is_finite())
            .collect();
        all.push(lo);
        all.sort_by(|a, b| a.partial_cmp(b).unwrap());
        all.dedup_by(|a, b| (*a - *b).abs() < btol(*a, *b));
        if hi.is_infinite() {
            all.push(f64::INFINITY);
        }
        all
    }

    /// Pointwise combination on the streaming common refinement: the
    /// result's `breaks`/`polys` are each written exactly once, with no
    /// intermediate break-set allocation, no sort, and no per-piece binary
    /// search (both inputs' break lists are already sorted, so a
    /// two-pointer merge + forward piece cursors suffice). Bit-for-bit
    /// identical to the `common_breaks` + `local_poly_at` reference
    /// (pinned by `tests/pwfn_differential.rs`).
    fn zip_with(&self, other: &PwPoly, f: impl Fn(&Poly, &Poly) -> Poly) -> PwPoly {
        let breaks = merged_breaks(&[self, other]);
        let mut ca = PieceCursor::new(self);
        let mut cb = PieceCursor::new(other);
        let mut polys = Vec::with_capacity(breaks.len() - 1);
        for &s in &breaks[..breaks.len() - 1] {
            polys.push(f(&ca.local_at(s), &cb.local_at(s)));
        }
        PwPoly::new(breaks, polys)
    }

    // ------------------------------------------------------- k-way algebra

    /// n-ary sum on a single k-way streaming merge: one pass over the
    /// union of all inputs' breakpoints, one output allocation, and *no*
    /// intermediate `PwPoly` temporaries (a pairwise fold materializes
    /// `k - 1` of them, re-sorting the growing break union each time).
    /// Adjacent result pieces that continue the same polynomial are merged
    /// on build.
    ///
    /// Accumulation order is input order, identical to
    /// `fns[1..].iter().fold(fns[0], add)` up to the sign of exact zeros;
    /// values match the pairwise fold to ≤ 1e-9 relative (bit-for-bit when
    /// no two inputs carry near-coincident breakpoints — there the two
    /// orders may keep different [`EPS_BREAK`]-cluster representatives).
    /// Pinned by `tests/pwfn_differential.rs`.
    pub fn sum_all(fns: &[&PwPoly]) -> PwPoly {
        assert!(!fns.is_empty(), "sum_all needs at least one function");
        if fns.len() == 1 {
            return fns[0].clone();
        }
        let breaks = merged_breaks(fns);
        let mut cursors: Vec<PieceCursor> = fns.iter().map(|&f| PieceCursor::new(f)).collect();
        let mut b = PwBuilder::with_capacity(breaks.len());
        for &s in &breaks[..breaks.len() - 1] {
            let mut acc = cursors[0].local_at(s);
            for c in &mut cursors[1..] {
                acc.add_assign(&c.local_at(s));
            }
            b.push(s, acc);
        }
        b.finish(*breaks.last().unwrap())
    }

    /// n-ary minimum on a single k-way sweep (see [`PwPoly::min_envelope`],
    /// which this shares its implementation with).
    pub fn min_all(fns: &[&PwPoly]) -> PwPoly {
        Self::min_envelope(fns).func
    }

    /// n-ary maximum via `max_i f_i = -min_i(-f_i)`, with the final
    /// negation done in place. Matches a `max_with` fold to ≤ 1e-9
    /// relative (same caveats as [`PwPoly::sum_all`]).
    pub fn max_all(fns: &[&PwPoly]) -> PwPoly {
        assert!(!fns.is_empty(), "max_all needs at least one function");
        let neg: Vec<PwPoly> = fns.iter().map(|f| f.scale(-1.0)).collect();
        let refs: Vec<&PwPoly> = neg.iter().collect();
        let mut out = Self::min_envelope(&refs).func;
        out.scale_mut(-1.0);
        out
    }

    // ----------------------------------------------------- in-place algebra

    /// `self += other`, reusing `self`'s break vector when both functions
    /// share it exactly (the common chained-update case: derived functions
    /// built on the same refinement); other inputs fall back to the pure
    /// streaming [`PwPoly::add`]. Matches `add` bit-for-bit except for the
    /// sign of exact zeros.
    pub fn add_assign(&mut self, other: &PwPoly) {
        if self.breaks == other.breaks {
            for (p, q) in self.polys.iter_mut().zip(other.polys.iter()) {
                p.add_assign(q);
            }
        } else {
            *self = self.add(other);
        }
    }

    /// In-place [`PwPoly::scale`]: no break-vector clone.
    pub fn scale_mut(&mut self, k: f64) {
        for p in &mut self.polys {
            p.scale_in_place(k);
        }
    }

    /// In-place [`PwPoly::shift_x`]: no vector clones at all.
    pub fn shift_x_mut(&mut self, dx: f64) {
        for b in &mut self.breaks {
            *b += dx;
        }
    }

    /// The polynomial governing `x`, re-expressed in local coordinates with
    /// origin `x` (clamped/constant-extended outside the domain).
    pub fn local_poly_at(&self, x: f64) -> Poly {
        if x < self.breaks[0] {
            return Poly::constant(self.eval(self.breaks[0]));
        }
        if x >= self.x_max() {
            // constant extension beyond a finite domain end
            return Poly::constant(self.eval_left(self.x_max()));
        }
        let i = self.piece_index(x);
        self.polys[i].shift(x - self.breaks[i])
    }

    pub fn add(&self, other: &PwPoly) -> PwPoly {
        self.zip_with(other, |a, b| a.add(b))
    }

    pub fn sub(&self, other: &PwPoly) -> PwPoly {
        self.zip_with(other, |a, b| a.sub(b))
    }

    pub fn mul(&self, other: &PwPoly) -> PwPoly {
        self.zip_with(other, |a, b| a.mul(b))
    }

    pub fn scale(&self, k: f64) -> PwPoly {
        PwPoly {
            breaks: self.breaks.clone(),
            polys: self.polys.iter().map(|p| p.scale(k)).collect(),
        }
    }

    pub fn shift_y(&self, dy: f64) -> PwPoly {
        PwPoly {
            breaks: self.breaks.clone(),
            polys: self
                .polys
                .iter()
                .map(|p| p.add(&Poly::constant(dy)))
                .collect(),
        }
    }

    /// Translate along x: `g(x) = f(x - dx)`.
    pub fn shift_x(&self, dx: f64) -> PwPoly {
        PwPoly {
            breaks: self.breaks.iter().map(|b| b + dx).collect(),
            polys: self.polys.clone(),
        }
    }

    // ------------------------------------------------------------ envelope

    /// Lower envelope of several functions with per-piece winner indices.
    /// Ties are broken toward the lower index (stable attribution).
    ///
    /// The winner index is the raw material of bottleneck attribution: the
    /// paper's `P_D(t) = min_k P_Dk(t)` keeps track of *which* data input
    /// is the limiting one.
    ///
    /// ```
    /// use bottlemod::pwfn::PwPoly;
    ///
    /// let f = PwPoly::linear_from(0.0, 0.0, 1.0); // x
    /// let g = PwPoly::constant(3.0);              // crosses f at x = 3
    /// let env = PwPoly::min_envelope(&[&f, &g]);
    /// assert_eq!(env.winner_at(1.0), 0);  // f is below
    /// assert_eq!(env.winner_at(10.0), 1); // g is below
    /// assert_eq!(env.func.eval(10.0), 3.0);
    /// ```
    pub fn min_envelope(fns: &[&PwPoly]) -> Envelope {
        assert!(!fns.is_empty());
        if fns.len() == 1 {
            // a single input: with uniform winners the reference's dedup
            // degenerates to `simplify`, so one simplify pass reproduces
            // the pairwise output bit-for-bit without the intermediate
            // clone the old path paid (clone + dedup rebuild)
            let func = fns[0].simplify();
            let winners = vec![0; func.n_pieces()];
            return Envelope { func, winners };
        }
        // single k-way sweep: one pass over the merged breakpoint union,
        // winner-chasing within each interval over *borrowed* piece views
        // (no per-interval clones; linear crossings in closed form). The
        // pairwise fold below is kept as the semantic reference (O(k) full
        // envelope rebuilds).
        let breaks = merged_breaks(fns);
        let mut cursors: Vec<PieceCursor> = fns.iter().map(|&f| PieceCursor::new(f)).collect();
        let mut eb = EnvBuilder::with_capacity(breaks.len());
        let mut views: Vec<LocalView> = Vec::with_capacity(fns.len());
        for w in breaks.windows(2) {
            let (s, e) = (w[0], w[1]);
            views.clear();
            for c in &mut cursors {
                views.push(c.view_at(s));
            }
            sweep_min_interval(&views, s, e, &mut eb);
        }
        eb.finish(*breaks.last().unwrap())
    }

    /// The pre-refactor pairwise envelope: fold `min_with` over the
    /// inputs, rebuilding the running envelope `k - 1` times. Retained as
    /// the semantic reference implementation — `tests/pwfn_differential.rs`
    /// pins the k-way sweep against it, and `benches/pwfn_kernel.rs`
    /// measures the k-way speedup over it.
    pub fn min_envelope_pairwise(fns: &[&PwPoly]) -> Envelope {
        assert!(!fns.is_empty());
        let mut env = Envelope {
            func: fns[0].clone(),
            winners: vec![0; fns[0].n_pieces()],
        };
        for (idx, f) in fns.iter().enumerate().skip(1) {
            env = env.min_with(f, idx);
        }
        env.dedup();
        env
    }

    /// Convenience: plain minimum.
    pub fn min(fns: &[&PwPoly]) -> PwPoly {
        Self::min_envelope(fns).func
    }

    /// Pointwise maximum (via `max(f,g) = -min(-f,-g)`; the outer negation
    /// is done in place).
    pub fn max_with(&self, other: &PwPoly) -> PwPoly {
        let mut out = PwPoly::min(&[&self.scale(-1.0), &other.scale(-1.0)]);
        out.scale_mut(-1.0);
        out
    }

    /// Clamp below at zero — used for pool residual capacities.
    pub fn max_with_zero(&self) -> PwPoly {
        let zero = PwPoly::constant_from(self.breaks[0], 0.0);
        self.max_with(&zero)
    }

    /// First `x >= from` where `eval(x) >= y` for a monotonically
    /// nondecreasing function; `None` if never reached before `x_max`.
    ///
    /// ```
    /// use bottlemod::pwfn::PwPoly;
    ///
    /// // a burst input: nothing until t = 5, then 10 B at once
    /// let f = PwPoly::step(0.0, 5.0, 0.0, 10.0);
    /// assert_eq!(f.first_reach(2.0, 0.0), Some(5.0));
    /// assert_eq!(f.first_reach(11.0, 0.0), None);
    /// ```
    pub fn first_reach(&self, y: f64, from: f64) -> Option<f64> {
        let from = from.max(self.breaks[0]);
        if self.eval(from) >= y - EPS * (1.0 + y.abs()) {
            return Some(from);
        }
        let start = self.piece_index(from);
        for i in start..self.polys.len() {
            let s = self.breaks[i].max(from);
            let e = self.breaks[i + 1];
            // value at start of the (sub)piece
            if self.polys[i].eval(s - self.breaks[i]) >= y - EPS * (1.0 + y.abs()) {
                return Some(s);
            }
            // allocation-free fast path: linear piece
            if let [a, b] = self.polys[i].coeffs.as_slice() {
                if *b > EPS {
                    let x = self.breaks[i] + (y - a) / b;
                    if x >= s - btol(x, s) && x < e + btol(x, e.min(1e300)) {
                        return Some(x.max(s));
                    }
                }
                continue;
            }
            let shifted = self.polys[i].sub(&Poly::constant(y));
            let hi = if e.is_finite() {
                e - self.breaks[i]
            } else {
                cauchy_bound(&shifted).max(1.0)
            };
            if let Some(r) = shifted.first_root_after(s - self.breaks[i] - 1.0, hi) {
                let x = self.breaks[i] + r;
                if x >= s - btol(x, s) && x < e + btol(x, e) {
                    return Some(x.max(s));
                }
            }
        }
        None
    }

    /// Numeric inverse at a single value for strictly increasing functions:
    /// smallest `x` with `f(x) >= y`.
    pub fn inverse_at(&self, y: f64) -> Option<f64> {
        self.first_reach(y, self.breaks[0])
    }

    /// Check monotone nondecreasing (piece derivatives nonnegative on their
    /// intervals and no downward jumps). Tolerance-based.
    pub fn is_nondecreasing(&self) -> bool {
        for i in 0..self.polys.len() {
            let d = self.polys[i].derivative();
            let w = if self.breaks[i + 1].is_finite() {
                self.breaks[i + 1] - self.breaks[i]
            } else {
                1e6
            };
            // sample + roots: a polynomial negative anywhere on [0,w] has a
            // negative value at an endpoint or at a critical point
            let mut pts = vec![0.0, w];
            for r in d.derivative().roots_in(0.0, w) {
                pts.push(r);
            }
            // tolerances are relative to the function's local magnitude:
            // byte-scale functions (~1e9) legitimately carry absolute noise
            let mag = 1.0 + self.eval(self.breaks[i]).abs();
            let slope_mag = 1.0 + d.eval(0.0).abs().max(d.eval(w).abs());
            for p in pts {
                if d.eval(p) < -1e-7 * slope_mag.max(mag * 1e-3) {
                    return false;
                }
            }
            if i > 0 && self.jump_at(self.breaks[i]) < -1e-7 * mag {
                return false;
            }
        }
        true
    }

    // ---------------------------------------------------------- composition

    /// Compose `self(inner(x))` where `inner` is monotonically nondecreasing.
    /// Result breakpoints: the union of `inner`'s breaks and the preimages of
    /// `self`'s breaks under `inner`.
    ///
    /// This is the paper's chaining mechanism: a successor's data input is
    /// `O_m(P(t))`, the producer's output function composed with its
    /// progress function.
    ///
    /// ```
    /// use bottlemod::pwfn::PwPoly;
    ///
    /// // output function O(p) = 3p over a progress that saturates at 2
    /// let outer = PwPoly::linear_from(0.0, 0.0, 3.0);
    /// let inner = PwPoly::from_points(&[(0.0, 0.0), (2.0, 2.0)]);
    /// let chained = outer.compose(&inner);
    /// assert_eq!(chained.eval(1.0), 3.0);
    /// assert_eq!(chained.eval(5.0), 6.0);
    /// ```
    pub fn compose(&self, inner: &PwPoly) -> PwPoly {
        let mut cuts: Vec<f64> = vec![];
        for &b in &self.breaks {
            if !b.is_finite() {
                continue;
            }
            if let Some(x) = inner.first_reach(b, inner.breaks[0]) {
                cuts.push(x);
            }
        }
        let refined = inner.refine_cow(&cuts);
        let mut breaks = Vec::with_capacity(refined.polys.len() + 1);
        let mut polys = Vec::with_capacity(refined.polys.len());
        for i in 0..refined.polys.len() {
            let s = refined.breaks[i];
            breaks.push(s);
            // value of inner just right of s selects the outer piece
            let inner_local = &refined.polys[i]; // local coords origin s
            let y0 = inner_local.eval(0.0);
            if y0 < self.breaks[0] - btol(y0, self.breaks[0]) {
                // inner below the outer domain on this whole piece (cuts
                // split at the crossing): clamp-left semantics
                polys.push(Poly::constant(self.polys[0].eval(0.0)));
                continue;
            }
            let oi = self.piece_index(y0);
            let outer = &self.polys[oi];
            // result(u) = outer(inner_local(u) - outer_origin), u = x - s
            let arg = inner_local.sub(&Poly::constant(self.breaks[oi]));
            polys.push(outer.compose(&arg));
        }
        breaks.push(refined.x_max());
        PwPoly::new(breaks, polys).simplify()
    }

    /// Exact inverse for strictly increasing piecewise functions whose
    /// pieces are linear with positive slope (errors otherwise). Jumps in
    /// the function become flat... no — jumps become *gaps* in the image; the
    /// inverse fills them with a constant piece (the jump time), matching the
    /// "smallest x with f(x) >= y" convention. Plateaus (zero slope) are
    /// skipped: the inverse jumps over them.
    pub fn inverse_linear(&self) -> Result<PwPoly, String> {
        let mut breaks: Vec<f64> = vec![];
        let mut polys: Vec<Poly> = vec![];
        let mut last_y = f64::NEG_INFINITY;
        for i in 0..self.polys.len() {
            let p = &self.polys[i];
            if p.degree() > 1 {
                return Err(format!("piece {i} has degree {} > 1", p.degree()));
            }
            let a = p.coeffs[0];
            let b = if p.degree() == 1 { p.coeffs[1] } else { 0.0 };
            let (s, e) = (self.breaks[i], self.breaks[i + 1]);
            let y_start = a;
            // jump (gap in image) => constant piece mapping [last_y, y_start) -> s
            if i > 0 && y_start > last_y + btol(y_start, last_y) {
                breaks.push(last_y);
                polys.push(Poly::constant(s));
            }
            if b <= EPS {
                // plateau: contributes nothing to the inverse domain
                last_y = last_y.max(y_start);
                continue;
            }
            let y_end = if e.is_finite() {
                p.eval(e - s)
            } else {
                f64::INFINITY
            };
            breaks.push(y_start);
            // inverse piece in local coords (origin y_start):
            // x = s + (y - y_start)/b
            polys.push(Poly::linear(s, 1.0 / b));
            last_y = y_end;
            if !e.is_finite() {
                breaks.push(f64::INFINITY);
                let out = PwPoly::new(breaks, polys);
                return Ok(out);
            }
        }
        if breaks.is_empty() {
            return Err("function has no increasing piece; inverse undefined".into());
        }
        breaks.push(last_y.max(breaks[breaks.len() - 1] + 1e-9));
        Ok(PwPoly::new(breaks, polys))
    }
}

// ----------------------------------------------------- streaming machinery

/// Sorted union of every input's finite breakpoints in one pass (the
/// inputs' break lists are already sorted — no sort, no intermediate
/// collection), deduplicated to [`EPS_BREAK`] keeping the smallest member
/// of each near-coincident cluster (exactly what sort + `dedup_by` keeps),
/// with a trailing `+inf` iff any input extends forever. For two inputs
/// this is bit-for-bit `common_breaks`.
fn merged_breaks(fns: &[&PwPoly]) -> Vec<f64> {
    let mut ends_infinite = false;
    let mut total = 0usize;
    let mut lists: Vec<&[f64]> = Vec::with_capacity(fns.len());
    for f in fns {
        let mut b: &[f64] = &f.breaks;
        if b.last().copied() == Some(f64::INFINITY) {
            ends_infinite = true;
            b = &b[..b.len() - 1];
        }
        total += b.len();
        lists.push(b);
    }
    let mut pos = vec![0usize; lists.len()];
    let mut out: Vec<f64> = Vec::with_capacity(total + 1);
    loop {
        // smallest pending break; ties keep the earliest input, matching
        // the stable sort of the reference (k is small — linear scan)
        let mut best: Option<f64> = None;
        let mut best_k = 0usize;
        for (k, l) in lists.iter().enumerate() {
            if let Some(&b) = l.get(pos[k]) {
                let smaller = match best {
                    None => true,
                    Some(bb) => b < bb,
                };
                if smaller {
                    best = Some(b);
                    best_k = k;
                }
            }
        }
        let Some(b) = best else { break };
        pos[best_k] += 1;
        match out.last() {
            Some(&last) if (b - last).abs() < btol(b, last) => {}
            _ => out.push(b),
        }
    }
    if ends_infinite {
        out.push(f64::INFINITY);
    }
    out
}

/// A forward-only cursor over one function's pieces. `local_at(x)`
/// re-expresses the piece governing `x` in local coordinates with origin
/// `x`, exactly like [`PwPoly::local_poly_at`] (including the clamp /
/// constant-extension edges), but amortizes the piece lookup to O(1) per
/// call when queried at nondecreasing positions — the streaming sweeps.
struct PieceCursor<'a> {
    f: &'a PwPoly,
    idx: usize,
}

impl<'a> PieceCursor<'a> {
    fn new(f: &'a PwPoly) -> Self {
        PieceCursor { f, idx: 0 }
    }

    /// `x` must be nondecreasing across calls.
    fn local_at(&mut self, x: f64) -> Poly {
        let f = self.f;
        if x < f.breaks[0] || x >= f.x_max() {
            // left clamp / right constant extension: same as the reference
            return f.local_poly_at(x);
        }
        while self.idx + 1 < f.polys.len() && f.breaks[self.idx + 1] <= x {
            self.idx += 1;
        }
        f.polys[self.idx].shift(x - f.breaks[self.idx])
    }

    /// Borrowed view of the piece governing `x` (same clamp semantics as
    /// [`PieceCursor::local_at`], no clone). `x` must be nondecreasing
    /// across calls, mixing freely with `local_at`.
    fn view_at(&mut self, x: f64) -> LocalView<'a> {
        let f = self.f;
        if x < f.breaks[0] {
            return LocalView::Const(f.polys[0].eval(0.0));
        }
        if x >= f.x_max() {
            return LocalView::Const(f.eval_left(f.x_max()));
        }
        while self.idx + 1 < f.polys.len() && f.breaks[self.idx + 1] <= x {
            self.idx += 1;
        }
        LocalView::Piece {
            poly: &f.polys[self.idx],
            origin: f.breaks[self.idx],
        }
    }
}

/// One function restricted to the current sweep interval: either a
/// borrowed polynomial piece (evaluated in its *own* origin — no shifted
/// clone is ever materialized during the sweep) or the clamp/extension
/// constant. Everything takes *global* coordinates.
enum LocalView<'a> {
    Piece { poly: &'a Poly, origin: f64 },
    Const(f64),
}

impl LocalView<'_> {
    fn eval(&self, x: f64) -> f64 {
        match self {
            LocalView::Const(c) => *c,
            LocalView::Piece { poly, origin } => poly.eval(x - origin),
        }
    }

    fn degree(&self) -> usize {
        match self {
            LocalView::Const(_) => 0,
            LocalView::Piece { poly, .. } => poly.degree(),
        }
    }

    /// Slope — only meaningful for `degree() <= 1`.
    fn slope(&self) -> f64 {
        match self {
            LocalView::Const(_) => 0.0,
            LocalView::Piece { poly, .. } => poly.coeffs.get(1).copied().unwrap_or(0.0),
        }
    }

    /// Materialize the piece re-expressed in local coordinates with origin
    /// `at` — the one allocation per emitted envelope piece.
    fn to_local_poly(&self, at: f64) -> Poly {
        match self {
            LocalView::Const(c) => Poly::constant(*c),
            LocalView::Piece { poly, origin } => {
                if at == *origin {
                    (*poly).clone()
                } else {
                    poly.shift(at - origin)
                }
            }
        }
    }
}

/// Simplify-on-build accumulator: a piece that continues the previous
/// polynomial ([`poly_continues`]) is merged instead of emitted, so k-way
/// results never need a separate `simplify` pass.
struct PwBuilder {
    breaks: Vec<f64>,
    polys: Vec<Poly>,
}

impl PwBuilder {
    fn with_capacity(n: usize) -> Self {
        PwBuilder {
            breaks: Vec::with_capacity(n),
            polys: Vec::with_capacity(n),
        }
    }

    fn push(&mut self, start: f64, poly: Poly) {
        if let Some(prev) = self.polys.last() {
            let prev_origin = self.breaks[self.breaks.len() - 1];
            if poly_continues(prev, prev_origin, start, &poly) {
                return;
            }
        }
        self.breaks.push(start);
        self.polys.push(poly);
    }

    fn finish(mut self, x_end: f64) -> PwPoly {
        self.breaks.push(x_end);
        PwPoly::new(self.breaks, self.polys)
    }
}

/// [`PwBuilder`] plus per-piece winner attribution; merges only pieces
/// that share the winner *and* continue the polynomial — the same
/// criterion as `Envelope::dedup` in the pairwise reference.
struct EnvBuilder {
    breaks: Vec<f64>,
    polys: Vec<Poly>,
    winners: Vec<usize>,
}

impl EnvBuilder {
    fn with_capacity(n: usize) -> Self {
        EnvBuilder {
            breaks: Vec::with_capacity(n),
            polys: Vec::with_capacity(n),
            winners: Vec::with_capacity(n),
        }
    }

    fn push(&mut self, start: f64, poly: Poly, winner: usize) {
        if let (Some(prev), Some(&pw)) = (self.polys.last(), self.winners.last()) {
            let prev_origin = self.breaks[self.breaks.len() - 1];
            if pw == winner && poly_continues(prev, prev_origin, start, &poly) {
                return;
            }
        }
        self.breaks.push(start);
        self.polys.push(poly);
        self.winners.push(winner);
    }

    fn finish(mut self, x_end: f64) -> Envelope {
        self.breaks.push(x_end);
        Envelope {
            func: PwPoly::new(self.breaks, self.polys),
            winners: self.winners,
        }
    }
}

/// Winner at `x+` (global): smallest value just right of `x`; near-ties
/// (1e-9 relative, the envelope comparison tolerance of the pairwise
/// reference) are re-ordered at a second, *still-local* probe — `1e-5` of
/// the remaining span (`+1e-5` on infinite intervals) — far enough past
/// `x` for the tied candidates' leading divergence term to register,
/// close enough not to jump past a dip-and-return of the true winner
/// (polynomials tied at `x` diverge monotonically as `c·u^m` until their
/// next crossing, so a far probe like the interval midpoint could pick a
/// function that only wins *after* a missed dip). Ultimate ties break
/// toward the lower index (stable attribution).
fn min_winner_at(views: &[LocalView], x: f64, e: f64) -> usize {
    let probe = x + 1e-9 * (1.0 + x.abs());
    let mut vmin = f64::INFINITY;
    for v in views {
        vmin = vmin.min(v.eval(probe));
    }
    let tol = 1e-9 * (1.0 + vmin.abs());
    let span = if e.is_finite() { e - x } else { 1.0 };
    let probe2 = x + (1e-5 * span).max(1e-9 * (1.0 + x.abs()));
    let mut best = 0usize;
    let mut best_v2 = f64::INFINITY;
    for (i, v) in views.iter().enumerate() {
        if v.eval(probe) <= vmin + tol {
            let v2 = v.eval(probe2);
            if v2 < best_v2 - 1e-12 * (1.0 + v2.abs()) {
                best = i;
                best_v2 = v2;
            }
        }
    }
    best
}

/// Earliest global `x` in `(cur, e)` where `views[j]` drops strictly below
/// `views[w]`, if any. Linear-vs-linear pairs (the §4 workload) are solved
/// in closed form with zero allocation; higher degrees materialize the
/// local difference polynomial and use the kernel's root finder.
fn next_downward_crossing(views: &[LocalView], w: usize, cur: f64, e: f64) -> Option<f64> {
    let vw = &views[w];
    // the winner's local polynomial is only needed on the non-linear path;
    // materialize it lazily, once per leg (not once per opponent)
    let mut pw_local: Option<Poly> = None;
    let mut next: Option<f64> = None;
    for (j, vj) in views.iter().enumerate() {
        if j == w {
            continue;
        }
        let cand = if vj.degree() <= 1 && vw.degree() <= 1 {
            // d(x) = dv + db·(x − cur); j falls below w iff db < 0 and j
            // is still above at cur
            let db = vj.slope() - vw.slope();
            let dv = vj.eval(cur) - vw.eval(cur);
            if db < -1e-15 * (1.0 + vj.slope().abs().max(vw.slope().abs())) && dv > 0.0 {
                Some(cur - dv / db)
            } else {
                None
            }
        } else {
            let pw = pw_local.get_or_insert_with(|| vw.to_local_poly(cur));
            let pj = vj.to_local_poly(cur);
            let d = pj.sub(pw);
            let span = if e.is_finite() {
                e - cur
            } else {
                cauchy_bound(&d).max(1.0)
            };
            let mut found = None;
            for r in d.roots_in(0.0, span) {
                let x = cur + r;
                if x <= cur + btol(cur, x) {
                    continue; // the crossing we just advanced past
                }
                if d.eval(r + 1e-9 * (1.0 + r.abs())) < 0.0 {
                    found = Some(x);
                    break;
                }
            }
            found
        };
        if let Some(x) = cand {
            let past_cur = x > cur + btol(cur, x);
            let before_end = !e.is_finite() || x < e - btol(x, e);
            let earliest = match next {
                None => true,
                Some(n) => x < n,
            };
            if past_cur && before_end && earliest {
                next = Some(x);
            }
        }
    }
    next
}

/// Lower-envelope sweep of one common-refinement interval `[s, e)`:
/// `views[i]` is input `i`'s governing piece (no input changes piece
/// inside the interval). Chases the winner from `s` to the earliest
/// downward crossing by any other input, emitting one envelope piece per
/// leg; only the emitted winner pieces are ever materialized.
fn sweep_min_interval(views: &[LocalView], s: f64, e: f64, eb: &mut EnvBuilder) {
    let mut cur = s;
    // each leg advances past ≥ 1 crossing; degree-≤ 2 differences cross at
    // most twice per pair, so this bounds well-formed inputs — the cap
    // only guards degenerate numerics
    let mut guard = 2 * views.len() * views.len() + 2;
    loop {
        let w = min_winner_at(views, cur, e);
        let next = next_downward_crossing(views, w, cur, e);
        eb.push(cur, views[w].to_local_poly(cur), w);
        guard -= 1;
        match next {
            Some(x) if guard > 0 => cur = x,
            _ => return,
        }
    }
}

impl Envelope {
    fn min_with(&self, g: &PwPoly, g_idx: usize) -> Envelope {
        let f = &self.func;
        let breaks0 = f.common_breaks(g);
        // split each interval at intersections of f and g
        let mut breaks: Vec<f64> = vec![];
        for i in 0..breaks0.len() - 1 {
            let s = breaks0[i];
            let e = breaks0[i + 1];
            breaks.push(s);
            let d = f.local_poly_at(s).sub(&g.local_poly_at(s));
            let hi = if e.is_finite() {
                e - s
            } else {
                cauchy_bound(&d).max(1.0)
            };
            for r in d.roots_in(0.0, hi) {
                let x = s + r;
                let below_end = !e.is_finite() || x < e - btol(x, e);
                if x > s + btol(x, s) && below_end {
                    breaks.push(x);
                }
            }
        }
        breaks.push(*breaks0.last().unwrap());
        breaks.dedup_by(|a, b| (*a - *b).abs() < btol(*a, *b));

        let mut polys = Vec::with_capacity(breaks.len() - 1);
        let mut winners = Vec::with_capacity(breaks.len() - 1);
        for i in 0..breaks.len() - 1 {
            let s = breaks[i];
            let e = breaks[i + 1];
            let fa = f.local_poly_at(s);
            let ga = g.local_poly_at(s);
            // compare at the interval midpoint (or s + 1 for infinite pieces)
            let m = if e.is_finite() { 0.5 * (e - s) } else { 1.0 };
            let (fv, gv) = (fa.eval(m), ga.eval(m));
            let tol = 1e-9 * (1.0 + fv.abs().max(gv.abs()));
            if gv < fv - tol {
                polys.push(ga);
                winners.push(g_idx);
            } else {
                polys.push(fa);
                // winner index from the underlying envelope piece
                let wi = self.winner_at(s);
                winners.push(wi);
            }
        }
        Envelope {
            func: PwPoly::new(breaks, polys),
            winners,
        }
    }

    /// Winner index governing position `x`.
    pub fn winner_at(&self, x: f64) -> usize {
        self.winners[self.func.piece_index(x)]
    }

    /// Merge adjacent pieces with identical winner *and* continuous equal
    /// polynomials ([`poly_continues`] — keeps attribution segments tidy).
    fn dedup(&mut self) {
        let f = &self.func;
        let mut breaks = vec![f.breaks[0]];
        let mut polys = vec![f.polys[0].clone()];
        let mut winners = vec![self.winners[0]];
        for i in 1..f.polys.len() {
            let prev_origin = breaks[breaks.len() - 1];
            let same_poly =
                poly_continues(polys.last().unwrap(), prev_origin, f.breaks[i], &f.polys[i]);
            if same_poly && self.winners[i] == *winners.last().unwrap() {
                continue;
            }
            breaks.push(f.breaks[i]);
            polys.push(f.polys[i].clone());
            winners.push(self.winners[i]);
        }
        breaks.push(f.x_max());
        self.func = PwPoly::new(breaks, polys);
        self.winners = winners;
    }

    /// Contiguous segments `(start, end, winner)`.
    pub fn segments(&self) -> Vec<(f64, f64, usize)> {
        let mut out: Vec<(f64, f64, usize)> = vec![];
        for i in 0..self.func.n_pieces() {
            let (s, e, w) = (self.func.breaks[i], self.func.breaks[i + 1], self.winners[i]);
            if let Some(last) = out.last_mut() {
                if last.2 == w && (last.1 - s).abs() < btol(last.1, s) {
                    last.1 = e;
                    continue;
                }
            }
            out.push((s, e, w));
        }
        out
    }
}

/// Cauchy root bound for a polynomial in local coordinates: all real roots
/// lie within `[-(1+A), 1+A]` where `A = max |c_i| / |c_lead|`.
pub fn cauchy_bound(p: &Poly) -> f64 {
    let lead = p.coeffs.last().unwrap().abs();
    if lead < EPS {
        return 1.0;
    }
    let a = p.coeffs[..p.coeffs.len() - 1]
        .iter()
        .fold(0.0f64, |m, c| m.max(c.abs()));
    1.0 + a / lead
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: f64, b: f64) {
        assert!((a - b).abs() < 1e-7, "{a} vs {b}");
    }

    #[test]
    fn constant_and_linear_eval() {
        let c = PwPoly::constant(5.0);
        assert_close(c.eval(0.0), 5.0);
        assert_close(c.eval(1e9), 5.0);
        let l = PwPoly::linear_from(1.0, 2.0, 3.0);
        assert_close(l.eval(1.0), 2.0);
        assert_close(l.eval(3.0), 8.0);
        assert_close(l.eval(0.0), 2.0); // clamped left
    }

    #[test]
    fn from_points_interpolates() {
        let f = PwPoly::from_points(&[(0.0, 0.0), (2.0, 4.0), (4.0, 4.0)]);
        assert_close(f.eval(1.0), 2.0);
        assert_close(f.eval(3.0), 4.0);
        assert_close(f.eval(100.0), 4.0);
    }

    #[test]
    fn step_has_jump() {
        let f = PwPoly::step(0.0, 2.0, 0.0, 10.0);
        assert_close(f.eval(1.9), 0.0);
        assert_close(f.eval(2.0), 10.0); // right-continuous
        assert_close(f.eval_left(2.0), 0.0);
        assert_close(f.jump_at(2.0), 10.0);
        assert_close(f.jump_at(1.0), 0.0);
    }

    /// A jagged many-piece ramp coarsens to the budget, with values at the
    /// kept breaks preserved and the reported bound honored everywhere.
    #[test]
    fn simplify_budget_caps_pieces_and_bounds_error() {
        // 64-piece piecewise-linear staircase over [0, 64]
        let mut pts = vec![(0.0, 0.0)];
        let mut y = 0.0;
        for i in 0..64 {
            y += if i % 2 == 0 { 2.0 } else { 0.5 };
            pts.push(((i + 1) as f64, y));
        }
        let f = PwPoly::from_points(&pts);
        assert!(f.n_pieces() > 8);
        let (g, err) = f.simplify_budget(8, 0.1);
        assert!(g.n_pieces() <= 8, "got {} pieces", g.n_pieces());
        assert!(err.is_finite() && err > 0.0);
        // endpoints of the whole domain are interpolated exactly
        assert_close(g.eval(0.0), f.eval(0.0));
        assert!((g.eval_left(64.0) - f.eval_left(64.0)).abs() < 1e-9);
        // reported bound respected at dense sample points
        for k in 0..=1000 {
            let x = 64.0 * k as f64 / 1000.0;
            let d = (g.eval(x) - f.eval(x)).abs();
            assert!(d <= err + 1e-9 * (1.0 + y.abs()), "x={x}: |Δ|={d} > {err}");
        }
        // monotone input stays monotone
        assert!(f.is_nondecreasing());
        assert!(g.is_nondecreasing());
    }

    /// Under-budget functions are returned unchanged with a zero bound,
    /// and the infinite tail piece is never merged away.
    #[test]
    fn simplify_budget_noop_and_infinite_tail() {
        let f = PwPoly::from_points(&[(0.0, 0.0), (1.0, 1.0), (2.0, 3.0)]);
        let (g, err) = f.simplify_budget(8, 0.0);
        assert_eq!(g, f);
        assert_eq!(err, 0.0);

        // many pieces with a constant-extension tail: the tail survives
        let mut pts = vec![(0.0, 0.0)];
        for i in 0..32 {
            pts.push(((i + 1) as f64, ((i + 1) as f64).sqrt() * 3.0));
        }
        let h = PwPoly::from_points(&pts);
        let (hb, herr) = h.simplify_budget(4, 0.0);
        assert!(hb.n_pieces() <= 4);
        assert!(herr.is_finite());
        assert!(!hb.x_max().is_finite(), "constant extension must survive");
        assert_close(hb.eval(1e9), h.eval(1e9));
    }

    #[test]
    fn piece_index_binary_search() {
        let f = PwPoly::from_points(&[(0.0, 0.0), (1.0, 1.0), (2.0, 3.0), (3.0, 3.0)]);
        assert_eq!(f.piece_index(0.5), 0);
        assert_eq!(f.piece_index(1.0), 1);
        assert_eq!(f.piece_index(2.5), 2);
        assert_eq!(f.piece_index(50.0), 3);
    }

    #[test]
    fn add_mul_on_common_refinement() {
        let f = PwPoly::from_points(&[(0.0, 0.0), (2.0, 2.0)]); // slope 1 then flat 2
        let g = PwPoly::constant(3.0);
        let s = f.add(&g);
        assert_close(s.eval(1.0), 4.0);
        assert_close(s.eval(10.0), 5.0);
        let m = f.mul(&g);
        assert_close(m.eval(1.0), 3.0);
        assert_close(m.eval(2.0), 6.0);
    }

    #[test]
    fn antiderivative_continuous() {
        let f = PwPoly::step(0.0, 1.0, 1.0, 2.0); // rate 1 then 2
        let g = f.antiderivative(0.0);
        assert_close(g.eval(1.0), 1.0);
        assert_close(g.eval(2.0), 3.0);
        assert_close(f.integrate(0.5, 1.5), 0.5 + 1.0);
    }

    #[test]
    fn refine_preserves_function() {
        let f = PwPoly::from_points(&[(0.0, 0.0), (2.0, 4.0), (3.0, 5.0)]);
        let r = f.refine(&[0.5, 1.0, 2.5, 7.0]);
        for x in [0.0, 0.3, 0.5, 1.0, 1.7, 2.0, 2.5, 2.9, 3.5, 10.0] {
            assert_close(f.eval(x), r.eval(x));
        }
        assert!(r.n_pieces() > f.n_pieces());
    }

    #[test]
    fn simplify_merges() {
        let f = PwPoly::linear_from(0.0, 0.0, 1.0);
        let r = f.refine(&[1.0, 2.0, 3.0]).simplify();
        assert_eq!(r.n_pieces(), 1);
        assert_close(r.eval(2.5), 2.5);
    }

    #[test]
    fn min_envelope_two_lines() {
        let f = PwPoly::linear_from(0.0, 0.0, 1.0); // x
        let g = PwPoly::linear_from(0.0, 2.0, 0.5); // 2 + x/2, crosses at x=4
        let env = PwPoly::min_envelope(&[&f, &g]);
        assert_close(env.func.eval(2.0), 2.0);
        assert_close(env.func.eval(6.0), 5.0);
        let segs = env.segments();
        assert_eq!(segs.len(), 2);
        assert_eq!(segs[0].2, 0);
        assert_eq!(segs[1].2, 1);
        assert_close(segs[0].1, 4.0);
    }

    #[test]
    fn min_envelope_three_with_quadratic() {
        // f = x, g = const 4, h = x^2/8 (crosses f at 0 and 8, g at ~5.66)
        let f = PwPoly::linear_from(0.0, 0.0, 1.0);
        let g = PwPoly::constant(4.0);
        let h = PwPoly::new(
            vec![0.0, f64::INFINITY],
            vec![Poly::new(vec![0.0, 0.0, 0.125])],
        );
        let env = PwPoly::min_envelope(&[&f, &g, &h]);
        // near 0 f and h tie at 0... for x in (0,8) h < f; h < 4 until x = 5.657
        assert_close(env.func.eval(2.0), 0.5);
        assert_close(env.func.eval(7.0), 4.0);
        assert_eq!(env.winner_at(7.0), 1);
        assert_close(env.func.eval(1.0), 0.125);
    }

    #[test]
    fn first_reach_linear_and_jump() {
        let f = PwPoly::from_points(&[(0.0, 0.0), (2.0, 4.0)]);
        assert_close(f.first_reach(2.0, 0.0).unwrap(), 1.0);
        assert!(f.first_reach(5.0, 0.0).is_none());
        let s = PwPoly::step(0.0, 3.0, 1.0, 10.0);
        assert_close(s.first_reach(5.0, 0.0).unwrap(), 3.0);
        assert_close(s.first_reach(0.5, 0.0).unwrap(), 0.0);
    }

    #[test]
    fn first_reach_on_infinite_piece() {
        let f = PwPoly::linear_from(0.0, 0.0, 2.0);
        assert_close(f.first_reach(1000.0, 0.0).unwrap(), 500.0);
    }

    #[test]
    fn compose_linear_pieces() {
        // outer: burst at 10 (0 before, 7 after); inner: data arriving at rate 2
        let outer = PwPoly::step(0.0, 10.0, 0.0, 7.0);
        let inner = PwPoly::linear_from(0.0, 0.0, 2.0);
        let c = outer.compose(&inner);
        assert_close(c.eval(4.9), 0.0);
        assert_close(c.eval(5.0), 7.0);
        assert_close(c.eval(9.0), 7.0);
    }

    #[test]
    fn compose_quadratic_inner() {
        // outer(y) = y^2 on [0, inf); inner(x) = 2x => (2x)^2 = 4x^2
        let outer = PwPoly::new(vec![0.0, f64::INFINITY], vec![Poly::new(vec![0.0, 0.0, 1.0])]);
        let inner = PwPoly::linear_from(0.0, 0.0, 2.0);
        let c = outer.compose(&inner);
        for x in [0.0, 0.5, 1.0, 3.0] {
            assert_close(c.eval(x), 4.0 * x * x);
        }
    }

    #[test]
    fn compose_respects_inner_breaks() {
        let outer = PwPoly::linear_from(0.0, 0.0, 3.0); // 3y
        let inner = PwPoly::from_points(&[(0.0, 0.0), (1.0, 1.0), (2.0, 1.5)]);
        let c = outer.compose(&inner);
        assert_close(c.eval(0.5), 1.5);
        assert_close(c.eval(1.5), 3.0 * 1.25);
        assert_close(c.eval(5.0), 4.5);
    }

    #[test]
    fn inverse_linear_roundtrip() {
        let f = PwPoly::from_points(&[(0.0, 0.0), (2.0, 4.0), (5.0, 10.0)]);
        let inv = f.inverse_linear().unwrap();
        for y in [0.0, 1.0, 3.9, 4.0, 7.0, 9.9] {
            assert_close(f.eval(inv.eval(y)), y);
        }
    }

    #[test]
    fn inverse_linear_with_plateau_and_jump() {
        // plateau between x=1..2 at y=1, then jump at x=3 from 2 to 5
        let f = PwPoly::new(
            vec![0.0, 1.0, 2.0, 3.0, f64::INFINITY],
            vec![
                Poly::linear(0.0, 1.0),
                Poly::constant(1.0),
                Poly::linear(1.0, 1.0),
                Poly::linear(5.0, 1.0),
            ],
        );
        let inv = f.inverse_linear().unwrap();
        // y in (1,2]: x = 2 + (y-1)
        assert_close(inv.eval(1.5), 2.5);
        // y in (2,5]: gap => inverse constant 3
        assert_close(inv.eval(3.0), 3.0);
        assert_close(inv.eval(4.99), 3.0);
        // y > 5: x = 3 + (y-5)
        assert_close(inv.eval(6.0), 4.0);
    }

    #[test]
    fn monotonicity_check() {
        assert!(PwPoly::from_points(&[(0.0, 0.0), (1.0, 2.0), (3.0, 2.0)]).is_nondecreasing());
        assert!(!PwPoly::from_points(&[(0.0, 0.0), (1.0, 2.0), (2.0, 1.0)]).is_nondecreasing());
        assert!(PwPoly::step(0.0, 1.0, 0.0, 5.0).is_nondecreasing());
        // downward jump
        let f = PwPoly::new(
            vec![0.0, 1.0, f64::INFINITY],
            vec![Poly::constant(5.0), Poly::constant(1.0)],
        );
        assert!(!f.is_nondecreasing());
    }

    #[test]
    fn clip_restricts_domain() {
        let f = PwPoly::linear_from(0.0, 0.0, 1.0);
        let c = f.clip(2.0, 5.0);
        assert_close(c.x_min(), 2.0);
        assert_close(c.x_max(), 5.0);
        assert_close(c.eval(3.0), 3.0);
    }

    #[test]
    fn sub_and_scale() {
        let f = PwPoly::linear_from(0.0, 0.0, 2.0);
        let g = PwPoly::linear_from(0.0, 1.0, 1.0);
        let d = f.sub(&g);
        assert_close(d.eval(0.0), -1.0);
        assert_close(d.eval(1.0), 0.0);
        assert_close(d.eval(2.0), 1.0);
        assert_close(f.scale(0.5).eval(4.0), 4.0);
    }

    #[test]
    fn sum_all_matches_pairwise_fold() {
        let f = PwPoly::from_points(&[(0.0, 0.0), (2.0, 4.0)]);
        let g = PwPoly::step(0.0, 3.0, 1.0, 5.0);
        let h = PwPoly::linear_from(1.0, 2.0, 0.5);
        let kway = PwPoly::sum_all(&[&f, &g, &h]);
        let fold = f.add(&g).add(&h);
        for x in [0.0, 0.5, 1.0, 1.5, 2.0, 2.9, 3.0, 3.5, 10.0] {
            assert_close(kway.eval(x), fold.eval(x));
        }
        // single input: identity
        assert_eq!(PwPoly::sum_all(&[&f]), f);
    }

    #[test]
    fn sum_all_merges_continuations_on_build() {
        // two copies of the same line: the sum is one line — the k-way
        // builder merges the redundant interior break of the refinement
        let f = PwPoly::linear_from(0.0, 0.0, 1.0).refine(&[2.0, 4.0]);
        let g = PwPoly::linear_from(0.0, 1.0, 1.0).refine(&[1.0, 3.0]);
        let s = PwPoly::sum_all(&[&f, &g]);
        assert_eq!(s.n_pieces(), 1, "{:?}", s.breaks);
        assert_close(s.eval(5.0), 11.0);
    }

    #[test]
    fn min_all_and_max_all_match_pairwise() {
        let f = PwPoly::linear_from(0.0, 0.0, 1.0);
        let g = PwPoly::constant(3.0);
        let h = PwPoly::linear_from(0.0, 6.0, -0.5);
        let kway = PwPoly::min_all(&[&f, &g, &h]);
        let pair = PwPoly::min_envelope_pairwise(&[&f, &g, &h]).func;
        for x in [0.0, 1.0, 2.9, 3.1, 5.9, 6.1, 10.0, 20.0] {
            assert_close(kway.eval(x), pair.eval(x));
        }
        let mx = PwPoly::max_all(&[&f, &g, &h]);
        for x in [0.0, 1.0, 3.0, 5.0, 7.0, 12.0] {
            let want = f.eval(x).max(g.eval(x)).max(h.eval(x));
            assert_close(mx.eval(x), want);
        }
    }

    #[test]
    fn kway_envelope_matches_pairwise_winners() {
        // the three-function quadratic case of the pairwise tests
        let f = PwPoly::linear_from(0.0, 0.0, 1.0);
        let g = PwPoly::constant(4.0);
        let h = PwPoly::new(
            vec![0.0, f64::INFINITY],
            vec![Poly::new(vec![0.0, 0.0, 0.125])],
        );
        let env = PwPoly::min_envelope(&[&f, &g, &h]);
        assert_close(env.func.eval(2.0), 0.5);
        assert_close(env.func.eval(1.0), 0.125);
        assert_close(env.func.eval(7.0), 4.0);
        assert_eq!(env.winner_at(2.0), 2);
        assert_eq!(env.winner_at(7.0), 1);
    }

    #[test]
    fn kway_envelope_catches_tangent_dip() {
        // w = 1 (const) and j = 1 − u/2 + u²/4 are equal at u = 0; j dips
        // to 0.75 at u = 1 and re-crosses at u = 2. A tie-break toward the
        // function that is lower *far* into the interval would pick w and
        // miss the dip entirely — the local second probe must not.
        let w = PwPoly::constant(1.0);
        let j = PwPoly::new(
            vec![0.0, f64::INFINITY],
            vec![Poly::new(vec![1.0, -0.5, 0.25])],
        );
        let env = PwPoly::min_envelope(&[&w, &j]);
        assert_close(env.func.eval(1.0), 0.75);
        assert_eq!(env.winner_at(1.0), 1);
        assert_close(env.func.eval(5.0), 1.0); // j(5) = 4.75: w wins again
        assert_eq!(env.winner_at(5.0), 0);
        // and the pairwise reference agrees
        let pair = PwPoly::min_envelope_pairwise(&[&w, &j]);
        for x in [0.3, 1.0, 1.7, 2.5, 5.0] {
            assert_close(env.func.eval(x), pair.func.eval(x));
        }
    }

    #[test]
    fn in_place_ops_match_pure() {
        let f = PwPoly::from_points(&[(0.0, 0.0), (2.0, 4.0), (5.0, 5.0)]);
        let g = PwPoly::step(0.0, 3.0, 1.0, 2.0);
        // add_assign, general breaks (falls back to streaming add)
        let mut a = f.clone();
        a.add_assign(&g);
        assert_eq!(a, f.add(&g));
        // add_assign, shared breaks (in-place fast path)
        let mut b = f.clone();
        b.add_assign(&f);
        assert_eq!(b, f.add(&f));
        // scale_mut / shift_x_mut
        let mut c = f.clone();
        c.scale_mut(-2.5);
        assert_eq!(c, f.scale(-2.5));
        let mut d = f.clone();
        d.shift_x_mut(3.0);
        assert_eq!(d, f.shift_x(3.0));
        // refine_in_place
        let mut e = f.clone();
        e.refine_in_place(&[1.0, 4.0]);
        assert_eq!(e, f.refine(&[1.0, 4.0]));
        let mut n = f.clone();
        n.refine_in_place(&[]);
        assert_eq!(n, f);
    }

    #[test]
    fn refine_cow_borrows_when_empty() {
        let f = PwPoly::from_points(&[(0.0, 0.0), (2.0, 4.0)]);
        assert!(matches!(f.refine_cow(&[]), Cow::Borrowed(_)));
        // out-of-domain cuts (left of or at the domain start) add nothing
        assert!(matches!(f.refine_cow(&[-5.0, 0.0]), Cow::Borrowed(_)));
        assert!(matches!(f.refine_cow(&[1.0]), Cow::Owned(_)));
    }

    #[test]
    fn clip_full_domain_is_identity() {
        let f = PwPoly::from_points(&[(0.0, 0.0), (2.0, 4.0)]);
        assert_eq!(f.clip(0.0, f64::INFINITY), f);
        assert_eq!(f.clip(-3.0, f64::INFINITY), f);
        assert_eq!(f.clone().clipped(0.0, f64::INFINITY), f);
        // a real clip still clips
        let c = f.clone().clipped(1.0, 3.0);
        assert_close(c.x_min(), 1.0);
        assert_close(c.x_max(), 3.0);
    }

    #[test]
    fn near_coincident_breaks_collapse_consistently() {
        // a second break within EPS_BREAK of an existing one collapses in
        // the binary refinement, in refine, and in the k-way merge alike
        let x = 2.0;
        let near = x + 0.3 * break_tol(x, x);
        let f = PwPoly::from_points(&[(0.0, 0.0), (x, 4.0)]);
        let g = PwPoly::from_points(&[(0.0, 1.0), (near, 2.0)]);
        let sum = f.add(&g);
        let kway = PwPoly::sum_all(&[&f, &g]);
        // the cluster {x, near} yields exactly one interior break in both
        let count_near = |b: &[f64]| b.iter().filter(|v| (**v - x).abs() < 1e-6).count();
        assert_eq!(count_near(&sum.breaks), 1, "{:?}", sum.breaks);
        assert_eq!(count_near(&kway.breaks), 1, "{:?}", kway.breaks);
        assert_eq!(count_near(&f.refine(&[near]).breaks), 1);
    }
}
