//! Structure-of-arrays batch evaluation of piecewise polynomials.
//!
//! Grid-style consumers (sweep reports, sensitivity scans, replay
//! validation, live-monitor curves, figure exporters) evaluate the same
//! [`PwPoly`]s at hundreds-to-thousands of points. The scalar path pays a
//! per-point binary search over `Vec<Poly>` pointer soup; this module
//! compiles one-or-many functions into one contiguous structure-of-arrays
//! block — the CPU realization of the seed's Pallas kernel layout
//! (`python/compile/kernels/pwpoly_eval.py`: `[B, S+1]` break rows plus
//! `[B, S, D]` degree-padded coefficient blocks) — and evaluates with a
//! galloping merge over pieces instead of independent binary searches.
//!
//! # Layout
//!
//! [`BatchPwPoly::compile`] flattens `M` functions with `P` total pieces:
//!
//! | array     | shape      | contents                                            |
//! |-----------|------------|-----------------------------------------------------|
//! | `offsets` | `[M + 1]`  | function `i`'s pieces are flat rows `offsets[i]..offsets[i+1]` |
//! | `starts`  | `[P]`      | piece start points (`breaks[0..n]`; the final break is never read by eval) |
//! | `coeffs`  | `[P × D]`  | local-coordinate coefficients, lowest degree first, zero-padded to the compile-wide max width `D` |
//!
//! # Bit-for-bit contract
//!
//! Every entry point returns exactly `PwPoly::eval` bit patterns:
//!
//! * **Piece choice** — the scalar `piece_index(x)` is "the number of inner
//!   breaks `<= x`, clamped to `n-1`" (and `0` left of the domain, where
//!   `eval` clamps `x` up to `breaks[0]` anyway). The internal `locate`
//!   helper computes the
//!   same count with a hint-seeded gallop, so the chosen piece — and hence
//!   the local coordinate `u = x - start` — is identical.
//! * **Horner order** — [`crate::pwfn::Poly::eval`] folds coefficients
//!   highest-degree-first from `acc = 0.0`. Zero-padding is exact, not
//!   approximate: after the left clamp, `u >= 0.0` and (for finite `x`)
//!   finite, so each pad step computes `acc = 0.0 * u + 0.0 = +0.0` —
//!   bitwise the same starting accumulator the scalar fold uses. The
//!   remaining steps are the identical operation sequence.
//!
//! The contract is pinned by `tests/pwfn_batch_differential.rs` and
//! asserted (never downgraded) in `benches/pwfn_batch.rs`.

use super::piecewise::PwPoly;

/// One-or-many [`PwPoly`]s compiled to a contiguous structure-of-arrays
/// form for batch evaluation. See the module docs for the layout and the
/// bit-for-bit contract.
#[derive(Clone, Debug)]
pub struct BatchPwPoly {
    /// Flat piece start points; function `i` owns `starts[offsets[i]..offsets[i+1]]`.
    starts: Vec<f64>,
    /// Degree-padded coefficients: flat piece `p` owns
    /// `coeffs[p * dwidth..(p + 1) * dwidth]`, lowest degree first.
    coeffs: Vec<f64>,
    /// Per-function piece ranges; `len() == n_funcs() + 1`.
    offsets: Vec<usize>,
    /// Padded coefficient width (compile-wide max piece degree + 1; `>= 1`).
    dwidth: usize,
}

impl BatchPwPoly {
    /// Compile `M` functions into one shared block. Cheap — one pass over
    /// the pieces and one allocation per array — so compiling per batch
    /// call is fine; hoist the compile out of a loop only when the same
    /// functions are evaluated on many grids.
    pub fn compile(fns: &[&PwPoly]) -> BatchPwPoly {
        let mut offsets = Vec::with_capacity(fns.len() + 1);
        offsets.push(0usize);
        let mut total = 0usize;
        let mut dwidth = 1usize;
        for f in fns {
            total += f.polys.len();
            offsets.push(total);
            for p in &f.polys {
                dwidth = dwidth.max(p.coeffs.len());
            }
        }
        let mut starts = Vec::with_capacity(total);
        let mut coeffs = vec![0.0; total * dwidth];
        let mut row = 0usize;
        for f in fns {
            for (start, p) in f.breaks.iter().zip(&f.polys) {
                starts.push(*start);
                coeffs[row * dwidth..row * dwidth + p.coeffs.len()].copy_from_slice(&p.coeffs);
                row += 1;
            }
        }
        BatchPwPoly {
            starts,
            coeffs,
            offsets,
            dwidth,
        }
    }

    /// [`BatchPwPoly::compile`] for a single function (the
    /// [`PwPoly::eval_many`] delegation path).
    pub fn compile_one(f: &PwPoly) -> BatchPwPoly {
        Self::compile(&[f])
    }

    /// Number of compiled functions.
    pub fn n_funcs(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Total piece count across all compiled functions.
    pub fn n_pieces(&self) -> usize {
        self.starts.len()
    }

    /// Padded coefficient width `D` (max degree + 1 across the compile).
    pub fn coeff_width(&self) -> usize {
        self.dwidth
    }

    /// Evaluate compiled function `i` at one point — the scalar reference
    /// entry point (`== fns[i].eval(x)` bit-for-bit).
    pub fn eval_one(&self, i: usize, x: f64) -> f64 {
        let lo = self.offsets[i];
        let row = &self.starts[lo..self.offsets[i + 1]];
        let x = x.max(row[0]);
        let idx = locate(row, 0, x);
        self.horner(lo + idx, x - row[idx])
    }

    /// Evaluate the single compiled function at `N` sorted-or-unsorted
    /// points. Piece lookup gallops from the previous point's piece, so a
    /// sorted (or locally clustered) grid costs amortized `O(1)` per point
    /// instead of the scalar path's `O(log n)` binary search; arbitrary
    /// order degrades gracefully to a gallop-bracketed binary search and
    /// stays exact.
    ///
    /// Panics if more than one function was compiled — use
    /// [`BatchPwPoly::eval_scenarios`] / [`BatchPwPoly::eval_grid`] for
    /// batches.
    pub fn eval_many(&self, xs: &[f64]) -> Vec<f64> {
        assert_eq!(
            self.n_funcs(),
            1,
            "eval_many is the single-function entry point; use eval_scenarios/eval_grid"
        );
        self.eval_scenarios(xs)
    }

    /// [`BatchPwPoly::eval_many`] specialized to nondecreasing grids: the
    /// piece cursor only ever moves forward, one comparison per point on
    /// the hot path. Results are only defined for monotone `xs`
    /// (`debug_assert`ed); pass arbitrary order to [`BatchPwPoly::eval_many`]
    /// instead.
    pub fn eval_many_sorted(&self, xs: &[f64]) -> Vec<f64> {
        assert_eq!(self.n_funcs(), 1, "eval_many_sorted is the single-function entry point");
        debug_assert!(
            xs.windows(2).all(|w| w[0] <= w[1]),
            "eval_many_sorted needs a nondecreasing grid"
        );
        let row = &self.starts[..];
        let x0 = row[0];
        let last = row.len() - 1;
        let mut idx = 0usize;
        let mut out = Vec::with_capacity(xs.len());
        for &x in xs {
            let x = x.max(x0);
            while idx < last && row[idx + 1] <= x {
                idx += 1;
            }
            out.push(self.horner(idx, x - row[idx]));
        }
        out
    }

    /// Evaluate all `M` compiled functions at all `N` points,
    /// function-major: `out[i * N + j] == fns[i].eval(xs[j])`. One merged
    /// pass over each function's pieces (per-function forward cursor with
    /// gallop fallback for unsorted grids).
    pub fn eval_scenarios(&self, xs: &[f64]) -> Vec<f64> {
        let m = self.n_funcs();
        let n = xs.len();
        let mut out = Vec::with_capacity(m * n);
        for i in 0..m {
            let lo = self.offsets[i];
            let row = &self.starts[lo..self.offsets[i + 1]];
            let x0 = row[0];
            let mut idx = 0usize;
            for &x in xs {
                let x = x.max(x0);
                idx = locate(row, idx, x);
                out.push(self.horner(lo + idx, x - row[idx]));
            }
        }
        out
    }

    /// Evaluate all `M` compiled functions at all `N` points, point-major
    /// (the transpose of [`BatchPwPoly::eval_scenarios`]):
    /// `out[j * M + i] == fns[i].eval(xs[j])`. One outer pass over the
    /// grid advancing `M` piece cursors in lockstep, with contiguous
    /// column-major writes — the shape sweep reports and sensitivity scans
    /// consume (all curves at one time point sit adjacent).
    pub fn eval_grid(&self, xs: &[f64]) -> Vec<f64> {
        let m = self.n_funcs();
        let n = xs.len();
        let mut out = vec![0.0; n * m];
        let mut cursors = vec![0usize; m];
        for (j, &x_raw) in xs.iter().enumerate() {
            let base = j * m;
            for i in 0..m {
                let lo = self.offsets[i];
                let row = &self.starts[lo..self.offsets[i + 1]];
                let x = x_raw.max(row[0]);
                let idx = locate(row, cursors[i], x);
                cursors[i] = idx;
                out[base + i] = self.horner(lo + idx, x - row[idx]);
            }
        }
        out
    }

    /// Horner fold over flat piece `piece` at local coordinate `u`;
    /// bit-identical to the scalar [`crate::pwfn::Poly::eval`] (zero pads
    /// contribute an exact `+0.0` accumulator — see the module docs).
    #[inline]
    fn horner(&self, piece: usize, u: f64) -> f64 {
        let c = &self.coeffs[piece * self.dwidth..(piece + 1) * self.dwidth];
        let mut acc = 0.0;
        for &k in c.iter().rev() {
            acc = acc * u + k;
        }
        acc
    }
}

/// Piece index of `x` within one function's `starts` row (strictly
/// increasing, `starts[0]` finite): the largest `idx` with
/// `starts[idx] <= x`, i.e. `min(#{j >= 1 : starts[j] <= x}, n-1)` — the
/// exact `PwPoly::piece_index` semantics — and `0` when `x < starts[0]`.
///
/// `hint` is the previous lookup's result. The hot path (the hint still
/// governs `x`, or the next piece does) is branch-light; otherwise an
/// exponential gallop from the hint brackets `x` and a binary search
/// finishes, so mis-hints cost `O(log distance)` and stay exact — sorted,
/// reverse-sorted, and arbitrary query orders all produce scalar-identical
/// piece choices.
fn locate(starts: &[f64], hint: usize, x: f64) -> usize {
    let last = starts.len() - 1;
    let idx = hint.min(last);
    let (lo, hi);
    if starts[idx] <= x {
        if idx == last || x < starts[idx + 1] {
            return idx; // hot path: hint still governs x
        }
        // gallop right to bracket: starts[lo] <= x < starts[hi]
        let mut l = idx + 1;
        let mut step = 1usize;
        while l + step <= last && starts[l + step] <= x {
            l += step;
            step <<= 1;
        }
        lo = l;
        hi = (l + step).min(last + 1);
    } else {
        if x < starts[0] {
            return 0; // left of the domain (eval clamps to piece 0)
        }
        // gallop left to bracket: starts[lo] <= x < starts[hi]
        let mut h = idx;
        let mut step = 1usize;
        while step <= h && starts[h - step] > x {
            h -= step;
            step <<= 1;
        }
        lo = h - step.min(h);
        hi = h;
    }
    // binary count of pieces in (lo, hi) whose start is <= x
    lo + starts[lo + 1..hi].partition_point(|s| *s <= x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pwfn::Poly;

    fn stepper() -> PwPoly {
        // jump at 10, quadratic middle, constant tail from 20 on
        PwPoly::new(
            vec![0.0, 10.0, 20.0, f64::INFINITY],
            vec![
                Poly::linear(1.0, 0.5),
                Poly::new(vec![9.0, 0.0, 0.25]),
                Poly::constant(34.0),
            ],
        )
    }

    #[test]
    fn eval_one_matches_scalar_everywhere() {
        let f = stepper();
        let b = BatchPwPoly::compile_one(&f);
        for x in [-5.0, 0.0, 3.7, 10.0 - 1e-12, 10.0, 15.5, 20.0, 1e6] {
            assert_eq!(b.eval_one(0, x).to_bits(), f.eval(x).to_bits(), "x={x}");
        }
    }

    #[test]
    fn locate_matches_piece_index_for_every_hint() {
        let f = stepper();
        let starts = &f.breaks[..f.polys.len()];
        for x in [-1.0, 0.0, 5.0, 10.0, 12.0, 20.0, 25.0] {
            for hint in 0..=4 {
                let expect = if x < starts[0] { 0 } else { f.piece_index(x) };
                assert_eq!(locate(starts, hint, x), expect, "x={x} hint={hint}");
            }
        }
    }

    #[test]
    fn grid_is_transposed_scenarios() {
        let f = stepper();
        let g = PwPoly::constant(7.0);
        let b = BatchPwPoly::compile(&[&f, &g]);
        let xs = [0.0, 30.0, 2.0, 11.0, 11.0, -4.0];
        let sc = b.eval_scenarios(&xs);
        let gr = b.eval_grid(&xs);
        assert_eq!(sc.len(), 2 * xs.len());
        for i in 0..2 {
            for j in 0..xs.len() {
                assert_eq!(sc[i * xs.len() + j].to_bits(), gr[j * 2 + i].to_bits());
            }
        }
    }

    #[test]
    fn unsorted_inputs_stay_exact() {
        let f = stepper();
        let b = BatchPwPoly::compile_one(&f);
        let xs = [25.0, 0.0, 19.9, 10.0, -3.0, 50.0, 10.0];
        let got = b.eval_many(&xs);
        for (&x, &y) in xs.iter().zip(&got) {
            assert_eq!(y.to_bits(), f.eval(x).to_bits(), "x={x}");
        }
    }

    #[test]
    fn empty_compile_and_empty_grid() {
        let b = BatchPwPoly::compile(&[]);
        assert_eq!(b.n_funcs(), 0);
        assert!(b.eval_grid(&[1.0, 2.0]).is_empty());
        assert!(b.eval_scenarios(&[1.0, 2.0]).is_empty());
        let one = BatchPwPoly::compile_one(&PwPoly::constant(3.0));
        assert!(one.eval_many(&[]).is_empty());
        assert!(one.eval_many_sorted(&[]).is_empty());
    }
}
