//! Dense univariate polynomials over `f64`.
//!
//! The building block of the piecewise-function substrate
//! ([`super::piecewise`]), which in turn carries every model function of
//! paper §2/§4 (requirements, inputs, outputs, progress). Coefficients are
//! stored lowest-degree first: `c[0] + c[1] x + c[2] x^2 + ...`.
//! All piecewise machinery evaluates polynomials in a *local* coordinate
//! (offset from the piece's left break) to keep conditioning sane, so the
//! raw polynomial type is deliberately simple and allocation-friendly.
//!
//! # Invariants
//!
//! * Trailing (near-)zero coefficients are trimmed by [`Poly::new`]; the
//!   zero polynomial is exactly `[0.0]`, so `degree()` is always defined.
//! * Every operation is a **pure `f64` computation** — identical operands
//!   give bit-identical results on any thread, which the sweep engine's
//!   determinism contract and the analysis-cache keys inherit.
//! * Root finding is exact (closed-form) for degree ≤ 2 and bracketed
//!   bisection for higher degrees; returned roots lie in the queried
//!   interval and are deduplicated to [`EPS`] tolerance.
//!
//! # Cost model
//!
//! Evaluation is Horner's rule, `O(degree)`; add/sub/scale are
//! `O(degree)`, multiplication and composition `O(degree²)` on the tiny
//! degrees (≤ 3 in practice) the models produce. Nothing here allocates
//! proportionally to *data volume* — only to piece/degree counts, keeping
//! the solver's §6 "flat in bytes" property intact.

use std::fmt;

/// Tolerance used for coefficient trimming and root deduplication.
pub const EPS: f64 = 1e-9;

/// A dense polynomial, lowest-degree coefficient first.
#[derive(Clone, PartialEq)]
pub struct Poly {
    /// `coeffs[i]` is the coefficient of `x^i`. Trailing zeros are trimmed;
    /// the zero polynomial is represented as `[0.0]`.
    pub coeffs: Vec<f64>,
}

impl fmt::Debug for Poly {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut first = true;
        for (i, c) in self.coeffs.iter().enumerate() {
            if *c == 0.0 && self.coeffs.len() > 1 {
                continue;
            }
            if !first {
                write!(f, " + ")?;
            }
            match i {
                0 => write!(f, "{c}")?,
                1 => write!(f, "{c}·x")?,
                _ => write!(f, "{c}·x^{i}")?,
            }
            first = false;
        }
        if first {
            write!(f, "0")?;
        }
        Ok(())
    }
}

impl Poly {
    /// Build a polynomial from coefficients (lowest degree first).
    pub fn new(mut coeffs: Vec<f64>) -> Self {
        while coeffs.len() > 1 && coeffs.last() == Some(&0.0) {
            coeffs.pop();
        }
        if coeffs.is_empty() {
            coeffs.push(0.0);
        }
        Poly { coeffs }
    }

    /// The zero polynomial.
    pub fn zero() -> Self {
        Poly { coeffs: vec![0.0] }
    }

    /// The constant polynomial `c`.
    pub fn constant(c: f64) -> Self {
        Poly { coeffs: vec![c] }
    }

    /// The linear polynomial `a + b x`.
    pub fn linear(a: f64, b: f64) -> Self {
        Poly::new(vec![a, b])
    }

    /// Degree (0 for constants, including the zero polynomial).
    pub fn degree(&self) -> usize {
        self.coeffs.len() - 1
    }

    /// True if every coefficient is (almost) zero.
    pub fn is_zero(&self) -> bool {
        self.coeffs.iter().all(|c| c.abs() < EPS)
    }

    /// True if the polynomial is a constant (degree 0 after trimming).
    pub fn is_constant(&self) -> bool {
        self.coeffs.len() == 1
    }

    /// Horner evaluation.
    pub fn eval(&self, x: f64) -> f64 {
        let mut acc = 0.0;
        for &c in self.coeffs.iter().rev() {
            acc = acc * x + c;
        }
        acc
    }

    /// First derivative.
    pub fn derivative(&self) -> Poly {
        if self.coeffs.len() <= 1 {
            return Poly::zero();
        }
        Poly::new(
            self.coeffs[1..]
                .iter()
                .enumerate()
                .map(|(i, c)| c * (i as f64 + 1.0))
                .collect(),
        )
    }

    /// Antiderivative with constant term `c0`.
    pub fn antiderivative(&self, c0: f64) -> Poly {
        let mut out = Vec::with_capacity(self.coeffs.len() + 1);
        out.push(c0);
        for (i, c) in self.coeffs.iter().enumerate() {
            out.push(c / (i as f64 + 1.0));
        }
        Poly::new(out)
    }

    pub fn add(&self, other: &Poly) -> Poly {
        let n = self.coeffs.len().max(other.coeffs.len());
        let mut out = vec![0.0; n];
        for (i, c) in self.coeffs.iter().enumerate() {
            out[i] += c;
        }
        for (i, c) in other.coeffs.iter().enumerate() {
            out[i] += c;
        }
        Poly::new(out)
    }

    /// In-place [`Poly::add`]: grows `self` only when `other` has the
    /// larger degree, otherwise allocation-free. Matches `add` bit-for-bit
    /// except for the sign of exact zeros (`0.0 + x` vs `x`).
    pub fn add_assign(&mut self, other: &Poly) {
        if other.coeffs.len() > self.coeffs.len() {
            self.coeffs.resize(other.coeffs.len(), 0.0);
        }
        for (i, c) in other.coeffs.iter().enumerate() {
            self.coeffs[i] += c;
        }
        self.trim();
    }

    /// In-place [`Poly::scale`]: allocation-free.
    pub fn scale_in_place(&mut self, k: f64) {
        for c in &mut self.coeffs {
            *c *= k;
        }
        self.trim();
    }

    /// Re-establish the [`Poly::new`] trimming invariant after an in-place
    /// edit (trailing exact zeros removed, zero polynomial stays `[0.0]`).
    fn trim(&mut self) {
        while self.coeffs.len() > 1 && self.coeffs.last() == Some(&0.0) {
            self.coeffs.pop();
        }
    }

    pub fn sub(&self, other: &Poly) -> Poly {
        let n = self.coeffs.len().max(other.coeffs.len());
        let mut out = vec![0.0; n];
        for (i, c) in self.coeffs.iter().enumerate() {
            out[i] += c;
        }
        for (i, c) in other.coeffs.iter().enumerate() {
            out[i] -= c;
        }
        Poly::new(out)
    }

    pub fn scale(&self, k: f64) -> Poly {
        Poly::new(self.coeffs.iter().map(|c| c * k).collect())
    }

    pub fn mul(&self, other: &Poly) -> Poly {
        let mut out = vec![0.0; self.coeffs.len() + other.coeffs.len() - 1];
        for (i, a) in self.coeffs.iter().enumerate() {
            for (j, b) in other.coeffs.iter().enumerate() {
                out[i + j] += a * b;
            }
        }
        Poly::new(out)
    }

    /// Compose `self(other(x))`.
    pub fn compose(&self, other: &Poly) -> Poly {
        // Horner in the polynomial ring.
        let mut acc = Poly::constant(*self.coeffs.last().unwrap());
        for &c in self.coeffs.iter().rev().skip(1) {
            acc = acc.mul(other).add(&Poly::constant(c));
        }
        acc
    }

    /// Substitute `x -> x + h` (shift the argument), i.e. return `q` with
    /// `q(x) = self(x + h)`.
    ///
    /// Closed forms for the degrees the solver actually produces (0–2);
    /// generic Horner-composition above that.
    pub fn shift(&self, h: f64) -> Poly {
        match self.coeffs.as_slice() {
            [_] => self.clone(),
            [a, b] => Poly::new(vec![a + b * h, *b]),
            [a, b, c] => Poly::new(vec![a + b * h + c * h * h, b + 2.0 * c * h, *c]),
            _ => self.compose(&Poly::linear(h, 1.0)),
        }
    }

    /// All real roots inside the closed interval `[lo, hi]`, ascending and
    /// deduplicated. Exact formulas for degree ≤ 2, recursive bracketing via
    /// the derivative's roots (which give the monotone segments) above.
    pub fn roots_in(&self, lo: f64, hi: f64) -> Vec<f64> {
        let mut out = self.roots_in_raw(lo, hi);
        out.sort_by(|a, b| a.partial_cmp(b).unwrap());
        out.dedup_by(|a, b| (*a - *b).abs() < EPS * (1.0 + a.abs().max(b.abs())));
        out
    }

    fn roots_in_raw(&self, lo: f64, hi: f64) -> Vec<f64> {
        if lo > hi {
            return vec![];
        }
        // Work on a trimmed view: ignore negligible leading coefficients
        // relative to the coefficient magnitude.
        let scale = self.coeffs.iter().fold(0.0f64, |m, c| m.max(c.abs()));
        if scale < EPS {
            return vec![]; // zero polynomial: treat as root-free (caller decides)
        }
        // allocation-free fast paths for the degrees the solver produces
        match self.coeffs.as_slice() {
            [_] => return vec![],
            [a, b] if b.abs() >= EPS * scale => {
                let r = -a / b;
                return if in_closed(r, lo, hi) { vec![r] } else { vec![] };
            }
            [a, b, c] if c.abs() >= EPS * scale => {
                return quadratic_roots(*a, *b, *c)
                    .into_iter()
                    .filter(|r| in_closed(*r, lo, hi))
                    .collect();
            }
            _ => {}
        }
        let mut coeffs = self.coeffs.clone();
        while coeffs.len() > 1 && coeffs.last().unwrap().abs() < EPS * scale {
            coeffs.pop();
        }
        match coeffs.len() {
            1 => vec![],
            2 => {
                let r = -coeffs[0] / coeffs[1];
                if in_closed(r, lo, hi) {
                    vec![r]
                } else {
                    vec![]
                }
            }
            3 => quadratic_roots(coeffs[0], coeffs[1], coeffs[2])
                .into_iter()
                .filter(|r| in_closed(*r, lo, hi))
                .collect(),
            _ => {
                // Bracket on monotone segments delimited by derivative roots.
                let p = Poly::new(coeffs);
                let dp = p.derivative();
                let mut cuts = vec![lo];
                for r in dp.roots_in(lo, hi) {
                    if r > lo + EPS && r < hi - EPS {
                        cuts.push(r);
                    }
                }
                cuts.push(hi);
                let mut roots = vec![];
                for w in cuts.windows(2) {
                    if let Some(r) = bisect_root(&p, w[0], w[1]) {
                        roots.push(r);
                    }
                }
                roots
            }
        }
    }

    /// The first root strictly greater than `after` within `(after, hi]`,
    /// if any.
    pub fn first_root_after(&self, after: f64, hi: f64) -> Option<f64> {
        self.roots_in(after, hi)
            .into_iter()
            .find(|r| *r > after + EPS * (1.0 + after.abs()))
    }
}

fn in_closed(x: f64, lo: f64, hi: f64) -> bool {
    let tol = EPS * (1.0 + lo.abs().max(hi.abs()));
    x >= lo - tol && x <= hi + tol
}

/// Real roots of `a + b x + c x^2` (numerically-stable quadratic formula).
pub fn quadratic_roots(a: f64, b: f64, c: f64) -> Vec<f64> {
    if c.abs() < EPS * (1.0 + a.abs() + b.abs()) {
        if b.abs() < EPS {
            return vec![];
        }
        return vec![-a / b];
    }
    let disc = b * b - 4.0 * c * a;
    if disc < 0.0 {
        return vec![];
    }
    let sq = disc.sqrt();
    // Citardauq-style to avoid cancellation.
    let q = -0.5 * (b + b.signum() * sq);
    let mut roots = vec![];
    if q.abs() > 0.0 {
        roots.push(q / c);
        if sq > 0.0 || roots.is_empty() {
            roots.push(a / q);
        }
    } else {
        // b == 0 and disc == 0 => double root at 0
        roots.push(0.0);
    }
    roots.sort_by(|x, y| x.partial_cmp(y).unwrap());
    roots.dedup_by(|x, y| (*x - *y).abs() < EPS);
    roots
}

/// Bisection on a monotone bracket `[lo, hi]`; returns the root if the sign
/// changes (or an endpoint is a root).
fn bisect_root(p: &Poly, lo: f64, hi: f64) -> Option<f64> {
    let flo = p.eval(lo);
    let fhi = p.eval(hi);
    let tol = EPS * (1.0 + lo.abs().max(hi.abs()));
    let ftol = EPS * p.coeffs.iter().fold(1.0f64, |m, c| m.max(c.abs()));
    if flo.abs() < ftol {
        return Some(lo);
    }
    if fhi.abs() < ftol {
        return Some(hi);
    }
    if flo.signum() == fhi.signum() {
        return None;
    }
    let (mut a, mut b) = (lo, hi);
    let (mut fa, _) = (flo, fhi);
    for _ in 0..200 {
        let m = 0.5 * (a + b);
        let fm = p.eval(m);
        if fm.abs() < ftol || (b - a) < tol {
            return Some(m);
        }
        if fa.signum() == fm.signum() {
            a = m;
            fa = fm;
        } else {
            b = m;
        }
    }
    Some(0.5 * (a + b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_horner() {
        let p = Poly::new(vec![1.0, 2.0, 3.0]); // 1 + 2x + 3x^2
        assert_eq!(p.eval(0.0), 1.0);
        assert_eq!(p.eval(1.0), 6.0);
        assert_eq!(p.eval(2.0), 17.0);
    }

    #[test]
    fn trims_trailing_zeros() {
        let p = Poly::new(vec![1.0, 2.0, 0.0, 0.0]);
        assert_eq!(p.degree(), 1);
        let z = Poly::new(vec![]);
        assert!(z.is_zero());
    }

    #[test]
    fn derivative_antiderivative_roundtrip() {
        let p = Poly::new(vec![4.0, -3.0, 2.0, 1.0]);
        let q = p.derivative().antiderivative(p.coeffs[0]);
        for (a, b) in p.coeffs.iter().zip(q.coeffs.iter()) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn arithmetic() {
        let a = Poly::new(vec![1.0, 1.0]); // 1 + x
        let b = Poly::new(vec![-1.0, 1.0]); // -1 + x
        let prod = a.mul(&b); // x^2 - 1
        assert_eq!(prod.coeffs, vec![-1.0, 0.0, 1.0]);
        assert_eq!(a.add(&b).coeffs, vec![0.0, 2.0]);
        assert_eq!(a.sub(&b).coeffs, vec![2.0]);
    }

    #[test]
    fn in_place_ops_match_pure() {
        let a = Poly::new(vec![1.5, -2.0, 3.25]);
        let b = Poly::new(vec![0.5, 4.0]);
        let mut c = a.clone();
        c.add_assign(&b);
        assert_eq!(c, a.add(&b));
        // growth path: other has the larger degree
        let mut d = b.clone();
        d.add_assign(&a);
        assert_eq!(d, b.add(&a));
        // cancellation re-trims the degree
        let mut e = Poly::new(vec![1.0, 0.0, 2.0]);
        e.add_assign(&Poly::new(vec![0.0, 0.0, -2.0]));
        assert_eq!(e.degree(), 0);
        assert_eq!(e, Poly::new(vec![1.0, 0.0, 2.0]).add(&Poly::new(vec![0.0, 0.0, -2.0])));
        // scale, including the degree-collapsing k = 0 case
        let mut f = a.clone();
        f.scale_in_place(-0.5);
        assert_eq!(f, a.scale(-0.5));
        let mut g = a.clone();
        g.scale_in_place(0.0);
        assert_eq!(g, a.scale(0.0));
        assert_eq!(g.degree(), 0);
    }

    #[test]
    fn compose_shift() {
        let p = Poly::new(vec![0.0, 0.0, 1.0]); // x^2
        let q = p.shift(1.0); // (x+1)^2 = 1 + 2x + x^2
        assert_eq!(q.coeffs, vec![1.0, 2.0, 1.0]);
        let r = p.compose(&Poly::linear(0.0, 2.0)); // (2x)^2
        assert_eq!(r.coeffs, vec![0.0, 0.0, 4.0]);
    }

    #[test]
    fn linear_roots() {
        let p = Poly::linear(-2.0, 1.0); // x - 2
        assert_eq!(p.roots_in(0.0, 5.0), vec![2.0]);
        assert!(p.roots_in(3.0, 5.0).is_empty());
    }

    #[test]
    fn quadratic_roots_both() {
        let p = Poly::new(vec![2.0, -3.0, 1.0]); // (x-1)(x-2)
        let r = p.roots_in(0.0, 5.0);
        assert_eq!(r.len(), 2);
        assert!((r[0] - 1.0).abs() < 1e-9 && (r[1] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn quadratic_no_real_roots() {
        let p = Poly::new(vec![1.0, 0.0, 1.0]); // x^2 + 1
        assert!(p.roots_in(-10.0, 10.0).is_empty());
    }

    #[test]
    fn cubic_roots_bracketed() {
        // (x-1)(x-2)(x-3) = x^3 - 6x^2 + 11x - 6
        let p = Poly::new(vec![-6.0, 11.0, -6.0, 1.0]);
        let r = p.roots_in(0.0, 4.0);
        assert_eq!(r.len(), 3);
        for (got, want) in r.iter().zip([1.0, 2.0, 3.0]) {
            assert!((got - want).abs() < 1e-7, "{got} vs {want}");
        }
    }

    #[test]
    fn quartic_double_root() {
        // (x-1)^2 (x+2)^2
        let a = Poly::new(vec![-1.0, 1.0]);
        let b = Poly::new(vec![2.0, 1.0]);
        let p = a.mul(&a).mul(&b).mul(&b);
        let r = p.roots_in(-5.0, 5.0);
        assert_eq!(r.len(), 2, "{r:?}");
        assert!((r[0] + 2.0).abs() < 1e-6 && (r[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn first_root_after_works() {
        let p = Poly::new(vec![2.0, -3.0, 1.0]); // roots 1, 2
        assert!((p.first_root_after(1.5, 10.0).unwrap() - 2.0).abs() < 1e-9);
        assert!((p.first_root_after(0.0, 10.0).unwrap() - 1.0).abs() < 1e-9);
        assert!(p.first_root_after(2.5, 10.0).is_none());
    }
}
