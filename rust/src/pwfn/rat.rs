//! Exact rational arithmetic over `i128`.
//!
//! The paper notes (§4) that with piecewise-*linear* functions, intersection
//! and root finding need only rational numbers and can therefore be done
//! without precision loss. [`Rat`] backs the exact PL fast path in
//! [`super::linear`]. Operations panic-free: overflow is reported as an
//! error so callers can fall back to the f64 [`super::piecewise`] engine.

use std::cmp::Ordering;
use std::fmt;

/// Error raised when an exact operation would overflow `i128`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Overflow;

impl fmt::Display for Overflow {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("rational arithmetic overflow")
    }
}

impl std::error::Error for Overflow {}

/// A normalized rational number `num/den`, `den > 0`, `gcd(num, den) = 1`.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct Rat {
    num: i128,
    den: i128,
}

fn gcd(mut a: i128, mut b: i128) -> i128 {
    a = a.abs();
    b = b.abs();
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a.max(1)
}

impl Rat {
    pub const ZERO: Rat = Rat { num: 0, den: 1 };
    pub const ONE: Rat = Rat { num: 1, den: 1 };

    pub fn new(num: i128, den: i128) -> Result<Rat, Overflow> {
        if den == 0 {
            return Err(Overflow);
        }
        let g = gcd(num, den);
        let sign = if den < 0 { -1 } else { 1 };
        Ok(Rat {
            num: sign * (num / g),
            den: (den / g).abs(),
        })
    }

    pub fn int(n: i64) -> Rat {
        Rat {
            num: n as i128,
            den: 1,
        }
    }

    /// Exact conversion from an f64 that is a dyadic rational of reasonable
    /// size (which all user-facing model constants are after parsing).
    pub fn from_f64(x: f64) -> Result<Rat, Overflow> {
        if !x.is_finite() {
            return Err(Overflow);
        }
        // scale by powers of two until integral (f64 mantissa is finite)
        let mut num = x;
        let mut den: i128 = 1;
        let mut iter = 0;
        while num.fract() != 0.0 {
            num *= 2.0;
            den = den.checked_mul(2).ok_or(Overflow)?;
            iter += 1;
            if iter > 80 || num.abs() > 1e30 {
                return Err(Overflow);
            }
        }
        if num.abs() >= i128::MAX as f64 {
            return Err(Overflow);
        }
        Rat::new(num as i128, den)
    }

    pub fn to_f64(self) -> f64 {
        self.num as f64 / self.den as f64
    }

    pub fn num(self) -> i128 {
        self.num
    }

    pub fn den(self) -> i128 {
        self.den
    }

    pub fn is_zero(self) -> bool {
        self.num == 0
    }

    pub fn is_negative(self) -> bool {
        self.num < 0
    }

    pub fn checked_add(self, o: Rat) -> Result<Rat, Overflow> {
        let g = gcd(self.den, o.den);
        let l = self.den / g;
        let r = o.den / g;
        let num = self
            .num
            .checked_mul(r)
            .and_then(|a| o.num.checked_mul(l).and_then(|b| a.checked_add(b)))
            .ok_or(Overflow)?;
        let den = self.den.checked_mul(r).ok_or(Overflow)?;
        Rat::new(num, den)
    }

    pub fn checked_sub(self, o: Rat) -> Result<Rat, Overflow> {
        self.checked_add(Rat {
            num: -o.num,
            den: o.den,
        })
    }

    pub fn checked_mul(self, o: Rat) -> Result<Rat, Overflow> {
        // cross-reduce first to keep magnitudes small
        let g1 = gcd(self.num, o.den);
        let g2 = gcd(o.num, self.den);
        let num = (self.num / g1).checked_mul(o.num / g2).ok_or(Overflow)?;
        let den = (self.den / g2).checked_mul(o.den / g1).ok_or(Overflow)?;
        Rat::new(num, den)
    }

    pub fn checked_div(self, o: Rat) -> Result<Rat, Overflow> {
        if o.num == 0 {
            return Err(Overflow);
        }
        self.checked_mul(Rat {
            num: o.den,
            den: o.num,
        })
    }

    pub fn min(self, o: Rat) -> Rat {
        if self <= o {
            self
        } else {
            o
        }
    }

    pub fn max(self, o: Rat) -> Rat {
        if self >= o {
            self
        } else {
            o
        }
    }
}

impl PartialOrd for Rat {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Rat {
    fn cmp(&self, other: &Self) -> Ordering {
        // compare a/b vs c/d via a*d vs c*b; fall back to f64 on overflow
        match (
            self.num.checked_mul(other.den),
            other.num.checked_mul(self.den),
        ) {
            (Some(l), Some(r)) => l.cmp(&r),
            _ => self
                .to_f64()
                .partial_cmp(&other.to_f64())
                .unwrap_or(Ordering::Equal),
        }
    }
}

impl fmt::Debug for Rat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(self, f)
    }
}

impl fmt::Display for Rat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.den == 1 {
            write!(f, "{}", self.num)
        } else {
            write!(f, "{}/{}", self.num, self.den)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalization() {
        let r = Rat::new(6, -4).unwrap();
        assert_eq!((r.num(), r.den()), (-3, 2));
        assert_eq!(Rat::new(0, 5).unwrap(), Rat::ZERO);
    }

    #[test]
    fn arithmetic_exact() {
        let a = Rat::new(1, 3).unwrap();
        let b = Rat::new(1, 6).unwrap();
        assert_eq!(a.checked_add(b).unwrap(), Rat::new(1, 2).unwrap());
        assert_eq!(a.checked_sub(b).unwrap(), b);
        assert_eq!(a.checked_mul(b).unwrap(), Rat::new(1, 18).unwrap());
        assert_eq!(a.checked_div(b).unwrap(), Rat::int(2));
    }

    #[test]
    fn division_by_zero_is_error() {
        assert!(Rat::int(1).checked_div(Rat::ZERO).is_err());
        assert!(Rat::new(1, 0).is_err());
    }

    #[test]
    fn ordering() {
        let a = Rat::new(1, 3).unwrap();
        let b = Rat::new(2, 5).unwrap();
        assert!(a < b);
        assert_eq!(a.min(b), a);
        assert_eq!(a.max(b), b);
    }

    #[test]
    fn from_f64_dyadic() {
        assert_eq!(Rat::from_f64(0.5).unwrap(), Rat::new(1, 2).unwrap());
        assert_eq!(Rat::from_f64(-3.25).unwrap(), Rat::new(-13, 4).unwrap());
        assert_eq!(Rat::from_f64(1e6).unwrap(), Rat::int(1_000_000));
        assert!(Rat::from_f64(f64::NAN).is_err());
    }

    #[test]
    fn roundtrip_f64() {
        for x in [0.0, 1.5, -2.75, 1024.0, 1.0 / 1024.0] {
            assert_eq!(Rat::from_f64(x).unwrap().to_f64(), x);
        }
    }

    #[test]
    fn overflow_reported() {
        let big = Rat::int(i64::MAX);
        let r = (0..4).try_fold(big, |acc, _| acc.checked_mul(big));
        assert!(r.is_err());
    }
}
