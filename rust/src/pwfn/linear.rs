//! Exact rational piecewise-*linear* functions.
//!
//! The paper's §4 observes that restricting to piecewise-linear functions
//! lets every operation the solver needs (min via intersections, compose,
//! inverse, integration of piecewise-constant rates) be carried out on
//! rational numbers without any precision loss. This module is that exact
//! fast path. It mirrors a subset of [`super::piecewise::PwPoly`]'s API;
//! [`PwLinear::to_pwpoly`] bridges into the general engine.
//!
//! Representation: piece `i` starts at `starts[i]` with value `vals[i]` and
//! slope `slopes[i]`, covering `[starts[i], starts[i+1])`; the last piece
//! extends to `+inf`. Right-continuous: a jump is `vals[i]` differing from
//! the left limit of piece `i-1` at `starts[i]`.

use super::piecewise::PwPoly;
use super::poly::Poly;
use super::rat::{Overflow, Rat};

/// An exact rational piecewise-linear function.
#[derive(Clone, Debug, PartialEq)]
pub struct PwLinear {
    pub starts: Vec<Rat>,
    pub vals: Vec<Rat>,
    pub slopes: Vec<Rat>,
}

/// Exact lower envelope with per-piece winners (cf. `piecewise::Envelope`).
#[derive(Clone, Debug)]
pub struct ExactEnvelope {
    pub func: PwLinear,
    pub winners: Vec<usize>,
}

impl PwLinear {
    pub fn new(starts: Vec<Rat>, vals: Vec<Rat>, slopes: Vec<Rat>) -> Self {
        assert!(!starts.is_empty());
        assert_eq!(starts.len(), vals.len());
        assert_eq!(starts.len(), slopes.len());
        for w in starts.windows(2) {
            assert!(w[0] < w[1], "starts must be strictly increasing");
        }
        PwLinear {
            starts,
            vals,
            slopes,
        }
    }

    pub fn constant(x0: Rat, c: Rat) -> Self {
        PwLinear::new(vec![x0], vec![c], vec![Rat::ZERO])
    }

    pub fn linear(x0: Rat, y0: Rat, slope: Rat) -> Self {
        PwLinear::new(vec![x0], vec![y0], vec![slope])
    }

    /// Exact PL interpolation through points, constant after the last.
    pub fn from_points(points: &[(Rat, Rat)]) -> Result<Self, Overflow> {
        assert!(points.len() >= 2);
        let mut starts = vec![];
        let mut vals = vec![];
        let mut slopes = vec![];
        for w in points.windows(2) {
            let ((x0, y0), (x1, y1)) = (w[0], w[1]);
            starts.push(x0);
            vals.push(y0);
            slopes.push(y1.checked_sub(y0)?.checked_div(x1.checked_sub(x0)?)?);
        }
        let last = points[points.len() - 1];
        starts.push(last.0);
        vals.push(last.1);
        slopes.push(Rat::ZERO);
        Ok(PwLinear::new(starts, vals, slopes))
    }

    pub fn n_pieces(&self) -> usize {
        self.starts.len()
    }

    fn piece_index(&self, x: Rat) -> usize {
        match self.starts.binary_search(&x) {
            Ok(i) => i,
            Err(0) => 0,
            Err(i) => i - 1,
        }
    }

    /// Exact evaluation (right-continuous, clamped left of the domain).
    pub fn eval(&self, x: Rat) -> Result<Rat, Overflow> {
        let x = x.max(self.starts[0]);
        let i = self.piece_index(x);
        self.vals[i].checked_add(self.slopes[i].checked_mul(x.checked_sub(self.starts[i])?)?)
    }

    /// Left limit.
    pub fn eval_left(&self, x: Rat) -> Result<Rat, Overflow> {
        if x <= self.starts[0] {
            return self.eval(x);
        }
        let i = self.piece_index(x);
        if i > 0 && x == self.starts[i] {
            let j = i - 1;
            self.vals[j].checked_add(self.slopes[j].checked_mul(x.checked_sub(self.starts[j])?)?)
        } else {
            self.eval(x)
        }
    }

    /// End of piece `i` (`None` for the last, infinite piece).
    fn piece_end(&self, i: usize) -> Option<Rat> {
        self.starts.get(i + 1).copied()
    }

    pub fn scale(&self, k: Rat) -> Result<Self, Overflow> {
        Ok(PwLinear {
            starts: self.starts.clone(),
            vals: self
                .vals
                .iter()
                .map(|v| v.checked_mul(k))
                .collect::<Result<_, _>>()?,
            slopes: self
                .slopes
                .iter()
                .map(|s| s.checked_mul(k))
                .collect::<Result<_, _>>()?,
        })
    }

    /// Exact lower envelope of several PL functions with winner attribution.
    pub fn min_envelope(fns: &[&PwLinear]) -> Result<ExactEnvelope, Overflow> {
        assert!(!fns.is_empty());
        let mut env = ExactEnvelope {
            func: fns[0].clone(),
            winners: vec![0; fns[0].n_pieces()],
        };
        for (idx, f) in fns.iter().enumerate().skip(1) {
            env = env.min_with(f, idx)?;
        }
        Ok(env)
    }

    /// First `x >= from` with `f(x) >= y`, exact (monotone functions).
    pub fn first_reach(&self, y: Rat, from: Rat) -> Result<Option<Rat>, Overflow> {
        let from = from.max(self.starts[0]);
        if self.eval(from)? >= y {
            return Ok(Some(from));
        }
        let start = self.piece_index(from);
        for i in start..self.n_pieces() {
            let s = self.starts[i].max(from);
            let v = self.eval(s)?;
            if v >= y {
                return Ok(Some(s));
            }
            if self.slopes[i].is_zero() || self.slopes[i].is_negative() {
                continue;
            }
            // x = s + (y - v)/slope
            let x = s.checked_add(y.checked_sub(v)?.checked_div(self.slopes[i])?)?;
            match self.piece_end(i) {
                Some(e) if x >= e => continue,
                _ => return Ok(Some(x)),
            }
        }
        Ok(None)
    }

    /// Exact compose `self(inner(x))` for nondecreasing `inner`.
    pub fn compose(&self, inner: &PwLinear) -> Result<PwLinear, Overflow> {
        // cut points: inner breaks + preimages of self breaks
        let mut cuts: Vec<Rat> = Vec::with_capacity(inner.starts.len() + self.starts.len());
        cuts.extend_from_slice(&inner.starts);
        for &b in &self.starts {
            if let Some(x) = inner.first_reach(b, inner.starts[0])? {
                cuts.push(x);
            }
        }
        cuts.sort();
        cuts.dedup();
        let mut starts = vec![];
        let mut vals: Vec<Rat> = vec![];
        let mut slopes: Vec<Rat> = vec![];
        for &s in &cuts {
            let y0 = inner.eval(s)?;
            let oi = self.piece_index(y0.max(self.starts[0]));
            let ii = inner.piece_index(s.max(inner.starts[0]));
            let v = self.eval(y0)?;
            let sl = self.slopes[oi].checked_mul(inner.slopes[ii])?;
            // merge with previous piece if it extrapolates to the same line
            if let (Some(&ps), Some(&pv), Some(&psl)) =
                (starts.last(), vals.last(), slopes.last())
            {
                let extrap = pv.checked_add(psl.checked_mul(s.checked_sub(ps)?)?)?;
                if psl == sl && extrap == v {
                    continue;
                }
            }
            starts.push(s);
            vals.push(v);
            slopes.push(sl);
        }
        Ok(PwLinear::new(starts, vals, slopes))
    }

    /// Exact inverse for nondecreasing functions, "smallest x with
    /// f(x) >= y" convention (plateaus skipped, jumps become constants).
    pub fn inverse(&self) -> Result<PwLinear, Overflow> {
        let mut starts = vec![];
        let mut vals = vec![];
        let mut slopes = vec![];
        let mut last_y: Option<Rat> = None;
        for i in 0..self.n_pieces() {
            let s = self.starts[i];
            let y0 = self.vals[i];
            if let Some(ly) = last_y {
                if y0 > ly {
                    // jump: inverse is constant s on [ly, y0)
                    starts.push(ly);
                    vals.push(s);
                    slopes.push(Rat::ZERO);
                }
            }
            let slope = self.slopes[i];
            if slope.is_negative() {
                return Err(Overflow);
            }
            if slope.is_zero() {
                last_y = Some(match last_y {
                    Some(ly) => ly.max(y0),
                    None => y0,
                });
                continue;
            }
            starts.push(y0);
            vals.push(s);
            slopes.push(Rat::ONE.checked_div(slope)?);
            last_y = Some(match self.piece_end(i) {
                Some(e) => self
                    .vals[i].checked_add(slope.checked_mul(e.checked_sub(s)?)?)?,
                None => return Ok(PwLinear::new(starts, vals, slopes)),
            });
        }
        if starts.is_empty() {
            return Err(Overflow);
        }
        Ok(PwLinear::new(starts, vals, slopes))
    }

    /// Bridge into the general f64 engine.
    pub fn to_pwpoly(&self) -> PwPoly {
        let mut breaks: Vec<f64> = Vec::with_capacity(self.starts.len() + 1);
        breaks.extend(self.starts.iter().map(|r| r.to_f64()));
        breaks.push(f64::INFINITY);
        let polys = self
            .vals
            .iter()
            .zip(self.slopes.iter())
            .map(|(v, s)| Poly::linear(v.to_f64(), s.to_f64()))
            .collect();
        PwPoly::new(breaks, polys)
    }
}

impl ExactEnvelope {
    fn min_with(&self, g: &PwLinear, g_idx: usize) -> Result<ExactEnvelope, Overflow> {
        let f = &self.func;
        // candidate cut points: both functions' starts + pairwise
        // intersections inside shared pieces
        let mut cuts: Vec<Rat> = Vec::with_capacity(f.starts.len() + g.starts.len());
        cuts.extend_from_slice(&f.starts);
        cuts.extend_from_slice(&g.starts);
        cuts.sort();
        cuts.dedup();
        let mut xs: Vec<Rat> = Vec::with_capacity(cuts.len());
        for (i, &s) in cuts.iter().enumerate() {
            let e = cuts.get(i + 1).copied();
            // lines at s
            let (fv, fs) = (f.eval(s)?, f.slopes[f.piece_index(s.max(f.starts[0]))]);
            let (gv, gs) = (g.eval(s)?, g.slopes[g.piece_index(s.max(g.starts[0]))]);
            let ds = fs.checked_sub(gs)?;
            if !ds.is_zero() {
                // f(s)+fs*(x-s) = g(s)+gs*(x-s)  =>  x = s + (gv-fv)/ds
                let x = s.checked_add(gv.checked_sub(fv)?.checked_div(ds)?)?;
                let inside = x > s && e.map_or(true, |e| x < e);
                if inside {
                    xs.push(x);
                }
            }
        }
        cuts.extend(xs);
        cuts.sort();
        cuts.dedup();

        let mut starts = Vec::with_capacity(cuts.len());
        let mut vals: Vec<Rat> = Vec::with_capacity(cuts.len());
        let mut slopes: Vec<Rat> = Vec::with_capacity(cuts.len());
        let mut winners = Vec::with_capacity(cuts.len());
        for &s in &cuts {
            let (fv, fs) = (f.eval(s)?, f.slopes[f.piece_index(s.max(f.starts[0]))]);
            let (gv, gs) = (g.eval(s)?, g.slopes[g.piece_index(s.max(g.starts[0]))]);
            // decide winner on this interval: compare at s, tie-break by slope
            let g_wins = gv < fv || (gv == fv && gs < fs);
            let (v, sl, w) = if g_wins {
                (gv, gs, g_idx)
            } else {
                (fv, fs, self.winners[f.piece_index(s.max(f.starts[0]))])
            };
            // merge continuation pieces
            if let (Some(&ps), Some(&pv), Some(&psl), Some(&pw)) =
                (starts.last(), vals.last(), slopes.last(), winners.last())
            {
                let extrap = pv.checked_add(psl.checked_mul(s.checked_sub(ps)?)?)?;
                if psl == sl && extrap == v && pw == w {
                    continue;
                }
            }
            starts.push(s);
            vals.push(v);
            slopes.push(sl);
            winners.push(w);
        }
        Ok(ExactEnvelope {
            func: PwLinear::new(starts, vals, slopes),
            winners,
        })
    }

    /// Contiguous segments `(start, end=None for inf, winner)`.
    pub fn segments(&self) -> Vec<(Rat, Option<Rat>, usize)> {
        let mut out: Vec<(Rat, Option<Rat>, usize)> = vec![];
        for i in 0..self.func.n_pieces() {
            let s = self.func.starts[i];
            let e = self.func.piece_end(i);
            let w = self.winners[i];
            if let Some(last) = out.last_mut() {
                if last.2 == w {
                    last.1 = e;
                    continue;
                }
            }
            out.push((s, e, w));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn r(n: i64, d: i64) -> Rat {
        Rat::new(n as i128, d as i128).unwrap()
    }

    #[test]
    fn eval_exact() {
        let f = PwLinear::linear(Rat::ZERO, Rat::ZERO, r(1, 3));
        assert_eq!(f.eval(Rat::int(9)).unwrap(), Rat::int(3));
        assert_eq!(f.eval(Rat::int(1)).unwrap(), r(1, 3));
    }

    #[test]
    fn from_points_and_left_limit() {
        let f = PwLinear::from_points(&[
            (Rat::int(0), Rat::int(0)),
            (Rat::int(2), Rat::int(4)),
            (Rat::int(4), Rat::int(4)),
        ])
        .unwrap();
        assert_eq!(f.eval(Rat::int(1)).unwrap(), Rat::int(2));
        assert_eq!(f.eval(Rat::int(3)).unwrap(), Rat::int(4));
        assert_eq!(f.eval_left(Rat::int(2)).unwrap(), Rat::int(4));
    }

    #[test]
    fn exact_min_envelope() {
        // f = x, g = 2 + x/2 -> cross exactly at x = 4
        let f = PwLinear::linear(Rat::ZERO, Rat::ZERO, Rat::ONE);
        let g = PwLinear::linear(Rat::ZERO, Rat::int(2), r(1, 2));
        let env = PwLinear::min_envelope(&[&f, &g]).unwrap();
        let segs = env.segments();
        assert_eq!(segs.len(), 2);
        assert_eq!(segs[0].1.unwrap(), Rat::int(4)); // exact crossing
        assert_eq!(segs[0].2, 0);
        assert_eq!(segs[1].2, 1);
        assert_eq!(env.func.eval(Rat::int(6)).unwrap(), Rat::int(5));
    }

    #[test]
    fn exact_min_envelope_non_dyadic_crossing() {
        // f = x/3, g = 1 + x/7 -> cross at x = 21/4 (non-dyadic!)
        let f = PwLinear::linear(Rat::ZERO, Rat::ZERO, r(1, 3));
        let g = PwLinear::linear(Rat::ZERO, Rat::int(1), r(1, 7));
        let env = PwLinear::min_envelope(&[&f, &g]).unwrap();
        assert_eq!(env.segments()[0].1.unwrap(), r(21, 4));
    }

    #[test]
    fn compose_exact() {
        // outer burst at 10 (0 -> 7), inner rate 1/3 => result jumps at x=30
        let outer = PwLinear::new(
            vec![Rat::ZERO, Rat::int(10)],
            vec![Rat::ZERO, Rat::int(7)],
            vec![Rat::ZERO, Rat::ZERO],
        );
        let inner = PwLinear::linear(Rat::ZERO, Rat::ZERO, r(1, 3));
        let c = outer.compose(&inner).unwrap();
        assert_eq!(c.eval(Rat::int(29)).unwrap(), Rat::ZERO);
        assert_eq!(c.eval(Rat::int(30)).unwrap(), Rat::int(7));
    }

    #[test]
    fn inverse_roundtrip_exact() {
        let f = PwLinear::from_points(&[
            (Rat::int(0), Rat::int(0)),
            (Rat::int(3), Rat::int(1)),
            (Rat::int(4), Rat::int(5)),
        ])
        .unwrap();
        let inv = f.inverse().unwrap();
        for y in [Rat::ZERO, r(1, 2), Rat::ONE, Rat::int(3)] {
            assert_eq!(f.eval(inv.eval(y).unwrap()).unwrap(), y);
        }
    }

    #[test]
    fn inverse_jump_gap() {
        // jump from 2 to 5 at x=1
        let f = PwLinear::new(
            vec![Rat::ZERO, Rat::int(1)],
            vec![Rat::ZERO, Rat::int(5)],
            vec![Rat::int(2), Rat::int(1)],
        );
        let inv = f.inverse().unwrap();
        assert_eq!(inv.eval(Rat::int(3)).unwrap(), Rat::int(1)); // inside gap
        assert_eq!(inv.eval(Rat::int(6)).unwrap(), Rat::int(2));
    }

    #[test]
    fn first_reach_exact() {
        let f = PwLinear::linear(Rat::ZERO, Rat::ZERO, r(97, 13));
        let y = Rat::int(1000);
        let x = f.first_reach(y, Rat::ZERO).unwrap().unwrap();
        assert_eq!(f.eval(x).unwrap(), y);
        assert_eq!(x, r(13000, 97));
    }

    #[test]
    fn to_pwpoly_matches() {
        let f = PwLinear::from_points(&[
            (Rat::int(0), Rat::int(0)),
            (Rat::int(2), Rat::int(4)),
            (Rat::int(5), Rat::int(6)),
        ])
        .unwrap();
        let g = f.to_pwpoly();
        for x in [0.0, 0.7, 2.0, 3.3, 5.0, 9.0] {
            let exact = f.eval(Rat::from_f64(x).unwrap()).unwrap().to_f64();
            assert!((g.eval(x) - exact).abs() < 1e-12);
        }
    }
}
