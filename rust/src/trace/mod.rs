//! Trace ingestion & calibration: from raw workflow traces to solver-ready
//! models.
//!
//! The paper's evaluation hand-builds its models and defers acquisition to
//! future work ("executions of such tasks can be logged and the requirement
//! functions can be derived from such logs", §5.2/§8). This subsystem is
//! that path, end to end:
//!
//! ```text
//!  trace.tsv ──parse──┐
//!                     ├─ calibrate ─ assemble ─ replay ─ error report
//!  series.log ─parse──┘      │           │         │
//!   (optional)          Process per   Workflow   solver re-run vs
//!                         task        (DAG +     observed completions
//!                                     wiring)
//! ```
//!
//! * [`mod@format`] — strict parsers/writers for a Nextflow-style per-task
//!   TSV trace and a BPF-style cumulative I/O series log;
//! * [`mod@segment`] — the reusable greedy piecewise-linear compactor
//!   behind every fitted curve (also used by [`crate::model::fit`]);
//! * [`mod@calibrate`] — per-task fitting of `R_D`, `R_R` and output
//!   functions, with a summary-statistics fallback when only TSV rows
//!   exist;
//! * [`mod@assemble`] — DAG assembly ([`crate::workflow::graph::Workflow`])
//!   plus the replay validator reporting per-task predicted-vs-observed
//!   completion error.
//!
//! Surfaces: `bottlemod calibrate <trace.tsv> [--io <series.log>]`, the
//! JSON-lines service's `calibrate` op (`docs/SERVICE.md`), example
//! fixtures under `rust/examples/traces/`, and the
//! `examples/trace_fitting.rs` walkthrough. Formats, heuristics and error
//! semantics are documented in `docs/TRACES.md`.

pub mod assemble;
pub mod calibrate;
pub mod format;
pub mod segment;

pub use assemble::{
    assemble, calibrate_trace, replay, CalibratedWorkflow, ReplayReport, TaskReplay,
    TaskSummary,
};
pub use calibrate::{calibrate, fit_series, CalibrateOpts, CalibratedTask, ModelSource};
pub use format::{
    parse_io_log, parse_tsv, parse_tsv_structural, write_io_log, write_tsv, IoSeries, TsvTask,
    TsvTrace,
};
