//! Greedy piecewise-linear segmentation of monotone sample series.
//!
//! This is the single compaction engine behind every trace-derived curve in
//! the repo: [`crate::model::fit`] (isolated-execution fitting) and
//! [`mod@crate::trace::calibrate`] (workflow-trace calibration) both
//! delegate here. Given a cloud of `(x, y)` samples sorted by `x`, [`compact`]
//! returns the few breakpoints whose linear interpolation stays within a
//! relative tolerance of every sample, and [`to_pwpoly`] /
//! [`to_pwpoly_dir`] turn breakpoints into a solver-ready [`PwPoly`],
//! widening near-vertical steps into steep PL ramps so the §4 restriction
//! (piecewise-linear resource requirements) holds and jumps at the domain
//! edge stay visible.
//!
//! Keeping fitted models small matters twice: the solver's cost is
//! proportional to piece count (paper §6), and the sweep engine's cache
//! keys hash every coefficient.

use crate::pwfn::{break_tol, poly::Poly, PwPoly};

/// Greedy PL segmentation of a monotone curve: returns breakpoints
/// `(x, y)` such that linear interpolation stays within `tol * y_span` of
/// every sample. Input must be sorted by x (ties allowed, last wins).
pub fn compact(points: &[(f64, f64)], tol: f64) -> Vec<(f64, f64)> {
    assert!(points.len() >= 2, "need at least two samples");
    let y_span = points
        .iter()
        .map(|p| p.1)
        .fold(f64::NEG_INFINITY, f64::max)
        - points.iter().map(|p| p.1).fold(f64::INFINITY, f64::min);
    let eps = tol * y_span.max(1e-300);

    let mut out = vec![points[0]];
    let mut seg_start = 0usize;
    let mut i = 1;
    while i < points.len() {
        // try extending the current segment to point i+1; check deviation
        let cand_end = (i + 1).min(points.len() - 1);
        let (x0, y0) = points[seg_start];
        let (x1, y1) = points[cand_end];
        let dx = x1 - x0;
        let ok = if dx.abs() < 1e-300 {
            true
        } else {
            let slope = (y1 - y0) / dx;
            points[seg_start..=cand_end].iter().all(|&(x, y)| {
                let pred = y0 + slope * (x - x0);
                (pred - y).abs() <= eps
            })
        };
        if ok && cand_end > i {
            i = cand_end;
            continue;
        }
        if ok && cand_end == i {
            // reached the end
            break;
        }
        // cut the segment at i
        out.push(points[i]);
        seg_start = i;
        i += 1;
    }
    let last = *points.last().unwrap();
    if out.last() != Some(&last) {
        out.push(last);
    }
    out
}

/// Build a monotone PwPoly from fitted breakpoints. Near-vertical steps
/// (consecutive points closer in x than `jump_eps_abs`) are widened into
/// steep piecewise-linear ramps of width `jump_eps_abs` — exactly
/// equivalent for the solver (the cumulative amount is preserved, and the
/// function stays PL so Algorithm 2's §4 restriction holds), and crucially
/// visible at the domain edge, where a true jump at `x = x_min` would
/// degenerate into an invisible constant offset of a derivative-based
/// model.
pub fn to_pwpoly(points: &[(f64, f64)], jump_eps_abs: f64) -> PwPoly {
    to_pwpoly_dir(points, jump_eps_abs, false)
}

/// Like [`to_pwpoly`], but widening direction is selectable: forward
/// (steps keep their left edge — right for resource requirements, whose
/// up-front cost must be payable from the start) or backward (steps keep
/// their right edge — right for data requirements, whose burst threshold
/// must not exceed the actually-available input).
pub fn to_pwpoly_dir(points: &[(f64, f64)], jump_eps_abs: f64, backward: bool) -> PwPoly {
    assert!(points.len() >= 2);
    // floor the ramp width at twice the kernel's breakpoint-coincidence
    // tolerance ([`crate::pwfn::EPS_BREAK`], relative) at this x scale:
    // any narrower and the widened step's two breaks would collapse back
    // into one deduplicated break the moment the fitted model re-enters
    // the piecewise algebra, smearing the step's slope across the merged
    // interval
    let xmag = points.iter().fold(0.0f64, |m, p| m.max(p.0.abs()));
    let eps = jump_eps_abs.max(2.0 * break_tol(xmag, xmag));
    // enforce strictly increasing x by widening steps
    let mut pts: Vec<(f64, f64)> = Vec::with_capacity(points.len());
    if backward {
        for &(x, y) in points.iter().rev() {
            let x = match pts.last() {
                Some(&(nx, ny)) => {
                    if y >= ny - 1e-300 && x >= nx - eps {
                        continue; // duplicate sample
                    }
                    x.min(nx - eps)
                }
                None => x,
            };
            pts.push((x, y));
        }
        pts.reverse();
        // backward widening may push the first x negative; clamp by
        // dropping points left of the original start
        let x0 = points[0].0;
        pts.retain(|&(x, _)| x >= x0 - 1e-300);
        if pts.first().map(|p| p.0) != Some(x0) {
            pts.insert(0, points[0]);
        }
    } else {
        for &(x, y) in points {
            let x = match pts.last() {
                Some(&(px, py)) => {
                    if y <= py + 1e-300 && x <= px + eps {
                        continue; // duplicate sample
                    }
                    x.max(px + eps)
                }
                None => x,
            };
            pts.push((x, y));
        }
    }
    if pts.len() < 2 {
        return PwPoly::constant_from(points[0].0, points.last().unwrap().1);
    }
    let mut breaks: Vec<f64> = Vec::with_capacity(pts.len() + 1);
    let mut polys: Vec<Poly> = Vec::with_capacity(pts.len());
    for w in pts.windows(2) {
        let ((x0, y0), (x1, y1)) = (w[0], w[1]);
        breaks.push(x0);
        polys.push(Poly::linear(y0, (y1 - y0) / (x1 - x0)));
    }
    breaks.push(pts[pts.len() - 1].0);
    breaks.push(f64::INFINITY);
    polys.push(Poly::constant(pts[pts.len() - 1].1));
    PwPoly::new(breaks, polys)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compact_collapses_straight_line() {
        let pts: Vec<(f64, f64)> = (0..1000).map(|i| (i as f64, 3.0 * i as f64)).collect();
        let fitted = compact(&pts, 0.01);
        assert!(fitted.len() <= 3, "{}", fitted.len());
        assert_eq!(fitted.first(), Some(&(0.0, 0.0)));
        assert_eq!(fitted.last(), Some(&(999.0, 2997.0)));
    }

    #[test]
    fn compact_respects_tolerance() {
        // noisy line: deviation within 0.5% of the span must be absorbed
        let pts: Vec<(f64, f64)> = (0..200)
            .map(|i| {
                let x = i as f64;
                (x, x + if i % 2 == 0 { 0.4 } else { -0.4 })
            })
            .collect();
        let fitted = compact(&pts, 0.01);
        assert!(fitted.len() <= 4, "{}", fitted.len());
        // interpolation stays within tol * span of every sample
        let span = 199.8;
        for &(x, y) in &pts {
            let w = fitted
                .windows(2)
                .find(|w| w[0].0 <= x && x <= w[1].0)
                .unwrap();
            let pred = w[0].1 + (w[1].1 - w[0].1) * (x - w[0].0) / (w[1].0 - w[0].0);
            assert!((pred - y).abs() <= 0.011 * span, "at {x}: {pred} vs {y}");
        }
    }

    #[test]
    fn to_pwpoly_widens_vertical_step() {
        // a burst: flat, then a vertical rise at x = 10
        let pts = vec![(0.0, 0.0), (10.0, 0.0), (10.0, 5.0), (12.0, 5.0)];
        let f = to_pwpoly_dir(&pts, 1e-3, true);
        assert!(f.is_nondecreasing());
        assert!(f.eval(9.9) < 1e-9);
        assert!((f.eval(10.0) - 5.0).abs() < 1e-9, "{}", f.eval(10.0));
        // backward widening: the threshold does not exceed x = 10
        assert!(f.eval(10.0 - 2e-3) < 5.0);
    }

    #[test]
    fn to_pwpoly_forward_keeps_left_edge() {
        // up-front cost: jump at x = 0 must be payable from the start
        let pts = vec![(0.0, 0.0), (0.0, 26.0), (80.0, 108.0)];
        let f = to_pwpoly(&pts, 1e-3);
        assert!(f.is_nondecreasing());
        assert!((f.eval(0.0) - 0.0).abs() < 1e-9);
        assert!((f.eval(1e-3) - 26.0).abs() < 1e-6, "{}", f.eval(1e-3));
        assert!((f.eval(80.0) - 108.0).abs() < 1e-6);
    }

    #[test]
    fn degenerate_all_same_x_becomes_constant_or_step() {
        let pts = vec![(5.0, 1.0), (5.0, 2.0), (5.0, 3.0)];
        let f = to_pwpoly(&pts, 1e-6);
        assert!(f.is_nondecreasing());
        assert!((f.eval(6.0) - 3.0).abs() < 1e-9);
    }
}
