//! Per-task model calibration: from trace rows/series to [`Process`]es.
//!
//! The paper defers model acquisition to future work (§5.2: requirement
//! functions "can be derived from such logs"). This module is that
//! derivation, with two fidelity tiers:
//!
//! * **Series fit** ([`fit_series`]) — when a task has a cumulative I/O
//!   series, fit `R_D(n)` from the (bytes-read → bytes-written) relation
//!   and `R_R(p)` from the (bytes-written → elapsed × allocation)
//!   relation, compacted by [`crate::trace::segment`]. This generalizes
//!   `model::fit::fit_process` (which now delegates here) from the virtual
//!   testbed's `IoTrace` to any parsed [`IoSeries`].
//! * **Summary fallback** — with only a TSV row, build a coarse model from
//!   the totals: CPU-seconds `= realtime · pcpu/100` spread over progress,
//!   and a data requirement whose shape is chosen by a memory heuristic
//!   (`peak_rss ≳ rchar/2` ⇒ the task held its whole input ⇒ burst-step;
//!   otherwise proportional streaming), following the feature taxonomy of
//!   Bader et al. 2025.
//!
//! **Fidelity caveat** (honest semantics, also in `docs/TRACES.md`): a
//! workflow trace observes each task *under its execution conditions* — a
//! task stalled on input logs wall time that the resource fit attributes
//! to resource demand. The calibrated curves therefore reproduce the
//! *observed* trajectory exactly when replayed under the same wiring
//! (which is what the replay validator measures), and are conservative
//! upper bounds elsewhere. Traces of isolated runs (full input staged,
//! fixed allocation) give execution-independent models — the
//! `model::fit` tests exercise that case.

use crate::model::builder::ProcessBuilder;
use crate::model::process::{
    DataRequirement, OutputFn, Process, ResourceRequirement,
};
use crate::pwfn::PwPoly;
use crate::util::error::Result;
use crate::{bail, ensure};

use super::format::{IoSeries, TsvTrace};
use super::segment::{compact, to_pwpoly, to_pwpoly_dir};

/// Options for trace calibration.
#[derive(Clone, Debug)]
pub struct CalibrateOpts {
    /// Relative y-tolerance for segment fitting (fraction of the y-span).
    pub tol: f64,
    /// x-gaps smaller than this fraction of the x-span become jumps.
    pub jump_eps: f64,
    /// Resource allocation assumed when the trace logs no `pcpu`
    /// (1.0 = one core / one unit of the resource).
    pub default_alloc: f64,
}

impl Default for CalibrateOpts {
    fn default() -> Self {
        CalibrateOpts {
            tol: 0.01,
            jump_eps: 1e-6,
            default_alloc: 1.0,
        }
    }
}

/// How a task's model was obtained.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelSource {
    /// Full curves fitted from a cumulative I/O series.
    Series,
    /// Summary fallback, proportional (streaming) data shape.
    SummaryStream,
    /// Summary fallback, burst-step data shape (peak RSS ≈ input size).
    SummaryBurst,
}

impl std::fmt::Display for ModelSource {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ModelSource::Series => "series",
            ModelSource::SummaryStream => "summary/stream",
            ModelSource::SummaryBurst => "summary/burst",
        })
    }
}

/// One calibrated task: a solver-ready process plus the trace facts the
/// assembler and the replay validator need.
#[derive(Clone, Debug)]
pub struct CalibratedTask {
    pub id: String,
    pub deps: Vec<String>,
    pub process: Process,
    /// Constant resource rate assumed for the fit (`pcpu/100`); the
    /// assembler wires `Fixed(alloc)` so fit and replay agree.
    pub alloc: f64,
    /// Total bytes read — the max of the TSV's `rchar` and the I/O
    /// series' final read counter, so a staged external input always
    /// covers the fitted `R_D`'s domain.
    pub rchar: f64,
    /// Total bytes written (a dep is wired as a data edge only if > 0).
    pub wchar: f64,
    pub observed_start: Option<f64>,
    pub observed_complete: Option<f64>,
    pub realtime: f64,
    pub source: ModelSource,
}

/// Fit a full process model from cumulative I/O samples of one execution.
///
/// `ts` is elapsed time since the task started; `read`/`written` are
/// cumulative byte counters sampled at those times (nondecreasing).
/// `alloc` is the (constant) resource rate assumed during the run. The
/// returned process uses output bytes as its progress metric — or, for a
/// task that writes nothing, consumed resource-seconds (so its pacing
/// still replays; its "output" then counts resource-seconds, which the
/// assembler never wires to a consumer).
pub fn fit_series(
    name: &str,
    ts: &[f64],
    read: &[f64],
    written: &[f64],
    alloc: f64,
    tol: f64,
    jump_eps: f64,
) -> Process {
    assert_eq!(ts.len(), read.len());
    assert_eq!(ts.len(), written.len());
    assert!(ts.len() >= 2, "need at least two samples");
    let alloc = if alloc > 1e-12 { alloc } else { 1.0 };
    let total_out = *written.last().unwrap();
    let total_in = *read.last().unwrap();

    if total_out <= 1e-9 {
        // no output: use consumed resource-seconds as the progress metric
        let max_progress = (ts[ts.len() - 1] * alloc).max(1e-9);
        let mut p = Process {
            name: name.to_string(),
            data_reqs: vec![],
            res_reqs: vec![ResourceRequirement {
                name: "cpu".to_string(),
                func: PwPoly::linear_from(0.0, 0.0, 1.0),
            }],
            outputs: vec![OutputFn {
                name: "out".to_string(),
                func: PwPoly::linear_from(0.0, 0.0, 1.0),
            }],
            max_progress,
        };
        if total_in > 1e-9 {
            let mut dr: Vec<(f64, f64)> = vec![];
            let mut max_read: f64 = 0.0;
            for i in 0..ts.len() {
                max_read = max_read.max(read[i]);
                dr.push((max_read, ts[i] * alloc));
            }
            anchor_at_origin(&mut dr);
            let fitted = compact(&dr, tol);
            p.data_reqs.push(DataRequirement {
                name: "in".to_string(),
                func: to_pwpoly_dir(&fitted, jump_eps * total_in, true),
            });
        }
        return p;
    }

    let x_span = total_in.max(1e-300);

    // ---- data requirement: written as a function of read ----------------
    // enforce monotone x by taking the running max of read
    let data_reqs = if total_in > 1e-9 {
        let mut dw: Vec<(f64, f64)> = vec![];
        let mut max_read: f64 = 0.0;
        for i in 0..ts.len() {
            max_read = max_read.max(read[i]);
            dw.push((max_read, written[i]));
        }
        anchor_at_origin(&mut dw);
        let fitted = compact(&dw, tol);
        vec![DataRequirement {
            name: "in".to_string(),
            func: to_pwpoly_dir(&fitted, jump_eps * x_span, true),
        }]
    } else {
        vec![]
    };

    // ---- resource requirement: cumulative resource vs written -----------
    // (time * alloc) as a function of output; up-front time becomes a jump
    let pw: Vec<(f64, f64)> = {
        let mut v: Vec<(f64, f64)> = vec![];
        let mut max_w: f64 = 0.0;
        for i in 0..ts.len() {
            max_w = max_w.max(written[i]);
            v.push((max_w, ts[i] * alloc));
        }
        v
    };
    let fitted_r = compact(&pw, tol);
    let res_req = to_pwpoly(&fitted_r, jump_eps * total_out.max(1e-300));

    Process {
        name: name.to_string(),
        data_reqs,
        res_reqs: vec![ResourceRequirement {
            name: "cpu".to_string(),
            func: res_req,
        }],
        outputs: vec![OutputFn {
            name: "out".to_string(),
            func: PwPoly::linear_from(0.0, 0.0, 1.0),
        }],
        max_progress: total_out,
    }
}

/// Anchor a fitted curve at the origin: if the first sample already shows
/// input (a task whose whole input was staged before it started — the
/// series then never observes the sub-`read[0]` region), prepend `(0, 0)`.
/// `R_D(0) = 0` is the conservative completion ("no progress before any
/// input") and, crucially, it keeps the burst threshold: without the
/// anchor, a fully-staged task's `(read, written)` cloud collapses onto a
/// single x and the widened step degenerates into a constant that never
/// gates on data.
fn anchor_at_origin(points: &mut Vec<(f64, f64)>) {
    if let Some(&(x0, _)) = points.first() {
        if x0 > 1e-12 {
            points.insert(0, (0.0, 0.0));
        }
    }
}

/// Build a summary-statistics model from a TSV row alone.
fn fit_summary(
    name: &str,
    realtime: f64,
    alloc: f64,
    rchar: f64,
    wchar: f64,
    peak_rss: f64,
) -> (Process, ModelSource) {
    let cpu_total = alloc * realtime;
    let max_progress = if wchar > 1e-9 {
        wchar
    } else {
        cpu_total.max(1e-9)
    };
    let burst = rchar > 1e-9 && peak_rss >= 0.5 * rchar;
    let mut b = ProcessBuilder::new(name, max_progress);
    if rchar > 1e-9 {
        b = if burst {
            b.burst_data("in", rchar)
        } else {
            b.stream_data("in", rchar)
        };
    }
    if cpu_total > 1e-12 {
        b = b.stream_resource("cpu", cpu_total);
    }
    let p = b.identity_output("out").build();
    (
        p,
        if burst {
            ModelSource::SummaryBurst
        } else {
            ModelSource::SummaryStream
        },
    )
}

/// Calibrate every task of a parsed trace: series fit where an I/O series
/// exists (≥ 2 usable samples), summary fallback otherwise. Series
/// timestamps are on the workflow clock; samples before the task's logged
/// start are dropped (input may accumulate before a task runs) and
/// samples after `start + realtime` are dropped (idle tails would inflate
/// the fitted resource demand).
pub fn calibrate(
    trace: &TsvTrace,
    series: &[IoSeries],
    opts: &CalibrateOpts,
) -> Result<Vec<CalibratedTask>> {
    let mut by_task: std::collections::HashMap<&str, &IoSeries> =
        std::collections::HashMap::new();
    for s in series {
        ensure!(
            trace.task(&s.task).is_some(),
            "io series for task '{}' which is not in the trace",
            s.task
        );
        ensure!(
            !s.ts.is_empty(),
            "io series for task '{}' is empty",
            s.task
        );
        by_task.insert(&s.task, s);
    }
    let mut out = Vec::with_capacity(trace.tasks.len());
    for t in &trace.tasks {
        let alloc = t
            .pcpu
            .map(|p| p / 100.0)
            .filter(|a| *a > 1e-12)
            .unwrap_or(opts.default_alloc);
        let sr = by_task.get(t.id.as_str()).copied();
        let fitted = sr.and_then(|s| {
            // anchor the fit window on the workflow clock: at the logged
            // start, else counted back from the logged completion, else
            // back from the series tail (a task's counters stop moving
            // when it ends — anchoring at the series *head* would fit the
            // wrong window whenever the log starts before the task does)
            let t0 = t
                .start
                .or_else(|| t.complete.map(|c| c - t.realtime))
                .unwrap_or_else(|| s.ts[s.ts.len() - 1] - t.realtime);
            let cutoff = t.realtime * (1.0 + 1e-9) + 1e-9;
            let mut ts = vec![];
            let mut read = vec![];
            let mut written = vec![];
            for i in 0..s.ts.len() {
                let rel = s.ts[i] - t0;
                if rel < -1e-9 || rel > cutoff {
                    continue;
                }
                ts.push(rel.max(0.0));
                read.push(s.read[i]);
                written.push(s.written[i]);
            }
            (ts.len() >= 2).then(|| {
                let series_read = read.iter().fold(0.0f64, |m, &x| m.max(x));
                let p = fit_series(
                    &t.name, &ts, &read, &written, alloc, opts.tol, opts.jump_eps,
                );
                (p, series_read)
            })
        });
        // a series-fitted R_D's domain ends at the series' read total; if
        // the TSV's rchar is smaller (the two counters measure reads
        // differently in real monitors), staging only rchar would leave
        // the model short of input forever — size the input to cover both
        let (mut process, source, rchar) = match fitted {
            Some((p, series_read)) => {
                (p, ModelSource::Series, t.rchar.max(series_read))
            }
            None => {
                let (p, s) =
                    fit_summary(&t.name, t.realtime, alloc, t.rchar, t.wchar, t.peak_rss);
                (p, s, t.rchar)
            }
        };
        // no (or zero) logged CPU: the model still paces the task on wall
        // time via default_alloc, but the resource must not masquerade as
        // CPU demand — an idle task charged a full core would misattribute
        // demand in any shared-pool reuse of the model
        if t.pcpu.map(|p| p <= 1e-12).unwrap_or(true) {
            for r in process.res_reqs.iter_mut() {
                r.name = "wall".to_string();
            }
        }
        if let Err(e) = process.validate() {
            bail!("calibrated model for task '{}' is invalid: {e}", t.id);
        }
        out.push(CalibratedTask {
            id: t.id.clone(),
            deps: t.deps.clone(),
            process,
            alloc,
            rchar,
            wchar: t.wchar,
            observed_start: t.start,
            observed_complete: t.complete.or_else(|| t.start.map(|s| s + t.realtime)),
            realtime: t.realtime,
            source,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::format::{parse_io_log, parse_tsv};

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() < tol
    }

    /// A synthetic streaming task: reads 1e8 at 1e7/s, writes half of it.
    fn stream_series() -> IoSeries {
        let mut s = IoSeries {
            task: "enc".into(),
            ..IoSeries::default()
        };
        for i in 0..=100 {
            let t = 0.1 * i as f64;
            s.ts.push(t);
            s.read.push(1e7 * t);
            s.written.push(5e6 * t);
        }
        s
    }

    #[test]
    fn fit_series_stream_shape() {
        let s = stream_series();
        let p = fit_series("enc", &s.ts, &s.read, &s.written, 1.0, 0.01, 1e-6);
        assert!(p.validate().is_ok());
        assert!(close(p.max_progress, 5e7, 1.0));
        // proportional: half the input gives half the progress
        assert!(close(p.data_reqs[0].func.eval(5e7), 2.5e7, 0.02 * 5e7));
        // 10 s of one core over 5e7 B of progress
        assert!(close(p.res_reqs[0].func.eval(5e7), 10.0, 0.1));
        assert!(p.data_reqs[0].func.n_pieces() <= 4);
    }

    #[test]
    fn fit_series_no_output_uses_cpu_metric() {
        let ts: Vec<f64> = (0..=10).map(|i| i as f64).collect();
        let read: Vec<f64> = ts.iter().map(|t| 1e6 * t).collect();
        let written = vec![0.0; ts.len()];
        let p = fit_series("probe", &ts, &read, &written, 2.0, 0.01, 1e-6);
        assert!(p.validate().is_ok());
        // progress metric = cpu-seconds at alloc 2.0 over 10 s
        assert!(close(p.max_progress, 20.0, 1e-9));
        assert!(close(p.res_reqs[0].func.eval(20.0), 20.0, 1e-9));
        assert_eq!(p.data_reqs.len(), 1);
    }

    const TSV: &str = "task_id\tdeps\tstart\tcomplete\trealtime\tpcpu\trchar\twchar\tpeak_rss\n\
        stream\t-\t0\t10\t10\t100\t1e8\t5e7\t1e6\n\
        burst\tstream\t10\t15\t5\t200\t5e7\t5e7\t4.9e7\n\
        nocpu\tburst\t15\t18\t3\t-\t1e6\t1e6\t0\n";

    #[test]
    fn summary_fallback_shapes() {
        let trace = parse_tsv(TSV).unwrap();
        let cal = calibrate(&trace, &[], &CalibrateOpts::default()).unwrap();
        assert_eq!(cal.len(), 3);

        // low peak_rss => streaming shape
        let s = &cal[0];
        assert_eq!(s.source, ModelSource::SummaryStream);
        assert!(close(s.process.data_reqs[0].func.eval(5e7), 2.5e7, 1.0));
        assert!(close(s.process.res_reqs[0].func.eval(5e7), 10.0, 1e-9));
        assert!(close(s.observed_complete.unwrap(), 10.0, 1e-12));

        // peak_rss ≈ rchar => burst shape, 2 cores
        let b = &cal[1];
        assert_eq!(b.source, ModelSource::SummaryBurst);
        assert!(b.process.data_reqs[0].func.eval(0.99 * 5e7) < 1.0);
        assert!(close(b.process.data_reqs[0].func.eval(5e7), 5e7, 1.0));
        assert!(close(b.alloc, 2.0, 1e-12));
        assert!(close(b.process.res_reqs[0].func.eval(5e7), 10.0, 1e-9));

        // missing pcpu => default alloc
        assert!(close(cal[2].alloc, 1.0, 1e-12));
    }

    #[test]
    fn series_preferred_over_summary_and_clock_normalized() {
        let trace = parse_tsv(TSV).unwrap();
        // series on the workflow clock, task starts at t=10: earlier
        // samples (input piling up) are dropped, later ones normalized
        let log = "burst 5 2.5e7 0\nburst 10 5e7 0\nburst 12.5 5e7 2.5e7\nburst 15 5e7 5e7\n";
        let series = parse_io_log(log).unwrap();
        let cal = calibrate(&trace, &series, &CalibrateOpts::default()).unwrap();
        let b = &cal[1];
        assert_eq!(b.source, ModelSource::Series);
        // all input was available at (relative) t=0; output spread over 5 s
        // at alloc 2.0 => 10 cpu-s total
        assert!(close(b.process.res_reqs[0].func.eval(5e7), 10.0, 0.2));
        assert!(b.process.max_progress == 5e7);
    }

    /// With no `start` column, the fit window is counted back from the
    /// series tail (counters stop moving when the task ends) — never
    /// anchored at the series head, which may long predate the task.
    #[test]
    fn series_anchored_at_tail_without_start_column() {
        let tsv = "task_id\tdeps\trealtime\tpcpu\trchar\twchar\na\t-\t5\t200\t5e7\t5e7\n";
        let trace = parse_tsv(tsv).unwrap();
        // workflow-clock log starting at t=5; the task only ran [10, 15]
        let log = "a 5 2.5e7 0\na 10 5e7 0\na 12.5 5e7 2.5e7\na 15 5e7 5e7\n";
        let series = parse_io_log(log).unwrap();
        let cal = calibrate(&trace, &series, &CalibrateOpts::default()).unwrap();
        assert_eq!(cal[0].source, ModelSource::Series);
        // fit window [10, 15]: 5 s at alloc 2.0 => 10 cpu-s over 5e7
        assert!(close(cal[0].process.res_reqs[0].func.eval(5e7), 10.0, 0.2));
    }

    /// pcpu absent or zero: the model is wall-paced, and its resource is
    /// named "wall" so it cannot masquerade as CPU demand downstream.
    #[test]
    fn wall_paced_resource_is_labelled() {
        let trace = parse_tsv(TSV).unwrap();
        let cal = calibrate(&trace, &[], &CalibrateOpts::default()).unwrap();
        assert_eq!(cal[2].process.res_reqs[0].name, "wall"); // pcpu '-'
        assert_eq!(cal[0].process.res_reqs[0].name, "cpu"); // pcpu 100
    }

    #[test]
    fn unknown_series_task_is_an_error() {
        let trace = parse_tsv(TSV).unwrap();
        let series = parse_io_log("ghost 0 0 0\n").unwrap();
        let e = calibrate(&trace, &series, &CalibrateOpts::default())
            .unwrap_err()
            .to_string();
        assert!(e.contains("ghost"), "{e}");
    }
}
