//! Raw trace formats: Nextflow-style per-task TSV and BPF-style I/O series.
//!
//! Two complementary inputs, mirroring what real workflow engines emit
//! (cf. *Low-level I/O Monitoring for Scientific Workflows*, Witzke et al.
//! 2024, and Nextflow's `trace.txt`):
//!
//! * **TSV trace** — one row per task with summary statistics: identity,
//!   dependency edges, wall time, average CPU utilization, cumulative bytes
//!   read/written (`rchar`/`wchar`) and peak resident set. Enough to build
//!   a coarse model of every task ([`mod@crate::trace::calibrate`]'s
//!   summary-stats fallback).
//! * **I/O series log** — timestamped cumulative `(read, written)` byte
//!   counters per task, the Fig 6 shape. When present for a task, the
//!   calibrator fits full requirement curves from it instead of the
//!   summary fallback.
//!
//! Both parsers are strict on *form*: malformed rows fail with the line
//! number and the offending value (via [`crate::util::error`]), never
//! silently skip. Sample *ordering* is tolerant — streaming producers
//! deliver I/O samples out of order and re-send overlapping windows, so
//! [`parse_io_log`] sorts per task and resolves duplicate timestamps by
//! last-write-wins.
//! Numbers accept scientific notation (`1.2e9` byte counts are common in
//! real traces). The writers ([`write_tsv`], [`write_io_log`]) emit the
//! exact same dialect, which is what makes the fluid-testbed round trip
//! (`execute` → export → parse → calibrate → replay) a byte-level test of
//! the whole pipeline.

use crate::util::error::{Error, Result};
use crate::{bail, ensure};

/// One TSV row: summary statistics of a single task execution.
#[derive(Clone, Debug, PartialEq)]
pub struct TsvTask {
    /// Unique task id (the `deps` column refers to these).
    pub id: String,
    /// Human-readable name (defaults to the id).
    pub name: String,
    /// Upstream task ids this task consumed data from / waited on.
    pub deps: Vec<String>,
    /// Wall-clock start on the workflow clock, if logged.
    pub start: Option<f64>,
    /// Wall-clock completion on the workflow clock, if logged.
    pub complete: Option<f64>,
    /// Wall-clock duration in seconds.
    pub realtime: f64,
    /// Average CPU utilization in percent (100 = one busy core), if logged.
    pub pcpu: Option<f64>,
    /// Cumulative bytes read.
    pub rchar: f64,
    /// Cumulative bytes written.
    pub wchar: f64,
    /// Peak resident set size in bytes, if logged (0 = unknown).
    pub peak_rss: f64,
}

/// A parsed TSV trace: one entry per task, in file order.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TsvTrace {
    pub tasks: Vec<TsvTask>,
}

/// Timestamped cumulative I/O counters of one task (BPF-style).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct IoSeries {
    pub task: String,
    /// Sample times (workflow clock). [`parse_io_log`] keeps these strictly
    /// increasing by construction: arriving samples are inserted in sorted
    /// order and a re-sent timestamp overwrites its predecessor.
    pub ts: Vec<f64>,
    /// Cumulative bytes read at each sample.
    pub read: Vec<f64>,
    /// Cumulative bytes written at each sample.
    pub written: Vec<f64>,
}

impl TsvTrace {
    /// Look up a task by id.
    pub fn task(&self, id: &str) -> Option<&TsvTask> {
        self.tasks.iter().find(|t| t.id == id)
    }
}

fn parse_num(field: &str, value: &str, line: usize) -> Result<f64> {
    value
        .parse::<f64>()
        .ok()
        .filter(|x| x.is_finite())
        .ok_or_else(|| {
            Error::msg(format!(
                "trace line {line}: bad number '{value}' in column '{field}'"
            ))
        })
}

fn parse_opt_num(field: &str, value: &str, line: usize) -> Result<Option<f64>> {
    if value == "-" || value.is_empty() {
        return Ok(None);
    }
    parse_num(field, value, line).map(Some)
}

/// Parse a Nextflow-style TSV trace.
///
/// The first non-comment line is a tab-separated header naming the columns;
/// rows follow in any column order. Required columns: `task_id`, `deps`,
/// `rchar`, `wchar`, and timing (`realtime`, or both `start` and
/// `complete`). Optional: `name`, `start`, `complete`, `pcpu`, `peak_rss`.
/// `-` means "not logged" in any optional field; `deps` is a
/// comma-separated list of task ids or `-` for none. Unknown columns are
/// ignored. Lines starting with `#` are comments.
pub fn parse_tsv(text: &str) -> Result<TsvTrace> {
    let trace = parse_tsv_structural(text)?;
    // referential integrity: every dep must name a task in this trace
    for t in &trace.tasks {
        for d in &t.deps {
            ensure!(
                trace.task(d).is_some(),
                "task '{}' depends on unknown task '{d}'",
                t.id
            );
        }
    }
    Ok(trace)
}

/// [`parse_tsv`] minus the referential-integrity check on `deps`.
///
/// A *streaming* producer (the live monitor's feed path) legitimately
/// delivers a row before the rows it depends on: each row here must be
/// well-formed on its own, but a dep may name a task whose row has not
/// arrived yet. Offline consumers want [`parse_tsv`], which rejects
/// dangling deps outright.
pub fn parse_tsv_structural(text: &str) -> Result<TsvTrace> {
    let mut lines = text
        .lines()
        .enumerate()
        .map(|(i, l)| (i + 1, l))
        .filter(|(_, l)| !l.trim().is_empty() && !l.trim_start().starts_with('#'));

    let (header_line, header) = lines
        .next()
        .ok_or_else(|| Error::msg("empty trace: no header line"))?;
    let cols: Vec<&str> = header.split('\t').map(str::trim).collect();
    let col = |name: &str| cols.iter().position(|c| *c == name);
    let need = |name: &str| {
        col(name).ok_or_else(|| {
            Error::msg(format!(
                "trace line {header_line}: header is missing required column '{name}'"
            ))
        })
    };
    let c_id = need("task_id")?;
    let c_deps = need("deps")?;
    let c_rchar = need("rchar")?;
    let c_wchar = need("wchar")?;
    let c_realtime = col("realtime");
    let c_start = col("start");
    let c_complete = col("complete");
    if c_realtime.is_none() && (c_start.is_none() || c_complete.is_none()) {
        bail!(
            "trace line {header_line}: need a 'realtime' column, or both 'start' and 'complete'"
        );
    }
    let c_name = col("name");
    let c_pcpu = col("pcpu");
    let c_rss = col("peak_rss");

    let mut tasks: Vec<TsvTask> = vec![];
    let mut seen_ids: std::collections::HashSet<String> = std::collections::HashSet::new();
    for (ln, line) in lines {
        let fields: Vec<&str> = line.split('\t').map(str::trim).collect();
        ensure!(
            fields.len() == cols.len(),
            "trace line {ln}: {} fields for {} header columns",
            fields.len(),
            cols.len()
        );
        let id = fields[c_id].to_string();
        ensure!(!id.is_empty(), "trace line {ln}: empty task_id");
        ensure!(
            seen_ids.insert(id.clone()),
            "trace line {ln}: duplicate task_id '{id}'"
        );
        let deps: Vec<String> = match fields[c_deps] {
            "-" | "" => vec![],
            d => d.split(',').map(|s| s.trim().to_string()).collect(),
        };
        ensure!(
            deps.iter().all(|d| !d.is_empty()),
            "trace line {ln}: empty dep id in '{}'",
            fields[c_deps]
        );
        ensure!(
            !deps.iter().any(|d| *d == id),
            "trace line {ln}: task '{id}' depends on itself"
        );
        let start = match c_start {
            Some(c) => parse_opt_num("start", fields[c], ln)?,
            None => None,
        };
        let complete = match c_complete {
            Some(c) => parse_opt_num("complete", fields[c], ln)?,
            None => None,
        };
        let realtime = match c_realtime {
            Some(c) => parse_opt_num("realtime", fields[c], ln)?,
            None => None,
        };
        let realtime = match (realtime, start, complete) {
            (Some(r), _, _) => r,
            (None, Some(s), Some(e)) => e - s,
            _ => bail!(
                "trace line {ln}: task '{id}' has neither realtime nor start+complete"
            ),
        };
        ensure!(
            realtime.is_finite() && realtime >= 0.0,
            "trace line {ln}: task '{id}' has negative or non-finite realtime {realtime}"
        );
        if let (Some(s), Some(e)) = (start, complete) {
            ensure!(
                e >= s,
                "trace line {ln}: task '{id}' completes at {e} before its start {s}"
            );
        }
        let pcpu = match c_pcpu {
            Some(c) => parse_opt_num("pcpu", fields[c], ln)?,
            None => None,
        };
        let rchar = parse_num("rchar", fields[c_rchar], ln)?;
        let wchar = parse_num("wchar", fields[c_wchar], ln)?;
        ensure!(
            rchar >= 0.0 && wchar >= 0.0,
            "trace line {ln}: task '{id}' has negative I/O counters"
        );
        let peak_rss = match c_rss {
            Some(c) => parse_opt_num("peak_rss", fields[c], ln)?.unwrap_or(0.0),
            None => 0.0,
        };
        tasks.push(TsvTask {
            name: match c_name {
                Some(c) if !fields[c].is_empty() && fields[c] != "-" => {
                    fields[c].to_string()
                }
                _ => id.clone(),
            },
            id,
            deps,
            start,
            complete,
            realtime,
            pcpu,
            rchar,
            wchar,
            peak_rss,
        });
    }
    ensure!(!tasks.is_empty(), "trace has a header but no task rows");
    Ok(TsvTrace { tasks })
}

/// Parse a BPF-style cumulative I/O log: whitespace-separated
/// `task_id  t  bytes_read  bytes_written` per line, `#` comments allowed.
/// Samples are grouped per task in file order. Per task, samples are kept
/// sorted by timestamp: a *streaming* producer (shard interleaving, window
/// re-sends — the live monitor's feed path) legitimately delivers samples
/// out of order or re-sends a timestamp it already reported, so neither is
/// an error. An out-of-order sample is inserted at its sorted position; an
/// exact-duplicate timestamp overwrites the earlier sample (last write
/// wins). Counter regressions across the *sorted* series are tolerated too
/// (a re-sent stale window): the calibrator monotonizes cumulative
/// counters with a running max before fitting. Malformed lines (wrong
/// field count, non-finite or negative values) are still errors, with the
/// line number.
pub fn parse_io_log(text: &str) -> Result<Vec<IoSeries>> {
    let mut out: Vec<IoSeries> = vec![];
    let mut index: std::collections::HashMap<String, usize> = std::collections::HashMap::new();
    for (ln, line) in text.lines().enumerate().map(|(i, l)| (i + 1, l)) {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let f: Vec<&str> = line.split_whitespace().collect();
        ensure!(
            f.len() == 4,
            "io log line {ln}: expected 'task t read written', got {} field(s)",
            f.len()
        );
        let t = parse_num("t", f[1], ln)?;
        let read = parse_num("read", f[2], ln)?;
        let written = parse_num("written", f[3], ln)?;
        ensure!(
            t.is_finite() && read.is_finite() && written.is_finite(),
            "io log line {ln}: non-finite sample"
        );
        ensure!(
            read >= 0.0 && written >= 0.0,
            "io log line {ln}: negative cumulative counter"
        );
        let idx = match index.get(f[0]) {
            Some(&i) => i,
            None => {
                out.push(IoSeries {
                    task: f[0].to_string(),
                    ..IoSeries::default()
                });
                index.insert(f[0].to_string(), out.len() - 1);
                out.len() - 1
            }
        };
        let series = &mut out[idx];
        // sorted insert, last write wins on an exact-duplicate timestamp
        let pos = series.ts.partition_point(|&x| x < t);
        if pos < series.ts.len() && series.ts[pos] == t {
            series.read[pos] = read;
            series.written[pos] = written;
        } else {
            series.ts.insert(pos, t);
            series.read.insert(pos, read);
            series.written.insert(pos, written);
        }
    }
    Ok(out)
}

/// Serialize a TSV trace in the dialect [`parse_tsv`] reads.
pub fn write_tsv(trace: &TsvTrace) -> String {
    let mut out = String::from(
        "task_id\tname\tdeps\tstart\tcomplete\trealtime\tpcpu\trchar\twchar\tpeak_rss\n",
    );
    let num = |x: f64| format!("{x}");
    let opt = |x: Option<f64>| x.map(&num).unwrap_or_else(|| "-".into());
    for t in &trace.tasks {
        let deps = if t.deps.is_empty() {
            "-".to_string()
        } else {
            t.deps.join(",")
        };
        out.push_str(&format!(
            "{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\n",
            t.id,
            t.name,
            deps,
            opt(t.start),
            opt(t.complete),
            num(t.realtime),
            opt(t.pcpu),
            num(t.rchar),
            num(t.wchar),
            num(t.peak_rss),
        ));
    }
    out
}

/// Serialize I/O series in the dialect [`parse_io_log`] reads.
pub fn write_io_log(series: &[IoSeries]) -> String {
    let mut out = String::from("# task t read written\n");
    for s in series {
        for i in 0..s.ts.len() {
            out.push_str(&format!(
                "{}\t{}\t{}\t{}\n",
                s.task, s.ts[i], s.read[i], s.written[i]
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const DEMO: &str = "task_id\tname\tdeps\tstart\tcomplete\trealtime\tpcpu\trchar\twchar\tpeak_rss\n\
        dl\tdownload\t-\t0\t10\t10\t1e9\t1e8\t1e8\t2e6\n\
        enc\tencode\tdl\t0\t20\t20\t100\t1e8\t5e7\t8e6\n\
        mux\tmux\tdl,enc\t20\t23\t3\t100\t1.5e8\t1.5e8\t4e6\n";

    #[test]
    fn parses_demo_tsv() {
        let tr = parse_tsv(DEMO).unwrap();
        assert_eq!(tr.tasks.len(), 3);
        let enc = tr.task("enc").unwrap();
        assert_eq!(enc.deps, vec!["dl".to_string()]);
        assert_eq!(enc.rchar, 1e8);
        assert_eq!(enc.wchar, 5e7);
        assert_eq!(enc.pcpu, Some(100.0));
        let mux = tr.task("mux").unwrap();
        assert_eq!(mux.deps.len(), 2);
        assert_eq!(mux.start, Some(20.0));
        // scientific notation survives
        assert_eq!(tr.task("dl").unwrap().pcpu, Some(1e9));
    }

    #[test]
    fn header_driven_column_order_and_extras() {
        let text = "extra\trchar\twchar\ttask_id\tdeps\trealtime\n\
            x\t100\t50\ta\t-\t5\n";
        let tr = parse_tsv(text).unwrap();
        assert_eq!(tr.tasks[0].id, "a");
        assert_eq!(tr.tasks[0].rchar, 100.0);
        assert_eq!(tr.tasks[0].pcpu, None);
        assert_eq!(tr.tasks[0].name, "a"); // defaults to id
    }

    #[test]
    fn errors_carry_line_numbers() {
        let bad_num = "task_id\tdeps\trealtime\trchar\twchar\na\t-\t5\toops\t0\n";
        let e = parse_tsv(bad_num).unwrap_err().to_string();
        assert!(e.contains("line 2") && e.contains("oops") && e.contains("rchar"), "{e}");

        let missing = "task_id\trealtime\trchar\twchar\na\t5\t1\t1\n";
        let e = parse_tsv(missing).unwrap_err().to_string();
        assert!(e.contains("deps"), "{e}");

        let no_timing = "task_id\tdeps\trchar\twchar\na\t-\t1\t1\n";
        let e = parse_tsv(no_timing).unwrap_err().to_string();
        assert!(e.contains("realtime"), "{e}");

        let unknown_dep = "task_id\tdeps\trealtime\trchar\twchar\na\tzz\t5\t1\t1\n";
        let e = parse_tsv(unknown_dep).unwrap_err().to_string();
        assert!(e.contains("unknown task 'zz'"), "{e}");
        // the structural parser tolerates the dangling dep (a streaming
        // producer may deliver 'zz' later) but nothing else
        let t = parse_tsv_structural(unknown_dep).unwrap();
        assert_eq!(t.tasks[0].deps, vec!["zz".to_string()]);

        let dup = "task_id\tdeps\trealtime\trchar\twchar\na\t-\t5\t1\t1\na\t-\t5\t1\t1\n";
        let e = parse_tsv(dup).unwrap_err().to_string();
        assert!(e.contains("duplicate"), "{e}");

        let self_dep = "task_id\tdeps\trealtime\trchar\twchar\na\ta\t5\t1\t1\n";
        let e = parse_tsv(self_dep).unwrap_err().to_string();
        assert!(e.contains("itself"), "{e}");
    }

    #[test]
    fn realtime_derived_from_start_complete() {
        let text = "task_id\tdeps\tstart\tcomplete\trchar\twchar\na\t-\t2\t7.5\t1\t1\n";
        let tr = parse_tsv(text).unwrap();
        assert_eq!(tr.tasks[0].realtime, 5.5);
    }

    #[test]
    fn io_log_roundtrip_and_grouping() {
        let text = "# comment\n\
            a 0.0 0 0\n\
            b 0.0 10 0\n\
            a 1.0 100 50\n\
            a 2.0 2e2 1e2\n\
            b 1.5 20 5\n";
        let series = parse_io_log(text).unwrap();
        assert_eq!(series.len(), 2);
        let a = &series[0];
        assert_eq!(a.task, "a");
        assert_eq!(a.ts, vec![0.0, 1.0, 2.0]);
        assert_eq!(a.read, vec![0.0, 100.0, 200.0]);
        assert_eq!(a.written[2], 100.0);
        // writer emits what the parser reads
        let again = parse_io_log(&write_io_log(&series)).unwrap();
        assert_eq!(again, series);
    }

    /// Streaming feeds deliver samples out of order: they are inserted at
    /// their sorted position, not rejected.
    #[test]
    fn io_log_accepts_out_of_order_samples() {
        let text = "a 1.0 100 50\na 0.5 40 20\na 2.0 200 100\na 1.5 150 75\n";
        let series = parse_io_log(text).unwrap();
        assert_eq!(series.len(), 1);
        assert_eq!(series[0].ts, vec![0.5, 1.0, 1.5, 2.0]);
        assert_eq!(series[0].read, vec![40.0, 100.0, 150.0, 200.0]);
        assert_eq!(series[0].written, vec![20.0, 50.0, 75.0, 100.0]);
        // equivalent to the in-order delivery of the same samples
        let in_order = parse_io_log("a 0.5 40 20\na 1.0 100 50\na 1.5 150 75\na 2.0 200 100\n")
            .unwrap();
        assert_eq!(series, in_order);
    }

    /// A re-sent timestamp (window overlap in a streaming feed) overwrites
    /// the earlier sample — last write wins, no duplicate row.
    #[test]
    fn io_log_duplicate_timestamp_is_last_write_wins() {
        let text = "a 0.0 0 0\na 1.0 80 40\na 1.0 100 50\na 2.0 200 100\n";
        let series = parse_io_log(text).unwrap();
        assert_eq!(series[0].ts, vec![0.0, 1.0, 2.0]);
        assert_eq!(series[0].read, vec![0.0, 100.0, 200.0]);
        assert_eq!(series[0].written, vec![0.0, 50.0, 100.0]);
        // a stale re-send that *regresses* the counter also wins (the
        // calibrator's running max absorbs it downstream)
        let stale = parse_io_log("a 0.0 0 0\na 1.0 100 50\na 1.0 90 45\n").unwrap();
        assert_eq!(stale[0].read, vec![0.0, 90.0]);
    }

    #[test]
    fn io_log_rejects_malformed_lines() {
        let short = "a 1.0 10\n";
        let e = parse_io_log(short).unwrap_err().to_string();
        assert!(e.contains("expected"), "{e}");

        let negative = "a 1.0 -5 0\n";
        let e = parse_io_log(negative).unwrap_err().to_string();
        assert!(e.contains("negative"), "{e}");

        let bad = "a x 10 0\n";
        let e = parse_io_log(bad).unwrap_err().to_string();
        assert!(e.contains("bad number"), "{e}");
    }

    #[test]
    fn tsv_roundtrip() {
        let tr = parse_tsv(DEMO).unwrap();
        let again = parse_tsv(&write_tsv(&tr)).unwrap();
        assert_eq!(again, tr);
    }
}
