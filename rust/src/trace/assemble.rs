//! From calibrated tasks to a solver-ready [`Workflow`], plus the replay
//! validator that closes the loop.
//!
//! Wiring rules (documented in `docs/TRACES.md`):
//!
//! * a task with **exactly one** dependency whose producer wrote bytes —
//!   and whose own read volume the producer's output can actually cover —
//!   is wired *pipelined*: its data input is the producer's
//!   output-over-time function `O(P(t))`, so streaming overlap replays;
//! * a task with **zero or several** dependencies (or one the producer
//!   cannot feed) gets Nextflow stage-in semantics: all dependencies
//!   become barrier edges (`StartRule::after`) and its input is modeled as
//!   fully staged (`DataSource::External` at `rchar` bytes);
//! * every resource requirement is wired `Fixed(alloc)` with the same
//!   constant allocation the calibrator assumed, so fit and replay agree.
//!
//! [`replay`] then re-runs the analytic solver on the assembled model and
//! compares each task's predicted completion against the trace's observed
//! completion. The relative error is the end-to-end quality metric of the
//! whole pipeline: parse → fit → assemble → solve. Segmentation loss,
//! fallback-shape mismatch and wiring approximations all land in it.

use crate::pwfn::PwPoly;
use crate::solver::SolverOpts;
use crate::util::error::Result;
use crate::workflow::engine::analyze_fixpoint;
use crate::workflow::graph::{DataSource, ResourceSource, StartRule, Workflow};
use crate::{bail, ensure};

use super::calibrate::{calibrate, CalibrateOpts, CalibratedTask};
use super::format::{parse_io_log, parse_tsv};

/// A calibrated workflow: the DAG plus the per-node trace facts
/// (`tasks[i]` describes `workflow.nodes[i]`).
#[derive(Clone, Debug)]
pub struct CalibratedWorkflow {
    pub workflow: Workflow,
    pub tasks: Vec<CalibratedTask>,
}

/// Assemble calibrated tasks into a workflow (see module docs for the
/// wiring rules). Fails with a descriptive error on unknown dependency
/// ids, duplicate ids, arity surprises, or dependency cycles.
pub fn assemble(tasks: Vec<CalibratedTask>) -> Result<CalibratedWorkflow> {
    let mut index: std::collections::HashMap<&str, usize> = std::collections::HashMap::new();
    for (i, t) in tasks.iter().enumerate() {
        ensure!(
            index.insert(t.id.as_str(), i).is_none(),
            "duplicate task id '{}'",
            t.id
        );
    }
    for t in &tasks {
        for d in &t.deps {
            ensure!(
                index.contains_key(d.as_str()),
                "task '{}' depends on unknown task '{d}'",
                t.id
            );
        }
    }
    let index_of = |id: &str| index[id];

    let mut wf = Workflow::new();
    for t in &tasks {
        let n_data = t.process.data_reqs.len();
        ensure!(
            n_data <= 1,
            "task '{}': calibrated processes carry at most one data requirement, got {n_data}",
            t.id
        );
        let mut after: Vec<usize> = vec![];
        let mut data_sources: Vec<DataSource> = vec![];
        if n_data == 0 {
            after.extend(t.deps.iter().map(|d| index_of(d)));
        } else {
            let pipelined = if t.deps.len() == 1 {
                let dep = &tasks[index_of(&t.deps[0])];
                // the producer must actually deliver the bytes this task read
                (dep.wchar > 1e-9 && t.rchar <= dep.wchar * 1.001 + 1e-6)
                    .then(|| index_of(&t.deps[0]))
            } else {
                None
            };
            match pipelined {
                Some(node) => {
                    data_sources.push(DataSource::ProcessOutput { node, output: 0 });
                }
                None => {
                    // stage-in semantics: barrier on every dep, input staged
                    after.extend(t.deps.iter().map(|d| index_of(d)));
                    data_sources.push(DataSource::External(PwPoly::constant(
                        t.rchar.max(1e-9),
                    )));
                }
            }
        }
        let resource_sources: Vec<ResourceSource> = t
            .process
            .res_reqs
            .iter()
            .map(|_| ResourceSource::Fixed(PwPoly::constant(t.alloc)))
            .collect();
        // a root task's start is exogenous (submit/queue delay the DAG
        // cannot derive) — honor the trace so a late-starting root does
        // not register as replay error. Dependent tasks' starts are
        // predictions, derived from their producers.
        let at = if t.deps.is_empty() {
            t.observed_start.unwrap_or(0.0)
        } else {
            0.0
        };
        wf.add_node(
            t.process.clone(),
            data_sources,
            resource_sources,
            StartRule { at, after },
        );
    }
    if let Err(e) = wf.validate() {
        bail!("assembled workflow is invalid: {e}");
    }
    Ok(CalibratedWorkflow {
        workflow: wf,
        tasks,
    })
}

/// Predicted-vs-observed completion of one task.
#[derive(Clone, Debug)]
pub struct TaskReplay {
    pub id: String,
    pub predicted_start: f64,
    pub predicted: Option<f64>,
    pub observed: Option<f64>,
    /// `|predicted − observed| / observed`, when both are known.
    pub rel_err: Option<f64>,
}

/// Result of replaying a calibrated workflow through the solver.
#[derive(Clone, Debug)]
pub struct ReplayReport {
    pub per_task: Vec<TaskReplay>,
    pub predicted_makespan: Option<f64>,
    /// Latest observed completion in the trace (`None` if the trace logs
    /// no completion times at all).
    pub observed_makespan: Option<f64>,
    /// Worst per-task relative error (`None` if nothing was comparable).
    pub max_rel_err: Option<f64>,
    pub events: usize,
    pub passes: usize,
}

/// One row of the calibration report: model provenance + curve sizes +
/// replay numbers for a task. The CLI table, the service JSON and the
/// examples all derive from [`CalibratedWorkflow::task_summaries`] so the
/// three surfaces cannot drift.
#[derive(Clone, Debug)]
pub struct TaskSummary {
    pub id: String,
    /// `"series"`, `"summary/stream"` or `"summary/burst"`.
    pub model: String,
    pub data_pieces: usize,
    pub res_pieces: usize,
    pub predicted_start: f64,
    pub predicted: Option<f64>,
    pub observed: Option<f64>,
    pub rel_err: Option<f64>,
}

impl CalibratedWorkflow {
    /// Per-task report rows, index-aligned with `report.per_task`.
    pub fn task_summaries(&self, report: &ReplayReport) -> Vec<TaskSummary> {
        self.tasks
            .iter()
            .zip(&report.per_task)
            .map(|(t, r)| TaskSummary {
                id: t.id.clone(),
                model: t.source.to_string(),
                data_pieces: t
                    .process
                    .data_reqs
                    .first()
                    .map(|d| d.func.n_pieces())
                    .unwrap_or(0),
                res_pieces: t
                    .process
                    .res_reqs
                    .first()
                    .map(|q| q.func.n_pieces())
                    .unwrap_or(0),
                predicted_start: r.predicted_start,
                predicted: r.predicted,
                observed: r.observed,
                rel_err: r.rel_err,
            })
            .collect()
    }
}

/// Re-run the analytic solver on the calibrated model and report per-task
/// predicted-vs-observed completion error.
pub fn replay(cal: &CalibratedWorkflow, opts: &SolverOpts) -> Result<ReplayReport> {
    let wa = analyze_fixpoint(&cal.workflow, opts, 8)
        .map_err(|e| crate::util::error::Error::msg(format!("replay failed: {e}")))?;
    let mut per_task = Vec::with_capacity(cal.tasks.len());
    let mut max_rel_err: Option<f64> = None;
    let mut observed_makespan: Option<f64> = None;
    for (i, t) in cal.tasks.iter().enumerate() {
        let predicted = wa.analyses[i].finish_time;
        let observed = t.observed_complete;
        if let Some(o) = observed {
            observed_makespan = Some(observed_makespan.unwrap_or(0.0).max(o));
        }
        let rel_err = match (predicted, observed) {
            (Some(p), Some(o)) => Some((p - o).abs() / o.abs().max(1e-9)),
            _ => None,
        };
        if let Some(e) = rel_err {
            max_rel_err = Some(max_rel_err.unwrap_or(0.0).max(e));
        }
        per_task.push(TaskReplay {
            id: t.id.clone(),
            predicted_start: wa.analyses[i].start_time,
            predicted,
            observed,
            rel_err,
        });
    }
    Ok(ReplayReport {
        per_task,
        predicted_makespan: wa.makespan,
        observed_makespan,
        max_rel_err,
        events: wa.events,
        passes: wa.passes,
    })
}

/// Replay validation curves: every task's predicted progress function
/// materialized on a shared time grid — what predicted-vs-observed I/O
/// plots and curve-level validation consume on top of [`replay`]'s scalar
/// completion errors. Runs the same fixpoint analysis as [`replay`]
/// (same options, same pass cap), then one structure-of-arrays batch pass
/// ([`crate::pwfn::BatchPwPoly`]) over all progress curves. Row `i` is
/// `cal.tasks[i]`; each value is bit-for-bit `progress.eval(ts[j])`.
pub fn replay_progress_grid(
    cal: &CalibratedWorkflow,
    opts: &SolverOpts,
    ts: &[f64],
) -> Result<Vec<Vec<f64>>> {
    let wa = analyze_fixpoint(&cal.workflow, opts, 8)
        .map_err(|e| crate::util::error::Error::msg(format!("replay failed: {e}")))?;
    if ts.is_empty() {
        return Ok(vec![Vec::new(); wa.analyses.len()]);
    }
    let curves: Vec<&PwPoly> = wa.analyses.iter().map(|a| &a.progress).collect();
    let flat = crate::pwfn::BatchPwPoly::compile(&curves).eval_scenarios(ts);
    Ok(flat.chunks(ts.len()).map(|row| row.to_vec()).collect())
}

/// The whole pipeline in one call: parse the TSV (and optional I/O log),
/// calibrate every task, assemble the workflow and replay it. This is
/// what the `calibrate` CLI subcommand and the service `calibrate` op
/// wrap.
pub fn calibrate_trace(
    tsv: &str,
    io_log: Option<&str>,
    opts: &CalibrateOpts,
    solver: &SolverOpts,
) -> Result<(CalibratedWorkflow, ReplayReport)> {
    let trace = parse_tsv(tsv)?;
    let series = match io_log {
        Some(text) => parse_io_log(text)?,
        None => vec![],
    };
    let tasks = calibrate(&trace, &series, opts)?;
    let cal = assemble(tasks)?;
    let report = replay(&cal, solver)?;
    Ok((cal, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workflow::graph::DataSource;

    const CHAIN: &str = "task_id\tdeps\tstart\tcomplete\trealtime\tpcpu\trchar\twchar\tpeak_rss\n\
        dl\t-\t0\t10\t10\t1e9\t1e8\t1e8\t2e6\n\
        enc\tdl\t0\t20\t20\t100\t1e8\t5e7\t8e6\n\
        mux\tdl,enc\t20\t23\t3\t100\t1.5e8\t1.5e8\t1.4e8\n";

    #[test]
    fn chain_assembles_with_expected_wiring() {
        let (cal, _) = calibrate_trace(
            CHAIN,
            None,
            &CalibrateOpts::default(),
            &SolverOpts::default(),
        )
        .unwrap();
        let wf = &cal.workflow;
        assert_eq!(wf.nodes.len(), 3);
        // enc is pipelined onto dl
        assert!(matches!(
            wf.nodes[1].data_sources[0],
            DataSource::ProcessOutput { node: 0, output: 0 }
        ));
        assert!(wf.nodes[1].start.after.is_empty());
        // mux has two deps: barrier wiring, staged input
        assert!(matches!(wf.nodes[2].data_sources[0], DataSource::External(_)));
        assert_eq!(wf.nodes[2].start.after, vec![0, 1]);
    }

    #[test]
    fn consistent_summary_trace_replays_exactly() {
        let (_, report) = calibrate_trace(
            CHAIN,
            None,
            &CalibrateOpts::default(),
            &SolverOpts::default(),
        )
        .unwrap();
        let max = report.max_rel_err.unwrap();
        assert!(max < 0.005, "max rel err {max}: {:?}", report.per_task);
        let m = report.predicted_makespan.unwrap();
        assert!((m - 23.0).abs() < 0.1, "{m}");
        assert_eq!(report.observed_makespan, Some(23.0));
        assert_eq!(report.per_task.len(), 3);
        // barrier start is predicted, not copied from the trace
        assert!((report.per_task[2].predicted_start - 20.0).abs() < 0.1);
    }

    #[test]
    fn series_trace_replays_exactly() {
        // enc gets a full I/O series: resource-limited at 2.5e6 B/s while
        // input arrives at 1e7 B/s (buffered reads)
        let mut log = String::from("# task t read written\n");
        for i in 0..=20 {
            let t = i as f64;
            log.push_str(&format!(
                "enc\t{t}\t{}\t{}\n",
                (1e7 * t).min(1e8),
                2.5e6 * t
            ));
        }
        let (cal, report) = calibrate_trace(
            CHAIN,
            Some(&log),
            &CalibrateOpts::default(),
            &SolverOpts::default(),
        )
        .unwrap();
        assert_eq!(
            cal.tasks[1].source,
            crate::trace::calibrate::ModelSource::Series
        );
        let max = report.max_rel_err.unwrap();
        assert!(max < 0.01, "max rel err {max}: {:?}", report.per_task);
    }

    /// The replay curve surface goes through the SoA batch backend: rows
    /// align with tasks, values are bit-for-bit the scalar progress eval,
    /// and every curve is done once its own predicted finish is on the grid.
    #[test]
    fn replay_progress_grid_matches_scalar_and_completes() {
        let (cal, report) = calibrate_trace(
            CHAIN,
            None,
            &CalibrateOpts::default(),
            &SolverOpts::default(),
        )
        .unwrap();
        let opts = SolverOpts::default();
        let wa = analyze_fixpoint(&cal.workflow, &opts, 8).unwrap();
        // a grid stretching past every predicted finish time
        let span = wa
            .analyses
            .iter()
            .filter_map(|a| a.finish_time)
            .fold(0.0_f64, f64::max)
            + 1.0;
        let ts: Vec<f64> = (0..=64).map(|i| span * i as f64 / 64.0).collect();
        let rows = replay_progress_grid(&cal, &opts, &ts).unwrap();
        assert_eq!(rows.len(), cal.tasks.len());
        for (a, row) in wa.analyses.iter().zip(&rows) {
            for (&t, &v) in ts.iter().zip(row) {
                assert_eq!(v.to_bits(), a.progress.eval(t).to_bits());
            }
            // the grid's end is past every finish: each curve is done there
            let end = *row.last().unwrap();
            assert!((end - a.max_progress).abs() < 1e-6 * a.max_progress.max(1.0));
        }
        assert!(report.max_rel_err.unwrap() < 0.005);
        // empty grid: one empty row per task
        let empty = replay_progress_grid(&cal, &opts, &[]).unwrap();
        assert!(empty.len() == 3 && empty.iter().all(|r| r.is_empty()));
    }

    #[test]
    fn oversized_read_falls_back_to_barrier() {
        // enc reads 2e8 but its only dep wrote 1e8: cannot be pipelined
        let tsv = "task_id\tdeps\tstart\tcomplete\trealtime\tpcpu\trchar\twchar\tpeak_rss\n\
            dl\t-\t0\t10\t10\t1e9\t1e8\t1e8\t2e6\n\
            enc\tdl\t10\t30\t20\t100\t2e8\t5e7\t8e6\n";
        let (cal, report) = calibrate_trace(
            tsv,
            None,
            &CalibrateOpts::default(),
            &SolverOpts::default(),
        )
        .unwrap();
        assert!(matches!(
            cal.workflow.nodes[1].data_sources[0],
            DataSource::External(_)
        ));
        assert_eq!(cal.workflow.nodes[1].start.after, vec![0]);
        // barrier start at 10, 20 s of cpu => completes at 30, as observed
        assert!(report.max_rel_err.unwrap() < 0.005, "{:?}", report.per_task);
    }

    /// A root task that sat in a queue until t=100 must not register its
    /// submit delay as replay error: its start is exogenous and honored.
    #[test]
    fn delayed_root_start_is_honored() {
        // the child is burst-shaped (peak_rss ≈ rchar): it observedly ran
        // staged, 110 → 130, which the burst data gate reproduces
        let tsv = "task_id\tdeps\tstart\tcomplete\trealtime\tpcpu\trchar\twchar\tpeak_rss\n\
            late\t-\t100\t110\t10\t100\t1e8\t1e8\t0\n\
            child\tlate\t110\t130\t20\t100\t1e8\t5e7\t9e7\n";
        let (cal, report) = calibrate_trace(
            tsv,
            None,
            &CalibrateOpts::default(),
            &SolverOpts::default(),
        )
        .unwrap();
        assert!((cal.workflow.nodes[0].start.at - 100.0).abs() < 1e-9);
        // the child's start stays a prediction (data-gated, not copied)
        assert!((cal.workflow.nodes[1].start.at).abs() < 1e-9);
        assert!(
            report.max_rel_err.unwrap() < 0.005,
            "{:?}",
            report.per_task
        );
        assert!((report.predicted_makespan.unwrap() - 130.0).abs() < 0.1);
    }

    /// TSV rchar and the I/O series can disagree (different monitors);
    /// the staged input must cover the fitted R_D's domain or the replay
    /// would starve forever.
    #[test]
    fn staged_input_covers_series_read_total() {
        let tsv = "task_id\tdeps\tstart\tcomplete\trealtime\tpcpu\trchar\twchar\tpeak_rss\n\
            a\t-\t0\t5\t5\t100\t4e7\t5e7\t0\n";
        let log = "a 0 5e7 0\na 2.5 5e7 2.5e7\na 5 5e7 5e7\n";
        let (cal, report) = calibrate_trace(
            tsv,
            Some(log),
            &CalibrateOpts::default(),
            &SolverOpts::default(),
        )
        .unwrap();
        assert_eq!(cal.tasks[0].rchar, 5e7);
        assert!(report.max_rel_err.unwrap() < 0.005, "{:?}", report.per_task);
    }

    #[test]
    fn cycle_reported() {
        let tsv = "task_id\tdeps\trealtime\trchar\twchar\na\tb\t1\t1\t1\nb\ta\t1\t1\t1\n";
        let e = calibrate_trace(
            tsv,
            None,
            &CalibrateOpts::default(),
            &SolverOpts::default(),
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("cycle"), "{e}");
    }
}
