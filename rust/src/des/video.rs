//! Fig 5 workflow adapted to the DES baseline (§6 comparison setup).
//!
//! Per the paper, the asymmetric link split cannot be expressed in WRENCH,
//! so the comparison uses the 50:50 fair-sharing case: both downloads are
//! concurrent transfers on the fairly-shared link. Tasks are non-streaming
//! execution units (task 2 starts only after its download completes —
//! WRENCH's model, less accurate than BottleMod's, as the paper notes).

use crate::workflow::scenario::VideoScenario;

use super::engine::{DesResult, DesTask, DesWorkflow, Platform, simulate};

/// File ids in the DES rendition of Fig 5.
pub mod files {
    pub const REMOTE_VIDEO_T1: usize = 0;
    pub const REMOTE_VIDEO_T2: usize = 1;
    pub const T1_OUT: usize = 2;
    pub const T2_OUT: usize = 3;
    pub const RESULT: usize = 4;
}

/// Build the DES workflow + platform for a given scenario and chunk size.
pub fn build(sc: &VideoScenario, chunk: f64) -> (DesWorkflow, Platform) {
    let wf = DesWorkflow {
        tasks: vec![
            DesTask {
                name: "task1-reverse".into(),
                inputs: vec![(files::REMOTE_VIDEO_T1, true)],
                // WRENCH sees the whole local execution as compute
                compute_seconds: sc.t1_decode_cpu + sc.t1_cpu,
                outputs: vec![(files::T1_OUT, sc.t1_output, false)],
                deps: vec![],
            },
            DesTask {
                name: "task2-rotate".into(),
                inputs: vec![(files::REMOTE_VIDEO_T2, true)],
                compute_seconds: sc.t2_time,
                outputs: vec![(files::T2_OUT, sc.input_size, false)],
                deps: vec![],
            },
            DesTask {
                name: "task3-mux".into(),
                inputs: vec![(files::T1_OUT, false), (files::T2_OUT, false)],
                compute_seconds: sc.t3_time,
                outputs: vec![(files::RESULT, sc.input_size + sc.t1_output, false)],
                deps: vec![0, 1],
            },
        ],
        file_sizes: vec![
            sc.input_size,
            sc.input_size,
            sc.t1_output,
            sc.input_size,
            sc.input_size + sc.t1_output,
        ],
    };
    let platform = Platform {
        link_bw: sc.link_rate,
        disk_bw: 40.0 * sc.link_rate, // fast local disk, like the ramdisk rig
        chunk,
    };
    (wf, platform)
}

/// Run the DES on the Fig 5 scenario; `chunk` defaults to 1 MB (a typical
/// packet-batch/IO granularity for workflow DES tools).
pub fn run(sc: &VideoScenario, chunk: f64) -> DesResult {
    let (wf, platform) = build(sc, chunk);
    simulate(&wf, &platform)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn des_5050_shape() {
        let sc = VideoScenario::default();
        let r = run(&sc, 1e6);
        // fair share: both downloads ≈ 178 s; task1 + 108 s ≈ 286;
        // writes & task3 add a few seconds
        assert!(
            (280.0..300.0).contains(&r.makespan),
            "makespan {}",
            r.makespan
        );
        // DES (no streaming) is *slower* than the streaming-aware
        // BottleMod prediction (263 s) — the model-fidelity gap the paper
        // describes
        assert!(r.makespan > 270.0);
    }

    #[test]
    fn des_events_scale_with_input() {
        let e1 = run(&VideoScenario::default(), 1e6).events;
        let sc100 = VideoScenario::default().with_input_size(10e9);
        let e10 = run(&sc100, 1e6).events;
        assert!(e10 > 5 * e1, "{e1} -> {e10}");
    }
}
