//! A WRENCH/SimGrid-like chunk-level discrete-event workflow simulator.
//!
//! This is the §6 comparison baseline, faithful to the properties the paper
//! ascribes to WRENCH:
//!
//! * tasks are **independent execution units** — a task starts only when all
//!   of its input files are fully staged (no data streaming, no pipelined
//!   execution);
//! * file transfers and disk I/O are simulated chunk by chunk, so the event
//!   count — and therefore the simulation cost — **scales with the amount
//!   of data moved** (the paper: "WRENCH simulates more disk reads and
//!   network packet traffic for a larger file");
//! * network links are **fairly shared** among concurrent transfers (the
//!   paper: "WRENCH can only simulate fairly shared links").
//!
//! The per-chunk rate is fixed when the chunk is scheduled
//! (`bandwidth / active_transfers`), a standard DES approximation.
//!
//! # Invariants
//!
//! * The event queue is a min-heap on `(time, sequence)`; ties resolve by
//!   insertion sequence, so a run is **deterministic** for a given
//!   workload and chunking — required for the §6 comparison tables to be
//!   reproducible.
//! * A task's compute begins only after *all* of its input files are fully
//!   staged (the WRENCH "independent execution units" property); outputs
//!   materialize atomically at completion.
//!
//! # Cost model
//!
//! Every transferred chunk is ≥ 1 heap event, so simulating `B` bytes at
//! chunk size `c` costs `Θ(B/c · log q)` (`q` = queue length) — the cost
//! **scales with data volume**. This is the deliberate foil to
//! [`crate::solver::exact`], whose cost depends on model complexity only:
//! the pair quantifies the paper's §6 speed claim (BottleMod flat,
//! DES linear in bytes).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Identifies a file in the simulated storage fabric.
pub type FileId = usize;
/// Identifies a task.
pub type TaskId = usize;

/// A simulated task (WRENCH-style: inputs, flops, outputs).
#[derive(Clone, Debug)]
pub struct DesTask {
    pub name: String,
    /// Input files that must be staged to the execution host first.
    /// `(file, over_network)`: network inputs share the link; local ones
    /// the disk.
    pub inputs: Vec<(FileId, bool)>,
    /// Seconds of compute at speed 1 (flops normalized).
    pub compute_seconds: f64,
    /// Output files produced at completion `(file, bytes, over_network)`.
    pub outputs: Vec<(FileId, f64, bool)>,
    /// Tasks that must complete before this one may start (control deps,
    /// in addition to file availability).
    pub deps: Vec<TaskId>,
}

/// The simulated platform.
#[derive(Clone, Debug)]
pub struct Platform {
    /// Shared network link bandwidth (bytes/s).
    pub link_bw: f64,
    /// Local disk bandwidth (bytes/s).
    pub disk_bw: f64,
    /// Transfer/IO chunk size in bytes — the DES granularity knob.
    pub chunk: f64,
}

/// A workflow instance for the DES.
#[derive(Clone, Debug, Default)]
pub struct DesWorkflow {
    pub tasks: Vec<DesTask>,
    /// Initial sizes of pre-existing (remote) files; files produced by
    /// tasks get their size from the producing task's outputs.
    pub file_sizes: Vec<f64>,
}

/// Simulation outcome + cost accounting.
#[derive(Clone, Debug)]
pub struct DesResult {
    /// Completion time per task.
    pub finish: Vec<f64>,
    pub makespan: f64,
    /// Number of discrete events processed (scales with bytes/chunk).
    pub events: usize,
}

#[derive(Debug, Clone, PartialEq)]
enum Ev {
    /// One chunk of transfer `tid` arrived.
    Chunk { transfer: usize },
    /// Task compute finished.
    ComputeDone { task: TaskId },
}

#[derive(Debug, Clone)]
struct Transfer {
    file: FileId,
    remaining: f64,
    over_network: bool,
    /// tasks waiting for this file at the execution site
    done: bool,
}

/// Priority-queue entry ordered by time then sequence number.
#[derive(Debug, Clone, PartialEq)]
struct QEntry {
    t: f64,
    seq: usize,
    ev: Ev,
}
impl Eq for QEntry {}
impl Ord for QEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.t
            .partial_cmp(&other.t)
            .unwrap()
            .then(self.seq.cmp(&other.seq))
    }
}
impl PartialOrd for QEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Run the discrete-event simulation.
pub fn simulate(wf: &DesWorkflow, platform: &Platform) -> DesResult {
    let n = wf.tasks.len();
    let mut queue: BinaryHeap<Reverse<QEntry>> = BinaryHeap::new();
    let mut seq = 0usize;
    let mut events = 0usize;

    let n_files = wf.file_sizes.len();
    // a file is "staged" when fully transferred to the execution site
    let mut staged = vec![false; n_files];
    let mut transfers: Vec<Transfer> = vec![];
    let mut active_net = 0usize;
    let mut active_disk = 0usize;

    let mut started = vec![false; n];
    let mut finished: Vec<Option<f64>> = vec![None; n];

    let push = |queue: &mut BinaryHeap<Reverse<QEntry>>, seq: &mut usize, t: f64, ev: Ev| {
        *seq += 1;
        queue.push(Reverse(QEntry { t, seq: *seq, ev }));
    };

    // kick off transfers for all pre-existing files any task needs
    let mut t_now = 0.0f64;

    // helper closures are awkward with borrows; use macros-by-hand below.

    // initial transfers: every network/disk input of every task whose file
    // pre-exists (size > 0 in file_sizes and no producing task)
    let produced_by: Vec<Option<TaskId>> = {
        let mut p = vec![None; n_files];
        for (ti, task) in wf.tasks.iter().enumerate() {
            for (f, _, _) in &task.outputs {
                p[*f] = Some(ti);
            }
        }
        p
    };

    // start a transfer for (file, over_network) if not already moving
    macro_rules! start_transfer {
        ($file:expr, $net:expr, $t:expr) => {{
            let file = $file;
            let net = $net;
            if !staged[file] && !transfers.iter().any(|tr| tr.file == file && !tr.done) {
                transfers.push(Transfer {
                    file,
                    remaining: wf.file_sizes[file],
                    over_network: net,
                    done: false,
                });
                let id = transfers.len() - 1;
                if net {
                    active_net += 1;
                } else {
                    active_disk += 1;
                }
                let share = if net {
                    platform.link_bw / active_net.max(1) as f64
                } else {
                    platform.disk_bw / active_disk.max(1) as f64
                };
                let chunk = platform.chunk.min(transfers[id].remaining).max(1.0);
                push(&mut queue, &mut seq, $t + chunk / share, Ev::Chunk { transfer: id });
            }
        }};
    }

    macro_rules! try_start_tasks {
        ($t:expr) => {{
            for ti in 0..n {
                if started[ti] || finished[ti].is_some() {
                    continue;
                }
                let task = &wf.tasks[ti];
                let deps_ok = task.deps.iter().all(|&d| finished[d].is_some());
                if !deps_ok {
                    continue;
                }
                let inputs_ok = task.inputs.iter().all(|(f, _)| staged[*f]);
                if inputs_ok {
                    started[ti] = true;
                    push(
                        &mut queue,
                        &mut seq,
                        $t + task.compute_seconds,
                        Ev::ComputeDone { task: ti },
                    );
                } else {
                    // request transfers for available but unstaged inputs
                    for (f, net) in &task.inputs {
                        let available = produced_by[*f]
                            .map(|p| finished[p].is_some())
                            .unwrap_or(true);
                        if available {
                            start_transfer!(*f, *net, $t);
                        }
                    }
                }
            }
        }};
    }

    try_start_tasks!(0.0);

    while let Some(Reverse(QEntry { t, ev, .. })) = queue.pop() {
        events += 1;
        t_now = t;
        match ev {
            Ev::Chunk { transfer } => {
                let share_next;
                {
                    let tr = &mut transfers[transfer];
                    let chunk = platform.chunk.min(tr.remaining).max(1.0);
                    tr.remaining -= chunk;
                    if tr.remaining <= 0.5 {
                        tr.done = true;
                        staged[tr.file] = true;
                        if tr.over_network {
                            active_net -= 1;
                        } else {
                            active_disk -= 1;
                        }
                        share_next = None;
                    } else {
                        let share = if tr.over_network {
                            platform.link_bw / active_net.max(1) as f64
                        } else {
                            platform.disk_bw / active_disk.max(1) as f64
                        };
                        let next_chunk = platform.chunk.min(tr.remaining).max(1.0);
                        share_next = Some(next_chunk / share);
                    }
                }
                match share_next {
                    Some(dt) => {
                        push(&mut queue, &mut seq, t + dt, Ev::Chunk { transfer })
                    }
                    None => try_start_tasks!(t),
                }
            }
            Ev::ComputeDone { task } => {
                // write outputs chunk-by-chunk to disk: modeled as a single
                // sequence of chunk events via a transfer over the disk
                finished[task] = Some(t);
                for (f, size, net) in &wf.tasks[task].outputs {
                    // producing a file stages it locally after disk writes;
                    // simulate the write as a disk transfer
                    let fidx = *f;
                    // set the size now that it exists
                    // (file_sizes holds pre-sizes; outputs define theirs)
                    let _ = size;
                    let _ = net;
                    staged[fidx] = false;
                    start_transfer!(fidx, *net, t);
                }
                try_start_tasks!(t);
            }
        }
    }

    let finish: Vec<f64> = finished
        .into_iter()
        .map(|f| f.unwrap_or(t_now))
        .collect();
    let makespan = finish.iter().copied().fold(0.0f64, f64::max);
    DesResult {
        finish,
        makespan,
        events,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn platform(chunk: f64) -> Platform {
        Platform {
            link_bw: 10.0,
            disk_bw: 100.0,
            chunk,
        }
    }

    /// single task, one network input: transfer then compute.
    #[test]
    fn single_task_transfer_then_compute() {
        let wf = DesWorkflow {
            tasks: vec![DesTask {
                name: "t".into(),
                inputs: vec![(0, true)],
                compute_seconds: 5.0,
                outputs: vec![],
                deps: vec![],
            }],
            file_sizes: vec![100.0],
        };
        let r = simulate(&wf, &platform(10.0));
        // 100 B at 10 B/s = 10 s + 5 s compute
        assert!((r.makespan - 15.0).abs() < 1e-6, "{}", r.makespan);
        assert!(r.events >= 11);
    }

    /// event count scales with file size (the §6 property).
    #[test]
    fn events_scale_with_bytes() {
        let mk = |size: f64| DesWorkflow {
            tasks: vec![DesTask {
                name: "t".into(),
                inputs: vec![(0, true)],
                compute_seconds: 1.0,
                outputs: vec![],
                deps: vec![],
            }],
            file_sizes: vec![size],
        };
        let e1 = simulate(&mk(100.0), &platform(1.0)).events;
        let e10 = simulate(&mk(1000.0), &platform(1.0)).events;
        assert!(e10 > 8 * e1, "events {e1} -> {e10}");
    }

    /// two concurrent transfers fair-share the link.
    #[test]
    fn fair_sharing() {
        let wf = DesWorkflow {
            tasks: vec![
                DesTask {
                    name: "a".into(),
                    inputs: vec![(0, true)],
                    compute_seconds: 0.0,
                    outputs: vec![],
                    deps: vec![],
                },
                DesTask {
                    name: "b".into(),
                    inputs: vec![(1, true)],
                    compute_seconds: 0.0,
                    outputs: vec![],
                    deps: vec![],
                },
            ],
            file_sizes: vec![100.0, 100.0],
        };
        let r = simulate(&wf, &platform(1.0));
        // both share 10 B/s -> 5 each -> both done ≈ 20 s
        assert!((r.makespan - 20.0).abs() < 1.0, "{}", r.makespan);
    }

    /// a dependent task starts only after its producer wrote the output
    /// (no streaming — unlike BottleMod).
    #[test]
    fn no_streaming_serialization() {
        let wf = DesWorkflow {
            tasks: vec![
                DesTask {
                    name: "producer".into(),
                    inputs: vec![(0, true)],
                    compute_seconds: 2.0,
                    outputs: vec![(1, 50.0, false)],
                    deps: vec![],
                },
                DesTask {
                    name: "consumer".into(),
                    inputs: vec![(1, false)],
                    compute_seconds: 1.0,
                    outputs: vec![],
                    deps: vec![0],
                },
            ],
            file_sizes: vec![100.0, 50.0],
        };
        let r = simulate(&wf, &platform(5.0));
        // transfer 10 s + compute 2 s + disk write 0.5 s + compute 1 s
        assert!((r.makespan - 13.5).abs() < 0.1, "{}", r.makespan);
        assert!(r.finish[1] > r.finish[0]);
    }
}
