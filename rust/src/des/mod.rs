//! WRENCH-like discrete-event baseline for the §6 performance comparison.

pub mod engine;
pub mod video;

pub use engine::{simulate, DesResult, DesTask, DesWorkflow, Platform};
