//! BottleMod: modeling data flows and tasks for fast bottleneck analysis.
//!
//! A reproduction of Lößer et al., *"BottleMod: Modeling Data Flows and
//! Tasks for Fast Bottleneck Analysis"* (2022), built as a three-layer
//! Rust + JAX + Pallas stack. See DESIGN.md for the architecture and the
//! per-experiment index.

pub mod api;
pub mod coordinator;
pub mod des;
pub mod live;
pub mod model;
pub mod pwfn;
pub mod runtime;
pub mod sched;
pub mod sense;
pub mod solver;
pub mod trace;
pub mod workflow;
pub mod testbed;
pub mod util;
