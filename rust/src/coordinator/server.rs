//! Multi-session socket serving: line-delimited v1 protocol over TCP or a
//! Unix socket, multiplexed onto one shared worker pool.
//!
//! `bottlemod serve` historically spoke to exactly one client over stdio.
//! A [`Server`] keeps that protocol byte-for-byte identical but accepts
//! many concurrent connections (`std::net` only — no new dependencies):
//!
//! * every connection is a **session**: its own thread, its own
//!   [`ApiHandler`] and its own quota-bounded [`AnalysisCache`], so one
//!   tenant's working set can neither read nor evict another's;
//! * all sessions submit to one shared [`Coordinator`] pool through its
//!   bounded queue — when the queue is full the session answers with a
//!   structured `overloaded` error immediately (admission control: the
//!   client gets a retryable signal, never a hang, and the server never
//!   buffers without bound);
//! * responses are written and flushed in request order per session —
//!   each session pairs every submission with a dedicated reply channel,
//!   so concurrent sessions cannot interleave each other's results;
//! * [`Server::shutdown`] drains gracefully: stop accepting, let every
//!   session finish its in-flight request and flush the response, then
//!   join the pool's workers.
//!
//! Wire reference: `docs/SERVICE.md` ("Transports" section).

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::api::{ApiHandler, ServiceStats};
use crate::runtime::cache::AnalysisCache;
use crate::util::par::num_threads;

use super::service::{Coordinator, DEFAULT_QUEUE_BOUND};

/// How often a blocked accept/read loop wakes to check the stop flag —
/// the upper bound on how long a drain waits for an *idle* connection.
const POLL_INTERVAL: Duration = Duration::from_millis(50);

/// Configuration of a multi-session server.
#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// Worker threads in the shared pool.
    pub threads: usize,
    /// Bound of the pool's submission queue (admission control).
    pub queue_bound: usize,
    /// Per-session cache quota: maximum resident entries.
    pub session_cache_entries: usize,
    /// Per-session cache quota: approximate maximum resident bytes.
    pub session_cache_bytes: u64,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            threads: num_threads(),
            queue_bound: DEFAULT_QUEUE_BOUND,
            session_cache_entries: 1 << 14,
            session_cache_bytes: 256 << 20, // 256 MiB
        }
    }
}

impl ServeOpts {
    fn session_cache(&self) -> Arc<AnalysisCache> {
        Arc::new(AnalysisCache::with_quota(
            self.session_cache_entries.max(1),
            self.session_cache_bytes.max(1),
        ))
    }
}

/// A multi-session analysis server: shared worker pool, one listener
/// thread per bound transport, one thread + quota'd cache per connection.
pub struct Server {
    pool: Arc<Coordinator>,
    opts: ServeOpts,
    stop: Arc<AtomicBool>,
    listeners: Vec<JoinHandle<()>>,
    sessions: Arc<Mutex<Vec<JoinHandle<()>>>>,
    /// One shared counter block for every session — any session's `stats`
    /// op reports whole-server traffic (`docs/SERVICE.md`, "stats").
    stats: Arc<ServiceStats>,
}

impl Server {
    /// A server with its worker pool already running; bind transports
    /// with [`Server::listen_tcp`] / [`Server::listen_unix`].
    pub fn new(opts: ServeOpts) -> Server {
        // the pool's fallback cache (used only by handler-less submits)
        // gets the same quota as a session
        let pool = Arc::new(Coordinator::with_queue_bound(
            opts.threads.max(1),
            opts.session_cache(),
            opts.queue_bound.max(1),
        ));
        Server {
            pool,
            opts,
            stop: Arc::new(AtomicBool::new(false)),
            listeners: Vec::new(),
            sessions: Arc::new(Mutex::new(Vec::new())),
            stats: Arc::new(ServiceStats::new()),
        }
    }

    /// A handler for one additional session (its own quota-bounded cache)
    /// multiplexed onto the shared pool — how the CLI runs its stdio
    /// session next to the socket listeners. Shares the server's global
    /// [`ServiceStats`], but does not count in the session gauges (those
    /// track socket connections).
    pub fn session_handler(&self) -> ApiHandler {
        ApiHandler::for_session_with_stats(
            Arc::clone(&self.pool),
            self.opts.session_cache(),
            Arc::clone(&self.stats),
        )
    }

    /// Bind a TCP listener (e.g. `"127.0.0.1:4700"`, or port `0` to let
    /// the OS pick) and start accepting sessions on a background thread.
    /// Returns the bound address.
    pub fn listen_tcp(&mut self, addr: &str) -> std::io::Result<SocketAddr> {
        let listener = TcpListener::bind(addr)?;
        let bound = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::clone(&self.stop);
        let sessions = Arc::clone(&self.sessions);
        let pool = Arc::clone(&self.pool);
        let opts = self.opts.clone();
        let stats = Arc::clone(&self.stats);
        self.listeners.push(std::thread::spawn(move || {
            while !stop.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let handler = ApiHandler::for_session_with_stats(
                            Arc::clone(&pool),
                            opts.session_cache(),
                            Arc::clone(&stats),
                        );
                        let stop = Arc::clone(&stop);
                        let stats = Arc::clone(&stats);
                        stats.session_opened();
                        let h = std::thread::spawn(move || {
                            serve_tcp_session(handler, stream, stop);
                            stats.session_closed();
                        });
                        register_session(&sessions, h);
                    }
                    // WouldBlock (nothing to accept yet) and transient
                    // accept errors both just wait for the next poll
                    Err(_) => std::thread::sleep(POLL_INTERVAL),
                }
            }
        }));
        Ok(bound)
    }

    /// Bind a Unix-domain socket listener at `path` (removing a stale
    /// socket file first) and start accepting sessions.
    #[cfg(unix)]
    pub fn listen_unix(&mut self, path: &str) -> std::io::Result<()> {
        let _ = std::fs::remove_file(path);
        let listener = UnixListener::bind(path)?;
        listener.set_nonblocking(true)?;
        let stop = Arc::clone(&self.stop);
        let sessions = Arc::clone(&self.sessions);
        let pool = Arc::clone(&self.pool);
        let opts = self.opts.clone();
        let stats = Arc::clone(&self.stats);
        self.listeners.push(std::thread::spawn(move || {
            while !stop.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let handler = ApiHandler::for_session_with_stats(
                            Arc::clone(&pool),
                            opts.session_cache(),
                            Arc::clone(&stats),
                        );
                        let stop = Arc::clone(&stop);
                        let stats = Arc::clone(&stats);
                        stats.session_opened();
                        let h = std::thread::spawn(move || {
                            serve_unix_session(handler, stream, stop);
                            stats.session_closed();
                        });
                        register_session(&sessions, h);
                    }
                    Err(_) => std::thread::sleep(POLL_INTERVAL),
                }
            }
        }));
        Ok(())
    }

    /// Serve until the process dies: block on the listener threads (they
    /// only return after [`Server::shutdown`] flips the stop flag, which
    /// this method never does).
    pub fn join(mut self) {
        for h in self.listeners.drain(..) {
            let _ = h.join();
        }
    }

    /// Graceful drain: stop accepting new connections and new requests,
    /// let every session finish its in-flight request and flush the
    /// response, then join the sessions and (via the last pool reference)
    /// the workers.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        for h in self.listeners.drain(..) {
            let _ = h.join();
        }
        let handles: Vec<JoinHandle<()>> = {
            let mut s = self.sessions.lock().unwrap_or_else(|e| e.into_inner());
            s.drain(..).collect()
        };
        for h in handles {
            let _ = h.join();
        }
        // dropping `self.pool` here closes the queue and joins the
        // workers if this was the last reference
    }
}

/// Track a session thread for the drain join, reaping finished sessions
/// so a long-lived server does not accumulate handles (finished threads
/// detach harmlessly).
fn register_session(sessions: &Mutex<Vec<JoinHandle<()>>>, handle: JoinHandle<()>) {
    let mut s = sessions.lock().unwrap_or_else(|e| e.into_inner());
    s.retain(|h| !h.is_finished());
    s.push(handle);
}

fn serve_tcp_session(handler: ApiHandler, stream: TcpStream, stop: Arc<AtomicBool>) {
    // accepted sockets may inherit the listener's non-blocking mode;
    // normalize to blocking-with-timeout so the pump wakes for drains
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(POLL_INTERVAL));
    let reader = match stream.try_clone() {
        Ok(s) => BufReader::new(s),
        Err(_) => return,
    };
    let mut writer = stream;
    pump_session(&handler, reader, &mut writer, &stop);
}

#[cfg(unix)]
fn serve_unix_session(handler: ApiHandler, stream: UnixStream, stop: Arc<AtomicBool>) {
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(POLL_INTERVAL));
    let reader = match stream.try_clone() {
        Ok(s) => BufReader::new(s),
        Err(_) => return,
    };
    let mut writer = stream;
    pump_session(&handler, reader, &mut writer, &stop);
}

/// Per-connection request/response loop: one JSON request per line in,
/// one response per line out — written and flushed before the next read,
/// which both guarantees per-session response ordering and keeps
/// block-buffered clients from deadlocking. Returns on EOF, a write
/// failure, or a drain (the in-flight request still completes and its
/// response is flushed).
fn pump_session(
    handler: &ApiHandler,
    mut input: impl BufRead,
    output: &mut impl Write,
    stop: &AtomicBool,
) {
    let mut raw: Vec<u8> = Vec::new();
    'serve: loop {
        raw.clear();
        // accumulate one full line, waking on the read timeout to honor
        // the stop flag; partial bytes stay in `raw` across wakeups
        loop {
            if stop.load(Ordering::SeqCst) {
                break 'serve;
            }
            match input.read_until(b'\n', &mut raw) {
                Ok(0) => {
                    if raw.is_empty() {
                        break 'serve; // clean EOF
                    }
                    break; // final unterminated line
                }
                Ok(_) => break,
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock
                            | std::io::ErrorKind::TimedOut
                            | std::io::ErrorKind::Interrupted
                    ) => {}
                Err(_) => break 'serve,
            }
        }
        let text = String::from_utf8_lossy(&raw);
        let line = text.trim();
        if line.is_empty() {
            continue;
        }
        let resp = handler.handle_wire(line);
        let sent = writeln!(output, "{resp}").and_then(|_| output.flush());
        if sent.is_err() {
            break;
        }
    }
    let _ = output.flush();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_opts_defaults_are_sane() {
        let o = ServeOpts::default();
        assert!(o.threads >= 1);
        assert_eq!(o.queue_bound, DEFAULT_QUEUE_BOUND);
        assert!(o.session_cache_entries >= 1);
        assert!(o.session_cache_bytes >= 1 << 20);
    }

    /// Every session handler shares one counter block: traffic sent
    /// through one session is visible to a `stats` query from another.
    #[test]
    fn stats_are_shared_across_sessions() {
        use crate::api::{Request, Response};
        let server = Server::new(ServeOpts {
            threads: 1,
            ..ServeOpts::default()
        });
        let first = server.session_handler();
        first.handle(&Request::Ping).unwrap();
        first.handle(&Request::Ping).unwrap();
        let second = server.session_handler();
        match second.handle(&Request::Stats { mask: false }).unwrap() {
            Response::Stats(s) => {
                assert_eq!(s.ops.get("ping"), Some(&2));
                // stdio-style handlers do not move the socket gauges
                assert_eq!(s.sessions_open, 0);
                assert_eq!(s.sessions_total, 0);
            }
            other => panic!("expected stats, got {other:?}"),
        }
    }

    /// A socket connection counts in the session gauges, and a `stats`
    /// request over the wire reports it.
    #[test]
    fn tcp_sessions_count_in_stats() {
        let mut server = Server::new(ServeOpts {
            threads: 1,
            ..ServeOpts::default()
        });
        let addr = server.listen_tcp("127.0.0.1:0").unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        writeln!(client, r#"{{"v": 1, "id": 1, "op": "stats"}}"#).unwrap();
        let mut reader = BufReader::new(client.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains(r#""sessions_open":1"#), "line: {line}");
        assert!(line.contains(r#""sessions_total":1"#), "line: {line}");
        drop(reader);
        drop(client);
        server.shutdown();
    }

    /// The session pump honors the drain flag even while a client holds
    /// the connection open without sending anything.
    #[test]
    fn tcp_session_drains_while_idle() {
        let mut server = Server::new(ServeOpts {
            threads: 1,
            ..ServeOpts::default()
        });
        let addr = server.listen_tcp("127.0.0.1:0").unwrap();
        let client = TcpStream::connect(addr).unwrap();
        // give the accept loop a moment to spawn the session
        std::thread::sleep(Duration::from_millis(100));
        server.shutdown(); // must not hang on the idle connection
        drop(client);
    }
}
