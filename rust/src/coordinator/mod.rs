//! L3 coordination: the batched scenario sweeps, the analysis service
//! (worker pool, stdio pump, multi-session socket server), and the
//! figure/table exporters that regenerate the paper's evaluation.

pub mod exporter;
pub mod server;
pub mod service;
pub mod sweeper;

pub use server::{ServeOpts, Server};
pub use service::{Coordinator, Job, JobResult};
pub use sweeper::{
    best_fraction, exact_sweep, exact_sweep_report, fig7_fractions, ExactSweep,
};
