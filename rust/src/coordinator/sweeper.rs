//! Sweep orchestration over candidate resource allocations.
//!
//! The Fig 7 experiment evaluates the Fig 5 workflow for 600 different link
//! prioritizations. The heavy lifting lives in the batched scenario-sweep
//! engine ([`crate::runtime::sweep::SweepBatch`]); this module keeps the
//! fraction-sweep convenience API the advisor, exporter and CLI consume:
//!
//! * [`exact_sweep`] — the event-driven exact solver fanned out over the
//!   scoped-thread pool, one scenario per link fraction;
//! * [`crate::runtime::fig7_sweep`] — the batched PJRT path (L2 grid
//!   solver), used when an approximate but fused evaluation is preferred
//!   and the XLA backend is compiled in.

use std::sync::Arc;

pub use crate::runtime::cache::{AnalysisCache, CacheStats};
pub use crate::runtime::sweep::{
    BottleneckReport, FixedWorkflow, RankedBottleneck, ScenarioOutcome, SweepBatch, SweepError,
    SweepModel,
};
use crate::workflow::scenario::{Perturbation, VideoScenario};

/// Outcome of an exact fraction sweep (the Fig 7 x/y arrays).
#[derive(Clone, Debug)]
pub struct ExactSweep {
    pub fractions: Vec<f64>,
    pub totals: Vec<f64>,
    /// total solver events across all configurations
    pub events: usize,
}

/// Evaluate the scenario's total time for each link fraction on `threads`
/// workers. Results are identical for any thread count (the engine's
/// determinism contract); a scenario that never finishes reports
/// `f64::INFINITY`.
pub fn exact_sweep(sc: &VideoScenario, fractions: &[f64], threads: usize) -> ExactSweep {
    let batch: Vec<Perturbation> = fractions.iter().map(|&f| Perturbation::Fraction(f)).collect();
    let outcomes = SweepBatch::new(Arc::new(sc.clone()))
        .with_threads(threads)
        .run(&batch)
        .expect("sweep analysis");
    ExactSweep {
        fractions: fractions.to_vec(),
        totals: outcomes
            .iter()
            .map(|o| o.makespan.unwrap_or(f64::INFINITY))
            .collect(),
        events: outcomes.iter().map(|o| o.events).sum(),
    }
}

/// Like [`exact_sweep`], but also returning the ranked cross-scenario
/// bottleneck report (what the `bottlemod sweep` CLI prints). Runs
/// incrementally — a fresh [`crate::runtime::cache::AnalysisCache`] is
/// attached, and its statistics land in [`BottleneckReport::cache`]
/// (fraction sweeps share fixpoint re-solves; results are bit-for-bit the
/// cold ones either way).
pub fn exact_sweep_report(
    sc: &VideoScenario,
    fractions: &[f64],
    threads: usize,
) -> (ExactSweep, BottleneckReport) {
    let batch: Vec<Perturbation> = fractions.iter().map(|&f| Perturbation::Fraction(f)).collect();
    let (outcomes, report) = SweepBatch::new(Arc::new(sc.clone()))
        .with_threads(threads)
        .with_new_cache()
        .run_report(&batch)
        .expect("sweep analysis");
    (
        ExactSweep {
            fractions: fractions.to_vec(),
            totals: outcomes
                .iter()
                .map(|o| o.makespan.unwrap_or(f64::INFINITY))
                .collect(),
            events: report.total_events,
        },
        report,
    )
}

/// The standard Fig 7 x-axis: `n` fractions spanning (0, 1).
pub fn fig7_fractions(n: usize) -> Vec<f64> {
    (1..=n).map(|i| i as f64 / (n as f64 + 1.0)).collect()
}

/// Find the best fraction (argmin of total time) — the advisor primitive.
pub fn best_fraction(sweep: &ExactSweep) -> (f64, f64) {
    let mut best = (sweep.fractions[0], sweep.totals[0]);
    for (f, t) in sweep.fractions.iter().zip(sweep.totals.iter()) {
        if *t < best.1 {
            best = (*f, *t);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_parallel_equals_serial() {
        let sc = VideoScenario::default();
        let fr = fig7_fractions(12);
        let par = exact_sweep(&sc, &fr, 4);
        let ser = exact_sweep(&sc, &fr, 1);
        for (a, b) in par.totals.iter().zip(ser.totals.iter()) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
        assert_eq!(par.events, ser.events);
    }

    #[test]
    fn optimum_is_high_fraction() {
        let sc = VideoScenario::default();
        let fr = fig7_fractions(40);
        let sweep = exact_sweep(&sc, &fr, 4);
        let (best_f, best_t) = best_fraction(&sweep);
        // the paper's conclusion: ≥93% is optimal
        assert!(best_f > 0.85, "best fraction {best_f} (t={best_t})");
        // and ≈32% better than 50:50
        let t50 = sweep
            .fractions
            .iter()
            .zip(&sweep.totals)
            .min_by(|a, b| {
                (a.0 - 0.5).abs().partial_cmp(&(b.0 - 0.5).abs()).unwrap()
            })
            .unwrap()
            .1;
        let gain = 1.0 - best_t / t50;
        assert!((0.25..0.40).contains(&gain), "gain {gain}");
    }

    #[test]
    fn report_accompanies_sweep() {
        let sc = VideoScenario::default();
        let (sweep, report) = exact_sweep_report(&sc, &fig7_fractions(8), 4);
        assert_eq!(sweep.totals.len(), 8);
        assert_eq!(report.scenarios, 8);
        assert_eq!(report.total_events, sweep.events);
        assert!(report
            .ranked
            .iter()
            .any(|r| r.bottleneck == "res:link" && r.scenarios == 8));
        // the report path runs incrementally and exposes its cache stats
        let stats = report.cache.expect("cache stats attached");
        assert!(stats.hits + stats.misses > 0);
    }
}
