//! Parallel sweep orchestration over candidate resource allocations.
//!
//! The Fig 7 experiment evaluates the Fig 5 workflow for 600 different link
//! prioritizations. Two engines:
//!
//! * [`exact_sweep`] — the event-driven exact solver, fanned out over a
//!   thread pool (each analysis is independent);
//! * [`crate::runtime::fig7_sweep`] — the batched PJRT path (L2 grid
//!   solver), used when an approximate but fused evaluation is preferred.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::solver::SolverOpts;
use crate::workflow::engine::analyze_fixpoint;
use crate::workflow::scenario::VideoScenario;

/// Outcome of an exact sweep.
#[derive(Clone, Debug)]
pub struct ExactSweep {
    pub fractions: Vec<f64>,
    pub totals: Vec<f64>,
    /// total solver events across all configurations
    pub events: usize,
}

/// Evaluate the scenario's total time for each link fraction, in parallel.
pub fn exact_sweep(sc: &VideoScenario, fractions: &[f64], threads: usize) -> ExactSweep {
    let threads = threads.max(1).min(fractions.len().max(1));
    let totals = vec![0.0f64; fractions.len()];
    let events = AtomicUsize::new(0);
    let next = AtomicUsize::new(0);
    let totals_ptr = std::sync::Mutex::new(totals);

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                let opts = SolverOpts::default();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= fractions.len() {
                        break;
                    }
                    let (wf, _) = sc.clone().with_fraction(fractions[i]).build();
                    let wa = analyze_fixpoint(&wf, &opts, 6).expect("sweep analysis");
                    let total = wa.makespan.unwrap_or(f64::INFINITY);
                    events.fetch_add(wa.events, Ordering::Relaxed);
                    totals_ptr.lock().unwrap()[i] = total;
                }
            });
        }
    });

    ExactSweep {
        fractions: fractions.to_vec(),
        totals: totals_ptr.into_inner().unwrap(),
        events: events.into_inner(),
    }
}

/// The standard Fig 7 x-axis: `n` fractions spanning (0, 1).
pub fn fig7_fractions(n: usize) -> Vec<f64> {
    (1..=n).map(|i| i as f64 / (n as f64 + 1.0)).collect()
}

/// Find the best fraction (argmin of total time) — the advisor primitive.
pub fn best_fraction(sweep: &ExactSweep) -> (f64, f64) {
    let mut best = (sweep.fractions[0], sweep.totals[0]);
    for (f, t) in sweep.fractions.iter().zip(sweep.totals.iter()) {
        if *t < best.1 {
            best = (*f, *t);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_parallel_equals_serial() {
        let sc = VideoScenario::default();
        let fr = fig7_fractions(12);
        let par = exact_sweep(&sc, &fr, 4);
        let ser = exact_sweep(&sc, &fr, 1);
        for (a, b) in par.totals.iter().zip(ser.totals.iter()) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
    }

    #[test]
    fn optimum_is_high_fraction() {
        let sc = VideoScenario::default();
        let fr = fig7_fractions(40);
        let sweep = exact_sweep(&sc, &fr, 4);
        let (best_f, best_t) = best_fraction(&sweep);
        // the paper's conclusion: ≥93% is optimal
        assert!(best_f > 0.85, "best fraction {best_f} (t={best_t})");
        // and ≈32% better than 50:50
        let t50 = sweep
            .fractions
            .iter()
            .zip(&sweep.totals)
            .min_by(|a, b| {
                (a.0 - 0.5).abs().partial_cmp(&(b.0 - 0.5).abs()).unwrap()
            })
            .unwrap()
            .1;
        let gain = 1.0 - best_t / t50;
        assert!((0.25..0.40).contains(&gain), "gain {gain}");
    }
}
