//! Figure/table exporters: regenerate the data behind every figure in the
//! paper's evaluation as JSON documents (one per figure) that any plotting
//! front end can consume. The CLI's `export-figures` subcommand drives
//! this; EXPERIMENTS.md records the headline numbers.

use std::path::Path;
use std::time::Instant;

use crate::util::error::{Context, Result};

use crate::des;
use crate::model::{Process, ProcessBuilder, ProcessInputs};
use crate::pwfn::{BatchPwPoly, Poly, PwPoly};
use crate::solver::{solve, Analysis, Bottleneck, SolverOpts};
use crate::testbed::video::VideoTestbed;
use crate::util::stats::Summary;
use crate::util::Json;
use crate::workflow::engine::analyze_fixpoint;
use crate::workflow::scenario::VideoScenario;

use super::sweeper::{exact_sweep, fig7_fractions};

fn grid(a: f64, b: f64, n: usize) -> Vec<f64> {
    (0..n).map(|i| a + (b - a) * i as f64 / (n - 1) as f64).collect()
}

fn write_json(dir: &Path, name: &str, j: &Json) -> Result<()> {
    let path = dir.join(name);
    std::fs::write(&path, j.to_string_pretty()).with_context(|| format!("writing {path:?}"))?;
    println!("wrote {}", path.display());
    Ok(())
}

/// Fig 1: the canonical stream/burst requirement shapes.
pub fn fig1(dir: &Path) -> Result<()> {
    let xs = grid(0.0, 100.0, 101);
    let data_stream = PwPoly::ramp_to(0.0, 1.0, 100.0);
    let data_burst = PwPoly::step(0.0, 100.0, 0.0, 100.0);
    let res_stream = PwPoly::linear_from(0.0, 0.0, 0.5);
    let res_burst = PwPoly::new(
        vec![0.0, 1e-9, f64::INFINITY],
        vec![Poly::constant(0.0), Poly::constant(50.0)],
    );
    let j = Json::obj(vec![
        ("x", Json::arr_f64(&xs)),
        ("data_stream", Json::arr_f64(&data_stream.sample(&xs))),
        ("data_burst", Json::arr_f64(&data_burst.sample(&xs))),
        ("resource_stream", Json::arr_f64(&res_stream.sample(&xs))),
        ("resource_burst", Json::arr_f64(&res_burst.sample(&xs))),
    ]);
    write_json(dir, "fig1_requirement_functions.json", &j)
}

/// The synthetic three-input / three-resource process behind Figs 3 and 4.
pub fn paper_example() -> (Process, ProcessInputs) {
    let p = ProcessBuilder::new("example", 100.0)
        // all three data requirements are stream-type over 100 units
        .stream_data("data0", 100.0)
        .stream_data("data1", 100.0)
        .stream_data("data2", 100.0)
        // res0: constant cost, ample allocation
        .stream_resource("res0", 50.0)
        // res1: piecewise-linear cost (cheap early, expensive late)
        .res_req_fn(
            "res1",
            PwPoly::from_points(&[(0.0, 0.0), (60.0, 30.0), (100.0, 90.0)]),
        )
        // res2: constant cost
        .stream_resource("res2", 40.0)
        .identity_output("out")
        .build();
    let inputs = ProcessInputs {
        data: vec![
            // data0: linear availability
            PwPoly::ramp_to(0.0, 2.0, 100.0),
            // data1: 20% available up front, the rest arrives at t=30
            PwPoly::new(
                vec![0.0, 30.0, f64::INFINITY],
                vec![Poly::constant(20.0), Poly::constant(100.0)],
            ),
            // data2: quadratic availability t^2/25 (complete at t=50)
            PwPoly::new(
                vec![0.0, 50.0, f64::INFINITY],
                vec![Poly::new(vec![0.0, 0.0, 0.04]), Poly::constant(100.0)],
            ),
        ],
        resources: vec![
            PwPoly::constant(1.2),
            // res1 allocation drops midway
            PwPoly::step(0.0, 35.0, 1.5, 0.45),
            PwPoly::constant(1.1),
        ],
        start_time: 0.0,
    };
    (p, inputs)
}

fn bottleneck_label(p: &Process, a: &Analysis, b: Bottleneck) -> Json {
    Json::Str(a.bottleneck_name(p, b))
}

/// Fig 3: data progress functions + min-envelope + limiting input.
pub fn fig3(dir: &Path) -> Result<()> {
    let (p, inputs) = paper_example();
    let a = solve(&p, &inputs, &SolverOpts::default())?;
    let ts = grid(0.0, 60.0, 241);
    let mut obj = vec![("t", Json::arr_f64(&ts))];
    let names = ["data0", "data1", "data2"];
    // all data-progress curves + the min-envelope share one grid: one SoA
    // batch compile, one merged pass per curve (bit-for-bit the scalar
    // per-point sample)
    let mut curves: Vec<&PwPoly> = a.data_progress.iter().collect();
    curves.push(&a.pd.func);
    let flat = BatchPwPoly::compile(&curves).eval_scenarios(&ts);
    let mut rows = flat.chunks(ts.len());
    for (&name, _) in names.iter().zip(&a.data_progress) {
        obj.push((name, Json::arr_f64(rows.next().unwrap())));
    }
    obj.push(("envelope", Json::arr_f64(rows.next().unwrap())));
    let segs: Vec<Json> = a
        .pd
        .segments()
        .into_iter()
        .map(|(s, e, w)| {
            Json::obj(vec![
                ("start", Json::Num(s)),
                ("end", Json::Num(if e.is_finite() { e } else { 60.0 })),
                ("limiting_input", Json::Str(names[w].to_string())),
            ])
        })
        .collect();
    obj.push(("limiting_segments", Json::Arr(segs)));
    write_json(dir, "fig3_data_progress.json", &Json::obj(obj))
}

/// Fig 4: final progress with bottleneck attribution, resource consumption
/// vs allocation, and buffered input data.
pub fn fig4(dir: &Path) -> Result<()> {
    let (p, inputs) = paper_example();
    let a = solve(&p, &inputs, &SolverOpts::default())?;
    let tmax = a.finish_time.unwrap_or(80.0) + 5.0;
    let ts = grid(0.0, tmax, 321);

    let mut obj = vec![
        ("t", Json::arr_f64(&ts)),
        ("progress", Json::arr_f64(&a.progress.sample(&ts))),
        (
            "data_progress",
            Json::Arr(
                a.data_progress
                    .iter()
                    .map(|f| Json::arr_f64(&f.sample(&ts)))
                    .collect(),
            ),
        ),
        (
            "finish_time",
            a.finish_time.map(Json::Num).unwrap_or(Json::Null),
        ),
    ];
    // bottleneck segments
    let segs: Vec<Json> = a
        .segments
        .iter()
        .map(|s| {
            Json::obj(vec![
                ("start", Json::Num(s.start)),
                ("end", Json::Num(s.end)),
                ("bottleneck", bottleneck_label(&p, &a, s.bottleneck)),
            ])
        })
        .collect();
    obj.push(("segments", Json::Arr(segs)));
    // resource consumption vs allocation (paper Fig 4 mid)
    let mut consumption = vec![];
    let mut allocation = vec![];
    for l in 0..p.res_reqs.len() {
        let demand = a.resource_demand(&p, l);
        consumption.push(Json::arr_f64(&demand.sample(&ts)));
        allocation.push(Json::arr_f64(&inputs.resources[l].sample(&ts)));
    }
    obj.push(("resource_consumption", Json::Arr(consumption)));
    obj.push(("resource_allocation", Json::Arr(allocation)));
    // buffered input data (paper Fig 4 bottom)
    let mut buffered = vec![];
    for k in 0..p.data_reqs.len() {
        buffered.push(Json::arr_f64(&a.buffered_data_sampled(&p, &inputs, k, &ts)));
    }
    obj.push(("buffered_data", Json::Arr(buffered)));
    write_json(dir, "fig4_progress_and_resources.json", &Json::obj(obj))
}

/// Fig 6: measured I/O traces of the isolated task executions.
pub fn fig6(dir: &Path) -> Result<()> {
    let mut tb = VideoTestbed::new(VideoScenario::default());
    tb.sample_every = 0.25;
    let t1 = tb.isolated_task1();
    let t2 = tb.isolated_task2();
    let trace_json = |tr: &crate::testbed::video::IoTrace| {
        Json::obj(vec![
            ("name", Json::Str(tr.name.clone())),
            ("t", Json::arr_f64(&tr.ts)),
            ("read", Json::arr_f64(&tr.read)),
            ("written", Json::arr_f64(&tr.written)),
        ])
    };
    let j = Json::obj(vec![
        ("task1", trace_json(&t1)),
        ("task2", trace_json(&t2)),
    ]);
    write_json(dir, "fig6_io_traces.json", &j)
}

/// Fig 7: predicted total time over `points` prioritizations + measured
/// (testbed) averages with min/max bars at a subset.
pub fn fig7(dir: &Path, points: usize, measured_points: usize, runs: usize) -> Result<()> {
    let sc = VideoScenario::default();
    let fractions = fig7_fractions(points);
    let threads = crate::util::par::num_threads();
    let sweep = exact_sweep(&sc, &fractions, threads);

    let mut measured = vec![];
    for i in 0..measured_points {
        let f = (i + 1) as f64 / (measured_points + 1) as f64;
        let tb = VideoTestbed::new(sc.clone().with_fraction(f));
        let runs_v = tb.measure(runs, 1000 + i as u64, 0.01);
        let s = Summary::of(&runs_v);
        measured.push(Json::obj(vec![
            ("fraction", Json::Num(f)),
            ("mean", Json::Num(s.mean)),
            ("min", Json::Num(s.min)),
            ("max", Json::Num(s.max)),
            ("runs", Json::Num(runs as f64)),
        ]));
    }

    let j = Json::obj(vec![
        ("fractions", Json::arr_f64(&sweep.fractions)),
        ("predicted_total", Json::arr_f64(&sweep.totals)),
        ("measured", Json::Arr(measured)),
    ]);
    write_json(dir, "fig7_prioritization_sweep.json", &j)
}

/// Fig 8: detailed progress/bottleneck/link-usage at 50 % and 95 %.
pub fn fig8(dir: &Path) -> Result<()> {
    let mut cases = vec![];
    for f in [0.5, 0.95] {
        let sc = VideoScenario::default().with_fraction(f);
        let (wf, nodes) = sc.build();
        let wa = analyze_fixpoint(&wf, &SolverOpts::default(), 6)?;
        let total = wa.makespan.unwrap();
        let ts = grid(0.0, total + 5.0, 301);

        // every node's progress shares the case grid: one SoA batch pass
        let prog_curves: Vec<&PwPoly> = wa.analyses.iter().map(|a| &a.progress).collect();
        let prog_flat = BatchPwPoly::compile(&prog_curves).eval_scenarios(&ts);
        let prog_rows: Vec<&[f64]> = prog_flat.chunks(ts.len()).collect();

        let mut node_objs = vec![];
        for (i, a) in wa.analyses.iter().enumerate() {
            let p = &wf.nodes[i].process;
            let segs: Vec<Json> = a
                .segments
                .iter()
                .map(|s| {
                    Json::obj(vec![
                        ("start", Json::Num(s.start)),
                        ("end", Json::Num(s.end.min(total + 5.0))),
                        ("bottleneck", bottleneck_label(p, a, s.bottleneck)),
                    ])
                })
                .collect();
            node_objs.push(Json::obj(vec![
                ("name", Json::Str(p.name.clone())),
                ("progress", Json::arr_f64(prog_rows[i])),
                ("max_progress", Json::Num(a.max_progress)),
                (
                    "finish",
                    a.finish_time.map(Json::Num).unwrap_or(Json::Null),
                ),
                ("segments", Json::Arr(segs)),
            ]));
        }
        // link rate usage of the two downloads (paper Fig 8 bottom)
        let dl1_demand = wa.analyses[nodes.dl1]
            .resource_demand(&wf.nodes[nodes.dl1].process, 0);
        let dl2_demand = wa.analyses[nodes.dl2]
            .resource_demand(&wf.nodes[nodes.dl2].process, 0);
        cases.push(Json::obj(vec![
            ("fraction", Json::Num(f)),
            ("total", Json::Num(total)),
            ("t", Json::arr_f64(&ts)),
            ("nodes", Json::Arr(node_objs)),
            ("dl1_rate", Json::arr_f64(&dl1_demand.sample(&ts))),
            ("dl2_rate", Json::arr_f64(&dl2_demand.sample(&ts))),
            ("link_capacity", Json::Num(sc.link_rate)),
        ]));
    }
    write_json(dir, "fig8_detailed_cases.json", &Json::obj(vec![("cases", Json::Arr(cases))]))
}

/// §6 table: BottleMod analysis wallclock vs DES simulation wallclock over
/// input sizes. Returns rows for printing too.
pub fn sec6(dir: &Path, sizes_gb: &[f64], reps: usize) -> Result<Vec<Vec<String>>> {
    let mut rows = vec![vec![
        "input size".to_string(),
        "BottleMod (ms)".to_string(),
        "BottleMod events".to_string(),
        "DES (ms)".to_string(),
        "DES events".to_string(),
    ]];
    let mut entries = vec![];
    for &gb in sizes_gb {
        let sc = VideoScenario::default()
            .with_input_size(gb * 1e9)
            .with_fraction(0.5);

        // BottleMod exact analysis
        let (wf, _) = sc.build();
        let opts = SolverOpts::default();
        let t0 = Instant::now();
        let mut events = 0;
        for _ in 0..reps {
            events = analyze_fixpoint(&wf, &opts, 6)?.events;
        }
        let bm_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;

        // DES simulation at 1 MB chunks
        let t0 = Instant::now();
        let mut des_events = 0;
        for _ in 0..reps {
            des_events = des::video::run(&sc, 1e6).events;
        }
        let des_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;

        rows.push(vec![
            format!("{gb:.1} GB"),
            format!("{bm_ms:.3}"),
            format!("{events}"),
            format!("{des_ms:.3}"),
            format!("{des_events}"),
        ]);
        entries.push(Json::obj(vec![
            ("input_gb", Json::Num(gb)),
            ("bottlemod_ms", Json::Num(bm_ms)),
            ("bottlemod_events", Json::Num(events as f64)),
            ("des_ms", Json::Num(des_ms)),
            ("des_events", Json::Num(des_events as f64)),
        ]));
    }
    write_json(dir, "sec6_performance.json", &Json::obj(vec![("rows", Json::Arr(entries))]))?;
    Ok(rows)
}

/// Export everything.
pub fn export_all(dir: &Path) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    fig1(dir)?;
    fig3(dir)?;
    fig4(dir)?;
    fig6(dir)?;
    fig7(dir, 600, 13, 10)?;
    fig8(dir)?;
    let rows = sec6(dir, &[1.1, 10.0, 100.0], 3)?;
    println!("{}", crate::util::stats::ascii_table(&rows));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example_solves_with_bottleneck_switches() {
        let (p, inputs) = paper_example();
        let a = solve(&p, &inputs, &SolverOpts::default()).unwrap();
        assert!(a.finish_time.is_some());
        // the example is designed to have several distinct bottlenecks
        let kinds: std::collections::BTreeSet<String> = a
            .segments
            .iter()
            .map(|s| a.bottleneck_name(&p, s.bottleneck))
            .collect();
        assert!(kinds.len() >= 2, "only {kinds:?}");
    }

    #[test]
    fn export_small_figs_to_tempdir() {
        let dir = std::env::temp_dir().join("bottlemod_export_test");
        std::fs::create_dir_all(&dir).unwrap();
        fig1(&dir).unwrap();
        fig3(&dir).unwrap();
        fig4(&dir).unwrap();
        // outputs parse back as JSON
        for f in [
            "fig1_requirement_functions.json",
            "fig3_data_progress.json",
            "fig4_progress_and_resources.json",
        ] {
            let text = std::fs::read_to_string(dir.join(f)).unwrap();
            assert!(Json::parse(&text).is_ok(), "{f} not valid json");
        }
    }

    #[test]
    fn sec6_rows_show_scaling_shape() {
        let dir = std::env::temp_dir().join("bottlemod_sec6_test");
        std::fs::create_dir_all(&dir).unwrap();
        let rows = sec6(&dir, &[1.1, 10.0], 1).unwrap();
        assert_eq!(rows.len(), 3);
        // DES events at 10 GB ≫ events at 1.1 GB; BottleMod events flat
        let bm1: f64 = rows[1][2].parse().unwrap();
        let bm10: f64 = rows[2][2].parse().unwrap();
        let des1: f64 = rows[1][4].parse().unwrap();
        let des10: f64 = rows[2][4].parse().unwrap();
        assert!(des10 > 5.0 * des1, "DES should scale: {des1} -> {des10}");
        assert!(bm10 < 2.0 * bm1, "BottleMod should stay flat: {bm1} -> {bm10}");
    }
}
