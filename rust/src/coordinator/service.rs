//! The analysis service: a leader/worker job queue over the exact engine.
//!
//! BottleMod's intended deployment (paper §7, "repeatedly executed online
//! with an updated state from monitoring") is as a sidecar service that a
//! resource manager queries. This module provides that shape without any
//! network dependency: a worker pool consuming analysis jobs from a queue,
//! plus a JSON-lines stdio front end (`bottlemod serve`).

use std::io::{BufRead, Write};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::model::spec::parse_workflow;
use crate::solver::SolverOpts;
use crate::util::Json;
use crate::workflow::engine::analyze_fixpoint;

/// A job for the worker pool.
#[derive(Debug, Clone)]
pub enum Job {
    /// Analyze a workflow spec (JSON text).
    Analyze { id: u64, spec: String },
}

/// Result of a job, as JSON (so the stdio server can emit it directly).
#[derive(Debug, Clone)]
pub struct JobResult {
    pub id: u64,
    pub payload: Json,
}

/// Run one job to completion.
pub fn run_job(job: &Job) -> JobResult {
    match job {
        Job::Analyze { id, spec } => {
            let payload = match parse_workflow(spec) {
                Err(e) => Json::obj(vec![("error", Json::Str(e.to_string()))]),
                Ok(wf) => match analyze_fixpoint(&wf, &SolverOpts::default(), 6) {
                    Err(e) => Json::obj(vec![("error", Json::Str(e.to_string()))]),
                    Ok(wa) => {
                        let schedule: Vec<Json> = wa
                            .schedule(&wf)
                            .into_iter()
                            .map(|(name, start, finish)| {
                                Json::obj(vec![
                                    ("name", Json::Str(name)),
                                    ("start", Json::Num(start)),
                                    (
                                        "finish",
                                        finish.map(Json::Num).unwrap_or(Json::Null),
                                    ),
                                ])
                            })
                            .collect();
                        let bottlenecks: Vec<Json> = wa
                            .analyses
                            .iter()
                            .enumerate()
                            .flat_map(|(i, a)| {
                                let p = &wf.nodes[i].process;
                                a.segments
                                    .iter()
                                    .map(|s| {
                                        Json::obj(vec![
                                            ("process", Json::Str(p.name.clone())),
                                            ("start", Json::Num(s.start)),
                                            ("end", Json::Num(s.end)),
                                            (
                                                "bottleneck",
                                                Json::Str(a.bottleneck_name(p, s.bottleneck)),
                                            ),
                                        ])
                                    })
                                    .collect::<Vec<_>>()
                            })
                            .collect();
                        Json::obj(vec![
                            (
                                "makespan",
                                wa.makespan.map(Json::Num).unwrap_or(Json::Null),
                            ),
                            ("events", Json::Num(wa.events as f64)),
                            ("passes", Json::Num(wa.passes as f64)),
                            ("schedule", Json::Arr(schedule)),
                            ("bottlenecks", Json::Arr(bottlenecks)),
                        ])
                    }
                },
            };
            JobResult { id: *id, payload }
        }
    }
}

/// A fixed-size worker pool consuming jobs.
pub struct Coordinator {
    tx: Option<mpsc::Sender<Job>>,
    results: mpsc::Receiver<JobResult>,
    workers: Vec<JoinHandle<()>>,
}

impl Coordinator {
    pub fn new(n_workers: usize) -> Self {
        let (tx, rx) = mpsc::channel::<Job>();
        let (rtx, rrx) = mpsc::channel::<JobResult>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n_workers.max(1))
            .map(|_| {
                let rx = Arc::clone(&rx);
                let rtx = rtx.clone();
                std::thread::spawn(move || loop {
                    let job = match rx.lock().unwrap().recv() {
                        Ok(j) => j,
                        Err(_) => break,
                    };
                    let _ = rtx.send(run_job(&job));
                })
            })
            .collect();
        Coordinator {
            tx: Some(tx),
            results: rrx,
            workers,
        }
    }

    pub fn submit(&self, job: Job) {
        self.tx.as_ref().unwrap().send(job).expect("queue alive");
    }

    /// Collect exactly `n` results (blocking).
    pub fn collect(&self, n: usize) -> Vec<JobResult> {
        (0..n).map(|_| self.results.recv().expect("worker alive")).collect()
    }

    pub fn shutdown(mut self) {
        self.tx.take(); // close the queue
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// JSON-lines server: one request object per line on stdin, one response
/// per line on stdout. Request: `{"id": 1, "op": "analyze", "spec": {...}}`.
pub fn serve_stdio(input: impl BufRead, mut output: impl Write) -> anyhow::Result<()> {
    for line in input.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let req = match Json::parse(&line) {
            Ok(j) => j,
            Err(e) => {
                writeln!(
                    output,
                    "{}",
                    Json::obj(vec![("error", Json::Str(format!("bad request: {e}")))])
                )?;
                continue;
            }
        };
        let id = req.get("id").as_f64().unwrap_or(0.0) as u64;
        let resp = match req.get("op").as_str() {
            Some("analyze") => {
                let spec = req.get("spec").to_string();
                run_job(&Job::Analyze { id, spec }).payload
            }
            Some("ping") => Json::obj(vec![("pong", Json::Bool(true))]),
            other => Json::obj(vec![(
                "error",
                Json::Str(format!("unknown op {other:?}")),
            )]),
        };
        let mut obj = match resp {
            Json::Obj(m) => m,
            other => {
                let mut m = std::collections::BTreeMap::new();
                m.insert("result".to_string(), other);
                m
            }
        };
        obj.insert("id".to_string(), Json::Num(id as f64));
        writeln!(output, "{}", Json::Obj(obj))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    const TINY_SPEC: &str = r#"{
      "processes": [
        {"name": "a", "max_progress": 10.0,
         "data": [{"req": {"type": "stream", "total": 10.0},
                   "source": {"external_constant": 10.0}}],
         "resources": [{"req": {"type": "stream", "total": 5.0},
                        "source": {"constant": 1.0}}],
         "outputs": [{"name": "out", "type": "identity"}]}
      ]
    }"#;

    #[test]
    fn pool_processes_jobs() {
        let c = Coordinator::new(3);
        for id in 0..6 {
            c.submit(Job::Analyze {
                id,
                spec: TINY_SPEC.to_string(),
            });
        }
        let mut results = c.collect(6);
        c.shutdown();
        results.sort_by_key(|r| r.id);
        assert_eq!(results.len(), 6);
        for r in &results {
            let mk = r.payload.get("makespan").as_f64().unwrap();
            assert!((mk - 5.0).abs() < 1e-6, "{mk}");
        }
    }

    #[test]
    fn stdio_server_roundtrip() {
        let spec_json = Json::parse(TINY_SPEC).unwrap();
        let req = Json::obj(vec![
            ("id", Json::Num(7.0)),
            ("op", Json::Str("analyze".into())),
            ("spec", spec_json),
        ]);
        let input = format!("{req}\n{{\"op\": \"ping\", \"id\": 8}}\n");
        let mut out = Vec::new();
        serve_stdio(std::io::Cursor::new(input), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let r1 = Json::parse(lines[0]).unwrap();
        assert_eq!(r1.get("id").as_f64(), Some(7.0));
        assert!((r1.get("makespan").as_f64().unwrap() - 5.0).abs() < 1e-6);
        let r2 = Json::parse(lines[1]).unwrap();
        assert_eq!(r2.get("pong").as_bool(), Some(true));
    }

    #[test]
    fn bad_spec_reports_error() {
        let r = run_job(&Job::Analyze {
            id: 1,
            spec: "{}".into(),
        });
        assert!(r.payload.get("error").as_str().is_some());
    }
}
