//! The analysis service: a leader/worker job queue plus the JSON-lines
//! stdio front end (`bottlemod serve`).
//!
//! BottleMod's intended deployment (paper §7, "repeatedly executed online
//! with an updated state from monitoring") is as a sidecar service that a
//! resource manager queries. This module provides that shape without any
//! network dependency — but it contains **no protocol logic of its own**:
//! a [`Job`] carries a typed [`Request`], workers run
//! [`crate::api::execute`], and [`serve_stdio`] is a line pump over
//! [`crate::api::ApiHandler::handle_wire`]. All request decoding, response
//! encoding and error construction lives in [`crate::api`]; the wire
//! reference (v1 envelope, legacy v0 shim, error codes) is
//! `docs/SERVICE.md`.

use std::io::{BufRead, Write};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::api::{execute, execute_with_threads, ApiError, ApiHandler, ErrorCode, Request, Response};
use crate::runtime::cache::AnalysisCache;

/// A job for the worker pool: any API request plus a caller-chosen
/// correlation id (the `batch` op uses the submission index).
#[derive(Clone, Debug)]
pub struct Job {
    pub id: u64,
    pub request: Request,
}

/// Result of a job: the typed outcome, correlated by id.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub id: u64,
    pub outcome: Result<Response, ApiError>,
}

/// Run one job with a private, per-call analysis cache.
pub fn run_job(job: &Job) -> JobResult {
    run_job_cached(job, None)
}

/// Run one job, optionally against a service-lifetime [`AnalysisCache`]:
/// repeat or overlapping requests are answered incrementally, and results
/// are bit-for-bit identical with or without the cache. This is a thin
/// shim over [`crate::api::execute`] — the pool does no per-op work of
/// its own.
pub fn run_job_cached(job: &Job, cache: Option<&Arc<AnalysisCache>>) -> JobResult {
    let fresh;
    let cache = match cache {
        Some(c) => c,
        None => {
            fresh = Arc::new(AnalysisCache::new());
            &fresh
        }
    };
    JobResult {
        id: job.id,
        outcome: execute(&job.request, cache),
    }
}

/// Worker-loop execution. Two differences from [`run_job_cached`]:
///
/// * a panicking job (a solver invariant tripped by a pathological model)
///   is caught and reported as an `internal` error instead of killing the
///   worker — a dead worker would leave `collect` blocking forever on a
///   result that never comes, wedging every future batch;
/// * a job's own solver fan-out is capped at 1 thread: the pool is the
///   parallelism across jobs, and K concurrent sweeps each spawning
///   `num_threads()` scoped threads would oversubscribe the machine.
///   Results are identical for any thread budget.
fn run_job_pooled(job: &Job, cache: &Arc<AnalysisCache>) -> JobResult {
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        execute_with_threads(&job.request, cache, 1)
    }))
    .unwrap_or_else(|_| {
        Err(ApiError::new(
            ErrorCode::Internal,
            "job panicked mid-execution; see server logs",
        ))
    });
    JobResult {
        id: job.id,
        outcome,
    }
}

/// A fixed-size worker pool consuming jobs. Dropping the pool closes the
/// queue and joins the workers.
pub struct Coordinator {
    tx: Option<mpsc::Sender<Job>>,
    results: mpsc::Receiver<JobResult>,
    workers: Vec<JoinHandle<()>>,
}

impl Coordinator {
    /// Pool with its own private analysis cache.
    pub fn new(n_workers: usize) -> Self {
        Self::with_cache(n_workers, Arc::new(AnalysisCache::new()))
    }

    /// Pool over a shared (e.g. [`ApiHandler`]-owned) cache: repeat or
    /// overlapping jobs are answered incrementally across workers. The
    /// per-request cache stats in sweep responses are counter deltas on
    /// the shared cache — exact under sequential use, approximate when
    /// workers run jobs concurrently (outcomes are never affected).
    pub fn with_cache(n_workers: usize, cache: Arc<AnalysisCache>) -> Self {
        let (tx, rx) = mpsc::channel::<Job>();
        let (rtx, rrx) = mpsc::channel::<JobResult>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n_workers.max(1))
            .map(|_| {
                let rx = Arc::clone(&rx);
                let rtx = rtx.clone();
                let cache = Arc::clone(&cache);
                std::thread::spawn(move || loop {
                    let job = match rx.lock().unwrap().recv() {
                        Ok(j) => j,
                        Err(_) => break,
                    };
                    let _ = rtx.send(run_job_pooled(&job, &cache));
                })
            })
            .collect();
        Coordinator {
            tx: Some(tx),
            results: rrx,
            workers,
        }
    }

    pub fn submit(&self, job: Job) {
        self.tx.as_ref().unwrap().send(job).expect("queue alive");
    }

    /// Collect exactly `n` results (blocking).
    pub fn collect(&self, n: usize) -> Vec<JobResult> {
        (0..n).map(|_| self.results.recv().expect("worker alive")).collect()
    }

    /// Explicit shutdown; equivalent to dropping the pool.
    pub fn shutdown(self) {}
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.tx.take(); // close the queue
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// JSON-lines server: one request object per line on stdin, one response
/// per line on stdout. Speaks the v1 envelope and the legacy v0 shapes
/// (`docs/SERVICE.md`); holds one [`ApiHandler`] — and therefore one
/// [`AnalysisCache`] — for the whole session, so repeat requests are
/// answered incrementally.
pub fn serve_stdio(input: impl BufRead, mut output: impl Write) -> crate::util::Result<()> {
    let handler = ApiHandler::new();
    for line in input.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        writeln!(output, "{}", handler.handle_wire(&line))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::test_fixtures::{CHAIN_TSV, TINY_SPEC};
    use crate::util::Json;
    use crate::workflow::scenario::Perturbation;

    fn analyze_job(id: u64, spec: &str) -> Job {
        Job {
            id,
            request: Request::Analyze {
                spec: spec.to_string(),
            },
        }
    }

    fn sweep_job(id: u64, fractions: &[f64]) -> Job {
        Job {
            id,
            request: Request::Sweep {
                workflow: crate::api::WorkflowSel::Video,
                perturbations: fractions.iter().map(|&f| Perturbation::Fraction(f)).collect(),
            },
        }
    }

    fn makespan(r: &JobResult) -> f64 {
        match r.outcome.as_ref().unwrap() {
            Response::Analyze(a) => a.makespan.unwrap(),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn pool_processes_jobs() {
        let c = Coordinator::new(3);
        for id in 0..6 {
            c.submit(analyze_job(id, TINY_SPEC));
        }
        let mut results = c.collect(6);
        c.shutdown();
        results.sort_by_key(|r| r.id);
        assert_eq!(results.len(), 6);
        for r in &results {
            let mk = makespan(r);
            assert!((mk - 5.0).abs() < 1e-6, "{mk}");
        }
    }

    /// Legacy v0 requests still round-trip through the stdio server with
    /// the flat payload shape, now tagged deprecated.
    #[test]
    fn stdio_server_roundtrip() {
        let spec_json = Json::parse(TINY_SPEC).unwrap();
        let req = Json::obj(vec![
            ("id", Json::Num(7.0)),
            ("op", Json::Str("analyze".into())),
            ("spec", spec_json),
        ]);
        let input = format!("{req}\n{{\"op\": \"ping\", \"id\": 8}}\n");
        let mut out = Vec::new();
        serve_stdio(std::io::Cursor::new(input), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let r1 = Json::parse(lines[0]).unwrap();
        assert_eq!(r1.get("id").as_f64(), Some(7.0));
        assert!((r1.get("makespan").as_f64().unwrap() - 5.0).abs() < 1e-6);
        assert_eq!(r1.get("deprecated").as_bool(), Some(true));
        let r2 = Json::parse(lines[1]).unwrap();
        assert_eq!(r2.get("pong").as_bool(), Some(true));
        assert_eq!(r2.get("deprecated").as_bool(), Some(true));
    }

    #[test]
    fn bad_spec_reports_error() {
        let r = run_job(&analyze_job(1, "{}"));
        let e = r.outcome.unwrap_err();
        assert_eq!(e.code, ErrorCode::InvalidSpec);
    }

    #[test]
    fn sweep_job_reports_best_fraction_and_bottlenecks() {
        let r = run_job(&sweep_job(9, &[0.25, 0.5, 0.75, 0.93]));
        assert_eq!(r.id, 9);
        let s = match r.outcome.unwrap() {
            Response::Sweep(s) => s,
            other => panic!("{other:?}"),
        };
        let (best_i, _) = s.best.unwrap();
        assert_eq!(best_i, 3, "0.93 wins the batch");
        assert_eq!(s.makespans.len(), 4);
        // the incremental engine reports its cache behaviour
        let stats = s.cache.expect("cache stats attached");
        assert!(stats.hit_rate() >= 0.0);
        assert!(!s.ranked.is_empty());
        assert!(s.ranked.iter().any(|b| b.bottleneck == "res:link"));
    }

    /// A degenerate request (fraction 0 starves dl1 forever, so the
    /// barrier node's dependency never finishes) must come back as a typed
    /// error — not a panic that kills the server.
    #[test]
    fn degenerate_fraction_reports_error_not_panic() {
        let r = run_job(&sweep_job(4, &[0.0]));
        let e = r.outcome.unwrap_err();
        assert_eq!(e.code, ErrorCode::AnalysisFailed);
    }

    #[test]
    fn empty_sweep_is_an_error() {
        let r = run_job(&sweep_job(2, &[]));
        let e = r.outcome.unwrap_err();
        assert_eq!(e.code, ErrorCode::BadRequest);
    }

    #[test]
    fn stdio_sweep_op() {
        let input = "{\"op\": \"sweep\", \"id\": 3, \"fractions\": [0.5, 0.9]}\n";
        let mut out = Vec::new();
        serve_stdio(std::io::Cursor::new(input), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let resp = Json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(resp.get("id").as_f64(), Some(3.0));
        assert_eq!(resp.get("totals").as_arr().unwrap().len(), 2);
        assert!((resp.get("best_fraction").as_f64().unwrap() - 0.9).abs() < 1e-9);
        assert_eq!(resp.get("deprecated").as_bool(), Some(true));
    }

    fn calibrate_job(id: u64, tsv: &str) -> Job {
        Job {
            id,
            request: Request::Calibrate {
                tsv: tsv.to_string(),
                io: None,
                tol: None,
            },
        }
    }

    #[test]
    fn calibrate_job_reports_replay_error() {
        let r = run_job(&calibrate_job(11, CHAIN_TSV));
        assert_eq!(r.id, 11);
        let c = match r.outcome.unwrap() {
            Response::Calibrate(c) => c,
            other => panic!("{other:?}"),
        };
        assert_eq!(c.tasks.len(), 2);
        assert_eq!(c.tasks[0].id, "dl");
        assert_eq!(c.tasks[0].model, "summary/stream");
        let mk = c.predicted_makespan.unwrap();
        assert!((mk - 20.0).abs() < 0.1, "{mk}");
        assert!(c.max_rel_err.unwrap() < 0.01);
    }

    #[test]
    fn calibrate_job_reports_parse_errors() {
        let r = run_job(&calibrate_job(
            12,
            "task_id\tdeps\trealtime\trchar\twchar\na\t-\t5\toops\t1\n",
        ));
        let e = r.outcome.unwrap_err();
        assert_eq!(e.code, ErrorCode::InvalidTrace);
        assert!(
            e.message.contains("line 2") && e.message.contains("oops"),
            "{}",
            e.message
        );
    }

    #[test]
    fn stdio_calibrate_op() {
        let req = Json::obj(vec![
            ("id", Json::Num(5.0)),
            ("op", Json::Str("calibrate".into())),
            ("tsv", Json::Str(CHAIN_TSV.into())),
        ]);
        let input = format!("{req}\n{{\"op\": \"calibrate\", \"id\": 6}}\n");
        let mut out = Vec::new();
        serve_stdio(std::io::Cursor::new(input), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let r1 = Json::parse(lines[0]).unwrap();
        assert_eq!(r1.get("id").as_f64(), Some(5.0));
        assert_eq!(r1.get("tasks").as_arr().unwrap().len(), 2);
        assert!(r1.get("max_rel_err").as_f64().unwrap() < 0.01);
        // missing tsv field is a per-request error, not a dead server
        let r2 = Json::parse(lines[1]).unwrap();
        assert!(r2.get("error").as_str().unwrap().contains("tsv"));
    }

    /// A malformed 'io' field must error, not silently degrade to the
    /// summary-only fallback.
    #[test]
    fn stdio_calibrate_rejects_non_string_io() {
        let req = Json::obj(vec![
            ("id", Json::Num(9.0)),
            ("op", Json::Str("calibrate".into())),
            ("tsv", Json::Str(CHAIN_TSV.into())),
            ("io", Json::Num(42.0)),
        ]);
        let mut out = Vec::new();
        serve_stdio(std::io::Cursor::new(format!("{req}\n")), &mut out).unwrap();
        let resp = Json::parse(String::from_utf8(out).unwrap().lines().next().unwrap())
            .unwrap();
        assert!(
            resp.get("error").as_str().unwrap().contains("io"),
            "{resp:?}"
        );
        // explicit null is fine (treated as absent)
        let req = Json::obj(vec![
            ("id", Json::Num(10.0)),
            ("op", Json::Str("calibrate".into())),
            ("tsv", Json::Str(CHAIN_TSV.into())),
            ("io", Json::Null),
        ]);
        let mut out = Vec::new();
        serve_stdio(std::io::Cursor::new(format!("{req}\n")), &mut out).unwrap();
        let resp = Json::parse(String::from_utf8(out).unwrap().lines().next().unwrap())
            .unwrap();
        assert_eq!(resp.get("tasks").as_arr().unwrap().len(), 2);
    }

    /// The server holds one analysis cache for the session: a repeated
    /// sweep request re-solves nothing, identical results, and the stats
    /// are reported per request (not lifetime totals).
    #[test]
    fn stdio_sweep_reuses_cache_across_requests() {
        let line = "{\"op\": \"sweep\", \"id\": 1, \"fractions\": [0.5, 0.9]}\n";
        let input = format!("{line}{line}");
        let mut out = Vec::new();
        serve_stdio(std::io::Cursor::new(input), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let r1 = Json::parse(lines[0]).unwrap();
        let r2 = Json::parse(lines[1]).unwrap();
        assert_eq!(r1.get("totals"), r2.get("totals"));
        assert_eq!(r1.get("ranked_bottlenecks"), r2.get("ranked_bottlenecks"));
        let c1 = r1.get("cache");
        let c2 = r2.get("cache");
        assert!(c1.get("misses").as_f64().unwrap() > 0.0);
        assert_eq!(c2.get("misses").as_f64(), Some(0.0), "{c2:?}");
        assert!(c2.get("hits").as_f64().unwrap() > 0.0);
    }
}
