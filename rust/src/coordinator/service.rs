//! The analysis service: a leader/worker job queue over the exact engine.
//!
//! BottleMod's intended deployment (paper §7, "repeatedly executed online
//! with an updated state from monitoring") is as a sidecar service that a
//! resource manager queries. This module provides that shape without any
//! network dependency: a worker pool consuming analysis jobs from a queue,
//! plus a JSON-lines stdio front end (`bottlemod serve`).
//!
//! The wire protocol — request/response schemas for the `analyze`, `sweep`
//! and `ping` ops, error payloads, and the sweep response's cache-stats
//! fields — is documented with runnable examples in `docs/SERVICE.md`.

use std::io::{BufRead, Write};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::model::spec::parse_workflow;
use crate::runtime::cache::AnalysisCache;
use crate::solver::SolverOpts;
use crate::trace::{calibrate_trace, CalibrateOpts, CalibratedWorkflow, ReplayReport};
use crate::util::Json;
use crate::workflow::engine::analyze_fixpoint_cached;
use crate::workflow::scenario::VideoScenario;

use super::sweeper::{best_fraction, ExactSweep, SweepBatch};
use crate::workflow::scenario::Perturbation;

/// A job for the worker pool.
#[derive(Debug, Clone)]
pub enum Job {
    /// Analyze a workflow spec (JSON text).
    Analyze { id: u64, spec: String },
    /// Run a fraction sweep of the Fig 5 scenario and report the ranked
    /// bottlenecks (the batched engine behind one service call).
    Sweep { id: u64, fractions: Vec<f64> },
    /// Calibrate solver-ready models from a raw trace (TSV text plus an
    /// optional I/O series log) and replay-validate them.
    Calibrate {
        id: u64,
        tsv: String,
        io: Option<String>,
    },
}

/// The `calibrate` op's response payload: per-task model summary + replay
/// error, and the makespans. Shared by the stdio server and the worker
/// pool; schema documented in `docs/SERVICE.md`.
fn calibration_json(cal: &CalibratedWorkflow, report: &ReplayReport) -> Json {
    let tasks: Vec<Json> = cal
        .task_summaries(report)
        .into_iter()
        .map(|s| {
            Json::obj(vec![
                ("id", Json::Str(s.id)),
                ("model", Json::Str(s.model)),
                ("data_pieces", Json::Num(s.data_pieces as f64)),
                ("res_pieces", Json::Num(s.res_pieces as f64)),
                ("predicted_start", Json::Num(s.predicted_start)),
                ("predicted", s.predicted.map(Json::Num).unwrap_or(Json::Null)),
                ("observed", s.observed.map(Json::Num).unwrap_or(Json::Null)),
                ("rel_err", s.rel_err.map(Json::Num).unwrap_or(Json::Null)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("tasks", Json::Arr(tasks)),
        (
            "predicted_makespan",
            report.predicted_makespan.map(Json::Num).unwrap_or(Json::Null),
        ),
        (
            "observed_makespan",
            report.observed_makespan.map(Json::Num).unwrap_or(Json::Null),
        ),
        (
            "max_rel_err",
            report.max_rel_err.map(Json::Num).unwrap_or(Json::Null),
        ),
        ("events", Json::Num(report.events as f64)),
        ("passes", Json::Num(report.passes as f64)),
    ])
}

/// Result of a job, as JSON (so the stdio server can emit it directly).
#[derive(Debug, Clone)]
pub struct JobResult {
    pub id: u64,
    pub payload: Json,
}

/// Run one job to completion with no *shared* analysis cache: `analyze`
/// runs uncached; `sweep` still attaches a fresh per-call cache (the
/// incremental engine is its normal mode and the response always carries
/// a `cache` stats object), it just cannot reuse anything across calls.
pub fn run_job(job: &Job) -> JobResult {
    run_job_cached(job, None)
}

/// Run one job, optionally against a service-lifetime [`AnalysisCache`]:
/// repeat or overlapping requests (the §7 "repeatedly executed online"
/// deployment) are answered incrementally, while every response still
/// reports per-request cache stats. Results are bit-for-bit identical with
/// or without the cache. The per-request stats are counter deltas on the
/// shared cache: exact for the sequential stdio server, approximate when
/// [`Coordinator`] workers run jobs concurrently (another job's lookups
/// can land in the window; outcomes are never affected).
pub fn run_job_cached(job: &Job, cache: Option<&Arc<AnalysisCache>>) -> JobResult {
    match job {
        Job::Analyze { id, spec } => {
            let payload = match parse_workflow(spec) {
                Err(e) => Json::obj(vec![("error", Json::Str(e.to_string()))]),
                Ok(wf) => match analyze_fixpoint_cached(
                    &wf,
                    &SolverOpts::default(),
                    6,
                    cache.map(|c| c.as_ref()),
                ) {
                    Err(e) => Json::obj(vec![("error", Json::Str(e.to_string()))]),
                    Ok(wa) => {
                        let schedule: Vec<Json> = wa
                            .schedule(&wf)
                            .into_iter()
                            .map(|(name, start, finish)| {
                                Json::obj(vec![
                                    ("name", Json::Str(name)),
                                    ("start", Json::Num(start)),
                                    (
                                        "finish",
                                        finish.map(Json::Num).unwrap_or(Json::Null),
                                    ),
                                ])
                            })
                            .collect();
                        let bottlenecks: Vec<Json> = wa
                            .analyses
                            .iter()
                            .enumerate()
                            .flat_map(|(i, a)| {
                                let p = &wf.nodes[i].process;
                                a.segments
                                    .iter()
                                    .map(|s| {
                                        Json::obj(vec![
                                            ("process", Json::Str(p.name.clone())),
                                            ("start", Json::Num(s.start)),
                                            ("end", Json::Num(s.end)),
                                            (
                                                "bottleneck",
                                                Json::Str(a.bottleneck_name(p, s.bottleneck)),
                                            ),
                                        ])
                                    })
                                    .collect::<Vec<_>>()
                            })
                            .collect();
                        Json::obj(vec![
                            (
                                "makespan",
                                wa.makespan.map(Json::Num).unwrap_or(Json::Null),
                            ),
                            ("events", Json::Num(wa.events as f64)),
                            ("passes", Json::Num(wa.passes as f64)),
                            ("schedule", Json::Arr(schedule)),
                            ("bottlenecks", Json::Arr(bottlenecks)),
                        ])
                    }
                },
            };
            JobResult { id: *id, payload }
        }
        Job::Sweep { id, fractions } => {
            if fractions.is_empty() {
                return JobResult {
                    id: *id,
                    payload: Json::obj(vec![(
                        "error",
                        Json::Str("sweep needs at least one fraction".into()),
                    )]),
                };
            }
            // unlike the CLI path, never panic on a degenerate scenario —
            // a bad request must come back as an error payload
            let batch: Vec<Perturbation> = fractions
                .iter()
                .map(|&f| Perturbation::Fraction(f))
                .collect();
            let engine = SweepBatch::new(std::sync::Arc::new(VideoScenario::default()))
                .with_threads(crate::util::par::num_threads());
            let engine = match cache {
                Some(c) => engine.with_cache(c.clone()),
                None => engine.with_new_cache(),
            };
            let run = engine.run_report(&batch);
            let (outcomes, report) = match run {
                Ok(r) => r,
                Err(e) => {
                    return JobResult {
                        id: *id,
                        payload: Json::obj(vec![("error", Json::Str(e.to_string()))]),
                    };
                }
            };
            let sweep = ExactSweep {
                fractions: fractions.clone(),
                totals: outcomes
                    .iter()
                    .map(|o| o.makespan.unwrap_or(f64::INFINITY))
                    .collect(),
                events: report.total_events,
            };
            let (best_f, best_t) = best_fraction(&sweep);
            let ranked: Vec<Json> = report
                .ranked
                .iter()
                .take(8)
                .map(|r| {
                    Json::obj(vec![
                        ("process", Json::Str(r.process.clone())),
                        ("bottleneck", Json::Str(r.bottleneck.clone())),
                        ("total_seconds", Json::Num(r.total_seconds)),
                        ("scenarios", Json::Num(r.scenarios as f64)),
                    ])
                })
                .collect();
            let mut fields = vec![
                ("fractions", Json::arr_f64(&sweep.fractions)),
                ("totals", Json::arr_f64(&sweep.totals)),
                ("best_fraction", Json::Num(best_f)),
                ("best_total", Json::Num(best_t)),
                ("events", Json::Num(sweep.events as f64)),
                ("ranked_bottlenecks", Json::Arr(ranked)),
            ];
            if let Some(stats) = report.cache {
                fields.push((
                    "cache",
                    Json::obj(vec![
                        ("hits", Json::Num(stats.hits as f64)),
                        ("misses", Json::Num(stats.misses as f64)),
                        ("hit_rate", Json::Num(stats.hit_rate())),
                        ("entries", Json::Num(stats.entries as f64)),
                        ("evictions", Json::Num(stats.evictions as f64)),
                    ]),
                ));
            }
            JobResult {
                id: *id,
                payload: Json::obj(fields),
            }
        }
        Job::Calibrate { id, tsv, io } => {
            let payload = match calibrate_trace(
                tsv,
                io.as_deref(),
                &CalibrateOpts::default(),
                &SolverOpts::default(),
            ) {
                Ok((cal, report)) => calibration_json(&cal, &report),
                Err(e) => Json::obj(vec![("error", Json::Str(e.to_string()))]),
            };
            JobResult { id: *id, payload }
        }
    }
}

/// A fixed-size worker pool consuming jobs.
pub struct Coordinator {
    tx: Option<mpsc::Sender<Job>>,
    results: mpsc::Receiver<JobResult>,
    workers: Vec<JoinHandle<()>>,
}

impl Coordinator {
    pub fn new(n_workers: usize) -> Self {
        let (tx, rx) = mpsc::channel::<Job>();
        let (rtx, rrx) = mpsc::channel::<JobResult>();
        let rx = Arc::new(Mutex::new(rx));
        // one analysis cache for the pool's lifetime: repeat/overlapping
        // jobs are answered incrementally across workers
        let cache = Arc::new(AnalysisCache::new());
        let workers = (0..n_workers.max(1))
            .map(|_| {
                let rx = Arc::clone(&rx);
                let rtx = rtx.clone();
                let cache = Arc::clone(&cache);
                std::thread::spawn(move || loop {
                    let job = match rx.lock().unwrap().recv() {
                        Ok(j) => j,
                        Err(_) => break,
                    };
                    let _ = rtx.send(run_job_cached(&job, Some(&cache)));
                })
            })
            .collect();
        Coordinator {
            tx: Some(tx),
            results: rrx,
            workers,
        }
    }

    pub fn submit(&self, job: Job) {
        self.tx.as_ref().unwrap().send(job).expect("queue alive");
    }

    /// Collect exactly `n` results (blocking).
    pub fn collect(&self, n: usize) -> Vec<JobResult> {
        (0..n).map(|_| self.results.recv().expect("worker alive")).collect()
    }

    pub fn shutdown(mut self) {
        self.tx.take(); // close the queue
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// JSON-lines server: one request object per line on stdin, one response
/// per line on stdout. Request: `{"id": 1, "op": "analyze", "spec": {...}}`.
/// Holds one [`AnalysisCache`] for the whole session, so repeat requests
/// are answered incrementally (each response still reports per-request
/// stats). Full protocol reference: `docs/SERVICE.md`.
pub fn serve_stdio(input: impl BufRead, mut output: impl Write) -> crate::util::Result<()> {
    let cache = Arc::new(AnalysisCache::new());
    for line in input.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let req = match Json::parse(&line) {
            Ok(j) => j,
            Err(e) => {
                writeln!(
                    output,
                    "{}",
                    Json::obj(vec![("error", Json::Str(format!("bad request: {e}")))])
                )?;
                continue;
            }
        };
        let id = req.get("id").as_f64().unwrap_or(0.0) as u64;
        let resp = match req.get("op").as_str() {
            Some("analyze") => {
                let spec = req.get("spec").to_string();
                run_job_cached(&Job::Analyze { id, spec }, Some(&cache)).payload
            }
            Some("sweep") => {
                let fractions: Vec<f64> = req
                    .get("fractions")
                    .as_arr()
                    .map(|a| a.iter().filter_map(|x| x.as_f64()).collect())
                    .unwrap_or_else(|| {
                        let n = req.get("points").as_f64().unwrap_or(40.0) as usize;
                        crate::coordinator::sweeper::fig7_fractions(n.max(1))
                    });
                run_job_cached(&Job::Sweep { id, fractions }, Some(&cache)).payload
            }
            Some("calibrate") => match (req.get("tsv").as_str(), req.get("io")) {
                (None, _) => Json::obj(vec![(
                    "error",
                    Json::Str("calibrate needs a 'tsv' string field".into()),
                )]),
                // a malformed 'io' must not silently degrade to the
                // summary-only fallback
                (Some(_), io) if !matches!(io, Json::Null | Json::Str(_)) => {
                    Json::obj(vec![(
                        "error",
                        Json::Str("calibrate 'io' must be a string when present".into()),
                    )])
                }
                (Some(tsv), io) => run_job_cached(
                    &Job::Calibrate {
                        id,
                        tsv: tsv.to_string(),
                        io: io.as_str().map(str::to_string),
                    },
                    Some(&cache),
                )
                .payload,
            },
            Some("ping") => Json::obj(vec![("pong", Json::Bool(true))]),
            other => Json::obj(vec![(
                "error",
                Json::Str(format!("unknown op {other:?}")),
            )]),
        };
        let mut obj = match resp {
            Json::Obj(m) => m,
            other => {
                let mut m = std::collections::BTreeMap::new();
                m.insert("result".to_string(), other);
                m
            }
        };
        obj.insert("id".to_string(), Json::Num(id as f64));
        writeln!(output, "{}", Json::Obj(obj))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    const TINY_SPEC: &str = r#"{
      "processes": [
        {"name": "a", "max_progress": 10.0,
         "data": [{"req": {"type": "stream", "total": 10.0},
                   "source": {"external_constant": 10.0}}],
         "resources": [{"req": {"type": "stream", "total": 5.0},
                        "source": {"constant": 1.0}}],
         "outputs": [{"name": "out", "type": "identity"}]}
      ]
    }"#;

    #[test]
    fn pool_processes_jobs() {
        let c = Coordinator::new(3);
        for id in 0..6 {
            c.submit(Job::Analyze {
                id,
                spec: TINY_SPEC.to_string(),
            });
        }
        let mut results = c.collect(6);
        c.shutdown();
        results.sort_by_key(|r| r.id);
        assert_eq!(results.len(), 6);
        for r in &results {
            let mk = r.payload.get("makespan").as_f64().unwrap();
            assert!((mk - 5.0).abs() < 1e-6, "{mk}");
        }
    }

    #[test]
    fn stdio_server_roundtrip() {
        let spec_json = Json::parse(TINY_SPEC).unwrap();
        let req = Json::obj(vec![
            ("id", Json::Num(7.0)),
            ("op", Json::Str("analyze".into())),
            ("spec", spec_json),
        ]);
        let input = format!("{req}\n{{\"op\": \"ping\", \"id\": 8}}\n");
        let mut out = Vec::new();
        serve_stdio(std::io::Cursor::new(input), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let r1 = Json::parse(lines[0]).unwrap();
        assert_eq!(r1.get("id").as_f64(), Some(7.0));
        assert!((r1.get("makespan").as_f64().unwrap() - 5.0).abs() < 1e-6);
        let r2 = Json::parse(lines[1]).unwrap();
        assert_eq!(r2.get("pong").as_bool(), Some(true));
    }

    #[test]
    fn bad_spec_reports_error() {
        let r = run_job(&Job::Analyze {
            id: 1,
            spec: "{}".into(),
        });
        assert!(r.payload.get("error").as_str().is_some());
    }

    #[test]
    fn sweep_job_reports_best_fraction_and_bottlenecks() {
        let r = run_job(&Job::Sweep {
            id: 9,
            fractions: vec![0.25, 0.5, 0.75, 0.93],
        });
        assert_eq!(r.id, 9);
        let best = r.payload.get("best_fraction").as_f64().unwrap();
        assert!((best - 0.93).abs() < 1e-9, "{best}");
        assert_eq!(r.payload.get("totals").as_arr().unwrap().len(), 4);
        // the incremental engine reports its cache behaviour
        let cache = r.payload.get("cache");
        assert!(cache.get("hits").as_f64().is_some());
        assert!(cache.get("hit_rate").as_f64().unwrap() >= 0.0);
        let ranked = r.payload.get("ranked_bottlenecks").as_arr().unwrap();
        assert!(!ranked.is_empty());
        assert!(ranked
            .iter()
            .any(|b| b.get("bottleneck").as_str() == Some("res:link")));
    }

    /// A degenerate request (fraction 0 starves dl1 forever, so the
    /// barrier node's dependency never finishes) must come back as an
    /// error payload — not a panic that kills the server.
    #[test]
    fn degenerate_fraction_reports_error_not_panic() {
        let r = run_job(&Job::Sweep {
            id: 4,
            fractions: vec![0.0],
        });
        assert!(r.payload.get("error").as_str().is_some());
    }

    #[test]
    fn empty_sweep_is_an_error() {
        let r = run_job(&Job::Sweep {
            id: 2,
            fractions: vec![],
        });
        assert!(r.payload.get("error").as_str().is_some());
    }

    #[test]
    fn stdio_sweep_op() {
        let input = "{\"op\": \"sweep\", \"id\": 3, \"fractions\": [0.5, 0.9]}\n";
        let mut out = Vec::new();
        serve_stdio(std::io::Cursor::new(input), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let resp = Json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(resp.get("id").as_f64(), Some(3.0));
        assert_eq!(resp.get("totals").as_arr().unwrap().len(), 2);
        assert!((resp.get("best_fraction").as_f64().unwrap() - 0.9).abs() < 1e-9);
    }

    const CHAIN_TSV: &str = "task_id\tdeps\tstart\tcomplete\trealtime\tpcpu\trchar\twchar\tpeak_rss\n\
        dl\t-\t0\t10\t10\t1e9\t1e8\t1e8\t2e6\n\
        enc\tdl\t0\t20\t20\t100\t1e8\t5e7\t8e6\n";

    #[test]
    fn calibrate_job_reports_replay_error() {
        let r = run_job(&Job::Calibrate {
            id: 11,
            tsv: CHAIN_TSV.to_string(),
            io: None,
        });
        assert_eq!(r.id, 11);
        let tasks = r.payload.get("tasks").as_arr().unwrap();
        assert_eq!(tasks.len(), 2);
        assert_eq!(tasks[0].get("id").as_str(), Some("dl"));
        assert_eq!(tasks[0].get("model").as_str(), Some("summary/stream"));
        let mk = r.payload.get("predicted_makespan").as_f64().unwrap();
        assert!((mk - 20.0).abs() < 0.1, "{mk}");
        let err = r.payload.get("max_rel_err").as_f64().unwrap();
        assert!(err < 0.01, "{err}");
    }

    #[test]
    fn calibrate_job_reports_parse_errors() {
        let r = run_job(&Job::Calibrate {
            id: 12,
            tsv: "task_id\tdeps\trealtime\trchar\twchar\na\t-\t5\toops\t1\n".into(),
            io: None,
        });
        let e = r.payload.get("error").as_str().unwrap();
        assert!(e.contains("line 2") && e.contains("oops"), "{e}");
    }

    #[test]
    fn stdio_calibrate_op() {
        let req = Json::obj(vec![
            ("id", Json::Num(5.0)),
            ("op", Json::Str("calibrate".into())),
            ("tsv", Json::Str(CHAIN_TSV.into())),
        ]);
        let input = format!("{req}\n{{\"op\": \"calibrate\", \"id\": 6}}\n");
        let mut out = Vec::new();
        serve_stdio(std::io::Cursor::new(input), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let r1 = Json::parse(lines[0]).unwrap();
        assert_eq!(r1.get("id").as_f64(), Some(5.0));
        assert_eq!(r1.get("tasks").as_arr().unwrap().len(), 2);
        assert!(r1.get("max_rel_err").as_f64().unwrap() < 0.01);
        // missing tsv field is a per-request error, not a dead server
        let r2 = Json::parse(lines[1]).unwrap();
        assert!(r2.get("error").as_str().unwrap().contains("tsv"));
    }

    /// A malformed 'io' field must error, not silently degrade to the
    /// summary-only fallback.
    #[test]
    fn stdio_calibrate_rejects_non_string_io() {
        let req = Json::obj(vec![
            ("id", Json::Num(9.0)),
            ("op", Json::Str("calibrate".into())),
            ("tsv", Json::Str(CHAIN_TSV.into())),
            ("io", Json::Num(42.0)),
        ]);
        let mut out = Vec::new();
        serve_stdio(std::io::Cursor::new(format!("{req}\n")), &mut out).unwrap();
        let resp = Json::parse(String::from_utf8(out).unwrap().lines().next().unwrap())
            .unwrap();
        assert!(
            resp.get("error").as_str().unwrap().contains("io"),
            "{resp:?}"
        );
        // explicit null is fine (treated as absent)
        let req = Json::obj(vec![
            ("id", Json::Num(10.0)),
            ("op", Json::Str("calibrate".into())),
            ("tsv", Json::Str(CHAIN_TSV.into())),
            ("io", Json::Null),
        ]);
        let mut out = Vec::new();
        serve_stdio(std::io::Cursor::new(format!("{req}\n")), &mut out).unwrap();
        let resp = Json::parse(String::from_utf8(out).unwrap().lines().next().unwrap())
            .unwrap();
        assert_eq!(resp.get("tasks").as_arr().unwrap().len(), 2);
    }

    /// The server holds one analysis cache for the session: a repeated
    /// sweep request re-solves nothing, identical results, and the stats
    /// are reported per request (not lifetime totals).
    #[test]
    fn stdio_sweep_reuses_cache_across_requests() {
        let line = "{\"op\": \"sweep\", \"id\": 1, \"fractions\": [0.5, 0.9]}\n";
        let input = format!("{line}{line}");
        let mut out = Vec::new();
        serve_stdio(std::io::Cursor::new(input), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let r1 = Json::parse(lines[0]).unwrap();
        let r2 = Json::parse(lines[1]).unwrap();
        assert_eq!(r1.get("totals"), r2.get("totals"));
        assert_eq!(r1.get("ranked_bottlenecks"), r2.get("ranked_bottlenecks"));
        let c1 = r1.get("cache");
        let c2 = r2.get("cache");
        assert!(c1.get("misses").as_f64().unwrap() > 0.0);
        assert_eq!(c2.get("misses").as_f64(), Some(0.0), "{c2:?}");
        assert!(c2.get("hits").as_f64().unwrap() > 0.0);
    }
}
