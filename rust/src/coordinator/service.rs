//! The analysis service: a leader/worker job queue plus the JSON-lines
//! stdio front end (`bottlemod serve`).
//!
//! BottleMod's intended deployment (paper §7, "repeatedly executed online
//! with an updated state from monitoring") is as a sidecar service that a
//! resource manager queries. This module provides that shape without any
//! network dependency — but it contains **no protocol logic of its own**:
//! a [`Job`] carries a typed [`Request`], workers run
//! [`crate::api::execute`], and [`serve_stdio`] is a line pump over
//! [`crate::api::ApiHandler::handle_wire`]. All request decoding, response
//! encoding and error construction lives in [`crate::api`]; the wire
//! reference (v1 envelope, legacy v0 shim, error codes) is
//! `docs/SERVICE.md`.
//!
//! The pool practices *admission control*: its submission queue is bounded
//! ([`DEFAULT_QUEUE_BOUND`] unless configured), and a submit against a
//! full queue returns a structured `overloaded` [`ApiError`] instead of
//! blocking or buffering without bound. Multi-session socket serving on
//! top of this pool lives in [`super::server`].

use std::io::{BufRead, Write};
use std::sync::mpsc::{self, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::api::{
    execute, execute_with_threads, ApiError, ApiHandler, ErrorCode, Request, Response,
};
use crate::runtime::cache::AnalysisCache;

/// A job for the worker pool: any API request plus a caller-chosen
/// correlation id (the `batch` op uses the submission index).
#[derive(Clone, Debug)]
pub struct Job {
    pub id: u64,
    pub request: Request,
}

/// Result of a job: the typed outcome, correlated by id.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub id: u64,
    pub outcome: Result<Response, ApiError>,
}

/// Run one job with a private, per-call analysis cache.
pub fn run_job(job: &Job) -> JobResult {
    run_job_cached(job, None)
}

/// Run one job, optionally against a service-lifetime [`AnalysisCache`]:
/// repeat or overlapping requests are answered incrementally, and results
/// are bit-for-bit identical with or without the cache. This is a thin
/// shim over [`crate::api::execute`] — the pool does no per-op work of
/// its own.
pub fn run_job_cached(job: &Job, cache: Option<&Arc<AnalysisCache>>) -> JobResult {
    let fresh;
    let cache = match cache {
        Some(c) => c,
        None => {
            fresh = Arc::new(AnalysisCache::new());
            &fresh
        }
    };
    JobResult {
        id: job.id,
        outcome: execute(&job.request, cache),
    }
}

/// Worker-loop execution. Two differences from [`run_job_cached`]:
///
/// * a panicking job (a solver invariant tripped by a pathological model)
///   is caught and reported as an `internal` error instead of killing the
///   worker — a dead worker would leave `collect` blocking forever on a
///   result that never comes, wedging every future batch;
/// * a job's own solver fan-out is capped at 1 thread: the pool is the
///   parallelism across jobs, and K concurrent sweeps each spawning
///   `num_threads()` scoped threads would oversubscribe the machine.
///   Results are identical for any thread budget.
fn run_job_pooled(job: &Job, cache: &Arc<AnalysisCache>) -> JobResult {
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        execute_with_threads(&job.request, cache, 1)
    }))
    .unwrap_or_else(|_| {
        Err(ApiError::new(
            ErrorCode::Internal,
            "job panicked mid-execution; see server logs",
        ))
    });
    JobResult {
        id: job.id,
        outcome,
    }
}

/// One queued unit of work: the job plus where its result goes and which
/// cache it runs against (`None` = the pool's own cache). Routing the
/// reply channel through the queue lets many sessions share one pool
/// without interleaving each other's results.
struct Assignment {
    job: Job,
    cache: Option<Arc<AnalysisCache>>,
    reply: mpsc::Sender<JobResult>,
}

enum Work {
    Run(Assignment),
    /// Test-only: a job body that panics *inside* the worker's
    /// catch-unwind, for the poison/regression tests below.
    #[cfg(test)]
    PanicInJob {
        id: u64,
        reply: mpsc::Sender<JobResult>,
    },
}

/// Default bound of the submission queue — deep enough that batch fan-out
/// never notices, shallow enough that a stampede gets `overloaded` errors
/// instead of an unbounded backlog.
pub const DEFAULT_QUEUE_BOUND: usize = 1024;

/// Receive the next unit of work off the shared queue, recovering the
/// mutex if a previous holder panicked while locking it: the receiver
/// behind the lock is still sound (its state is only mutated by `recv`
/// itself), and one poisoned lock must not cascade into killing every
/// remaining worker. Returns `None` when the queue is closed and drained.
fn recv_work<T>(rx: &Mutex<Receiver<T>>) -> Option<T> {
    let guard = rx.lock().unwrap_or_else(|e| e.into_inner());
    guard.recv().ok()
}

/// A fixed-size worker pool consuming jobs through a bounded queue.
/// Dropping the pool closes the queue, lets the workers drain what was
/// already admitted, and joins them — that is the pool-level half of
/// graceful shutdown.
pub struct Coordinator {
    tx: Option<SyncSender<Work>>,
    queue_bound: usize,
    results_tx: mpsc::Sender<JobResult>,
    results_rx: Mutex<mpsc::Receiver<JobResult>>,
    workers: Vec<JoinHandle<()>>,
}

impl Coordinator {
    /// Pool with its own private analysis cache.
    pub fn new(n_workers: usize) -> Self {
        Self::with_cache(n_workers, Arc::new(AnalysisCache::new()))
    }

    /// Pool over a shared (e.g. [`ApiHandler`]-owned) cache: repeat or
    /// overlapping jobs are answered incrementally across workers. The
    /// per-request cache stats in sweep responses are counter deltas on
    /// the shared cache — exact under sequential use, approximate when
    /// workers run jobs concurrently (outcomes are never affected).
    pub fn with_cache(n_workers: usize, cache: Arc<AnalysisCache>) -> Self {
        Self::with_queue_bound(n_workers, cache, DEFAULT_QUEUE_BOUND)
    }

    /// [`Coordinator::with_cache`] with an explicit submission-queue bound
    /// (admission control): once `queue_bound` jobs are waiting, further
    /// submissions fail fast with `overloaded`.
    pub fn with_queue_bound(
        n_workers: usize,
        cache: Arc<AnalysisCache>,
        queue_bound: usize,
    ) -> Self {
        let queue_bound = queue_bound.max(1);
        let (tx, rx) = mpsc::sync_channel::<Work>(queue_bound);
        let (rtx, rrx) = mpsc::channel::<JobResult>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n_workers.max(1))
            .map(|_| {
                let rx = Arc::clone(&rx);
                let pool_cache = Arc::clone(&cache);
                std::thread::spawn(move || {
                    while let Some(work) = recv_work(&rx) {
                        match work {
                            Work::Run(a) => {
                                let cache = a.cache.as_ref().unwrap_or(&pool_cache);
                                let _ = a.reply.send(run_job_pooled(&a.job, cache));
                            }
                            #[cfg(test)]
                            Work::PanicInJob { id, reply } => {
                                let outcome = std::panic::catch_unwind(
                                    || -> Result<Response, ApiError> {
                                        panic!("injected test panic")
                                    },
                                )
                                .unwrap_or_else(|_| {
                                    Err(ApiError::new(
                                        ErrorCode::Internal,
                                        "job panicked mid-execution; see server logs",
                                    ))
                                });
                                let _ = reply.send(JobResult { id, outcome });
                            }
                        }
                    }
                })
            })
            .collect();
        Coordinator {
            tx: Some(tx),
            queue_bound,
            results_tx: rtx,
            results_rx: Mutex::new(rrx),
            workers,
        }
    }

    /// Submit a job whose result [`Coordinator::collect`] will pick up.
    /// Fails fast instead of blocking: `overloaded` when the bounded queue
    /// is full, `internal` when the pool is gone.
    pub fn submit(&self, job: Job) -> Result<(), ApiError> {
        let reply = self.results_tx.clone();
        self.submit_with(job, None, reply)
    }

    /// Submit a job with its own reply channel and (optionally) its own
    /// session cache — how the socket server multiplexes many sessions
    /// onto one pool without mixing their results or cache quotas.
    pub fn submit_to(
        &self,
        job: Job,
        cache: Option<Arc<AnalysisCache>>,
        reply: &mpsc::Sender<JobResult>,
    ) -> Result<(), ApiError> {
        self.submit_with(job, cache, reply.clone())
    }

    fn submit_with(
        &self,
        job: Job,
        cache: Option<Arc<AnalysisCache>>,
        reply: mpsc::Sender<JobResult>,
    ) -> Result<(), ApiError> {
        let Some(tx) = self.tx.as_ref() else {
            return Err(ApiError::new(
                ErrorCode::Internal,
                "worker pool is shut down",
            ));
        };
        match tx.try_send(Work::Run(Assignment { job, cache, reply })) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(_)) => Err(ApiError::new(
                ErrorCode::Overloaded,
                format!(
                    "submission queue is full ({} jobs waiting); retry later",
                    self.queue_bound
                ),
            )),
            Err(TrySendError::Disconnected(_)) => Err(ApiError::new(
                ErrorCode::Internal,
                "worker pool is gone (every worker exited)",
            )),
        }
    }

    /// Collect exactly `n` results of [`Coordinator::submit`]-ed jobs
    /// (blocking). Errors with `internal` — instead of panicking — if the
    /// result channel dies before delivering them all.
    pub fn collect(&self, n: usize) -> Result<Vec<JobResult>, ApiError> {
        let rx = self.results_rx.lock().unwrap_or_else(|e| e.into_inner());
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(rx.recv().map_err(|_| {
                ApiError::new(
                    ErrorCode::Internal,
                    "worker pool died before delivering every result",
                )
            })?);
        }
        Ok(out)
    }

    /// Queue a job that panics inside the worker's catch-unwind — the
    /// regression harness for "a panicking job must leave the pool
    /// serving".
    #[cfg(test)]
    fn submit_panic_for_test(&self, id: u64) -> Result<(), ApiError> {
        let tx = self.tx.as_ref().expect("pool alive");
        tx.try_send(Work::PanicInJob {
            id,
            reply: self.results_tx.clone(),
        })
        .map_err(|_| ApiError::new(ErrorCode::Overloaded, "queue full"))
    }

    /// Explicit shutdown; equivalent to dropping the pool.
    pub fn shutdown(self) {}
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.tx.take(); // close the queue; workers drain what was admitted
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// JSON-lines server: one request object per line on stdin, one response
/// per line on stdout. Speaks the v1 envelope and the legacy v0 shapes
/// (`docs/SERVICE.md`); holds one [`ApiHandler`] — and therefore one
/// [`AnalysisCache`] — for the whole session, so repeat requests are
/// answered incrementally.
pub fn serve_stdio(input: impl BufRead, mut output: impl Write) -> crate::util::Result<()> {
    let handler = ApiHandler::new();
    pump_lines(&handler, input, &mut output)
}

/// The line pump shared by [`serve_stdio`] and the CLI's socket-serving
/// stdio session: one request line in, one response line out. The output
/// is flushed after **every** response — behind a block-buffered pipe a
/// request/response client would otherwise deadlock waiting for a reply
/// sitting in this process's buffer — and once more on shutdown.
pub fn pump_lines(
    handler: &ApiHandler,
    input: impl BufRead,
    output: &mut impl Write,
) -> crate::util::Result<()> {
    for line in input.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        writeln!(output, "{}", handler.handle_wire(&line))?;
        output.flush()?;
    }
    output.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::test_fixtures::{CHAIN_TSV, TINY_SPEC};
    use crate::util::Json;
    use crate::workflow::scenario::Perturbation;

    fn analyze_job(id: u64, spec: &str) -> Job {
        Job {
            id,
            request: Request::Analyze {
                spec: spec.to_string(),
            },
        }
    }

    fn sweep_job(id: u64, fractions: &[f64]) -> Job {
        Job {
            id,
            request: Request::Sweep {
                workflow: crate::api::WorkflowSel::Video,
                perturbations: fractions.iter().map(|&f| Perturbation::Fraction(f)).collect(),
            },
        }
    }

    fn makespan(r: &JobResult) -> f64 {
        match r.outcome.as_ref().unwrap() {
            Response::Analyze(a) => a.makespan.unwrap(),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn pool_processes_jobs() {
        let c = Coordinator::new(3);
        for id in 0..6 {
            c.submit(analyze_job(id, TINY_SPEC)).unwrap();
        }
        let mut results = c.collect(6).unwrap();
        c.shutdown();
        results.sort_by_key(|r| r.id);
        assert_eq!(results.len(), 6);
        for r in &results {
            let mk = makespan(r);
            assert!((mk - 5.0).abs() < 1e-6, "{mk}");
        }
    }

    /// A job that panics inside a worker must come back as an `internal`
    /// error while the pool keeps serving every other job — the poisoned
    /// state a panic leaves behind (caught unwind, possibly a poisoned
    /// shard or queue mutex) must never cascade.
    #[test]
    fn panicking_job_leaves_pool_serving() {
        let c = Coordinator::new(2);
        c.submit_panic_for_test(99).unwrap();
        for id in 0..4 {
            c.submit(analyze_job(id, TINY_SPEC)).unwrap();
        }
        let mut results = c.collect(5).unwrap();
        results.sort_by_key(|r| r.id);
        let panicked = results.iter().find(|r| r.id == 99).unwrap();
        assert_eq!(
            panicked.outcome.as_ref().unwrap_err().code,
            ErrorCode::Internal
        );
        for r in results.iter().filter(|r| r.id != 99) {
            let mk = makespan(r);
            assert!((mk - 5.0).abs() < 1e-6, "job {} after panic: {mk}", r.id);
        }
    }

    /// The worker queue survives a mutex poisoned by a panicking holder.
    #[test]
    fn recv_work_recovers_from_poisoned_mutex() {
        let (tx, rx) = mpsc::channel::<u32>();
        let rx = Arc::new(Mutex::new(rx));
        let poisoner = Arc::clone(&rx);
        let _ = std::thread::spawn(move || {
            let _guard = poisoner.lock().unwrap();
            panic!("poison the queue mutex");
        })
        .join();
        assert!(rx.lock().is_err(), "mutex must actually be poisoned");
        tx.send(7).unwrap();
        assert_eq!(recv_work(&rx), Some(7));
        drop(tx);
        assert_eq!(recv_work(&rx), None);
    }

    /// With one busy worker and a queue bound of 1, further submissions
    /// must fail fast with `overloaded` — never block or panic.
    #[test]
    fn full_queue_reports_overloaded() {
        let c = Coordinator::with_queue_bound(1, Arc::new(AnalysisCache::new()), 1);
        // occupy the worker with a non-trivial job, then flood: the queue
        // admits at most one waiter, so the flood must trip admission
        // control long before the worker can drain 50 analyses
        c.submit(sweep_job(0, &[0.25, 0.5, 0.75])).unwrap();
        let mut accepted = 1;
        let mut overloaded = None;
        for id in 1..=50 {
            match c.submit(analyze_job(id, TINY_SPEC)) {
                Ok(()) => accepted += 1,
                Err(e) => {
                    overloaded = Some(e);
                    break;
                }
            }
        }
        let e = overloaded.expect("a 50-deep flood must overload a 1-deep queue");
        assert_eq!(e.code, ErrorCode::Overloaded);
        assert!(e.message.contains("retry"), "{}", e.message);
        // everything that was admitted still completes
        let results = c.collect(accepted).unwrap();
        assert_eq!(results.len(), accepted);
        assert!(results.iter().all(|r| r.outcome.is_ok()));
    }

    /// Legacy v0 requests still round-trip through the stdio server with
    /// the flat payload shape, now tagged deprecated.
    #[test]
    fn stdio_server_roundtrip() {
        let spec_json = Json::parse(TINY_SPEC).unwrap();
        let req = Json::obj(vec![
            ("id", Json::Num(7.0)),
            ("op", Json::Str("analyze".into())),
            ("spec", spec_json),
        ]);
        let input = format!("{req}\n{{\"op\": \"ping\", \"id\": 8}}\n");
        let mut out = Vec::new();
        serve_stdio(std::io::Cursor::new(input), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let r1 = Json::parse(lines[0]).unwrap();
        assert_eq!(r1.get("id").as_f64(), Some(7.0));
        assert!((r1.get("makespan").as_f64().unwrap() - 5.0).abs() < 1e-6);
        assert_eq!(r1.get("deprecated").as_bool(), Some(true));
        let r2 = Json::parse(lines[1]).unwrap();
        assert_eq!(r2.get("pong").as_bool(), Some(true));
        assert_eq!(r2.get("deprecated").as_bool(), Some(true));
    }

    /// A block-buffered client would deadlock if responses sat in the
    /// server's write buffer: every response line must be followed by a
    /// flush.
    #[test]
    fn stdio_flushes_after_every_response() {
        #[derive(Default)]
        struct FlushCounter {
            buf: Vec<u8>,
            flushes: usize,
            flushed_bytes: usize,
        }
        impl Write for FlushCounter {
            fn write(&mut self, data: &[u8]) -> std::io::Result<usize> {
                self.buf.extend_from_slice(data);
                Ok(data.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                self.flushes += 1;
                self.flushed_bytes = self.buf.len();
                Ok(())
            }
        }
        let input = "{\"v\":1,\"id\":1,\"op\":\"ping\"}\n{\"v\":1,\"id\":2,\"op\":\"ping\"}\n";
        let mut w = FlushCounter::default();
        serve_stdio(std::io::Cursor::new(input), &mut w).unwrap();
        assert!(w.flushes >= 2, "one flush per response, got {}", w.flushes);
        assert_eq!(
            w.flushed_bytes,
            w.buf.len(),
            "the final flush must cover every written byte"
        );
        let text = String::from_utf8(w.buf).unwrap();
        assert_eq!(text.lines().count(), 2);
    }

    #[test]
    fn bad_spec_reports_error() {
        let r = run_job(&analyze_job(1, "{}"));
        let e = r.outcome.unwrap_err();
        assert_eq!(e.code, ErrorCode::InvalidSpec);
    }

    #[test]
    fn sweep_job_reports_best_fraction_and_bottlenecks() {
        let r = run_job(&sweep_job(9, &[0.25, 0.5, 0.75, 0.93]));
        assert_eq!(r.id, 9);
        let s = match r.outcome.unwrap() {
            Response::Sweep(s) => s,
            other => panic!("{other:?}"),
        };
        let (best_i, _) = s.best.unwrap();
        assert_eq!(best_i, 3, "0.93 wins the batch");
        assert_eq!(s.makespans.len(), 4);
        // the incremental engine reports its cache behaviour
        let stats = s.cache.expect("cache stats attached");
        assert!(stats.hit_rate() >= 0.0);
        assert!(!s.ranked.is_empty());
        assert!(s.ranked.iter().any(|b| b.bottleneck == "res:link"));
    }

    /// A degenerate request (fraction 0 starves dl1 forever, so the
    /// barrier node's dependency never finishes) must come back as a typed
    /// error — not a panic that kills the server.
    #[test]
    fn degenerate_fraction_reports_error_not_panic() {
        let r = run_job(&sweep_job(4, &[0.0]));
        let e = r.outcome.unwrap_err();
        assert_eq!(e.code, ErrorCode::AnalysisFailed);
    }

    #[test]
    fn empty_sweep_is_an_error() {
        let r = run_job(&sweep_job(2, &[]));
        let e = r.outcome.unwrap_err();
        assert_eq!(e.code, ErrorCode::BadRequest);
    }

    #[test]
    fn stdio_sweep_op() {
        let input = "{\"op\": \"sweep\", \"id\": 3, \"fractions\": [0.5, 0.9]}\n";
        let mut out = Vec::new();
        serve_stdio(std::io::Cursor::new(input), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let resp = Json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(resp.get("id").as_f64(), Some(3.0));
        assert_eq!(resp.get("totals").as_arr().unwrap().len(), 2);
        assert!((resp.get("best_fraction").as_f64().unwrap() - 0.9).abs() < 1e-9);
        assert_eq!(resp.get("deprecated").as_bool(), Some(true));
    }

    fn calibrate_job(id: u64, tsv: &str) -> Job {
        Job {
            id,
            request: Request::Calibrate {
                tsv: tsv.to_string(),
                io: None,
                tol: None,
            },
        }
    }

    #[test]
    fn calibrate_job_reports_replay_error() {
        let r = run_job(&calibrate_job(11, CHAIN_TSV));
        assert_eq!(r.id, 11);
        let c = match r.outcome.unwrap() {
            Response::Calibrate(c) => c,
            other => panic!("{other:?}"),
        };
        assert_eq!(c.tasks.len(), 2);
        assert_eq!(c.tasks[0].id, "dl");
        assert_eq!(c.tasks[0].model, "summary/stream");
        let mk = c.predicted_makespan.unwrap();
        assert!((mk - 20.0).abs() < 0.1, "{mk}");
        assert!(c.max_rel_err.unwrap() < 0.01);
    }

    #[test]
    fn calibrate_job_reports_parse_errors() {
        let r = run_job(&calibrate_job(
            12,
            "task_id\tdeps\trealtime\trchar\twchar\na\t-\t5\toops\t1\n",
        ));
        let e = r.outcome.unwrap_err();
        assert_eq!(e.code, ErrorCode::InvalidTrace);
        assert!(
            e.message.contains("line 2") && e.message.contains("oops"),
            "{}",
            e.message
        );
    }

    #[test]
    fn stdio_calibrate_op() {
        let req = Json::obj(vec![
            ("id", Json::Num(5.0)),
            ("op", Json::Str("calibrate".into())),
            ("tsv", Json::Str(CHAIN_TSV.into())),
        ]);
        let input = format!("{req}\n{{\"op\": \"calibrate\", \"id\": 6}}\n");
        let mut out = Vec::new();
        serve_stdio(std::io::Cursor::new(input), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let r1 = Json::parse(lines[0]).unwrap();
        assert_eq!(r1.get("id").as_f64(), Some(5.0));
        assert_eq!(r1.get("tasks").as_arr().unwrap().len(), 2);
        assert!(r1.get("max_rel_err").as_f64().unwrap() < 0.01);
        // missing tsv field is a per-request error, not a dead server
        let r2 = Json::parse(lines[1]).unwrap();
        assert!(r2.get("error").as_str().unwrap().contains("tsv"));
    }

    /// A malformed 'io' field must error, not silently degrade to the
    /// summary-only fallback.
    #[test]
    fn stdio_calibrate_rejects_non_string_io() {
        let req = Json::obj(vec![
            ("id", Json::Num(9.0)),
            ("op", Json::Str("calibrate".into())),
            ("tsv", Json::Str(CHAIN_TSV.into())),
            ("io", Json::Num(42.0)),
        ]);
        let mut out = Vec::new();
        serve_stdio(std::io::Cursor::new(format!("{req}\n")), &mut out).unwrap();
        let resp = Json::parse(String::from_utf8(out).unwrap().lines().next().unwrap())
            .unwrap();
        assert!(
            resp.get("error").as_str().unwrap().contains("io"),
            "{resp:?}"
        );
        // explicit null is fine (treated as absent)
        let req = Json::obj(vec![
            ("id", Json::Num(10.0)),
            ("op", Json::Str("calibrate".into())),
            ("tsv", Json::Str(CHAIN_TSV.into())),
            ("io", Json::Null),
        ]);
        let mut out = Vec::new();
        serve_stdio(std::io::Cursor::new(format!("{req}\n")), &mut out).unwrap();
        let resp = Json::parse(String::from_utf8(out).unwrap().lines().next().unwrap())
            .unwrap();
        assert_eq!(resp.get("tasks").as_arr().unwrap().len(), 2);
    }

    /// The server holds one analysis cache for the session: a repeated
    /// sweep request re-solves nothing, identical results, and the stats
    /// are reported per request (not lifetime totals).
    #[test]
    fn stdio_sweep_reuses_cache_across_requests() {
        let line = "{\"op\": \"sweep\", \"id\": 1, \"fractions\": [0.5, 0.9]}\n";
        let input = format!("{line}{line}");
        let mut out = Vec::new();
        serve_stdio(std::io::Cursor::new(input), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let r1 = Json::parse(lines[0]).unwrap();
        let r2 = Json::parse(lines[1]).unwrap();
        assert_eq!(r1.get("totals"), r2.get("totals"));
        assert_eq!(r1.get("ranked_bottlenecks"), r2.get("ranked_bottlenecks"));
        let c1 = r1.get("cache");
        let c2 = r2.get("cache");
        assert!(c1.get("misses").as_f64().unwrap() > 0.0);
        assert_eq!(c2.get("misses").as_f64(), Some(0.0), "{c2:?}");
        assert!(c2.get("hits").as_f64().unwrap() > 0.0);
    }
}
