//! Versioned request decoding: the v1 envelope and the legacy v0 shim.
//!
//! A v1 request is one JSON object per line with an explicit envelope:
//!
//! ```json
//! {"v": 1, "id": 7, "op": "sweep", "workflow": "genomics",
//!  "perturbations": [{"kind": "link_rate_scale", "value": 2}]}
//! ```
//!
//! * `v` — protocol version ([`PROTOCOL_VERSION`]). Missing (or `0`) means
//!   a **legacy v0** request: the pre-envelope shapes keep working through
//!   the v0 shim, and their responses are tagged `"deprecated": true`.
//!   Any other version is rejected with `unsupported_version`.
//! * `id` — a required non-negative integer, echoed verbatim on every
//!   response (including errors; `null` when the id itself was
//!   missing/invalid or the line did not parse).
//! * `op` + op-specific fields — see `docs/SERVICE.md`.
//!
//! Decoding is *strict*: wrong-typed fields are `bad_request` errors, not
//! silent defaults. All decode errors are structured [`ApiError`]s; this
//! module never panics on wire input.

use crate::util::Json;
use crate::workflow::scenario::Perturbation;

use super::error::{ApiError, ErrorCode};

/// The protocol version this build speaks natively.
pub const PROTOCOL_VERSION: u64 = 1;

/// Which workflow model a `sweep` runs over.
#[derive(Clone, Debug, PartialEq)]
pub enum WorkflowSel {
    /// The built-in Fig 5 video scenario (the default).
    Video,
    /// The built-in genomics scenario.
    Genomics,
    /// An inline workflow spec (the `model::spec` JSON schema, as text).
    Spec(String),
    /// A model calibrated from a raw trace (TSV text + optional I/O log).
    Trace { tsv: String, io: Option<String> },
}

/// A fully decoded API request — the single typed surface behind the CLI,
/// the stdio service and the worker pool.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Ping,
    Analyze {
        /// The workflow spec as JSON text.
        spec: String,
    },
    Sweep {
        workflow: WorkflowSel,
        perturbations: Vec<Perturbation>,
    },
    /// Per-knob makespan sensitivities, confidence band and ranked
    /// fix-this-first advice for a workflow (`docs/SENSITIVITY.md`).
    Sensitivity {
        workflow: WorkflowSel,
        /// Relative finite-difference step override (`SenseOpts::h`).
        h: Option<f64>,
    },
    Calibrate {
        tsv: String,
        io: Option<String>,
        /// Segment-fit tolerance override (`CalibrateOpts::tol`).
        tol: Option<f64>,
    },
    /// Heterogeneous requests executed through the worker pool in one
    /// call; results come back in submission order. Batches cannot nest.
    Batch { requests: Vec<Request> },
    /// Open this session's live monitor (`docs/LIVE.md`). At most one per
    /// session; a `Trace` selector seeds it with an initial feed.
    MonitorOpen {
        workflow: WorkflowSel,
        /// Segment-fit tolerance override (`CalibrateOpts::tol`).
        tol: Option<f64>,
    },
    /// Feed trace events (TSV rows and/or I/O samples) to the open
    /// monitor; the response carries the refreshed prediction.
    MonitorFeed {
        tsv: Option<String>,
        io: Option<String>,
    },
    /// Report the open monitor's state; `close: true` also closes it.
    MonitorStatus { close: bool },
    /// Global service counters (uptime, sessions, in-flight requests,
    /// per-op totals). `mask: true` zeroes the time-varying fields so the
    /// response bytes are reproducible (the conformance corpus uses it).
    Stats { mask: bool },
}

/// One decoded wire line: the response dialect (`v == 0` → legacy), the
/// echoed id (`None` when missing or invalid), and the request or its
/// decode error.
#[derive(Clone, Debug)]
pub struct Wire {
    pub v: u64,
    pub id: Option<u64>,
    pub body: Result<Request, ApiError>,
}

/// Decode one wire line (JSON parse + envelope + body).
pub fn decode_line(line: &str) -> Wire {
    match Json::parse(line) {
        Ok(j) => decode_value(&j),
        Err(e) => Wire {
            v: PROTOCOL_VERSION,
            id: None,
            body: Err(ApiError::bad_request(format!("bad request: {e}"))),
        },
    }
}

/// Decode one parsed request object.
pub fn decode_value(j: &Json) -> Wire {
    let id = j.get("id").as_u64();
    let v = match j.get("v") {
        Json::Null => 0,
        val => match val.as_u64() {
            Some(n) => n,
            None => {
                return Wire {
                    v: PROTOCOL_VERSION,
                    id,
                    body: Err(ApiError::bad_request(
                        "envelope field 'v' must be a non-negative integer",
                    )),
                }
            }
        },
    };
    if v != 0 && v != PROTOCOL_VERSION {
        return Wire {
            v: PROTOCOL_VERSION,
            id,
            body: Err(ApiError::new(
                ErrorCode::UnsupportedVersion,
                format!("unsupported protocol version {v} (supported: {PROTOCOL_VERSION})"),
            )),
        };
    }
    let body = if id.is_none() {
        Err(ApiError::bad_request(
            "request 'id' must be a non-negative integer",
        ))
    } else if v == 0 {
        decode_v0(j)
    } else {
        decode_v1_body(j)
    };
    Wire { v, id, body }
}

fn decode_v1_body(j: &Json) -> Result<Request, ApiError> {
    let op = j
        .get("op")
        .as_str()
        .ok_or_else(|| ApiError::bad_request("request needs a string 'op' field"))?;
    decode_v1_op(op, j, true)
}

/// One v1 op body. `allow_batch` is false for items nested inside a
/// `batch` request (batches cannot nest).
fn decode_v1_op(op: &str, j: &Json, allow_batch: bool) -> Result<Request, ApiError> {
    match op {
        "ping" => Ok(Request::Ping),
        "analyze" => {
            let spec = j.get("spec");
            if spec.as_obj().is_none() {
                return Err(ApiError::bad_request("analyze needs an object 'spec' field"));
            }
            Ok(Request::Analyze {
                spec: spec.to_string(),
            })
        }
        "sweep" => Ok(Request::Sweep {
            workflow: decode_workflow_sel(j.get("workflow"))?,
            perturbations: decode_perturbations(j)?,
        }),
        "sensitivity" => {
            let h = match j.get("h") {
                Json::Null => None,
                val => match val.as_f64() {
                    Some(x) if x > 0.0 && x.is_finite() => Some(x),
                    _ => {
                        return Err(ApiError::bad_request(
                            "sensitivity 'h' must be a positive number",
                        ))
                    }
                },
            };
            Ok(Request::Sensitivity {
                workflow: decode_workflow_sel(j.get("workflow"))?,
                h,
            })
        }
        "calibrate" => {
            let tsv = j
                .get("tsv")
                .as_str()
                .ok_or_else(|| ApiError::bad_request("calibrate needs a 'tsv' string field"))?
                .to_string();
            let io = match j.get("io") {
                Json::Null => None,
                Json::Str(s) => Some(s.clone()),
                _ => {
                    return Err(ApiError::bad_request(
                        "calibrate 'io' must be a string when present",
                    ))
                }
            };
            let tol = match j.get("tol") {
                Json::Null => None,
                val => match val.as_f64() {
                    Some(t) if t > 0.0 && t.is_finite() => Some(t),
                    _ => {
                        return Err(ApiError::bad_request(
                            "calibrate 'tol' must be a positive number",
                        ))
                    }
                },
            };
            Ok(Request::Calibrate { tsv, io, tol })
        }
        "monitor_open" => {
            let tol = match j.get("tol") {
                Json::Null => None,
                val => match val.as_f64() {
                    Some(t) if t > 0.0 && t.is_finite() => Some(t),
                    _ => {
                        return Err(ApiError::bad_request(
                            "monitor_open 'tol' must be a positive number",
                        ))
                    }
                },
            };
            let bands = match j.get("bands") {
                Json::Null => false,
                val => val.as_bool().ok_or_else(|| {
                    ApiError::bad_request("monitor_open 'bands' must be a boolean")
                })?,
            };
            Ok(Request::MonitorOpen {
                workflow: decode_workflow_sel(j.get("workflow"))?,
                tol,
                bands,
            })
        }
        "monitor_feed" => {
            let field = |name: &str| match j.get(name) {
                Json::Null => Ok(None),
                Json::Str(s) => Ok(Some(s.clone())),
                _ => Err(ApiError::bad_request(format!(
                    "monitor_feed '{name}' must be a string when present"
                ))),
            };
            let tsv = field("tsv")?;
            let io = field("io")?;
            if tsv.is_none() && io.is_none() {
                return Err(ApiError::bad_request(
                    "monitor_feed needs a 'tsv' or 'io' string field",
                ));
            }
            Ok(Request::MonitorFeed { tsv, io })
        }
        "monitor_status" => {
            let close = match j.get("close") {
                Json::Null => false,
                val => val.as_bool().ok_or_else(|| {
                    ApiError::bad_request("monitor_status 'close' must be a boolean")
                })?,
            };
            Ok(Request::MonitorStatus { close })
        }
        "stats" => {
            let mask = match j.get("mask") {
                Json::Null => false,
                val => val
                    .as_bool()
                    .ok_or_else(|| ApiError::bad_request("stats 'mask' must be a boolean"))?,
            };
            Ok(Request::Stats { mask })
        }
        "batch" => {
            if !allow_batch {
                return Err(ApiError::bad_request("batch requests cannot nest"));
            }
            let items = j
                .get("requests")
                .as_arr()
                .ok_or_else(|| ApiError::bad_request("batch needs a 'requests' array"))?;
            if items.is_empty() {
                return Err(ApiError::bad_request("batch needs at least one request"));
            }
            let mut requests = Vec::with_capacity(items.len());
            for (i, item) in items.iter().enumerate() {
                // `detail.index` always names the offending batch *item*;
                // an inner error's own detail (e.g. a perturbation index)
                // moves under `detail.in_item`
                let tag = |mut e: ApiError| {
                    let mut fields = vec![("index", Json::Num(i as f64))];
                    if let Some(inner) = e.detail.take() {
                        fields.push(("in_item", inner));
                    }
                    e.with_detail(Json::obj(fields))
                };
                let op = item.get("op").as_str().ok_or_else(|| {
                    tag(ApiError::bad_request(format!(
                        "batch item {i} needs a string 'op' field"
                    )))
                })?;
                requests.push(decode_v1_op(op, item, false).map_err(tag)?);
            }
            Ok(Request::Batch { requests })
        }
        other => Err(ApiError::new(
            ErrorCode::UnknownOp,
            format!("unknown op {other:?}"),
        )),
    }
}

fn decode_workflow_sel(j: &Json) -> Result<WorkflowSel, ApiError> {
    match j {
        Json::Null => Ok(WorkflowSel::Video),
        Json::Str(name) => match name.as_str() {
            "video" => Ok(WorkflowSel::Video),
            "genomics" => Ok(WorkflowSel::Genomics),
            other => Err(ApiError::bad_request(format!(
                "unknown workflow '{other}' (named workflows: \"video\", \"genomics\")"
            ))),
        },
        Json::Obj(_) => {
            let spec = j.get("spec");
            let trace = j.get("trace");
            match (spec, trace) {
                (Json::Obj(_), Json::Null) => Ok(WorkflowSel::Spec(spec.to_string())),
                (Json::Null, Json::Obj(_)) => {
                    let tsv = trace
                        .get("tsv")
                        .as_str()
                        .ok_or_else(|| {
                            ApiError::bad_request("workflow.trace needs a 'tsv' string field")
                        })?
                        .to_string();
                    let io = match trace.get("io") {
                        Json::Null => None,
                        Json::Str(s) => Some(s.clone()),
                        _ => {
                            return Err(ApiError::bad_request(
                                "workflow.trace 'io' must be a string when present",
                            ))
                        }
                    };
                    Ok(WorkflowSel::Trace { tsv, io })
                }
                _ => Err(ApiError::bad_request(
                    "workflow object needs exactly one of 'spec' (object) or 'trace' (object)",
                )),
            }
        }
        _ => Err(ApiError::bad_request(
            "'workflow' must be a name or an object",
        )),
    }
}

fn decode_perturbations(j: &Json) -> Result<Vec<Perturbation>, ApiError> {
    let ps = j.get("perturbations");
    let fr = j.get("fractions");
    match (ps, fr) {
        (Json::Null, Json::Null) => Err(ApiError::bad_request(
            "sweep needs a 'perturbations' (or 'fractions') array",
        )),
        (Json::Arr(items), Json::Null) => {
            if items.is_empty() {
                return Err(ApiError::bad_request("sweep needs at least one perturbation"));
            }
            let mut out = Vec::with_capacity(items.len());
            for (i, item) in items.iter().enumerate() {
                out.push(Perturbation::from_json(item).map_err(|m| {
                    ApiError::bad_request(m)
                        .with_detail(Json::obj(vec![("index", Json::Num(i as f64))]))
                })?);
            }
            Ok(out)
        }
        (Json::Null, Json::Arr(xs)) => {
            if xs.is_empty() {
                return Err(ApiError::bad_request("sweep needs at least one fraction"));
            }
            xs.iter()
                .enumerate()
                .map(|(i, x)| {
                    x.as_f64().map(Perturbation::Fraction).ok_or_else(|| {
                        ApiError::bad_request("'fractions' must be an array of numbers")
                            .with_detail(Json::obj(vec![("index", Json::Num(i as f64))]))
                    })
                })
                .collect()
        }
        (Json::Null, _) => Err(ApiError::bad_request("'fractions' must be an array")),
        (_, Json::Null) => Err(ApiError::bad_request("'perturbations' must be an array")),
        _ => Err(ApiError::bad_request(
            "sweep takes 'perturbations' or 'fractions', not both",
        )),
    }
}

/// The legacy v0 shim: the pre-envelope request shapes, mapped onto the
/// same typed [`Request`]s. Field semantics and error strings are
/// preserved verbatim from the v0 server so old clients see identical
/// behaviour (plus the `"deprecated": true` response tag).
fn decode_v0(j: &Json) -> Result<Request, ApiError> {
    match j.get("op").as_str() {
        Some("ping") => Ok(Request::Ping),
        // v0 forwarded the spec verbatim (object or not) and let the model
        // parser report the failure; keep that
        Some("analyze") => Ok(Request::Analyze {
            spec: j.get("spec").to_string(),
        }),
        Some("sweep") => {
            let fractions: Vec<f64> = match j.get("fractions").as_arr() {
                Some(a) => a.iter().filter_map(|x| x.as_f64()).collect(),
                None => {
                    // the canonical Fig-7 grid — same helper as the CLI,
                    // advisor and exporter, so the shim cannot diverge
                    let n = (j.get("points").as_f64().unwrap_or(40.0) as usize).max(1);
                    crate::coordinator::sweeper::fig7_fractions(n)
                }
            };
            if fractions.is_empty() {
                return Err(ApiError::bad_request("sweep needs at least one fraction"));
            }
            Ok(Request::Sweep {
                workflow: WorkflowSel::Video,
                perturbations: fractions.into_iter().map(Perturbation::Fraction).collect(),
            })
        }
        Some("calibrate") => match (j.get("tsv").as_str(), j.get("io")) {
            (None, _) => Err(ApiError::bad_request(
                "calibrate needs a 'tsv' string field",
            )),
            // a malformed 'io' must not silently degrade to the
            // summary-only fallback
            (Some(_), io) if !matches!(io, Json::Null | Json::Str(_)) => Err(
                ApiError::bad_request("calibrate 'io' must be a string when present"),
            ),
            (Some(tsv), io) => Ok(Request::Calibrate {
                tsv: tsv.to_string(),
                io: io.as_str().map(str::to_string),
                tol: None,
            }),
        },
        other => Err(ApiError::new(
            ErrorCode::UnknownOp,
            format!("unknown op {other:?}"),
        )),
    }
}

impl WorkflowSel {
    /// The v1 wire encoding of the selector.
    pub fn to_json(&self) -> Json {
        match self {
            WorkflowSel::Video => Json::Str("video".to_string()),
            WorkflowSel::Genomics => Json::Str("genomics".to_string()),
            WorkflowSel::Spec(text) => Json::obj(vec![(
                "spec",
                Json::parse(text).unwrap_or(Json::Null),
            )]),
            WorkflowSel::Trace { tsv, io } => {
                let mut fields = vec![("tsv", Json::Str(tsv.clone()))];
                if let Some(io) = io {
                    fields.push(("io", Json::Str(io.clone())));
                }
                Json::obj(vec![("trace", Json::obj(fields))])
            }
        }
    }
}

impl Request {
    /// The v1 JSON body (op + params, no envelope). `decode` ∘ `to_json`
    /// is the identity for every well-formed request.
    pub fn to_json(&self) -> Json {
        match self {
            Request::Ping => Json::obj(vec![("op", Json::Str("ping".to_string()))]),
            Request::Analyze { spec } => Json::obj(vec![
                ("op", Json::Str("analyze".to_string())),
                ("spec", Json::parse(spec).unwrap_or(Json::Null)),
            ]),
            Request::Sweep {
                workflow,
                perturbations,
            } => Json::obj(vec![
                ("op", Json::Str("sweep".to_string())),
                ("workflow", workflow.to_json()),
                (
                    "perturbations",
                    Json::Arr(perturbations.iter().map(|p| p.to_json()).collect()),
                ),
            ]),
            Request::Sensitivity { workflow, h } => {
                let mut fields = vec![
                    ("op", Json::Str("sensitivity".to_string())),
                    ("workflow", workflow.to_json()),
                ];
                if let Some(h) = h {
                    fields.push(("h", Json::Num(*h)));
                }
                Json::obj(fields)
            }
            Request::Calibrate { tsv, io, tol } => {
                let mut fields = vec![
                    ("op", Json::Str("calibrate".to_string())),
                    ("tsv", Json::Str(tsv.clone())),
                ];
                if let Some(io) = io {
                    fields.push(("io", Json::Str(io.clone())));
                }
                if let Some(t) = tol {
                    fields.push(("tol", Json::Num(*t)));
                }
                Json::obj(fields)
            }
            Request::Batch { requests } => Json::obj(vec![
                ("op", Json::Str("batch".to_string())),
                (
                    "requests",
                    Json::Arr(requests.iter().map(|r| r.to_json()).collect()),
                ),
            ]),
            Request::MonitorOpen {
                workflow,
                tol,
                bands,
            } => {
                let mut fields = vec![
                    ("op", Json::Str("monitor_open".to_string())),
                    ("workflow", workflow.to_json()),
                ];
                if let Some(t) = tol {
                    fields.push(("tol", Json::Num(*t)));
                }
                if *bands {
                    fields.push(("bands", Json::Bool(true)));
                }
                Json::obj(fields)
            }
            Request::MonitorFeed { tsv, io } => {
                let mut fields = vec![("op", Json::Str("monitor_feed".to_string()))];
                if let Some(t) = tsv {
                    fields.push(("tsv", Json::Str(t.clone())));
                }
                if let Some(i) = io {
                    fields.push(("io", Json::Str(i.clone())));
                }
                Json::obj(fields)
            }
            Request::MonitorStatus { close } => {
                let mut fields = vec![("op", Json::Str("monitor_status".to_string()))];
                if *close {
                    fields.push(("close", Json::Bool(true)));
                }
                Json::obj(fields)
            }
            Request::Stats { mask } => {
                let mut fields = vec![("op", Json::Str("stats".to_string()))];
                if *mask {
                    fields.push(("mask", Json::Bool(true)));
                }
                Json::obj(fields)
            }
        }
    }

    /// The wire op name — the key the service's per-op request counters
    /// ([`super::handler::ServiceStats`]) aggregate under.
    pub fn op_name(&self) -> &'static str {
        match self {
            Request::Ping => "ping",
            Request::Analyze { .. } => "analyze",
            Request::Sweep { .. } => "sweep",
            Request::Sensitivity { .. } => "sensitivity",
            Request::Calibrate { .. } => "calibrate",
            Request::Batch { .. } => "batch",
            Request::MonitorOpen { .. } => "monitor_open",
            Request::MonitorFeed { .. } => "monitor_feed",
            Request::MonitorStatus { .. } => "monitor_status",
            Request::Stats { .. } => "stats",
        }
    }
}

/// Wrap a request body in the full v1 envelope.
pub fn encode_request(id: u64, req: &Request) -> Json {
    match req.to_json() {
        Json::Obj(mut m) => {
            m.insert("id".to_string(), Json::Num(id as f64));
            m.insert("v".to_string(), Json::Num(PROTOCOL_VERSION as f64));
            Json::Obj(m)
        }
        other => other, // unreachable: request bodies are objects
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn v1_envelope_decodes() {
        let w = decode_line(r#"{"v": 1, "id": 7, "op": "ping"}"#);
        assert_eq!(w.v, 1);
        assert_eq!(w.id, Some(7));
        assert_eq!(w.body.unwrap(), Request::Ping);
    }

    #[test]
    fn missing_or_fractional_id_is_rejected() {
        for line in [
            r#"{"v": 1, "op": "ping"}"#,
            r#"{"v": 1, "id": 1.5, "op": "ping"}"#,
            r#"{"v": 1, "id": "7", "op": "ping"}"#,
            r#"{"v": 1, "id": -2, "op": "ping"}"#,
            r#"{"op": "ping"}"#, // the v0 shim requires an id too, now
        ] {
            let w = decode_line(line);
            assert_eq!(w.id, None, "{line}");
            let e = w.body.unwrap_err();
            assert_eq!(e.code, ErrorCode::BadRequest, "{line}");
            assert!(e.message.contains("'id'"), "{line}: {}", e.message);
        }
    }

    #[test]
    fn unsupported_version_is_typed() {
        let w = decode_line(r#"{"v": 3, "id": 1, "op": "ping"}"#);
        assert_eq!(w.body.unwrap_err().code, ErrorCode::UnsupportedVersion);
        // the id still rides along for the response
        assert_eq!(w.id, Some(1));
    }

    #[test]
    fn legacy_shapes_map_onto_v1() {
        let w = decode_line(r#"{"id": 2, "op": "sweep", "fractions": [0.5, 0.9]}"#);
        assert_eq!(w.v, 0);
        match w.body.unwrap() {
            Request::Sweep {
                workflow,
                perturbations,
            } => {
                assert_eq!(workflow, WorkflowSel::Video);
                assert_eq!(
                    perturbations,
                    vec![Perturbation::Fraction(0.5), Perturbation::Fraction(0.9)]
                );
            }
            other => panic!("{other:?}"),
        }
        // points sugar
        let w = decode_line(r#"{"id": 3, "op": "sweep", "points": 3}"#);
        match w.body.unwrap() {
            Request::Sweep { perturbations, .. } => assert_eq!(perturbations.len(), 3),
            other => panic!("{other:?}"),
        }
    }

    /// An unknown perturbation kind on the wire is `ErrorCode::BadRequest`
    /// (the satellite contract), with the offending index in `detail`.
    #[test]
    fn unknown_perturbation_kind_is_bad_request() {
        let w = decode_line(
            r#"{"v": 1, "id": 4, "op": "sweep", "perturbations": [{"kind": "identity"}, {"kind": "warp"}]}"#,
        );
        let e = w.body.unwrap_err();
        assert_eq!(e.code, ErrorCode::BadRequest);
        assert!(e.message.contains("unknown perturbation kind 'warp'"), "{}", e.message);
        assert_eq!(e.detail.unwrap().get("index").as_f64(), Some(1.0));
    }

    #[test]
    fn sweep_request_roundtrips_through_v1_json() {
        let req = Request::Sweep {
            workflow: WorkflowSel::Genomics,
            perturbations: vec![
                Perturbation::LinkRateScale(2.0),
                Perturbation::Identity,
                Perturbation::Task2Burst,
            ],
        };
        let wire = encode_request(11, &req);
        let w = decode_value(&wire);
        assert_eq!(w.v, 1);
        assert_eq!(w.id, Some(11));
        assert_eq!(w.body.unwrap(), req);
    }

    #[test]
    fn batches_cannot_nest() {
        let w = decode_line(
            r#"{"v": 1, "id": 5, "op": "batch", "requests": [{"op": "batch", "requests": [{"op": "ping"}]}]}"#,
        );
        let e = w.body.unwrap_err();
        assert!(e.message.contains("cannot nest"), "{}", e.message);
        assert_eq!(e.detail.unwrap().get("index").as_f64(), Some(0.0));
    }

    #[test]
    fn monitor_ops_decode_and_roundtrip() {
        let w = decode_line(r#"{"v": 1, "id": 1, "op": "monitor_open", "workflow": "video"}"#);
        assert_eq!(
            w.body.unwrap(),
            Request::MonitorOpen {
                workflow: WorkflowSel::Video,
                tol: None,
                bands: false,
            }
        );
        // selector defaults to video, like sweep
        let w = decode_line(r#"{"v": 1, "id": 2, "op": "monitor_open"}"#);
        assert!(matches!(
            w.body.unwrap(),
            Request::MonitorOpen {
                workflow: WorkflowSel::Video,
                ..
            }
        ));
        let w = decode_line(r#"{"v": 1, "id": 3, "op": "monitor_feed", "tsv": "x"}"#);
        assert_eq!(
            w.body.unwrap(),
            Request::MonitorFeed {
                tsv: Some("x".to_string()),
                io: None
            }
        );
        let w = decode_line(r#"{"v": 1, "id": 4, "op": "monitor_status", "close": true}"#);
        assert_eq!(w.body.unwrap(), Request::MonitorStatus { close: true });

        for req in [
            Request::MonitorOpen {
                workflow: WorkflowSel::Trace {
                    tsv: "task_id\n".to_string(),
                    io: None,
                },
                tol: Some(0.05),
                bands: true,
            },
            Request::MonitorFeed {
                tsv: Some("a\t1\n".to_string()),
                io: Some("a 0 1 2\n".to_string()),
            },
            Request::MonitorStatus { close: false },
            Request::MonitorStatus { close: true },
        ] {
            let w = decode_value(&encode_request(9, &req));
            assert_eq!(w.body.unwrap(), req);
        }
    }

    #[test]
    fn monitor_op_field_errors_are_bad_request() {
        for line in [
            r#"{"v": 1, "id": 1, "op": "monitor_feed"}"#,
            r#"{"v": 1, "id": 2, "op": "monitor_feed", "tsv": 7}"#,
            r#"{"v": 1, "id": 3, "op": "monitor_status", "close": "yes"}"#,
            r#"{"v": 1, "id": 4, "op": "monitor_open", "tol": -1}"#,
            r#"{"v": 1, "id": 5, "op": "monitor_open", "bands": "yes"}"#,
        ] {
            let e = decode_line(line).body.unwrap_err();
            assert_eq!(e.code, ErrorCode::BadRequest, "{line}");
        }
    }

    #[test]
    fn sensitivity_and_stats_decode_and_roundtrip() {
        // selector defaults to video, like sweep
        let w = decode_line(r#"{"v": 1, "id": 1, "op": "sensitivity"}"#);
        assert_eq!(
            w.body.unwrap(),
            Request::Sensitivity {
                workflow: WorkflowSel::Video,
                h: None
            }
        );
        let w = decode_line(
            r#"{"v": 1, "id": 2, "op": "sensitivity", "workflow": "genomics", "h": 0.001}"#,
        );
        assert_eq!(
            w.body.unwrap(),
            Request::Sensitivity {
                workflow: WorkflowSel::Genomics,
                h: Some(0.001)
            }
        );
        let w = decode_line(r#"{"v": 1, "id": 3, "op": "stats"}"#);
        assert_eq!(w.body.unwrap(), Request::Stats { mask: false });
        let w = decode_line(r#"{"v": 1, "id": 4, "op": "stats", "mask": true}"#);
        assert_eq!(w.body.unwrap(), Request::Stats { mask: true });

        for line in [
            r#"{"v": 1, "id": 5, "op": "sensitivity", "h": 0}"#,
            r#"{"v": 1, "id": 6, "op": "sensitivity", "h": "small"}"#,
            r#"{"v": 1, "id": 7, "op": "stats", "mask": 1}"#,
        ] {
            let e = decode_line(line).body.unwrap_err();
            assert_eq!(e.code, ErrorCode::BadRequest, "{line}");
        }

        for req in [
            Request::Sensitivity {
                workflow: WorkflowSel::Genomics,
                h: Some(0.01),
            },
            Request::Sensitivity {
                workflow: WorkflowSel::Video,
                h: None,
            },
            Request::Stats { mask: true },
            Request::Stats { mask: false },
            Request::MonitorOpen {
                workflow: WorkflowSel::Video,
                tol: None,
                bands: true,
            },
        ] {
            let w = decode_value(&encode_request(9, &req));
            assert_eq!(w.body.unwrap(), req);
        }
    }

    #[test]
    fn op_names_cover_every_request() {
        let cases: Vec<(Request, &str)> = vec![
            (Request::Ping, "ping"),
            (Request::Stats { mask: false }, "stats"),
            (
                Request::Sensitivity {
                    workflow: WorkflowSel::Video,
                    h: None,
                },
                "sensitivity",
            ),
            (Request::MonitorStatus { close: false }, "monitor_status"),
            (
                Request::Batch {
                    requests: vec![Request::Ping],
                },
                "batch",
            ),
        ];
        for (req, name) in cases {
            assert_eq!(req.op_name(), name);
            // op_name always matches the wire encoding's 'op' field
            assert_eq!(req.to_json().get("op").as_str(), Some(name));
        }
    }

    /// `detail.index` names the failing batch *item*; an inner error's own
    /// detail (here: the perturbation index inside the item) nests under
    /// `detail.in_item`.
    #[test]
    fn batch_decode_detail_indexes_the_item() {
        let w = decode_line(
            r#"{"v": 1, "id": 6, "op": "batch", "requests": [{"op": "ping"}, {"op": "sweep", "perturbations": [{"kind": "identity"}, {"kind": "warp"}]}]}"#,
        );
        let e = w.body.unwrap_err();
        let detail = e.detail.unwrap();
        assert_eq!(detail.get("index").as_f64(), Some(1.0), "{detail}");
        assert_eq!(detail.get("in_item").get("index").as_f64(), Some(1.0));
    }
}
