//! The typed, versioned request/response boundary of the system.
//!
//! Everything that crosses a process boundary — the CLI, the JSON-lines
//! stdio service (`bottlemod serve`), the in-process worker pool — speaks
//! through this one layer:
//!
//! * [`Request`] / [`Response`] — the typed op vocabulary (`ping`,
//!   `analyze`, generic `sweep` over any [`request::WorkflowSel`],
//!   `calibrate`, heterogeneous `batch`, the `sensitivity` report op
//!   (`docs/SENSITIVITY.md`), the service-scoped `stats` counters op, and
//!   the session-scoped `monitor_open` / `monitor_feed` / `monitor_status`
//!   live-monitor ops, `docs/LIVE.md`);
//! * [`request::decode_line`] / [`response::encode`] — the `{"v": 1, ...}`
//!   envelope with a legacy-v0 compatibility shim (pre-envelope shapes
//!   keep working, tagged `"deprecated": true`);
//! * [`ApiError`] / [`ErrorCode`] — the structured error taxonomy that
//!   replaced the ad-hoc `{"error": "..."}` strings;
//! * [`ApiHandler`] — the session front end owning the analysis cache and
//!   the `batch` worker pool.
//!
//! Wire reference with runnable, CI-conformance-checked examples:
//! `docs/SERVICE.md`.

pub mod error;
pub mod handler;
pub mod request;
pub mod response;

pub use error::{ApiError, ErrorCode};
pub use handler::{execute, execute_with_threads, ApiHandler, ServiceStats};
pub use request::{
    decode_line, decode_value, encode_request, Request, Wire, WorkflowSel, PROTOCOL_VERSION,
};
pub use response::{
    encode, encode_v0, encode_v1, AnalyzeResult, CalibrateResult, MonitorResult, Response,
    ScheduleRow, SegmentRow, StatsSnapshot, SweepResult,
};

/// Workloads shared by the in-crate protocol test suites (the
/// integration test `tests/service_protocol.rs` keeps its own copy —
/// `cfg(test)` items are invisible across crate boundaries).
#[cfg(test)]
pub(crate) mod test_fixtures {
    /// A one-process spec solving to makespan 5.
    pub(crate) const TINY_SPEC: &str = r#"{
      "processes": [
        {"name": "a", "max_progress": 10.0,
         "data": [{"req": {"type": "stream", "total": 10.0},
                   "source": {"external_constant": 10.0}}],
         "resources": [{"req": {"type": "stream", "total": 5.0},
                        "source": {"constant": 1.0}}],
         "outputs": [{"name": "out", "type": "identity"}]}
      ]
    }"#;

    /// A two-task chain trace: dl (10 s) → enc (completes at 20 s).
    pub(crate) const CHAIN_TSV: &str =
        "task_id\tdeps\tstart\tcomplete\trealtime\tpcpu\trchar\twchar\tpeak_rss\n\
         dl\t-\t0\t10\t10\t1e9\t1e8\t1e8\t2e6\n\
         enc\tdl\t0\t20\t20\t100\t1e8\t5e7\t8e6\n";
}
