//! Typed results and the versioned response encoders.
//!
//! Handlers produce [`Response`] values built from domain types
//! ([`crate::trace::TaskSummary`], [`crate::runtime::sweep::RankedBottleneck`],
//! [`crate::runtime::cache::CacheStats`], ...); encoding to the wire
//! happens here and only here:
//!
//! * [`encode_v1`] — the v1 envelope
//!   `{"v": 1, "id": ..., "ok": true, "result": {...}}` /
//!   `{"v": 1, "id": ..., "ok": false, "error": {...}}`;
//! * [`encode_v0`] — the legacy flat payload (identical field-for-field to
//!   the pre-envelope server), tagged `"deprecated": true`.
//!
//! Object keys serialize sorted (`Json::Obj` is a `BTreeMap`), so every
//! response is byte-deterministic — the property the golden protocol tests
//! and the docs-conformance CI step pin.

use std::collections::BTreeMap;

use crate::live::{Advisory, FeedReport, MonitorStatus, Snapshot};
use crate::runtime::cache::CacheStats;
use crate::runtime::sweep::RankedBottleneck;
use crate::trace::TaskSummary;
use crate::util::Json;
use crate::workflow::scenario::Perturbation;

use super::error::ApiError;
use super::request::PROTOCOL_VERSION;

/// One row of an analysis schedule.
#[derive(Clone, Debug)]
pub struct ScheduleRow {
    pub name: String,
    pub start: f64,
    pub finish: Option<f64>,
}

/// One maximal constant-bottleneck segment of one process.
#[derive(Clone, Debug)]
pub struct SegmentRow {
    pub process: String,
    pub start: f64,
    pub end: f64,
    /// `"res:link"`, `"data:video"`, `"unconstrained"`, ...
    pub bottleneck: String,
}

/// Result of an `analyze` op.
#[derive(Clone, Debug)]
pub struct AnalyzeResult {
    pub makespan: Option<f64>,
    pub events: usize,
    pub passes: usize,
    pub schedule: Vec<ScheduleRow>,
    pub bottlenecks: Vec<SegmentRow>,
}

/// Result of a generic `sweep` op.
#[derive(Clone, Debug)]
pub struct SweepResult {
    /// Workload label (`"video"`, `"genomics"`, `"spec"`, `"trace"`).
    pub workflow: String,
    /// The evaluated batch, echoed in order.
    pub perturbations: Vec<Perturbation>,
    /// Per-scenario completion time (`None` = never finishes), batch order.
    pub makespans: Vec<Option<f64>>,
    /// Argmin over the finished scenarios: `(batch index, makespan)`.
    pub best: Option<(usize, f64)>,
    /// Total solver events across the batch.
    pub events: usize,
    /// Ranked cross-scenario bottlenecks, descending by limited seconds.
    pub ranked: Vec<RankedBottleneck>,
    /// Incremental-engine statistics for this request.
    pub cache: Option<CacheStats>,
}

/// Result of a `stats` op: a point-in-time snapshot of the service's
/// global counters. With `mask: true` on the request every time-varying
/// field is zeroed and `ops` is empty, so the bytes are reproducible.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StatsSnapshot {
    pub uptime_secs: f64,
    /// Live sessions right now (socket transports only).
    pub sessions_open: u64,
    /// Sessions accepted since the server started.
    pub sessions_total: u64,
    /// Requests currently being handled — the queue-depth proxy.
    pub inflight: u64,
    /// Requests shed by admission control (`overloaded` responses).
    pub overloaded: u64,
    /// Completed-request totals keyed by wire op name.
    pub ops: BTreeMap<String, u64>,
}

/// Result of a `calibrate` op.
#[derive(Clone, Debug)]
pub struct CalibrateResult {
    pub tasks: Vec<TaskSummary>,
    pub predicted_makespan: Option<f64>,
    pub observed_makespan: Option<f64>,
    pub max_rel_err: Option<f64>,
    pub events: usize,
    pub passes: usize,
}

/// Result of one of the session-scoped monitor ops (`docs/LIVE.md`).
#[derive(Clone, Debug)]
pub enum MonitorResult {
    /// `monitor_open` — the workload label plus, for a `Trace` selector,
    /// the report of the seeding feed.
    Opened {
        workflow: String,
        feed: Option<FeedReport>,
    },
    /// `monitor_feed` — what the event changed and the live prediction.
    Feed(FeedReport),
    /// `monitor_status` — session summary; `closed` when the op closed it.
    Status {
        status: MonitorStatus,
        closed: bool,
    },
}

/// A typed API response, paired with [`super::request::Request`].
#[derive(Clone, Debug)]
pub enum Response {
    Pong,
    Analyze(AnalyzeResult),
    Sweep(SweepResult),
    /// A ranked per-knob sensitivity report (`docs/SENSITIVITY.md`).
    Sensitivity(crate::sense::Report),
    Calibrate(CalibrateResult),
    /// Per-item outcomes of a `batch`, in submission order.
    Batch(Vec<Result<Response, ApiError>>),
    Monitor(MonitorResult),
    Stats(StatsSnapshot),
}

fn opt_num(x: Option<f64>) -> Json {
    x.map(Json::Num).unwrap_or(Json::Null)
}

fn cache_json(s: &CacheStats) -> Json {
    Json::obj(vec![
        ("hits", Json::Num(s.hits as f64)),
        ("misses", Json::Num(s.misses as f64)),
        ("hit_rate", Json::Num(s.hit_rate())),
        ("entries", Json::Num(s.entries as f64)),
        ("bytes", Json::Num(s.bytes as f64)),
        ("evictions", Json::Num(s.evictions as f64)),
    ])
}

fn ranked_json(rows: &[RankedBottleneck]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj(vec![
                    ("process", Json::Str(r.process.clone())),
                    ("bottleneck", Json::Str(r.bottleneck.clone())),
                    ("total_seconds", Json::Num(r.total_seconds)),
                    ("scenarios", Json::Num(r.scenarios as f64)),
                ])
            })
            .collect(),
    )
}

fn analyze_json(r: &AnalyzeResult) -> Json {
    let schedule: Vec<Json> = r
        .schedule
        .iter()
        .map(|s| {
            Json::obj(vec![
                ("name", Json::Str(s.name.clone())),
                ("start", Json::Num(s.start)),
                ("finish", opt_num(s.finish)),
            ])
        })
        .collect();
    let bottlenecks: Vec<Json> = r
        .bottlenecks
        .iter()
        .map(|s| {
            Json::obj(vec![
                ("process", Json::Str(s.process.clone())),
                ("start", Json::Num(s.start)),
                ("end", Json::Num(s.end)),
                ("bottleneck", Json::Str(s.bottleneck.clone())),
            ])
        })
        .collect();
    Json::obj(vec![
        ("makespan", opt_num(r.makespan)),
        ("events", Json::Num(r.events as f64)),
        ("passes", Json::Num(r.passes as f64)),
        ("schedule", Json::Arr(schedule)),
        ("bottlenecks", Json::Arr(bottlenecks)),
    ])
}

fn calibrate_json(r: &CalibrateResult) -> Json {
    let tasks: Vec<Json> = r
        .tasks
        .iter()
        .map(|s| {
            Json::obj(vec![
                ("id", Json::Str(s.id.clone())),
                ("model", Json::Str(s.model.clone())),
                ("data_pieces", Json::Num(s.data_pieces as f64)),
                ("res_pieces", Json::Num(s.res_pieces as f64)),
                ("predicted_start", Json::Num(s.predicted_start)),
                ("predicted", opt_num(s.predicted)),
                ("observed", opt_num(s.observed)),
                ("rel_err", opt_num(s.rel_err)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("tasks", Json::Arr(tasks)),
        ("predicted_makespan", opt_num(r.predicted_makespan)),
        ("observed_makespan", opt_num(r.observed_makespan)),
        ("max_rel_err", opt_num(r.max_rel_err)),
        ("events", Json::Num(r.events as f64)),
        ("passes", Json::Num(r.passes as f64)),
    ])
}

fn pair_json(p: &(String, String)) -> Json {
    Json::obj(vec![
        ("process", Json::Str(p.0.clone())),
        ("bottleneck", Json::Str(p.1.clone())),
    ])
}

fn snapshot_json(s: &Snapshot) -> Json {
    let ranked: Vec<Json> = s
        .ranked
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("process", Json::Str(r.process.clone())),
                ("bottleneck", Json::Str(r.bottleneck.clone())),
                ("seconds", Json::Num(r.seconds)),
            ])
        })
        .collect();
    let mut fields = vec![
        ("tasks", Json::Num(s.tasks as f64)),
        ("makespan", opt_num(s.makespan)),
        ("now", Json::Num(s.now)),
        ("remaining", opt_num(s.remaining)),
        (
            "bottleneck",
            s.bottleneck.as_ref().map(pair_json).unwrap_or(Json::Null),
        ),
        ("ranked", Json::Arr(ranked)),
        ("events", Json::Num(s.solver_events as f64)),
        ("passes", Json::Num(s.passes as f64)),
    ];
    // only monitors opened with `bands: true` carry a band — absent here,
    // the pinned snapshot bytes predating the field stay intact
    if let Some(b) = &s.band {
        fields.push((
            "band",
            Json::obj(vec![
                ("lower", Json::Num(b.lower)),
                ("median", Json::Num(b.median)),
                ("upper", Json::Num(b.upper)),
            ]),
        ));
    }
    Json::obj(fields)
}

fn stats_json(s: &StatsSnapshot) -> Json {
    let ops: BTreeMap<String, Json> = s
        .ops
        .iter()
        .map(|(k, v)| (k.clone(), Json::Num(*v as f64)))
        .collect();
    Json::obj(vec![
        ("uptime_secs", Json::Num(s.uptime_secs)),
        ("sessions_open", Json::Num(s.sessions_open as f64)),
        ("sessions_total", Json::Num(s.sessions_total as f64)),
        ("inflight", Json::Num(s.inflight as f64)),
        ("overloaded", Json::Num(s.overloaded as f64)),
        ("ops", Json::Obj(ops)),
    ])
}

fn advisory_json(a: &Advisory) -> Json {
    let mut fields = vec![
        (
            "from",
            a.shift.from.as_ref().map(pair_json).unwrap_or(Json::Null),
        ),
        ("to", pair_json(&a.shift.to)),
    ];
    if let Some(rec) = &a.recommendation {
        fields.push((
            "recommendation",
            Json::obj(vec![
                ("best_fraction", Json::Num(rec.best_fraction)),
                ("best_total", Json::Num(rec.best_total)),
                ("baseline_total", Json::Num(rec.fair_total)),
                ("gain", Json::Num(rec.gain)),
            ]),
        ));
    }
    if let Some(note) = &a.note {
        fields.push(("note", Json::Str(note.clone())));
    }
    Json::obj(fields)
}

fn feed_json(r: &FeedReport) -> Json {
    let mut fields = vec![
        ("event", Json::Num(r.event as f64)),
        ("refit", Json::Num(r.refit as f64)),
        ("reused", Json::Num(r.reused as f64)),
        (
            "dirty",
            Json::Arr(r.dirty.iter().map(|d| Json::Str(d.clone())).collect()),
        ),
        ("cache", cache_json(&r.cache)),
    ];
    if let Some(s) = &r.stale {
        fields.push(("stale", Json::Str(s.clone())));
    }
    if let Some(snap) = &r.snapshot {
        fields.push(("snapshot", snapshot_json(snap)));
    }
    if let Some(adv) = &r.advisory {
        fields.push(("advisory", advisory_json(adv)));
    }
    Json::obj(fields)
}

fn monitor_json(r: &MonitorResult) -> Json {
    let inner = match r {
        MonitorResult::Opened { workflow, feed } => {
            let mut fields = vec![
                ("opened", Json::Bool(true)),
                ("workflow", Json::Str(workflow.clone())),
            ];
            if let Some(f) = feed {
                fields.push(("feed", feed_json(f)));
            }
            Json::obj(fields)
        }
        MonitorResult::Feed(f) => Json::obj(vec![("feed", feed_json(f))]),
        MonitorResult::Status { status, closed } => {
            let mut fields = vec![
                ("label", Json::Str(status.label.clone())),
                ("events", Json::Num(status.events as f64)),
                ("advisories", Json::Num(status.advisories as f64)),
                ("tasks", Json::Num(status.tasks as f64)),
                ("pending_series", Json::Num(status.pending_series as f64)),
                ("cache", cache_json(&status.cache)),
            ];
            if let Some(snap) = &status.snapshot {
                fields.push(("snapshot", snapshot_json(snap)));
            }
            if *closed {
                fields.push(("closed", Json::Bool(true)));
            }
            Json::obj(fields)
        }
    };
    Json::obj(vec![("monitor", inner)])
}

fn sweep_json_v1(r: &SweepResult) -> Json {
    let best = match r.best {
        Some((i, t)) => Json::obj(vec![
            ("index", Json::Num(i as f64)),
            ("makespan", Json::Num(t)),
            ("perturbation", r.perturbations[i].to_json()),
        ]),
        None => Json::Null,
    };
    let mut fields = vec![
        ("workflow", Json::Str(r.workflow.clone())),
        (
            "perturbations",
            Json::Arr(r.perturbations.iter().map(|p| p.to_json()).collect()),
        ),
        (
            "makespans",
            Json::Arr(r.makespans.iter().map(|m| opt_num(*m)).collect()),
        ),
        ("best", best),
        ("events", Json::Num(r.events as f64)),
        ("ranked_bottlenecks", ranked_json(&r.ranked)),
    ];
    if let Some(s) = &r.cache {
        fields.push(("cache", cache_json(s)));
    }
    Json::obj(fields)
}

/// The legacy Fig-5 fraction-sweep shape (x-axis echoed as `fractions`,
/// top-8 ranked bottlenecks) — only reachable from v0 requests, whose
/// perturbations are all `Fraction`s by construction.
fn sweep_json_v0(r: &SweepResult) -> Json {
    let fractions: Vec<f64> = r
        .perturbations
        .iter()
        .map(|p| match p {
            Perturbation::Fraction(f) => *f,
            _ => f64::NAN,
        })
        .collect();
    let totals: Vec<f64> = r
        .makespans
        .iter()
        .map(|m| m.unwrap_or(f64::INFINITY))
        .collect();
    let (best_f, best_t) = match r.best {
        Some((i, t)) => (Json::Num(fractions[i]), Json::Num(t)),
        None => (Json::Null, Json::Null),
    };
    let top = &r.ranked[..r.ranked.len().min(8)];
    let mut fields = vec![
        ("fractions", Json::arr_f64(&fractions)),
        ("totals", Json::arr_f64(&totals)),
        ("best_fraction", best_f),
        ("best_total", best_t),
        ("events", Json::Num(r.events as f64)),
        ("ranked_bottlenecks", ranked_json(top)),
    ];
    if let Some(s) = &r.cache {
        fields.push(("cache", cache_json(s)));
    }
    Json::obj(fields)
}

impl Response {
    /// The v1 `result` payload.
    pub fn result_json(&self) -> Json {
        match self {
            Response::Pong => Json::obj(vec![("pong", Json::Bool(true))]),
            Response::Analyze(r) => analyze_json(r),
            Response::Sweep(r) => sweep_json_v1(r),
            Response::Sensitivity(r) => {
                // the canonical report plus cache bookkeeping as a sibling
                // key, mirroring sweep_json_v1 (the report's own bytes stay
                // thread-count-independent)
                match r.to_json() {
                    Json::Obj(mut m) => {
                        if let Some(s) = &r.cache {
                            m.insert("cache".to_string(), cache_json(s));
                        }
                        Json::Obj(m)
                    }
                    other => other, // unreachable: reports encode as objects
                }
            }
            Response::Calibrate(r) => calibrate_json(r),
            Response::Batch(items) => {
                let results: Vec<Json> = items
                    .iter()
                    .map(|item| match item {
                        Ok(r) => Json::obj(vec![
                            ("ok", Json::Bool(true)),
                            ("result", r.result_json()),
                        ]),
                        Err(e) => Json::obj(vec![
                            ("ok", Json::Bool(false)),
                            ("error", e.to_json()),
                        ]),
                    })
                    .collect();
                Json::obj(vec![("results", Json::Arr(results))])
            }
            Response::Monitor(r) => monitor_json(r),
            Response::Stats(s) => stats_json(s),
        }
    }

    /// The flat pre-envelope payload (v0 dialect).
    fn legacy_payload(&self) -> Json {
        match self {
            Response::Sweep(r) => sweep_json_v0(r),
            // ping/analyze/calibrate payloads are identical in both
            // dialects; batch is unreachable from v0 (no such op)
            other => other.result_json(),
        }
    }
}

/// Encode a v1 response envelope.
pub fn encode_v1(id: Option<u64>, outcome: &Result<Response, ApiError>) -> Json {
    let id_json = id.map(|i| Json::Num(i as f64)).unwrap_or(Json::Null);
    match outcome {
        Ok(r) => Json::obj(vec![
            ("v", Json::Num(PROTOCOL_VERSION as f64)),
            ("id", id_json),
            ("ok", Json::Bool(true)),
            ("result", r.result_json()),
        ]),
        Err(e) => Json::obj(vec![
            ("v", Json::Num(PROTOCOL_VERSION as f64)),
            ("id", id_json),
            ("ok", Json::Bool(false)),
            ("error", e.to_json()),
        ]),
    }
}

/// Encode a legacy (v0) response: the flat pre-envelope shape — errors as
/// plain `{"error": "<message>"}` strings — tagged `"deprecated": true`.
pub fn encode_v0(id: Option<u64>, outcome: &Result<Response, ApiError>) -> Json {
    let payload = match outcome {
        Ok(r) => r.legacy_payload(),
        Err(e) => Json::obj(vec![("error", Json::Str(e.message.clone()))]),
    };
    let mut obj = match payload {
        Json::Obj(m) => m,
        other => {
            let mut m = BTreeMap::new();
            m.insert("result".to_string(), other);
            m
        }
    };
    obj.insert(
        "id".to_string(),
        id.map(|i| Json::Num(i as f64)).unwrap_or(Json::Null),
    );
    obj.insert("deprecated".to_string(), Json::Bool(true));
    Json::Obj(obj)
}

/// Encode in the dialect the request was decoded as (`v == 0` → legacy).
pub fn encode(v: u64, id: Option<u64>, outcome: &Result<Response, ApiError>) -> Json {
    if v == 0 {
        encode_v0(id, outcome)
    } else {
        encode_v1(id, outcome)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn v1_envelopes_are_byte_deterministic() {
        let ok = encode_v1(Some(1), &Ok(Response::Pong));
        assert_eq!(ok.to_string(), r#"{"id":1,"ok":true,"result":{"pong":true},"v":1}"#);
        let err = encode_v1(None, &Err(ApiError::bad_request("nope")));
        assert_eq!(
            err.to_string(),
            r#"{"error":{"code":"bad_request","message":"nope"},"id":null,"ok":false,"v":1}"#
        );
    }

    #[test]
    fn v0_is_flat_and_tagged_deprecated() {
        let ok = encode_v0(Some(8), &Ok(Response::Pong));
        assert_eq!(ok.to_string(), r#"{"deprecated":true,"id":8,"pong":true}"#);
        let err = encode_v0(Some(3), &Err(ApiError::bad_request("kaput")));
        assert_eq!(
            err.to_string(),
            r#"{"deprecated":true,"error":"kaput","id":3}"#
        );
    }

    /// The minimal monitor payloads (no analysis yet) are byte-exact —
    /// these are the shapes the docs conformance corpus pins.
    #[test]
    fn monitor_payloads_are_byte_deterministic() {
        let opened = Response::Monitor(MonitorResult::Opened {
            workflow: "video".to_string(),
            feed: None,
        });
        assert_eq!(
            encode_v1(Some(1), &Ok(opened)).to_string(),
            r#"{"id":1,"ok":true,"result":{"monitor":{"opened":true,"workflow":"video"}},"v":1}"#
        );
        let feed = Response::Monitor(MonitorResult::Feed(FeedReport {
            event: 1,
            refit: 0,
            reused: 0,
            dirty: vec![],
            cache: CacheStats::default(),
            stale: None,
            snapshot: None,
            advisory: None,
        }));
        assert_eq!(
            encode_v1(Some(2), &Ok(feed)).to_string(),
            concat!(
                r#"{"id":2,"ok":true,"result":{"monitor":{"feed":{"cache":"#,
                r#"{"bytes":0,"entries":0,"evictions":0,"hit_rate":0,"hits":0,"misses":0},"#,
                r#""dirty":[],"event":1,"refit":0,"reused":0}}},"v":1}"#
            )
        );
        let status = Response::Monitor(MonitorResult::Status {
            status: MonitorStatus {
                label: "video".to_string(),
                events: 1,
                advisories: 0,
                tasks: 0,
                pending_series: 0,
                cache: CacheStats::default(),
                snapshot: None,
            },
            closed: true,
        });
        assert_eq!(
            encode_v1(Some(3), &Ok(status)).to_string(),
            concat!(
                r#"{"id":3,"ok":true,"result":{"monitor":{"advisories":0,"cache":"#,
                r#"{"bytes":0,"entries":0,"evictions":0,"hit_rate":0,"hits":0,"misses":0},"#,
                r#""closed":true,"events":1,"label":"video","pending_series":0,"tasks":0}},"v":1}"#
            )
        );
    }

    /// The masked `stats` payload is byte-exact (the conformance corpus
    /// pins it), and a banded snapshot encodes its band under sorted keys.
    #[test]
    fn stats_and_banded_snapshot_are_byte_deterministic() {
        let masked = encode_v1(Some(9), &Ok(Response::Stats(StatsSnapshot::default())));
        assert_eq!(
            masked.to_string(),
            concat!(
                r#"{"id":9,"ok":true,"result":{"inflight":0,"ops":{},"overloaded":0,"#,
                r#""sessions_open":0,"sessions_total":0,"uptime_secs":0},"v":1}"#
            )
        );
        let mut ops = BTreeMap::new();
        ops.insert("ping".to_string(), 2u64);
        ops.insert("sweep".to_string(), 1u64);
        let live = Response::Stats(StatsSnapshot {
            uptime_secs: 1.5,
            sessions_open: 1,
            sessions_total: 3,
            inflight: 1,
            overloaded: 0,
            ops,
        });
        let j = encode_v1(Some(10), &Ok(live)).to_string();
        assert!(j.contains(r#""ops":{"ping":2,"sweep":1}"#), "{j}");
        assert!(j.contains(r#""uptime_secs":1.5"#), "{j}");

        let snap = Snapshot {
            tasks: 1,
            makespan: Some(23.0),
            now: 23.0,
            remaining: Some(0.0),
            bottleneck: None,
            ranked: vec![],
            solver_events: 4,
            passes: 2,
            band: Some(crate::sense::Band {
                lower: 21.5,
                median: 23.0,
                upper: 25.0,
            }),
        };
        assert_eq!(
            snapshot_json(&snap).to_string(),
            concat!(
                r#"{"band":{"lower":21.5,"median":23,"upper":25},"bottleneck":null,"#,
                r#""events":4,"makespan":23,"now":23,"passes":2,"ranked":[],"#,
                r#""remaining":0,"tasks":1}"#
            )
        );
    }

    #[test]
    fn v0_sweep_payload_keeps_the_legacy_shape() {
        let r = SweepResult {
            workflow: "video".to_string(),
            perturbations: vec![Perturbation::Fraction(0.5), Perturbation::Fraction(0.9)],
            makespans: vec![Some(263.0), Some(181.0)],
            best: Some((1, 181.0)),
            events: 10,
            ranked: vec![],
            cache: None,
        };
        let j = encode_v0(Some(2), &Ok(Response::Sweep(r)));
        assert_eq!(j.get("fractions").as_arr().unwrap().len(), 2);
        assert_eq!(j.get("best_fraction").as_f64(), Some(0.9));
        assert_eq!(j.get("best_total").as_f64(), Some(181.0));
        assert_eq!(j.get("totals").as_arr().unwrap()[0].as_f64(), Some(263.0));
        assert_eq!(j.get("deprecated").as_bool(), Some(true));
        // v1 of the same result uses the generic shape
        let j1 = encode_v1(Some(2), &Ok(Response::Sweep(SweepResult {
            workflow: "video".to_string(),
            perturbations: vec![Perturbation::Fraction(0.5)],
            makespans: vec![None],
            best: None,
            events: 1,
            ranked: vec![],
            cache: None,
        })));
        let res = j1.get("result");
        assert_eq!(res.get("workflow").as_str(), Some("video"));
        assert_eq!(res.get("makespans").as_arr().unwrap()[0], Json::Null);
        assert_eq!(res.get("best"), &Json::Null);
    }
}
